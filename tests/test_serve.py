"""Async serving plane tests (DESIGN.md §17).

Covers the epoch-snapshot primitive (a scan started before an ingest
must not see its rows; snapshot-local JIT promotion never touches the
parent), thread-safety of the shared ResultCache and TelemetryPlane,
CiaoServeEngine correctness (quiesced counts bit-identical to the
``matches_exact`` oracle across host / batch / device modes),
backpressure (block and reject), tenant-tier admission control, and a
threaded stress sweep with concurrent writers and mixed-mode readers.
"""
from __future__ import annotations

import gc
import json
import random
import threading
import time
import weakref

import pytest

from repro.core.batch_scan import ResultCache, ScanBatcher
from repro.core.client import NumpyEngine, encode_chunk
from repro.core.predicates import Query
from repro.core.server import (
    CiaoStore, DataSkippingScanner, PlanFamily, PushdownPlan, ScanResult,
    StaleEpochError,
)
from repro.core.shard import (
    ShardedCiaoStore, ShardedScanner, ShardRouter, choose_routing_key,
)
from repro.core.telemetry import TelemetryPlane
from repro.data.datasets import generate_records, predicate_pool
from repro.serve.store_engine import (
    AdmissionError, BackpressureError, CiaoServeEngine, QueryAdmission,
    TierPolicy,
)

N_RECORDS = 3000
CHUNK = 250


@pytest.fixture(scope="module")
def ycsb():
    recs = generate_records("ycsb", N_RECORDS, seed=7)
    objs = [json.loads(r) for r in recs]
    pool = predicate_pool("ycsb")
    return recs, objs, pool


def _family(pool) -> PlanFamily:
    # tier 0 has EMPTY coverage: its chunks stay raw remainders, so the
    # JIT-promotion path is exercised by every sweep below
    return PlanFamily(plan=PushdownPlan(clauses=pool[:6]),
                      tier_sizes=(0, 2, 6))


def _encode_chunks(recs, fam):
    eng = NumpyEngine()
    out = []
    for i, start in enumerate(range(0, len(recs), CHUNK)):
        ch = encode_chunk(recs[start:start + CHUNK])
        tier = i % fam.n_tiers
        bv = eng.eval_fused_prefix(ch, fam.plan.clauses,
                                   fam.tier_sizes[tier])
        out.append((ch, bv, tier))
    return out


def _oracle(objs, q: Query) -> int:
    return sum(1 for o in objs if q.matches_exact(o))


# ---------------------------------------------------------------------------
# snapshot isolation
# ---------------------------------------------------------------------------

def test_snapshot_isolation_plain(ycsb):
    """A snapshot pins its view: rows ingested after snapshot() are
    invisible to scans against it, while the live store sees them."""
    recs, objs, pool = ycsb
    fam = _family(pool)
    chunks = _encode_chunks(recs, fam)
    half = len(chunks) // 2
    half_rows = half * CHUNK

    store = CiaoStore(fam, segment_capacity=256)
    for ch, bv, tier in chunks[:half]:
        store.ingest_chunk(ch, bv, epoch=fam.plan.epoch, tier=tier)
    snap = store.snapshot()
    base = snap.base_version
    snap_scanner = DataSkippingScanner(snap, telemetry=False)

    for ch, bv, tier in chunks[half:]:
        store.ingest_chunk(ch, bv, epoch=fam.plan.epoch, tier=tier)

    live_scanner = DataSkippingScanner(store)
    for k in range(6):
        q = Query(clauses=(pool[k],))
        snap_count = snap_scanner.scan(q).count
        assert snap_count == _oracle(objs[:half_rows], q)
        assert live_scanner.scan(q).count == _oracle(objs, q)
    # untainted reads keep the pinned base version
    q_pushed = Query(clauses=(pool[0],))
    assert snap.base_version == base
    assert store.data_version > base


def test_snapshot_isolation_sharded(ycsb):
    recs, objs, pool = ycsb
    fam = _family(pool)
    chunks = _encode_chunks(recs, fam)
    half = len(chunks) // 2
    half_rows = half * CHUNK

    router = ShardRouter(n_shards=4, key=choose_routing_key(fam.plan))
    store = ShardedCiaoStore(fam, router=router, segment_capacity=256)
    for ch, bv, tier in chunks[:half]:
        store.ingest_chunk(ch, bv, epoch=fam.plan.epoch, tier=tier)
    snap = store.snapshot()
    scanner = ShardedScanner(snap, telemetry=False)
    batcher = ScanBatcher(snap, cache=ResultCache(), telemetry=False)

    for ch, bv, tier in chunks[half:]:
        store.ingest_chunk(ch, bv, epoch=fam.plan.epoch, tier=tier)

    queries = [Query(clauses=(pool[k],)) for k in range(6)]
    for q in queries:
        assert scanner.scan(q).count == _oracle(objs[:half_rows], q)
    got = [r.count for r in batcher.scan_batch(queries)]
    assert got == [_oracle(objs[:half_rows], q) for q in queries]


def test_snapshot_local_promotion_leaves_parent_untouched(ycsb):
    """JIT promotion triggered by a snapshot scan stays snapshot-local:
    the parent keeps its raw remainders and data_version, and the
    snapshot's version forks negative so ResultCache entries from
    different lineages can never alias."""
    recs, objs, pool = ycsb
    fam = _family(pool)
    chunks = _encode_chunks(recs, fam)

    store = CiaoStore(fam, segment_capacity=256)
    for ch, bv, tier in chunks:
        store.ingest_chunk(ch, bv, epoch=fam.plan.epoch, tier=tier)
    parent_raw = len(store.raw)
    parent_version = store.data_version
    assert parent_raw > 0            # tier 0 left raw remainders

    snap = store.snapshot()
    scanner = DataSkippingScanner(snap, telemetry=False)
    q = Query(clauses=(pool[0],))
    assert scanner.scan(q).count == _oracle(objs, q)

    assert len(store.raw) == parent_raw          # parent untouched
    assert store.data_version == parent_version
    assert len(snap.raw) < parent_raw            # snapshot promoted
    assert snap.data_version < 0                 # forked version
    # repeat scan promotes nothing further and stays exact
    jit_before = len(snap.jit_blocks)
    assert scanner.scan(q).count == _oracle(objs, q)
    assert len(snap.jit_blocks) == jit_before


# ---------------------------------------------------------------------------
# shared-structure thread safety
# ---------------------------------------------------------------------------

def test_result_cache_thread_safe():
    """Concurrent store/lookup/invalidate churn must never corrupt the
    LRU dict or blow past the capacity bound."""
    cache = ResultCache(cap=32)
    qs = [Query(clauses=(predicate_pool("ycsb")[k],)) for k in range(8)]
    res = ScanResult(count=1, rows_scanned=1, rows_skipped=0,
                     raw_parsed=0, time_s=0.0, used_skipping=True)
    errors: list[BaseException] = []

    def churn(seed: int) -> None:
        rng = random.Random(seed)
        try:
            for _ in range(400):
                q = qs[rng.randrange(len(qs))]
                sid = rng.randrange(4)
                op = rng.randrange(10)
                if op < 5:
                    cache.store(sid, q, res, epoch=0,
                                data_version=rng.randrange(3))
                elif op < 9:
                    hit = cache.lookup(sid, q, epoch=0,
                                       data_version=rng.randrange(3))
                    if hit is not None:
                        assert hit.count == 1
                else:
                    cache.invalidate(sid if rng.random() < 0.5 else None)
        except BaseException as e:      # pragma: no cover - failure path
            errors.append(e)

    threads = [threading.Thread(target=churn, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert len(cache) <= cache.cap
    assert cache.hits + cache.misses > 0


def test_telemetry_thread_safe():
    """Concurrent record_scan/record_client_eval + snapshot() reads:
    counters must end exactly at the submitted totals (no lost updates)
    and snapshots must never raise mid-mutation."""
    tele = TelemetryPlane()
    res = ScanResult(count=3, rows_scanned=10, rows_skipped=5,
                     raw_parsed=0, time_s=0.001, used_skipping=True)
    n_threads, per_thread = 8, 300
    errors: list[BaseException] = []

    def record(i: int) -> None:
        try:
            for k in range(per_thread):
                tele.record_scan(res, tenant=f"t{i % 3}")
                if k % 16 == 0:
                    tele.record_client_eval(f"c{i}", 0.0005, n_records=100)
                if k % 32 == 0:
                    tele.snapshot()
        except BaseException as e:      # pragma: no cover - failure path
            errors.append(e)

    threads = [threading.Thread(target=record, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    snap = tele.snapshot()
    total = sum(t["scans"] for t in snap["tenants"].values())
    assert total == n_threads * per_thread


def test_telemetry_stats_report_consistent_under_ingest(ycsb):
    """stats_report() runs under the ingest lock: a report taken while a
    writer is mid-stream is a consistent snapshot (counters agree with
    each other), not a torn read."""
    recs, objs, pool = ycsb
    fam = _family(pool)
    chunks = _encode_chunks(recs, fam)
    store = CiaoStore(fam, segment_capacity=256)

    stop = threading.Event()
    errors: list[BaseException] = []

    def ingest() -> None:
        try:
            for ch, bv, tier in chunks:
                store.ingest_chunk(ch, bv, epoch=fam.plan.epoch, tier=tier)
        except BaseException as e:      # pragma: no cover
            errors.append(e)
        finally:
            stop.set()

    t = threading.Thread(target=ingest)
    t.start()
    while not stop.is_set():
        rep = store.stats_report()
        s = rep["load"]
        # chunk-atomic: rows land in n_records in chunk multiples
        assert s["n_records"] % CHUNK == 0
        assert s["n_records"] <= len(recs)
    t.join()
    assert not errors
    assert store.stats_report()["load"]["n_records"] == len(recs)


# ---------------------------------------------------------------------------
# serve engine
# ---------------------------------------------------------------------------

def test_engine_quiesced_counts_match_oracle(ycsb):
    recs, objs, pool = ycsb
    fam = _family(pool)
    chunks = _encode_chunks(recs, fam)
    router = ShardRouter(n_shards=4, key=choose_routing_key(fam.plan))
    store = ShardedCiaoStore(fam, router=router, segment_capacity=256)
    queries = [Query(clauses=(pool[k],)) for k in range(6)]
    oracle = [_oracle(objs, q) for q in queries]

    with CiaoServeEngine(store, queue_depth=8,
                         result_cache=ResultCache()) as serve:
        for ch, bv, tier in chunks:
            serve.ingest_chunk(ch, bv, epoch=fam.plan.epoch, tier=tier)
        serve.quiesce()
        for mode in ("host", "batch", "device"):
            assert [serve.query(q, mode=mode).count
                    for q in queries] == oracle, mode
        assert [r.count for r in serve.query_batch(queries)] == oracle
        rep = serve.stats_report()
        assert rep["engine"]["drained"] == rep["engine"]["enqueued"]
        assert rep["engine"]["errors"] == 0


def test_engine_snapshot_pins_before_ingest(ycsb):
    """A query answered from the engine's snapshot must not see rows
    from an ingest submitted after the snapshot was taken."""
    recs, objs, pool = ycsb
    fam = _family(pool)
    chunks = _encode_chunks(recs, fam)
    store = CiaoStore(fam, segment_capacity=256)
    q = Query(clauses=(pool[0],))

    with CiaoServeEngine(store) as serve:
        for ch, bv, tier in chunks[:6]:
            serve.ingest_chunk(ch, bv, epoch=fam.plan.epoch, tier=tier)
        serve.quiesce()
        snap = serve.snapshot()
        before = serve.query(q).count
        assert before == _oracle(objs[:6 * CHUNK], q)
        for ch, bv, tier in chunks[6:]:
            serve.ingest_chunk(ch, bv, epoch=fam.plan.epoch, tier=tier)
        serve.quiesce()
        # the pinned snapshot still answers the old view
        assert DataSkippingScanner(snap, telemetry=False).scan(q).count \
            == before
        # the engine re-snapshots and sees everything
        assert serve.query(q).count == _oracle(objs, q)


def test_engine_stale_epoch_raises_at_submit(ycsb):
    recs, objs, pool = ycsb
    fam = _family(pool)
    chunks = _encode_chunks(recs, fam)
    store = CiaoStore(fam, segment_capacity=256)
    with CiaoServeEngine(store) as serve:
        serve.ingest_chunk(*chunks[0][:2], epoch=fam.plan.epoch,
                           tier=chunks[0][2])
        fam2 = PlanFamily(
            plan=PushdownPlan(clauses=pool[:6], epoch=fam.plan.epoch + 1),
            tier_sizes=(0, 2, 6))
        serve.advance_epoch(fam2)
        with pytest.raises(StaleEpochError):
            serve.ingest_chunk(*chunks[1][:2], epoch=fam.plan.epoch,
                               tier=chunks[1][2])


def test_engine_backpressure_reject(ycsb):
    """With the drain stalled (writer blocked on the store's ingest
    lock), reject policy raises once the bounded queue fills — and after
    the stall clears, everything that WAS accepted lands exactly."""
    recs, objs, pool = ycsb
    fam = _family(pool)
    chunks = _encode_chunks(recs, fam)
    store = CiaoStore(fam, segment_capacity=256)
    serve = CiaoServeEngine(store, queue_depth=2, backpressure="reject")
    try:
        accepted = 0
        with store._ingest_lock:         # stall the writer mid-drain
            serve.ingest_chunk(*chunks[0][:2], epoch=fam.plan.epoch,
                               tier=chunks[0][2])
            accepted += 1
            deadline = time.time() + 5.0
            while serve._queues[0].qsize() > 0:   # writer picked it up
                assert time.time() < deadline, "writer never dequeued"
                time.sleep(0.001)
            with pytest.raises(BackpressureError):
                for ch, bv, tier in chunks[1:]:
                    serve.ingest_chunk(ch, bv, epoch=fam.plan.epoch,
                                       tier=tier)
                    accepted += 1
        assert accepted >= 3             # 1 in flight + queue_depth
        serve.quiesce()
        n_rows = accepted * CHUNK
        q = Query(clauses=(pool[0],))
        assert serve.query(q).count == _oracle(objs[:n_rows], q)
        assert serve.stats_report()["engine"]["rejected"] == 1
    finally:
        serve.close()


def test_engine_backpressure_block(ycsb):
    """Block policy: a submitter against a full queue waits (accounted
    in blocked_s) and completes once the drain resumes — nothing lost."""
    recs, objs, pool = ycsb
    fam = _family(pool)
    chunks = _encode_chunks(recs, fam)[:6]
    store = CiaoStore(fam, segment_capacity=256)
    serve = CiaoServeEngine(store, queue_depth=1, backpressure="block")
    errors: list[BaseException] = []

    def feed() -> None:
        try:
            for ch, bv, tier in chunks:
                serve.ingest_chunk(ch, bv, epoch=fam.plan.epoch, tier=tier)
        except BaseException as e:      # pragma: no cover
            errors.append(e)

    try:
        with store._ingest_lock:
            t = threading.Thread(target=feed)
            t.start()
            time.sleep(0.05)             # let the feeder hit the full queue
            assert t.is_alive()          # blocked, not failed
        t.join(timeout=10.0)
        assert not t.is_alive() and not errors
        serve.quiesce()
        q = Query(clauses=(pool[0],))
        assert serve.query(q).count == _oracle(objs[:len(chunks) * CHUNK], q)
        assert serve.stats_report()["engine"]["blocked_s"] > 0.0
    finally:
        serve.close()


def test_admission_control(ycsb):
    recs, objs, pool = ycsb
    # unit: reject tier refuses at quota, block tier queues
    adm = QueryAdmission(
        {"gold": TierPolicy(2, on_full="block"),
         "free": TierPolicy(1, on_full="reject")},
        tenant_tiers={"freeloader": "free"}, default_tier="gold")
    tier = adm.acquire("freeloader")
    with pytest.raises(AdmissionError):
        adm.acquire("freeloader")
    adm.release(tier)
    adm.acquire("freeloader")            # slot freed

    t1 = adm.acquire("vip")
    t2 = adm.acquire("vip")
    unblocked = threading.Event()

    def blocked_acquire() -> None:
        t3 = adm.acquire("vip")          # waits for a slot
        unblocked.set()
        adm.release(t3)

    t = threading.Thread(target=blocked_acquire)
    t.start()
    time.sleep(0.05)
    assert not unblocked.is_set()        # still waiting
    adm.release(t1)
    t.join(timeout=5.0)
    assert unblocked.is_set()
    adm.release(t2)
    assert adm.stats()["gold"]["blocked_s"] > 0.0

    # integration: engine gates queries through the same policy
    fam = _family(pool)
    chunks = _encode_chunks(recs, fam)[:3]
    store = CiaoStore(fam, segment_capacity=256)
    adm2 = QueryAdmission({"gold": TierPolicy(4),
                           "free": TierPolicy(0, on_full="reject")},
                          tenant_tiers={"freeloader": "free"},
                          default_tier="gold")
    with CiaoServeEngine(store, admission=adm2) as serve:
        for ch, bv, tier_ in chunks:
            serve.ingest_chunk(ch, bv, epoch=fam.plan.epoch, tier=tier_)
        serve.quiesce()
        q = Query(clauses=(pool[0],))
        with pytest.raises(AdmissionError):
            serve.query(q, tenant="freeloader")
        assert serve.query(q, tenant="vip").count \
            == _oracle(objs[:3 * CHUNK], q)
        assert serve.stats_report()["admission"]["free"]["rejected"] == 1


def test_threaded_stress_sweep(ycsb):
    """2 concurrent writers + 3 mixed-mode readers with a random tier
    mix: live counts stay bounded by the oracle, nothing deadlocks, and
    after quiesce every query is bit-identical to matches_exact across
    all three scan modes."""
    recs, objs, pool = ycsb
    fam = _family(pool)
    chunks = _encode_chunks(recs, fam)
    router = ShardRouter(n_shards=4, key=choose_routing_key(fam.plan))
    store = ShardedCiaoStore(fam, router=router, segment_capacity=256)
    queries = [Query(clauses=(pool[k],)) for k in range(8)]
    oracle = [_oracle(objs, q) for q in queries]
    serve = CiaoServeEngine(store, queue_depth=4,
                            result_cache=ResultCache())
    writers_done = threading.Event()
    errors: list[BaseException] = []

    def write(slice_: list) -> None:
        try:
            for ch, bv, tier in slice_:
                serve.ingest_chunk(ch, bv, epoch=fam.plan.epoch, tier=tier)
        except BaseException as e:      # pragma: no cover
            errors.append(e)

    def read(seed: int) -> None:
        rng = random.Random(seed)
        try:
            while not writers_done.is_set():
                k = rng.randrange(len(queries))
                mode = rng.choice(("host", "batch", "device"))
                r = serve.query(queries[k], mode=mode)
                assert 0 <= r.count <= oracle[k], (mode, k)
        except BaseException as e:      # pragma: no cover
            errors.append(e)

    try:
        ws = [threading.Thread(target=write, args=(chunks[0::2],)),
              threading.Thread(target=write, args=(chunks[1::2],))]
        rs = [threading.Thread(target=read, args=(i,)) for i in range(3)]
        for t in ws + rs:
            t.start()
        for t in ws:
            t.join(timeout=120.0)
            assert not t.is_alive(), "writer deadlocked"
        writers_done.set()
        for t in rs:
            t.join(timeout=120.0)
            assert not t.is_alive(), "reader deadlocked"
        assert not errors, errors
        serve.quiesce()
        for mode in ("host", "batch", "device"):
            assert [serve.query(q, mode=mode).count
                    for q in queries] == oracle, mode
        rep = serve.stats_report()
        assert rep["engine"]["errors"] == 0
        assert rep["engine"]["drained"] == rep["engine"]["enqueued"]
    finally:
        serve.close()


# ---------------------------------------------------------------------------
# snapshot retirement + per-tenant pressure telemetry (DESIGN.md §18)
# ---------------------------------------------------------------------------

def test_snapshot_close_releases_promoted_fork(ycsb):
    """An abandoned tainted snapshot (its scan promoted raw remainders
    into fork-local jit segments) must not pin those segments after an
    explicit close(): the retire hook drops every fork-held reference,
    so gc reclaims them while the parent stays intact and exact."""
    recs, objs, pool = ycsb
    fam = _family(pool)
    chunks = _encode_chunks(recs, fam)
    store = CiaoStore(fam, segment_capacity=256)
    for ch, bv, tier in chunks:
        store.ingest_chunk(ch, bv, epoch=fam.plan.epoch, tier=tier)

    snap = store.snapshot()
    scanner = DataSkippingScanner(snap, telemetry=False)
    q = Query(clauses=(pool[0],))
    assert scanner.scan(q).count == _oracle(objs, q)
    assert snap.jit_blocks               # tainted: fork-local promotion ran
    refs = [weakref.ref(seg) for seg in snap.jit_blocks]

    snap.close()
    assert not snap.jit_blocks and not snap.raw and not snap.blocks
    del scanner, snap
    gc.collect()
    assert all(r() is None for r in refs)   # nothing pins the fork segments
    # the parent never saw the fork: still raw, still exact
    assert store.raw
    assert DataSkippingScanner(store, telemetry=False).scan(q).count \
        == _oracle(objs, q)


def test_sharded_snapshot_close_delegates(ycsb):
    recs, objs, pool = ycsb
    fam = _family(pool)
    chunks = _encode_chunks(recs, fam)
    router = ShardRouter(n_shards=4, key=choose_routing_key(fam.plan))
    store = ShardedCiaoStore(fam, router=router, segment_capacity=256)
    for ch, bv, tier in chunks:
        store.ingest_chunk(ch, bv, epoch=fam.plan.epoch, tier=tier)
    snap = store.snapshot()
    scanner = ShardedScanner(snap, log_queries=False)
    q = Query(clauses=(pool[0],))
    assert scanner.scan(q).count == _oracle(objs, q)
    assert snap.jit_blocks
    refs = [weakref.ref(seg) for seg in snap.jit_blocks]
    snap.close()
    assert not snap.blocks and not snap.jit_blocks and not snap.raw
    del scanner
    gc.collect()
    assert all(r() is None for r in refs)
    assert ShardedScanner(store, log_queries=False).scan(q).count \
        == _oracle(objs, q)


def test_backpressure_and_admission_telemetry_per_tenant(ycsb):
    """Serve-plane pressure shows up in the per-tenant telemetry:
    ingest rejections under the submitting tenant, admission rejections
    and admitted counts under the querying tenant — all inside the
    store's stats_report, next to the tenant's scan counters."""
    recs, objs, pool = ycsb
    fam = _family(pool)
    chunks = _encode_chunks(recs, fam)
    store = CiaoStore(fam, segment_capacity=256)
    adm = QueryAdmission({"gold": TierPolicy(4),
                          "free": TierPolicy(0, on_full="reject")},
                         tenant_tiers={"freeloader": "free"},
                         default_tier="gold")
    serve = CiaoServeEngine(store, queue_depth=1, backpressure="reject",
                            admission=adm)
    try:
        assert adm.telemetry is store.telemetry   # wired by the engine
        with store._ingest_lock:         # stall the writer mid-drain
            serve.ingest_chunk(*chunks[0][:2], epoch=fam.plan.epoch,
                               tier=chunks[0][2], tenant="acme")
            deadline = time.time() + 5.0
            while serve._queues[0].qsize() > 0:
                assert time.time() < deadline, "writer never dequeued"
                time.sleep(0.001)
            with pytest.raises(BackpressureError):
                for ch, bv, tier in chunks[1:]:
                    serve.ingest_chunk(ch, bv, epoch=fam.plan.epoch,
                                       tier=tier, tenant="acme")
        serve.quiesce()
        q = Query(clauses=(pool[0],))
        with pytest.raises(AdmissionError):
            serve.query(q, tenant="freeloader")
        assert serve.query(q, tenant="vip").count > 0
        tenants = serve.stats_report()["store"]["telemetry"]["tenants"]
        assert tenants["acme"]["backpressure"]["ingest_rejected"] >= 1
        assert tenants["freeloader"]["backpressure"]["admission_rejected"] \
            == 1
        assert tenants["vip"]["backpressure"]["admitted"] >= 1
    finally:
        serve.close()


def test_backpressure_block_wait_telemetry_per_tenant(ycsb):
    recs, objs, pool = ycsb
    fam = _family(pool)
    chunks = _encode_chunks(recs, fam)[:6]
    store = CiaoStore(fam, segment_capacity=256)
    serve = CiaoServeEngine(store, queue_depth=1, backpressure="block")
    errors: list[BaseException] = []

    def feed() -> None:
        try:
            for ch, bv, tier in chunks:
                serve.ingest_chunk(ch, bv, epoch=fam.plan.epoch, tier=tier,
                                   tenant="bulk")
        except BaseException as e:      # pragma: no cover
            errors.append(e)

    try:
        with store._ingest_lock:
            t = threading.Thread(target=feed)
            t.start()
            time.sleep(0.05)             # feeder hits the full queue
            assert t.is_alive()
        t.join(timeout=10.0)
        assert not t.is_alive() and not errors
        serve.quiesce()
        bp = serve.stats_report()["store"]["telemetry"]["tenants"]["bulk"][
            "backpressure"]
        assert bp["ingest_blocked_s"] > 0.0
        assert bp["ingest_rejected"] == 0
    finally:
        serve.close()
