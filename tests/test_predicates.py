"""Predicate semantics: pattern compilation, no-false-negative invariant."""
import json

from hypothesis import given, settings, strategies as st

from repro.core.predicates import (
    clause, exact, key_value, presence, query, substring,
)


def test_pattern_strings_match_paper_table1():
    assert exact("name", "Bob").patterns() == (b'"Bob"',)
    assert substring("text", "delicious").patterns() == (b"delicious",)
    assert presence("email").patterns() == (b'"email"',)
    assert key_value("age", 10).patterns() == (b'"age"', b"10")


def test_exact_match_raw():
    rec = b'{"name":"Bob","age":22}'
    assert exact("name", "Bob").matches_raw(rec)
    assert not exact("name", "Alice").matches_raw(rec)
    # false positive by design: value appears under another key
    rec2 = b'{"nickname":"Bob","name":"Al"}'
    assert exact("name", "Bob").matches_raw(rec2)


def test_key_value_segment_semantics():
    rec = b'{"age":10,"score":22}'
    assert key_value("age", 10).matches_raw(rec)
    assert not key_value("age", 22).matches_raw(rec)  # 22 is beyond the comma
    assert key_value("score", 22).matches_raw(rec)
    # last pair closed by }
    assert key_value("score", 2).matches_raw(rec)  # substring of 22: FP ok


def test_predicate_equality_is_type_strict():
    # Python's 10 == 10.0 == True-style cross-type equality must NOT leak
    # into predicate identity: json_scalar(10) is "10" but
    # json_scalar(10.0) is "10.0", so the two predicates match different
    # row sets, and every clause cache / pushed-clause lookup keys on
    # equality.  (Regression: an earlier ``score = 10`` scan's cached
    # mask answered a later ``score = 10.0`` scan.)
    assert key_value("a", 10) == key_value("a", 10)
    assert key_value("a", 10) != key_value("a", 10.0)
    assert key_value("a", 1) != key_value("a", True)
    assert key_value("a", 0) != key_value("a", False)
    assert hash(key_value("a", 10)) != hash(key_value("a", 10.0))
    assert hash(key_value("a", 1)) != hash(key_value("a", True))
    assert clause(key_value("a", 10)) != clause(key_value("a", 10.0))
    # row semantics really do differ across the alias
    assert key_value("a", 10).matches_exact({"a": "10"})
    assert not key_value("a", 10.0).matches_exact({"a": "10"})
    assert key_value("a", True).matches_exact({"a": True})
    assert not key_value("a", 1).matches_exact({"a": True})


def test_key_value_multiple_key_occurrences():
    # key string also appears inside a text field before the real pair
    rec = b'{"text":"age is a number","age":7}'
    assert key_value("age", 7).matches_raw(rec)


def test_clause_disjunction():
    c = clause(exact("name", "Bob"), exact("name", "John"))
    assert c.matches_raw(b'{"name":"John"}')
    assert c.matches_raw(b'{"name":"Bob"}')
    assert not c.matches_raw(b'{"name":"Alice"}')


def test_exact_semantics_on_parsed():
    q = query(clause(key_value("age", 10)), clause(presence("email")))
    assert q.matches_exact({"age": 10, "email": "x@y.z"})
    assert not q.matches_exact({"age": 10})
    assert not q.matches_exact({"age": 11, "email": "x@y.z"})


_KEYS = ["alpha", "beta", "gamma", "text", "num"]


@st.composite
def json_record(draw):
    obj = {}
    for k in draw(st.lists(st.sampled_from(_KEYS), unique=True, min_size=1)):
        kind = draw(st.integers(0, 2))
        if kind == 0:
            obj[k] = draw(st.integers(0, 99))
        elif kind == 1:
            obj[k] = draw(st.text(alphabet="abcdef ", min_size=0, max_size=12))
        else:
            obj[k] = draw(st.booleans())
    return obj


@st.composite
def simple_predicate(draw):
    k = draw(st.sampled_from(_KEYS))
    kind = draw(st.integers(0, 3))
    if kind == 0:
        return exact(k, draw(st.text(alphabet="abcdef", min_size=1, max_size=6)))
    if kind == 1:
        return substring(k, draw(st.text(alphabet="abcdef ", min_size=1, max_size=6)))
    if kind == 2:
        return presence(k)
    return key_value(k, draw(st.integers(0, 99)))


@given(st.lists(json_record(), min_size=1, max_size=20),
       st.lists(simple_predicate(), min_size=1, max_size=8))
@settings(max_examples=200, deadline=None)
def test_no_false_negatives(objs, preds):
    """THE invariant (paper §IV-B): exact-match => raw pattern-match."""
    for obj in objs:
        rec = json.dumps(obj, separators=(",", ":")).encode()
        for p in preds:
            if p.matches_exact(obj):
                assert p.matches_raw(rec), (obj, p.describe())
