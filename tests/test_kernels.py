"""Pallas kernels vs pure-jnp oracle vs paper-faithful bytes.find engine.

Shape/dtype sweeps per the assignment: every kernel is validated in
interpret mode (kernel body executed on CPU) against ref.py, and ref.py
against the PythonEngine ground truth.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.client import PythonEngine, encode_chunk, encode_patterns
from repro.data.datasets import generate_records, predicate_pool
from repro.kernels import ops
from repro.kernels.engine import KernelEngine

BACKENDS = ("xla", "pallas_interpret")


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("r_blk", (32, 128, 256))
@pytest.mark.parametrize("n_rec,stride", [(7, 128), (64, 256), (200, 384)])
def test_match_any_shape_sweep(backend, r_blk, n_rec, stride):
    rng = np.random.default_rng(n_rec * stride + r_blk)
    data = rng.integers(32, 127, size=(n_rec, stride), dtype=np.uint8)
    # plant some needles
    needles = [b"hello", b"x", b"abcdefgh"]
    for i in range(0, n_rec, 3):
        nd = needles[i % len(needles)]
        pos = int(rng.integers(0, stride - len(nd)))
        data[i, pos : pos + len(nd)] = np.frombuffer(nd, np.uint8)
    pats, plens = encode_patterns(needles + [b"notthere"])
    out = ops.match_any(data, pats, plens[:, None], backend=backend, r_blk=r_blk)
    # oracle
    expected = np.zeros_like(out)
    for pi, nd in enumerate(needles + [b"notthere"]):
        for ri in range(n_rec):
            expected[pi, ri] = nd in data[ri].tobytes()
    assert np.array_equal(out, expected)


@pytest.mark.parametrize("backend", BACKENDS)
def test_key_value_kernel_vs_oracle(backend):
    recs = generate_records("ycsb", 128, seed=3)
    chunk = encode_chunk(recs)
    from repro.core.predicates import key_value

    for key, val in (("linear_score", 7), ("isActive", True), ("children", 0)):
        p = key_value(key, val)
        kp, vp = p.patterns()
        out = ops.match_key_value(chunk.data, kp, vp, backend=backend)
        expected = np.array([p.matches_raw(r) for r in recs])
        assert np.array_equal(out, expected), (key, val)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("dataset", ("yelp", "winlog", "ycsb"))
def test_kernel_engine_matches_python_oracle(backend, dataset):
    recs = generate_records(dataset, 150, seed=9)
    pool = predicate_pool(dataset)
    rng = np.random.default_rng(1)
    clauses = [pool[i] for i in rng.choice(len(pool), size=15, replace=False)]
    chunk = encode_chunk(recs)
    out = KernelEngine(backend=backend).eval(chunk, clauses)
    expected = PythonEngine().eval(chunk, clauses)
    assert np.array_equal(out, expected)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("p,w", [(1, 1), (3, 64), (8, 130), (2, 257)])
def test_bitvector_reduce_sweep(backend, p, w):
    rng = np.random.default_rng(p * w)
    bv = rng.integers(0, 2**32, size=(p, w), dtype=np.uint64).astype(np.uint32)
    a, o, c = ops.reduce_bitvectors(bv, backend=backend)
    assert np.array_equal(a, np.bitwise_and.reduce(bv, axis=0))
    assert np.array_equal(o, np.bitwise_or.reduce(bv, axis=0))
    assert c == int(np.bitwise_count(np.bitwise_and.reduce(bv, axis=0)).sum())


@given(st.integers(0, 2**31), st.integers(1, 6), st.integers(10, 60))
@settings(max_examples=25, deadline=None)
def test_match_any_property_random_bytes(seed, n_pat, rec_len):
    """Property: kernel path == python substring check, arbitrary bytes."""
    rng = np.random.default_rng(seed)
    n_rec = 16
    data = rng.integers(1, 255, size=(n_rec, 128), dtype=np.uint8)
    lens = rng.integers(5, rec_len + 1, size=n_rec)
    for i, l in enumerate(lens):
        data[i, l:] = 0
    needles = [bytes(rng.integers(1, 255, size=rng.integers(1, 6), dtype=np.uint8).tolist())
               for _ in range(n_pat)]
    pats, plens = encode_patterns(needles)
    out = ops.match_any(data, pats, plens[:, None], backend="pallas_interpret",
                        r_blk=16)
    for pi, nd in enumerate(needles):
        for ri in range(n_rec):
            assert out[pi, ri] == (nd in data[ri].tobytes()), (nd, ri)


@pytest.mark.parametrize("shape", [
    (2, 4, 2, 128, 64, True, 64),
    (1, 8, 8, 256, 32, True, 128),
    (2, 4, 1, 64, 128, False, 32),
    (1, 2, 2, 96, 16, True, 32),   # non-power-of-two S
])
def test_flash_attention_kernel_vs_jnp_flash(shape):
    """Pallas flash attention (interpret) vs the production jnp flash path."""
    import jax.numpy as jnp

    from repro.kernels.flash_attention import flash_attention_tpu
    from repro.models.attention import flash_attention

    B, H, Hkv, S, d, causal, qb = shape
    rng = np.random.default_rng(B * S + d)
    q = jnp.asarray(rng.normal(size=(B, H, S, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Hkv, S, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Hkv, S, d)), jnp.float32)
    out = flash_attention_tpu(q, k, v, causal=causal, q_block=qb, k_block=qb)
    ref = flash_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3),
        q_positions=jnp.arange(S), k_positions=jnp.arange(S),
        mask_mode="causal" if causal else "none", q_chunk=32, k_chunk=32,
    ).transpose(0, 2, 1, 3)
    assert float(jnp.abs(out - ref).max()) < 2e-5


def test_flash_attention_kernel_bf16():
    import jax.numpy as jnp

    from repro.kernels.flash_attention import flash_attention_tpu
    from repro.models.attention import flash_attention

    rng = np.random.default_rng(5)
    B, H, S, d = 1, 2, 64, 32
    q = jnp.asarray(rng.normal(size=(B, H, S, d)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(B, H, S, d)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(B, H, S, d)), jnp.bfloat16)
    out = flash_attention_tpu(q, k, v, causal=True, q_block=32, k_block=32)
    ref = flash_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3),
        q_positions=jnp.arange(S), k_positions=jnp.arange(S),
        mask_mode="causal", q_chunk=32, k_chunk=32,
    ).transpose(0, 2, 1, 3)
    assert float(jnp.abs(out.astype(jnp.float32) -
                         ref.astype(jnp.float32)).max()) < 0.05
