"""Fused single-pass pushdown pipeline: engine equivalence + launch count.

The engine-equivalence contract (DESIGN.md §4): every engine — the
paper-faithful bytes.find oracle, the vectorized numpy engine, the jnp
oracle, the Pallas kernel in interpret mode, and the fused single-launch
path they all back — must produce bit-identical packed bitvectors, load
masks, and popcounts, and must never produce a false negative w.r.t. exact
semantics on the parsed record.
"""
import json

import numpy as np
import pytest

from repro.core import bitvector
from repro.core.client import NumpyEngine, PythonEngine, encode_chunk
from repro.core.predicates import (
    Clause, SimplePredicate, clause, exact, key_value, presence, substring,
)
from repro.kernels.engine import KernelEngine, compile_plan

BACKENDS = ("xla", "pallas_interpret")

_KEYS = ["name", "age", "tags", "city", "note"]
_WORDS = ["bob", "ann", "x", "par,is", "ab}c", "tok", "zz", "a b"]


def _random_record(rng) -> dict:
    obj = {}
    for k in _KEYS:
        if rng.random() < 0.4:
            continue
        r = rng.random()
        if r < 0.35:
            obj[k] = int(rng.integers(0, 30))
        elif r < 0.7:
            n = int(rng.integers(1, 4))
            obj[k] = " ".join(_WORDS[int(i)] for i in rng.integers(0, len(_WORDS), n))
        elif r < 0.85:
            obj[k] = bool(rng.integers(0, 2))
        else:
            obj[k] = None
    return obj


def _random_term(rng) -> SimplePredicate:
    k = _KEYS[int(rng.integers(0, len(_KEYS)))]
    kind = int(rng.integers(0, 4))
    if kind == 0:
        return exact(k, _WORDS[int(rng.integers(0, len(_WORDS)))])
    if kind == 1:
        return substring(k, _WORDS[int(rng.integers(0, len(_WORDS)))])
    if kind == 2:
        return presence(k)
    r = rng.random()
    if r < 0.4:
        return key_value(k, int(rng.integers(0, 30)))
    if r < 0.6:
        return key_value(k, bool(rng.integers(0, 2)))
    # delimiter-containing values exercise the unbounded degradation
    return key_value(k, _WORDS[int(rng.integers(0, len(_WORDS)))])


def _random_clauses(rng, n: int) -> list[Clause]:
    out = []
    for _ in range(n):
        terms = tuple(_random_term(rng) for _ in range(int(rng.integers(1, 4))))
        out.append(Clause(terms))
    return out


@pytest.mark.parametrize("seed", range(6))
def test_differential_all_engines_bit_identical(seed):
    """Random chunks x random clause sets: all engines, same packed bits."""
    rng = np.random.default_rng(1000 + seed)
    objs = [_random_record(rng) for _ in range(24)]
    recs = [json.dumps(o, separators=(",", ":")).encode() for o in objs]
    chunk = encode_chunk(recs)
    clauses = _random_clauses(rng, int(rng.integers(2, 7)))

    ref_engine = PythonEngine()
    expected_fused = ref_engine.eval_fused(chunk, clauses)
    engines = [NumpyEngine()] + [KernelEngine(backend=b) for b in BACKENDS]
    for eng in engines:
        fused = eng.eval_fused(chunk, clauses)
        assert np.array_equal(fused.words, expected_fused.words), eng.name
        assert np.array_equal(fused.or_words, expected_fused.or_words), eng.name
        assert np.array_equal(fused.counts, expected_fused.counts), eng.name
        assert fused.n_records == chunk.n_records
        # packed path must agree with the fused words exactly
        assert np.array_equal(eng.eval_packed(chunk, clauses), fused.words)

    # THE invariant (paper §IV-B): exact match on the parsed record
    # implies the client bit is set — false positives allowed, false
    # negatives never.
    bits = bitvector.unpack(expected_fused.words, chunk.n_records)
    for ci, cl in enumerate(clauses):
        for ri, obj in enumerate(objs):
            if cl.matches_exact(obj):
                assert bits[ci, ri], (cl.describe(), obj)


@pytest.mark.parametrize("backend", BACKENDS)
def test_multi_block_accumulation(backend):
    """Several record tiles per chunk: pack, load-mask OR and popcount
    accumulate correctly across grid blocks (and the word slice drops the
    padding tile)."""
    rng = np.random.default_rng(5)
    objs = [_random_record(rng) for _ in range(150)]
    recs = [json.dumps(o, separators=(",", ":")).encode() for o in objs]
    chunk = encode_chunk(recs)
    clauses = _random_clauses(rng, 5)
    expected = PythonEngine().eval_fused(chunk, clauses)
    eng = KernelEngine(backend=backend, r_blk=64)  # 150 -> 3 tiles of 64
    fused = eng.eval_fused(chunk, clauses)
    assert np.array_equal(fused.words, expected.words)
    assert np.array_equal(fused.or_words, expected.or_words)
    assert np.array_equal(fused.counts, expected.counts)


def test_empty_patterns_engines_agree():
    """Empty substring / empty key-value value: match-all / key-presence
    semantics, bit-identical across ALL engines (regression: NumpyEngine
    returned all-False for zero-length patterns)."""
    chunk = encode_chunk([b'{"note":"hi","age":3}', b'{"age":4}'])
    cls = [clause(substring("note", "")), clause(key_value("note", ""))]
    expected = PythonEngine().eval(chunk, cls)
    assert expected[0].all()          # empty substring matches everything
    assert expected[1].tolist() == [True, False]  # '"note"' presence
    for eng in [NumpyEngine()] + [KernelEngine(backend=b) for b in BACKENDS]:
        assert np.array_equal(eng.eval(chunk, cls), expected), eng.name


def test_ops_clause_bitvectors_empty_plan():
    """The public kernels.clause_bitvectors handles degenerate inputs."""
    from repro.kernels import clause_bitvectors
    from repro.kernels.plan import compile_plan as cp

    data = encode_chunk([b'{"a":1}']).data
    for backend in BACKENDS:
        words, or_words, counts = clause_bitvectors(
            data, cp([]), backend=backend)
        assert words.shape == (0, 1) and counts.shape == (0,)
        assert not or_words.any()
        words, or_words, counts = clause_bitvectors(
            np.zeros((0, 128), np.uint8), cp([clause(presence("a"))]),
            backend=backend)
        assert words.shape == (1, 0) and or_words.shape == (0,)
        assert counts.tolist() == [0]


def test_ingest_mismatch_leaves_stats_untouched():
    """A rejected ingest must not corrupt n_records / selectivities."""
    from repro.core.server import CiaoStore, PushdownPlan

    clauses = [clause(presence("age"))]
    store = CiaoStore(PushdownPlan(clauses=clauses))
    eng = KernelEngine(backend="xla")
    good = encode_chunk([b'{"age":1}', b'{"age":2}'])
    store.ingest_chunk(good, eng.eval_fused(good, clauses))
    before = (store.stats.n_records, store.clause_counts.copy())
    with pytest.raises(ValueError):
        store.ingest_chunk(encode_chunk([b'{"x":0}']),
                           eng.eval_fused(good, clauses))
    # clause-dimension mismatch (stale client plan), both ingest forms
    stale = [clause(presence("age")), clause(presence("x"))]
    with pytest.raises(ValueError):
        store.ingest_chunk(good, eng.eval_fused(good, stale))
    with pytest.raises(ValueError):
        store.ingest_chunk(good, eng.eval_packed(good, stale))
    # raw-array word width covering a different record count
    short = encode_chunk([b'{"age":%d}' % i for i in range(40)])
    with pytest.raises(ValueError):
        store.ingest_chunk(short, eng.eval_packed(good, clauses))
    assert store.stats.n_records == before[0]
    assert np.array_equal(store.clause_counts, before[1])


def test_wide_record_stride_no_false_negative():
    """Strides past the int16 sentinel must not wrap the position scan.

    Regression: the xla oracle's value-confinement scan uses int16
    positions for normal chunks; a record wider than 0x7FFF bytes must
    fall back to int32 (a wrapped iota made a key-value match near the
    record end a FALSE NEGATIVE — forbidden)."""
    tail = b'"name":"bob","age":7}'
    rec = b'{"pad":"' + b"x" * 33000 + b'",' + tail
    chunk = encode_chunk([rec, b'{"age":8}'])
    assert chunk.stride > 0x7FFF
    clauses = [clause(key_value("age", 7))]
    expected = PythonEngine().eval(chunk, clauses)
    assert expected[0, 0]  # the match near the record end must be found
    for b in BACKENDS:
        out = KernelEngine(backend=b).eval(chunk, clauses)
        assert np.array_equal(out, expected), b


@pytest.mark.parametrize("backend", BACKENDS)
def test_fused_edge_cases(backend):
    eng = KernelEngine(backend=backend)
    recs = [b'{"a":1}', b'{"b":2}']
    chunk = encode_chunk(recs)
    # empty plan — every protocol method, including unpack-based eval
    # (regression: bitvector.unpack crashed reshaping (0, W) words)
    fused = eng.eval_fused(chunk, [])
    assert fused.words.shape == (0, 1)
    assert fused.or_words.shape == (1,)
    assert not fused.or_words.any()
    assert eng.eval(chunk, []).shape == (0, 2)
    assert eng.eval_packed(chunk, []).shape == (0, 1)
    # empty chunk
    empty = encode_chunk([])
    fused = eng.eval_fused(empty, [clause(presence("a"))])
    assert fused.words.shape == (1, 0)
    assert fused.counts.tolist() == [0]


def test_compile_plan_dedups_shared_disjuncts():
    """A disjunct shared by several clauses occupies ONE predicate slot."""
    shared = substring("note", "tok")
    cls = [clause(shared, presence("age")), clause(shared),
           clause(shared, key_value("age", 7))]
    plan = compile_plan(cls)
    assert plan.n_preds == 3  # shared, presence, key_value — not 5
    assert plan.membership.shape == (3, 3)
    assert plan.membership.sum() == 5
    assert plan.kinds.sum() == 1  # exactly one key-value predicate


def test_numpy_engine_dedups_evaluation(monkeypatch):
    """NumpyEngine evaluates a shared disjunct once per chunk, not per clause."""
    from repro.core import client as client_mod

    calls = []
    real = client_mod.eval_simple

    def counting(data, pred, **kw):
        calls.append(pred)
        return real(data, pred, **kw)

    monkeypatch.setattr(client_mod, "eval_simple", counting)
    shared = substring("note", "tok")
    cls = [clause(shared), clause(shared, presence("age")), clause(shared)]
    chunk = encode_chunk([b'{"note":"a tok b","age":3}', b'{"note":"x"}'])
    out = NumpyEngine().eval(chunk, cls)
    assert len(calls) == 2  # shared + presence, despite 3 clauses
    assert np.array_equal(out, PythonEngine().eval(chunk, cls))


@pytest.mark.parametrize("backend", BACKENDS)
def test_single_kernel_launch_per_chunk(backend, monkeypatch):
    """The whole plan — simple AND key-value mixed — is ONE pallas_call.

    Counted at trace time: a fresh (plan, chunk-bucket) specialization must
    stage exactly one kernel launch for the pallas backend and exactly zero
    host round-trips in between (the xla oracle stages none).  Repeat
    evaluations hit the jit cache: zero further launches.
    """
    from jax.experimental import pallas as pl

    from repro.kernels import fused as fused_mod

    counted = []
    real = pl.pallas_call

    def counting(*args, **kwargs):
        counted.append(kwargs.get("grid"))
        return real(*args, **kwargs)

    monkeypatch.setattr(fused_mod.pl, "pallas_call", counting)

    rng = np.random.default_rng(7)
    # unique record count/stride so no previous jit specialization matches
    objs = [_random_record(rng) for _ in range(41)]
    recs = [json.dumps(o, separators=(",", ":")).encode() for o in objs]
    chunk = encode_chunk(recs)
    # mixed plan: simple patterns + several distinct key-value pairs
    clauses = [
        clause(exact("name", "bob"), key_value("age", 7)),
        clause(key_value("age", 11)),
        clause(substring("note", "zz"), key_value("city", 3)),
        clause(presence("tags")),
    ]
    eng = KernelEngine(backend=backend)
    out1 = eng.eval_fused(chunk, clauses)
    n_trace = len(counted)
    if backend == "pallas_interpret":
        assert n_trace == 1, f"expected ONE fused launch, traced {n_trace}"
    else:
        assert n_trace == 0  # xla oracle: no pallas at all
    out2 = eng.eval_fused(chunk, clauses)
    assert len(counted) == n_trace, "re-evaluation must reuse the jit cache"
    assert np.array_equal(out1.words, out2.words)
    expected = PythonEngine().eval_fused(chunk, clauses)
    assert np.array_equal(out1.words, expected.words)


@pytest.mark.parametrize("seed", range(3))
def test_differential_post_replan_plan_bit_identical(seed):
    """Engine equivalence must hold PER EPOCH: after a replan evolves the
    plan (dropped + surviving + fresh clauses, new local row order), every
    engine still produces bit-identical packed bitvectors for the new
    epoch's clause list."""
    from repro.core.server import PushdownPlan, evolve_plan

    rng = np.random.default_rng(4000 + seed)
    objs = [_random_record(rng) for _ in range(24)]
    recs = [json.dumps(o, separators=(",", ":")).encode() for o in objs]
    chunk = encode_chunk(recs)
    clauses0 = _random_clauses(rng, 5)
    plan0 = PushdownPlan(clauses=clauses0)
    # replan: drop two, keep three (shuffled rows), push two fresh clauses
    survivors = [clauses0[4], clauses0[1], clauses0[2]]
    plan1 = evolve_plan(plan0, survivors + _random_clauses(rng, 2))
    assert plan1.remap_from(plan0).tolist()[:3] == [4, 1, 2]

    expected = PythonEngine().eval_fused(chunk, plan1.clauses)
    engines = [NumpyEngine()] + [KernelEngine(backend=b) for b in BACKENDS]
    for eng in engines:
        fused = eng.eval_fused(chunk, plan1.clauses)
        assert np.array_equal(fused.words, expected.words), eng.name
        assert np.array_equal(fused.or_words, expected.or_words), eng.name
        assert np.array_equal(fused.counts, expected.counts), eng.name


def test_hot_swap_same_bucket_epoch_no_retrace(monkeypatch):
    """A replan whose compiled plan lands in the SAME (P, Mk, Mv) shape
    bucket must not retrace the fused kernel (epoch hot-swap without
    jit-thrash): only the first epoch's evaluation stages a pallas_call."""
    from jax.experimental import pallas as pl

    from repro.core.server import PushdownPlan, evolve_plan
    from repro.kernels import fused as fused_mod

    counted = []
    real = pl.pallas_call

    def counting(*args, **kwargs):
        counted.append(kwargs.get("grid"))
        return real(*args, **kwargs)

    monkeypatch.setattr(fused_mod.pl, "pallas_call", counting)

    rng = np.random.default_rng(11)
    # unique record count so no previous jit specialization matches
    objs = [_random_record(rng) for _ in range(37)]
    recs = [json.dumps(o, separators=(",", ":")).encode() for o in objs]
    chunk = encode_chunk(recs)
    plan0 = PushdownPlan(clauses=[
        clause(key_value("age", 7)), clause(presence("tags")),
    ])
    # same predicate count, same key, value in the same 8-byte width
    # bucket -> identical compiled shapes, different constants
    plan1 = evolve_plan(plan0, [
        clause(key_value("age", 23)), clause(presence("city")),
    ])
    eng = KernelEngine(backend="pallas_interpret")
    out0 = eng.eval_fused(chunk, plan0.clauses)
    n_trace = len(counted)
    assert n_trace <= 1  # one fresh specialization at most
    out1 = eng.eval_fused(chunk, plan1.clauses)
    assert len(counted) == n_trace, "same-bucket epoch swap retraced"
    expected0 = PythonEngine().eval_fused(chunk, plan0.clauses)
    expected1 = PythonEngine().eval_fused(chunk, plan1.clauses)
    assert np.array_equal(out0.words, expected0.words)
    assert np.array_equal(out1.words, expected1.words)


def test_server_ingest_consumes_fused_outputs():
    """CiaoStore accepts ChunkBitvectors directly (no host OR re-reduce)."""
    from repro.core.server import CiaoStore, PushdownPlan

    rng = np.random.default_rng(3)
    objs = [_random_record(rng) for _ in range(60)]
    recs = [json.dumps(o, separators=(",", ":")).encode() for o in objs]
    chunk = encode_chunk(recs)
    clauses = _random_clauses(rng, 4)
    plan = PushdownPlan(clauses=clauses)
    eng = KernelEngine(backend="xla")

    s1 = CiaoStore(plan)
    s1.ingest_chunk(chunk, eng.eval_fused(chunk, plan.clauses))
    s2 = CiaoStore(plan)
    s2.ingest_chunk(chunk, eng.eval_packed(chunk, plan.clauses))
    assert s1.stats.n_loaded == s2.stats.n_loaded
    assert sum(b.n_rows for b in s1.blocks) == sum(b.n_rows for b in s2.blocks)
    for b1, b2 in zip(s1.blocks, s2.blocks):
        assert b1.rows == b2.rows
        assert np.array_equal(b1.bitvectors, b2.bitvectors)
    # per-clause popcounts feed the store's observed selectivities,
    # identically for the fused and the raw-array ingest path
    exact_counts = PythonEngine().eval(chunk, clauses).sum(axis=1)
    assert np.array_equal(s1.clause_counts, exact_counts)
    assert np.array_equal(s2.clause_counts, exact_counts)
    assert np.allclose(
        s1.observed_selectivities(), exact_counts / chunk.n_records)
    # n_records mismatch is rejected
    other = encode_chunk(recs[:10])
    with pytest.raises(ValueError):
        s1.ingest_chunk(other, eng.eval_fused(chunk, plan.clauses))