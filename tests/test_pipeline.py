"""Data pipeline: work stealing, recipe batching, prefetch, tokenizer."""
import numpy as np
import pytest

from repro.core.client import NumpyEngine
from repro.core.planner import build_plan
from repro.core.predicates import Query
from repro.core.server import CiaoStore
from repro.core.workload import generate_workload
from repro.data.datasets import generate_records, predicate_pool
from repro.data.pipeline import (
    ClientShard, IngestCoordinator, Prefetcher, RecipeBatcher,
)
from repro.data.tokenizer import PAD_ID, ByteTokenizer


def _plan(dataset="ycsb", budget=1.5, seed=0):
    pool = predicate_pool(dataset)
    rng = np.random.default_rng(seed)
    wl = generate_workload(pool, n_queries=20, distribution="zipf",
                           zipf_a=1.5, rng=rng)
    return build_plan(wl, generate_records(dataset, 300, seed=seed + 1),
                      budget_us=budget)


def test_work_stealing_improves_makespan():
    rep = _plan()
    eng = NumpyEngine()

    def clients():
        return [
            ClientShard("ycsb", i, eng, rep.plan, chunk_records=64,
                        speed=(0.2 if i == 0 else 1.0))
            for i in range(4)
        ]

    c1 = IngestCoordinator(clients(), CiaoStore(rep.plan), steal=True)
    c1.run(chunks_per_client=3)
    c2 = IngestCoordinator(clients(), CiaoStore(rep.plan), steal=False)
    c2.run(chunks_per_client=3)
    assert c1.makespan < c2.makespan * 0.5
    assert c1.stolen > 0
    # same amount of data either way
    assert c1.store.stats.n_records == c2.store.stats.n_records


def test_ingest_exactly_once():
    rep = _plan()
    eng = NumpyEngine()
    store = CiaoStore(rep.plan)
    clients = [ClientShard("ycsb", i, eng, rep.plan, chunk_records=32)
               for i in range(3)]
    coord = IngestCoordinator(clients, store)
    coord.run(chunks_per_client=5)
    assert store.stats.n_records == 3 * 5 * 32


def test_recipe_batcher_shapes_and_vocab():
    rep = _plan()
    eng = NumpyEngine()
    store = CiaoStore(rep.plan)
    clients = [ClientShard("ycsb", i, eng, rep.plan, chunk_records=256)
               for i in range(4)]
    IngestCoordinator(clients, store).run(chunks_per_client=4)
    recipe = Query((rep.plan.clauses[0],))
    tok = ByteTokenizer(vocab_size=151936)
    b = RecipeBatcher(store, tok, seq_len=64, batch_size=4)
    it = iter(b.batches(recipe))
    for _ in range(3):
        tokens, mask = next(it)
        assert tokens.shape == (4, 64)
        assert tokens.dtype == np.int32
        assert tokens.max() < 151936 and tokens.min() >= 0
        assert mask.shape == (4, 64)


def test_recipe_rows_actually_match():
    rep = _plan()
    eng = NumpyEngine()
    store = CiaoStore(rep.plan)
    clients = [ClientShard("ycsb", i, eng, rep.plan, chunk_records=256)
               for i in range(2)]
    IngestCoordinator(clients, store).run(chunks_per_client=2)
    recipe = Query((rep.plan.clauses[0],))
    b = RecipeBatcher(store, ByteTokenizer(vocab_size=1024), seq_len=32, batch_size=2)
    import json

    n = 0
    for rec in b.matching_records(recipe):
        assert recipe.matches_exact(json.loads(rec))
        n += 1
    assert n > 0


def test_prefetcher_propagates_and_finishes():
    it = Prefetcher(iter(range(5)), depth=2)
    assert list(it) == [0, 1, 2, 3, 4]

    def boom():
        yield 1
        raise ValueError("boom")

    it = Prefetcher(boom(), depth=2)
    assert next(it) == 1
    with pytest.raises(ValueError):
        for _ in it:
            pass


def test_prefetcher_close_releases_blocked_worker():
    """An abandoned consumer must not leave the worker parked on a full
    queue forever: close() unblocks and joins it."""
    produced = []

    def gen():
        for i in range(1000):
            produced.append(i)
            yield i

    it = Prefetcher(gen(), depth=2)
    assert next(it) == 0  # worker is now blocked on the full queue
    it.close()
    assert not it._t.is_alive()
    assert len(produced) < 1000  # the stream was genuinely abandoned early
    # closed iterator terminates cleanly instead of hanging
    assert list(it) == []
    it.close()  # idempotent


def test_prefetcher_close_surfaces_worker_exception():
    def boom():
        yield 1
        raise RuntimeError("worker died")

    it = Prefetcher(boom(), depth=4)
    assert next(it) == 1
    it._t.join(timeout=5)  # let the failure land before we abandon it
    with pytest.raises(RuntimeError, match="worker died"):
        it.close()
    it.close()  # exception is raised once, close stays idempotent


def test_prefetcher_close_reports_unreleasable_worker():
    """A worker stuck INSIDE the wrapped iterator can't be released —
    close() must say so instead of returning as if the thread exited."""
    import threading

    gate = threading.Event()

    def stuck():
        yield 0
        gate.wait()   # stuck in the iterator, not in the queue handoff
        yield 1

    it = Prefetcher(stuck(), depth=1)
    assert next(it) == 0
    it._JOIN_S = 0.2
    try:
        with pytest.raises(RuntimeError, match="cannot be released"):
            it.close()
    finally:
        gate.set()    # let the thread finish
    it._t.join(timeout=5)
    assert not it._t.is_alive()


def test_prefetcher_context_manager():
    with Prefetcher(iter(range(100)), depth=2) as it:
        assert next(it) == 0
    assert not it._t.is_alive()

    # a consumer-side exception propagates (not masked by close)
    with pytest.raises(ValueError, match="consumer"):
        with Prefetcher(iter(range(100)), depth=2) as it:
            raise ValueError("consumer bug")
    assert not it._t.is_alive()


def test_tokenizer_determinism_and_padding():
    tok = ByteTokenizer(vocab_size=65536)
    a = tok.encode(b'{"x": 1}')
    b2 = tok.encode(b'{"x": 1}')
    assert np.array_equal(a, b2)
    batch = tok.pad_batch([a], seq_len=32)
    assert batch.shape == (1, 32)
    assert batch[0, -1] == PAD_ID
