"""Host multi-query batcher + result cache (DESIGN.md §16): differential sweeps.

The contract under test: :class:`ScanBatcher` results are BIT-IDENTICAL
to sequential ``DataSkippingScanner`` / ``ShardedScanner`` scans issued
in the same order — counts AND the full accounting surface
(rows_scanned / rows_skipped / raw_parsed / segments_pruned /
segments_scanned and every per-(epoch, tier) group) — across mixed
epochs and tiers, shard counts, promoted and un-promoted stores, and
partition-pruning range routers.  Plus the :class:`ResultCache`
contract: warm repeats reproduce the producing scan's result exactly,
counts stay scan-order independent, any ingest invalidates (a stale
``(shard, epoch)`` entry never answers), and the telemetry plane's
counters always agree with the ``ScanResult`` accounting they fold.
"""
import json

import pytest

from repro.core.batch_scan import ResultCache, ScanBatcher, copy_scan_result
from repro.core.client import NumpyEngine, encode_chunk
from repro.core.predicates import (
    Clause, Kind, Query, SimplePredicate, clause, key_value,
)
from repro.core.server import (
    CiaoStore, DataSkippingScanner, PlanFamily, PushdownPlan, evolve_family,
)
from repro.core.shard import ShardedCiaoStore, ShardedScanner, ShardRouter
from repro.core.telemetry import TelemetryPlane
from repro.core.workload import estimate_selectivities
from repro.data.datasets import generate_records, predicate_pool

CHUNK = 256
N_RECORDS = 2048


def _accounting(r) -> tuple:
    return (r.count, r.rows_scanned, r.rows_skipped, r.raw_parsed,
            r.segments_pruned, r.segments_scanned, r.shards_pruned,
            r.used_skipping,
            tuple(sorted(
                (k, (g.count, g.rows_scanned, g.rows_skipped, g.raw_parsed,
                     g.segments_pruned))
                for k, g in r.groups.items())))


@pytest.fixture(scope="module")
def ycsb():
    recs = generate_records("ycsb", N_RECORDS, seed=7)
    pool = predicate_pool("ycsb")
    sel = estimate_selectivities(pool, recs[:300])
    ranked = sorted(pool, key=lambda c: abs(sel[c] - 0.2))
    objs = [json.loads(r) for r in recs]
    return recs, objs, ranked


def _families(ranked):
    fam0 = PlanFamily(plan=PushdownPlan(clauses=ranked[:8]),
                      tier_sizes=(2, 4, 8))
    fam1 = evolve_family(fam0, ranked[:4] + ranked[8:12], (2, 4, 8))
    return fam0, fam1


def _build(store, recs, fam0, fam1, *, jit=True):
    """Mixed-epoch / mixed-tier ingest, replan at the halfway point."""
    eng = NumpyEngine()

    def ingest(lo, hi, epoch):
        fam = store.family
        for i, start in enumerate(range(lo, hi, CHUNK)):
            tier = i % fam.n_tiers
            chunk = encode_chunk(recs[start: start + CHUNK])
            bv = eng.eval_fused_prefix(chunk, fam.plan.clauses,
                                       fam.tier_sizes[tier])
            store.ingest_chunk(chunk, bv, epoch=epoch, tier=tier)

    half = (len(recs) // 2) // CHUNK * CHUNK
    ingest(0, half, epoch=0)
    store.advance_epoch(fam1)
    ingest(half, len(recs), epoch=1)
    if jit:
        store.jit_load_raw()
    return store


def _workload(fam0, fam1, ranked):
    qs = [Query((c,)) for c in fam0.plan.clauses[:3] + fam1.plan.clauses[:3]]
    qs += [Query((fam0.plan.clauses[0], ranked[13]))]   # pushed + residual
    qs += [Query((c,)) for c in ranked[14:17]]          # residual-only
    for v in (3, 55, 97, 250):                          # 250: no match
        qs.append(Query((clause(key_value("linear_score", v)),)))
    qs.append(Query((clause(key_value("phone_country", "ZZ")),)))
    return qs


def _ingest_more(store, recs, fam1, lo=0, hi=64):
    eng = NumpyEngine()
    chunk = encode_chunk(recs[lo:hi])
    bv = eng.eval_fused_prefix(chunk, fam1.plan.clauses, 4)
    store.ingest_chunk(chunk, bv, epoch=1, tier=1)


# ---------------------------------------------------------------------------
# batch-of-N vs sequential, monolithic
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("jit", [True, False])
def test_batch_bit_identical_to_sequential(ycsb, jit):
    """Promoted AND un-promoted stores: the un-promoted case pins the
    sequential promotion semantics (query i sees only jit segments
    promoted by queries <= i)."""
    recs, objs, ranked = ycsb
    fam0, fam1 = _families(ranked)
    queries = _workload(fam0, fam1, ranked)
    a = _build(CiaoStore(fam0, segment_capacity=512), recs, fam0, fam1,
               jit=jit)
    b = _build(CiaoStore(fam0, segment_capacity=512), recs, fam0, fam1,
               jit=jit)
    batched = ScanBatcher(a, log_queries=False).scan_batch(queries)
    host = DataSkippingScanner(b, log_queries=False)
    for q, r in zip(queries, batched):
        oracle = sum(1 for o in objs if q.matches_exact(o))
        h = host.scan(q)
        assert r.count == oracle, q.describe()
        assert _accounting(r) == _accounting(h), q.describe()
        assert list(r.groups) == sorted(r.groups)


def test_single_query_scan_matches_scanner(ycsb):
    recs, objs, ranked = ycsb
    fam0, fam1 = _families(ranked)
    store = _build(CiaoStore(fam0, segment_capacity=512), recs, fam0, fam1)
    bat = ScanBatcher(store, log_queries=False)
    host = DataSkippingScanner(store, log_queries=False)
    for q in _workload(fam0, fam1, ranked)[:4]:
        assert _accounting(bat.scan(q)) == _accounting(host.scan(q))


# ---------------------------------------------------------------------------
# batch-of-N vs sequential, sharded (hash + pruning range router)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_shards", [1, 4])
def test_sharded_batch_bit_identical(ycsb, n_shards):
    recs, objs, ranked = ycsb
    fam0, fam1 = _families(ranked)
    router = (ShardRouter(n_shards=n_shards, key="linear_score", mode="hash")
              if n_shards > 1 else None)
    a = _build(ShardedCiaoStore(fam0, router=router, n_shards=n_shards,
                                segment_capacity=512), recs, fam0, fam1)
    b = _build(ShardedCiaoStore(fam0, router=router, n_shards=n_shards,
                                segment_capacity=512), recs, fam0, fam1)
    queries = _workload(fam0, fam1, ranked)
    batched = ScanBatcher(a, log_queries=False).scan_batch(queries)
    with ShardedScanner(b, log_queries=False) as sc:
        for q, r in zip(queries, batched):
            oracle = sum(1 for o in objs if q.matches_exact(o))
            h = sc.scan(q)
            assert r.count == oracle, q.describe()
            assert _accounting(r) == _accounting(h), q.describe()


def test_sharded_batch_range_router_prunes(ycsb):
    """Partition-refuted shards: snapshot rows_skipped, never promote."""
    recs, objs, ranked = ycsb
    fam0, fam1 = _families(ranked)
    router = ShardRouter.from_samples(4, "linear_score", objs[:400])
    a = _build(ShardedCiaoStore(fam0, router=router, segment_capacity=512),
               recs, fam0, fam1)
    b = _build(ShardedCiaoStore(fam0, router=router, segment_capacity=512),
               recs, fam0, fam1)
    queries = _workload(fam0, fam1, ranked)
    batched = ScanBatcher(a, log_queries=False).scan_batch(queries)
    pruned = 0
    with ShardedScanner(b, log_queries=False) as sc:
        for q, r in zip(queries, batched):
            assert _accounting(r) == _accounting(sc.scan(q)), q.describe()
            pruned += r.shards_pruned
    assert pruned > 0          # the range router actually refuted shards


# ---------------------------------------------------------------------------
# result cache: warm repeats, order independence, invalidation
# ---------------------------------------------------------------------------

def test_cache_warm_repeat_bit_identical(ycsb):
    recs, objs, ranked = ycsb
    fam0, fam1 = _families(ranked)
    store = _build(ShardedCiaoStore(
        fam0, router=ShardRouter(n_shards=4, key="linear_score",
                                 mode="hash"),
        segment_capacity=512), recs, fam0, fam1)
    queries = _workload(fam0, fam1, ranked)
    cache = ResultCache()
    bat = ScanBatcher(store, cache=cache, log_queries=False)
    cold = bat.scan_batch(queries)
    assert cache.hits == 0 and cache.misses > 0
    warm = bat.scan_batch(queries)
    assert cache.hits > 0
    for q, rc, rw in zip(queries, cold, warm):
        assert _accounting(rc) == _accounting(rw), q.describe()


def test_cache_scan_order_independent_counts(ycsb):
    """Counts never depend on the order cached/uncached queries run in."""
    recs, objs, ranked = ycsb
    fam0, fam1 = _families(ranked)
    queries = _workload(fam0, fam1, ranked)
    perm = list(reversed(range(len(queries))))
    a = _build(CiaoStore(fam0, segment_capacity=512), recs, fam0, fam1,
               jit=False)
    b = _build(CiaoStore(fam0, segment_capacity=512), recs, fam0, fam1,
               jit=False)
    ba = ScanBatcher(a, cache=ResultCache(), log_queries=False)
    bb = ScanBatcher(b, cache=ResultCache(), log_queries=False)
    fwd = ba.scan_batch(queries) + ba.scan_batch(queries)        # cold + warm
    rev = bb.scan_batch([queries[i] for i in perm])
    rev = [rev[perm.index(i)] for i in range(len(queries))]
    rev += [r for r in rev]                                       # warm = cold
    for q, rf, rr in zip(queries, fwd, rev):
        oracle = sum(1 for o in objs if q.matches_exact(o))
        assert rf.count == oracle == rr.count, q.describe()


def test_cache_invalidated_by_ingest(ycsb):
    """data_version bump on ingest: stale (shard, epoch) entries never
    answer — post-ingest batch counts match the fresh oracle."""
    recs, objs, ranked = ycsb
    fam0, fam1 = _families(ranked)
    store = _build(ShardedCiaoStore(
        fam0, router=ShardRouter(n_shards=4, key="linear_score",
                                 mode="hash"),
        segment_capacity=512), recs, fam0, fam1)
    twin = _build(ShardedCiaoStore(
        fam0, router=ShardRouter(n_shards=4, key="linear_score",
                                 mode="hash"),
        segment_capacity=512), recs, fam0, fam1)
    queries = _workload(fam0, fam1, ranked)
    cache = ResultCache()
    bat = ScanBatcher(store, cache=cache, log_queries=False)
    bat.scan_batch(queries)
    bat.scan_batch(queries)            # cache fully warm
    hits_before = cache.hits
    versions = [sh.data_version for sh in store.shards]
    # ingest records routed to shard 0 ONLY: its version bumps, the rest
    # keep their cached entries valid
    router = store.router
    picked = [i for i in range(len(recs))
              if router.shard_of(objs[i], recs[i]) == 0][:48]
    extra = [recs[i] for i in picked]
    eng = NumpyEngine()
    chunk = encode_chunk(extra)
    bv = eng.eval_fused_prefix(chunk, fam1.plan.clauses, 4)
    store.ingest_chunk(chunk, bv, epoch=1, tier=1)
    twin.ingest_chunk(chunk, bv, epoch=1, tier=1)
    after = [sh.data_version for sh in store.shards]
    assert after[0] > versions[0] and after[1:] == versions[1:]
    objs2 = objs + [objs[i] for i in picked]
    got = bat.scan_batch(queries)
    with ShardedScanner(twin, log_queries=False) as sc:
        for q, r in zip(queries, got):
            oracle = sum(1 for o in objs2 if q.matches_exact(o))
            h = sc.scan(q)
            assert r.count == oracle, q.describe()
            assert _accounting(r) == _accounting(h), q.describe()
    # shards untouched by the ingest keep answering from cache
    assert cache.hits > hits_before


def test_cache_epoch_match_required(ycsb):
    """advance_epoch alone (new plan, same data) must invalidate."""
    recs, objs, ranked = ycsb
    fam0, fam1 = _families(ranked)
    store = _build(CiaoStore(fam0, segment_capacity=512), recs, fam0, fam1)
    queries = _workload(fam0, fam1, ranked)[:5]
    cache = ResultCache()
    bat = ScanBatcher(store, cache=cache, log_queries=False)
    before = bat.scan_batch(queries)
    fam2 = evolve_family(store.family, ranked[:8], (2, 4, 8))
    store.advance_epoch(fam2)
    hits0 = cache.hits
    after = bat.scan_batch(queries)
    assert cache.hits == hits0          # nothing answered stale
    for q, r0, r1 in zip(queries, before, after):
        assert r0.count == r1.count     # same data, same counts


def test_cache_lru_and_unhashable(ycsb):
    recs, objs, ranked = ycsb
    fam0, fam1 = _families(ranked)
    cache = ResultCache(cap=2)
    r = ScanBatcher(_build(CiaoStore(fam0, segment_capacity=512), recs,
                           fam0, fam1), cache=cache,
                    log_queries=False).scan(Query((ranked[0],)))
    cache.store(0, Query((ranked[1],)), r, epoch=0, data_version=1)
    cache.store(0, Query((ranked[2],)), r, epoch=0, data_version=1)
    cache.store(0, Query((ranked[3],)), r, epoch=0, data_version=1)
    assert len(cache) == 2             # LRU evicted past cap
    # unhashable clause values are silently uncacheable
    bad = Query((Clause(terms=(SimplePredicate(
        Kind.KEY_VALUE, "k", ["not", "hashable"]),)),))
    cache.store(0, bad, r, epoch=0, data_version=1)
    assert cache.lookup(0, bad, epoch=0, data_version=1) is None
    assert len(cache) == 2
    # invalidate() drops per-shard and globally
    assert cache.invalidate(0) == 2
    assert len(cache) == 0


def test_copy_scan_result_is_deep(ycsb):
    recs, objs, ranked = ycsb
    fam0, fam1 = _families(ranked)
    store = _build(CiaoStore(fam0, segment_capacity=512), recs, fam0, fam1)
    r = ScanBatcher(store, log_queries=False).scan(Query((ranked[0],)))
    c = copy_scan_result(r)
    assert _accounting(c) == _accounting(r)
    c.shards_scanned += 1
    next(iter(c.groups.values())).count += 99
    assert _accounting(c) != _accounting(r)   # no aliasing


# ---------------------------------------------------------------------------
# scanner cache wiring: ShardedScanner shares the same cache contract
# ---------------------------------------------------------------------------

def test_sharded_scanner_cache_wiring(ycsb):
    recs, objs, ranked = ycsb
    fam0, fam1 = _families(ranked)
    store = _build(ShardedCiaoStore(
        fam0, router=ShardRouter(n_shards=4, key="linear_score",
                                 mode="hash"),
        segment_capacity=512), recs, fam0, fam1)
    queries = [Query((c,)) for c in ranked[:6]]     # six DISTINCT clauses
    cache = ResultCache()
    with ShardedScanner(store, cache=cache, log_queries=False) as sc:
        cold = [sc.scan(q) for q in queries]
        assert cache.hits == 0
        warm = [sc.scan(q) for q in queries]
        assert cache.hits > 0
    for q, rc, rw in zip(queries, cold, warm):
        assert _accounting(rc) == _accounting(rw), q.describe()
    # one cache serves batcher and scanner alike: the batcher now hits
    bat = ScanBatcher(store, cache=cache, log_queries=False)
    h0 = cache.hits
    again = bat.scan_batch(queries)
    assert cache.hits > h0
    for q, rw, rb in zip(queries, warm, again):
        assert _accounting(rw) == _accounting(rb), q.describe()


# ---------------------------------------------------------------------------
# telemetry: counters agree with the ScanResult accounting they fold
# ---------------------------------------------------------------------------

def test_telemetry_counters_match_results(ycsb):
    recs, objs, ranked = ycsb
    fam0, fam1 = _families(ranked)
    store = _build(CiaoStore(fam0, segment_capacity=512), recs, fam0, fam1)
    queries = _workload(fam0, fam1, ranked)
    bat = ScanBatcher(store, cache=ResultCache(), log_queries=False)
    got = bat.scan_batch(queries) + bat.scan_batch(queries)
    snap = store.telemetry.snapshot()
    t = snap["tenants"]["default"]
    assert t["scans"] == len(got)
    assert t["count"] == sum(r.count for r in got)
    assert t["rows_scanned"] == sum(r.rows_scanned for r in got)
    assert t["rows_skipped"] == sum(r.rows_skipped for r in got)
    assert t["raw_parsed"] == sum(r.raw_parsed for r in got)
    assert t["segments_pruned"] == sum(r.segments_pruned for r in got)
    assert t["segments_scanned"] == sum(r.segments_scanned for r in got)
    assert t["cache_hits"] == bat.cache.hits
    assert t["cache_misses"] == bat.cache.misses
    assert 0.0 < t["cache_hit_rate"] <= 1.0
    assert 0.0 <= t["zone_skip_fraction"] <= 1.0
    assert t["latency"]["n"] == len(got)
    # per-(epoch, tier) aggregates cover exactly the groups scanned
    by_tier = snap["tiers"]
    want = {}
    for r in got:
        for (e, tr), g in r.groups.items():
            k = f"{e},{tr}"
            want[k] = want.get(k, 0) + g.count
    assert {k: v["count"] for k, v in by_tier.items()} == want


def test_telemetry_tenant_isolation(ycsb):
    recs, objs, ranked = ycsb
    fam0, fam1 = _families(ranked)
    store = _build(CiaoStore(fam0, segment_capacity=512), recs, fam0, fam1)
    q = Query((ranked[0],))
    ScanBatcher(store, tenant="alpha", log_queries=False).scan(q)
    ScanBatcher(store, tenant="beta", log_queries=False).scan_batch([q, q])
    tn = store.telemetry.snapshot()["tenants"]
    assert tn["alpha"]["scans"] == 1
    assert tn["beta"]["scans"] == 2


def test_stats_report_shape(ycsb):
    recs, objs, ranked = ycsb
    fam0, fam1 = _families(ranked)
    store = _build(ShardedCiaoStore(
        fam0, router=ShardRouter(n_shards=2, key="linear_score",
                                 mode="hash"),
        segment_capacity=512), recs, fam0, fam1)
    ScanBatcher(store, log_queries=False).scan(Query((ranked[0],)))
    rep = store.stats_report()
    assert rep["n_shards"] == 2
    assert rep["data_version"] == store.data_version
    assert len(rep["shards"]) == 2
    assert "telemetry" in rep and "tenants" in rep["telemetry"]
    assert json.dumps(rep)              # JSON-serializable end to end


def test_telemetry_feeds_allocator_profiles(ycsb):
    """Measured client rates override the speed*chunk prior."""
    recs, objs, ranked = ycsb
    plane = TelemetryPlane()
    plane.record_client_eval(0, 0.10, 1000)   # 10k rec/s measured
    plane.record_client_eval(1, 0.10, 30000)  # 300k rec/s measured
    m0, m1 = plane.client_eval(0), plane.client_eval(1)
    assert m0["records_per_s"] == pytest.approx(10000.0)
    assert m1["records_per_s"] == pytest.approx(300000.0)

    class _C:                                   # allocator's view of a client
        def __init__(self, shard_id, speed):
            self.shard_id = shard_id
            self.speed = speed
            self.chunk_records = 512
            self.cost_scale = 1.0 / speed

    from repro.data.pipeline import FleetTierAllocator
    fam0, _ = _families(ranked)
    fam = PlanFamily(plan=fam0.plan, tier_sizes=(2, 4, 8),
                     tier_costs=(10.0, 20.0, 40.0),
                     tier_values=(1.0, 2.0, 4.0))
    # equal priors, wildly different measured rates -> weights follow
    alloc = FleetTierAllocator(fam, budget_us=30.0, telemetry=plane)
    w = [p.weight for p in alloc.profiles([_C(0, 1.0), _C(1, 1.0)])]
    assert w[1] == pytest.approx(30 * w[0])
    # no telemetry -> priors (equal speeds, equal weights)
    alloc2 = FleetTierAllocator(fam, budget_us=30.0)
    w2 = [p.weight for p in alloc2.profiles([_C(0, 1.0), _C(1, 1.0)])]
    assert w2[0] == pytest.approx(w2[1])


def test_scanner_telemetry_tristate(ycsb):
    """None inherits store.telemetry, False disables, instance overrides."""
    recs, objs, ranked = ycsb
    fam0, fam1 = _families(ranked)
    store = _build(CiaoStore(fam0, segment_capacity=512), recs, fam0, fam1)
    q = Query((ranked[0],))
    own = TelemetryPlane()
    DataSkippingScanner(store, log_queries=False).scan(q)            # inherit
    DataSkippingScanner(store, log_queries=False,
                        telemetry=False).scan(q)                     # off
    DataSkippingScanner(store, log_queries=False,
                        telemetry=own).scan(q)                       # explicit
    assert store.telemetry.snapshot()["tenants"]["default"]["scans"] == 1
    assert own.snapshot()["tenants"]["default"]["scans"] == 1
