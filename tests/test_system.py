"""End-to-end behaviour tests for the paper's system.

These assert the paper's *mechanisms* at small scale:
  1. the full CIAO pipeline returns exactly the same query answers as a
     full-scan baseline, across budgets and workloads;
  2. loading ratio tracks the union selectivity of the pushed set;
  3. higher budgets never select a worse objective (monotone knapsack);
  4. the CIAO → tokenizer → train-batch path feeds a real train step.
"""
import numpy as np
import pytest

from repro.core.client import NumpyEngine, encode_chunk
from repro.core.planner import build_plan
from repro.core.predicates import Query
from repro.core.server import CiaoStore, DataSkippingScanner, FullScanBaseline
from repro.core.workload import generate_workload
from repro.data.datasets import generate_records, predicate_pool


def _pipeline(dataset, budget, n=2000, n_queries=40, kind="zipf", seed=0):
    records = generate_records(dataset, n, seed=seed)
    pool = predicate_pool(dataset)
    rng = np.random.default_rng(seed)
    wl = generate_workload(
        pool, n_queries=n_queries,
        distribution="zipf" if kind == "zipf" else "uniform",
        zipf_a=1.5, rng=rng,
    )
    rep = build_plan(wl, records[:400], budget_us=budget)
    eng = NumpyEngine()
    store = CiaoStore(rep.plan)
    base = FullScanBaseline()
    for i in range(0, n, 500):
        chunk = encode_chunk(records[i: i + 500])
        bv = (eng.eval_packed(chunk, rep.plan.clauses) if rep.plan.n
              else np.zeros((0, 0), np.uint32))
        store.ingest_chunk(chunk, bv)
        base.ingest_chunk(chunk)
    return wl, rep, store, base, records


@pytest.mark.parametrize("dataset", ("yelp", "winlog", "ycsb"))
@pytest.mark.parametrize("budget", (0.0, 0.5, 1.5))
def test_all_query_answers_exact(dataset, budget):
    wl, rep, store, base, _ = _pipeline(dataset, budget)
    scanner = DataSkippingScanner(store)
    for q in wl.queries[:25]:
        assert scanner.scan(q).count == base.scan(q).count, q.describe()


def test_loading_ratio_tracks_union_selectivity():
    wl, rep, store, base, records = _pipeline("ycsb", 1.5)
    if rep.plan.n == 0:
        pytest.skip("budget pushed nothing")
    union = sum(
        1 for r in records
        if any(c.matches_raw(r) for c in rep.plan.clauses)
    ) / len(records)
    assert abs(store.stats.loading_ratio - union) < 1e-9


def test_budget_monotone_objective():
    records = generate_records("ycsb", 1200, seed=3)
    pool = predicate_pool("ycsb")
    wl = generate_workload(pool, n_queries=40, distribution="zipf",
                           zipf_a=1.5, rng=np.random.default_rng(3))
    objs = []
    for b in (0.25, 0.5, 1.0, 2.0, 4.0):
        rep = build_plan(wl, records[:400], budget_us=b)
        objs.append(rep.selection.objective)
    assert all(a <= b_ + 1e-9 for a, b_ in zip(objs, objs[1:])), objs


def test_ciao_feeds_training_end_to_end():
    """CIAO store → recipe batches → one jitted train step, loss finite."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.data.pipeline import RecipeBatcher
    from repro.data.tokenizer import ByteTokenizer
    from repro.models.layers import split
    from repro.models.model import build_model
    from repro.train import optimizer as opt_mod
    from repro.train.optimizer import OptConfig
    from repro.train.train_step import make_train_step

    wl, rep, store, base, _ = _pipeline("ycsb", 1.5)
    recipe = Query((rep.plan.clauses[0],)) if rep.plan.n else Query(tuple())
    cfg = get_config("qwen3-1.7b").reduced()
    tok = ByteTokenizer(vocab_size=cfg.vocab_size)
    batcher = RecipeBatcher(store, tok, seq_len=64, batch_size=2)
    tokens, mask = next(iter(batcher.batches(recipe)))

    model = build_model(cfg)
    values, _ = split(model.init(jax.random.PRNGKey(0)))
    oc = OptConfig()
    state = opt_mod.init(values, oc)
    step = jax.jit(make_train_step(model, oc))
    _, _, metrics = step(values, state, {
        "tokens": jnp.asarray(tokens), "loss_mask": jnp.asarray(mask)})
    assert np.isfinite(float(metrics["loss"]))
