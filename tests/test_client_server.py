"""Engines agreement + partial loading + data skipping correctness."""
import numpy as np
import pytest

from repro.core.client import NumpyEngine, PythonEngine, encode_chunk
from repro.core.predicates import Query
from repro.core.server import (
    CiaoStore, DataSkippingScanner, FullScanBaseline, PushdownPlan,
)
from repro.core.workload import estimate_selectivities
from repro.data.datasets import generate_records, predicate_pool

DATASETS = ("yelp", "winlog", "ycsb")


@pytest.mark.parametrize("dataset", DATASETS)
def test_numpy_engine_matches_python_oracle(dataset):
    recs = generate_records(dataset, 200, seed=11)
    pool = predicate_pool(dataset)
    rng = np.random.default_rng(3)
    clauses = [pool[i] for i in rng.choice(len(pool), size=25, replace=False)]
    chunk = encode_chunk(recs)
    a = NumpyEngine().eval(chunk, clauses)
    b = PythonEngine().eval(chunk, clauses)
    assert np.array_equal(a, b)


def test_chunk_roundtrip():
    recs = generate_records("yelp", 50, seed=0)
    chunk = encode_chunk(recs)
    assert chunk.records() == recs
    assert chunk.data.shape[1] % 128 == 0


def _build_store(dataset, n=1500, budget_clauses=4, chunk_size=500, seed=2):
    recs = generate_records(dataset, n, seed=seed)
    pool = predicate_pool(dataset)
    sel = estimate_selectivities(pool, recs[:300])
    # choose mid-selectivity clauses so both loaded and unloaded rows exist
    ranked = sorted(pool, key=lambda c: abs(sel[c] - 0.2))
    plan = PushdownPlan(clauses=ranked[:budget_clauses])
    store = CiaoStore(plan)
    eng = NumpyEngine()
    for i in range(0, n, chunk_size):
        chunk = encode_chunk(recs[i : i + chunk_size])
        store.ingest_chunk(chunk, eng.eval_packed(chunk, plan.clauses))
    base = FullScanBaseline()
    for i in range(0, n, chunk_size):
        base.ingest_chunk(encode_chunk(recs[i : i + chunk_size]))
    return store, base, plan, recs


@pytest.mark.parametrize("dataset", DATASETS)
def test_partial_loading_partition(dataset):
    """loaded ∪ raw == all records; loaded == records matching >=1 clause."""
    store, base, plan, recs = _build_store(dataset)
    n_loaded = sum(b.n_rows for b in store.blocks)
    n_raw = sum(r.n for r in store.raw)
    assert n_loaded + n_raw == len(recs)
    expected_loaded = sum(
        1 for r in recs if any(c.matches_raw(r) for c in plan.clauses)
    )
    assert n_loaded == expected_loaded
    assert 0 < n_loaded < len(recs), "need a non-trivial split for this test"


@pytest.mark.parametrize("dataset", DATASETS)
def test_query_counts_match_full_scan(dataset):
    """Pushed-down and non-pushed queries both return exact counts."""
    store, base, plan, recs = _build_store(dataset)
    scanner = DataSkippingScanner(store)
    # queries over pushed clauses (skipping path)
    for c in plan.clauses[:2]:
        q = Query((c,))
        r1, r2 = scanner.scan(q), base.scan(q)
        assert r1.count == r2.count
        assert r1.used_skipping
    # conjunctive query mixing two pushed clauses
    q = Query(tuple(plan.clauses[:2]))
    assert scanner.scan(q).count == base.scan(q).count
    # query with NO pushed clause (must scan raw too)
    pool = predicate_pool("ycsb" if dataset == "ycsb" else dataset)
    other = [c for c in pool if c not in set(plan.clauses)][0]
    q = Query((other,))
    r1, r2 = scanner.scan(q), base.scan(q)
    assert r1.count == r2.count
    assert not r1.used_skipping
    assert r1.raw_parsed > 0


def test_skipping_actually_skips():
    store, base, plan, recs = _build_store("ycsb")
    scanner = DataSkippingScanner(store)
    q = Query((plan.clauses[0],))
    r = scanner.scan(q)
    assert r.rows_skipped > 0


def test_store_save_load_roundtrip(tmp_path):
    store, base, plan, recs = _build_store("winlog", n=600)
    path = str(tmp_path / "store.npz")
    store.save(path)
    from repro.core.server import CiaoStore

    loaded = CiaoStore.load(path, plan)
    s1 = DataSkippingScanner(store)
    s2 = DataSkippingScanner(loaded)
    q = Query((plan.clauses[0],))
    assert s1.scan(q).count == s2.scan(q).count


def test_zero_budget_plan_loads_everything():
    recs = generate_records("yelp", 300, seed=5)
    plan = PushdownPlan(clauses=[])
    store = CiaoStore(plan)
    chunk = encode_chunk(recs)
    store.ingest_chunk(chunk, np.zeros((0, 0), np.uint32))
    assert store.stats.loading_ratio == 1.0
