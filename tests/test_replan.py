"""Adaptive replanning: plan epochs, remap stability, closed-loop control.

Invariants under test (DESIGN.md §11):
  * global clause ids are stable across epochs; the remap table maps new
    local bitvector rows to old ones exactly;
  * a stale-epoch ingest raises BEFORE any state mutates (no corruption);
  * data ingested under epoch k stays queryable — and skippable — after
    epoch k+1 (scan counts always match the full-scan baseline);
  * checkpoints persist the feedback state (observed selectivities,
    LoadStats, plan registry) the replanner depends on;
  * plan hot-swaps between same-shape-bucket epochs do not retrace the
    fused kernel.
"""
import numpy as np
import pytest

from repro.core.client import NumpyEngine, encode_chunk
from repro.core.cost_model import CostModel
from repro.core.predicates import Query, clause, presence
from repro.core.replan import Replanner, ReplanPolicy
from repro.core.server import (
    CiaoStore, DataSkippingScanner, FullScanBaseline, PushdownPlan,
    StaleEpochError, evolve_plan,
)
from repro.core.workload import (
    DriftPhase, drifting_workloads, estimate_selectivities,
)
from repro.data.datasets import generate_records, predicate_pool
from repro.data.pipeline import ClientShard, IngestCoordinator


def _ycsb_plans():
    pool = predicate_pool("ycsb")
    recs = generate_records("ycsb", 600, seed=2)
    sel = estimate_selectivities(pool, recs[:300])
    ranked = sorted(pool, key=lambda c: abs(sel[c] - 0.2))
    return ranked, recs


# ---------------------------------------------------------------------------
# plan epochs and id remapping
# ---------------------------------------------------------------------------

def test_evolve_plan_stable_global_ids_and_remap():
    a, b, c, d = (clause(presence("a")), clause(presence("b")),
                  clause(presence("c")), clause(presence("d")))
    p0 = PushdownPlan(clauses=[a, b, c])
    assert p0.epoch == 0 and p0.global_ids == p0.ids
    # drop b, keep c (moves local row), add d
    p1 = evolve_plan(p0, [c, d, a])
    assert p1.epoch == 1
    assert p1.global_ids[a] == p0.global_ids[a]   # survivor keeps gid
    assert p1.global_ids[c] == p0.global_ids[c]
    assert p1.global_ids[d] == 3                   # fresh monotonic id
    remap = p1.remap_from(p0)
    assert remap.tolist() == [p0.ids[c], -1, p0.ids[a]]
    # dropped-then-repushed clause draws a FRESH id (old bitvector rows
    # were computed under a plan that still had it, so reuse would alias)
    p2 = evolve_plan(p1, [b, d])
    assert p2.global_ids[d] == p1.global_ids[d]
    assert p2.global_ids[b] == 4
    assert p2.remap_from(p1).tolist() == [-1, p1.ids[d]]


def test_retired_global_id_never_reissued():
    """A gid freed two epochs ago must not alias a brand-new clause.

    Regression: the fresh-id counter once ran off the PREVIOUS plan's
    survivors only, so [a,b] -> [a] -> [a,c] re-issued b's gid to c and
    remap_table(0, 2) mapped c onto b's epoch-0 bitvector rows.
    """
    a, b, c = (clause(presence("a")), clause(presence("b")),
               clause(presence("c")))
    p0 = PushdownPlan(clauses=[a, b])          # gids a:0, b:1
    p1 = evolve_plan(p0, [a])                  # b's gid 1 retired
    p2 = evolve_plan(p1, [a, c])
    assert p2.global_ids[c] == 2               # NOT b's retired gid 1
    assert p2.remap_from(p0).tolist() == [0, -1]  # c is no epoch-0 survivor
    assert p2.gid_watermark == 2


def test_scan_iterator_survives_mid_stream_epoch_advance():
    """pushed_by_epoch resolves epochs created after the map was built
    (replan racing a partially-consumed batch iterator)."""
    ranked, recs = _ycsb_plans()
    plan0 = PushdownPlan(clauses=ranked[:2])
    store = CiaoStore(plan0)
    eng = NumpyEngine()
    chunk = encode_chunk(recs[:200])
    store.ingest_chunk(chunk, eng.eval_fused(chunk, plan0.clauses))
    recipe = Query((ranked[0],))
    pushed = store.pushed_by_epoch(recipe)
    # epoch 1 appears while the consumer holds the map
    plan1 = evolve_plan(plan0, [ranked[0], ranked[3]])
    store.advance_epoch(plan1)
    chunk2 = encode_chunk(recs[200:400])
    store.ingest_chunk(chunk2, eng.eval_fused(chunk2, plan1.clauses), epoch=1)
    for blk in store.blocks:
        assert pushed[blk.epoch] is not None  # lazy resolve, no KeyError
    # end-to-end: the batcher iterator built before the bump keeps working
    from repro.data.pipeline import RecipeBatcher
    from repro.data.tokenizer import ByteTokenizer

    store2 = CiaoStore(PushdownPlan(clauses=ranked[:2]))
    store2.ingest_chunk(chunk, eng.eval_fused(chunk, ranked[:2]))
    batcher = RecipeBatcher(store2, ByteTokenizer(vocab_size=1024),
                            seq_len=32, batch_size=2)
    it = batcher.matching_records(recipe)
    next(it)  # start the generator (snapshots the epoch map)
    store2.advance_epoch(evolve_plan(store2.plan, [ranked[0], ranked[3]]))
    store2.ingest_chunk(chunk2, eng.eval_fused(chunk2, store2.plan.clauses),
                        epoch=1)
    n = sum(1 for _ in it)  # must not raise KeyError on epoch-1 blocks
    assert n >= 0


def test_advance_epoch_rejects_non_monotonic():
    plan = PushdownPlan(clauses=[clause(presence("a"))])
    store = CiaoStore(plan)
    with pytest.raises(ValueError):
        store.advance_epoch(PushdownPlan(clauses=[clause(presence("b"))]))
    new = evolve_plan(plan, [clause(presence("b"))])
    remap = store.advance_epoch(new)
    assert store.epoch == 1 and remap.tolist() == [-1]
    assert store.remap_table(0, 1).tolist() == [-1]


def test_stale_epoch_ingest_raises_without_corruption():
    ranked, recs = _ycsb_plans()
    plan0 = PushdownPlan(clauses=ranked[:3])
    store = CiaoStore(plan0)
    eng = NumpyEngine()
    chunk = encode_chunk(recs[:200])
    store.ingest_chunk(chunk, eng.eval_fused(chunk, plan0.clauses),
                       epoch=0)
    plan1 = evolve_plan(plan0, ranked[2:5])
    store.advance_epoch(plan1)
    before = (store.stats.n_records, store.stats.n_loaded,
              len(store.blocks), store.epoch_records(1),
              store.clause_counts.copy())
    # a chunk evaluated under the superseded plan must be rejected whole
    stale_bv = eng.eval_fused(chunk, plan0.clauses)
    with pytest.raises(StaleEpochError):
        store.ingest_chunk(chunk, stale_bv, epoch=0)
    assert (store.stats.n_records, store.stats.n_loaded,
            len(store.blocks), store.epoch_records(1)) == before[:4]
    assert np.array_equal(store.clause_counts, before[4])
    # re-evaluated under the current plan it is accepted
    store.ingest_chunk(chunk, eng.eval_fused(chunk, plan1.clauses), epoch=1)
    assert store.epoch_records(1) == 200


def test_cross_epoch_scan_counts_match_baseline():
    """Bitvectors ingested under epoch k stay queryable after k+1."""
    ranked, recs = _ycsb_plans()
    plan0 = PushdownPlan(clauses=ranked[:3])
    store = CiaoStore(plan0)
    base = FullScanBaseline()
    eng = NumpyEngine()
    for lo in range(0, 300, 100):
        chunk = encode_chunk(recs[lo:lo + 100])
        store.ingest_chunk(chunk, eng.eval_fused(chunk, plan0.clauses))
        base.ingest_chunk(chunk)
    plan1 = evolve_plan(plan0, [ranked[2], ranked[4], ranked[5]])
    store.advance_epoch(plan1)
    for lo in range(300, 600, 100):
        chunk = encode_chunk(recs[lo:lo + 100])
        store.ingest_chunk(chunk, eng.eval_fused(chunk, plan1.clauses),
                           epoch=1)
        base.ingest_chunk(chunk)
    scanner = DataSkippingScanner(store)
    # pushed in both epochs / only old / only new / never pushed
    probes = [ranked[2], ranked[0], ranked[4], ranked[7]]
    for c in probes:
        q = Query((c,))
        assert scanner.scan(q).count == base.scan(q).count, c.describe()
    q = Query((ranked[2], ranked[4]))
    assert scanner.scan(q).count == base.scan(q).count
    assert store.stats.n_jit_loaded > 0  # old-only probes promoted some raw


def test_epoch1_raw_remainder_not_promoted_for_covered_queries():
    ranked, recs = _ycsb_plans()
    plan0 = PushdownPlan(clauses=ranked[:2])
    store = CiaoStore(plan0)
    eng = NumpyEngine()
    chunk = encode_chunk(recs[:300])
    store.ingest_chunk(chunk, eng.eval_fused(chunk, plan0.clauses))
    plan1 = evolve_plan(plan0, [ranked[0], ranked[3]])
    store.advance_epoch(plan1)
    chunk2 = encode_chunk(recs[300:600])
    store.ingest_chunk(chunk2, eng.eval_fused(chunk2, plan1.clauses), epoch=1)
    scanner = DataSkippingScanner(store)
    # ranked[0] is pushed in BOTH epochs: fully covered, zero JIT loads
    r = scanner.scan(Query((ranked[0],)))
    assert r.used_skipping and r.raw_parsed == 0
    assert store.stats.n_jit_loaded == 0
    # ranked[3] is pushed only in epoch 1: epoch-0 raw promoted, epoch-1 kept
    r = scanner.scan(Query((ranked[3],)))
    assert r.raw_parsed > 0
    assert all(rr.epoch == 1 for rr in store.raw)


# ---------------------------------------------------------------------------
# persistence (the save/load bugfix)
# ---------------------------------------------------------------------------

def test_save_load_preserves_selectivities_stats_and_epochs(tmp_path):
    ranked, recs = _ycsb_plans()
    plan0 = PushdownPlan(clauses=ranked[:3])
    store = CiaoStore(plan0)
    eng = NumpyEngine()
    chunk = encode_chunk(recs[:300])
    store.ingest_chunk(chunk, eng.eval_fused(chunk, plan0.clauses))
    plan1 = evolve_plan(plan0, [ranked[2], ranked[4]])
    store.advance_epoch(plan1)
    chunk2 = encode_chunk(recs[300:500])
    store.ingest_chunk(chunk2, eng.eval_fused(chunk2, plan1.clauses), epoch=1)
    # force a JIT promotion so every block list is non-trivial
    DataSkippingScanner(store).scan(Query((ranked[7],)))

    path = str(tmp_path / "store.npz")
    store.save(path)
    loaded = CiaoStore.load(path)

    # the replanner's feedback state survives the restore
    assert loaded.epoch == 1
    # ... including the workload window (coverage drift resumes warm)
    assert loaded.query_log == store.query_log
    assert sorted(loaded.plans) == [0, 1]
    assert loaded.plans[0].clauses == plan0.clauses
    assert loaded.plan.global_ids == plan1.global_ids
    for e in (0, 1):
        assert loaded.epoch_records(e) == store.epoch_records(e)
        assert np.array_equal(loaded.observed_selectivities(e),
                              store.observed_selectivities(e))
    assert loaded.observed_selectivities().any()  # regression: was all-zero
    s0, s1 = store.stats, loaded.stats
    assert (s0.n_records, s0.n_loaded, s0.n_jit_loaded) == \
        (s1.n_records, s1.n_loaded, s1.n_jit_loaded)
    assert s1.loading_ratio == s0.loading_ratio

    # scans agree block-for-block after restore
    q = Query((ranked[4],))
    r1 = DataSkippingScanner(store, log_queries=False).scan(q)
    r2 = DataSkippingScanner(loaded, log_queries=False).scan(q)
    assert (r1.count, r1.rows_scanned) == (r2.count, r2.rows_scanned)

    # restoring under a mismatched plan is rejected loudly
    with pytest.raises(ValueError):
        CiaoStore.load(path, PushdownPlan(clauses=ranked[5:7]))


# ---------------------------------------------------------------------------
# the control loop
# ---------------------------------------------------------------------------

def _drift_setup(n_queries=60):
    pool = predicate_pool("ycsb")
    wl1, wl2 = drifting_workloads(
        pool,
        [DriftPhase(n_queries, "zipf", 1.5, seed=1),
         DriftPhase(n_queries, "zipf", 2.0, seed=7)],
    )
    sample = generate_records("ycsb", 300, seed=17)
    return pool, wl1, wl2, sample


def test_replanner_bumps_epoch_on_coverage_drift():
    pool, wl1, wl2, sample = _drift_setup()
    sel = estimate_selectivities(wl1.clause_pool(), sample)
    hot = sorted(wl1.clause_pool(),
                 key=lambda c: sum(1 for q in wl1.queries if c in q.clauses))
    plan0 = PushdownPlan(clauses=hot[-2:])
    store = CiaoStore(plan0)
    policy = ReplanPolicy(check_every_records=256, min_observe_records=128,
                          workload_window=24, min_window_queries=8)
    repl = Replanner(store, sample, budget_us=60.0, base_workload=wl1,
                     cost_model=CostModel().scaled(20.0), policy=policy,
                     planned_sel=sel)
    eng = NumpyEngine()
    scanner = DataSkippingScanner(store)
    shards = [ClientShard("ycsb", i, eng, plan0, chunk_records=128)
              for i in range(2)]
    q1, q2 = iter(wl1.queries), iter(wl2.queries)

    def on_chunk(done):
        src = q1 if store.epoch == 0 and done <= 4 else q2
        for _ in range(4):
            q = next(src, None)
            if q is not None:
                scanner.scan(q)

    coord = IngestCoordinator(shards, store, replanner=repl,
                              on_chunk=on_chunk)
    coord.run(chunks_per_client=6)
    assert coord.epoch_bumps >= 1
    assert store.epoch >= 1
    assert repl.history[0].reason == "coverage"
    # the broadcast reached every shard: all evaluate the current plan
    assert all(s.plan is store.plan for s in shards)
    # ingest continued under the new epoch
    assert store.epoch_records(store.epoch) > 0
    # client timing reports recalibrated the cost model
    assert repl.cost_scale != 1.0


def test_replanner_quiet_without_drift():
    pool, wl1, _, sample = _drift_setup()
    sel = estimate_selectivities(wl1.clause_pool(), sample)
    hot = sorted(wl1.clause_pool(),
                 key=lambda c: sum(1 for q in wl1.queries if c in q.clauses))
    plan0 = PushdownPlan(clauses=hot[-2:])
    store = CiaoStore(plan0)
    policy = ReplanPolicy(check_every_records=256, min_observe_records=128,
                          workload_window=24, min_window_queries=8)
    repl = Replanner(store, sample, budget_us=60.0, base_workload=wl1,
                     cost_model=CostModel().scaled(20.0), policy=policy,
                     planned_sel=sel)
    eng = NumpyEngine()
    scanner = DataSkippingScanner(store)
    shards = [ClientShard("ycsb", i, eng, plan0, chunk_records=128)
              for i in range(2)]
    qs = iter(wl1.queries * 2)  # stationary workload: same distribution

    def on_chunk(done):
        for _ in range(4):
            q = next(qs, None)
            if q is not None:
                scanner.scan(q)

    coord = IngestCoordinator(shards, store, replanner=repl,
                              on_chunk=on_chunk)
    coord.run(chunks_per_client=6)
    assert store.epoch == 0 and coord.epoch_bumps == 0


def test_observe_timing_recalibrates_and_clamps():
    ranked, recs = _ycsb_plans()
    plan0 = PushdownPlan(clauses=ranked[:2])
    store = CiaoStore(plan0)
    repl = Replanner(store, recs[:100], budget_us=5.0,
                     policy=ReplanPolicy(max_cost_scale=50.0))
    predicted = repl._predicted_plan_us()
    assert predicted > 0
    # observed exactly 3x the predicted cost -> scale 3
    repl.observe_timing(1000, predicted * 3 * 1000 / 1e6)
    assert repl.cost_scale == pytest.approx(3.0, rel=1e-6)
    # absurd reports clamp at the policy bound
    repl.observe_timing(1000, predicted * 1e6 * 1000 / 1e6)
    assert repl.cost_scale <= 50.0
    m = CostModel()
    s = m.scaled(2.0)
    assert s.clause_cost(plan0.clauses[0], 0.1) == pytest.approx(
        2.0 * m.clause_cost(plan0.clauses[0], 0.1))
    with pytest.raises(ValueError):
        m.scaled(0.0)


def test_query_log_stays_bounded():
    plan = PushdownPlan(clauses=[clause(presence("a"))])
    store = CiaoStore(plan)
    store.query_log_cap = 10
    q = Query((plan.clauses[0],))
    for _ in range(100):
        store.log_query(q)
    assert len(store.query_log) <= 20  # trimmed at 2x cap, back to cap
    assert store.query_log[-1] is q


def test_forced_step_same_selection_is_a_noop():
    pool, wl1, _, sample = _drift_setup()
    rep_sel = estimate_selectivities(wl1.clause_pool(), sample)
    from repro.core.planner import build_plan
    cm = CostModel().scaled(20.0)
    rep = build_plan(wl1, sample, budget_us=60.0, cost_model=cm)
    store = CiaoStore(PushdownPlan(clauses=list(rep.plan.clauses)))
    repl = Replanner(store, sample, budget_us=60.0, base_workload=wl1,
                     cost_model=cm, planned_sel=rep_sel,
                     policy=ReplanPolicy(recalibrate_cost=False))
    # no observations at all: the re-solve reproduces the same selection
    assert repl.step(force=True) is None
    assert store.epoch == 0 and not repl.history
