"""Roofline analysis: HLO trip-count parser + analytic flops validation.

The analytic FLOPs model must agree with XLA ``cost_analysis`` on a config
small enough to compile fully unrolled (scan_layers=False, no flash
chunking) — this is the contract that lets the big cells use the model.
"""
import dataclasses
import json

import jax
import pytest

from repro.analysis import flops as flops_mod
from repro.analysis.hlo import collective_bytes
from repro.configs import get_config
from repro.configs.base import ShapeConfig

SYNTH_HLO = """
HloModule test

%body (p: (s32[], f32[128,128])) -> (s32[], f32[128,128]) {
  %p = (s32[], f32[128,128]) parameter(0)
  %ag = f32[128,128] all-gather(%x), dimensions={0}
  ROOT %t = (s32[], f32[128,128]) tuple(%i, %ag)
}

%cond (p: (s32[], f32[128,128])) -> pred[] {
  %p = (s32[], f32[128,128]) parameter(0)
  %c = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (a: f32[128,128]) -> f32[128,128] {
  %a = f32[128,128] parameter(0)
  %ar = f32[128,128] all-reduce(%a), to_apply=%add
  %w = (s32[], f32[128,128]) while(%init), condition=%cond, body=%body
  ROOT %r = f32[128,128] get-tuple-element(%w), index=1
}
"""


def test_parser_scales_while_bodies():
    out = collective_bytes(SYNTH_HLO)
    unit = 128 * 128 * 4
    assert out["bytes"]["all-reduce"] == 2 * unit      # 2x ring factor
    assert out["bytes"]["all-gather"] == 7 * unit      # trip count 7
    assert out["counts"]["all-gather"] == 7


def test_parser_prefers_backend_config_trip_count():
    hlo = SYNTH_HLO.replace(
        "body=%body", 'body=%body, backend_config={"known_trip_count":{"n":"3"}}'
    )
    out = collective_bytes(hlo)
    assert out["counts"]["all-gather"] == 3


def _flops_from_compiled(cfg, shape, kind="train"):
    """cost_analysis flops of a fully-unrolled compiled step (1 device)."""
    from repro.models.model import build_model
    from repro.train.optimizer import OptConfig
    from repro.train.train_step import make_train_step
    from repro.configs import input_specs
    from repro.train import optimizer as opt_mod

    model = build_model(cfg)
    values_sds, _ = model.abstract_params()
    specs = input_specs(cfg, shape)
    if kind == "train":
        oc = OptConfig()
        opt_sds = jax.eval_shape(lambda p: opt_mod.init(p, oc), values_sds)
        fn = make_train_step(model, oc, n_micro=1)
        compiled = jax.jit(fn).lower(values_sds, opt_sds, specs).compile()
    else:
        def fn(params, inputs):
            return model.prefill(params, inputs, s_alloc=shape.seq_len + 8)
        compiled = jax.jit(fn).lower(values_sds, specs).compile()
    from repro._compat.jaxapi import cost_analysis

    return float(cost_analysis(compiled)["flops"])


@pytest.mark.parametrize("arch", ["qwen3-8b", "deepseek-v3-671b", "recurrentgemma-9b"])
def test_analytic_flops_matches_compiled_unrolled(arch):
    cfg = get_config(arch).reduced()
    # unrolled, no remat, no flash chunking, fp32 for clean accounting
    cfg = dataclasses.replace(
        cfg, scan_layers=False, remat="none", microbatches=1,
        attn_q_chunk=4096, attn_k_chunk=4096, compute_dtype="float32",
        param_dtype="float32",
    )
    shape = ShapeConfig("t", "train", 128, 2)
    compiled_flops = _flops_from_compiled(cfg, shape)
    model_cfg_est = dataclasses.replace(cfg, remat="none")
    from repro.models.model import build_model

    m = build_model(cfg)
    est = flops_mod.estimate(model_cfg_est, shape, m.param_count(),
                             m.active_param_count())
    ratio = est.flops_global / compiled_flops
    # XLA counts transcendental/elementwise ops that the model skips, and the
    # model's causal-attention factor is exact while XLA prices the full
    # masked matmul: accept 0.5x..1.6x
    assert 0.5 < ratio < 1.6, (ratio, est.flops_global, compiled_flops)


def test_estimate_close_to_six_nd_dense():
    cfg = get_config("qwen3-8b")
    from repro.models.model import build_model

    m = build_model(cfg)
    shape = ShapeConfig("t", "train", 4096, 256)
    est = flops_mod.estimate(cfg, shape, m.param_count(), m.active_param_count())
    six_nd = 6.0 * m.param_count() * shape.global_batch * shape.seq_len
    # remat=full means ~4/3 of the classic 3x-forward accounting, plus
    # attention score flops on top of 6ND
    assert 1.0 < est.flops_global / six_nd < 2.2


def test_dryrun_artifacts_complete():
    """All 40 cells x 2 meshes recorded (ok or documented skip)."""
    import glob

    files = glob.glob("artifacts/dryrun/*.json")
    if len(files) < 80:
        pytest.skip("dry-run sweep artifacts not present in this checkout")
    n_ok = n_skip = 0
    for f in files:
        rec = json.load(open(f))
        if "skipped" in rec:
            n_skip += 1
        else:
            assert rec["roofline"]["device_flops"] > 0, f
            n_ok += 1
    assert n_ok == 64 and n_skip == 16, (n_ok, n_skip)
