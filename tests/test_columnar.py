"""Columnar scan engine: lowering exactness, zone-map soundness, and the
differential sweep vs the ``matches_exact`` / FullScanBaseline oracle
(DESIGN.md §13).

The load-bearing invariant: the vectorized scanner must produce counts
BIT-IDENTICAL to per-row exact evaluation across mixed epochs, mixed
tiers, partial coverage prefixes, zone-map-pruned segments, promoted
remainders, and the all-pruned / empty-store edges.
"""
import json

import numpy as np
import pytest

from repro.core import bitvector
from repro.core.client import NumpyEngine, encode_chunk
from repro.core.columnar import (
    ColumnarSegment, build_key_columns, eval_lowered, query_mask,
)
from repro.core.predicates import (
    Query, clause, exact, json_scalar, key_value, lowerable, presence,
    substring,
)
from repro.core.server import (
    CiaoStore, DataSkippingScanner, FullScanBaseline, PlanFamily,
    PushdownPlan, evolve_family,
)
from repro.core.workload import estimate_selectivities
from repro.data.datasets import generate_records, predicate_pool


def _segment(objs, n_covered=0, bits=None, epoch=0, tier=0):
    recs = [json.dumps(o, separators=(",", ":")).encode() for o in objs]
    if bits is None:
        bits = np.zeros((n_covered, len(objs)), bool)
    return ColumnarSegment(
        records=recs, bitvectors=bitvector.pack(bits),
        epoch=epoch, n_covered=n_covered, tier=tier)


# ---------------------------------------------------------------------------
# predicate lowering: exact matches_exact semantics over columns
# ---------------------------------------------------------------------------

# adversarial value mix: cross-representation pairs (10 vs "10" vs 10.0),
# bool-vs-int traps (True vs 1), None, nested values, numeric strings
_TRICKY_OBJS = [
    {"a": 10, "b": "x"},
    {"a": "10", "b": "xy"},
    {"a": 10.0, "c": True},
    {"a": True, "c": 1},
    {"a": False, "c": 0},
    {"a": None, "b": "none"},
    {"a": "true", "c": "None"},
    {"b": "contains 10 inside", "c": 2.5},
    {"a": [1, 2], "b": {"nested": 1}},
    {"a": "", "b": "x", "c": -3},
    {"c": 24e-1},
    {"a": 2.4, "c": "2.4"},
]

_TRICKY_PREDS = [
    key_value("a", 10), key_value("a", 10.0), key_value("a", "10"),
    key_value("a", True), key_value("c", 1), key_value("c", True),
    key_value("c", 0), key_value("c", False), key_value("a", None),
    key_value("c", 2.4), key_value("c", "2.4"), key_value("c", 24e-1),
    key_value("missing", 1),
    exact("a", "10"), exact("a", "true"), exact("a", ""), exact("b", "x"),
    substring("b", "10"), substring("b", "x"), substring("a", "1"),
    presence("a"), presence("c"), presence("missing"),
]


@pytest.mark.parametrize("pred", _TRICKY_PREDS,
                         ids=[p.describe() for p in _TRICKY_PREDS])
def test_lowered_predicates_match_exact_oracle(pred):
    cols = build_key_columns(_TRICKY_OBJS)
    assert lowerable(pred)
    col = cols.get(pred.key)
    if col is None:
        got = np.zeros(len(_TRICKY_OBJS), bool)
    else:
        got = eval_lowered(col, pred)
    want = np.array([pred.matches_exact(o) for o in _TRICKY_OBJS])
    assert np.array_equal(got, want), (pred.describe(), got, want)


def test_lowered_random_sweep_matches_exact_oracle():
    rng = np.random.default_rng(11)
    keys = ["k0", "k1", "k2", "k3"]
    vals = [0, 1, 7, 10, -3, 2.5, 10.0, "10", "a", "ab", "true", "None",
            True, False, None]
    objs = []
    for _ in range(300):
        o = {}
        for k in keys:
            if rng.random() < 0.75:
                o[k] = vals[int(rng.integers(len(vals)))]
        objs.append(o)
    cols = build_key_columns(objs)
    preds = []
    for k in keys + ["absent"]:
        for v in vals:
            preds.append(key_value(k, v))
            if isinstance(v, str):
                preds.append(exact(k, v))
                preds.append(substring(k, v))
        preds.append(presence(k))
    for p in preds:
        col = cols.get(p.key)
        got = (np.zeros(len(objs), bool) if col is None
               else eval_lowered(col, p))
        want = np.array([p.matches_exact(o) for o in objs])
        assert np.array_equal(got, want), p.describe()


def test_non_lowerable_terms_fall_back_to_exact():
    # EXACT with a non-string operand is outside the lowering (and CAN
    # match: kind EXACT compares v == value directly); the clause must
    # still evaluate exactly through the per-row raw-bytes fallback
    weird = exact("a", 10)
    assert not lowerable(weird)
    seg = _segment(_TRICKY_OBJS)
    q = Query((clause(weird, key_value("b", "xy")),))
    mask = query_mask(seg, q)
    want = np.array([q.matches_exact(o) for o in _TRICKY_OBJS])
    assert np.array_equal(mask, want)
    assert mask.any()  # the fallback actually fired on a matching row


def test_huge_int_no_float64_aliasing():
    big = (1 << 53) + 1
    objs = [{"a": big}, {"a": float(1 << 53)}, {"a": 1 << 53},
            {"a": str(big)}]
    cols = build_key_columns(objs)
    for v in (big, 1 << 53, float(1 << 53), str(big)):
        p = key_value("a", v)
        got = eval_lowered(cols["a"], p)
        want = np.array([p.matches_exact(o) for o in objs])
        assert np.array_equal(got, want), (v, got, want)


# ---------------------------------------------------------------------------
# zone maps
# ---------------------------------------------------------------------------

def test_zone_map_refutations_are_sound():
    rng = np.random.default_rng(3)
    objs = [{"n": int(rng.integers(50, 80)), "s": f"w{i % 7}"}
            for i in range(64)] + [{"n": 60, "s": "w0"}]
    seg = _segment(objs)
    refuted = [
        clause(key_value("n", 10)),        # below num_min
        clause(key_value("n", 99)),        # above num_max
        clause(exact("s", "w9")),          # not in the dictionary
        clause(substring("s", "zz")),      # no dict entry contains it
        clause(presence("missing")),       # key absent everywhere
    ]
    for c in refuted:
        assert not seg.clause_possible(c), c.describe()
        # soundness: the refutation must imply ZERO exact matches
        assert not any(Query((c,)).matches_exact(o) for o in objs)
    possible = [
        clause(key_value("n", 60)), clause(exact("s", "w0")),
        clause(substring("s", "w")), clause(presence("n")),
        clause(key_value("n", 10), key_value("n", 60)),  # OR: one disjunct
    ]
    for c in possible:
        assert seg.clause_possible(c), c.describe()


def test_zone_map_nan_marks_column_nonprunable():
    """NaN poisoning regression (DESIGN.md §14): a NaN among a key's
    numeric values marks the zone map non-prunable, and no segment is
    ever wrongly skipped — every numeric lookup's count stays exact."""
    objs = [{"n": 10.0, "s": "a"}, {"n": float("nan"), "s": "b"},
            {"n": 90.0, "s": "c"}, {"n": float("nan"), "s": "d"}] * 8
    seg = _segment(objs)
    assert not seg.key_cols["n"].num_prunable   # detected at build time
    assert seg.key_cols["s"].num_prunable       # only the NaN column
    # min/max over the non-NaN values stays clean (NaN never enters num)
    assert (seg.key_cols["n"].num_min, seg.key_cols["n"].num_max) == \
        (10.0, 90.0)
    # no wrongful skip: every lookup with >= 1 exact match stays possible,
    # and query_mask reproduces matches_exact bit for bit — NaN included
    for v in (10, 10.0, 90, float("nan")):
        c = clause(key_value("n", v))
        assert seg.clause_possible(c)
        mask = query_mask(seg, Query((c,)))
        want = np.array([Query((c,)).matches_exact(o) for o in objs])
        assert np.array_equal(mask, want), v
    # values absent in EVERY representation may still be refuted by the
    # exact repr set (sound: a NaN row equals nothing but NaN)
    assert not seg.clause_possible(clause(key_value("n", 55)))
    assert sum(1 for o in objs
               if Query((clause(key_value("n", 55)),)).matches_exact(o)) == 0


def test_scan_counts_exact_with_pruned_and_all_pruned_segments():
    recs = generate_records("ycsb", 900, seed=21)
    pool = predicate_pool("ycsb")
    plan = PushdownPlan(clauses=pool[:2])
    store = CiaoStore(plan, segment_capacity=256)   # many small segments
    eng = NumpyEngine()
    for lo in range(0, 900, 300):
        chunk = encode_chunk(recs[lo:lo + 300])
        store.ingest_chunk(chunk, eng.eval_fused(chunk, plan.clauses))
    base = FullScanBaseline()
    for lo in range(0, 900, 300):
        base.ingest_chunk(encode_chunk(recs[lo:lo + 300]))
    scanner = DataSkippingScanner(store, log_queries=False)
    # every-segment-pruned edge: value outside every zone map
    q = Query((clause(key_value("linear_score", 250)),))
    r = scanner.scan(q)
    assert r.count == base.scan(q).count == 0
    assert r.segments_pruned == len(store.blocks) + len(store.jit_blocks)
    # point lookup: most segments pruned via the repr dictionary, counts
    # still exact
    target = json.loads(recs[5])["customer_id"]
    q = Query((clause(key_value("customer_id", target)),))
    r = scanner.scan(q)
    assert r.count == base.scan(q).count >= 1
    assert r.segments_pruned >= 1
    # empty store edge
    empty = CiaoStore(PushdownPlan(clauses=pool[:2]))
    r = DataSkippingScanner(empty, log_queries=False).scan(q)
    assert r.count == 0 and r.rows_scanned == 0


# ---------------------------------------------------------------------------
# THE differential sweep: mixed epochs x tiers x coverage prefixes
# ---------------------------------------------------------------------------

def _mixed_store(segment_capacity=512):
    recs = generate_records("ycsb", 1800, seed=9)
    pool = predicate_pool("ycsb")
    sel = estimate_selectivities(pool, recs[:300])
    ranked = sorted(pool, key=lambda c: abs(sel[c] - 0.25))
    fam0 = PlanFamily(plan=PushdownPlan(clauses=ranked[:6]),
                      tier_sizes=(0, 2, 6))
    store = CiaoStore(fam0, segment_capacity=segment_capacity)
    eng = NumpyEngine()
    for i, lo in enumerate(range(0, 900, 300)):
        tier = i % 3
        chunk = encode_chunk(recs[lo:lo + 300])
        bv = eng.eval_fused_prefix(chunk, fam0.plan.clauses,
                                   fam0.tier_sizes[tier])
        store.ingest_chunk(chunk, bv, tier=tier)
    fam1 = evolve_family(fam0, ranked[2:8], (1, 3, 6))
    store.advance_epoch(fam1)
    for i, lo in enumerate(range(900, 1800, 300)):
        tier = (i + 1) % 3
        chunk = encode_chunk(recs[lo:lo + 300])
        bv = eng.eval_fused_prefix(chunk, fam1.plan.clauses,
                                   fam1.tier_sizes[tier])
        store.ingest_chunk(chunk, bv, epoch=1, tier=tier)
    base = FullScanBaseline()
    for lo in range(0, 1800, 300):
        base.ingest_chunk(encode_chunk(recs[lo:lo + 300]))
    return store, base, ranked, recs


def test_differential_columnar_vs_full_scan_oracle():
    store, base, ranked, recs = _mixed_store()
    scanner = DataSkippingScanner(store, log_queries=False)
    queries = (
        [Query((c,)) for c in ranked[:10]] +
        [Query((a, b)) for a, b in zip(ranked[:4], ranked[6:10])] +
        [Query((ranked[0], ranked[1], ranked[12]))] +
        [Query((clause(key_value("linear_score", 250)),)),
         Query((clause(exact("phone_country", "ZZ")),)),
         Query((clause(presence("email")),)),
         Query((clause(substring("url_site", "www.")),))]
    )
    for q in queries:
        r = scanner.scan(q)
        assert r.count == base.scan(q).count, q.describe()
        # aggregate accounting stays consistent under pruning
        assert r.rows_scanned + r.rows_skipped == sum(
            s.n_rows for s in list(store.blocks) + list(store.jit_blocks))
    # second pass: memoized clause masks / AND masks must not drift
    for q in queries:
        assert scanner.scan(q).count == base.scan(q).count, q.describe()


def test_differential_sweep_across_segment_capacities():
    for cap in (128, 1024, 8192):
        store, base, ranked, recs = _mixed_store(segment_capacity=cap)
        scanner = DataSkippingScanner(store, log_queries=False)
        for q in [Query((c,)) for c in ranked[:6]] + \
                 [Query((ranked[0], ranked[7]))]:
            assert scanner.scan(q).count == base.scan(q).count, \
                (cap, q.describe())


def test_recipe_batcher_streams_source_bytes():
    """Matching records come back as the ORIGINAL ingested bytes — no
    json.dumps round-trip — and exactly the oracle's match set."""
    from repro.data.pipeline import RecipeBatcher
    from repro.data.tokenizer import ByteTokenizer

    store, base, ranked, recs = _mixed_store()
    recipe = Query((ranked[1],))
    b = RecipeBatcher(store, ByteTokenizer(vocab_size=512),
                      seq_len=16, batch_size=2)
    got = list(b.matching_records(recipe))
    want = [r for r in recs if recipe.matches_exact(json.loads(r))]
    assert sorted(got) == sorted(want)


def test_segment_compaction_bounds_and_order():
    store, base, ranked, recs = _mixed_store(segment_capacity=512)
    segs = store.blocks
    # loaded rows survive compaction exactly once
    n_loaded = sum(s.n_rows for s in segs)
    assert n_loaded == store.stats.n_loaded
    # sealed segments respect the capacity bound (cap + one chunk slack)
    for s in store.segments:
        assert s.n_rows <= 512 + 300
    # every segment is homogeneous in its coverage group
    for s in segs:
        assert s.bitvectors.shape[0] == s.n_covered
        assert s.bitvectors.shape[1] == bitvector.num_words(s.n_rows)


def test_save_load_format4_roundtrip(tmp_path):
    store, base, ranked, recs = _mixed_store()
    DataSkippingScanner(store).scan(Query((ranked[9],)))  # force JIT
    path = str(tmp_path / "store.npz")
    store.save(path)
    loaded = CiaoStore.load(path)
    # compaction behavior survives the restore (not the 8192 default)
    assert loaded.segment_capacity == store.segment_capacity == 512
    assert [s.n_covered for s in loaded.blocks] == \
        [s.n_covered for s in store.blocks]
    assert [s.records() for s in loaded.blocks] == \
        [s.records() for s in store.blocks]
    s1 = DataSkippingScanner(store, log_queries=False)
    s2 = DataSkippingScanner(loaded, log_queries=False)
    for q in (Query((ranked[0],)), Query((ranked[2], ranked[7]))):
        a, b2 = s1.scan(q), s2.scan(q)
        assert (a.count, a.rows_scanned, a.rows_skipped,
                a.segments_pruned) == \
            (b2.count, b2.rows_scanned, b2.rows_skipped, b2.segments_pruned)


def test_load_migrates_format3_checkpoint(tmp_path):
    """A format-3 checkpoint (parsed row dicts per block) restores into
    columnar segments with identical scan results."""
    recs = generate_records("ycsb", 200, seed=4)
    pool = predicate_pool("ycsb")
    plan = PushdownPlan(clauses=pool[:2])
    store = CiaoStore(plan)
    eng = NumpyEngine()
    chunk = encode_chunk(recs)
    store.ingest_chunk(chunk, eng.eval_fused(chunk, plan.clauses))
    path = str(tmp_path / "f3.npz")
    store.save(path)

    # rewrite the checkpoint into the legacy format-3 shape: rows_<i>
    # JSON instead of seg_blob/seg_off
    z = dict(np.load(path))
    meta = json.loads(bytes(z["meta"].tobytes()).decode())
    assert meta["format"] == 4
    meta["format"] = 3
    z["meta"] = np.frombuffer(json.dumps(meta).encode(), np.uint8)
    for bi in range(int(z["n_blocks"])):
        blob, off = z.pop(f"seg_blob_{bi}"), z.pop(f"seg_off_{bi}")
        b = blob.tobytes()
        rows = [json.loads(b[off[i]:off[i + 1]])
                for i in range(len(off) - 1)]
        z[f"rows_{bi}"] = np.frombuffer(
            json.dumps(rows).encode(), np.uint8)
    legacy = str(tmp_path / "legacy.npz")
    np.savez_compressed(legacy, **z)

    loaded = CiaoStore.load(legacy)
    q = Query((plan.clauses[0],))
    a = DataSkippingScanner(store, log_queries=False).scan(q)
    b = DataSkippingScanner(loaded, log_queries=False).scan(q)
    assert (a.count, a.rows_scanned) == (b.count, b.rows_scanned)


def test_scan_counts_independent_of_query_order_across_value_types():
    # Regression: segment clause caches (and the pushed-clause lookup)
    # key on clause equality, and Python's 10 == 10.0 aliased the int and
    # float probes — the first query's cached mask answered the second,
    # so counts depended on query ORDER.  The probes differ exactly on
    # string rows: json_scalar(10) = "10" matches the row "10",
    # json_scalar(10.0) = "10.0" does not.
    objs = [{"score": 100 + i} for i in range(20)] + [{"score": "10"}] * 4
    recs = [json.dumps(o).encode() for o in objs]
    q_int = Query((clause(key_value("score", 10)),))
    q_float = Query((clause(key_value("score", 10.0)),))
    oracles = {q: sum(1 for o in objs if q.matches_exact(o))
               for q in (q_int, q_float)}
    assert oracles[q_int] == 4 and oracles[q_float] == 0
    for order in ((q_int, q_float), (q_float, q_int)):
        store = CiaoStore(PushdownPlan(clauses=[]), segment_capacity=64)
        chunk = encode_chunk(recs)
        store.ingest_chunk(chunk, np.zeros((0, chunk.n_records), bool))
        s = DataSkippingScanner(store, log_queries=False)
        for q in order:
            assert s.scan(q).count == oracles[q]


def test_xla_and_reduce_matches_numpy():
    from repro.kernels.residual import bv_and_many_xla, popcount_xla

    rng = np.random.default_rng(0)
    words = rng.integers(0, 2**32, size=(5, 7), dtype=np.uint64
                         ).astype(np.uint32)
    assert np.array_equal(bv_and_many_xla(words),
                          bitvector.bv_and_many(words))
    assert popcount_xla(words) == int(bitvector.popcount_rows(words).sum())
    # end to end: a scanner routed through the device AND-reduce agrees
    store, base, ranked, recs = _mixed_store()
    s_np = DataSkippingScanner(store, log_queries=False)
    s_xla = DataSkippingScanner(store, log_queries=False,
                                and_reduce=bv_and_many_xla)
    for q in [Query((c,)) for c in ranked[:4]]:
        assert s_np.scan(q).count == s_xla.scan(q).count == \
            base.scan(q).count
