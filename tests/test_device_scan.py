"""Device-resident scan plane (DESIGN.md §15): differential sweeps.

The contract under test everywhere: :class:`DeviceScanner` /
:class:`ShardedDeviceScanner` results are BIT-IDENTICAL to the host
``DataSkippingScanner`` / ``ShardedScanner`` — not just counts, but the
full accounting surface (rows_scanned / rows_skipped / raw_parsed /
segments_pruned and every per-(epoch, tier) group) — across backends
(xla / numpy reference / pallas interpret), shard counts (1 / 4 / 8),
mixed epochs and tiers, dictionary strings, NaN zone bounds, cache
eviction under a starved byte budget, and batched vs one-at-a-time
launches.  Plus the cache-plane residency contract (zero steady-state
uploads) and the ``kernels.residual`` pow2-bucket jit-cache pin.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.client import NumpyEngine, encode_chunk
from repro.core.device_scan import DeviceScanner, ShardedDeviceScanner
from repro.core.predicates import (
    Query, clause, exact, key_value, presence, substring,
)
from repro.core.server import (
    CiaoStore, DataSkippingScanner, PlanFamily, PushdownPlan, evolve_family,
)
from repro.core.shard import ShardedCiaoStore, ShardedScanner, ShardRouter
from repro.core.workload import estimate_selectivities
from repro.data.datasets import generate_records, predicate_pool

CHUNK = 256
N_RECORDS = 2048


def _accounting(r) -> tuple:
    return (r.count, r.rows_scanned, r.rows_skipped, r.raw_parsed,
            r.segments_pruned, r.shards_pruned, r.used_skipping,
            tuple(sorted(
                (k, (g.count, g.rows_scanned, g.rows_skipped, g.raw_parsed,
                     g.segments_pruned))
                for k, g in r.groups.items())))


@pytest.fixture(scope="module")
def ycsb():
    recs = generate_records("ycsb", N_RECORDS, seed=7)
    pool = predicate_pool("ycsb")
    sel = estimate_selectivities(pool, recs[:300])
    ranked = sorted(pool, key=lambda c: abs(sel[c] - 0.2))
    objs = [json.loads(r) for r in recs]
    return recs, objs, ranked


def _families(ranked):
    fam0 = PlanFamily(plan=PushdownPlan(clauses=ranked[:8]),
                      tier_sizes=(2, 4, 8))
    fam1 = evolve_family(fam0, ranked[:4] + ranked[8:12], (2, 4, 8))
    return fam0, fam1


def _build(store, recs, fam0, fam1, *, jit=True):
    """Mixed-epoch / mixed-tier ingest, replan at the halfway point."""
    eng = NumpyEngine()

    def ingest(lo, hi, epoch):
        fam = store.family
        for i, start in enumerate(range(lo, hi, CHUNK)):
            tier = i % fam.n_tiers
            chunk = encode_chunk(recs[start: start + CHUNK])
            bv = eng.eval_fused_prefix(chunk, fam.plan.clauses,
                                       fam.tier_sizes[tier])
            store.ingest_chunk(chunk, bv, epoch=epoch, tier=tier)

    half = (len(recs) // 2) // CHUNK * CHUNK
    ingest(0, half, epoch=0)
    store.advance_epoch(fam1)
    ingest(half, len(recs), epoch=1)
    if jit:
        store.jit_load_raw()   # promotions done up front -> scans idempotent
    return store


def _workload(fam0, fam1, ranked):
    qs = [Query((c,)) for c in fam0.plan.clauses[:3] + fam1.plan.clauses[:3]]
    qs += [Query((fam0.plan.clauses[0], ranked[13]))]   # pushed + residual
    qs += [Query((c,)) for c in ranked[14:17]]          # residual-only
    for v in (3, 55, 97, 250):                          # 250: no match
        qs.append(Query((clause(key_value("linear_score", v)),)))
    qs.append(Query((clause(key_value("phone_country", "ZZ")),)))
    return qs


# ---------------------------------------------------------------------------
# backend sweep, unsharded
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["xla", "numpy", "pallas_interpret"])
def test_device_backends_bit_identical_to_host(ycsb, backend):
    recs, objs, ranked = ycsb
    fam0, fam1 = _families(ranked)
    store = _build(CiaoStore(fam0, segment_capacity=512), recs, fam0, fam1)
    host = DataSkippingScanner(store, log_queries=False)
    dev = DeviceScanner(store, backend=backend, log_queries=False)
    queries = _workload(fam0, fam1, ranked)
    if backend == "pallas_interpret":
        queries = queries[:5]      # the interpreter walks the grid in python
    got = dev.scan_batch(queries)
    for q, r in zip(queries, got):
        oracle = sum(1 for o in objs if q.matches_exact(o))
        h = host.scan(q)
        assert r.count == oracle, q.describe()
        assert _accounting(r) == _accounting(h), q.describe()
    assert len(dev.cache.slots) >= 2      # the plane actually engaged


def test_batch_matches_one_at_a_time(ycsb):
    recs, objs, ranked = ycsb
    fam0, fam1 = _families(ranked)
    queries = _workload(fam0, fam1, ranked)
    # two identical stores: raw NOT promoted up front, so per-scan
    # promotion accounting must interleave identically in both orders
    a = _build(CiaoStore(fam0, segment_capacity=512), recs, fam0, fam1,
               jit=False)
    b = _build(CiaoStore(fam0, segment_capacity=512), recs, fam0, fam1,
               jit=False)
    batched = DeviceScanner(a, log_queries=False).scan_batch(queries)
    sc = DeviceScanner(b, log_queries=False)
    singles = [sc.scan(q) for q in queries]
    for q, rb, rs in zip(queries, batched, singles):
        assert _accounting(rb) == _accounting(rs), q.describe()


def test_multi_query_batch_vs_host_with_raw_promotion(ycsb):
    """Un-promoted store: the batch's raw promotions and jit-segment
    visibility snapshots must reproduce a sequential host run exactly."""
    recs, objs, ranked = ycsb
    fam0, fam1 = _families(ranked)
    queries = _workload(fam0, fam1, ranked)
    a = _build(CiaoStore(fam0, segment_capacity=512), recs, fam0, fam1,
               jit=False)
    b = _build(CiaoStore(fam0, segment_capacity=512), recs, fam0, fam1,
               jit=False)
    dev_res = DeviceScanner(a, log_queries=False).scan_batch(queries)
    host = DataSkippingScanner(b, log_queries=False)
    for q, r in zip(queries, dev_res):
        assert _accounting(r) == _accounting(host.scan(q)), q.describe()


# ---------------------------------------------------------------------------
# sharded sweeps
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_shards", [1, 4, 8])
def test_sharded_device_bit_identical(ycsb, n_shards):
    recs, objs, ranked = ycsb
    fam0, fam1 = _families(ranked)
    if n_shards > 1:
        router = ShardRouter(n_shards=n_shards, key="linear_score",
                             mode="hash")
    else:
        router = None
    s_host = _build(ShardedCiaoStore(fam0, router=router, n_shards=n_shards,
                                     segment_capacity=512),
                    recs, fam0, fam1)
    s_dev = _build(ShardedCiaoStore(fam0, router=router, n_shards=n_shards,
                                    segment_capacity=512),
                   recs, fam0, fam1)
    queries = _workload(fam0, fam1, ranked)
    dev = ShardedDeviceScanner(s_dev, log_queries=False)
    got = dev.scan_batch(queries)
    with ShardedScanner(s_host, log_queries=False) as sc:
        for q, r in zip(queries, got):
            oracle = sum(1 for o in objs if q.matches_exact(o))
            h = sc.scan(q)
            assert r.count == oracle, q.describe()
            assert _accounting(r) == _accounting(h), q.describe()
            assert list(r.groups) == sorted(r.groups)


def test_sharded_device_range_router_prunes_shards(ycsb):
    recs, objs, ranked = ycsb
    fam0, fam1 = _families(ranked)
    router = ShardRouter.from_samples(4, "linear_score", objs[:400])
    s_host = _build(ShardedCiaoStore(fam0, router=router,
                                     segment_capacity=512),
                    recs, fam0, fam1)
    s_dev = _build(ShardedCiaoStore(fam0, router=router,
                                    segment_capacity=512),
                   recs, fam0, fam1)
    queries = _workload(fam0, fam1, ranked)
    dev = ShardedDeviceScanner(s_dev, log_queries=False)
    got = dev.scan_batch(queries)
    pruned = 0
    with ShardedScanner(s_host, log_queries=False) as sc:
        for q, r in zip(queries, got):
            assert _accounting(r) == _accounting(sc.scan(q)), q.describe()
            pruned += r.shards_pruned
    assert pruned > 0   # partition metadata demonstrably engaged


# ---------------------------------------------------------------------------
# edge cases: empty store, all-pruned, dictionary strings, NaN bounds
# ---------------------------------------------------------------------------

def test_empty_store_and_all_pruned_segments(ycsb):
    recs, objs, ranked = ycsb
    fam0, fam1 = _families(ranked)
    empty = CiaoStore(fam0, segment_capacity=512)
    dev = DeviceScanner(empty, log_queries=False)
    r = dev.scan(Query((ranked[0],)))
    assert (r.count, r.rows_scanned, r.rows_skipped) == (0, 0, 0)
    # populated store, query whose zone maps refute EVERY segment: the
    # launch sees no active (query, slot) pair yet accounting still
    # matches the host's all-pruned path
    store = _build(CiaoStore(fam0, segment_capacity=512), recs, fam0, fam1)
    host = DataSkippingScanner(store, log_queries=False)
    dev = DeviceScanner(store, log_queries=False)
    q = Query((clause(key_value("linear_score", 250)),))
    got, want = dev.scan(q), host.scan(q)
    assert got.count == 0
    assert _accounting(got) == _accounting(want)
    assert got.segments_pruned == len(store.blocks) + len(store.jit_blocks)


def _tiny_plan(clauses):
    return PlanFamily(plan=PushdownPlan(clauses=tuple(clauses)),
                      tier_sizes=(len(clauses),))


def test_dictionary_strings_and_nan_zone_bounds():
    """Exotic dictionary strings + NaN numerics: the device dictionary
    codes and zone verdicts must reproduce host semantics exactly (a NaN
    among a key's values poisons numeric pruning; NaN equals nothing)."""
    objs = []
    words = ["par,is", "ab}c", "a b", "", "tokén", "zz"]
    for i in range(256):
        o = {"s": words[i % len(words)], "n": 10.0 * (i % 7)}
        if i % 5 == 0:
            o["n"] = float("nan")
        if i % 3 == 0:
            o["extra"] = "x%d" % (i % 4)
        objs.append(o)
    recs = [json.dumps(o).encode() for o in objs]
    cl = [clause(exact("s", "par,is")), clause(substring("s", "b"))]
    fam = _tiny_plan(cl)
    store = CiaoStore(fam, segment_capacity=128)
    eng = NumpyEngine()
    for start in range(0, len(recs), 64):
        chunk = encode_chunk(recs[start: start + 64])
        bv = eng.eval_fused_prefix(chunk, fam.plan.clauses, len(cl))
        store.ingest_chunk(chunk, bv, epoch=0, tier=0)
    store.jit_load_raw()
    host = DataSkippingScanner(store, log_queries=False)
    dev = DeviceScanner(store, log_queries=False)
    queries = [
        Query((clause(exact("s", "par,is")),)),
        Query((clause(exact("s", "")),)),
        Query((clause(substring("s", "b")),)),
        Query((clause(substring("s", "é")),)),
        Query((clause(presence("extra")),)),
        Query((clause(key_value("extra", "x1")),)),
        Query((clause(key_value("n", 30)),)),          # int vs 30.0 rows
        Query((clause(key_value("n", 30.0)),)),
        Query((clause(key_value("n", float("nan"))),)),  # matches nothing
        Query((clause(key_value("n", 7.5)),)),           # no match
    ]
    got = dev.scan_batch(queries)
    for q, r in zip(queries, got):
        oracle = sum(1 for o in objs if q.matches_exact(o))
        assert r.count == oracle, q.describe()
        assert _accounting(r) == _accounting(host.scan(q)), q.describe()


# ---------------------------------------------------------------------------
# cache residency: steady-state uploads, eviction under pressure
# ---------------------------------------------------------------------------

def test_steady_state_zero_uploads_and_ingest_resync(ycsb):
    recs, objs, ranked = ycsb
    fam0, fam1 = _families(ranked)
    store = _build(CiaoStore(fam0, segment_capacity=512), recs, fam0, fam1)
    twin = _build(CiaoStore(fam0, segment_capacity=512), recs, fam0, fam1)
    host = DataSkippingScanner(twin, log_queries=False)
    dev = DeviceScanner(store, log_queries=False)
    queries = _workload(fam0, fam1, ranked)
    dev.scan_batch(queries)
    warm = dev.cache.uploads
    assert warm > 0
    dev.scan_batch(queries)
    dev.scan_batch(queries[:4])
    assert dev.cache.uploads == warm      # plane resident: zero transfers
    # ingest invalidates the open tail -> resync, still bit-identical to
    # a sequential host run over a twin store with the same ingest
    eng = NumpyEngine()
    chunk = encode_chunk(recs[:CHUNK])
    bv = eng.eval_fused_prefix(chunk, store.family.plan.clauses,
                               store.family.tier_sizes[0])
    store.ingest_chunk(chunk, bv, epoch=1, tier=0)
    twin.ingest_chunk(chunk, bv, epoch=1, tier=0)
    for q, r in zip(queries, dev.scan_batch(queries)):
        assert _accounting(r) == _accounting(host.scan(q)), q.describe()
    assert dev.cache.uploads > warm


def test_cache_eviction_mid_sweep_stays_bit_identical(ycsb):
    recs, objs, ranked = ycsb
    fam0, fam1 = _families(ranked)
    store = _build(CiaoStore(fam0, segment_capacity=512), recs, fam0, fam1)
    host = DataSkippingScanner(store, log_queries=False)
    dev = DeviceScanner(store, byte_budget=200 << 10, log_queries=False)
    queries = _workload(fam0, fam1, ranked)
    for q in queries:                     # one at a time: LRU churns
        assert _accounting(dev.scan(q)) == _accounting(host.scan(q)), \
            q.describe()
    assert dev.cache.evictions > 0        # budget demonstrably starved
    assert len(dev.cache.slots) >= 1      # but the plane never went dark
    # evicted segments fell back to the host path, accounted identically
    got = dev.scan_batch(queries)
    for q, r in zip(queries, got):
        assert _accounting(r) == _accounting(host.scan(q)), q.describe()


# ---------------------------------------------------------------------------
# kernels.residual: pow2 buckets pin the jit cache
# ---------------------------------------------------------------------------

def test_residual_pow2_buckets_pin_trace_count():
    from repro.kernels.residual import (
        _and_reduce, _popcount, bv_and_many_xla, popcount_xla,
    )
    from repro.core.bitvector import bv_and_many, popcount

    rng = np.random.default_rng(3)
    base_and = _and_reduce._cache_size()
    base_pop = _popcount._cache_size()
    buckets = set()
    for p in (1, 2, 3, 5, 8, 9, 13):
        for w in (1, 2, 6, 7, 16, 17):
            words = rng.integers(0, 2**32, (p, w), dtype=np.uint32)
            assert np.array_equal(bv_and_many_xla(words),
                                  bv_and_many(words))
            got = popcount_xla(words[0])
            assert got == popcount(words[0])
            buckets.add((1 << (p - 1).bit_length(),
                         1 << (w - 1).bit_length()))
    grown_and = _and_reduce._cache_size() - base_and
    grown_pop = _popcount._cache_size() - base_pop
    # the AND cache grows with DISTINCT pow2 buckets, not with the 42
    # raw shapes; popcount flattens, so it grows with row buckets only
    assert 0 < grown_and <= len(buckets)
    assert 0 < grown_pop <= len({b[0] * b[1] for b in buckets}) + 1
    # replaying every shape mints no new traces
    for p in (3, 9, 13):
        for w in (6, 17):
            bv_and_many_xla(rng.integers(0, 2**32, (p, w), dtype=np.uint32))
    assert _and_reduce._cache_size() - base_and == grown_and


# ---------------------------------------------------------------------------
# SPMD shard_map path (subprocess: 4 host devices)
# ---------------------------------------------------------------------------

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_spmd_shard_map_bit_identical_subprocess():
    code = textwrap.dedent("""
        import json
        import numpy as np
        import jax
        assert len(jax.devices()) == 4
        from tests.test_device_scan import (
            _accounting, _build, _families, _workload,
        )
        from repro.core.device_scan import ShardedDeviceScanner
        from repro.core.server import CiaoStore, DataSkippingScanner
        from repro.core.shard import ShardedCiaoStore, ShardRouter
        from repro.core.workload import estimate_selectivities
        from repro.data.datasets import generate_records, predicate_pool

        recs = generate_records("ycsb", 2048, seed=7)
        pool = predicate_pool("ycsb")
        sel = estimate_selectivities(pool, recs[:300])
        ranked = sorted(pool, key=lambda c: abs(sel[c] - 0.2))
        fam0, fam1 = _families(ranked)
        router = ShardRouter(n_shards=4, key="linear_score", mode="hash")
        s_dev = _build(ShardedCiaoStore(fam0, router=router,
                                        segment_capacity=512),
                       recs, fam0, fam1)
        s_seq = _build(ShardedCiaoStore(fam0, router=router,
                                        segment_capacity=512),
                       recs, fam0, fam1)
        queries = _workload(fam0, fam1, ranked)[:8]
        spmd = ShardedDeviceScanner(s_dev, log_queries=False, spmd=True)
        seq = ShardedDeviceScanner(s_seq, log_queries=False, spmd=False)
        a = spmd.scan_batch(queries)
        b = seq.scan_batch(queries)
        same = all(_accounting(x) == _accounting(y) for x, y in zip(a, b))
        print(json.dumps({"same": same, "n": len(a)}))
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = SRC + os.pathsep + os.path.join(SRC, "..")
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    payload = json.loads(out.stdout.strip().splitlines()[-1])
    assert payload == {"same": True, "n": 8}
