"""Packed bitvector ops: roundtrip, reductions, popcount, jnp parity."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import bitvector as bv


@given(st.lists(st.booleans(), min_size=0, max_size=300))
@settings(max_examples=100, deadline=None)
def test_pack_unpack_roundtrip(bits):
    arr = np.array(bits, dtype=bool)
    words = bv.pack(arr)
    assert words.dtype == np.uint32
    out = bv.unpack(words, len(bits))
    assert np.array_equal(out, arr)


@given(st.integers(1, 5), st.integers(1, 200), st.integers(0, 2**31))
@settings(max_examples=60, deadline=None)
def test_reductions_match_unpacked(p, r, seed):
    rng = np.random.default_rng(seed)
    bits = rng.random((p, r)) < 0.4
    words = bv.pack(bits)
    assert np.array_equal(bv.unpack(bv.bv_and_many(words), r), bits.all(axis=0))
    assert np.array_equal(bv.unpack(bv.bv_or_many(words), r), bits.any(axis=0))
    assert bv.popcount(bv.pack(bits[0])) == int(bits[0].sum())
    idx = bv.select_indices(bv.pack(bits[0]), r)
    assert np.array_equal(idx, np.nonzero(bits[0])[0])


def test_jnp_parity():
    import jax.numpy as jnp

    rng = np.random.default_rng(0)
    bits = rng.random((3, 130)) < 0.5
    words = bv.pack(bits)
    jwords = bv.jnp_pack(jnp.asarray(bits))
    assert np.array_equal(np.asarray(jwords), words)
    assert np.array_equal(
        np.asarray(bv.jnp_unpack(jnp.asarray(words), 130)), bits
    )
    assert int(bv.jnp_popcount(jnp.asarray(words))) == int(bits.sum())
    assert np.array_equal(
        np.asarray(bv.jnp_and_many(jnp.asarray(words))), bv.bv_and_many(words)
    )


@given(st.integers(0, 2**31), st.integers(0, 400))
@settings(max_examples=60, deadline=None)
def test_popcount_fallback_matches(seed, r):
    """numpy<2 path: the unpackbits fallback == np.bitwise_count path.

    The fallback is what ``bv.popcount`` resolves to when
    ``np.bitwise_count`` is unavailable; it must agree bit-for-bit with
    the primary implementation and with the unpacked ground truth on
    arbitrary shapes (including empty and non-contiguous inputs).
    """
    rng = np.random.default_rng(seed)
    bits = rng.random(r) < 0.3
    words = bv.pack(bits)
    expected = int(bits.sum())
    assert bv.popcount(words) == expected
    assert bv._popcount_unpack(words) == expected
    # non-contiguous view (fallback must not assume contiguity)
    two = np.stack([words, words])
    assert bv._popcount_unpack(two.T) == 2 * expected
