"""Skipping-index registry (DESIGN.md §19): RANGE / IN / n-gram pruning.

The load-bearing invariant, per index and for the registry's conjunctive
composition: NO index may ever refute a segment or shard that contains a
matching row, and the vectorized lowering of the new predicate kinds
must stay bit-identical to ``matches_exact``.  Plus the cache/pushdown
key discipline for the new kinds (type-strict, no cross-kind aliasing)
and the format-5 -> format-6 summary migration.
"""
import json

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import bitvector
from repro.core.batch_scan import ResultCache, ScanBatcher
from repro.core.client import NumpyEngine, encode_chunk
from repro.core.columnar import ColumnarSegment, _term_possible, query_mask
from repro.core.predicates import (
    Query, between, clause, exact, in_list, key_value, rng, substring,
)
from repro.core.server import CiaoStore, PlanFamily, PushdownPlan
from repro.core.shard import _KeySummary
from repro.core.skip_index import (
    REGISTRY, KeyStats, NGramBloom, conservative_bounds, range_fold_value,
)


def _segment(objs, n_covered=0):
    recs = [json.dumps(o, separators=(",", ":")).encode() for o in objs]
    bits = np.zeros((n_covered, len(objs)), bool)
    return ColumnarSegment(records=recs, bitvectors=bitvector.pack(bits),
                           epoch=0, n_covered=n_covered, tier=0)


# ---------------------------------------------------------------------------
# n-gram bloom: no false negatives, serialization, refutation power
# ---------------------------------------------------------------------------

_BLOOM_STRS = ["session 41 tok03 event", "café au lait", "日本語テスト",
               "naïve", "", "ab", "x" * 200]


def test_ngram_bloom_never_false_negative():
    b = NGramBloom()
    for s in _BLOOM_STRS:
        b.add(s)
    for s in _BLOOM_STRS:
        # every substring of an added string must stay possible —
        # including multibyte unicode slices (UTF-8 substring closure)
        for i in range(len(s)):
            for j in range(i + 1, min(i + 8, len(s)) + 1):
                assert b.might_contain(s[i:j]), (s, s[i:j])
    # needles shorter than one full 3-gram are always possible
    assert b.might_contain("") and b.might_contain("zz")
    # a rare absent trigram refutes (deterministic hashes, sparse bloom)
    assert not b.might_contain("zzqxv")
    assert not b.might_contain("語本日")          # reversed: absent grams


def test_ngram_bloom_hex_roundtrip_and_union():
    a, b = NGramBloom(), NGramBloom()
    a.add("alpha"), b.add("bravo")
    restored = NGramBloom.from_hex(a.to_hex())
    assert np.array_equal(restored.bits, a.bits)
    a.union(b)
    assert a.might_contain("alpha") and a.might_contain("bravo")


# ---------------------------------------------------------------------------
# range index probe: bounds intersection, conservative defaults
# ---------------------------------------------------------------------------

def _num_stats(lo, hi, prunable=True):
    return KeyStats(any_notnull=True, rnum_min=lo, rnum_max=hi,
                    rnum_prunable=prunable)


def test_range_probe_interval_logic():
    s = _num_stats(10.0, 20.0)
    assert REGISTRY.term_possible(between("k", 15, 30), s)
    assert REGISTRY.term_possible(between("k", 20, 25), s)   # touches max
    assert not REGISTRY.term_possible(between("k", 21, 25), s)
    assert not REGISTRY.term_possible(rng("k", hi=9.5), s)
    assert REGISTRY.term_possible(rng("k", lo=20.0), s)
    # exclusive query bounds still probe the closed summary interval
    # (conservative: the summary cannot distinguish open endpoints)
    assert REGISTRY.term_possible(rng("k", lo=20.0, lo_incl=False), s)
    # unprunable (format-5 restore) never refutes
    assert REGISTRY.term_possible(between("k", 999, 1000),
                                  _num_stats(10.0, 20.0, prunable=False))
    # empty fold (no range-matchable values seen) refutes every range
    assert not REGISTRY.term_possible(
        between("k", 0, 1e9),
        KeyStats(any_notnull=True, rnum_prunable=True))


def test_conservative_bounds_and_fold_universe():
    lo, hi = conservative_bounds(2**53 + 1)       # not f64-exact: widened
    assert lo < 2**53 + 1 < hi
    assert conservative_bounds(10) == (10.0, 10.0)
    assert range_fold_value(True) is None         # bools never match RANGE
    assert range_fold_value(None) is None
    assert range_fold_value("10") == 10.0         # cross-representation
    assert range_fold_value("007") is None        # not a JSON number
    assert range_fold_value(float("nan")) is None  # NaN matches no range


# ---------------------------------------------------------------------------
# format-5 -> format-6 migration: stripped fields degrade, never refute
# ---------------------------------------------------------------------------

def test_format5_summary_restores_conservative():
    ks = _KeySummary()
    for v in (10, 250, "tok03 event", "30"):
        ks.add(v, 4096)
    obj = ks.to_obj()
    for k in ("rmin", "rmax", "rmin_inf", "rmax_inf", "rnum_prunable",
              "ngram"):
        assert k in obj                            # format-6 writes them
        obj.pop(k)
    old = _KeySummary.from_obj(obj)                # format-5 block
    assert old.rnum_prunable is False and old.ngram is None
    # migrated range bounds never refute (no fold state to trust) —
    # membership pruning via the legacy value set stays, and is sound
    for t in (between("k", 10**6, 10**6 + 1), rng("k", hi=-1e9),
              between("k", 25, 35)):
        assert REGISTRY.term_possible(t, old.stats())
    assert REGISTRY.term_possible(in_list("k", [10]), old.stats())
    # whereas the full format-6 restore keeps its pruning power
    new = _KeySummary.from_obj(ks.to_obj())
    assert new.stats().rnum_prunable is True
    assert not REGISTRY.term_possible(between("k", 10**6, 10**6 + 1),
                                      new.stats())
    assert not REGISTRY.term_possible(substring("k", "zzqxv"), new.stats())
    assert REGISTRY.term_possible(substring("k", "tok03"), new.stats())
    assert REGISTRY.term_possible(between("k", 25, 35), new.stats())


# ---------------------------------------------------------------------------
# cache / pushdown key discipline (type-strict, no cross-kind aliasing)
# ---------------------------------------------------------------------------

def test_new_kinds_type_strict_keys():
    assert in_list("k", [10]) != in_list("k", [10.0])
    assert hash(in_list("k", [10])) != hash(in_list("k", [10.0]))
    assert in_list("k", [1]) != in_list("k", [True])
    assert between("k", 10, 20) != between("k", 10.0, 20)
    assert between("k", 10, 20) != rng("k", 10, 20, lo_incl=False)
    # no cross-kind aliasing between kinds sharing a value shape
    assert in_list("k", [10, 20]) != Query  # sanity: different types
    assert key_value("k", 10) != in_list("k", [10])
    assert clause(between("k", 10, 20)) != clause(in_list("k", [10, 20]))


def test_pushed_in_covers_range_and_in_exactly():
    c_rng = clause(between("k", 10, 20))
    c_in = clause(in_list("k", [1, 2]))
    plan = PushdownPlan(clauses=[c_rng, c_in])
    assert plan.pushed_in(Query((c_rng,))) == [0]
    # ids come back in query clause order
    assert plan.pushed_in(Query((c_in, c_rng))) == [1, 0]
    # float-aliased bounds / elements are DIFFERENT predicates: no cover
    assert plan.pushed_in(Query((clause(between("k", 10.0, 20)),))) == []
    assert plan.pushed_in(Query((clause(in_list("k", [1.0, 2])),))) == []
    assert plan.pushed_in(
        Query((clause(rng("k", 10, 20, hi_incl=False)),))) == []


def _mini_store(objs):
    recs = [json.dumps(o, separators=(",", ":")).encode() for o in objs]
    fam = PlanFamily(plan=PushdownPlan(clauses=[clause(key_value("s", 1)),
                                                clause(key_value("s", 2))]),
                     tier_sizes=(1, 2))
    store = CiaoStore(fam, segment_capacity=8)
    eng = NumpyEngine()
    chunk = encode_chunk(recs)
    bv = eng.eval_fused_prefix(chunk, fam.plan.clauses, 2)
    store.ingest_chunk(chunk, bv, epoch=0, tier=1)
    return store


_ALIAS_OBJS = [{"k": 10, "s": 1}, {"k": 10, "s": 2}, {"k": "10", "s": 1},
               {"k": 10.0, "s": 3}, {"k": 10.5, "s": 1}, {"k": 2, "s": 2},
               {"k": "10.0", "s": 1}, {"k": True, "s": 2}]

_ALIAS_QUERIES = [
    Query((clause(in_list("k", [10])),)),
    Query((clause(in_list("k", [10.0])),)),
    Query((clause(between("k", 10, 10)),)),
    Query((clause(rng("k", 10, 11, hi_incl=False)),)),
    Query((clause(key_value("k", 10)),)),
    Query((clause(in_list("k", [True, 2])),)),
]


@pytest.mark.parametrize("reverse", [False, True])
def test_result_cache_no_aliasing_across_new_kinds(reverse):
    """Cold+warm cached counts == oracle for every query, both scan
    orders: IN/RANGE/KEY_VALUE twins over aliasing value reprs must hit
    only their own cache entries."""
    store = _mini_store(_ALIAS_OBJS)
    queries = list(reversed(_ALIAS_QUERIES)) if reverse else _ALIAS_QUERIES
    cache = ResultCache()
    bat = ScanBatcher(store, cache=cache, log_queries=False)
    cold = bat.scan_batch(queries)
    assert cache.misses >= len(queries) and cache.hits == 0
    warm = bat.scan_batch(queries)
    assert cache.hits >= len(queries)
    for q, rc, rw in zip(queries, cold, warm):
        oracle = sum(1 for o in _ALIAS_OBJS if q.matches_exact(o))
        assert rc.count == oracle == rw.count, q.describe()


# ---------------------------------------------------------------------------
# differential sweep: lowering exactness + pruning soundness on
# adversarial values (hypothesis shim when the real package is absent)
# ---------------------------------------------------------------------------

_ADVERSARIAL_VALUES = [
    0, -0.0, 0.0, 1, -1.5, 0.1, 10, 10.0, 2**53, 2**53 + 1, -(2**53) - 1,
    1e308, True, False, None, "", "10", "10.0", "007", "1e3", "a",
    "café", "日本語テスト", "session tok03 event", "naïve café",
]

_SWEEP_PREDS = [
    between("k", 0, 10), between("k", 2**53, 2**53 + 1),
    between("k", -1, -0.0), rng("k", lo=-0.5, lo_incl=False),
    rng("k", hi=0.0), rng("k", 9.5, 10.5), rng("k", 0, 0),
    rng("k", lo=1e307), rng("k", 999, 1001),
    in_list("k", [10]), in_list("k", [10.0, "10"]), in_list("k", [True]),
    in_list("k", [None, ""]), in_list("k", [2**53 + 1, -0.0]),
    substring("k", "é"), substring("k", "本語"), substring("k", "10"),
    substring("k", "fé c"), substring("k", "tok03"),
    exact("k", "café"), exact("k", ""), key_value("k", 10),
]


@settings(max_examples=120, deadline=None)
@given(st.lists(st.sampled_from(_ADVERSARIAL_VALUES), min_size=1,
                max_size=10),
       st.integers(min_value=0, max_value=len(_SWEEP_PREDS) - 1))
def test_sweep_lowering_and_pruning_vs_exact_oracle(values, pi):
    pred = _SWEEP_PREDS[pi]
    objs = [{"k": v} for v in values]
    seg = _segment(objs)
    q = Query((clause(pred),))
    oracle = [bool(q.matches_exact(o)) for o in objs]
    mask = query_mask(seg, q)
    if mask is None:                   # zone-pruned: must be sound
        assert not any(oracle), (values, pred.describe())
    else:
        assert list(map(bool, mask)) == oracle, (values, pred.describe())
    # segment zone probe soundness (column-level)
    col = seg.key_col("k")
    if col is not None and not _term_possible(col, pred):
        assert not any(oracle), (values, pred.describe())
    # shard summary probe soundness — small cap forces the saturated
    # membership path while range bounds + bloom stay active
    ks = _KeySummary()
    for v in values:
        ks.add(v, 4)
    if not REGISTRY.term_possible(pred, ks.stats()):
        assert not any(oracle), (values, pred.describe())


@settings(max_examples=60, deadline=None)
@given(st.lists(st.sampled_from(_ADVERSARIAL_VALUES), min_size=1,
                max_size=8),
       st.lists(st.sampled_from(_ADVERSARIAL_VALUES), min_size=1,
                max_size=3))
def test_sweep_in_list_equals_or_of_key_values(values, elements):
    """IN is exactly the OR of per-element KEY_VALUE semantics at every
    level that evaluates rows."""
    elements = [e for e in elements if not isinstance(e, (list, dict))]
    if not elements:
        elements = [0]
    pred = in_list("k", elements)
    objs = [{"k": v} for v in values]
    kvs = [key_value("k", e) for e in elements]
    for o in objs:
        assert pred.matches_exact(o) == any(t.matches_exact(o)
                                            for t in kvs), (o, elements)
    seg = _segment(objs)
    mask = query_mask(seg, Query((clause(pred),)))
    want = [any(t.matches_exact(o) for t in kvs) for o in objs]
    if mask is None:
        assert not any(want)
    else:
        assert list(map(bool, mask)) == want
