"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
output shapes + no NaNs (assignment requirement), plus decode consistency."""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs, make_batch
from repro.configs.base import SHAPES, ShapeConfig, shape_applicable
from repro.models.layers import split
from repro.models.model import build_model

SMOKE_SHAPE = ShapeConfig("smoke", "train", 64, 2)


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_config(arch).reduced()
            model = build_model(cfg)
            values, axes = split(model.init(jax.random.PRNGKey(0)))
            cache[arch] = (cfg, model, values)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", list_archs())
def test_forward_loss_finite(arch, built):
    cfg, model, values = built(arch)
    batch = make_batch(cfg, SMOKE_SHAPE)
    loss = jax.jit(model.loss)(values, batch)
    assert np.isfinite(float(loss))
    # random-init CE should be near ln(V)
    assert abs(float(loss) - math.log(cfg.vocab_size)) < 2.0


@pytest.mark.parametrize("arch", list_archs())
def test_train_step_reduces_loss(arch, built):
    from repro.train.train_step import make_train_step
    from repro.train.optimizer import OptConfig

    cfg, model, values = built(arch)
    opt_cfg = OptConfig(learning_rate=5e-3, warmup_steps=1, weight_decay=0.0)
    from repro.train import optimizer as opt_mod

    opt_state = opt_mod.init(values, opt_cfg)
    step = jax.jit(make_train_step(model, opt_cfg, n_micro=1))
    batch = make_batch(cfg, SMOKE_SHAPE)
    params = values
    losses = []
    for _ in range(8):
        params, opt_state, metrics = step(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0], losses  # memorizing one batch


@pytest.mark.parametrize("arch", list_archs())
def test_decode_matches_forward(arch, built):
    cfg, model, values = built(arch)
    if cfg.moe is not None:  # avoid capacity-drop divergence
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
        model = build_model(cfg)
    B, S = 2, 12
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, size=(B, S)).astype(np.int32)
    if cfg.family == "encdec":
        frames = rng.normal(size=(B, 8, cfg.d_model)).astype(np.float32)
        from repro.models import encdec

        full, _ = jax.jit(
            lambda v, f, t: encdec.forward(v, cfg, f, t))(values, frames, toks)
        _, cache = model.prefill(
            values, {"frames": frames, "tokens": toks[:, : S - 1]},
            s_alloc=32, cache_dtype=jnp.float32)
    else:
        from repro.models import transformer

        full, _ = jax.jit(
            lambda v, t: transformer.forward(v, cfg, t))(values, toks)
        _, cache = model.prefill(
            values, {"tokens": toks[:, : S - 1]}, s_alloc=32,
            cache_dtype=jnp.float32)
    dec, _ = model.decode(values, cache, toks[:, S - 1], jnp.int32(S - 1))
    err = np.abs(np.asarray(full[:, S - 1], np.float32) -
                 np.asarray(dec, np.float32)).max()
    assert err < 0.06, err


def test_long_500k_skips_documented():
    for arch in list_archs():
        cfg = get_config(arch)
        ok, why = shape_applicable(cfg, SHAPES["long_500k"])
        if arch in ("recurrentgemma-9b", "rwkv6-3b"):
            assert ok
        else:
            assert not ok and "full-attention" in why


def test_param_counts_match_published():
    expected = {
        "deepseek-7b": 6.9e9,
        "qwen3-1.7b": 1.7e9,
        "qwen3-8b": 8.2e9,
        "deepseek-v3-671b": 671e9,
        "llama4-scout-17b-a16e": 108e9,
        "rwkv6-3b": 3.1e9,
    }
    for arch, n in expected.items():
        model = build_model(get_config(arch))
        assert abs(model.param_count() - n) / n < 0.06, arch
    # active params
    assert abs(build_model(get_config("llama4-scout-17b-a16e")).active_param_count() - 17.2e9) < 1e9
    assert abs(build_model(get_config("deepseek-v3-671b")).active_param_count() - 37.5e9) < 2e9


def test_local_attention_window_respected(built):
    """recurrentgemma local attention must not see beyond the window."""
    cfg, model, values = built("recurrentgemma-9b")
    B, S = 1, 40
    rng = np.random.default_rng(1)
    t1 = rng.integers(0, cfg.vocab_size, size=(B, S)).astype(np.int32)
    t2 = t1.copy()
    t2[0, 0] = (t2[0, 0] + 1) % cfg.vocab_size  # perturb far-past token
    from repro.models import transformer

    f = jax.jit(lambda v, t: transformer.forward(v, cfg, t)[0])
    l1, l2 = f(values, t1), f(values, t2)
    # reduced window is 32; positions beyond window+shift unaffected by
    # attention — but RG-LRU recurrence can carry information, so only check
    # the attention-specific case via pure-attn arch instead:
    cfg_q = get_config("qwen3-1.7b").reduced()
    cfg_q = dataclasses.replace(cfg_q, attention="local", window=8)
    mq = build_model(cfg_q)
    vq, _ = split(mq.init(jax.random.PRNGKey(0)))
    fq = jax.jit(lambda v, t: __import__(
        "repro.models.transformer", fromlist=["forward"]
    ).forward(v, cfg_q, t)[0])
    lq1, lq2 = fq(vq, t1), fq(vq, t2)
    # last position is > window away from position 0
    np.testing.assert_allclose(
        np.asarray(lq1[0, -1], np.float32), np.asarray(lq2[0, -1], np.float32),
        atol=1e-5)
    # but a nearby position IS affected
    assert not np.allclose(
        np.asarray(lq1[0, 1], np.float32), np.asarray(lq2[0, 1], np.float32),
        atol=1e-5)
