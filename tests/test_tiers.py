"""Multi-tenant tiered pushdown: nested tiers, coverage, allocation.

Invariants under test (DESIGN.md §12):
  * the multi-budget solver emits NESTED tiers (Ti ⊆ Ti+1) from one CELF
    run, each within its budget, with the top tier identical to the
    single-budget CELF solve;
  * nesting is preserved across ``evolve_plan``/remap (coverage gid sets
    stay nested per epoch; surviving clauses keep stable gids);
  * the store validates a chunk's coverage claim before touching state,
    and scans stay EXACT under mixed-tier, mixed-epoch ingest (counts
    always equal the full-scan baseline — the differential sweep);
  * every tier of a family shares ONE jit trace per shape bucket, and all
    engines are bit-identical on every tier's clause subset;
  * the fleet allocator maximizes expected savings under a global budget
    and re-tiers when measured per-shard cost drifts.
"""
import numpy as np
import pytest

from repro.core.client import NumpyEngine, PythonEngine, encode_chunk
from repro.core.planner import build_plan_family
from repro.core.predicates import Query, clause, presence
from repro.core.selection import (
    ClientProfile,
    SelectionProblem,
    allocate_tiers,
    celf_greedy,
    objective,
    tiered_celf,
)
from repro.core.server import (
    CiaoStore,
    DataSkippingScanner,
    FullScanBaseline,
    PlanFamily,
    PushdownPlan,
    evolve_family,
    trivial_family,
)
from repro.core.workload import estimate_selectivities, generate_workload
from repro.data.datasets import generate_records, predicate_pool
from repro.data.pipeline import ClientShard, FleetTierAllocator, IngestCoordinator


def _problem(seed: int, n_queries: int = 18) -> SelectionProblem:
    pool = predicate_pool("ycsb")
    rng = np.random.default_rng(seed)
    wl = generate_workload(pool, n_queries=n_queries, distribution="zipf",
                           zipf_a=1.5, rng=rng)
    cands = wl.clause_pool()
    sel = {c: float(rng.uniform(0.01, 0.6)) for c in cands}
    cost = {c: float(rng.uniform(0.2, 2.0)) for c in cands}
    return SelectionProblem(queries=tuple(wl.queries), sel=sel, cost=cost,
                            budget=0.0)


# ---------------------------------------------------------------------------
# the multi-budget solver
# ---------------------------------------------------------------------------

def test_tiered_celf_nested_budgeted_and_top_matches_celf():
    """Property sweep: Ti ⊆ Ti+1, every tier within budget, objectives
    non-decreasing, and the top tier IS the single-budget CELF solution."""
    for seed in range(12):
        prob = _problem(seed)
        rng = np.random.default_rng(100 + seed)
        budgets = np.sort(rng.uniform(0.3, 8.0, size=rng.integers(2, 5)))
        ts = tiered_celf(prob, budgets.tolist())
        assert ts.n_tiers == len(budgets)
        for t in range(ts.n_tiers):
            tier = ts.tier(t)
            assert ts.tier_cost(t) <= ts.budgets[t] + 1e-9
            assert abs(ts.objectives[t] - objective(prob, tier)) < 1e-9
            if t:
                assert set(ts.tier(t - 1)) <= set(tier)          # nesting
                assert ts.objectives[t] >= ts.objectives[t - 1] - 1e-12
        top = celf_greedy(
            SelectionProblem(queries=prob.queries, sel=prob.sel,
                             cost=prob.cost, budget=float(budgets[-1])),
            ratio=True)
        assert list(ts.order) == list(top.selected)


def test_tiered_celf_rejects_bad_budgets():
    prob = _problem(0)
    with pytest.raises(ValueError):
        tiered_celf(prob, [])
    with pytest.raises(ValueError):
        tiered_celf(prob, [2.0, 1.0])
    with pytest.raises(ValueError):
        tiered_celf(prob, [-1.0, 1.0])


# ---------------------------------------------------------------------------
# the fleet allocator
# ---------------------------------------------------------------------------

def test_allocator_prefers_cheap_fast_clients():
    costs = [0.0, 1.0, 3.0]
    values = [0.0, 5.0, 8.0]
    clients = [ClientProfile(cost_scale=0.25, weight=0.5),   # fast
               ClientProfile(cost_scale=4.0, weight=0.5)]    # slow phone
    alloc = allocate_tiers(costs, values, clients, budget=1.0)
    assert alloc.feasible and alloc.spent <= 1.0 + 1e-9
    assert alloc.tiers[0] > alloc.tiers[1]  # fast client climbs first


def test_allocator_budget_extremes():
    costs = [0.0, 1.0, 3.0]
    values = [0.0, 5.0, 8.0]
    clients = [ClientProfile(cost_scale=1.0, weight=1 / 3)] * 3
    rich = allocate_tiers(costs, values, clients, budget=1e9)
    assert rich.tiers == [2, 2, 2]
    poor = allocate_tiers(costs, values, clients, budget=0.0)
    assert poor.tiers == [0, 0, 0] and poor.feasible
    # savings monotone in budget
    mid = allocate_tiers(costs, values, clients, budget=1.5)
    assert poor.expected_savings <= mid.expected_savings \
        <= rich.expected_savings


def test_allocator_validates_shapes():
    with pytest.raises(ValueError):
        allocate_tiers([0.0, 1.0], [0.0], [ClientProfile()], budget=1.0)
    with pytest.raises(ValueError):
        allocate_tiers([2.0, 1.0], [0.0, 1.0], [ClientProfile()], budget=1.0)


# ---------------------------------------------------------------------------
# PlanFamily: nesting across construction and evolution
# ---------------------------------------------------------------------------

def test_family_validates_tier_sizes():
    plan = PushdownPlan(clauses=[clause(presence("a")), clause(presence("b"))])
    with pytest.raises(ValueError):
        PlanFamily(plan=plan, tier_sizes=(2, 1))         # not ascending
    with pytest.raises(ValueError):
        PlanFamily(plan=plan, tier_sizes=(1,))           # top != plan.n
    with pytest.raises(ValueError):
        PlanFamily(plan=plan, tier_sizes=(1, 2), budgets=(1.0,))
    fam = PlanFamily(plan=plan, tier_sizes=(0, 2))
    assert fam.n_tiers == 2 and fam.tier_clauses(0) == []


def test_nesting_preserved_across_evolve_and_remap():
    """Coverage gid sets stay nested per epoch, survivors keep gids, and
    every tier's covered rows remap exactly like the whole plan's."""
    a, b, c, d, e = (clause(presence(x)) for x in "abcde")
    fam0 = PlanFamily(plan=PushdownPlan(clauses=[a, b, c, d]),
                      tier_sizes=(1, 2, 4))
    fam1 = evolve_family(fam0, [c, e, a], (1, 2, 3))
    for fam in (fam0, fam1):
        covs = [fam.coverage_gids(s) for s in fam.tier_sizes]
        for lo, hi in zip(covs, covs[1:]):
            assert lo <= hi                               # nesting invariant
    # survivors keep stable gids; the new clause drew a fresh one
    assert fam1.plan.global_ids[a] == fam0.plan.global_ids[a]
    assert fam1.plan.global_ids[c] == fam0.plan.global_ids[c]
    assert fam1.plan.global_ids[e] == 4
    # remap is consistent tier-by-tier: a tier-covered new row either maps
    # to the old local row of the same gid or is -1 (newly pushed)
    remap = fam1.plan.remap_from(fam0.plan)
    for s in fam1.tier_sizes:
        for new_local in range(s):
            old_local = remap[new_local]
            if old_local >= 0:
                cl = fam1.plan.clauses[new_local]
                assert fam0.plan.ids[cl] == old_local
                assert fam0.plan.global_ids[cl] == fam1.plan.global_ids[cl]


def test_trivial_family_roundtrip():
    plan = PushdownPlan(clauses=[clause(presence("a"))])
    fam = trivial_family(plan)
    assert fam.tier_sizes == (1,) and fam.top_tier == 0
    assert PlanFamily.from_obj(plan, fam.to_obj()).tier_sizes == (1,)


# ---------------------------------------------------------------------------
# coverage-aware store: validation, stats, breakdown
# ---------------------------------------------------------------------------

def _ycsb_family(n_tiers=(1, 2, 4)):
    pool = predicate_pool("ycsb")
    recs = generate_records("ycsb", 600, seed=2)
    sel = estimate_selectivities(pool, recs[:300])
    ranked = sorted(pool, key=lambda c: abs(sel[c] - 0.2))
    plan = PushdownPlan(clauses=ranked[: n_tiers[-1]])
    fam = PlanFamily(plan=plan, tier_sizes=tuple(n_tiers))
    return fam, ranked, recs


def test_ingest_validates_coverage_before_stats():
    fam, ranked, recs = _ycsb_family()
    store = CiaoStore(fam)
    eng = NumpyEngine()
    chunk = encode_chunk(recs[:100])
    # tier 1 covers 2 clauses; shipping 4 rows is a coverage lie
    bv_full = eng.eval_fused(chunk, fam.plan.clauses)
    before = (store.stats.n_records, len(store.blocks), len(store.raw))
    with pytest.raises(ValueError):
        store.ingest_chunk(chunk, bv_full, tier=1)
    with pytest.raises(ValueError):
        store.ingest_chunk(chunk, bv_full, tier=7)   # no such tier
    assert (store.stats.n_records, len(store.blocks), len(store.raw)) == before
    # the honest tier-1 chunk is accepted and tagged
    bv = eng.eval_fused_prefix(chunk, fam.plan.clauses, 2)
    store.ingest_chunk(chunk, bv, tier=1)
    assert store.blocks[-1].n_covered == 2 and store.blocks[-1].tier == 1
    assert store.group_records[(0, 1)] == 100


def test_empty_tier_keeps_everything_raw():
    fam, ranked, recs = _ycsb_family(n_tiers=(0, 4))
    store = CiaoStore(fam)
    eng = NumpyEngine()
    chunk = encode_chunk(recs[:120])
    store.ingest_chunk(chunk, eng.eval_fused_prefix(chunk, fam.plan.clauses, 0),
                       tier=0)
    assert not store.blocks and len(store.raw) == 1
    assert store.raw[0].n_covered == 0
    # zero coverage is never skippable: the first scan JIT-promotes it
    base = FullScanBaseline()
    base.ingest_chunk(chunk)
    q = Query((ranked[0],))
    r = DataSkippingScanner(store).scan(q)
    assert r.count == base.scan(q).count
    assert r.raw_parsed == 120


def test_observed_selectivities_use_per_clause_denominators():
    fam, ranked, recs = _ycsb_family(n_tiers=(1, 2))
    store = CiaoStore(fam)
    eng = NumpyEngine()
    c_lo = encode_chunk(recs[:200])      # tier 0: covers clause 0 only
    c_hi = encode_chunk(recs[200:300])   # tier 1: covers both
    store.ingest_chunk(c_lo, eng.eval_fused_prefix(c_lo, fam.plan.clauses, 1),
                       tier=0)
    store.ingest_chunk(c_hi, eng.eval_fused_prefix(c_hi, fam.plan.clauses, 2),
                       tier=1)
    obs = store.observed_selectivities()
    bits_all = eng.eval(encode_chunk(recs[:300]), fam.plan.clauses)
    bits_hi = eng.eval(c_hi, fam.plan.clauses)
    # clause 0 was evaluated on all 300 records, clause 1 only on the 100
    assert obs[0] == pytest.approx(bits_all[0].mean())
    assert obs[1] == pytest.approx(bits_hi[1].mean())


def test_scan_result_group_breakdown_sums_to_aggregate():
    fam, ranked, recs = _ycsb_family()
    store = CiaoStore(fam)
    eng = NumpyEngine()
    for lo, tier in ((0, 0), (100, 1), (200, 2)):
        chunk = encode_chunk(recs[lo:lo + 100])
        k = fam.tier_sizes[tier]
        store.ingest_chunk(chunk,
                           eng.eval_fused_prefix(chunk, fam.plan.clauses, k),
                           tier=tier)
    r = DataSkippingScanner(store).scan(Query((ranked[1],)))
    assert set(r.groups) <= {(0, 0), (0, 1), (0, 2)}
    assert sum(g.rows_scanned for g in r.groups.values()) == r.rows_scanned
    assert sum(g.rows_skipped for g in r.groups.values()) == r.rows_skipped
    assert sum(g.raw_parsed for g in r.groups.values()) == r.raw_parsed
    assert sum(g.count for g in r.groups.values()) == r.count
    # clause ranked[1] is covered by tiers 1/2 but NOT tier 0: only the
    # tier-0 group can have JIT parses, the covered groups can skip
    assert r.groups[(0, 0)].raw_parsed > 0
    assert r.groups[(0, 1)].rows_skipped + r.groups[(0, 2)].rows_skipped > 0


# ---------------------------------------------------------------------------
# THE soundness gate: differential sweep under mixed tiers, mixed epochs
# ---------------------------------------------------------------------------

def test_differential_mixed_tier_mixed_epoch_scan_counts():
    """Scanner counts equal FullScanBaseline counts for every probe under
    interleaved tiers and a mid-stream epoch bump."""
    pool = predicate_pool("ycsb")
    recs = generate_records("ycsb", 1200, seed=5)
    sel = estimate_selectivities(pool, recs[:300])
    ranked = sorted(pool, key=lambda c: abs(sel[c] - 0.25))
    fam0 = PlanFamily(plan=PushdownPlan(clauses=ranked[:4]),
                      tier_sizes=(1, 2, 4))
    store = CiaoStore(fam0)
    base = FullScanBaseline()
    eng = NumpyEngine()
    rng = np.random.default_rng(11)
    lo = 0
    for i in range(6):                              # epoch 0, mixed tiers
        chunk = encode_chunk(recs[lo:lo + 100]); lo += 100
        tier = int(rng.integers(0, 3))
        k = fam0.tier_sizes[tier]
        store.ingest_chunk(chunk,
                           eng.eval_fused_prefix(chunk, fam0.plan.clauses, k),
                           epoch=0, tier=tier)
        base.ingest_chunk(chunk)
    fam1 = evolve_family(fam0, [ranked[2], ranked[4], ranked[5]], (1, 3))
    store.advance_epoch(fam1)
    for i in range(6):                              # epoch 1, mixed tiers
        chunk = encode_chunk(recs[lo:lo + 100]); lo += 100
        tier = int(rng.integers(0, 2))
        k = fam1.tier_sizes[tier]
        store.ingest_chunk(chunk,
                           eng.eval_fused_prefix(chunk, fam1.plan.clauses, k),
                           epoch=1, tier=tier)
        base.ingest_chunk(chunk)
    scanner = DataSkippingScanner(store)
    probes = [Query((c,)) for c in ranked[:6]]      # covered + uncovered mix
    probes += [Query((ranked[0], ranked[2])), Query((ranked[2], ranked[4])),
               Query((ranked[1], ranked[5])), Query((ranked[7],))]
    for q in probes:
        got, want = scanner.scan(q).count, base.scan(q).count
        assert got == want, (q.describe(), got, want)
    # repeat post-JIT (promoted blocks must stay consistent)
    for q in probes:
        assert scanner.scan(q).count == base.scan(q).count


def test_recipe_batcher_exact_under_mixed_tiers():
    import json

    from repro.data.pipeline import RecipeBatcher
    from repro.data.tokenizer import ByteTokenizer

    fam, ranked, recs = _ycsb_family()
    store = CiaoStore(fam)
    eng = NumpyEngine()
    for lo, tier in ((0, 0), (150, 2), (300, 1)):
        chunk = encode_chunk(recs[lo:lo + 150])
        k = fam.tier_sizes[tier]
        store.ingest_chunk(chunk,
                           eng.eval_fused_prefix(chunk, fam.plan.clauses, k),
                           tier=tier)
    recipe = Query((ranked[1],))
    b = RecipeBatcher(store, ByteTokenizer(vocab_size=1024),
                      seq_len=32, batch_size=2)
    want = sum(1 for r in recs[:450] if recipe.matches_exact(json.loads(r)))
    got = 0
    for rec in b.matching_records(recipe):
        assert recipe.matches_exact(json.loads(rec))
        got += 1
    assert got == want


# ---------------------------------------------------------------------------
# kernel plane: shared traces + engine bit-identity per tier
# ---------------------------------------------------------------------------

def test_all_tiers_share_one_jit_trace(monkeypatch):
    """Every tier of one family must reuse ONE pallas staging (the subset
    views keep the full plan's shapes); re-evaluation adds zero."""
    from repro.kernels import fused as fused_mod
    from repro.kernels.engine import KernelEngine

    counted = []
    real = fused_mod.pl.pallas_call

    def counting(*args, **kwargs):
        counted.append(1)
        return real(*args, **kwargs)

    monkeypatch.setattr(fused_mod.pl, "pallas_call", counting)
    recs = generate_records("ycsb", 200, seed=3)
    pool = tuple(predicate_pool("ycsb")[:5])
    chunk = encode_chunk(recs)
    eng = KernelEngine("pallas_interpret")
    eng.eval_fused_prefix(chunk, pool, 5)
    n_first = len(counted)
    assert n_first <= 1          # one fresh specialization at most
    for k in (3, 1, 4, 2, 5, 3):
        eng.eval_fused_prefix(chunk, pool, k)
    assert len(counted) == n_first, "a tier re-staged the fused kernel"


@pytest.mark.parametrize("backend", ["xla", "pallas_interpret"])
def test_engines_bit_identical_on_every_tier(backend):
    from repro.kernels.engine import KernelEngine

    recs = generate_records("winlog", 300, seed=4)
    pool = tuple(predicate_pool("winlog")[:5])
    chunk = encode_chunk(recs)
    kern = KernelEngine(backend)
    hosts = [PythonEngine(), NumpyEngine()]
    for k in range(len(pool) + 1):
        want = hosts[0].eval_fused_prefix(chunk, pool, k)
        for e in (*hosts[1:], kern):
            got = e.eval_fused_prefix(chunk, pool, k)
            assert got.words.shape[0] == k
            assert np.array_equal(got.words, want.words), (e, k)
            assert np.array_equal(got.or_words, want.or_words), (e, k)
            assert np.array_equal(got.counts, want.counts), (e, k)
        # the view must equal a direct subset compile bit-for-bit
        direct = kern.eval_fused(chunk, pool[:k])
        assert np.array_equal(direct.words, want.words)


# ---------------------------------------------------------------------------
# pipeline: allocation, drift re-tiering, tiered replan broadcast
# ---------------------------------------------------------------------------

def _tiered_setup(budget_frac=0.6, speeds=(4.0, 1.0, 1.0, 0.25, 0.25)):
    pool = predicate_pool("ycsb")
    rng = np.random.default_rng(1)
    wl = generate_workload(pool, n_queries=40, distribution="zipf",
                           zipf_a=1.5, rng=rng)
    sample = generate_records("ycsb", 300, seed=17)
    from repro.core.cost_model import CostModel
    cm = CostModel().scaled(20.0)
    sel = estimate_selectivities(wl.clause_pool(), sample)
    costs = sorted(cm.clause_cost(c, sel[c]) for c in wl.clause_pool())
    med = costs[len(costs) // 2]
    rep = build_plan_family(wl, sample, cost_model=cm,
                            tier_budgets_us=[med, 3 * med, 8 * med])
    budget = budget_frac * rep.family.tier_costs[-1]
    eng = NumpyEngine()
    shards = [ClientShard("ycsb", i, eng, rep.family.plan, chunk_records=64,
                          speed=s) for i, s in enumerate(speeds)]
    return rep, budget, shards, wl, sample, cm


def test_allocator_assigns_fleet_and_coordinator_tags_tiers():
    rep, budget, shards, wl, sample, cm = _tiered_setup()
    store = CiaoStore(rep.family)
    alloc = FleetTierAllocator(rep.family, budget, retier_every_records=10**9)
    # steal=False: every shard must produce its own chunks so each tier's
    # ingest tagging is observable
    coord = IngestCoordinator(shards, store, allocator=alloc, steal=False)
    tiers = [s.tier for s in shards]
    # fast shard never runs a lower tier than a slow shard
    assert tiers[0] == max(tiers)
    assert tiers[3] == tiers[4] == min(tiers)
    assert alloc.allocation.feasible
    coord.run(chunks_per_client=2)
    # chunks arrived tagged with the shard's (epoch, tier)
    seen = set(store.group_records)
    assert seen == {(0, t) for t in set(tiers)}
    assert store.stats.n_records == sum(s.eval_records for s in shards)


def test_retier_on_cost_drift():
    rep, budget, shards, wl, sample, cm = _tiered_setup()
    store = CiaoStore(rep.family)
    alloc = FleetTierAllocator(rep.family, budget, retier_every_records=64)
    coord = IngestCoordinator(shards, store, allocator=alloc)
    t0 = shards[0].tier
    assert t0 == max(s.tier for s in shards)
    # the fast shard's device degrades 100x: its measured cost scale
    # spikes, and the next re-tier check must demote it
    shards[0].cost_scale = 100.0
    coord.run(chunks_per_client=2)
    assert alloc.retier_events >= 1
    assert shards[0].tier < t0


def test_tiered_replan_broadcasts_family_and_retiers():
    from repro.core.replan import Replanner, ReplanPolicy
    from repro.core.workload import DriftPhase, drifting_workloads

    pool = predicate_pool("ycsb")
    wl1, wl2 = drifting_workloads(
        pool, [DriftPhase(60, "zipf", 1.5, seed=1),
               DriftPhase(60, "zipf", 2.0, seed=7)])
    sample = generate_records("ycsb", 300, seed=17)
    from repro.core.cost_model import CostModel
    cm = CostModel().scaled(20.0)
    rep = build_plan_family(wl1, sample, cost_model=cm,
                            tier_budgets_us=[15.0, 40.0, 90.0])
    store = CiaoStore(rep.family)
    scanner = DataSkippingScanner(store)
    policy = ReplanPolicy(check_every_records=256, min_observe_records=128,
                          workload_window=24, min_window_queries=8)
    repl = Replanner(store, sample, tier_budgets_us=[15.0, 40.0, 90.0],
                     base_workload=wl1, cost_model=cm, policy=policy,
                     planned_sel=rep.sel)
    eng = NumpyEngine()
    shards = [ClientShard("ycsb", i, eng, rep.family.plan, chunk_records=128,
                          speed=(4.0 if i == 0 else 1.0)) for i in range(3)]
    alloc = FleetTierAllocator(
        rep.family, budget_us=float(np.mean(rep.family.tier_costs)),
        retier_every_records=10**9)
    q1, q2 = iter(wl1.queries), iter(wl2.queries)

    def on_chunk(done):
        src = q1 if store.epoch == 0 and done <= 4 else q2
        for _ in range(4):
            q = next(src, None)
            if q is not None:
                scanner.scan(q)

    coord = IngestCoordinator(shards, store, replanner=repl,
                              allocator=alloc, on_chunk=on_chunk)
    coord.run(chunks_per_client=6)
    assert store.epoch >= 1 and coord.epoch_bumps >= 1
    # the family broadcast reached every shard and re-ran the allocator
    assert all(s.family is store.family for s in shards)
    assert alloc.family is store.family
    # nested invariant holds for every registered epoch
    for fam in store.families.values():
        for a, b in zip(fam.tier_sizes, fam.tier_sizes[1:]):
            assert a <= b
    # per-tier ingest kept flowing after the bump
    assert any(e == store.epoch for e, _ in store.group_records)


def test_observe_timing_predicts_over_the_evaluated_prefix():
    """A tiered client reports timings for its PREFIX, not the whole
    plan — the recalibration must compare like with like (regression:
    floor-heavy fleets collapsed cost_scale toward the clamp)."""
    from repro.core.replan import Replanner, ReplanPolicy

    fam, ranked, recs = _ycsb_family(n_tiers=(1, 4))
    store = CiaoStore(fam)
    repl = Replanner(store, recs[:100], tier_budgets_us=[5.0, 50.0],
                     policy=ReplanPolicy(max_cost_scale=50.0))
    full = repl._predicted_plan_us()
    prefix = repl._predicted_plan_us(1)
    assert 0 < prefix < full
    # a report timed against the floor tier, exactly 2x its predicted
    # cost, must calibrate scale ~2 (not 2 * prefix/full)
    repl.observe_timing(1000, prefix * 2 * 1000 / 1e6, n_clauses=1)
    assert repl.cost_scale == pytest.approx(2.0, rel=1e-6)
    # an empty tier carries no cost signal and must not move the scale
    repl.observe_timing(1000, 1.0, n_clauses=0)
    assert repl.cost_scale == pytest.approx(2.0, rel=1e-6)


def test_tiered_replan_noop_on_within_tier_order_flip(monkeypatch):
    """Same per-tier clause SETS (order flipped inside a tier) must not
    bump the epoch — a bump would only reset stats and invalidate
    in-flight chunks for a semantically identical family."""
    from repro.core import replan as replan_mod
    from repro.core.planner import FamilyReport
    from repro.core.selection import TieredSelection
    from repro.core.workload import Workload

    fam, ranked, recs = _ycsb_family(n_tiers=(1, 3))
    a, b, c = fam.plan.clauses
    store = CiaoStore(fam)
    eng = NumpyEngine()
    chunk = encode_chunk(recs[:600])
    store.ingest_chunk(chunk, eng.eval_fused(chunk, fam.plan.clauses))

    def fake_family(order, sizes):
        plan = PushdownPlan(clauses=list(order))
        famx = PlanFamily(plan=plan, tier_sizes=sizes)
        tiered = TieredSelection(
            budgets=(5.0, 50.0)[: len(sizes)], order=tuple(order),
            cum_costs=tuple(float(i + 1) for i in range(len(order))),
            tier_sizes=sizes, objectives=tuple(0.0 for _ in sizes))
        return FamilyReport(family=famx, tiered=tiered,
                            sel={cl: 0.1 for cl in order},
                            cost={cl: 1.0 for cl in order})

    base = Workload("base", [Query((x,)) for x in (a, b, c)])
    repl = replan_mod.Replanner(
        store, recs[:100], tier_budgets_us=[5.0, 50.0], base_workload=base)
    # within-tier flip: [a | b, c] -> [a | c, b]: every cut set matches
    monkeypatch.setattr(replan_mod, "build_plan_family",
                        lambda *args, **kw: fake_family((a, c, b), (1, 3)))
    assert repl.step(force=True) is None
    assert store.epoch == 0 and not repl.history
    # a moved cut point IS a semantic change: the epoch must advance
    monkeypatch.setattr(replan_mod, "build_plan_family",
                        lambda *args, **kw: fake_family((a, c, b), (2, 3)))
    out = repl.step(force=True)
    assert out is not None and store.epoch == 1


def test_eval_fused_prefix_rejects_out_of_range_on_all_engines():
    from repro.kernels.engine import KernelEngine

    recs = generate_records("ycsb", 50, seed=1)
    pool = tuple(predicate_pool("ycsb")[:3])
    chunk = encode_chunk(recs)
    for eng in (NumpyEngine(), PythonEngine(), KernelEngine("xla")):
        for bad in (-1, 4):
            with pytest.raises(ValueError):
                eng.eval_fused_prefix(chunk, pool, bad)


def test_drift_signal_ignores_tier_uncovered_clauses():
    """A clause no produced tier covered has observed selectivity 0 by
    construction — it must not fire a 'selectivity' replan nor clobber
    its cached sample estimate (regression: coverage-blind drift)."""
    from repro.core.replan import Replanner, ReplanPolicy

    fam, ranked, recs = _ycsb_family(n_tiers=(1, 2))
    store = CiaoStore(fam)
    eng = NumpyEngine()
    # every chunk at tier 0: clause 1 never gets coverage
    for lo in range(0, 600, 200):
        chunk = encode_chunk(recs[lo:lo + 200])
        store.ingest_chunk(
            chunk, eng.eval_fused_prefix(chunk, fam.plan.clauses, 1), tier=0)
    obs0 = float(store.observed_selectivities()[0])
    assert store.clause_records()[1] == 0
    planned = {fam.plan.clauses[0]: max(obs0, 1e-4),
               fam.plan.clauses[1]: 0.3}
    from repro.core.workload import Workload
    base = Workload("base", [Query((c,)) for c in fam.plan.clauses])
    repl = Replanner(store, recs[:200], tier_budgets_us=[5.0, 50.0],
                     base_workload=base,
                     policy=ReplanPolicy(min_observe_records=128),
                     planned_sel=planned)
    sig = repl.drift_signal()
    # clause 1's fake obs of 0 vs planned 0.3 would be drift 1.0 — it
    # must be excluded; clause 0 matches its planned value exactly
    assert sig.sel_drift < 0.5
    assert sig.triggers(repl.policy) != "selectivity"
    # the re-solve path must not overwrite clause 1's estimate either
    repl._replan("forced", sig)
    assert repl._sel_cache[fam.plan.clauses[1]] == pytest.approx(0.3)


# ---------------------------------------------------------------------------
# persistence
# ---------------------------------------------------------------------------

def test_save_load_roundtrips_families_and_coverage(tmp_path):
    fam, ranked, recs = _ycsb_family()
    store = CiaoStore(fam)
    eng = NumpyEngine()
    for lo, tier in ((0, 0), (150, 2), (300, 1)):
        chunk = encode_chunk(recs[lo:lo + 150])
        k = fam.tier_sizes[tier]
        store.ingest_chunk(chunk,
                           eng.eval_fused_prefix(chunk, fam.plan.clauses, k),
                           tier=tier)
    DataSkippingScanner(store).scan(Query((ranked[7],)))  # force JIT blocks
    path = str(tmp_path / "tiered.npz")
    store.save(path)
    loaded = CiaoStore.load(path)
    assert loaded.family.tier_sizes == fam.tier_sizes
    assert [b.n_covered for b in loaded.blocks] == \
        [b.n_covered for b in store.blocks]
    assert [b.tier for b in loaded.jit_blocks] == \
        [b.tier for b in store.jit_blocks]
    assert loaded.group_records == store.group_records
    assert loaded.group_loaded == store.group_loaded
    assert np.array_equal(loaded.observed_selectivities(),
                          store.observed_selectivities())
    for q in (Query((ranked[0],)), Query((ranked[1], ranked[2]))):
        a = DataSkippingScanner(store, log_queries=False).scan(q)
        b = DataSkippingScanner(loaded, log_queries=False).scan(q)
        assert (a.count, a.rows_scanned, a.rows_skipped) == \
            (b.count, b.rows_scanned, b.rows_skipped)
