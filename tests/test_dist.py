"""Sharding rules + multi-device semantics (subprocess with 8 host devices).

The in-process tests cover rule resolution (pure logic).  The subprocess
tests set XLA_FLAGS for 8 devices and verify: sharded == single-device train
step, resharding checkpoint restore (elastic restart), compressed all-reduce,
and flash-decoding sharded attention vs the local reference.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.dist import collectives as _coll

# The dist plane is restored in stages.  The REDUCE path (tree_reduce +
# compressed_allreduce) is real and its tests run; the shard_map
# flash-decoding attention path is still a stub, so the model-parallel
# subprocess tests that end in it stay skip-marked.
needs_full_dist = pytest.mark.skipif(
    getattr(_coll, "IS_STUB", False),
    reason="repro.dist.collectives attention path not restored",
)
needs_reduce = pytest.mark.skipif(
    getattr(_coll, "REDUCE_IS_STUB", True),
    reason="repro.dist.collectives reduce path not restored",
)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env,
        timeout=900,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    payload = out.stdout.strip().splitlines()[-1]
    return json.loads(payload)


def test_spec_for_leaf_rules():
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.dist.sharding import spec_for_leaf
    from repro.launch.mesh import make_test_mesh

    if len(jax.devices()) < 1:
        pytest.skip("no devices")
    mesh = make_test_mesh((1, 1), ("data", "model"))
    # axes with size 1 are dropped entirely
    assert spec_for_leaf((8, 4), ("embed", "ffn"), mesh) == P()


@needs_full_dist
def test_sharded_train_step_matches_single_device():
    code = textwrap.dedent("""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config, make_batch
        from repro.configs.base import ShapeConfig
        from repro.models.layers import split
        from repro.models.model import build_model
        from repro.dist import sharding as shd
        from repro.launch.mesh import make_test_mesh
        from repro.train import optimizer as opt_mod
        from repro.train.optimizer import OptConfig
        from repro.train.train_step import make_train_step

        cfg = get_config("qwen3-8b").reduced()
        model = build_model(cfg)
        values, axes = split(model.init(jax.random.PRNGKey(0)))
        batch = make_batch(cfg, ShapeConfig("s", "train", 64, 4))
        oc = OptConfig(learning_rate=1e-3, weight_decay=0.0)

        # single device
        s0 = opt_mod.init(values, oc)
        p_ref, _, m_ref = jax.jit(make_train_step(model, oc))(values, s0, batch)

        # 4x2 mesh
        mesh = make_test_mesh((4, 2), ("data", "model"))
        psh = shd.param_shardings(values, axes, mesh)
        v2 = jax.tree.map(jax.device_put, values, psh)
        s2 = opt_mod.init(v2, oc)
        with jax.set_mesh(mesh):
            p_m, _, m_m = jax.jit(make_train_step(model, oc))(v2, s2, batch)
        err = max(jax.tree.leaves(jax.tree.map(
            lambda a, b: float(jnp.abs(a.astype(jnp.float32) -
                                       b.astype(jnp.float32)).max()),
            p_ref, p_m)))
        print(json.dumps({
            "loss_ref": float(m_ref["loss"]), "loss_mesh": float(m_m["loss"]),
            "max_param_err": err,
        }))
    """)
    out = run_sub(code)
    assert abs(out["loss_ref"] - out["loss_mesh"]) < 5e-3, out
    assert out["max_param_err"] < 5e-3, out


@needs_full_dist
def test_resharding_checkpoint_restore():
    """Save on (4,2) mesh, restore on (2,2,2) mesh — elastic restart."""
    code = textwrap.dedent("""
        import json, tempfile
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models.layers import split
        from repro.models.model import build_model
        from repro.dist import sharding as shd
        from repro.launch.mesh import make_test_mesh
        from repro.train import checkpoint as ckpt

        cfg = get_config("qwen3-1.7b").reduced()
        model = build_model(cfg)
        values, axes = split(model.init(jax.random.PRNGKey(0)))
        mesh1 = make_test_mesh((4, 2), ("data", "model"))
        v1 = jax.tree.map(jax.device_put, values,
                          shd.param_shardings(values, axes, mesh1))
        d = tempfile.mkdtemp()
        ckpt.save(d, v1, step=1)

        mesh2 = make_test_mesh((2, 2, 2), ("pod", "data", "model"))
        sh2 = shd.param_shardings(values, axes, mesh2)
        v2, _ = ckpt.restore(d, 1, values, shardings=sh2)
        err = max(jax.tree.leaves(jax.tree.map(
            lambda a, b: float(jnp.abs(a - b).max()), values, v2)))
        ok_shard = all(
            v.sharding == s for v, s in zip(jax.tree.leaves(v2),
                                            jax.tree.leaves(sh2)))
        print(json.dumps({"err": err, "ok_shard": bool(ok_shard)}))
    """)
    out = run_sub(code)
    assert out["err"] == 0.0
    assert out["ok_shard"]


def test_tree_reduce_deterministic_association():
    """The merge tree is fixed by POSITION: ((x0·x1)·(x2·x3)) with an odd
    tail carried up — pinned exactly so the shard scan merge can rely on
    a reproducible association order."""
    from repro.dist.collectives import tree_reduce

    paren = lambda a, b: f"({a}{b})"
    assert tree_reduce(["a"], paren) == "a"
    assert tree_reduce(list("ab"), paren) == "(ab)"
    assert tree_reduce(list("abcd"), paren) == "((ab)(cd))"
    assert tree_reduce(list("abcde"), paren) == "(((ab)(cd))e)"
    assert tree_reduce(list("abcdefg"), paren) == "(((ab)(cd))((ef)g))"
    assert tree_reduce(list(range(100)), lambda a, b: a + b) == 4950
    with pytest.raises(ValueError):
        tree_reduce([], paren)


def test_tree_reduce_float_sums_reproducible():
    """A fixed tree makes float accumulation identical run to run and
    independent of completion order (the caller supplies stable shard
    order; the tree does the rest)."""
    import numpy as np

    from repro.dist.collectives import tree_reduce

    rng = np.random.default_rng(3)
    xs = list(rng.normal(size=33) * 10.0 ** rng.integers(-8, 8, size=33))
    add = lambda a, b: a + b
    assert tree_reduce(xs, add) == tree_reduce(list(xs), add)


@needs_reduce
def test_compressed_allreduce():
    code = textwrap.dedent("""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from repro.dist.collectives import compressed_allreduce
        from repro.launch.mesh import make_test_mesh

        mesh = make_test_mesh((2, 4), ("data", "model"))

        # compressed allreduce over "data": replicated input -> n * value
        x = {"a": jnp.ones((64, 64)) * 0.5, "b": jnp.arange(32, dtype=jnp.float32)}
        out = compressed_allreduce(x, mesh, axis="data")
        err_a = float(jnp.abs(out["a"] - 1.0).max())   # 2 devices * 0.5
        rel_b = float(jnp.abs(out["b"] - 2 * x["b"]).max() /
                      jnp.maximum(jnp.abs(2 * x["b"]).max(), 1))
        # int8 wire payload must bound the error: scale = max|x| / 127
        bound_b = 2 * float(jnp.abs(x["b"]).max()) / 127
        print(json.dumps({"err_a": err_a, "rel_b": rel_b, "bound_b": bound_b}))
    """)
    out = run_sub(code)
    assert out["err_a"] < 0.01
    assert out["rel_b"] < 0.01


@needs_reduce
def test_compressed_allreduce_device_varying_inputs():
    """The quantization scale must be AGREED across the axis: with
    device-local scales, the summed int8 payload dequantizes to garbage
    the moment per-device inputs differ (regression: 2 devices holding
    1.0 and 100.0 summed to 8.0 instead of ~101)."""
    code = textwrap.dedent("""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.dist.collectives import _quantized_psum
        from repro.launch.mesh import make_test_mesh

        mesh = make_test_mesh((8,), ("pod",))
        vals = (1.0, 100.0, 3.0, 7.0, 0.5, 50.0, 2.0, 9.0)
        x = jnp.stack([jnp.full((16,), v, jnp.float32) for v in vals])
        f = shard_map(lambda s: _quantized_psum(s[0], "pod")[None],
                      mesh=mesh, in_specs=(P("pod"),),
                      out_specs=P("pod"), check_rep=False)
        out = np.asarray(f(x))
        want = float(sum(vals))
        # every device must hold the same dequantized sum, within the
        # agreed-scale error bound n_axis * scale / 2
        spread = float(np.abs(out - out[0, 0]).max())
        err = float(np.abs(out - want).max())
        bound = len(vals) * (max(vals) / 127) / 2
        print(json.dumps({"err": err, "bound": bound, "spread": spread}))
    """)
    out = run_sub(code)
    assert out["spread"] == 0.0
    assert out["err"] <= out["bound"] + 1e-6


@needs_full_dist
def test_sharded_decode_attention():
    code = textwrap.dedent("""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from repro.dist.collectives import sharded_decode_attention_gqa
        from repro.launch.mesh import make_test_mesh
        from repro.models import attention as attn

        mesh = make_test_mesh((2, 4), ("data", "model"))
        B, H, Hkv, hd, S = 4, 8, 2, 16, 64
        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.normal(size=(B, H, hd)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(B, S, Hkv, hd)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, S, Hkv, hd)), jnp.float32)
        pos = jnp.arange(S, dtype=jnp.int32)
        ref = attn.combine_partials(
            attn.decode_attention_gqa(q, k, v, pos), None)
        out_sh = sharded_decode_attention_gqa(
            q, k, v, pos, mesh, batch_axes=("data",), seq_axis="model")
        err_attn = float(jnp.abs(ref - out_sh.astype(jnp.float32)).max())
        print(json.dumps({"err_attn": err_attn}))
    """)
    out = run_sub(code)
    assert out["err_attn"] < 1e-4, out


@needs_full_dist
def test_sharded_flash_decode_matches_unsharded():
    """decode with a (2,4) mesh (flash-decoding shard_map engaged) must match
    single-device decode numerically."""
    code = textwrap.dedent("""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models.layers import split
        from repro.models.model import build_model
        from repro.dist import sharding as shd
        from repro.launch.mesh import make_test_mesh

        cfg = get_config("qwen3-8b").reduced()
        model = build_model(cfg)
        values, axes = split(model.init(jax.random.PRNGKey(0)))
        B, S = 2, 15
        rng = np.random.default_rng(0)
        toks = rng.integers(0, cfg.vocab_size, size=(B, S)).astype(np.int32)
        s_alloc = 32  # divisible by model axis 4 -> shard_map path engages

        # reference: no mesh
        _, cache = model.prefill(values, {"tokens": toks[:, :S-1]},
                                 s_alloc=s_alloc, cache_dtype=jnp.float32)
        ref, _ = model.decode(values, cache, toks[:, S-1], jnp.int32(S-1))

        mesh = make_test_mesh((2, 4), ("data", "model"))
        psh = shd.param_shardings(values, axes, mesh,
                                  rules=shd.rules_for("serve_tp"))
        v2 = jax.tree.map(jax.device_put, values, psh)
        with jax.set_mesh(mesh):
            from repro.models import transformer
            assert transformer._use_sharded_decode(s_alloc)
            _, cache2 = jax.jit(
                lambda v, t: model.prefill(v, {"tokens": t}, s_alloc=s_alloc,
                                           cache_dtype=jnp.float32)
            )(v2, toks[:, :S-1])
            out, _ = jax.jit(
                lambda v, c, t, i: model.decode(v, c, t, i)
            )(v2, cache2, toks[:, S-1], jnp.int32(S-1))
        err = float(jnp.abs(jnp.asarray(ref, jnp.float32) -
                            jnp.asarray(out, jnp.float32)).max())
        print(json.dumps({"err": err}))
    """)
    out = run_sub(code)
    assert out["err"] < 5e-2, out


@needs_full_dist
def test_sharded_moe_matches_dense():
    """shard_map EP MoE must match the dense auto-partitioned MoE."""
    code = textwrap.dedent("""
        import json, dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models.layers import split
        from repro.models import moe as moe_mod
        from repro.launch.mesh import make_test_mesh

        cfg = get_config("deepseek-v3-671b").reduced()
        # no drops so both paths agree exactly
        cfg = dataclasses.replace(
            cfg, compute_dtype="float32",
            moe=dataclasses.replace(cfg.moe, capacity_factor=32.0))
        p_leaf = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
        from repro.models.layers import split as split_p
        p, _ = split_p(p_leaf)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model), jnp.float32)
        ref, aux_ref = moe_mod.apply_moe(p, x, cfg)

        mesh = make_test_mesh((2, 4), ("data", "model"))
        with jax.set_mesh(mesh):
            assert moe_mod.moe_sharding_available(cfg)
            out, aux = jax.jit(lambda pp, xx: moe_mod.apply_moe_sharded(pp, xx, cfg))(p, x)
        err = float(jnp.abs(ref - out).max())
        print(json.dumps({"err": err, "aux_ref": float(aux_ref), "aux": float(aux)}))
    """)
    out = run_sub(code)
    assert out["err"] < 2e-4, out
    assert abs(out["aux"] - out["aux_ref"]) < 1e-4, out
