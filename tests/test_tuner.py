"""Online physical-design tuner (DESIGN.md §18).

Covers the incremental background migration (counts bit-identical to the
unsharded oracle BEFORE, DURING — per batch, live store and fenced
snapshot both — and AFTER the move; accounting counters re-derived
exactly; partition pruning recovered on the new routing key), the
workload-driven per-key column layout (lazy keys materialize on first
touch with identical counts; device admission refuses lazy segments),
the tuner's drift triggers (key-shift, skew, no-trigger stability), and
the serve-plane integration (migration writer coexisting with the
writer pool, backpressure/admission telemetry in stats_report).
"""
import json
import threading

import numpy as np
import pytest

from repro.core.client import NumpyEngine, encode_chunk
from repro.core.columnar import ColumnarSegment
from repro.core.predicates import Query, clause, key_value
from repro.core.replan import LayoutDrift, layout_drift_signal
from repro.core.server import CiaoStore, DataSkippingScanner, PushdownPlan
from repro.core.shard import (
    ShardedCiaoStore, ShardedScanner, ShardRouter, reshard,
)
from repro.core.tuner import PhysicalDesignTuner, TunerPolicy
from repro.core.workload import estimate_selectivities
from repro.data.datasets import generate_records, predicate_pool

CHUNK = 256
N_RECORDS = 2048
KEY_A = "linear_score"
KEY_B = "visits"


@pytest.fixture(scope="module")
def ycsb():
    recs = generate_records("ycsb", N_RECORDS, seed=11)
    pool = predicate_pool("ycsb")
    sel = estimate_selectivities(pool, recs[:300])
    ranked = sorted(pool, key=lambda c: abs(sel[c] - 0.2))
    objs = [json.loads(r) for r in recs]
    return recs, objs, ranked


def _plan(ranked):
    return PushdownPlan(clauses=ranked[:6])


def _ingest(store, recs, plan, *, jit=False):
    eng = NumpyEngine()
    for start in range(0, len(recs), CHUNK):
        chunk = encode_chunk(recs[start: start + CHUNK])
        bv = eng.eval_fused(chunk, plan.clauses)
        store.ingest_chunk(chunk, bv)
    if jit:
        store.jit_load_raw()
    return store


def _queries(objs, key, n=12):
    vals = sorted({o[key] for o in objs})
    step = max(1, len(vals) // n)
    qs = [Query((clause(key_value(key, v)),)) for v in vals[::step][:n]]
    qs.append(Query((clause(key_value(key, -1)),)))   # no match
    return qs


def _counts(scanner, qs):
    return [scanner.scan(q).count for q in qs]


# ---------------------------------------------------------------------------
# incremental migration: exactness before / during / after
# ---------------------------------------------------------------------------

def test_migration_counts_exact_every_batch(ycsb):
    recs, objs, ranked = ycsb
    plan = _plan(ranked)
    store = _ingest(
        ShardedCiaoStore(
            plan, router=ShardRouter.from_samples(4, KEY_A, objs[:400]),
            segment_capacity=256),
        recs, plan, jit=True)
    oracle = _ingest(CiaoStore(plan, segment_capacity=256), recs, plan,
                     jit=True)
    qs = _queries(objs, KEY_B) + _queries(objs, KEY_A, n=4)
    want = _counts(DataSkippingScanner(oracle), qs)
    sc = ShardedScanner(store)
    assert _counts(sc, qs) == want                      # before

    mig = store.begin_migration(
        ShardRouter.from_samples(4, KEY_B, objs[:400]), batch_rows=300)
    batches = 0
    while not mig.done:
        mig.step()
        batches += 1
        assert _counts(sc, qs) == want                  # during, live store
        snap = store.snapshot()
        assert _counts(ShardedScanner(snap, log_queries=False),
                       qs) == want                      # during, snapshot
    assert batches > 2                                  # actually incremental
    assert mig.rows_moved > 0
    assert _counts(sc, qs) == want                      # after
    assert store.router.key == KEY_B

    # placement-derived counters are exact: rows partition the shards
    assert sum(sh.stats.n_records for sh in store.shards) == N_RECORDS
    assert sum(sh.stats.n_loaded for sh in store.shards) == \
        oracle.stats.n_loaded
    per_group = {}
    for sh in store.shards:
        for k, n in sh.group_records.items():
            per_group[k] = per_group.get(k, 0) + n
    assert per_group == dict(oracle.group_records)

    # partition pruning recovered on the NEW key: point lookups off the
    # hot key now refute whole shards
    pruned = sum(sc.scan(q).shards_pruned for q in _queries(objs, KEY_B))
    assert pruned > 0
    tele = store.telemetry.snapshot()["tuner"]
    assert tele["migrations"] == 1
    assert tele["rows_moved"] == mig.rows_moved


def test_migration_summaries_rebuilt_and_old_snapshots_sound(ycsb):
    recs, objs, ranked = ycsb
    plan = _plan(ranked)
    store = _ingest(
        ShardedCiaoStore(
            plan, router=ShardRouter.from_samples(4, KEY_A, objs[:400]),
            segment_capacity=256),
        recs, plan)
    pre = store.snapshot()
    pre_summaries = list(pre.summaries)
    qs = _queries(objs, KEY_B)
    want = _counts(ShardedScanner(pre), qs)
    mig = store.begin_migration(
        ShardRouter.from_samples(4, KEY_B, objs[:400]))
    mig.run()
    # live store got FRESH exhaustive summaries; the old snapshot kept
    # its (now over-permissive) ones and still answers exactly
    assert all(a is not b for a, b in zip(store.summaries, pre_summaries))
    assert all(s.exhaustive for s in store.summaries)
    assert _counts(ShardedScanner(pre), qs) == want


def test_migration_concurrent_with_ingest_and_scans(ycsb):
    recs, objs, ranked = ycsb
    plan = _plan(ranked)
    half = N_RECORDS // 2
    store = _ingest(
        ShardedCiaoStore(
            plan, router=ShardRouter.from_samples(4, KEY_A, objs[:400]),
            segment_capacity=256),
        recs[:half], plan)
    oracle = _ingest(CiaoStore(plan, segment_capacity=256), recs, plan)
    qs = _queries(objs, KEY_B, n=6)
    errors: list[BaseException] = []
    mig = store.begin_migration(
        ShardRouter.from_samples(4, KEY_B, objs[:400]), batch_rows=200)

    def feed():
        try:
            eng = NumpyEngine()
            for start in range(half, N_RECORDS, CHUNK):
                chunk = encode_chunk(recs[start: start + CHUNK])
                store.ingest_chunk(chunk, eng.eval_fused(chunk, plan.clauses))
        except BaseException as e:      # pragma: no cover - failure path
            errors.append(e)

    def read():
        try:
            sc = ShardedScanner(store, log_queries=False)
            while not mig.done:
                for q in qs:
                    sc.scan(q)
        except BaseException as e:      # pragma: no cover - failure path
            errors.append(e)

    threads = [threading.Thread(target=feed),
               threading.Thread(target=read)]
    for t in threads:
        t.start()
    while not mig.done:
        mig.step()
    for t in threads:
        t.join()
    assert not errors
    # quiesced: every row landed exactly once, counts match the oracle
    want = _counts(DataSkippingScanner(oracle), qs)
    assert _counts(ShardedScanner(store), qs) == want
    assert sum(sh.stats.n_records for sh in store.shards) == N_RECORDS


def test_migration_requires_same_shard_count(ycsb):
    recs, objs, ranked = ycsb
    plan = _plan(ranked)
    store = ShardedCiaoStore(
        plan, router=ShardRouter.from_samples(4, KEY_A, objs[:400]))
    with pytest.raises(ValueError, match="shard count"):
        store.begin_migration(
            ShardRouter.from_samples(8, KEY_A, objs[:400]))


def test_reshard_still_matches_oracle_after_delegation(ycsb):
    recs, objs, ranked = ycsb
    plan = _plan(ranked)
    store = _ingest(
        ShardedCiaoStore(
            plan, router=ShardRouter.from_samples(4, KEY_A, objs[:400]),
            segment_capacity=256),
        recs, plan, jit=True)
    oracle = _ingest(CiaoStore(plan, segment_capacity=256), recs, plan,
                     jit=True)
    out = reshard(store, ShardRouter.from_samples(8, KEY_B, objs[:400]))
    qs = _queries(objs, KEY_B) + _queries(objs, KEY_A, n=4)
    want = _counts(DataSkippingScanner(oracle), qs)
    assert _counts(ShardedScanner(out), qs) == want
    assert sum(sh.stats.n_records for sh in out.shards) == N_RECORDS
    assert dict(out.group_records) == dict(oracle.group_records)


# ---------------------------------------------------------------------------
# per-key layout policy
# ---------------------------------------------------------------------------

def test_lazy_layout_counts_identical(ycsb):
    recs, objs, ranked = ycsb
    plan = _plan(ranked)
    eager = _ingest(CiaoStore(plan, segment_capacity=256), recs, plan)
    lazy = CiaoStore(plan, segment_capacity=256)
    plan_keys = {t.key for c in plan.clauses for t in c.terms}
    lazy.layout_eager_keys = frozenset(plan_keys | {KEY_A})
    _ingest(lazy, recs, plan)
    qs = (_queries(objs, KEY_A, n=4) + _queries(objs, KEY_B, n=4)
          + _queries(objs, "phone_country", n=3)
          + [Query((clause(key_value("isActive", True)),))])
    want = _counts(DataSkippingScanner(eager), qs)
    assert _counts(DataSkippingScanner(lazy), qs) == want
    # the lazy store really deferred some columns, then materialized
    # exactly the touched ones
    segs = [b for b in lazy.blocks if isinstance(b, ColumnarSegment)]
    assert any(KEY_B in s.key_cols for s in segs)       # touched -> built
    assert all("email" not in s.key_cols for s in segs)  # untouched -> raw


def test_lazy_key_absent_vs_deferred():
    objs = [{"a": i, "b": i * 2} for i in range(8)]
    recs = [json.dumps(o).encode() for o in objs]
    seg = ColumnarSegment(
        records=recs, bitvectors=np.zeros((0, 1), np.uint32),
        epoch=0, n_covered=0, tier=0, objs=objs,
        eager_keys=frozenset({"a"}))
    assert seg.lazy_keys == frozenset({"b"})
    # genuinely absent key refutes without materializing anything
    assert not seg.clause_possible(Query((clause(key_value("zz", 1)),))
                                   .clauses[0])
    assert seg.lazy_keys == frozenset({"b"})
    # deferred key materializes on first touch, with exact results
    c = clause(key_value("b", 6))
    assert seg.clause_possible(c)
    mask, leftover = seg.clause_mask(c)
    assert int(mask.sum()) == 1 and not leftover
    assert "b" in seg.key_cols and not seg.lazy_keys


def test_lazy_materialization_race_is_single_winner():
    objs = [{"a": i, "b": i % 5} for i in range(512)]
    recs = [json.dumps(o).encode() for o in objs]
    seg = ColumnarSegment(
        records=recs, bitvectors=np.zeros((0, 16), np.uint32),
        epoch=0, n_covered=0, tier=0, objs=objs,
        eager_keys=frozenset({"a"}))
    cols, errors = [], []

    def touch():
        try:
            cols.append(seg.key_col("b"))
        except BaseException as e:      # pragma: no cover - failure path
            errors.append(e)

    threads = [threading.Thread(target=touch) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert all(c is cols[0] for c in cols)      # one winner, shared column
    assert cols[0].num_valid.sum() == 512


def test_device_cache_refuses_lazy_segments():
    from repro.core.device_cache import DeviceSegmentCache
    objs = [{"a": i, "b": i} for i in range(16)]
    recs = [json.dumps(o).encode() for o in objs]
    lazy = ColumnarSegment(
        records=recs, bitvectors=np.zeros((1, 1), np.uint32),
        epoch=0, n_covered=1, tier=0, objs=objs,
        eager_keys=frozenset({"a"}))
    full = ColumnarSegment(
        records=recs, bitvectors=np.zeros((1, 1), np.uint32),
        epoch=0, n_covered=1, tier=0, objs=objs)
    assert not DeviceSegmentCache._eligible(lazy)
    assert DeviceSegmentCache._eligible(full)
    # materializing every lazy key restores eligibility
    lazy.key_col("b")
    assert not lazy.lazy_keys
    assert DeviceSegmentCache._eligible(lazy)


# ---------------------------------------------------------------------------
# drift signal + tuner loop
# ---------------------------------------------------------------------------

def test_layout_drift_triggers():
    sig = LayoutDrift(routing_key=KEY_A, hot_key=KEY_B, hot_share=0.8,
                      routing_share=0.1, n_window=32)
    assert sig.triggers() == "key-shift"
    assert LayoutDrift(routing_key=KEY_A, hot_key=KEY_A, hot_share=0.9,
                       routing_share=0.9, n_window=32).triggers() is None
    assert LayoutDrift(routing_key=KEY_A, hot_key=KEY_B, hot_share=0.8,
                       routing_share=0.1, n_window=2).triggers() is None
    assert LayoutDrift(routing_key=KEY_A, hot_key=KEY_A, hot_share=1.0,
                       routing_share=1.0, n_window=32,
                       shard_skew=8.0).triggers() == "skew"


def test_layout_drift_signal_reads_query_log(ycsb):
    recs, objs, ranked = ycsb
    plan = _plan(ranked)
    store = _ingest(
        ShardedCiaoStore(
            plan, router=ShardRouter.from_samples(4, KEY_A, objs[:400])),
        recs, plan)
    for q in _queries(objs, KEY_B):
        store.log_query(q)
    sig = layout_drift_signal(store)
    assert sig.routing_key == KEY_A
    assert sig.hot_key == KEY_B
    assert sig.hot_share > 0.9
    assert sig.triggers() == "key-shift"


def test_tuner_migrates_on_key_shift_and_retunes_layout(ycsb):
    recs, objs, ranked = ycsb
    plan = _plan(ranked)
    store = _ingest(
        ShardedCiaoStore(
            plan, router=ShardRouter.from_samples(4, KEY_A, objs[:400]),
            segment_capacity=256),
        recs, plan)
    oracle = _ingest(CiaoStore(plan, segment_capacity=256), recs, plan)
    tuner = PhysicalDesignTuner(
        store, policy=TunerPolicy(check_every_scans=8, batch_rows=512))
    sc = ShardedScanner(store)
    qs = _queries(objs, KEY_B)
    want = _counts(DataSkippingScanner(oracle), qs)
    assert _counts(sc, qs) == want
    assert tuner.step() is None or tuner.migrating  # throttled or started
    while not tuner.migrating:
        for q in qs:
            sc.scan(q)
        tuner.step()
    tuner.run_migration()
    assert store.router.key == KEY_B
    assert _counts(sc, qs) == want
    kinds = [e.kind for e in tuner.history]
    assert "migration-start" in kinds and "migration-finish" in kinds
    # layout co-selection: the hot key and plan keys went eager
    eager = store.shards[0].layout_eager_keys
    assert KEY_B in eager
    assert {t.key for c in plan.clauses for t in c.terms} <= eager
    tele = store.telemetry.snapshot()["tuner"]
    assert tele["router_swaps"] == 1 and tele["layout_retunes"] == 1


def test_tuner_stable_workload_no_action(ycsb):
    recs, objs, ranked = ycsb
    plan = _plan(ranked)
    store = _ingest(
        ShardedCiaoStore(
            plan, router=ShardRouter.from_samples(4, KEY_A, objs[:400])),
        recs, plan)
    tuner = PhysicalDesignTuner(store, policy=TunerPolicy(check_every_scans=4))
    sc = ShardedScanner(store)
    for q in _queries(objs, KEY_A):          # workload ON the routing key
        sc.scan(q)
    for _ in range(8):
        assert tuner.step() is None
    assert store.router.key == KEY_A and not tuner.history


def test_tuner_skew_triggers_requantile():
    # range boundaries fitted to a key distribution that then drifted:
    # the live rows all land past the last cut point
    plan = PushdownPlan(clauses=(clause(key_value("flag", True)),))
    rng = np.random.default_rng(3)
    warm = [{"k": float(v), "flag": True} for v in rng.uniform(0, 100, 400)]
    store = ShardedCiaoStore(
        plan, router=ShardRouter.from_samples(6, "k", warm),
        segment_capacity=128)
    objs = [{"k": float(v), "flag": bool(i % 2)}
            for i, v in enumerate(rng.uniform(85, 100, 1200))]
    recs = [json.dumps(o).encode() for o in objs]
    eng = NumpyEngine()
    for start in range(0, len(recs), 200):
        chunk = encode_chunk(recs[start: start + 200])
        store.ingest_chunk(chunk, eng.eval_fused(chunk, plan.clauses))
    rows = [sh.stats.n_records for sh in store.shards]
    assert max(rows) / (sum(rows) / len(rows)) > 4.0    # genuinely skewed
    tuner = PhysicalDesignTuner(
        store, policy=TunerPolicy(check_every_scans=0, batch_rows=600))
    ev = tuner.step()
    assert ev is not None and ev.reason == "skew"
    tuner.run_migration()
    rows = [sh.stats.n_records for sh in store.shards]
    assert sum(rows) == 1200
    assert max(rows) / (sum(rows) / len(rows)) < 2.0    # re-balanced
