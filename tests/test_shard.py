"""Sharded store plane (DESIGN.md §14).

Covers the three-level skipping cascade (partition-prune -> zone-prune ->
pushed-bitvector AND -> vectorized residual), the scatter-gather scan
merge (stable shard order, sorted groups), router determinism, format-5
checkpoints + 2/3/4 migrations with offline resharding, and the control
plane (replanner, ingest coordinator, recipe batcher) running unmodified
over a sharded substrate.  The load-bearing property throughout: sharded
counts are BIT-IDENTICAL to the unsharded oracle across shard counts,
epochs, and tiers.
"""
import json
import random

import numpy as np
import pytest

from repro.core import bitvector
from repro.core.client import NumpyEngine, encode_chunk
from repro.core.predicates import Query, clause, key_value
from repro.core.server import (
    CiaoStore, DataSkippingScanner, PlanFamily, PushdownPlan, ScanResult,
    StaleEpochError, evolve_family,
)
from repro.core.shard import (
    ShardedCiaoStore, ShardedScanner, ShardRouter, ShardSummary,
    choose_routing_key, merge_scan_results, reshard,
)
from repro.core.workload import Workload, estimate_selectivities
from repro.data.datasets import generate_records, predicate_pool

CHUNK = 256
N_RECORDS = 2048


@pytest.fixture(scope="module")
def ycsb():
    recs = generate_records("ycsb", N_RECORDS, seed=7)
    pool = predicate_pool("ycsb")
    sel = estimate_selectivities(pool, recs[:300])
    ranked = sorted(pool, key=lambda c: abs(sel[c] - 0.2))
    objs = [json.loads(r) for r in recs]
    return recs, objs, ranked


def _families(ranked):
    fam0 = PlanFamily(plan=PushdownPlan(clauses=ranked[:8]),
                      tier_sizes=(2, 4, 8))
    fam1 = evolve_family(fam0, ranked[:4] + ranked[8:12], (2, 4, 8))
    return fam0, fam1


def _build(store, recs, fam0, fam1, *, jit=False):
    """Mixed-epoch / mixed-tier ingest: replan at the halfway point."""
    eng = NumpyEngine()

    def ingest(lo, hi, epoch):
        fam = store.family
        for i, start in enumerate(range(lo, hi, CHUNK)):
            tier = i % fam.n_tiers
            chunk = encode_chunk(recs[start: start + CHUNK])
            bv = eng.eval_fused_prefix(chunk, fam.plan.clauses,
                                       fam.tier_sizes[tier])
            store.ingest_chunk(chunk, bv, epoch=epoch, tier=tier)

    half = (len(recs) // 2) // CHUNK * CHUNK
    ingest(0, half, epoch=0)
    store.advance_epoch(fam1)
    ingest(half, len(recs), epoch=1)
    if jit:
        store.jit_load_raw()
    return store


def _workload(fam0, fam1, ranked, objs):
    qs = [Query((c,)) for c in fam0.plan.clauses[:3] + fam1.plan.clauses[:3]]
    qs += [Query((fam0.plan.clauses[0], ranked[13]))]   # pushed + residual
    qs += [Query((c,)) for c in ranked[14:17]]          # residual-only
    # routing-key point lookups (partition-prunable under range routing)
    for v in (3, 55, 97):
        qs.append(Query((clause(key_value("linear_score", v)),)))
    qs.append(Query((clause(key_value("linear_score", 250)),)))   # no match
    qs.append(Query((clause(key_value("phone_country", "ZZ")),)))
    return qs


# ---------------------------------------------------------------------------
# router
# ---------------------------------------------------------------------------

def test_router_deterministic_and_balanced(ycsb):
    recs, objs, _ = ycsb
    r = ShardRouter(n_shards=8, key="customer_id", mode="hash")
    sid = r.route(objs, recs)
    assert np.array_equal(sid, r.route(objs, recs))   # deterministic
    counts = np.bincount(sid, minlength=8)
    assert counts.min() > 0.5 * len(recs) / 8         # roughly balanced
    # raw-bytes fallback (no key) is deterministic too
    r2 = ShardRouter(n_shards=4)
    assert np.array_equal(r2.route(objs, recs), r2.route(objs, recs))


def test_router_range_quantiles_balance_skew(ycsb):
    recs, objs, _ = ycsb
    # skew the routing key hard: quantile boundaries must still balance rows
    rng = np.random.default_rng(0)
    skew = [dict(o, linear_score=int(99 * rng.random() ** 3)) for o in objs]
    r = ShardRouter.from_samples(8, "linear_score", skew[:500])
    sid = r.route(skew, recs)
    counts = np.bincount(sid, minlength=8)
    # heavy duplicate mass can only concentrate on ONE shard (an equal
    # value never splits); the rest stay within a constant of the mean
    assert (counts > 0).sum() >= 6
    assert counts.max() < 0.35 * len(recs)
    # range routing sends equal values to one shard
    v_to_sid = {}
    for o, s in zip(skew, sid):
        v_to_sid.setdefault(o["linear_score"], set()).add(int(s))
    assert all(len(s) == 1 for s in v_to_sid.values())


def test_router_serialization_roundtrip(ycsb):
    recs, objs, _ = ycsb
    for r in (ShardRouter(n_shards=4),
              ShardRouter(n_shards=8, key="phone_country", mode="hash"),
              ShardRouter.from_samples(4, "linear_score", objs[:200])):
        r2 = ShardRouter.from_obj(r.to_obj())
        assert np.array_equal(r.route(objs[:64], recs[:64]),
                              r2.route(objs[:64], recs[:64]))


def test_router_validation():
    with pytest.raises(ValueError):
        ShardRouter(n_shards=0)
    with pytest.raises(ValueError):
        ShardRouter(n_shards=2, mode="modulo")
    with pytest.raises(ValueError):
        ShardRouter(n_shards=2, mode="range")          # needs a key
    with pytest.raises(ValueError):
        ShardRouter(n_shards=3, key="x", mode="range", boundaries=(2.0,))
    with pytest.raises(ValueError):
        ShardRouter(n_shards=3, key="x", mode="range", boundaries=(2.0, 1.0))


def test_choose_routing_key(ycsb):
    _, _, ranked = ycsb
    fam0, _ = _families(ranked)
    key = choose_routing_key(fam0)
    assert key in {t.key for c in fam0.plan.clauses for t in c.terms}
    # workload weighting can move the choice: weight one clause heavily
    heavy = fam0.plan.clauses[-1]
    wl = Workload(name="w", queries=[Query((heavy,), freq=100.0)])
    assert choose_routing_key(fam0, wl) == heavy.terms[0].key
    assert choose_routing_key(PushdownPlan(clauses=[])) is None


# ---------------------------------------------------------------------------
# the differential sweep (acceptance)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["hash", "range"])
def test_sharded_counts_bit_identical_to_unsharded(ycsb, mode):
    """Mixed-epoch / mixed-tier workload: counts at 1, 4 and 8 shards are
    bit-identical to the unsharded oracle AND to matches_exact."""
    recs, objs, ranked = ycsb
    fam0, fam1 = _families(ranked)
    plain = _build(CiaoStore(fam0, segment_capacity=512), recs, fam0, fam1)
    stores = []
    for n in (1, 4, 8):
        if mode == "range" and n > 1:
            router = ShardRouter.from_samples(n, "linear_score", objs[:400])
        elif n > 1:
            router = ShardRouter(n_shards=n, key="linear_score", mode="hash")
        else:
            router = None
        stores.append(_build(
            ShardedCiaoStore(fam0, router=router, n_shards=n,
                             segment_capacity=512),
            recs, fam0, fam1))
    oracle_scanner = DataSkippingScanner(plain, log_queries=False)
    scanners = [ShardedScanner(s, log_queries=False) for s in stores]
    any_pruned = 0
    try:
        for q in _workload(fam0, fam1, ranked, objs):
            oracle = sum(1 for o in objs if q.matches_exact(o))
            a = oracle_scanner.scan(q)
            assert a.count == oracle
            for sc in scanners:
                r = sc.scan(q)
                assert r.count == oracle, (q.describe(), r.count, oracle)
                assert r.used_skipping == a.used_skipping, q.describe()
                assert list(r.groups) == sorted(r.groups)
                any_pruned += r.shards_pruned
    finally:
        for sc in scanners:
            sc.close()
    if mode == "range":
        assert any_pruned > 0   # partition metadata demonstrably pruned
    # aggregated feedback state is exact across shard counts
    for s in stores:
        assert s.stats.n_records == plain.stats.n_records
        assert s.stats.n_loaded == plain.stats.n_loaded
        for e in (0, 1):
            assert s.epoch_records(e) == plain.epoch_records(e)
            assert np.array_equal(s.clause_records(e),
                                  plain.clause_records(e))
            assert np.array_equal(s.observed_selectivities(e),
                                  plain.observed_selectivities(e))


def test_partition_prune_skips_shards_soundly(ycsb):
    recs, objs, ranked = ycsb
    fam0, fam1 = _families(ranked)
    router = ShardRouter.from_samples(8, "linear_score", objs[:400])
    store = _build(ShardedCiaoStore(fam0, router=router, segment_capacity=512),
                   recs, fam0, fam1, jit=True)
    with ShardedScanner(store, log_queries=False) as sc:
        q = Query((clause(key_value("linear_score", 55)),))
        r = sc.scan(q)
        assert r.count == sum(1 for o in objs if q.matches_exact(o))
        assert r.shards_pruned >= 6          # only the owning shard scans
        assert r.shards_scanned <= 2
        # a pruned shard's rows land in the merged result as skipped
        assert r.rows_scanned + r.rows_skipped >= store.stats.n_records
        # no-match probe: every shard refuted, zero work dispatched
        r = sc.scan(Query((clause(key_value("linear_score", -5)),)))
        assert (r.count, r.shards_scanned) == (0, 0)
        assert r.shards_pruned == 8


def test_sharded_raw_coverage_and_jit_promotion(ycsb):
    """Residual-only queries JIT-promote raw remainders per shard, exactly
    once, and the promoted rows keep their coverage metadata."""
    recs, objs, ranked = ycsb
    fam0, fam1 = _families(ranked)
    store = _build(
        ShardedCiaoStore(fam0,
                         router=ShardRouter(n_shards=4, key="linear_score"),
                         segment_capacity=512),
        recs, fam0, fam1)
    assert len(store.raw) > 0
    with ShardedScanner(store, log_queries=False) as sc:
        q = Query((ranked[14],))             # residual: no coverage anywhere
        r1 = sc.scan(q)
        assert r1.raw_parsed > 0             # promotion happened
        assert r1.count == sum(1 for o in objs if q.matches_exact(o))
        r2 = sc.scan(q)
        assert r2.raw_parsed == 0            # ...exactly once
        assert r2.count == r1.count
    # promoted segments keep (epoch, n_covered, tier)
    assert {(s.epoch, s.tier) for s in store.jit_blocks} <= \
        {(e, t) for (e, t) in store.group_records}


def test_sharded_ingest_validation_touches_no_state(ycsb):
    recs, _, ranked = ycsb
    fam0, _ = _families(ranked)
    store = ShardedCiaoStore(fam0,
                             router=ShardRouter(n_shards=4,
                                                key="linear_score"))
    eng = NumpyEngine()
    chunk = encode_chunk(recs[:CHUNK])
    bv = eng.eval_fused(chunk, fam0.plan.clauses)
    with pytest.raises(StaleEpochError):
        store.ingest_chunk(chunk, bv, epoch=3)
    with pytest.raises(ValueError):
        store.ingest_chunk(chunk, bv, tier=7)
    with pytest.raises(ValueError):          # coverage claim vs bitvectors
        store.ingest_chunk(chunk, bv, tier=0)
    assert store.stats.n_records == 0
    assert all(s.stats.n_records == 0 for s in store.shards)


# ---------------------------------------------------------------------------
# deterministic scatter-gather merge
# ---------------------------------------------------------------------------

def _tier_result(groups, count):
    r = ScanResult(count=count, rows_scanned=count, rows_skipped=0,
                   raw_parsed=0, time_s=0.001, used_skipping=True)
    for k in groups:
        g = r.group(*k)
        g.count += count
        g.rows_scanned += count
    return r


def test_merge_is_order_independent_and_sorted():
    parts = [
        _tier_result([(1, 2), (0, 0)], 3),
        _tier_result([(0, 1)], 5),
        _tier_result([(1, 0), (0, 0)], 7),
        _tier_result([(2, 1)], 1),
    ]
    merged = merge_scan_results(parts)
    assert list(merged.groups) == sorted(merged.groups)
    assert merged.count == 16
    for _ in range(5):
        shuffled = parts[:]
        random.Random(0xC1A0).shuffle(shuffled)
        m2 = merge_scan_results(shuffled)
        assert list(m2.groups) == list(merged.groups)   # ordering contract
        assert m2.count == merged.count
        assert {k: vars(v) for k, v in m2.groups.items()} == \
            {k: vars(v) for k, v in merged.groups.items()}


def test_unsharded_scanner_groups_sorted(ycsb):
    recs, objs, ranked = ycsb
    fam0, fam1 = _families(ranked)
    store = _build(CiaoStore(fam0, segment_capacity=512), recs, fam0, fam1)
    r = DataSkippingScanner(store, log_queries=False).scan(
        Query((fam0.plan.clauses[0],)))
    assert len(r.groups) > 1
    assert list(r.groups) == sorted(r.groups)


# ---------------------------------------------------------------------------
# NaN poisoning (satellite): partition + zone metadata stay sound
# ---------------------------------------------------------------------------

def _nan_records():
    rows = [{"score": 10.0, "tag": "a"}, {"score": float("nan"), "tag": "b"},
            {"score": 50.0, "tag": "c"}, {"score": float("nan"), "tag": "d"},
            {"score": 90.0, "tag": "e"}] * 40
    return [json.dumps(r).encode() for r in rows], rows


def test_partition_summary_nan_marks_nonprunable():
    recs, rows = _nan_records()
    s = ShardSummary()
    s.update(rows)
    assert s.term_possible(key_value("score", 50))
    assert s.term_possible(key_value("score", float("nan")))
    # the EXACT repr set may still refute an absent value (sound: a NaN
    # row cannot equal 10000 in any representation)...
    assert not s.term_possible(key_value("score", 10_000))
    # set-backed refutation works on the clean column too
    assert not s.term_possible(key_value("tag", "zz"))
    assert not s.term_possible(key_value("missing", 1))
    # ...but once the value set saturates, only min/max could refute —
    # and the NaN marks it non-prunable, so the lookup must stay possible
    sat = ShardSummary(value_cap=3)
    sat.update(rows)
    assert sat.term_possible(key_value("score", 10_000))
    # control: the same saturated summary WITHOUT NaN refutes via min/max
    clean = ShardSummary(value_cap=3)
    clean.update([r for r in rows if r["score"] == r["score"]])
    assert not clean.term_possible(key_value("score", 10_000))
    assert clean.term_possible(key_value("score", 50))


def test_nan_column_never_wrongly_skips_sharded_or_not():
    recs, rows = _nan_records()
    plan = PushdownPlan(clauses=[clause(key_value("tag", "a"))])
    eng = NumpyEngine()
    plain = CiaoStore(plan, segment_capacity=64)
    sharded = ShardedCiaoStore(
        plan, router=ShardRouter(n_shards=4, key="tag"), segment_capacity=64)
    for store in (plain, sharded):
        for lo in range(0, len(recs), 50):
            chunk = encode_chunk(recs[lo: lo + 50])
            store.ingest_chunk(chunk, eng.eval_fused(chunk, plan.clauses))
        store.jit_load_raw()
    queries = [Query((clause(key_value("score", v)),))
               for v in (10, 10.0, 50, 90, 77, 10_000, float("nan"))]
    s_plain = DataSkippingScanner(plain, log_queries=False)
    with ShardedScanner(sharded, log_queries=False) as s_sh:
        for q in queries:
            oracle = sum(1 for o in rows if q.matches_exact(o))
            assert s_plain.scan(q).count == oracle
            assert s_sh.scan(q).count == oracle
    # the zone map carries the poison flag on the affected column only —
    # NaN rows match no pushed clause, so they live in the JIT segments
    nan_segs = [s for s in plain.blocks + plain.jit_blocks
                if not s.key_cols["score"].num_prunable]
    assert nan_segs
    assert all(s.key_cols["tag"].num_prunable
               for s in plain.blocks + plain.jit_blocks)


def test_used_skipping_parity_across_epochs(ycsb):
    recs, objs, ranked = ycsb
    fam0, fam1 = _families(ranked)
    plain = _build(CiaoStore(fam0, segment_capacity=512), recs, fam0, fam1)
    store = _build(
        ShardedCiaoStore(fam0,
                         router=ShardRouter(n_shards=4, key="linear_score"),
                         segment_capacity=512),
        recs, fam0, fam1)
    # this clause was pushed by the epoch-0 plan but dropped by the
    # epoch-1 replan: used_skipping must come from pushdown resolved per
    # SEGMENT epoch (ORed through the merge), not from a current-epoch
    # recomputation (regression: the executor clobbered the merged flag)
    q_old = Query((fam0.plan.clauses[5],))
    assert fam0.plan.clauses[5] not in fam1.plan.clauses
    mono = DataSkippingScanner(plain, log_queries=False).scan(q_old)
    with ShardedScanner(store, log_queries=False) as sc:
        r = sc.scan(q_old)
    assert mono.used_skipping
    assert r.used_skipping == mono.used_skipping
    assert r.count == mono.count


def test_range_router_huge_int_values_fall_back_to_hash():
    r = ShardRouter(n_shards=4, key="v", mode="range",
                    boundaries=(10.0, 20.0, 30.0))
    # > float64 max: float(v) raises OverflowError (regression: killed
    # the whole ingest_chunk); routes by the hash rule instead
    big = 10 ** 400
    rec = json.dumps({"v": big}).encode()
    sid = r.shard_of({"v": big}, rec)
    assert 0 <= sid < 4
    assert sid == r.shard_of({"v": big}, rec)
    # f64-INEXACT ints also hash-route: the partition summaries never
    # admit them to the numeric bounds, so range clustering is moot
    assert 0 <= r.shard_of({"v": 2 ** 63 + 1}, b"x") < 4
    # ordinary numerics still range-route by boundary
    assert r.shard_of({"v": 5.0}, b"x") == 0
    assert r.shard_of({"v": 15}, b"x") == 1
    assert r.shard_of({"v": 35}, b"x") == 3


# ---------------------------------------------------------------------------
# saturated summaries vs cross-representation strings (regression)
# ---------------------------------------------------------------------------

def _crossrepr_records():
    """200 distinct numeric scores (saturates a capped repr set) plus
    string ``"10"`` rows that cross-repr match the numeric probe 10 —
    which lies OUTSIDE the numeric min/max of [100, 299]."""
    rows = [{"score": 100 + i, "tag": "n"} for i in range(200)]
    rows += [{"score": "10", "tag": "s"}] * 8
    random.Random(5).shuffle(rows)
    return [json.dumps(r).encode() for r in rows], rows


def test_saturated_summary_keeps_cross_repr_strings_possible():
    _, rows = _crossrepr_records()
    sat = ShardSummary(value_cap=16)
    sat.update(rows)
    assert sat._keys["score"].reprs is None
    assert sat._keys["score"].strs is not None
    # the numeric bounds only summarize numeric rows: an out-of-range
    # probe must not refute the string "10" that cross-repr matches it
    assert sat.term_possible(key_value("score", 10))
    # ...while a probe matching no string still refutes via the str set —
    # including the float spelling (json_scalar(10.0) = "10.0" != "10")
    assert not sat.term_possible(key_value("score", 10.0))
    assert not sat.term_possible(key_value("score", 11))
    # both sets saturated: nothing may refute an out-of-range probe
    tiny = ShardSummary(value_cap=2)
    tiny.update([{"k": 1}, {"k": 2}, {"k": 3},
                 {"k": "a"}, {"k": "b"}, {"k": "c"}])
    assert tiny.term_possible(key_value("k", 99))


def test_saturated_shard_summary_never_wrongly_prunes():
    recs, rows = _crossrepr_records()
    plan = PushdownPlan(clauses=[clause(key_value("tag", "n"))])
    eng = NumpyEngine()
    plain = CiaoStore(plan, segment_capacity=64)
    sharded = ShardedCiaoStore(
        plan, router=ShardRouter(n_shards=4, key="score"),
        segment_capacity=64, summary_value_cap=16)
    for store in (plain, sharded):
        for lo in range(0, len(recs), 50):
            chunk = encode_chunk(recs[lo: lo + 50])
            store.ingest_chunk(chunk, eng.eval_fused(chunk, plan.clauses))
    # the regression's trigger really is armed: repr summaries saturated
    assert any(ks.reprs is None
               for s in sharded.summaries for ks in s._keys.values())
    queries = [Query((clause(key_value("score", v)),))
               for v in (10, 10.0, "10", 150, 11, 9999)]
    # the regression case: int probe 10 cross-repr matches the "10" rows
    assert sum(1 for o in rows if queries[0].matches_exact(o)) == 8
    s_plain = DataSkippingScanner(plain, log_queries=False)
    with ShardedScanner(sharded, log_queries=False) as s_sh:
        for q in queries:
            oracle = sum(1 for o in rows if q.matches_exact(o))
            assert s_plain.scan(q).count == oracle
            assert s_sh.scan(q).count == oracle
        # pruning still fires on a truly absent value (not over-conservative)
        assert s_sh.scan(Query((clause(key_value("score", 9999)),))
                         ).shards_pruned > 0


def test_pruned_shard_skip_accounting_matches_scanned_population():
    recs, _ = _crossrepr_records()
    plan = PushdownPlan(clauses=[clause(key_value("tag", "n"))])
    eng = NumpyEngine()
    sharded = ShardedCiaoStore(
        plan, router=ShardRouter(n_shards=4, key="score"),
        segment_capacity=64, summary_value_cap=16)
    for lo in range(0, len(recs), 50):
        chunk = encode_chunk(recs[lo: lo + 50])
        sharded.ingest_chunk(chunk, eng.eval_fused(chunk, plan.clauses))
    with ShardedScanner(sharded, log_queries=False) as s_sh:
        r = s_sh.scan(Query((clause(key_value("score", 9999)),)))
    assert r.count == 0
    assert r.shards_pruned == sharded.n_shards
    # pruned shards report skips over the SAME population a scanned shard
    # does — loaded + JIT segment rows; never-promoted raw residents stay
    # out of the accounting on both paths
    seg_rows = sum(seg.n_rows for s in sharded.shards
                   for seg in (*s.blocks, *s.jit_blocks))
    raw_rows = sum(rr.n for s in sharded.shards for rr in s.raw)
    assert raw_rows > 0
    assert r.rows_skipped == seg_rows
    assert r.rows_scanned == 0
    assert sum(g.rows_skipped for g in r.groups.values()) == seg_rows


# ---------------------------------------------------------------------------
# checkpoints: format 5 + 2/3/4 migrations + offline reshard
# ---------------------------------------------------------------------------

def _scan_counts(store, queries):
    if isinstance(store, ShardedCiaoStore):
        with ShardedScanner(store, log_queries=False) as sc:
            return [sc.scan(q).count for q in queries]
    sc = DataSkippingScanner(store, log_queries=False)
    return [sc.scan(q).count for q in queries]


def test_format5_roundtrip(tmp_path, ycsb):
    recs, objs, ranked = ycsb
    fam0, fam1 = _families(ranked)
    router = ShardRouter.from_samples(4, "linear_score", objs[:400])
    store = _build(ShardedCiaoStore(fam0, router=router, segment_capacity=512),
                   recs, fam0, fam1)
    queries = _workload(fam0, fam1, ranked, objs)
    before = _scan_counts(store, queries)
    path = str(tmp_path / "ckpt5")
    store.save(path)
    # the manifest must be STRICT RFC-8259 JSON: empty numeric bounds
    # serialize as null, never as json.dump's Infinity/-Infinity tokens
    # (regression: string-only keys broke every non-Python consumer)
    manifest_text = (tmp_path / "ckpt5" / "manifest.json").read_text()
    json.loads(manifest_text, parse_constant=lambda tok: pytest.fail(
        f"non-standard JSON token {tok!r} in manifest"))
    loaded = ShardedCiaoStore.load(path)
    assert loaded.n_shards == 4
    assert loaded.router.to_obj() == router.to_obj()
    assert _scan_counts(loaded, queries) == before
    # partition summaries survive: pruning still fires after restore
    with ShardedScanner(loaded, log_queries=False) as sc:
        r = sc.scan(Query((clause(key_value("linear_score", 55)),)))
        assert r.shards_pruned >= 2
    # feedback state survives per shard
    assert np.array_equal(loaded.observed_selectivities(),
                          store.observed_selectivities())
    assert loaded.stats.n_records == store.stats.n_records


def _legacy_rewrite(src_path, dst_path, fmt):
    """Rewrite a format-4 npz checkpoint into the legacy format 2 or 3."""
    z = dict(np.load(src_path))
    meta = json.loads(bytes(z["meta"].tobytes()).decode())
    assert meta["format"] == 4
    meta["format"] = fmt
    for prefix in ("seg", "jit"):
        i = 0
        while f"{prefix}_blob_{i}" in z:
            blob, off = z.pop(f"{prefix}_blob_{i}"), z.pop(f"{prefix}_off_{i}")
            b = blob.tobytes()
            rows = [json.loads(b[off[k]: off[k + 1]])
                    for k in range(len(off) - 1)]
            name = "rows" if prefix == "seg" else "jit_rows"
            z[f"{name}_{i}"] = np.frombuffer(
                json.dumps(rows).encode(), np.uint8)
            i += 1
    if fmt == 2:
        # pre-tier checkpoints had no families / coverage columns /
        # per-clause denominators / group attribution / query log
        for key in ("families", "epoch_clause_records", "group_records",
                    "group_loaded", "query_log"):
            meta.pop(key, None)
        for key in ("block_ncov", "block_tiers", "raw_ncov", "raw_tiers",
                    "jit_ncov", "jit_tiers"):
            z.pop(key, None)
    z["meta"] = np.frombuffer(json.dumps(meta).encode(), np.uint8)
    np.savez_compressed(dst_path, **z)


@pytest.mark.parametrize("fmt", [2, 3, 4])
def test_migrate_legacy_checkpoint_to_sharded(tmp_path, ycsb, fmt):
    """Formats 2-4 load into a 1-shard store; counts and coverage claims
    survive, and an offline reshard restores partition pruning."""
    recs, objs, ranked = ycsb
    plan = PushdownPlan(clauses=ranked[:4])
    store = CiaoStore(plan, segment_capacity=512)
    eng = NumpyEngine()
    for lo in range(0, 1024, CHUNK):
        chunk = encode_chunk(recs[lo: lo + CHUNK])
        store.ingest_chunk(chunk, eng.eval_fused(chunk, plan.clauses))
    f4 = str(tmp_path / "f4.npz")
    store.save(f4)
    if fmt == 4:
        legacy = f4
    else:
        legacy = str(tmp_path / f"f{fmt}.npz")
        _legacy_rewrite(f4, legacy, fmt)

    queries = [Query((c,)) for c in ranked[:4]] + \
        [Query((clause(key_value("linear_score", v)),)) for v in (3, 55)]
    want = _scan_counts(store, queries)

    migrated = ShardedCiaoStore.load(legacy)
    assert migrated.n_shards == 1
    assert not migrated.summaries[0].exhaustive   # pruning disabled...
    assert _scan_counts(migrated, queries) == want
    # ...until the offline reshard rebuilds exhaustive summaries
    re8 = reshard(migrated,
                  ShardRouter.from_samples(8, "linear_score", objs[:400]))
    assert all(s.exhaustive for s in re8.summaries)
    assert _scan_counts(re8, queries) == want
    with ShardedScanner(re8, log_queries=False) as sc:
        assert sc.scan(queries[-1]).shards_pruned >= 6
    # aggregate feedback totals survive the migration chain exactly
    assert re8.stats.n_records == store.stats.n_records
    assert re8.epoch_records(0) == store.epoch_records(0)
    assert np.array_equal(re8.clause_records(0), store.clause_records(0))
    # save/load the resharded store as format 5 and re-check counts
    p5 = str(tmp_path / "resharded")
    re8.save(p5)
    assert _scan_counts(ShardedCiaoStore.load(p5), queries) == want
    # coverage claims survive: ingest under the current plan still works
    chunk = encode_chunk(recs[1024: 1024 + CHUNK])
    re8.ingest_chunk(chunk, eng.eval_fused(chunk, re8.plan.clauses))
    assert re8.stats.n_records == store.stats.n_records + CHUNK


def test_reshard_mixed_epoch_tier_store(tmp_path, ycsb):
    """Reshard preserves counts across epochs, tiers, raw remainders and
    JIT segments; format-5 roundtrip of the result is stable."""
    recs, objs, ranked = ycsb
    fam0, fam1 = _families(ranked)
    src = _build(
        ShardedCiaoStore(fam0,
                         router=ShardRouter(n_shards=2, key="phone_country"),
                         segment_capacity=512),
        recs, fam0, fam1)
    queries = _workload(fam0, fam1, ranked, objs)
    # promote SOME remainders, leave the rest raw: this clause is pushed
    # only in epoch 1 at local row 4, so every raw group except epoch 1's
    # top-tier coverage misses it and gets JIT-promoted
    with ShardedScanner(src, log_queries=False) as sc:
        sc.scan(Query((fam1.plan.clauses[4],)))
    assert len(src.raw) > 0 and len(src.jit_blocks) > 0
    want = _scan_counts(src, queries)
    out = reshard(src, ShardRouter.from_samples(4, "linear_score",
                                                objs[:400]))
    assert _scan_counts(out, queries) == want
    assert np.array_equal(out.observed_selectivities(1),
                          src.observed_selectivities(1))
    # loaded rows are preserved exactly once across target shards
    assert sum(s.n_rows for s in out.blocks) == \
        sum(s.n_rows for s in src.blocks)
    assert sum(r.n for s in out.shards for r in s.raw) == \
        sum(r.n for s in src.shards for r in s.raw)
    # per-shard accounting is placement-derived, not dumped on shard 0:
    # the counters the scan executor reads per shard must be exact
    for sh in out.shards:
        resident = sum(s.n_rows for s in list(sh.blocks) + sh.jit_segments)
        resident += sum(r.n for r in sh.raw)
        assert sh.stats.n_records == resident
        assert sum(sh.group_records.values()) == resident
        assert sum(sh._epoch_records.values()) == resident
    assert out.stats.n_records == src.stats.n_records
    # pruned-shard attribution after reshard never exceeds resident rows
    with ShardedScanner(out, log_queries=False) as sc:
        r = sc.scan(Query((clause(key_value("linear_score", -7)),)))
        assert r.count == 0 and r.shards_pruned == out.n_shards
        assert r.rows_skipped == out.stats.n_records


# ---------------------------------------------------------------------------
# control plane over a sharded substrate
# ---------------------------------------------------------------------------

def test_replanner_over_sharded_store(ycsb):
    from repro.core.replan import Replanner, ReplanPolicy

    recs, objs, ranked = ycsb
    plan = PushdownPlan(clauses=ranked[:4])
    store = ShardedCiaoStore(
        plan, router=ShardRouter(n_shards=4, key="linear_score"),
        segment_capacity=512)
    wl = Workload(name="w", queries=[Query((c,)) for c in ranked[4:10]])
    rp = Replanner(store, recs[:300], budget_us=50.0, base_workload=wl,
                   policy=ReplanPolicy(check_every_records=256,
                                       min_observe_records=256,
                                       min_window_queries=4))
    eng = NumpyEngine()
    for lo in range(0, 1024, CHUNK):
        chunk = encode_chunk(recs[lo: lo + CHUNK])
        store.ingest_chunk(chunk, eng.eval_fused(chunk, store.plan.clauses))
    with ShardedScanner(store) as sc:       # log a drifted workload
        for q in wl.queries * 4:
            sc.scan(q)
    new_plan = rp.step(force=True)
    assert new_plan is not None and store.epoch == 1
    assert all(s.plan.epoch == 1 for s in store.shards)
    # ingest continues under the new epoch, fanned out to every shard
    chunk = encode_chunk(recs[1024: 1024 + CHUNK])
    store.ingest_chunk(chunk, eng.eval_fused(chunk, store.plan.clauses),
                       epoch=1)
    assert store.epoch_records(1) == CHUNK


def test_pipeline_coordinator_and_batcher_over_sharded_store(ycsb):
    from repro.data.pipeline import ClientShard, IngestCoordinator, RecipeBatcher
    from repro.data.tokenizer import ByteTokenizer

    _, _, ranked = ycsb
    plan = PushdownPlan(clauses=ranked[:4])

    def run(store):
        clients = [
            ClientShard(dataset="ycsb", shard_id=i, engine=NumpyEngine(),
                        plan=plan, chunk_records=128,
                        speed=[4.0, 1.0, 0.5][i])
            for i in range(3)
        ]
        coord = IngestCoordinator(clients, store)
        coord.run(chunks_per_client=3)
        return store

    plain = run(CiaoStore(plan, segment_capacity=512))
    sharded = run(ShardedCiaoStore(
        plan, router=ShardRouter(n_shards=4, key="linear_score"),
        segment_capacity=512))
    assert sharded.stats.n_records == plain.stats.n_records
    assert sharded.stats.n_loaded == plain.stats.n_loaded
    recipe = Query((plan.clauses[0],))
    tok = ByteTokenizer(vocab_size=512)
    got_plain = sorted(RecipeBatcher(plain, tok, seq_len=64, batch_size=2)
                       .matching_records(recipe))
    got_shard = sorted(RecipeBatcher(sharded, tok, seq_len=64, batch_size=2)
                       .matching_records(recipe))
    assert got_plain == got_shard
