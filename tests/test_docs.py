"""Documentation gates: links resolve, the benchmark catalogue is complete.

Docs are part of the contract here — README/ARCHITECTURE/DESIGN cross-
reference each other and the source tree, and benchmarks/README.md
promises to catalogue every benchmark.  These tests keep that true:

  * every relative markdown link / image in the tracked docs resolves to
    a real file or directory (external URLs and intra-page anchors are
    out of scope);
  * every ``benchmarks/bench_*.py`` module is documented (linked) in
    ``benchmarks/README.md``;
  * every tracked ``BENCH_*.json`` perf artifact is mentioned both in
    ``benchmarks/README.md`` and in the top-level README;
  * the DESIGN.md sections the docs cite actually exist.
"""
from __future__ import annotations

import re
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent

DOCS = [
    "README.md",
    "ARCHITECTURE.md",
    "DESIGN.md",
    "ROADMAP.md",
    "CHANGES.md",
    "benchmarks/README.md",
]

# [text](target) — but not images' alt text brackets or footnote syntax;
# images ![alt](target) are matched too (group catches the target).
_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")


def _links(doc: str) -> list[tuple[str, str]]:
    text = (ROOT / doc).read_text()
    # strip fenced code blocks — link syntax inside them is illustrative
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    return [(doc, m.group(1)) for m in _LINK.finditer(text)]


def _is_external(target: str) -> bool:
    return target.startswith(("http://", "https://", "mailto:"))


@pytest.mark.parametrize("doc", [d for d in DOCS if (ROOT / d).exists()])
def test_relative_links_resolve(doc):
    broken = []
    for src, target in _links(doc):
        if _is_external(target) or target.startswith("#"):
            continue
        if target.startswith("../"):
            continue  # site-relative GitHub URL (e.g. the CI badge)
        path = target.split("#", 1)[0]
        if not path:
            continue
        base = (ROOT / src).parent
        if not (base / path).exists() and not (ROOT / path).exists():
            broken.append(f"{src}: ({target})")
    assert not broken, "broken relative links:\n" + "\n".join(broken)


def test_every_benchmark_documented():
    readme = (ROOT / "benchmarks" / "README.md").read_text()
    missing = []
    for bench in sorted((ROOT / "benchmarks").glob("bench_*.py")):
        if bench.name == "bench_schema.py":
            continue  # the gate itself, documented in prose
        if bench.name not in readme:
            missing.append(bench.name)
    assert not missing, (
        "benchmarks missing from benchmarks/README.md: " + ", ".join(missing))


def test_tracked_artifacts_documented():
    bench_readme = (ROOT / "benchmarks" / "README.md").read_text()
    top_readme = (ROOT / "README.md").read_text()
    tracked = sorted(p.name for p in ROOT.glob("BENCH_*.json"))
    assert tracked, "no tracked BENCH_*.json artifacts at repo root"
    for name in tracked:
        assert name in bench_readme, f"{name} not in benchmarks/README.md"
        assert name in top_readme, f"{name} not in README.md"


def test_cited_design_sections_exist():
    design = (ROOT / "DESIGN.md").read_text()
    present = set(re.findall(r"^##+\s*§(\d+)", design, flags=re.M))
    cited = set()
    for doc in DOCS + ["src/repro/core/batch_scan.py",
                       "src/repro/core/telemetry.py"]:
        p = ROOT / doc
        if p.exists():
            cited |= set(re.findall(r"§(\d+)", p.read_text()))
    # only sections cited as DESIGN.md sections need to exist; paper
    # sections are cited with roman numerals (§V, §VII) and ignored
    missing = sorted(int(s) for s in cited - present)
    assert not missing, f"cited DESIGN.md sections missing: {missing}"
