"""Benchmark artifact hygiene: schema gate + quick-run write discipline.

The tracked ``BENCH_kernels.json`` is the PR-over-PR perf trajectory; these
tests pin (a) its schema, (b) that ``--quick`` runs can never overwrite it,
and (c) — under the ``ci_smoke`` marker — that a reduced-size benchmark run
emits a schema-valid artifact end to end.
"""
import json
import os

import pytest

from benchmarks.bench_schema import (
    SchemaError, validate_device, validate_file, validate_kernels,
    validate_replan, validate_scan, validate_shard, validate_tiers,
)
from benchmarks.run import (
    write_device_artifacts, write_kernels_artifacts, write_scan_artifacts,
    write_shard_artifacts, write_tiers_artifacts,
)

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_GOOD_KERNELS = {
    "engines": [
        {"engine": "python-bytes-find", "backend": "python",
         "device": "host", "interpret": False, "records_per_s": 10000,
         "us_per_record": 100.0, "effective_GBps": 0.1},
        {"engine": "xla-jit", "backend": "xla", "device": "cpu",
         "interpret": False, "records_per_s": 500000,
         "us_per_record": 2.0, "effective_GBps": 5.0},
    ],
    "fused_vs_split": [
        {"backend": "xla", "n_records": 1000, "n_clauses": 12,
         "n_kv_pairs": 5, "split_us_per_record": 10.0,
         "fused_us_per_record": 4.0, "speedup": 2.5,
         "launches_split": 7, "launches_fused": 1},
    ],
}


def test_schema_accepts_tracked_artifact():
    path = os.path.join(REPO_ROOT, "BENCH_kernels.json")
    assert validate_file(path) == "BENCH_kernels.json"


def test_schema_accepts_wellformed_synthetic():
    validate_kernels(_GOOD_KERNELS)


@pytest.mark.parametrize("mutate", [
    lambda o: o.pop("engines"),
    lambda o: o.pop("fused_vs_split"),
    lambda o: o["engines"][0].pop("us_per_record"),
    lambda o: o["engines"][0].pop("backend"),         # provenance required
    lambda o: o["engines"][0].__setitem__("interpret", "no"),
    lambda o: o["engines"][0].__setitem__("us_per_record", "fast"),
    lambda o: o["engines"][0].__setitem__("us_per_record", -1.0),
    lambda o: o["engines"].clear(),
    lambda o: o["fused_vs_split"][0].__setitem__("launches_fused", 2),
    lambda o: o["fused_vs_split"][0].__setitem__("speedup", None),
])
def test_schema_rejects_malformed_kernels(mutate):
    obj = json.loads(json.dumps(_GOOD_KERNELS))
    mutate(obj)
    with pytest.raises(SchemaError):
        validate_kernels(obj)


def test_schema_rejects_unregistered_and_bad_json(tmp_path):
    with pytest.raises(SchemaError):
        validate_file(str(tmp_path / "mystery.json"))
    p = tmp_path / "bench_kernels.json"
    p.write_text("{not json")
    with pytest.raises(SchemaError):
        validate_file(str(p))


def test_replan_schema_requires_epoch_advance():
    obj = {
        "budget_us": 50.0,
        "post_drift_scan_speedup": 1.5,
        "eff_loading_ratio_delta": 0.2,
        "static": {"epoch": 0, "eff_loading_ratio": 1.0,
                   "post_drift_scan_s": 2.0},
        "adaptive": {"epoch": 1, "eff_loading_ratio": 0.7,
                     "post_drift_scan_s": 1.3},
    }
    validate_replan(obj)
    obj["adaptive"]["epoch"] = 0
    with pytest.raises(SchemaError):
        validate_replan(obj)


def _tier_scenario(mode, eff, e2e, ok=True):
    return {
        "mode": mode, "tier_assignment": [2, 1, 0], "budget_spent_us": 10.0,
        "budget_ok": ok, "n_records": 1000, "eff_loading_ratio": eff,
        "loading_s": e2e / 2, "scan_s": e2e / 2, "end_to_end_s": e2e,
        "retier_events": 1,
    }


_GOOD_TIERS = {
    "global_budget_us": 10.0,
    "fleet": [{"speed": 4.0, "count": 1}],
    "tiers": {"sizes": [1, 3, 8], "budgets": [1.0, 3.0, 9.0]},
    "tiered": _tier_scenario("tiered", 0.35, 0.5),
    "uniform_min": _tier_scenario("uniform_min", 1.0, 1.2),
    "uniform_max": _tier_scenario("uniform_max", 0.7, 2.0, ok=False),
    "wins": {"eff_loading_ratio": True, "end_to_end_s": True},
}


def test_tiers_schema_accepts_tracked_artifact():
    path = os.path.join(REPO_ROOT, "BENCH_tiers.json")
    assert validate_file(path) == "BENCH_tiers.json"


def test_tiers_schema_accepts_wellformed_synthetic():
    validate_tiers(_GOOD_TIERS)


@pytest.mark.parametrize("mutate", [
    lambda o: o.pop("tiered"),
    lambda o: o.pop("wins"),
    lambda o: o["tiers"].__setitem__("sizes", [3, 1]),       # not nested
    lambda o: o["tiers"].__setitem__("sizes", [4]),          # single tier
    lambda o: o["tiered"].__setitem__("budget_ok", False),   # over budget
    lambda o: o["uniform_max"].__setitem__("budget_ok", True),
    lambda o: o["tiered"].__setitem__("eff_loading_ratio", 0.9),  # loses
    lambda o: o["tiered"].__setitem__("end_to_end_s", 5.0),       # loses
    lambda o: o["tiered"].pop("retier_events"),
    lambda o: o["tiered"].__setitem__("retier_events", 0),  # no drift demo
    lambda o: o.__setitem__("tiers", []),  # corrupted section shape
])
def test_tiers_schema_rejects_malformed_or_losing(mutate):
    obj = json.loads(json.dumps(_GOOD_TIERS))
    mutate(obj)
    with pytest.raises(SchemaError):
        validate_tiers(obj)


def test_tiers_quick_run_never_touches_tracked_artifact(tmp_path):
    artifacts = tmp_path / "artifacts"
    artifacts.mkdir()
    tracked = tmp_path / "BENCH_tiers.json"
    tracked.write_text("SENTINEL")
    written = write_tiers_artifacts(
        _GOOD_TIERS, quick=True,
        artifacts_dir=str(artifacts), tracked_path=str(tracked))
    assert written == [str(artifacts / "bench_tiers.json")]
    assert tracked.read_text() == "SENTINEL"
    written = write_tiers_artifacts(
        _GOOD_TIERS, quick=False,
        artifacts_dir=str(artifacts), tracked_path=str(tracked))
    assert str(tracked) in written
    assert json.loads(tracked.read_text()) == _GOOD_TIERS


@pytest.mark.ci_smoke
def test_quick_tiers_benchmark_beats_baselines():
    """Reduced-size tiered-fleet benchmark -> schema-valid artifact, i.e.
    the allocator beats uniform-min AND uniform-max within budget (the
    in-suite twin of the CI smoke gate's ``benchmarks.run --quick``)."""
    from benchmarks import bench_tiers

    out = bench_tiers.run(n_records=4864, n_queries=200, n_exec_queries=80)
    validate_tiers(out)


_GOOD_SCAN = {
    "quick": False,
    "n_records": 24576, "n_loaded": 15000, "n_segments": 12,
    "n_queries": 20, "n_epochs": 2, "n_tiers": 3,
    "row_at_a_time": {"scan_s": 1.2, "us_per_query": 60000.0},
    "columnar": {"scan_s": 0.01, "cold_scan_s": 0.05,
                 "us_per_query": 500.0, "segments_pruned": 40},
    "speedup": 120.0, "cold_speedup": 24.0,
    "counts_match": True,
}


def test_scan_schema_accepts_tracked_artifact():
    path = os.path.join(REPO_ROOT, "BENCH_scan.json")
    assert validate_file(path) == "BENCH_scan.json"


def test_scan_schema_accepts_wellformed_synthetic():
    validate_scan(_GOOD_SCAN)
    quick = json.loads(json.dumps(_GOOD_SCAN))
    quick["quick"] = True
    quick["speedup"] = 2.0  # the reduced-size floor is 1.5x, not 5x
    validate_scan(quick)


@pytest.mark.parametrize("mutate", [
    lambda o: o.pop("columnar"),
    lambda o: o.pop("counts_match"),
    lambda o: o.__setitem__("counts_match", False),   # THE claim gate
    lambda o: o.__setitem__("speedup", 4.9),          # below full-size floor
    lambda o: o["columnar"].__setitem__("segments_pruned", 0),
    lambda o: o["columnar"].pop("cold_scan_s"),
    lambda o: o["row_at_a_time"].__setitem__("scan_s", "slow"),
    lambda o: o.__setitem__("n_queries", 3),
    lambda o: o.__setitem__("quick", "no"),
])
def test_scan_schema_rejects_malformed_or_losing(mutate):
    obj = json.loads(json.dumps(_GOOD_SCAN))
    mutate(obj)
    with pytest.raises(SchemaError):
        validate_scan(obj)


def test_scan_quick_run_never_touches_tracked_artifact(tmp_path):
    artifacts = tmp_path / "artifacts"
    artifacts.mkdir()
    tracked = tmp_path / "BENCH_scan.json"
    tracked.write_text("SENTINEL")
    written = write_scan_artifacts(
        _GOOD_SCAN, quick=True,
        artifacts_dir=str(artifacts), tracked_path=str(tracked))
    assert written == [str(artifacts / "bench_scan.json")]
    assert tracked.read_text() == "SENTINEL"
    written = write_scan_artifacts(
        _GOOD_SCAN, quick=False,
        artifacts_dir=str(artifacts), tracked_path=str(tracked))
    assert str(tracked) in written
    assert json.loads(tracked.read_text()) == _GOOD_SCAN


@pytest.mark.ci_smoke
def test_quick_scan_benchmark_beats_row_path():
    """Reduced-size columnar-scan benchmark -> schema-valid artifact:
    counts bit-identical to the exact-match oracle, zone maps pruning,
    columnar beating the row-at-a-time path (the in-suite twin of the CI
    smoke gate's ``benchmarks.run --quick`` scan section)."""
    from benchmarks import bench_scan

    out = bench_scan.run(n_records=4096, chunk_records=512, repeats=1,
                         quick=True)
    validate_scan(out)


def _shard_run(n, scan_s, pruned=0.85):
    return {"n_shards": n, "scan_s": scan_s,
            "us_per_query": scan_s / 100 * 1e6, "counts_match": True,
            "selective_pruned_fraction": pruned if n > 1 else 0.0,
            "max_shard_rows": 70000 // n, "min_shard_rows": 50000 // n}


_GOOD_SHARD = {
    "quick": False,
    "n_records": 65536, "routing_card": 2048,
    "n_queries": 119, "n_selective": 108,
    "routing_key": "visits", "mode": "range",
    "runs": [_shard_run(1, 0.14), _shard_run(4, 0.068),
             _shard_run(8, 0.056)],
    "counts_match": True,
    "speedup_4": 2.06, "speedup_8": 2.47,
    "selective_pruned_fraction": 0.89,
}


def test_shard_schema_accepts_tracked_artifact():
    path = os.path.join(REPO_ROOT, "BENCH_shard.json")
    assert validate_file(path) == "BENCH_shard.json"


def test_shard_schema_accepts_wellformed_synthetic():
    validate_shard(_GOOD_SHARD)
    quick = json.loads(json.dumps(_GOOD_SHARD))
    quick["quick"] = True
    quick["speedup_8"] = 0.9   # reduced-size floor (0.8x) gates collapse only
    validate_shard(quick)
    quick["speedup_8"] = 0.7
    with pytest.raises(SchemaError):
        validate_shard(quick)


@pytest.mark.parametrize("mutate", [
    lambda o: o.pop("runs"),
    lambda o: o.pop("counts_match"),
    lambda o: o.__setitem__("counts_match", False),       # THE claim gate
    lambda o: o["runs"][0].__setitem__("counts_match", False),
    lambda o: o.__setitem__("speedup_8", 1.9),            # below full floor
    lambda o: o.__setitem__("selective_pruned_fraction", 0.29),
    lambda o: o.__setitem__("selective_pruned_fraction", 1.5),
    lambda o: o["runs"].pop(),                            # missing 8-shard row
    lambda o: o["runs"][1].pop("scan_s"),
    lambda o: o["runs"][1].__setitem__("scan_s", 0.0),
    lambda o: o.__setitem__("routing_key", ""),
    lambda o: o.__setitem__("quick", "no"),
])
def test_shard_schema_rejects_malformed_or_losing(mutate):
    obj = json.loads(json.dumps(_GOOD_SHARD))
    mutate(obj)
    with pytest.raises(SchemaError):
        validate_shard(obj)


def test_shard_quick_run_never_touches_tracked_artifact(tmp_path):
    artifacts = tmp_path / "artifacts"
    artifacts.mkdir()
    tracked = tmp_path / "BENCH_shard.json"
    tracked.write_text("SENTINEL")
    written = write_shard_artifacts(
        _GOOD_SHARD, quick=True,
        artifacts_dir=str(artifacts), tracked_path=str(tracked))
    assert written == [str(artifacts / "bench_shard.json")]
    assert tracked.read_text() == "SENTINEL"
    written = write_shard_artifacts(
        _GOOD_SHARD, quick=False,
        artifacts_dir=str(artifacts), tracked_path=str(tracked))
    assert str(tracked) in written
    assert json.loads(tracked.read_text()) == _GOOD_SHARD


@pytest.mark.ci_smoke
def test_quick_shard_benchmark_beats_monolith():
    """Reduced-size shard benchmark -> schema-valid artifact: counts
    bit-identical to the 1-shard oracle, partition metadata pruning the
    selective workload, and the 8-shard scan beating the monolith (the
    in-suite twin of the CI smoke gate's ``benchmarks.run --quick --only
    shard``)."""
    from benchmarks import bench_shard

    out = bench_shard.run(n_records=16384, repeats=2, quick=True)
    validate_shard(out)


def _device_side(scan_s):
    return {"scan_s": scan_s, "us_per_query": scan_s / 20 * 1e6,
            "records_per_s": int(24576 * 20 / scan_s)}


_GOOD_DEVICE = {
    "quick": False,
    "backend": "xla", "device": "cpu", "interpret": False,
    "n_records": 24576, "n_segments": 12, "n_queries": 20, "n_slots": 12,
    "numpy": _device_side(0.19),
    "host_skipping": _device_side(0.002),
    "device_batched": _device_side(0.017),
    "device_sequential": _device_side(0.049),
    "speedup": 11.0, "batch8_speedup": 3.2,
    "counts_match": True,
    "uploads_steady": 0,
    "upload_bytes_warm": 5000000,
    "roofline": {"device_flops": 2.6e7, "device_bytes": 3.8e7,
                 "compute_s": 1.3e-7, "memory_s": 4.6e-5,
                 "step_time_s": 4.6e-5, "measured_s": 0.0134,
                 "dominant": "memory",
                 "shape": {"n_rows": 32768, "n_terms": 32, "n_clauses": 32,
                           "n_queries": 32, "n_slots": 15}},
    "roofline_frac": 0.0035,
}


def test_device_schema_accepts_tracked_artifact():
    path = os.path.join(REPO_ROOT, "BENCH_device.json")
    assert validate_file(path) == "BENCH_device.json"


def test_device_schema_accepts_wellformed_synthetic():
    validate_device(_GOOD_DEVICE)
    quick = json.loads(json.dumps(_GOOD_DEVICE))
    quick["quick"] = True
    quick["speedup"] = 0.6       # reduced-size floor gates collapse only
    quick["batch8_speedup"] = 0.9
    validate_device(quick)


@pytest.mark.parametrize("mutate", [
    lambda o: o.pop("numpy"),
    lambda o: o.pop("roofline"),
    lambda o: o.pop("counts_match"),
    lambda o: o.__setitem__("counts_match", False),      # THE claim gate
    lambda o: o.__setitem__("uploads_steady", 2),        # plane not resident
    lambda o: o.__setitem__("speedup", 1.9),             # below full floor
    lambda o: o.__setitem__("batch8_speedup", 2.9),      # fusion claim
    lambda o: o.__setitem__("roofline_frac", 0.0),
    lambda o: o.__setitem__("roofline_frac", 1.2),       # beats the hardware
    lambda o: o["roofline"].pop("measured_s"),
    lambda o: o["device_batched"].__setitem__("scan_s", 0.0),
    lambda o: o["numpy"].pop("records_per_s"),
    lambda o: o.pop("backend"),
    lambda o: o.__setitem__("interpret", "no"),
    lambda o: o.__setitem__("quick", "no"),
])
def test_device_schema_rejects_malformed_or_losing(mutate):
    obj = json.loads(json.dumps(_GOOD_DEVICE))
    mutate(obj)
    with pytest.raises(SchemaError):
        validate_device(obj)


def test_device_quick_run_never_touches_tracked_artifact(tmp_path):
    artifacts = tmp_path / "artifacts"
    artifacts.mkdir()
    tracked = tmp_path / "BENCH_device.json"
    tracked.write_text("SENTINEL")
    written = write_device_artifacts(
        _GOOD_DEVICE, quick=True,
        artifacts_dir=str(artifacts), tracked_path=str(tracked))
    assert written == [str(artifacts / "bench_device.json")]
    assert tracked.read_text() == "SENTINEL"
    written = write_device_artifacts(
        _GOOD_DEVICE, quick=False,
        artifacts_dir=str(artifacts), tracked_path=str(tracked))
    assert str(tracked) in written
    assert json.loads(tracked.read_text()) == _GOOD_DEVICE


@pytest.mark.ci_smoke
def test_quick_device_benchmark_beats_numpy():
    """Reduced-size device-scan benchmark -> schema-valid artifact:
    counts bit-identical to the host skipping oracle, zero steady-state
    uploads, the fused launch beating the numpy plane-scan reference
    (the in-suite twin of the CI smoke gate's ``benchmarks.run --quick
    --only device``)."""
    from benchmarks import bench_device

    out = bench_device.run(n_records=6144, repeats=2, quick=True)
    validate_device(out)


def test_quick_run_never_touches_tracked_artifact(tmp_path):
    """--quick writes under artifacts/ only; full runs update both."""
    artifacts = tmp_path / "artifacts"
    artifacts.mkdir()
    tracked = tmp_path / "BENCH_kernels.json"
    tracked.write_text("SENTINEL")

    written = write_kernels_artifacts(
        _GOOD_KERNELS, quick=True,
        artifacts_dir=str(artifacts), tracked_path=str(tracked))
    assert written == [str(artifacts / "bench_kernels.json")]
    assert tracked.read_text() == "SENTINEL"  # quick run must not clobber

    written = write_kernels_artifacts(
        _GOOD_KERNELS, quick=False,
        artifacts_dir=str(artifacts), tracked_path=str(tracked))
    assert str(tracked) in written
    assert json.loads(tracked.read_text()) == _GOOD_KERNELS


def test_malformed_output_never_reaches_disk(tmp_path):
    artifacts = tmp_path / "artifacts"
    artifacts.mkdir()
    bad = json.loads(json.dumps(_GOOD_KERNELS))
    bad["engines"] = []
    with pytest.raises(SchemaError):
        write_kernels_artifacts(bad, quick=False,
                                artifacts_dir=str(artifacts),
                                tracked_path=str(tmp_path / "B.json"))
    assert not (tmp_path / "B.json").exists()
    assert not (artifacts / "bench_kernels.json").exists()


@pytest.mark.ci_smoke
def test_quick_benchmark_emits_schema_valid_artifact():
    """Reduced-size end-to-end kernels benchmark -> valid artifact shape.

    This is the CI smoke gate's in-suite twin (CI also runs the full
    ``benchmarks.run --quick`` + ``bench_schema`` CLI on the emitted file).
    """
    from benchmarks import bench_kernels

    out = bench_kernels.main(n_records=160, n_clauses=4, repeats=1)
    validate_kernels(out)  # validated as-emitted, exactly like run.py writes
