"""Optimizer, microbatching, grad compression, checkpoint/restart."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, make_batch
from repro.configs.base import ShapeConfig
from repro.models.layers import split
from repro.models.model import build_model
from repro.train import checkpoint as ckpt
from repro.train import optimizer as opt_mod
from repro.train.optimizer import OptConfig
from repro.train.train_step import dequantize_int8, make_train_step, quantize_int8

SHAPE = ShapeConfig("smoke", "train", 64, 4)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen3-1.7b").reduced()
    model = build_model(cfg)
    values, _ = split(model.init(jax.random.PRNGKey(0)))
    batch = make_batch(cfg, SHAPE)
    return cfg, model, values, batch


def test_schedule_warmup_and_decay():
    oc = OptConfig(learning_rate=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(opt_mod.schedule(oc, jnp.int32(s))) for s in (1, 5, 10, 50, 100)]
    assert lrs[0] < lrs[1] < lrs[2]
    assert lrs[2] == pytest.approx(1e-3, rel=1e-5)
    assert lrs[3] < lrs[2] and lrs[4] < lrs[3]
    assert lrs[4] >= 1e-4 * 0.99  # min_lr_frac floor


def test_adamw_moves_params_and_clips(setup):
    cfg, model, values, batch = setup
    oc = OptConfig(grad_clip=1e-6)  # absurdly small clip
    state = opt_mod.init(values, oc)
    step = jax.jit(make_train_step(model, oc))
    p2, s2, m = step(values, state, batch)
    assert float(m["grad_norm"]) > 0
    # clip bound: update magnitude limited
    diffs = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), values, p2)
    assert max(jax.tree.leaves(diffs)) < 1.0


def test_microbatch_equivalence(setup):
    """n_micro=1 vs n_micro=4 must give (nearly) identical updates."""
    cfg, model, values, batch = setup
    oc = OptConfig(learning_rate=1e-3, weight_decay=0.0)
    s1 = opt_mod.init(values, oc)
    s4 = opt_mod.init(values, oc)
    p1, _, m1 = jax.jit(make_train_step(model, oc, n_micro=1))(values, s1, batch)
    p4, _, m4 = jax.jit(make_train_step(model, oc, n_micro=4))(values, s4, batch)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-3
    err = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max()), p1, p4)))
    assert err < 5e-3, err


def test_adafactor_runs(setup):
    cfg, model, values, batch = setup
    oc = OptConfig(kind="adafactor", learning_rate=1e-3)
    state = opt_mod.init(values, oc)
    step = jax.jit(make_train_step(model, oc))
    p2, s2, m = step(values, state, batch)
    assert np.isfinite(float(m["loss"]))
    # factored states are smaller than params
    nbytes_v = sum(x.size for x in jax.tree.leaves(s2["f"]))
    nbytes_p = sum(x.size for x in jax.tree.leaves(values))
    assert nbytes_v < 0.6 * nbytes_p


def test_int8_quantization_error_feedback():
    g = jnp.array([1.0, -0.5, 0.003, 100.0])
    q, s = quantize_int8(g)
    d = dequantize_int8(q, s)
    assert float(jnp.abs(g - d).max()) <= float(s) * 0.5 + 1e-6
    # error feedback: residual accumulates what quantization lost
    resid = g - d
    q2, s2 = quantize_int8(g + resid)
    d2 = dequantize_int8(q2, s2)
    assert float(jnp.abs((g + resid) - d2).max()) <= float(s2) * 0.5 + 1e-6


def test_compressed_training_converges(setup):
    cfg, model, values, batch = setup
    oc = OptConfig(learning_rate=5e-3, weight_decay=0.0, warmup_steps=1)
    state = opt_mod.init(values, oc)
    step = jax.jit(make_train_step(model, oc, compress=True))
    params = values
    losses = []
    for _ in range(8):
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    assert "ef" in state  # error-feedback buffer present


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path, setup):
    cfg, model, values, batch = setup
    oc = OptConfig()
    state = opt_mod.init(values, oc)
    d = str(tmp_path)
    ckpt.save(d, (values, state), step=7)
    assert ckpt.latest_step(d) == 7
    (v2, s2), manifest = ckpt.restore(d, 7, (values, state))
    assert manifest["step"] == 7
    for a, b in zip(jax.tree.leaves(values), jax.tree.leaves(v2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_ignores_partial_writes(tmp_path, setup):
    cfg, model, values, batch = setup
    d = str(tmp_path)
    ckpt.save(d, values, step=3)
    # simulate a crashed write: directory without DONE
    os.makedirs(os.path.join(d, "step_00000009"))
    assert ckpt.latest_step(d) == 3


def test_async_checkpointer(tmp_path, setup):
    cfg, model, values, batch = setup
    w = ckpt.AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3):
        w.save(values, step=s)
    w.wait()
    assert ckpt.latest_step(str(tmp_path)) == 3
    # gc kept only 2
    steps = [n for n in os.listdir(str(tmp_path)) if n.startswith("step_")]
    assert len(steps) == 2


def test_train_driver_crash_and_resume(tmp_path):
    """Fault injection: run crashes at step 6, restart resumes and finishes."""
    from repro.launch import train as train_mod

    d = str(tmp_path / "run")
    args = [
        "--arch", "qwen3-1.7b", "--reduced", "--dataset", "ycsb",
        "--steps", "10", "--batch", "2", "--seq", "64",
        "--ckpt-dir", d, "--ckpt-every", "2", "--n-clients", "2",
        "--chunks-per-client", "2", "--chunk-records", "64", "--log-every", "5",
    ]
    with pytest.raises(SystemExit):
        train_mod.main(args + ["--fail-at-step", "6"])
    resumed_from = ckpt.latest_step(d)
    assert resumed_from is not None and 2 <= resumed_from <= 6
    res = train_mod.main(args)  # auto-resume
    # async writer may still land step 6 between our read and the resume
    assert 10 - 6 <= res["steps_run"] <= 10 - 2
    assert res["last_loss"] is not None
    assert ckpt.latest_step(d) == 10
