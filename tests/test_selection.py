"""Submodular selection: invariants, approximation bound, CELF equivalence."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.predicates import Query, clause, key_value
from repro.core.selection import (
    SelectionProblem,
    brute_force,
    celf_greedy,
    combined_celf,
    combined_greedy,
    greedy,
    objective,
)


def _make_problem(rng, n_preds=10, n_queries=8, budget=3.0):
    pool = [clause(key_value(f"k{i}", i)) for i in range(n_preds)]
    sel = {c: float(rng.uniform(0.01, 0.95)) for c in pool}
    cost = {c: float(rng.uniform(0.2, 1.5)) for c in pool}
    queries = []
    for _ in range(n_queries):
        k = rng.integers(1, min(4, n_preds) + 1)
        idx = rng.choice(n_preds, size=k, replace=False)
        queries.append(Query(tuple(pool[i] for i in idx), freq=1.0))
    return SelectionProblem(tuple(queries), sel, cost, budget)


@given(st.integers(0, 10_000))
@settings(max_examples=60, deadline=None)
def test_submodularity(seed):
    """f(S)+f(T) >= f(S∪T)+f(S∩T) (paper §V-B)."""
    rng = np.random.default_rng(seed)
    p = _make_problem(rng)
    cands = p.candidates()
    S = {c for c in cands if rng.random() < 0.5}
    T = {c for c in cands if rng.random() < 0.5}
    lhs = objective(p, S) + objective(p, T)
    rhs = objective(p, S | T) + objective(p, S & T)
    assert lhs >= rhs - 1e-9


@given(st.integers(0, 10_000))
@settings(max_examples=40, deadline=None)
def test_monotone(seed):
    rng = np.random.default_rng(seed)
    p = _make_problem(rng)
    cands = p.candidates()
    S = [c for c in cands if rng.random() < 0.4]
    extra = [c for c in cands if c not in S]
    if not extra:
        return
    assert objective(p, S + [extra[0]]) >= objective(p, S) - 1e-12


@given(st.integers(0, 10_000))
@settings(max_examples=30, deadline=None)
def test_budget_respected(seed):
    rng = np.random.default_rng(seed)
    p = _make_problem(rng, budget=float(rng.uniform(0.5, 4.0)))
    for res in (greedy(p, ratio=False), greedy(p, ratio=True), combined_celf(p)):
        assert res.total_cost <= p.budget + 1e-9


@given(st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_combined_beats_0316_opt(seed):
    """Paper §V-C: max(Alg1, Alg2) >= (1/2)(1-1/e)·OPT ≈ 0.316·OPT."""
    rng = np.random.default_rng(seed)
    p = _make_problem(rng, n_preds=8, n_queries=6)
    opt = brute_force(p)
    res = combined_greedy(p)
    if opt.objective > 0:
        assert res.objective >= 0.316 * opt.objective - 1e-9


@given(st.integers(0, 10_000))
@settings(max_examples=40, deadline=None)
def test_celf_matches_eager_greedy(seed):
    """CELF lazy evaluation returns the same objective with fewer evals."""
    rng = np.random.default_rng(seed)
    p = _make_problem(rng, n_preds=14, n_queries=10)
    for ratio in (False, True):
        eager = greedy(p, ratio=ratio)
        lazy = celf_greedy(p, ratio=ratio)
        assert abs(eager.objective - lazy.objective) < 1e-9, (
            eager.describe(), lazy.describe())


def test_celf_fewer_evaluations_large():
    rng = np.random.default_rng(7)
    p = _make_problem(rng, n_preds=200, n_queries=100, budget=20.0)
    eager = greedy(p, ratio=True)
    lazy = celf_greedy(p, ratio=True)
    assert abs(eager.objective - lazy.objective) < 1e-9
    assert lazy.evaluations < eager.evaluations / 2, (
        lazy.evaluations, eager.evaluations)


def test_zero_budget_selects_nothing():
    rng = np.random.default_rng(0)
    p = _make_problem(rng, budget=0.0)
    assert combined_greedy(p).selected == []
