"""Cost model: exact-fit recovery, calibration R², clause pricing."""
import numpy as np

from repro.core.cost_model import CostModel, calibrate, fit
from repro.core.predicates import clause, exact, key_value, substring
from repro.data.datasets import generate_records


def test_fit_recovers_exact_coefficients():
    # record lengths must vary or {sel*lt, (1-sel)*lt, 1} are collinear and
    # k2/k4/c are unidentifiable (the paper calibrates across datasets of
    # different record lengths for the same reason)
    true = CostModel(k1=0.004, k2=0.0015, k3=0.002, k4=0.001, c=0.05)
    rng = np.random.default_rng(0)
    sels = rng.uniform(0, 1, 50)
    plens = rng.integers(2, 30, 50)
    rlens = rng.uniform(80, 500, 50)
    times = [
        true.sel_len_cost(float(s), int(p), float(lt))
        for s, p, lt in zip(sels, plens, rlens)
    ]
    res = fit(sels, plens, rlens, times)
    assert res.r_squared > 0.999
    np.testing.assert_allclose(res.model.coefficients(), true.coefficients(),
                               rtol=1e-6, atol=1e-9)


def test_calibration_on_real_engine():
    """Paper §VII-F: R² of the timed fit (local target: > 0.5)."""
    records = generate_records("ycsb", 400, seed=1)
    probes = (
        [exact("phone_country", c) for c in ("US", "CN", "IN")]
        + [substring("url_site", s) for s in ("www.alpha.", "www.beta.", "x")]
        + [key_value("linear_score", v) for v in (1, 7, 55, 99)]
        + [substring("email", "@"), substring("name", "zzz")]
    )
    res = calibrate(records, probes, repeats=3)
    assert res.n_probes == len(probes)
    # timing noise on shared CI hardware: this is a sanity floor, the paper
    # reports 0.67-0.98 across platforms
    assert res.r_squared > 0.3, res.r_squared
    assert res.model.pattern_cost(10, 0.5) > 0


def test_clause_cost_is_sum_of_disjuncts():
    m = CostModel()
    c1 = clause(exact("a", "x"))
    c2 = clause(exact("a", "x"), exact("a", "y"))
    assert m.clause_cost(c2, 0.3) > m.clause_cost(c1, 0.3)
    np.testing.assert_allclose(
        m.clause_cost(c2, 0.3),
        m.simple_cost(exact("a", "x"), 0.3) + m.simple_cost(exact("a", "y"), 0.3),
    )


def test_key_value_priced_two_patterns():
    m = CostModel()
    kv = key_value("age", 10)
    assert m.simple_cost(kv, 0.2) > m.simple_cost(exact("age", "x"), 0.2) * 0.9
