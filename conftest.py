"""Repo-level pytest bootstrap.

* Puts ``src/`` on ``sys.path`` so ``PYTHONPATH=src`` is not required.
* Gates optional dev deps: when the real ``hypothesis`` package is missing
  (this container has no network access), registers the deterministic
  sampling shim from ``repro._compat.hypothesis_shim`` under the same
  module name so the property tests still collect and run.
* Per-test timeout: uses ``pytest-timeout`` when installed (CI does);
  otherwise falls back to a SIGALRM watchdog so a deadlocked queue in
  the threaded serve-plane tests fails fast instead of hanging the run.
"""
from __future__ import annotations

import os
import signal
import sys

_SRC = os.path.join(os.path.dirname(os.path.abspath(__file__)), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

try:  # real hypothesis wins when installed
    import hypothesis  # noqa: F401
except ImportError:
    from repro._compat import hypothesis_shim as _shim

    sys.modules["hypothesis"] = _shim
    sys.modules["hypothesis.strategies"] = _shim.strategies


try:
    import pytest_timeout  # noqa: F401
    _HAVE_PYTEST_TIMEOUT = True
except ImportError:
    _HAVE_PYTEST_TIMEOUT = False

# generous: some tier-1 tests run 900s-budget subprocesses; this guard
# exists to kill DEADLOCKS (a stuck queue join), not slow tests
_FALLBACK_TIMEOUT_S = 1200


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "ci_smoke: reduced-size end-to-end gates the CI workflow also runs",
    )


if not _HAVE_PYTEST_TIMEOUT and hasattr(signal, "SIGALRM"):
    import pytest

    @pytest.hookimpl(hookwrapper=True)
    def pytest_runtest_call(item):
        def _alarm(signum, frame):
            raise TimeoutError(
                f"test exceeded the {_FALLBACK_TIMEOUT_S}s deadlock "
                f"watchdog (conftest SIGALRM fallback): {item.nodeid}")

        old = signal.signal(signal.SIGALRM, _alarm)
        signal.alarm(_FALLBACK_TIMEOUT_S)
        try:
            yield
        finally:
            signal.alarm(0)
            signal.signal(signal.SIGALRM, old)
