"""Repo-level pytest bootstrap.

* Puts ``src/`` on ``sys.path`` so ``PYTHONPATH=src`` is not required.
* Gates optional dev deps: when the real ``hypothesis`` package is missing
  (this container has no network access), registers the deterministic
  sampling shim from ``repro._compat.hypothesis_shim`` under the same
  module name so the property tests still collect and run.
"""
from __future__ import annotations

import os
import sys

_SRC = os.path.join(os.path.dirname(os.path.abspath(__file__)), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

try:  # real hypothesis wins when installed
    import hypothesis  # noqa: F401
except ImportError:
    from repro._compat import hypothesis_shim as _shim

    sys.modules["hypothesis"] = _shim
    sys.modules["hypothesis.strategies"] = _shim.strategies


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "ci_smoke: reduced-size end-to-end gates the CI workflow also runs",
    )
