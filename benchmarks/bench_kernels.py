"""Kernel/engine throughput (framework table): records/s per engine, and
the roofline math for the TPU substring-match kernel (it is memory-bound:
arithmetic intensity ~1 op/byte, so v5e peak is ~819 GB/s of chunk bytes)."""
from __future__ import annotations

import json
import time

import numpy as np

from repro.core.client import NumpyEngine, PythonEngine, encode_chunk
from repro.data.datasets import generate_records, predicate_pool
from repro.kernels.engine import KernelEngine


def main(n_records: int = 4000, n_clauses: int = 12, repeats: int = 3):
    records = generate_records("ycsb", n_records, seed=43)
    pool = predicate_pool("ycsb")
    rng = np.random.default_rng(0)
    clauses = [pool[i] for i in rng.choice(len(pool), size=n_clauses, replace=False)]
    chunk = encode_chunk(records)
    chunk_bytes = chunk.data.nbytes

    rows = []
    engines = [
        ("python-bytes-find", PythonEngine()),
        ("numpy-vectorized", NumpyEngine()),
        ("xla-jit", KernelEngine(backend="xla")),
        ("pallas-interpret", KernelEngine(backend="pallas_interpret")),
    ]
    expected = None
    for name, eng in engines:
        eng.eval(chunk, clauses[:1])  # warm caches / jit
        best = np.inf
        out = None
        reps = 1 if name == "pallas-interpret" else repeats
        for _ in range(reps):
            t0 = time.perf_counter()
            out = eng.eval(chunk, clauses)
            best = min(best, time.perf_counter() - t0)
        if expected is None:
            expected = out
        assert np.array_equal(out, expected), f"{name} disagrees"
        rec_per_s = n_records / best
        us_per_record = best / n_records * 1e6
        rows.append({
            "engine": name,
            "records_per_s": int(rec_per_s),
            "us_per_record": round(us_per_record, 3),
            "effective_GBps": round(chunk_bytes * n_clauses / best / 1e9, 3),
        })
        print(f"[kernels] {name:20s} {rec_per_s:12.0f} rec/s "
              f"({us_per_record:8.2f} us/rec, {rows[-1]['effective_GBps']} GB/s)")

    # roofline note for the TPU target (not measurable here):
    # multi_match_any streams chunk bytes once per pattern with ~3 VPU ops
    # per byte -> memory-bound; bound = HBM_bw / (stride bytes per record).
    stride = chunk.stride
    v5e_bound = 819e9 / stride / n_clauses
    rows.append({
        "engine": "tpu-v5e-roofline-bound",
        "records_per_s": int(v5e_bound),
        "us_per_record": round(1e6 / v5e_bound, 4),
        "effective_GBps": 819.0,
    })
    print(f"[kernels] v5e HBM-bound ceiling at stride {stride}, "
          f"{n_clauses} patterns: {v5e_bound:,.0f} rec/s")
    with open("artifacts/bench_kernels.json", "w") as f:
        json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    main()
