"""Kernel/engine throughput (framework table): records/s per engine, plus
the fused-vs-split comparison that tracks the pushdown hot path.

Two sections:

  * engine table — µs/record for every engine on a mixed plan (the paper's
    1.0 µs/record client budget is the reference line);
  * fused vs seed-split — the fused single-launch path
    (``KernelEngine.eval_fused``) against the seed pipeline it replaced
    (one ``match_any`` launch + one ``match_key_value`` launch per
    key-value pair + host OR/pack + a ``reduce_bitvectors`` launch for the
    load mask), per kernel backend.  Written to ``BENCH_kernels.json`` by
    ``benchmarks.run`` so the perf trajectory is tracked PR over PR.

Also keeps the roofline note for the TPU target: substring match streams
chunk bytes once per pattern with ~3 VPU ops/byte — memory-bound, so v5e
peak is ~819 GB/s of chunk bytes.
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.core import bitvector
from repro.core.client import (
    NumpyEngine, PythonEngine, dedup_terms, encode_chunk, encode_patterns,
)
from repro.core.predicates import Kind
from repro.data.datasets import generate_records, predicate_pool
from repro.kernels import ops
from repro.kernels.engine import KernelEngine


def _mixed_plan(dataset: str, n_clauses: int, rng: np.random.Generator):
    """Half simple-pattern clauses, half key-value clauses (paper Table I)."""
    pool = predicate_pool(dataset)
    kv, simple = [], []
    for c in pool:
        (kv if any(t.kind is Kind.KEY_VALUE for t in c.terms) else simple).append(c)
    take_kv = min(n_clauses // 2, len(kv))
    take_s = min(n_clauses - take_kv, len(simple))
    picked = [kv[i] for i in rng.choice(len(kv), size=take_kv, replace=False)]
    picked += [simple[i] for i in rng.choice(len(simple), size=take_s, replace=False)]
    return picked


def _seed_split_eval(chunk, clauses, backend: str):
    """The seed pushdown pipeline, preserved for benchmarking the speedup:
    one launch for the simple set, one launch PER key-value pair, host-side
    OR of disjuncts + numpy bit-pack, then a separate reduce launch for the
    ingest load mask."""
    simple_pats: dict[bytes, int] = {}
    kv_pairs: dict[tuple[bytes, bytes], int] = {}
    for cl in clauses:
        for t in cl.terms:
            if t.kind is Kind.KEY_VALUE:
                k, v = t.patterns()
                kv_pairs.setdefault((k, v), len(kv_pairs))
            else:
                simple_pats.setdefault(t.patterns()[0], len(simple_pats))
    R = chunk.n_records
    simple_hits = np.zeros((len(simple_pats), R), dtype=bool)
    if simple_pats:
        pats, plens = encode_patterns(list(simple_pats))
        simple_hits = ops.match_any(chunk.data, pats, plens[:, None],
                                    backend=backend)
    kv_hits = np.zeros((len(kv_pairs), R), dtype=bool)
    for (k, v), idx in kv_pairs.items():
        kv_hits[idx] = ops.match_key_value(chunk.data, k, v, backend=backend)
    out = np.zeros((len(clauses), R), dtype=bool)
    for ci, cl in enumerate(clauses):
        row = out[ci]
        for t in cl.terms:
            if t.kind is Kind.KEY_VALUE:
                row |= kv_hits[kv_pairs[t.patterns()]]
            else:
                row |= simple_hits[simple_pats[t.patterns()[0]]]
    words = bitvector.pack(out)
    _, or_words, _ = ops.reduce_bitvectors(words, backend=backend)
    return words, or_words


def _best_of(fn, repeats: int) -> float:
    best = np.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def main(n_records: int = 4000, n_clauses: int = 12, repeats: int = 3):
    records = generate_records("ycsb", n_records, seed=43)
    rng = np.random.default_rng(0)
    clauses = _mixed_plan("ycsb", n_clauses, rng)
    terms = dedup_terms(clauses)[0]
    n_kv_pairs = sum(1 for t in terms if t.kind is Kind.KEY_VALUE)
    has_simple = any(t.kind is not Kind.KEY_VALUE for t in terms)
    chunk = encode_chunk(records)
    chunk_bytes = chunk.data.nbytes

    rows = []
    # backend/device/interpret metadata per row: artifact consumers must
    # know WHAT executed each number (a pallas figure measured under the
    # interpreter is not a TPU figure), so the schema requires them
    import jax

    platform = jax.devices()[0].platform
    engines = [
        ("python-bytes-find", PythonEngine(), "python", "host", False),
        ("numpy-vectorized", NumpyEngine(), "numpy", "host", False),
        ("xla-jit", KernelEngine(backend="xla"), "xla", platform, False),
        ("pallas-interpret", KernelEngine(backend="pallas_interpret"),
         "pallas_interpret", platform, True),
    ]
    expected = None
    for name, eng, backend, device, interpret in engines:
        eng.eval(chunk, clauses)  # warm caches / jit
        best = np.inf
        out = None
        reps = 1 if name == "pallas-interpret" else repeats
        for _ in range(reps):
            t0 = time.perf_counter()
            out = eng.eval(chunk, clauses)
            best = min(best, time.perf_counter() - t0)
        if expected is None:
            expected = out
        assert np.array_equal(out, expected), f"{name} disagrees"
        rec_per_s = n_records / best
        us_per_record = best / n_records * 1e6
        rows.append({
            "engine": name,
            "backend": backend,
            "device": device,
            "interpret": interpret,
            "records_per_s": int(rec_per_s),
            "us_per_record": round(us_per_record, 3),
            "effective_GBps": round(chunk_bytes * n_clauses / best / 1e9, 3),
        })
        print(f"[kernels] {name:20s} {rec_per_s:12.0f} rec/s "
              f"({us_per_record:8.2f} us/rec, {rows[-1]['effective_GBps']} GB/s)")

    # fused single-launch path vs the seed split pipeline, per backend
    fused_vs_split = []
    for backend in ("xla", "pallas_interpret"):
        eng = KernelEngine(backend=backend)
        split_words, split_or = _seed_split_eval(chunk, clauses, backend)
        fused = eng.eval_fused(chunk, clauses)
        assert np.array_equal(fused.words, split_words), backend
        assert np.array_equal(fused.or_words, split_or), backend
        reps = 1 if backend == "pallas_interpret" else repeats
        t_split = _best_of(
            lambda: _seed_split_eval(chunk, clauses, backend), reps)
        t_fused = _best_of(lambda: eng.eval_fused(chunk, clauses), reps)
        entry = {
            "backend": backend,
            "n_records": n_records,
            "n_clauses": len(clauses),
            "n_kv_pairs": n_kv_pairs,
            "split_us_per_record": round(t_split / n_records * 1e6, 4),
            "fused_us_per_record": round(t_fused / n_records * 1e6, 4),
            "speedup": round(t_split / t_fused, 2),
            # match_any (iff simple patterns exist) + per-kv-pair + reduce
            "launches_split": int(has_simple) + n_kv_pairs + 1,
            "launches_fused": 1,
        }
        fused_vs_split.append(entry)
        print(f"[kernels] fused-vs-split {backend:16s} "
              f"{entry['split_us_per_record']:9.3f} -> "
              f"{entry['fused_us_per_record']:9.3f} us/rec "
              f"(x{entry['speedup']}, launches {entry['launches_split']}->1)")

    # roofline note for the TPU target (not measurable here):
    # multi_match_any streams chunk bytes once per pattern with ~3 VPU ops
    # per byte -> memory-bound; bound = HBM_bw / (stride bytes per record).
    stride = chunk.stride
    v5e_bound = 819e9 / stride / n_clauses
    rows.append({
        "engine": "tpu-v5e-roofline-bound",
        "backend": "analytic",
        "device": "tpu-v5e",
        "interpret": False,
        "records_per_s": int(v5e_bound),
        "us_per_record": round(1e6 / v5e_bound, 4),
        "effective_GBps": 819.0,
    })
    print(f"[kernels] v5e HBM-bound ceiling at stride {stride}, "
          f"{n_clauses} patterns: {v5e_bound:,.0f} rec/s")
    # no writes here: the entry point that ran (benchmarks.run, or the
    # __main__ block below) owns the artifacts/ detail file, and only a
    # full-size benchmarks.run may update the tracked BENCH_kernels.json
    return {"engines": rows, "fused_vs_split": fused_vs_split}


if __name__ == "__main__":
    import os

    os.makedirs("artifacts", exist_ok=True)
    out = main()
    with open("artifacts/bench_kernels.json", "w") as f:
        json.dump(out, f, indent=1)
