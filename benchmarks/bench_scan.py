"""Columnar scan engine vs the row-at-a-time query path (DESIGN.md §13).

Measures exactly the replacement this repo made: the seed scanner ANDed
pushed bitvectors and then called ``q.matches_exact(row)`` on per-row
dicts; the columnar scanner prunes segments by zone map, ANDs the pushed
bitvectors, and evaluates residual predicates vectorized over whole
struct-of-arrays columns.

Setup: a mixed-epoch / mixed-tier ycsb store — two plan epochs (a replan
mid-ingest), chunks cycling through three nested coverage tiers, raw
remainders pre-promoted so both paths scan the identical row population
(JIT parse noise excluded).  The row-at-a-time baseline gets every
advantage the seed path had: rows pre-parsed into dicts OUTSIDE the
timed region, and the same pushed-bitvector skipping.

Workload (selective, the paper's §VII shape): single pushed clauses from
both epochs, pushed+residual conjunctions, residual-only clauses the
client never evaluated, high-cardinality point lookups and no-match
probes (where zone maps prune whole segments).

Counts are asserted bit-identical per query across BOTH paths and the
``matches_exact`` full-scan oracle — the artifact's ``counts_match`` is a
claim gate, not a note.  ``scan_s`` is steady-state (segment caches
warm, the recurring-workload regime); ``cold_scan_s`` is the first pass.

    PYTHONPATH=src python -m benchmarks.bench_scan
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.core import bitvector
from repro.core.client import NumpyEngine, encode_chunk
from repro.core.predicates import Query, clause, key_value
from repro.core.server import (
    CiaoStore, DataSkippingScanner, PlanFamily, PushdownPlan, evolve_family,
)
from repro.core.workload import estimate_selectivities
from repro.data.datasets import generate_records, predicate_pool


def _build_store(n_records: int, chunk_records: int, capacity: int):
    recs = generate_records("ycsb", n_records, seed=7)
    pool = predicate_pool("ycsb")
    sel = estimate_selectivities(pool, recs[:400])
    ranked = sorted(pool, key=lambda c: abs(sel[c] - 0.2))
    fam0 = PlanFamily(plan=PushdownPlan(clauses=ranked[:8]),
                      tier_sizes=(2, 4, 8))
    store = CiaoStore(fam0, segment_capacity=capacity)
    eng = NumpyEngine()

    def ingest(lo: int, hi: int, epoch: int):
        fam = store.family
        for i, start in enumerate(range(lo, hi, chunk_records)):
            tier = i % fam.n_tiers
            chunk = encode_chunk(recs[start: start + chunk_records])
            bv = eng.eval_fused_prefix(chunk, fam.plan.clauses,
                                       fam.tier_sizes[tier])
            store.ingest_chunk(chunk, bv, epoch=epoch, tier=tier)

    half = (n_records // 2) // chunk_records * chunk_records
    ingest(0, half, epoch=0)
    # replan mid-ingest: half the survivors keep their gids, half are new
    order1 = ranked[:4] + ranked[8:12]
    fam1 = evolve_family(fam0, order1, (2, 4, 8))
    store.advance_epoch(fam1)
    ingest(half, n_records, epoch=1)
    # pre-promote every remainder: both measured paths see the same rows
    store.jit_load_raw()
    return store, fam0, fam1, ranked, recs


def _workload(fam0: PlanFamily, fam1: PlanFamily, ranked, recs,
              rng: np.random.Generator) -> list[Query]:
    residual = [c for c in ranked[12:20]]
    qs: list[Query] = []
    # pushed-selective: clauses from both epochs' plans (skipping path)
    for c in fam0.plan.clauses[:3] + fam1.plan.clauses[:3]:
        qs.append(Query((c,)))
    # pushed AND residual: the vectorized-residual case the tentpole targets
    for i, c in enumerate(fam0.plan.clauses[:4]):
        qs.append(Query((c, residual[i])))
    # residual-only (no clause pushed: full segment evaluation)
    for c in residual[4:8]:
        qs.append(Query((c,)))
    # high-cardinality point lookups: most segments lack the value in
    # their dictionary -> zone maps prune them whole
    for i in rng.choice(len(recs), size=4, replace=False):
        obj = json.loads(recs[int(i)])
        qs.append(Query((clause(key_value("customer_id",
                                          obj["customer_id"])),)))
    # no-match probes: numeric range + dictionary zone maps refute outright
    qs.append(Query((clause(key_value("linear_score", 250)),)))
    qs.append(Query((clause(key_value("phone_country", "ZZ")),)))
    return qs


def _row_scan(store: CiaoStore, rows_cache: dict, q: Query) -> int:
    """The seed row-at-a-time path: bitvector skip -> matches_exact."""
    pushed_by_epoch = store.pushed_by_epoch(q)
    count = 0
    for seg in store.blocks:
        rows = rows_cache[id(seg)]
        pushed = pushed_by_epoch[(seg.epoch, seg.n_covered)]
        if pushed:
            words = bitvector.bv_and_many(seg.bitvectors[pushed])
            idx = bitvector.select_indices(words, seg.n_rows)
            for i in idx:
                if q.matches_exact(rows[i]):
                    count += 1
        else:
            for row in rows:
                if q.matches_exact(row):
                    count += 1
    for seg in store.jit_blocks:
        if pushed_by_epoch[(seg.epoch, seg.n_covered)]:
            continue
        for row in rows_cache[id(seg)]:
            if q.matches_exact(row):
                count += 1
    return count


def _best_of(fn, repeats: int) -> float:
    best = np.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(n_records: int = 24576, chunk_records: int = 512,
        segment_capacity: int = 8192, repeats: int = 3,
        quick: bool | None = None) -> dict:
    quick = (n_records <= 8192) if quick is None else quick
    store, fam0, fam1, ranked, recs = _build_store(
        n_records, chunk_records, segment_capacity)
    rng = np.random.default_rng(5)
    queries = _workload(fam0, fam1, ranked, recs, rng)

    # oracle + the row-path baseline rows, both OUTSIDE any timed region
    all_objs = [json.loads(r) for r in recs]
    rows_cache = {id(seg): seg.rows
                  for seg in list(store.blocks) + list(store.jit_blocks)}

    scanner = DataSkippingScanner(store, log_queries=False)
    pruned = 0
    cold_counts = []
    t0 = time.perf_counter()
    for q in queries:                       # cold pass: caches empty
        r = scanner.scan(q)
        pruned += r.segments_pruned
        cold_counts.append(r.count)
    cold_columnar_s = time.perf_counter() - t0

    # bit-identical-count gate (untimed): columnar == row path == oracle
    counts_match = True
    for q, got in zip(queries, cold_counts):
        oracle = sum(1 for o in all_objs if q.matches_exact(o))
        if got != oracle or _row_scan(store, rows_cache, q) != oracle:
            counts_match = False

    columnar_s = _best_of(
        lambda: [scanner.scan(q) for q in queries], repeats)
    row_s = _best_of(
        lambda: [_row_scan(store, rows_cache, q) for q in queries], repeats)

    n_segments = len(store.blocks) + len(store.jit_blocks)
    out = {
        "quick": bool(quick),
        "n_records": int(n_records),
        "n_loaded": int(store.stats.n_loaded),
        "n_segments": int(n_segments),
        "n_queries": len(queries),
        "n_epochs": 2,
        "n_tiers": fam0.n_tiers,
        "row_at_a_time": {
            "scan_s": round(row_s, 6),
            "us_per_query": round(row_s / len(queries) * 1e6, 1),
        },
        "columnar": {
            "scan_s": round(columnar_s, 6),
            "cold_scan_s": round(cold_columnar_s, 6),
            "us_per_query": round(columnar_s / len(queries) * 1e6, 1),
            "segments_pruned": int(pruned),
        },
        "speedup": round(row_s / columnar_s, 2),
        "cold_speedup": round(row_s / cold_columnar_s, 2),
        "counts_match": bool(counts_match),
    }
    print(f"[scan] {n_records} records, {n_segments} segments, "
          f"{len(queries)} queries (2 epochs x {fam0.n_tiers} tiers)")
    print(f"[scan] row-at-a-time {row_s * 1e3:9.2f} ms/batch")
    print(f"[scan] columnar      {columnar_s * 1e3:9.2f} ms/batch "
          f"(x{out['speedup']}, cold x{out['cold_speedup']}, "
          f"{pruned} segments zone-pruned, counts_match={counts_match})")
    return out


if __name__ == "__main__":
    import os

    os.makedirs("artifacts", exist_ok=True)
    out = run()
    with open("artifacts/bench_scan.json", "w") as f:
        json.dump(out, f, indent=1)
