"""Sharded store plane vs the monolithic store (DESIGN.md §14).

Measures what partition-aware placement buys the scan path.  A
mixed-epoch / mixed-tier ycsb store is ingested through a
:class:`ShardedCiaoStore` at 1, 4 and 8 shards with RANGE partitioning on
a **skewed routing key** (``visits`` is re-drawn from a power law, so the
quantile boundaries are workload-derived, not uniform).  Range placement
CLUSTERS routing-key values: each shard's partition min/max refutes most
point lookups outright — skipping the monolithic store can never get
from its ingest-ordered segments, whose zone maps all span the full
value range.

The workload is the paper's selective §VII shape, with the twist that
matters for a store front-end: the selective subset uses DISTINCT lookup
values per measured pass (ad-hoc point lookups — no memoized clause mask
ever helps), alongside recurring pushed / pushed+residual /
residual-only queries that exercise the whole cascade.  Claim gates
(``bench_schema.validate_shard``):

  * per-query counts BIT-IDENTICAL to the 1-shard oracle at 4 and 8
    shards (the 1-shard store is itself checked against the unsharded
    ``CiaoStore`` and ``matches_exact``);
  * >= 30% of per-query shard visits partition-pruned on the selective
    subset at 8 shards;
  * >= 2x scan speedup at 8 shards.  Reduced-size ``--quick`` runs only
    gate against collapse (>= 0.8x): tiny per-shard segments leave
    little vectorized work to skip, so the quick ratio sits in
    wall-clock noise on loaded CI runners — the 2x claim is
    full-size-only.

    PYTHONPATH=src python -m benchmarks.bench_shard
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.core.client import NumpyEngine, encode_chunk
from repro.core.predicates import Query, clause, key_value
from repro.core.server import (
    CiaoStore, DataSkippingScanner, PlanFamily, PushdownPlan, evolve_family,
)
from repro.core.shard import ShardedCiaoStore, ShardedScanner, ShardRouter
from repro.core.workload import estimate_selectivities
from repro.data.datasets import generate_records, predicate_pool

ROUTING_KEY = "visits"


def _skewed_records(n_records: int, card: int, seed: int) -> list[bytes]:
    """ycsb records with the routing key re-drawn from a power law over
    ``card`` distinct values (skew: quadratic concentration at 0)."""
    recs = generate_records("ycsb", n_records, seed=seed)
    rng = np.random.default_rng(seed + 1)
    out = []
    for r in recs:
        obj = json.loads(r)
        obj[ROUTING_KEY] = int(card * float(rng.random()) ** 2)
        out.append(json.dumps(obj, separators=(",", ":")).encode())
    return out


def _build(factory, recs, fam0, fam1, chunk_records: int):
    store = factory(fam0)
    eng = NumpyEngine()

    def ingest(lo, hi, epoch):
        fam = store.family
        for i, start in enumerate(range(lo, hi, chunk_records)):
            tier = i % fam.n_tiers
            chunk = encode_chunk(recs[start: start + chunk_records])
            bv = eng.eval_fused_prefix(chunk, fam.plan.clauses,
                                       fam.tier_sizes[tier])
            store.ingest_chunk(chunk, bv, epoch=epoch, tier=tier)

    half = (len(recs) // 2) // chunk_records * chunk_records
    ingest(0, half, epoch=0)
    store.advance_epoch(fam1)
    ingest(half, len(recs), epoch=1)
    # pre-promote every remainder: all measured paths scan the identical
    # row population (JIT parse noise excluded, shard pruning clean)
    store.jit_load_raw()
    return store


def _fixed_queries(fam0, fam1, ranked) -> list[Query]:
    qs = [Query((c,)) for c in fam0.plan.clauses[:3] + fam1.plan.clauses[:3]]
    qs.append(Query((fam0.plan.clauses[0], ranked[13])))
    qs.append(Query((fam1.plan.clauses[1], ranked[14])))
    qs += [Query((c,)) for c in ranked[15:17]]          # residual-only
    qs.append(Query((clause(key_value("phone_country", "ZZ")),)))
    return qs


def _lookup_sets(objs, card: int, per_set: int, n_sets: int,
                 seed: int) -> list[list[Query]]:
    """Disjoint ad-hoc point-lookup batches on the routing key: mostly
    values present in the store, a few misses beyond the value range."""
    rng = np.random.default_rng(seed)
    present = sorted({o[ROUTING_KEY] for o in objs})
    picks = rng.choice(len(present), size=min(len(present), per_set * n_sets),
                       replace=False)
    sets = []
    for k in range(n_sets):
        vals = [present[int(i)] for i in picks[k * per_set: (k + 1) * per_set]]
        vals += [card + 10 + k * per_set + j for j in range(per_set // 8)]
        sets.append([Query((clause(key_value(ROUTING_KEY, int(v))),))
                     for v in vals])
    return sets


def run(n_records: int = 65536, chunk_records: int = 512,
        segment_capacity: int | None = None, repeats: int = 3,
        quick: bool | None = None) -> dict:
    quick = (n_records <= 16384) if quick is None else quick
    # scaled-down segment size, CONSTANT across every measured store: at a
    # fixed capacity the monolithic store's segment count grows with total
    # data while a shard's grows with data/N — the structural scan-cost
    # asymmetry sharding exists to create.  ~1 row of capacity per 128
    # records keeps the segments-per-store ratio of a production-size
    # store while the benchmark ingest stays tractable.
    if segment_capacity is None:
        segment_capacity = max(256, n_records // 128)
    # routing-key cardinality ~4 distinct values per segment of capacity:
    # LOW-cardinality point lookups are the regime where segment zone
    # maps stop refuting (nearly every segment contains every value) but
    # range placement still prunes whole shards — partition metadata's
    # unique contribution over the existing skipping levels
    card = max(512, segment_capacity * 4)
    recs = _skewed_records(n_records, card, seed=11)
    objs = [json.loads(r) for r in recs]
    pool = predicate_pool("ycsb")
    sel = estimate_selectivities(pool, recs[:400])
    ranked = sorted(pool, key=lambda c: abs(sel[c] - 0.2))
    fam0 = PlanFamily(plan=PushdownPlan(clauses=ranked[:8]),
                      tier_sizes=(2, 4, 8))
    fam1 = evolve_family(fam0, ranked[:4] + ranked[8:12], (2, 4, 8))
    fixed = _fixed_queries(fam0, fam1, ranked)
    per_set = 48 if quick else 96
    lookup_sets = _lookup_sets(objs, card, per_set, repeats, seed=5)
    batches = [fixed + ls for ls in lookup_sets]

    # unsharded differential oracle (counts only, untimed)
    plain = _build(lambda f: CiaoStore(f, segment_capacity=segment_capacity),
                   recs, fam0, fam1, chunk_records)
    oracle = DataSkippingScanner(plain, log_queries=False)
    oracle_counts = [[oracle.scan(q).count for q in batch]
                     for batch in batches]
    exact0 = [sum(1 for o in objs if q.matches_exact(o))
              for q in batches[0]]
    counts_match = oracle_counts[0] == exact0

    runs = []
    times = {}
    for n_shards in (1, 4, 8):
        router = (ShardRouter.from_samples(n_shards, ROUTING_KEY, objs[:800])
                  if n_shards > 1 else None)
        store = _build(
            lambda f: ShardedCiaoStore(f, router=router, n_shards=n_shards,
                                       segment_capacity=segment_capacity),
            recs, fam0, fam1, chunk_records)
        shard_rows = [s.stats.n_records for s in store.shards]
        with ShardedScanner(store, log_queries=False) as scanner:
            # timed FIRST, on cold caches: each batch's lookups are
            # distinct values, so no memoized clause mask ever helps the
            # selective subset (the recurring fixed queries warm up after
            # batch 0 — on every store equally)
            scan_s = np.inf
            for batch in batches:
                t0 = time.perf_counter()
                for q in batch:
                    scanner.scan(q)
                scan_s = min(scan_s, time.perf_counter() - t0)
            # counts gate + pruning attribution, untimed
            n_match = pruned_sel = scanned_sel = 0
            for batch, want in zip(batches, oracle_counts):
                got = []
                for q in batch:
                    r = scanner.scan(q)
                    got.append(r.count)
                    if len(q.clauses) == 1 and \
                            q.clauses[0].terms[0].key == ROUTING_KEY:
                        pruned_sel += r.shards_pruned
                        scanned_sel += r.shards_scanned
                n_match += got == want
        times[n_shards] = scan_s
        runs.append({
            "n_shards": n_shards,
            "scan_s": round(scan_s, 6),
            "us_per_query": round(scan_s / len(batches[0]) * 1e6, 1),
            "counts_match": n_match == len(batches),
            "selective_pruned_fraction": round(
                pruned_sel / max(pruned_sel + scanned_sel, 1), 4),
            "max_shard_rows": int(max(shard_rows)),
            "min_shard_rows": int(min(shard_rows)),
        })

    at8 = next(r for r in runs if r["n_shards"] == 8)
    out = {
        "quick": bool(quick),
        "n_records": int(n_records),
        "routing_card": int(card),
        "n_queries": len(batches[0]),
        "n_selective": len(lookup_sets[0]),
        "routing_key": ROUTING_KEY,
        "mode": "range",
        "runs": runs,
        "counts_match": bool(counts_match
                             and all(r["counts_match"] for r in runs)),
        "speedup_4": round(times[1] / times[4], 2),
        "speedup_8": round(times[1] / times[8], 2),
        "selective_pruned_fraction": at8["selective_pruned_fraction"],
    }
    print(f"[shard] {n_records} records, {len(batches[0])} queries/batch "
          f"({len(lookup_sets[0])} ad-hoc lookups, card {card}), "
          f"routing on {ROUTING_KEY} (range)")
    for r in runs:
        print(f"[shard] N={r['n_shards']}: {r['scan_s'] * 1e3:9.2f} ms/batch "
              f"(pruned {r['selective_pruned_fraction']:.0%} of shard visits "
              f"on the selective subset, counts_match={r['counts_match']})")
    print(f"[shard] speedup x{out['speedup_4']} @4, x{out['speedup_8']} @8; "
          f"counts_match={out['counts_match']}")
    return out


if __name__ == "__main__":
    import os

    os.makedirs("artifacts", exist_ok=True)
    out = run()
    with open("artifacts/bench_shard.json", "w") as f:
        json.dump(out, f, indent=1)
