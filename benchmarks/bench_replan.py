"""Drifting-workload benchmark: adaptive replanning vs a static epoch-0 plan.

Scenario (DESIGN.md §11): a fleet of client shards streams chunks while the
query workload is piecewise-stationary — phase 1 draws Zipf(1.5) queries,
then the Zipf parameter AND the hot-clause permutation shift (phase 2).
The static run keeps the epoch-0 plan; the adaptive run wires a
``Replanner`` into the ingest coordinator, which detects the coverage
collapse from the scanner's query log, re-solves the budgeted selection
from observed selectivities + the recalibrated cost model, and broadcasts
the new plan epoch to every shard mid-stream.

Post-drift metrics (the paper's protocol, measured over the tail of the
phase-2 workload):

  * ``scan_s``     — wall-clock of the post-drift query batch;
  * ``eff_ratio``  — effective loading ratio (loaded + JIT-loaded records)
    / ingested records: a static plan degrades to ~1.0 because un-pushed
    queries JIT-promote the whole raw remainder;
  * ``skip_frac``  — fraction of candidate rows skipped via bitvectors.

The cost model is calibrated from timed numpy-engine probes first
(paper §VII-F) so the budget means real µs/record on THIS hardware and the
replanner's online recalibration stays near 1.0.
"""
from __future__ import annotations

import json
import time

from repro.core.client import NumpyEngine
from repro.core.cost_model import CostModel, calibrate_scaled
from repro.core.planner import build_plan
from repro.core.replan import Replanner, ReplanPolicy
from repro.core.server import CiaoStore, DataSkippingScanner, PushdownPlan
from repro.core.workload import DriftPhase, Workload, drifting_workloads
from repro.data.datasets import generate_records, predicate_pool
from repro.data.pipeline import ClientShard, IngestCoordinator


def calibrated_cost_model(sample_records: list[bytes],
                          pool, n_probes: int = 4) -> CostModel:
    """Recalibrate the default model to this hardware + engine.

    The probe plan is sized like the plans the budget will actually buy
    (~``n_probes`` clauses) — see :func:`repro.core.cost_model.
    calibrate_scaled` for why probe size matters.
    """
    return calibrate_scaled(sample_records, pool[:n_probes], NumpyEngine())


def _scenario(
    *, adaptive: bool, dataset: str, budget_us: float,
    cost_model: CostModel, wl_phases: list[Workload],
    sample: list[bytes], chunk_records: int, chunks_per_phase: int,
    n_shards: int, queries_per_chunk: int, n_tail_queries: int,
) -> dict:
    wl1, wl2 = wl_phases
    rep0 = build_plan(wl1, sample, budget_us=budget_us, cost_model=cost_model)
    plan0 = PushdownPlan(clauses=list(rep0.plan.clauses))
    store = CiaoStore(plan0)
    scanner = DataSkippingScanner(store)
    replanner = None
    if adaptive:
        policy = ReplanPolicy(
            check_every_records=2 * chunk_records,
            min_observe_records=chunk_records,
            min_coverage=0.6,
            workload_window=4 * queries_per_chunk,
            min_window_queries=max(2 * queries_per_chunk, 8),
        )
        replanner = Replanner(
            store, sample, budget_us=budget_us, base_workload=wl1,
            cost_model=cost_model, policy=policy, planned_sel=rep0.sel,
        )
    eng = NumpyEngine()
    shards = [ClientShard(dataset, i, eng, plan0,
                          chunk_records=chunk_records)
              for i in range(n_shards)]

    qstream = iter(wl1.queries)

    def on_chunk(done: int) -> None:
        for _ in range(queries_per_chunk):
            q = next(qstream, None)
            if q is not None:
                scanner.scan(q)

    coord = IngestCoordinator(shards, store, replanner=replanner,
                              on_chunk=on_chunk)
    coord.run(chunks_per_client=chunks_per_phase)      # phase 1
    qstream = iter(wl2.queries[:-n_tail_queries])      # drift hits here
    coord.run(chunks_per_client=chunks_per_phase)      # phase 2

    # post-drift measurement: the tail of the phase-2 workload
    tail = wl2.queries[-n_tail_queries:]
    t0 = time.perf_counter()
    scanned = skipped = 0
    for q in tail:
        r = scanner.scan(q)
        scanned += r.rows_scanned
        skipped += r.rows_skipped
    scan_s = time.perf_counter() - t0
    stats = store.stats
    return {
        "adaptive": adaptive,
        "epoch": store.epoch,
        "epoch_bumps": coord.epoch_bumps,
        "n_records": stats.n_records,
        "loading_ratio_ingest": round(stats.loading_ratio, 4),
        "eff_loading_ratio": round(
            (stats.n_loaded + stats.n_jit_loaded) / stats.n_records, 4),
        "post_drift_scan_s": round(scan_s, 4),
        "rows_scanned": scanned,
        "skip_frac": round(skipped / max(scanned + skipped, 1), 4),
        "replan_events": [e.describe() for e in
                          (replanner.history if replanner else [])],
        "cost_scale": round(replanner.cost_scale, 3) if replanner else None,
    }


def run(
    dataset: str = "ycsb", *, n_records: int = 16384,
    n_shards: int = 2, queries_per_phase: int = 150,
    n_tail_queries: int = 60, budget_clauses: float = 4.0, seed: int = 1,
) -> dict:
    if n_tail_queries <= 0 or n_tail_queries >= queries_per_phase:
        raise ValueError(
            "n_tail_queries must be in (0, queries_per_phase): the tail is "
            "held out of the ingest-time stream for the post-drift scan")
    pool = predicate_pool(dataset)
    phases = [
        DriftPhase(queries_per_phase, "zipf", 1.5, seed=seed),
        DriftPhase(queries_per_phase, "zipf", 2.0, seed=seed + 6),
    ]
    wl_phases = drifting_workloads(pool, phases)
    sample = generate_records(dataset, 400, seed=17)
    cost_model = calibrated_cost_model(sample, pool)
    # budget = ~budget_clauses x the median clause cost on this hardware
    sel = {c: 0.2 for c in pool}
    costs = sorted(cost_model.clause_cost(c, sel[c]) for c in pool)
    budget_us = budget_clauses * costs[len(costs) // 2]

    chunk_records = 512
    chunks_per_phase = max(n_records // (2 * n_shards * chunk_records), 1)
    queries_per_chunk = max(
        queries_per_phase // (chunks_per_phase * n_shards) // 2, 1)

    common = dict(
        dataset=dataset, budget_us=budget_us, cost_model=cost_model,
        wl_phases=wl_phases, sample=sample, chunk_records=chunk_records,
        chunks_per_phase=chunks_per_phase, n_shards=n_shards,
        queries_per_chunk=queries_per_chunk, n_tail_queries=n_tail_queries,
    )
    static = _scenario(adaptive=False, **common)
    adaptive = _scenario(adaptive=True, **common)
    out = {
        "budget_us": round(budget_us, 3),
        "static": static,
        "adaptive": adaptive,
        "post_drift_scan_speedup": round(
            static["post_drift_scan_s"]
            / max(adaptive["post_drift_scan_s"], 1e-9), 2),
        "eff_loading_ratio_delta": round(
            static["eff_loading_ratio"] - adaptive["eff_loading_ratio"], 4),
    }
    print(f"[replan] budget {budget_us:.2f} us/rec | static scan "
          f"{static['post_drift_scan_s']:.3f}s ratio "
          f"{static['eff_loading_ratio']:.2%} | adaptive scan "
          f"{adaptive['post_drift_scan_s']:.3f}s ratio "
          f"{adaptive['eff_loading_ratio']:.2%} (epoch "
          f"{adaptive['epoch']}, x{out['post_drift_scan_speedup']} scan, "
          f"skip {adaptive['skip_frac']:.0%} vs {static['skip_frac']:.0%})")
    for ev in adaptive["replan_events"]:
        print(f"[replan]   {ev}")
    return out


if __name__ == "__main__":
    import os

    os.makedirs("artifacts", exist_ok=True)
    out = run()
    with open("artifacts/bench_replan.json", "w") as f:
        json.dump(out, f, indent=1)
