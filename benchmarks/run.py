"""Benchmark orchestrator — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows summarizing every benchmark,
and writes the detailed JSON artifacts under artifacts/.

    PYTHONPATH=src python -m benchmarks.run            # full suite
    PYTHONPATH=src python -m benchmarks.run --quick    # reduced sizes
"""
from __future__ import annotations

import argparse
import json
import os


def _row(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.3f},{derived}")


def _write_gated_artifacts(
    out: dict, *, validator, detail_name: str, quick: bool,
    artifacts_dir: str, tracked_path: str,
) -> list[str]:
    """Schema-gated artifact writer shared by every tracked benchmark.

    The schema gate runs FIRST (a malformed artifact is a bug, not data).
    Quick runs only ever write under ``artifacts_dir`` — the tracked
    perf-trajectory file records full-size numbers exclusively, so a CI
    smoke run can never clobber PR-over-PR comparability.
    """
    validator(out)
    detail = os.path.join(artifacts_dir, detail_name)
    with open(detail, "w") as f:
        json.dump(out, f, indent=1)
    written = [detail]
    if not quick:
        with open(tracked_path, "w") as f:
            json.dump(out, f, indent=1)
        written.append(tracked_path)
    return written


def write_kernels_artifacts(
    out: dict, *, quick: bool, artifacts_dir: str = "artifacts",
    tracked_path: str = "BENCH_kernels.json",
) -> list[str]:
    """Write the kernels benchmark JSON; returns the paths written."""
    from .bench_schema import validate_kernels

    return _write_gated_artifacts(
        out, validator=validate_kernels, detail_name="bench_kernels.json",
        quick=quick, artifacts_dir=artifacts_dir, tracked_path=tracked_path)


def write_tiers_artifacts(
    out: dict, *, quick: bool, artifacts_dir: str = "artifacts",
    tracked_path: str = "BENCH_tiers.json",
) -> list[str]:
    """Write the tiered-fleet benchmark JSON; returns the paths written."""
    from .bench_schema import validate_tiers

    return _write_gated_artifacts(
        out, validator=validate_tiers, detail_name="bench_tiers.json",
        quick=quick, artifacts_dir=artifacts_dir, tracked_path=tracked_path)


def write_scan_artifacts(
    out: dict, *, quick: bool, artifacts_dir: str = "artifacts",
    tracked_path: str = "BENCH_scan.json",
) -> list[str]:
    """Write the columnar-scan benchmark JSON; returns the paths written."""
    from .bench_schema import validate_scan

    return _write_gated_artifacts(
        out, validator=validate_scan, detail_name="bench_scan.json",
        quick=quick, artifacts_dir=artifacts_dir, tracked_path=tracked_path)


def write_shard_artifacts(
    out: dict, *, quick: bool, artifacts_dir: str = "artifacts",
    tracked_path: str = "BENCH_shard.json",
) -> list[str]:
    """Write the sharded-store benchmark JSON; returns the paths written."""
    from .bench_schema import validate_shard

    return _write_gated_artifacts(
        out, validator=validate_shard, detail_name="bench_shard.json",
        quick=quick, artifacts_dir=artifacts_dir, tracked_path=tracked_path)


def write_device_artifacts(
    out: dict, *, quick: bool, artifacts_dir: str = "artifacts",
    tracked_path: str = "BENCH_device.json",
) -> list[str]:
    """Write the device-scan benchmark JSON; returns the paths written."""
    from .bench_schema import validate_device

    return _write_gated_artifacts(
        out, validator=validate_device, detail_name="bench_device.json",
        quick=quick, artifacts_dir=artifacts_dir, tracked_path=tracked_path)


def write_batch_artifacts(
    out: dict, *, quick: bool, artifacts_dir: str = "artifacts",
    tracked_path: str = "BENCH_batch.json",
) -> list[str]:
    """Write the multi-query batch benchmark JSON; returns the paths written."""
    from .bench_schema import validate_batch

    return _write_gated_artifacts(
        out, validator=validate_batch, detail_name="bench_batch.json",
        quick=quick, artifacts_dir=artifacts_dir, tracked_path=tracked_path)


def write_serve_artifacts(
    out: dict, *, quick: bool, artifacts_dir: str = "artifacts",
    tracked_path: str = "BENCH_serve.json",
) -> list[str]:
    """Write the async serving plane benchmark JSON; returns the paths
    written."""
    from .bench_schema import validate_serve

    return _write_gated_artifacts(
        out, validator=validate_serve, detail_name="bench_serve.json",
        quick=quick, artifacts_dir=artifacts_dir, tracked_path=tracked_path)


def write_tuner_artifacts(
    out: dict, *, quick: bool, artifacts_dir: str = "artifacts",
    tracked_path: str = "BENCH_tuner.json",
) -> list[str]:
    """Write the physical-design tuner benchmark JSON; returns the paths
    written."""
    from .bench_schema import validate_tuner

    return _write_gated_artifacts(
        out, validator=validate_tuner, detail_name="bench_tuner.json",
        quick=quick, artifacts_dir=artifacts_dir, tracked_path=tracked_path)


def write_skip_artifacts(
    out: dict, *, quick: bool, artifacts_dir: str = "artifacts",
    tracked_path: str = "BENCH_skip.json",
) -> list[str]:
    """Write the skipping-index benchmark JSON; returns the paths written."""
    from .bench_schema import validate_skip

    return _write_gated_artifacts(
        out, validator=validate_skip, detail_name="bench_skip.json",
        quick=quick, artifacts_dir=artifacts_dir, tracked_path=tracked_path)


# suite name -> what it measures (single source for --only and --list)
_SUITES = {
    "e2e": "paper Figs 3-5 end-to-end loading/query/overlap speedups",
    "micro": "paper Figs 6-12 micro-benchmarks + pattern-memo check",
    "cost": "paper Table IV cost-model fit",
    "selection": "CELF predicate selection scaling + quality bound",
    "kernels": "client engine throughput + fused-vs-split launches",
    "replan": "workload-drift replanning vs a static plan",
    "tiers": "tiered fleet allocation vs uniform baselines",
    "scan": "columnar segment scan vs row-at-a-time",
    "shard": "sharded store scaling + partition pruning",
    "device": "device-resident fused scan plane",
    "batch": "multi-query batcher + result cache",
    "serve": "async serving under live ingest",
    "tuner": "online physical-design tuner drift recovery",
    "skip": "skipping-index registry: range/IN/n-gram pruning",
    "roofline": "per-kernel analytic roofline cells",
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument(
        "--only", default=None,
        help="comma list of suites (see --list): "
             + ",".join(_SUITES))
    ap.add_argument("--list", action="store_true",
                    help="list the registered bench suites and exit")
    args = ap.parse_args()
    if args.list:
        for name, what in _SUITES.items():
            print(f"{name:10s} {what}")
        return
    only = set(args.only.split(",")) if args.only else None
    if only is not None:
        unknown = only - set(_SUITES)
        if unknown:
            ap.error(f"unknown suite(s): {','.join(sorted(unknown))} "
                     f"(see --list)")
    os.makedirs("artifacts", exist_ok=True)

    csv_rows: list[tuple[str, float, str]] = []

    if only is None or "e2e" in only:
        from . import bench_end_to_end

        n = 6000 if args.quick else 20000
        rows = bench_end_to_end.run(n_records=n,
                                    n_queries_exec=20 if args.quick else 60)
        with open("artifacts/bench_end_to_end.json", "w") as f:
            json.dump(rows, f, indent=1)
        best = {}
        for r in rows:
            for k in ("loading_speedup", "query_speedup", "e2e_speedup",
                      "e2e_overlapped_speedup"):
                best[k] = max(best.get(k, 0), r[k])
        at1 = [r for r in rows if r["budget_us"] == 1.0]
        csv_rows.append((
            "fig3-5_end_to_end",
            1e6 * sum(r["loading_s"] + r["query_s"] for r in at1) / max(
                sum(1 for _ in at1), 1) / 1000,
            f"best_load_x{best['loading_speedup']};best_query_x{best['query_speedup']};"
            f"best_e2e_x{best['e2e_speedup']};best_e2e_overlap_x{best['e2e_overlapped_speedup']}"
            f";paper=21x/23x/19x",
        ))

    if only is None or "micro" in only:
        from . import bench_micro

        out = bench_micro.main()
        fr = [r["fraction_improved"] for r in out["fig6_query_fraction"]]
        csv_rows.append(("fig6_query_fraction", 0.0,
                         f"improved_{min(fr):.0%}-{max(fr):.0%};paper=37-68%"))
        csv_rows.append(("fig7-12_micro", 0.0,
                         f"selectivity+overlap+skewness recorded"))

    if only is None or "cost" in only:
        from . import bench_cost_model

        rows = bench_cost_model.main(n_records=1500 if args.quick else 3000)
        r2s = ";".join(f"{r['platform']}=R2_{r['r_squared']}" for r in rows)
        csv_rows.append(("tableIV_cost_model", 0.0, r2s + ";paper=0.666-0.978"))

    if only is None or "selection" in only:
        from . import bench_selection

        out = bench_selection.main()
        last = out["scaling"][-1]
        csv_rows.append((
            "selection_celf", last["celf_s"] * 1e6 / max(last["n_preds"], 1),
            f"celf_x{last['speedup']}_at_P{last['n_preds']};"
            f"quality_worst_{out['quality']['worst_ratio']}(>=0.316)",
        ))

    if only is None or "kernels" in only:
        from . import bench_kernels

        out = bench_kernels.main(n_records=1500 if args.quick else 4000)
        for r in out["engines"]:
            csv_rows.append((f"kernel_{r['engine']}", r["us_per_record"],
                             f"{r['records_per_s']}rec/s;{r['effective_GBps']}GBps"))
        for r in out["fused_vs_split"]:
            csv_rows.append((
                f"kernel_fused_{r['backend']}", r["fused_us_per_record"],
                f"split_{r['split_us_per_record']}us;x{r['speedup']};"
                f"launches_{r['launches_split']}->{r['launches_fused']}",
            ))
        write_kernels_artifacts(out, quick=args.quick)

    if only is None or "replan" in only:
        from . import bench_replan
        from .bench_schema import validate_replan

        out = bench_replan.run(
            n_records=4096 if args.quick else 16384,
            queries_per_phase=80 if args.quick else 150,
            n_tail_queries=30 if args.quick else 60,
        )
        validate_replan(out)
        with open("artifacts/bench_replan.json", "w") as f:
            json.dump(out, f, indent=1)
        csv_rows.append((
            "replan_drift", 0.0,
            f"scan_x{out['post_drift_scan_speedup']};"
            f"ratio_{out['adaptive']['eff_loading_ratio']:.2f}vs"
            f"{out['static']['eff_loading_ratio']:.2f};"
            f"epochs_{out['adaptive']['epoch']}",
        ))

    if only is None or "tiers" in only:
        from . import bench_tiers

        out = bench_tiers.run(
            n_records=4864 if args.quick else 13312,
            n_queries=200 if args.quick else 300,
            n_exec_queries=80 if args.quick else 120,
        )
        write_tiers_artifacts(out, quick=args.quick)
        csv_rows.append((
            "tiers_fleet", 0.0,
            f"eff_{out['tiered']['eff_loading_ratio']:.2f}vs"
            f"{out['uniform_min']['eff_loading_ratio']:.2f}/"
            f"{out['uniform_max']['eff_loading_ratio']:.2f};"
            f"e2e_{out['tiered']['end_to_end_s']}vs"
            f"{out['uniform_min']['end_to_end_s']}/"
            f"{out['uniform_max']['end_to_end_s']};"
            f"retiers_{out['tiered']['retier_events']}",
        ))

    if only is None or "scan" in only:
        from . import bench_scan

        out = bench_scan.run(
            n_records=6144 if args.quick else 24576,
            repeats=2 if args.quick else 3,
            quick=args.quick,
        )
        write_scan_artifacts(out, quick=args.quick)
        csv_rows.append((
            "scan_columnar", out["columnar"]["us_per_query"],
            f"row_{out['row_at_a_time']['us_per_query']}us;"
            f"x{out['speedup']};cold_x{out['cold_speedup']};"
            f"pruned_{out['columnar']['segments_pruned']};"
            f"counts_match_{out['counts_match']}",
        ))

    if only is None or "shard" in only:
        from . import bench_shard

        out = bench_shard.run(
            n_records=16384 if args.quick else 65536,
            repeats=2 if args.quick else 3,
            quick=args.quick,
        )
        write_shard_artifacts(out, quick=args.quick)
        at8 = next(r for r in out["runs"] if r["n_shards"] == 8)
        csv_rows.append((
            "shard_store", at8["us_per_query"],
            f"x{out['speedup_4']}@4;x{out['speedup_8']}@8;"
            f"pruned_{out['selective_pruned_fraction']:.0%};"
            f"counts_match_{out['counts_match']}",
        ))

    if only is None or "device" in only:
        from . import bench_device

        out = bench_device.run(
            n_records=6144 if args.quick else 24576,
            repeats=2 if args.quick else 3,
            quick=args.quick,
        )
        write_device_artifacts(out, quick=args.quick)
        csv_rows.append((
            "device_scan", out["device_batched"]["us_per_query"],
            f"x{out['speedup']}_vs_numpy;batch8_x{out['batch8_speedup']};"
            f"uploads_steady_{out['uploads_steady']};"
            f"roofline_frac_{out['roofline_frac']};"
            f"counts_match_{out['counts_match']}",
        ))

    if only is None or "batch" in only:
        from . import bench_batch

        out = bench_batch.run(
            n_records=6144 if args.quick else 24576,
            repeats=2 if args.quick else 3,
            quick=args.quick,
        )
        write_batch_artifacts(out, quick=args.quick)
        csv_rows.append((
            "batch_scan", out["batched"]["us_per_query"],
            f"seq_{out['sequential']['us_per_query']}us;x{out['speedup']};"
            f"cache_x{out['cache_speedup']};"
            f"counts_match_{out['counts_match']}",
        ))

    if only is None or "serve" in only:
        from . import bench_serve

        out = bench_serve.run(
            n_records=6144 if args.quick else 24576,
            segment_capacity=512 if args.quick else 1024,
            quick=args.quick,
        )
        write_serve_artifacts(out, quick=args.quick)
        csv_rows.append((
            "serve_live_p99", out["live"]["p99_us"],
            f"x{out['throughput_speedup']}_vs_serialized;"
            f"p99_ratio_{out['p99_ratio']};"
            f"counts_match_{out['counts_match']}",
        ))

    if only is None or "tuner" in only:
        from . import bench_tuner

        out = bench_tuner.run(
            n_records=8192 if args.quick else 49152,
            segment_capacity=512 if args.quick else 1024,
            quick=args.quick,
        )
        write_tuner_artifacts(out, quick=args.quick)
        csv_rows.append((
            "tuner_drift", out["after"]["us_per_query"],
            f"recovery_x{out['recovery_speedup']}_vs_stale;"
            f"p99_ratio_{out['p99_ratio']};"
            f"rows_moved_{out['migration']['rows_moved']};"
            f"counts_match_{out['counts_match']}",
        ))

    if only is None or "skip" in only:
        from . import bench_skip

        out = bench_skip.run(
            n_records=6144 if args.quick else 24576,
            repeats=2 if args.quick else 3,
            quick=args.quick,
        )
        write_skip_artifacts(out, quick=args.quick)
        csv_rows.append((
            "skip_registry", out["skip"]["us_per_query"],
            f"noskip_{out['noskip']['us_per_query']}us;x{out['speedup']};"
            f"pruned_{out['pruned_fraction']:.0%};"
            f"migration_ok_{out['migration_ok']};"
            f"counts_match_{out['counts_match']}",
        ))

    if only is None or "roofline" in only:
        from . import bench_roofline

        recs = bench_roofline.main()
        if recs:
            ok = [r for r in recs.values() if "roofline" in r]
            csv_rows.append((
                "roofline_cells", 0.0,
                f"{len(ok)}_cells_compiled;"
                f"{sum(1 for r in recs.values() if 'skipped' in r)}_documented_skips",
            ))

    print("\n=== name,us_per_call,derived ===")
    for name, us, derived in csv_rows:
        _row(name, us, derived)


if __name__ == "__main__":
    main()
