"""Device-resident scan plane vs the host columnar scanner (DESIGN.md §15).

Measures the tentpole replacement: the host ``DataSkippingScanner``
walks segments one at a time (zone-prune, bitvector AND, vectorized
residual per segment, per query), while :class:`DeviceScanner` keeps
every hot segment resident as device arrays and evaluates the WHOLE
query batch against the WHOLE plane in one fused launch.

Setup reuses ``bench_scan``'s mixed-epoch / mixed-tier ycsb store and
its selective workload (pushed clauses from both epochs, pushed+residual
conjunctions, residual-only clauses, point lookups, no-match probes), so
the two artifacts describe the same population.

The gated ``numpy`` baseline is ``scan_core_numpy`` — the SAME
multi-query plane scan, numpy-vectorized with one temporary per stage,
driven through the same scanner pipeline (``DeviceScanner`` with
``backend="numpy"``, plane pre-mirrored to host) — so the speedup
isolates what the fused single launch buys on identical work, exactly
like ``bench_kernels``' numpy-vectorized vs xla-jit rows.  The host
``DataSkippingScanner`` is the CORRECTNESS oracle and is reported
untimed-gated as ``host_skipping`` context: on this selective workload
its zone-map + pushed-bitvector skipping does far less work per query
than any dense plane pass, and the artifact says so rather than hiding
it.

Claim gates (enforced by ``bench_schema.validate_device``):

  * counts bit-identical to sequential host scans (plus full
    rows_scanned / rows_skipped accounting equality — checked here),
    for BOTH the device backend and the numpy reference;
  * ZERO steady-state host->device uploads: after the warm pass the
    plane is resident and scans move only (Q, S) parameter tables;
  * fused batched device scan >= 2x the numpy-vectorized reference;
  * a batch of 8 queries >= 3x over the same 8 queries launched
    sequentially (the multi-query fusion claim);
  * roofline fraction from the analytic flops model
    (``analysis.flops.scan_estimate`` over the EXACT launch shape) vs
    the measured launch: ``v5e_bound_s / measured_launch_s``.

    PYTHONPATH=src python -m benchmarks.bench_device
"""
from __future__ import annotations

import json
import time

import numpy as np

from benchmarks.bench_scan import _best_of, _build_store, _workload
from repro.analysis.flops import scan_estimate
from repro.analysis.roofline import Roofline
from repro.core.device_scan import DeviceScanner
from repro.core.server import DataSkippingScanner
from repro.kernels.scan_fused import scan_counts


def _accounting(r) -> tuple:
    return (r.count, r.rows_scanned, r.rows_skipped, r.raw_parsed,
            r.segments_pruned,
            tuple(sorted((k, (g.count, g.rows_scanned, g.rows_skipped))
                         for k, g in r.groups.items())))


def run(n_records: int = 24576, chunk_records: int = 512,
        segment_capacity: int = 8192, repeats: int = 3,
        backend: str = "xla", quick: bool | None = None) -> dict:
    import jax

    quick = (n_records <= 8192) if quick is None else quick
    store, fam0, fam1, ranked, recs = _build_store(
        n_records, chunk_records, segment_capacity)
    rng = np.random.default_rng(5)
    queries = _workload(fam0, fam1, ranked, recs, rng)

    host = DataSkippingScanner(store, log_queries=False)
    dev = DeviceScanner(store, backend=backend, log_queries=False)
    npy = DeviceScanner(store, backend="numpy", log_queries=False)

    # warm pass: uploads the plane, compiles the launch.  The store was
    # fully promoted by _build_store, so repeated scans are idempotent
    # and the bit-identical gate can compare steady passes directly.
    dev_results = dev.scan_batch(queries)
    uploads_warm = dev.cache.uploads
    dev_results = dev.scan_batch(queries)
    uploads_steady = dev.cache.uploads - uploads_warm
    npy_results = npy.scan_batch(queries)

    host_results = [host.scan(q) for q in queries]
    counts_match = all(
        _accounting(d) == _accounting(h) == _accounting(n)
        for d, h, n in zip(dev_results, host_results, npy_results))

    host_s = _best_of(lambda: [host.scan(q) for q in queries], repeats)
    numpy_s = _best_of(lambda: npy.scan_batch(queries), repeats)
    device_s = _best_of(lambda: dev.scan_batch(queries), repeats)

    # multi-query fusion: 8 queries in one launch vs 8 single launches.
    # best-of with extra repeats — the two sides are compared against
    # each other, so this ratio is the most noise-sensitive gate
    qs8 = queries[:8]
    dev.scan_batch(qs8)                       # warm the Q=8 shape
    for q in qs8:
        dev.scan_batch([q])                   # warm the Q=1 shape
    reps8 = max(repeats, 5)
    batch8_s = _best_of(lambda: dev.scan_batch(qs8), reps8)
    seq8_s = _best_of(lambda: [dev.scan_batch([q]) for q in qs8], reps8)

    # roofline: analytic flops/bytes of the EXACT steady launch shape,
    # v5e bound vs the measured launch (parameter prep excluded — this
    # is the kernel's fraction, not the host pipeline's)
    prep = dev._prepare(queries)
    p = prep.params
    plane = dev.cache.plane
    assert p is not None and plane is not None
    shape = dict(n_rows=int(plane.sid.shape[0]),
                 n_terms=int(p.kinds.shape[0]),
                 n_clauses=int(p.membership.shape[0]),
                 n_queries=int(p.query_clause.shape[0]),
                 n_slots=int(p.pushed_tbl.shape[1]) - 1)
    est = scan_estimate(**shape)
    scan_counts(plane, p, backend=backend)    # warm this exact shape
    launch_s = _best_of(lambda: scan_counts(plane, p, backend=backend),
                        repeats)
    roof = Roofline(
        arch="tpu-v5e",
        shape="x".join(f"{k[2:]}{v}" for k, v in shape.items()),
        mesh="1x1", device_flops=est.flops_global,
        device_bytes=est.hbm_bytes_global, collective_bytes=0.0,
        model_flops_global=est.flops_global, n_devices=1,
    ).finalize()
    roofline_frac = roof.step_time_s / launch_s

    n_queries = len(queries)
    n_segments = len(store.blocks) + len(store.jit_blocks)

    def side(scan_s: float) -> dict:
        return {
            "scan_s": round(scan_s, 6),
            "us_per_query": round(scan_s / n_queries * 1e6, 1),
            "records_per_s": int(n_records * n_queries / scan_s),
        }

    out = {
        "quick": bool(quick),
        "backend": backend,
        "device": jax.devices()[0].platform,
        "interpret": backend == "pallas_interpret",
        "n_records": int(n_records),
        "n_segments": int(n_segments),
        "n_queries": n_queries,
        "n_slots": len(dev.cache.slots),
        "numpy": side(numpy_s),
        "host_skipping": side(host_s),
        "device_batched": side(device_s),
        "device_sequential": side(seq8_s / 8 * n_queries),
        "speedup": round(numpy_s / device_s, 2),
        "batch8_speedup": round(seq8_s / batch8_s, 2),
        "counts_match": bool(counts_match),
        "uploads_steady": int(uploads_steady),
        "upload_bytes_warm": int(dev.cache.upload_bytes),
        "roofline": {
            "device_flops": est.flops_global,
            "device_bytes": est.hbm_bytes_global,
            "compute_s": roof.compute_s,
            "memory_s": roof.memory_s,
            "step_time_s": roof.step_time_s,
            "measured_s": round(launch_s, 6),
            "dominant": roof.dominant,
            "shape": shape,
        },
        "roofline_frac": round(roofline_frac, 6),
    }
    print(f"[device] {n_records} records, {n_segments} segments "
          f"({len(dev.cache.slots)} device-resident), {n_queries} queries, "
          f"backend={backend}")
    print(f"[device] numpy reference{numpy_s * 1e3:9.2f} ms/batch; host "
          f"skipping scanner {host_s * 1e3:.2f} ms/batch (context)")
    print(f"[device] device fused   {device_s * 1e3:9.2f} ms/batch "
          f"(x{out['speedup']}, counts_match={counts_match}, "
          f"steady uploads={uploads_steady})")
    print(f"[device] batch-of-8     {batch8_s * 1e3:9.2f} ms vs sequential "
          f"{seq8_s * 1e3:9.2f} ms (x{out['batch8_speedup']})")
    print(f"[device] launch {launch_s * 1e6:9.1f} us measured; v5e "
          f"{roof.dominant}-bound {roof.step_time_s * 1e6:.1f} us "
          f"-> roofline_frac {roofline_frac:.4f}")
    return out


if __name__ == "__main__":
    import os

    os.makedirs("artifacts", exist_ok=True)
    out = run()
    with open("artifacts/bench_device.json", "w") as f:
        json.dump(out, f, indent=1)
