"""Host multi-query batcher + result cache vs sequential scans (§16).

Measures what the host half of the multi-query plane buys on the
workload shape CIAO's premise predicts (paper §V: a recurring predicate
set amortized across the whole workload).  A mixed-epoch / mixed-tier
ycsb store is scanned by an 8-query "analytics panel": every query
conjoins one of four recurring wide slice clauses with a shared ad-hoc
AUDIT clause whose operand is non-lowerable (``EXACT`` on an int — the
per-row parsed-record fallback, the expensive residual read).  The
panel's audit value is DISTINCT on every measured pass, so no memoized
clause mask or cached result ever helps either side: the measured gap
is purely the batcher's structural sharing — the audit clause's parse
set resolves ONCE over the union of the panel's narrowed candidates,
where the sequential scanner re-parses it per query.

On top, the :class:`~repro.core.batch_scan.ResultCache` is measured on
the OTHER recurring extreme: the identical panel re-issued verbatim,
answered from epoch/version-validated cache entries without touching a
segment.  Claim gates (``bench_schema.validate_batch``):

  * per-query counts BIT-IDENTICAL to the sequential
    ``DataSkippingScanner`` oracle AND the row-at-a-time
    ``matches_exact`` oracle, full accounting surface included;
  * batch-of-8 >= 2x over 8 sequential scans at full size (>= 0.8x for
    reduced-size ``--quick``/CI smoke runs, which gate against collapse
    only — tiny stores leave little parse work to share);
  * warm-cache repeats >= 5x over the uncached batch (>= 1.5x quick).

    PYTHONPATH=src python -m benchmarks.bench_batch
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.core.batch_scan import ResultCache, ScanBatcher
from repro.core.client import NumpyEngine, encode_chunk
from repro.core.predicates import Kind, Query, SimplePredicate, clause
from repro.core.server import (
    CiaoStore, DataSkippingScanner, PlanFamily, PushdownPlan, evolve_family,
)
from repro.core.workload import estimate_selectivities
from repro.data.datasets import generate_records, predicate_pool

AUDIT_KEY = "linear_score"
PANEL_SIZE = 8


def _build(recs, fam0, fam1, chunk_records: int, segment_capacity: int):
    store = CiaoStore(fam0, segment_capacity=segment_capacity)
    eng = NumpyEngine()

    def ingest(lo, hi, epoch):
        fam = store.family
        for i, start in enumerate(range(lo, hi, chunk_records)):
            tier = i % fam.n_tiers
            chunk = encode_chunk(recs[start: start + chunk_records])
            bv = eng.eval_fused_prefix(chunk, fam.plan.clauses,
                                       fam.tier_sizes[tier])
            store.ingest_chunk(chunk, bv, epoch=epoch, tier=tier)

    half = (len(recs) // 2) // chunk_records * chunk_records
    ingest(0, half, epoch=0)
    store.advance_epoch(fam1)
    ingest(half, len(recs), epoch=1)
    # pre-promote: both measured paths scan the identical row population
    store.jit_load_raw()
    return store


def _panel(slices, audit_value: int) -> list[Query]:
    """8 recurring slice queries sharing one ad-hoc audit clause.

    The audit term is ``EXACT`` with an int operand — deliberately
    non-lowerable (``core.predicates.lowerable``), forcing the per-row
    parsed-record fallback the batcher exists to share."""
    audit = clause(SimplePredicate(Kind.EXACT, AUDIT_KEY, int(audit_value)))
    return [Query((slices[i % len(slices)], audit))
            for i in range(PANEL_SIZE)]


def _accounting(r) -> tuple:
    return (r.count, r.rows_scanned, r.rows_skipped, r.raw_parsed,
            r.segments_pruned, r.segments_scanned, r.shards_pruned,
            r.used_skipping,
            tuple(sorted(
                (k, (g.count, g.rows_scanned, g.rows_skipped, g.raw_parsed,
                     g.segments_pruned))
                for k, g in r.groups.items())))


def run(n_records: int = 24576, chunk_records: int = 512,
        segment_capacity: int = 256, repeats: int = 3,
        quick: bool | None = None) -> dict:
    quick = (n_records <= 8192) if quick is None else quick
    recs = generate_records("ycsb", n_records, seed=7)
    objs = [json.loads(r) for r in recs]
    pool = predicate_pool("ycsb")
    sel = estimate_selectivities(pool, recs[:300])
    ranked = sorted(pool, key=lambda c: abs(sel[c] - 0.2))
    fam0 = PlanFamily(plan=PushdownPlan(clauses=ranked[:8]),
                      tier_sizes=(2, 4, 8))
    fam1 = evolve_family(fam0, ranked[:4] + ranked[8:12], (2, 4, 8))
    # the four recurring slice clauses: widest selectivity, so the
    # panel's candidate sets overlap — the regime where sharing the
    # audit clause's parse set actually amortizes
    slices = sorted(pool, key=lambda c: -sel[c])[:4]
    store = _build(recs, fam0, fam1, chunk_records, segment_capacity)
    n_segments = len(store.blocks) + len(store.jit_blocks)

    host = DataSkippingScanner(store, log_queries=False)
    batcher = ScanBatcher(store, log_queries=False)

    # counts + accounting gate first (untimed): batch vs the sequential
    # scanner vs the row-at-a-time exact oracle, on one fixed panel
    gate_panel = _panel(slices, audit_value=42)
    got = batcher.scan_batch(gate_panel)
    counts_match = accounting_match = True
    for q, r in zip(gate_panel, got):
        h = host.scan(q)
        exact = sum(1 for o in objs if q.matches_exact(o))
        counts_match &= (r.count == h.count == exact)
        accounting_match &= (_accounting(r) == _accounting(h))

    # timed: DISTINCT audit values per pass — no memo or cache can help,
    # both sides pay the full parse cost of an ad-hoc panel
    seq_s = np.inf
    for k in range(repeats):
        panel = _panel(slices, audit_value=100 + k)
        t0 = time.perf_counter()
        for q in panel:
            host.scan(q)
        seq_s = min(seq_s, time.perf_counter() - t0)
    batch_s = np.inf
    for k in range(repeats):
        panel = _panel(slices, audit_value=200 + k)
        t0 = time.perf_counter()
        batcher.scan_batch(panel)
        batch_s = min(batch_s, time.perf_counter() - t0)
    speedup = seq_s / batch_s

    # warm cache: the identical panel re-issued verbatim
    cache = ResultCache()
    cached_batcher = ScanBatcher(store, cache=cache, log_queries=False)
    warm_panel = _panel(slices, audit_value=300)
    cold_res = cached_batcher.scan_batch(warm_panel)     # fills the cache
    warm_s = np.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        warm_res = cached_batcher.scan_batch(warm_panel)
        warm_s = min(warm_s, time.perf_counter() - t0)
    uncached_s = np.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        batcher.scan_batch(warm_panel)
        uncached_s = min(uncached_s, time.perf_counter() - t0)
    cache_speedup = uncached_s / warm_s
    for rc, rw in zip(cold_res, warm_res):
        counts_match &= (rc.count == rw.count)
        accounting_match &= (_accounting(rc) == _accounting(rw))

    out = {
        "quick": bool(quick),
        "n_records": int(n_records),
        "n_segments": int(n_segments),
        "n_queries": PANEL_SIZE,
        "n_slices": len(slices),
        "audit_key": AUDIT_KEY,
        "sequential": {
            "scan_s": round(float(seq_s), 6),
            "us_per_query": round(seq_s / PANEL_SIZE * 1e6, 1),
        },
        "batched": {
            "scan_s": round(float(batch_s), 6),
            "us_per_query": round(batch_s / PANEL_SIZE * 1e6, 1),
        },
        "speedup": round(float(speedup), 2),
        "cache": {
            "warm_scan_s": round(float(warm_s), 6),
            "uncached_scan_s": round(float(uncached_s), 6),
            "speedup": round(float(cache_speedup), 2),
            "hits": int(cache.hits),
            "misses": int(cache.misses),
            "hit_rate": round(float(cache.hit_rate), 4),
        },
        "cache_speedup": round(float(cache_speedup), 2),
        "counts_match": bool(counts_match),
        "accounting_match": bool(accounting_match),
    }
    print(f"[batch] {n_records} records, {n_segments} segments, "
          f"panel of {PANEL_SIZE} ({len(slices)} recurring slices + "
          f"shared ad-hoc audit on {AUDIT_KEY})")
    print(f"[batch] sequential {seq_s * 1e3:9.2f} ms/panel, "
          f"batched {batch_s * 1e3:9.2f} ms/panel: x{out['speedup']}")
    print(f"[batch] warm cache {warm_s * 1e3:9.3f} ms/panel "
          f"(uncached {uncached_s * 1e3:.2f} ms): x{out['cache_speedup']}, "
          f"hit_rate {out['cache']['hit_rate']:.0%}")
    print(f"[batch] counts_match={out['counts_match']} "
          f"accounting_match={out['accounting_match']}")
    return out


if __name__ == "__main__":
    import os

    os.makedirs("artifacts", exist_ok=True)
    out = run()
    with open("artifacts/bench_batch.json", "w") as f:
        json.dump(out, f, indent=1)
