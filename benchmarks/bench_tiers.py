"""Heterogeneous-fleet benchmark: tiered allocation vs uniform budgets.

The paper's §VI trade-off — "different budgets for different clients" —
measured end to end on a fleet of 1 fast, 4 medium and 8 slow clients
(speed = relative records/sec; measured eval wall-clock is divided by
speed, so a slow device also *evaluates* slower).  One ``PlanFamily`` of
nested budget tiers is solved with a single CELF run; three policies
split the SAME global client-cost budget (fleet-record-weighted average
µs/record):

  * ``tiered``      — ``FleetTierAllocator`` (greedy multiple-choice
    knapsack over per-client cost scales): cheap/fast clients climb
    tiers while slow clients run a short prefix.  The policy comparison
    runs on frozen ``1/speed`` cost-scale priors so the allocation is
    deterministic; cost-drift re-tiering is then demonstrated after the
    measured phase by degrading one client 5x and letting the next
    cost-report check re-solve (``retier_demo`` in the artifact);
  * ``uniform_min`` — the largest SINGLE tier the whole fleet can run
    within the budget (slow clients' cost inflation caps everyone at the
    floor tier);
  * ``uniform_max`` — every client runs the top tier, budget be damned
    (the "just push everything" baseline; reported as infeasible).

The query batch is the workload's held-out tail restricted to queries the
MID tier covers (steady-state coverage is the replan control plane's job
— bench_replan measures drift; this benchmark isolates allocation).  The
floor tier does NOT cover all of them, which is exactly the trade-off:
uniform-min's whole store sits at floor coverage, so the first uncovered
query JIT-promotes every remainder (effective loading ratio -> ~1, scans
crawl through promoted rows); uniform-max avoids that by burning slow
clients (full-plan eval at 4x time inflation dominates loading) and by
loading the fat high-selectivity tail of the clause set on every chunk.
The tiered allocator pays floor coverage only for the slow fifth of the
records and keeps the fleet inside the budget.

Metrics per policy (ingest + the query batch):

  * ``eff_loading_ratio`` — (loaded + JIT-loaded) / ingested records;
  * ``loading_s``         — max per-client eval wall-clock (the fleet
    works in parallel; slow-device inflation included) + server load;
  * ``scan_s``            — wall-clock of the query batch;
  * ``end_to_end_s``      — loading_s + scan_s;
  * ``budget_spent_us``   — modeled fleet spend with live cost scales,
    sum_j weight_j * scale_j * tier_cost[t_j].

``bench_schema.validate_tiers`` gates the artifact: tiered must beat
BOTH baselines on eff_loading_ratio and end_to_end_s, within budget.

    PYTHONPATH=src python -m benchmarks.bench_tiers
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.core.client import NumpyEngine, encode_chunk
from repro.core.cost_model import CostModel, calibrate_scaled
from repro.core.planner import build_plan_family
from repro.core.predicates import Query
from repro.core.server import CiaoStore, DataSkippingScanner, PlanFamily
from repro.core.workload import Workload, generate_workload
from repro.data.datasets import generate_records, predicate_pool
from repro.data.pipeline import ClientShard, FleetTierAllocator, IngestCoordinator

FLEET = ((4.0, 1), (1.0, 4), (0.25, 8))   # (speed, count): fast/medium/slow


def _fleet_shards(dataset: str, plan, chunk_records: int,
                  cost_ewma_alpha: float = 0.3) -> list[ClientShard]:
    eng = NumpyEngine()
    shards = []
    for speed, count in FLEET:
        for _ in range(count):
            shards.append(ClientShard(dataset, len(shards), eng, plan,
                                      chunk_records=chunk_records,
                                      speed=speed,
                                      cost_ewma_alpha=cost_ewma_alpha))
    return shards


def _weights(shards: list[ClientShard]) -> np.ndarray:
    rates = np.array([s.speed * s.chunk_records for s in shards])
    return rates / rates.sum()


def _modeled_spend(family: PlanFamily, shards) -> float:
    w = _weights(shards)
    return float(sum(
        wi * s.cost_scale * family.tier_costs[s.tier]
        for wi, s in zip(w, shards)))


def _measured_tier_costs(family: PlanFamily, sample: list[bytes],
                         repeats: int = 3) -> tuple[float, ...]:
    """Per-tier measured µs/record on THIS hardware (paper §V-D spirit).

    The analytic cost model prices clauses additively, but a vectorized
    engine amortizes per-chunk overheads — the floor tier's real cost is
    NOT 1/20th of the top tier's.  Re-pricing the family's tiers from
    timed probes keeps the allocator's budget arithmetic and every
    shard's cost-scale EWMA (measured / modeled) anchored to the same
    scale, so allocations don't drift with the machine the benchmark
    happens to run on.
    """
    eng = NumpyEngine()
    chunk = encode_chunk(sample)
    costs = []
    for s in family.tier_sizes:
        if s == 0:
            costs.append(0.0)
            continue
        cl = family.plan.clauses[:s]
        eng.eval_fused(chunk, cl)   # warm any caches
        best = np.inf
        for _ in range(repeats):
            t0 = time.perf_counter()
            eng.eval_fused(chunk, cl)
            best = min(best, time.perf_counter() - t0)
        costs.append(best / max(chunk.n_records, 1) * 1e6)
    return tuple(float(c) for c in np.maximum.accumulate(costs))


def _uniform_min_tier(family: PlanFamily, shards, budget_us: float) -> int:
    """Largest single tier the whole fleet can run within the budget."""
    w = _weights(shards)
    fleet_scale = float(sum(wi * s.cost_scale for wi, s in zip(w, shards)))
    t_min = 0
    for t, cost in enumerate(family.tier_costs):
        if fleet_scale * cost <= budget_us + 1e-9:
            t_min = t
    return t_min


def _scenario(
    mode: str, *, dataset: str, family: PlanFamily, budget_us: float,
    exec_queries: list[Query], chunk_records: int, chunks_per_client: int,
) -> dict:
    store = CiaoStore(family)
    # frozen cost-scale priors (1/speed): the POLICY comparison must be
    # deterministic, not a function of transient host timing noise — live
    # EWMA re-tiering is exercised by the drift demo below and by
    # tests/test_tiers.py::test_retier_on_cost_drift
    shards = _fleet_shards(dataset, family.plan, chunk_records,
                           cost_ewma_alpha=0.0)
    allocator = None
    if mode == "tiered":
        allocator = FleetTierAllocator(family, budget_us,
                                       retier_every_records=8 * chunk_records)
    elif mode == "uniform_min":
        t = _uniform_min_tier(family, shards, budget_us)
        for s in shards:
            s.set_family(family, t)
    elif mode == "uniform_max":
        for s in shards:
            s.set_family(family, family.top_tier)
    else:
        raise ValueError(mode)
    # work stealing ON: idle fast clients claim pending slots, so record
    # volume lands rate-proportionally (the allocator's weight model) and
    # a stolen chunk ships the STEALING client's tier coverage
    coord = IngestCoordinator(shards, store, allocator=allocator)
    coord.run(chunks_per_client=chunks_per_client)

    scanner = DataSkippingScanner(store)
    t0 = time.perf_counter()
    scanned = skipped = matches = 0
    for q in exec_queries:
        r = scanner.scan(q)
        scanned += r.rows_scanned
        skipped += r.rows_skipped
        matches += r.count
    scan_s = time.perf_counter() - t0

    stats = store.stats
    w = _weights(shards)
    spent_us = _modeled_spend(family, shards)
    measured_us = float(sum(
        wi * s.observed_us_per_record() for wi, s in zip(w, shards)))
    loading_s = max(s.eval_time_s for s in shards) + stats.load_time_s
    assignment = [s.tier for s in shards]
    retier_demo = None
    if allocator is not None:
        # cost-drift re-tiering demo (after the measured phase so metrics
        # stay comparable): the busiest client degrades 5x; the next
        # cost-report check must re-solve and demote it
        before = [s.tier for s in shards]
        shards[0].cost_scale *= 5.0
        allocator.on_records(allocator.retier_every_records, shards)
        retier_demo = {"before": before, "after": [s.tier for s in shards],
                       "degraded_client": 0}
    return {
        "mode": mode,
        "tier_assignment": assignment,
        "budget_spent_us": round(spent_us, 4),
        "measured_us_per_record": round(measured_us, 4),
        "budget_ok": bool(spent_us <= budget_us * 1.10),  # EWMA drift slack
        "n_records": stats.n_records,
        "loading_ratio_ingest": round(stats.loading_ratio, 4),
        "eff_loading_ratio": round(
            (stats.n_loaded + stats.n_jit_loaded) / stats.n_records, 4),
        "loading_s": round(loading_s, 4),
        "scan_s": round(scan_s, 4),
        "end_to_end_s": round(loading_s + scan_s, 4),
        "rows_scanned": scanned,
        "skip_frac": round(skipped / max(scanned + skipped, 1), 4),
        "matches": matches,
        "retier_events": allocator.retier_events if allocator else 0,
        "retier_demo": retier_demo,
        "group_records": {
            f"{e}:{t}": n for (e, t), n in sorted(store.group_records.items())
        },
    }


def run(
    dataset: str = "ycsb", *, n_records: int = 13312,
    n_queries: int = 300, n_exec_queries: int = 120, seed: int = 3,
) -> dict:
    pool = predicate_pool(dataset)
    rng = np.random.default_rng(seed)
    # zipf 1.1: hot clauses dominate but no single clause covers every
    # query — the floor tier genuinely under-covers, the mid tier doesn't
    wl = generate_workload(pool, n_queries=n_queries, distribution="zipf",
                           zipf_a=1.1, rng=rng, name="fleet-queries")
    sample = generate_records(dataset, 400, seed=17)
    cost_model = calibrate_scaled(sample, pool[:4], NumpyEngine(),
                                  base=CostModel())
    sel = {c: 0.2 for c in pool}
    costs = sorted(cost_model.clause_cost(c, sel[c]) for c in pool)
    med = costs[len(costs) // 2]
    # T0 ~ the hottest 1-2 clauses, T1 ~ a lean hot prefix, T2 ~ deep
    # (the greedy keeps adding positive-gain clauses, including the fat
    # high-selectivity band — real benefit for their queries, real load)
    tier_budgets = [1.5 * med, 3.0 * med, 40.0 * med]
    rep = build_plan_family(Workload(wl.name, wl.queries[:-n_exec_queries]),
                            sample, tier_budgets_us=tier_budgets,
                            cost_model=cost_model)
    # re-price tiers from timed probes so budget arithmetic and the
    # shards' cost-scale feedback share one measured scale
    family = PlanFamily(
        plan=rep.family.plan, tier_sizes=rep.family.tier_sizes,
        budgets=rep.family.budgets,
        tier_costs=_measured_tier_costs(rep.family, sample),
        tier_values=rep.family.tier_values,
    )
    # global budget: the measured cost of {fast/medium -> mid tier,
    # slow -> floor} with the 1/speed priors, +2% headroom.  It sits
    # strictly between uniform-floor and uniform-mid affordability
    # (0.8*c0 + 0.55*c1 < 1.3*c1 whenever c0 < c1), so the uniform
    # baseline is capped at the floor tier while the allocator spreads
    # the same spend across the fleet.
    probe = _fleet_shards(dataset, family.plan, 1)
    w = _weights(probe)
    target = {4.0: 1, 1.0: 1, 0.25: 0}
    budget_us = 1.02 * float(sum(
        wi * s.cost_scale * family.tier_costs[target[s.speed]]
        for wi, s in zip(w, probe)))

    # the held-out query batch, restricted to mid-tier-covered queries
    t1 = set(family.tier_clauses(1))
    t0 = set(family.tier_clauses(0))
    tail = wl.queries[-n_exec_queries:]
    exec_queries = [q for q in tail if any(c in t1 for c in q.clauses)]
    n_floor_uncovered = sum(
        1 for q in exec_queries if not any(c in t0 for c in q.clauses))
    if not n_floor_uncovered:
        raise RuntimeError(
            "degenerate workload: the floor tier covers every exec query "
            "(no allocation trade-off to measure) — lower zipf_a")

    chunk_records = 256
    n_shards = sum(c for _, c in FLEET)
    chunks_per_client = max(n_records // (n_shards * chunk_records), 1)

    common = dict(dataset=dataset, family=family, budget_us=budget_us,
                  exec_queries=exec_queries, chunk_records=chunk_records,
                  chunks_per_client=chunks_per_client)
    out = {
        "global_budget_us": round(budget_us, 4),
        "fleet": [{"speed": s, "count": c} for s, c in FLEET],
        "tiers": {
            "sizes": list(family.tier_sizes),
            "budgets": [round(b, 4) for b in family.budgets],
            "costs": [round(c, 4) for c in family.tier_costs],
            "values": [round(v, 4) for v in family.tier_values],
        },
        "n_exec_queries": len(exec_queries),
        "n_floor_uncovered_queries": n_floor_uncovered,
        "tiered": _scenario("tiered", **common),
        "uniform_min": _scenario("uniform_min", **common),
        "uniform_max": _scenario("uniform_max", **common),
    }
    tiered = out["tiered"]
    out["wins"] = {
        "eff_loading_ratio": bool(
            tiered["eff_loading_ratio"]
            < min(out["uniform_min"]["eff_loading_ratio"],
                  out["uniform_max"]["eff_loading_ratio"])),
        "end_to_end_s": bool(
            tiered["end_to_end_s"]
            < min(out["uniform_min"]["end_to_end_s"],
                  out["uniform_max"]["end_to_end_s"])),
    }
    for mode in ("tiered", "uniform_min", "uniform_max"):
        r = out[mode]
        print(f"[tiers] {mode:>11}: tiers={r['tier_assignment']} "
              f"spent {r['budget_spent_us']:.2f}/{budget_us:.2f}us "
              f"eff_ratio {r['eff_loading_ratio']:.2%} "
              f"load {r['loading_s']:.2f}s scan {r['scan_s']:.2f}s "
              f"e2e {r['end_to_end_s']:.2f}s skip {r['skip_frac']:.0%}")
    print(f"[tiers] wins: {out['wins']} "
          f"(retier_events={tiered['retier_events']}, "
          f"{n_floor_uncovered}/{len(exec_queries)} exec queries uncovered "
          f"at the floor tier)")
    return out


if __name__ == "__main__":
    import os

    os.makedirs("artifacts", exist_ok=True)
    out = run()
    with open("artifacts/bench_tiers.json", "w") as f:
        json.dump(out, f, indent=1)
