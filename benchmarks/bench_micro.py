"""Paper Figs 6-12: micro-benchmarks.

  * Fig 6: fraction of queries with lower query time due to skipping
    (YCSB, workload C, varied budgets; paper: 37-68%).
  * Figs 7/8: selectivity sensitivity (winlog; sel 0.01/0.15/0.35;
    loading ratio tracks union selectivity; query time drops with sel).
  * Figs 9/10: overlap sensitivity (1/2/4 predicates per query).
  * Figs 11/12: skewness sensitivity (skew factor 0 / 0.5 / 2.0).
"""
from __future__ import annotations

import json

import numpy as np

from repro.core.client import NumpyEngine, encode_chunk
from repro.core.predicates import Query
from repro.core.server import (
    CiaoStore, DataSkippingScanner, FullScanBaseline, PushdownPlan,
)
from repro.core.workload import Workload, estimate_selectivities
from repro.data.datasets import generate_records, predicate_pool

from .common import make_workload


def _ingest(records, plan, chunk_size=1000):
    eng = NumpyEngine()
    store = CiaoStore(plan)
    base = FullScanBaseline()
    for i in range(0, len(records), chunk_size):
        chunk = encode_chunk(records[i: i + chunk_size])
        bv = (eng.eval_packed(chunk, plan.clauses) if plan.n
              else np.zeros((0, 0), np.uint32))
        store.ingest_chunk(chunk, bv)
        base.ingest_chunk(chunk)
    return store, base


# ---------------------------------------------------------------------------
# Fig 6: fraction of queries that benefit
# ---------------------------------------------------------------------------

def query_fraction(n_records=8000, budgets=(0.25, 0.5, 1.0, 2.0)) -> list[dict]:
    from repro.core.planner import build_plan

    records = generate_records("ycsb", n_records, seed=23)
    wl = make_workload("ycsb", "C", n_queries=60, seed=5)
    rows = []
    for budget in budgets:
        rep = build_plan(wl, records[:500], budget_us=budget)
        store, base = _ingest(records, rep.plan)
        scanner = DataSkippingScanner(store)
        store.jit_load_raw()  # exclude one-time JIT from per-query timing
        n_better = 0
        for q in wl.queries:
            t_ciao = min(scanner.scan(q).time_s for _ in range(2))
            t_base = min(base.scan(q).time_s for _ in range(2))
            if t_ciao < t_base:
                n_better += 1
        frac = n_better / len(wl.queries)
        rows.append({"budget_us": budget, "n_pushed": rep.plan.n,
                     "fraction_improved": round(frac, 3)})
        print(f"[fig6] budget={budget}: {frac:.0%} of queries improved "
              f"(paper: 37-68%)")
    return rows


# ---------------------------------------------------------------------------
# Figs 7/8: selectivity
# ---------------------------------------------------------------------------

def _winlog_clauses_by_selectivity(records, target_sel):
    pool = predicate_pool("winlog")
    sel = estimate_selectivities(pool, records[:1000])
    ranked = sorted(pool, key=lambda c: abs(sel[c] - target_sel))
    return ranked, sel


def selectivity_sweep(n_records=8000) -> list[dict]:
    records = generate_records("winlog", n_records, seed=29)
    rows = []
    for target in (0.01, 0.15, 0.35):
        ranked, sel = _winlog_clauses_by_selectivity(records, target)
        pushed = ranked[:2]                      # paper: push 2 predicates
        plan = PushdownPlan(clauses=pushed)
        store, base = _ingest(records, plan)
        q = Query((pushed[0],))
        scanner = DataSkippingScanner(store)
        t_q = min(scanner.scan(q).time_s for _ in range(3))
        t_b = min(base.scan(q).time_s for _ in range(3))
        rows.append({
            "target_sel": target,
            "actual_sel": round(float(np.mean([sel[c] for c in pushed])), 4),
            "loading_ratio": round(store.stats.loading_ratio, 4),
            "load_s": round(store.stats.load_time_s, 4),
            "base_load_s": round(base.stats.load_time_s, 4),
            "query_speedup": round(t_b / max(t_q, 1e-9), 2),
        })
        print(f"[fig7/8] sel~{target}: ratio={rows[-1]['loading_ratio']} "
              f"query x{rows[-1]['query_speedup']}")
    return rows


# ---------------------------------------------------------------------------
# Figs 9/10: predicate overlap
# ---------------------------------------------------------------------------

def overlap_sweep(n_records=8000) -> list[dict]:
    records = generate_records("winlog", n_records, seed=31)
    ranked, sel = _winlog_clauses_by_selectivity(records, 0.15)
    pushed = ranked[:2]
    rows = []
    for name, preds_per_query in (("L_ol", 1), ("M_ol", 2), ("H_ol", 4)):
        # queries that include the pushed predicates `preds_per_query` deep
        queries = []
        for qi in range(5):
            cls = tuple(ranked[qi: qi + preds_per_query]) if preds_per_query > 1 \
                else (ranked[2 + qi],)
            if preds_per_query >= 2:
                cls = tuple(pushed[:preds_per_query]) if preds_per_query <= 2 \
                    else tuple(pushed) + tuple(ranked[2 + qi: 2 + qi + preds_per_query - 2])
            queries.append(Query(cls))
        plan = PushdownPlan(clauses=pushed)
        store, base = _ingest(records, plan)
        scanner = DataSkippingScanner(store)
        covered = sum(1 for q in queries if plan.pushed_in(q))
        t_q = sum(scanner.scan(q).time_s for q in queries)
        t_b = sum(base.scan(q).time_s for q in queries)
        rows.append({
            "workload": name,
            "covered_queries": covered,
            "loading_ratio": round(store.stats.loading_ratio, 4),
            "query_speedup": round(t_b / max(t_q, 1e-9), 2),
        })
        print(f"[fig9/10] {name}: covered={covered}/5 "
              f"query x{rows[-1]['query_speedup']}")
    return rows


# ---------------------------------------------------------------------------
# Figs 11/12: skewness
# ---------------------------------------------------------------------------

def skewness_sweep(n_records=8000) -> list[dict]:
    records = generate_records("winlog", n_records, seed=37)
    ranked, sel = _winlog_clauses_by_selectivity(records, 0.1)
    hot = ranked[0]
    rows = []
    # 5 queries x 2 predicates; vary how many queries contain the hot clause
    for name, n_covered in (("L_sk", 1), ("M_sk", 3), ("H_sk", 5)):
        queries = []
        for qi in range(5):
            if qi < n_covered:
                queries.append(Query((hot, ranked[3 + qi])))
            else:
                queries.append(Query((ranked[3 + qi], ranked[9 + qi])))
        wl = Workload(name=name, queries=queries)
        plan = PushdownPlan(clauses=[hot])       # paper: push ONE predicate
        store, base = _ingest(records, plan)
        scanner = DataSkippingScanner(store)
        t_q = sum(scanner.scan(q).time_s for q in queries)
        t_b = sum(base.scan(q).time_s for q in queries)
        rows.append({
            "workload": name,
            "skewness_factor": round(wl.skewness_factor(), 3),
            "loading_ratio": round(store.stats.loading_ratio, 4),
            "load_s": round(store.stats.load_time_s, 4),
            "base_load_s": round(base.stats.load_time_s, 4),
            "query_speedup": round(t_b / max(t_q, 1e-9), 2),
        })
        print(f"[fig11/12] {name}: skew={rows[-1]['skewness_factor']} "
              f"ratio={rows[-1]['loading_ratio']} query x{rows[-1]['query_speedup']}")
    return rows


# ---------------------------------------------------------------------------
# pattern-compilation memoization (client hot path)
# ---------------------------------------------------------------------------

def patterns_memo(n_records=2000, repeats=3) -> dict:
    """`SimplePredicate.patterns()` must compile once per instance.

    The client engines call it per (record, term); before memoization
    each call re-encoded the pattern bytes.  Asserts the memo (identity
    across calls — deterministic) and reports the raw-match throughput.
    """
    import time

    from repro.core.predicates import between, in_list, key_value, substring

    preds = [substring("f1", "needle"), key_value("f2", 42),
             between("f3", 10, 20), in_list("f4", ["a", "b", "c"])]
    for p in preds:
        assert p.patterns() is p.patterns(), \
            f"patterns() not memoized for {p.describe()}"
    records = [enc for enc in (
        json.dumps({"f1": f"x{i}needle", "f2": i % 100,
                    "f3": i % 37, "f4": "abc"[i % 3]}).encode()
        for i in range(n_records))]
    best = np.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        hits = sum(1 for r in records for p in preds if p.matches_raw(r))
        best = min(best, time.perf_counter() - t0)
    row = {"n_records": n_records, "n_terms": len(preds),
           "memoized": True, "hits": int(hits),
           "match_us_per_record": round(best / n_records * 1e6, 3)}
    print(f"[patterns] memoized, raw match "
          f"{row['match_us_per_record']}us/record over {len(preds)} terms")
    return row


def main():
    out = {
        "fig6_query_fraction": query_fraction(),
        "fig7_8_selectivity": selectivity_sweep(),
        "fig9_10_overlap": overlap_sweep(),
        "fig11_12_skewness": skewness_sweep(),
        "patterns_memo": patterns_memo(),
    }
    with open("artifacts/bench_micro.json", "w") as f:
        json.dump(out, f, indent=1)
    return out


if __name__ == "__main__":
    main()
