"""Selection-algorithm benchmarks (framework table): eager vs CELF scaling,
combined-greedy quality vs brute-force OPT on small instances."""
from __future__ import annotations

import json
import time

import numpy as np

from repro.core.predicates import Query, clause, key_value
from repro.core.selection import (
    SelectionProblem, brute_force, celf_greedy, combined_greedy,
    greedy,
)


def _problem(rng, n_preds, n_queries, budget):
    pool = [clause(key_value(f"k{i}", i)) for i in range(n_preds)]
    sel = {c: float(rng.uniform(0.01, 0.9)) for c in pool}
    cost = {c: float(rng.uniform(0.1, 1.0)) for c in pool}
    queries = [
        Query(tuple(pool[i] for i in rng.choice(n_preds, size=rng.integers(1, 6),
                                                replace=False)))
        for _ in range(n_queries)
    ]
    return SelectionProblem(tuple(queries), sel, cost, budget)


def scaling(sizes=((100, 200), (400, 800), (1000, 2000), (2000, 4000))):
    rng = np.random.default_rng(0)
    rows = []
    for n_preds, n_queries in sizes:
        p = _problem(rng, n_preds, n_queries, budget=10.0)
        t0 = time.perf_counter()
        e = greedy(p, ratio=True)
        t_eager = time.perf_counter() - t0
        t0 = time.perf_counter()
        l = celf_greedy(p, ratio=True)
        t_celf = time.perf_counter() - t0
        assert abs(e.objective - l.objective) < 1e-9
        rows.append({
            "n_preds": n_preds, "n_queries": n_queries,
            "eager_s": round(t_eager, 4), "celf_s": round(t_celf, 4),
            "eager_evals": e.evaluations, "celf_evals": l.evaluations,
            "speedup": round(t_eager / max(t_celf, 1e-9), 2),
        })
        print(f"[selection] P={n_preds} Q={n_queries}: eager {t_eager:.3f}s "
              f"({e.evaluations} evals) vs CELF {t_celf:.3f}s "
              f"({l.evaluations} evals) -> x{rows[-1]['speedup']}")
    return rows


def quality(n_trials=20):
    rng = np.random.default_rng(1)
    worst = 1.0
    for _ in range(n_trials):
        p = _problem(rng, 10, 8, budget=float(rng.uniform(0.5, 3.0)))
        opt = brute_force(p)
        res = combined_greedy(p)
        if opt.objective > 0:
            worst = min(worst, res.objective / opt.objective)
    print(f"[selection] combined-greedy worst-case f/OPT over {n_trials} "
          f"trials: {worst:.3f} (guarantee: 0.316)")
    return {"worst_ratio": round(worst, 4), "n_trials": n_trials}


def main():
    out = {"scaling": scaling(), "quality": quality()}
    with open("artifacts/bench_selection.json", "w") as f:
        json.dump(out, f, indent=1)
    return out


if __name__ == "__main__":
    main()
