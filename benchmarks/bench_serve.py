"""Async serving plane: concurrent ingest + snapshot scans (§17).

Measures what :class:`~repro.serve.store_engine.CiaoServeEngine` buys
over the architecture it replaces: a serialized ingest-then-scan loop
that cannot answer a single query until the load finishes.  Both sides
run the identical workload — the same pre-encoded chunk stream into a
4-shard store, the same 8-query panel — and the metric is *aggregate
scan throughput*: queries answered per second of total wall-clock.

  * **serialized baseline** — ingest every chunk, THEN scan the panel
    repeatedly on one thread (the panel pass count adapts so the scan
    phase is a meaningful fraction of the ingest time).  Queries served
    during ingest: zero, by construction — that dead window is the cost
    the serving plane exists to delete.
  * **live engine** — a feeder thread streams the same chunks through
    the engine's backpressured write queues while ``query_threads``
    reader threads answer the panel continuously from epoch snapshots
    (mixed ``host`` / ``batch`` modes, no result cache: every count is
    recomputed).  Per-query wall-clock latencies are recorded for the
    percentile gates.

Claim gates (``bench_schema.validate_serve``):

  * every live count is bounded by the ``matches_exact`` oracle, and
    after ``quiesce()`` the panel is BIT-IDENTICAL to it on both the
    host and batch paths (``counts_match``);
  * live p99 scan latency <= 3x the quiesced p99 at the SAME reader
    concurrency (<= 8x for reduced-size ``--quick`` runs);
  * aggregate scan throughput >= 2x the serialized loop at 8 query
    threads (>= 0.5x quick — tiny quick stores leave almost no ingest
    window to overlap, so CI gates against collapse only).

    PYTHONPATH=src python -m benchmarks.bench_serve
"""
from __future__ import annotations

import json
import os
import threading
import time

import numpy as np

from repro.core.batch_scan import ScanBatcher
from repro.core.client import NumpyEngine, encode_chunk
from repro.core.predicates import Query
from repro.core.server import PlanFamily, PushdownPlan
from repro.core.shard import ShardedCiaoStore, ShardedScanner, ShardRouter, \
    choose_routing_key
from repro.data.datasets import generate_records, predicate_pool
from repro.serve.store_engine import CiaoServeEngine

PANEL_SIZE = 8


def _prepare(n_records: int, chunk_records: int):
    """Pre-encode the chunk stream so both sides measure pure store-side
    work (client-side eval is the same constant for either architecture)."""
    recs = generate_records("ycsb", n_records, seed=7)
    objs = [json.loads(r) for r in recs]
    pool = predicate_pool("ycsb")
    # tier 0 has EMPTY coverage: a third of the stream stays raw, so
    # snapshot-local JIT promotion is part of the measured scan path
    fam = PlanFamily(plan=PushdownPlan(clauses=pool[:6]),
                     tier_sizes=(0, 2, 6))
    eng = NumpyEngine()
    chunks = []
    for i, start in enumerate(range(0, n_records, chunk_records)):
        ch = encode_chunk(recs[start:start + chunk_records])
        tier = i % fam.n_tiers
        bv = eng.eval_fused_prefix(ch, fam.plan.clauses,
                                   fam.tier_sizes[tier])
        chunks.append((ch, bv, tier))
    queries = [Query(clauses=(pool[k],)) for k in range(PANEL_SIZE)]
    oracle = [sum(1 for o in objs if q.matches_exact(o)) for q in queries]
    return fam, chunks, queries, oracle


def _mk_store(fam, n_shards: int, segment_capacity: int) -> ShardedCiaoStore:
    router = ShardRouter(n_shards=n_shards, key=choose_routing_key(fam.plan))
    return ShardedCiaoStore(fam, router=router,
                            segment_capacity=segment_capacity)


def _pcts(lat_s: list[float]) -> tuple[float, float]:
    arr = np.asarray(lat_s, dtype=np.float64) * 1e6
    return float(np.percentile(arr, 50)), float(np.percentile(arr, 99))


def run(n_records: int = 24576, chunk_records: int = 512,
        segment_capacity: int = 1024, n_shards: int = 4,
        query_threads: int = 8, quick: bool | None = None) -> dict:
    quick = (n_records <= 8192) if quick is None else quick
    fam, chunks, queries, oracle = _prepare(n_records, chunk_records)
    epoch = fam.plan.epoch

    # process warmup, outside every timed window: the batcher's dedup
    # compiler imports the kernels package (which pulls jax) on first
    # use — a one-time interpreter cost, not a serving-plane cost.
    warm = _mk_store(fam, n_shards, segment_capacity)
    warm.ingest_chunk(*chunks[0][:2], epoch=epoch, tier=chunks[0][2])
    ScanBatcher(warm, log_queries=False, telemetry=False) \
        .scan_batch(queries)
    ShardedScanner(warm, log_queries=False, telemetry=False) \
        .scan(queries[0])
    del warm

    # -- serialized baseline: ingest everything, then scan ----------------
    store_s = _mk_store(fam, n_shards, segment_capacity)
    t0 = time.perf_counter()
    for ch, bv, tier in chunks:
        store_s.ingest_chunk(ch, bv, epoch=epoch, tier=tier)
    ingest_s = time.perf_counter() - t0
    scanner = ShardedScanner(store_s, log_queries=False, telemetry=False)
    serial_lat: list[float] = []

    def panel_pass() -> None:
        for q in queries:
            tq = time.perf_counter()
            scanner.scan(q)
            serial_lat.append(time.perf_counter() - tq)

    panel_pass()                  # cold probe: pays promotion + memos
    panel_pass()                  # warm pass: the steady-state panel cost
    warm_s = sum(serial_lat[PANEL_SIZE:])
    # size the scan phase to ~1/3 of the ingest window (a mixed workload,
    # not a scan microbench) using the WARM cost — the most favorable
    # amortization the serialized architecture can claim for itself
    passes = 2 if warm_s <= 0 else \
        max(2, min(64, int(ingest_s / (3 * warm_s))))
    for _ in range(passes):
        panel_pass()
    total_serial_s = time.perf_counter() - t0
    q_serial = len(serial_lat)
    serial_qps = q_serial / total_serial_s

    # -- live engine: feeder + query_threads readers, no result cache -----
    store_l = _mk_store(fam, n_shards, segment_capacity)
    serve = CiaoServeEngine(store_l, queue_depth=8)
    live_lat_per: list[list[float]] = [[] for _ in range(query_threads)]
    feeder_done = threading.Event()
    bounded = [True]
    errors: list[BaseException] = []

    def feed() -> None:
        try:
            for ch, bv, tier in chunks:
                serve.ingest_chunk(ch, bv, epoch=epoch, tier=tier)
        except BaseException as e:      # pragma: no cover - failure path
            errors.append(e)
        finally:
            feeder_done.set()

    def read(ri: int) -> None:
        lat = live_lat_per[ri]
        try:
            loops = 0
            while True:
                for k, q in enumerate(queries):
                    mode = "batch" if (ri + k) % 2 else "host"
                    tq = time.perf_counter()
                    r = serve.query(q, mode=mode)
                    lat.append(time.perf_counter() - tq)
                    if r.count > oracle[k]:
                        bounded[0] = False
                loops += 1
                if feeder_done.is_set() and loops >= 2:
                    return
        except BaseException as e:      # pragma: no cover - failure path
            errors.append(e)

    t0 = time.perf_counter()
    threads = [threading.Thread(target=feed)] + [
        threading.Thread(target=read, args=(i,))
        for i in range(query_threads)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    serve.quiesce()
    total_live_s = time.perf_counter() - t0
    if errors:
        raise errors[0]
    live_lat = [x for per in live_lat_per for x in per]
    q_live = len(live_lat)
    live_qps = q_live / total_live_s
    live_p50, live_p99 = _pcts(live_lat)

    # -- quiesced reference: same reader concurrency, writes stopped ------
    per_thread = max(2, passes // 2)
    quiesced_per: list[list[float]] = [[] for _ in range(query_threads)]

    def read_quiesced(ri: int) -> None:
        lat = quiesced_per[ri]
        for _ in range(per_thread):
            for k, q in enumerate(queries):
                mode = "batch" if (ri + k) % 2 else "host"
                tq = time.perf_counter()
                serve.query(q, mode=mode)
                lat.append(time.perf_counter() - tq)

    qthreads = [threading.Thread(target=read_quiesced, args=(i,))
                for i in range(query_threads)]
    for t in qthreads:
        t.start()
    for t in qthreads:
        t.join()
    quiesced_lat = [x for per in quiesced_per for x in per]
    q_p50, q_p99 = _pcts(quiesced_lat)
    p99_ratio = live_p99 / q_p99 if q_p99 > 0 else float("inf")

    # -- exactness gate: quiesced counts vs the row-at-a-time oracle ------
    counts_match = True
    for mode in ("host", "batch"):
        got = [serve.query(q, mode=mode).count for q in queries]
        counts_match &= (got == oracle)
    rep = serve.stats_report()
    counts_match &= (rep["engine"]["errors"] == 0)
    counts_match &= (rep["engine"]["drained"] == rep["engine"]["enqueued"])
    serve.close()

    out = {
        "quick": bool(quick),
        "n_records": int(n_records),
        "n_chunks": len(chunks),
        "n_shards": int(n_shards),
        "query_threads": int(query_threads),
        "panel_size": PANEL_SIZE,
        "cpu_count": int(os.cpu_count() or 1),
        "serialized": {
            "ingest_s": round(ingest_s, 6),
            "total_s": round(total_serial_s, 6),
            "queries": int(q_serial),
            "qps": round(serial_qps, 2),
        },
        "live": {
            "total_s": round(total_live_s, 6),
            "queries": int(q_live),
            "qps": round(live_qps, 2),
            "p50_us": round(live_p50, 1),
            "p99_us": round(live_p99, 1),
            "blocked_s": rep["engine"]["blocked_s"],
        },
        "quiesced": {
            "queries": len(quiesced_lat),
            "p50_us": round(q_p50, 1),
            "p99_us": round(q_p99, 1),
        },
        "throughput_speedup": round(live_qps / serial_qps, 2),
        "p99_ratio": round(p99_ratio, 2),
        "counts_match": bool(counts_match),
        "live_counts_bounded": bool(bounded[0]),
    }
    print(f"[serve] {n_records} records / {len(chunks)} chunks into "
          f"{n_shards} shards, panel of {PANEL_SIZE} x "
          f"{query_threads} reader threads (cpu_count="
          f"{out['cpu_count']})")
    print(f"[serve] serialized: ingest {ingest_s:6.2f} s, then "
          f"{q_serial} queries -> {serial_qps:8.1f} qps over "
          f"{total_serial_s:.2f} s")
    print(f"[serve] live:       {q_live} queries DURING ingest -> "
          f"{live_qps:8.1f} qps over {total_live_s:.2f} s: "
          f"x{out['throughput_speedup']}")
    print(f"[serve] p99: live {live_p99:9.1f} us vs quiesced "
          f"{q_p99:9.1f} us = x{out['p99_ratio']} "
          f"(p50 {live_p50:.1f} vs {q_p50:.1f} us)")
    print(f"[serve] counts_match={out['counts_match']} "
          f"live_counts_bounded={out['live_counts_bounded']}")
    return out


if __name__ == "__main__":
    os.makedirs("artifacts", exist_ok=True)
    out = run()
    with open("artifacts/bench_serve.json", "w") as f:
        json.dump(out, f, indent=1)
