"""Paper Figs 3/4/5: loading / prefilter / query time vs budget, 3 datasets
x workloads A/B/C.  Validation targets: paper reports up to 21x loading,
23x query, 19x end-to-end at budget 1.0 µs/record (dataset- and
workload-dependent; the 'easy' workload A benefits most)."""
from __future__ import annotations

import json

from .common import make_workload, run_end_to_end

BUDGETS = (0.25, 0.5, 1.0, 2.0)
DATASETS = ("winlog", "yelp", "ycsb")
WORKLOADS = ("A", "B", "C")


def run(n_records: int = 20000, n_queries_exec: int = 60) -> list[dict]:
    rows = []
    for dataset in DATASETS:
        for wname in WORKLOADS:
            wl = make_workload(dataset, wname)
            for budget in BUDGETS:
                r = run_end_to_end(
                    dataset, wl, budget,
                    n_records=n_records, n_queries_exec=n_queries_exec,
                )
                rows.append({
                    "dataset": dataset,
                    "workload": wname,
                    "budget_us": budget,
                    "n_pushed": r.n_pushed,
                    "loading_ratio": round(r.loading_ratio, 4),
                    "prefilter_s": round(r.prefilter_s, 4),
                    "loading_s": round(r.loading_s, 4),
                    "query_s": round(r.query_s, 4),
                    "baseline_loading_s": round(r.baseline_loading_s, 4),
                    "baseline_query_s": round(r.baseline_query_s, 4),
                    "loading_speedup": round(r.loading_speedup, 2),
                    "query_speedup": round(r.query_speedup, 2),
                    "e2e_speedup": round(r.end_to_end_speedup, 2),
                    "e2e_overlapped_speedup": round(r.end_to_end_overlapped_speedup, 2),
                })
                print(f"[e2e] {dataset}/{wname} budget={budget}: "
                      f"load x{rows[-1]['loading_speedup']} "
                      f"query x{rows[-1]['query_speedup']} "
                      f"e2e x{rows[-1]['e2e_speedup']} "
                      f"(ratio {rows[-1]['loading_ratio']})")
    return rows


def main():
    rows = run()
    with open("artifacts/bench_end_to_end.json", "w") as f:
        json.dump(rows, f, indent=1)
    best = {}
    for r in rows:
        for k in ("loading_speedup", "query_speedup", "e2e_speedup",
                  "e2e_overlapped_speedup"):
            best[k] = max(best.get(k, 0), r[k])
    print(f"[e2e] best across cells: {best} (paper: 21x/23x/19x)")
    return rows


if __name__ == "__main__":
    main()
