"""Shared benchmark scaffolding: CIAO pipeline runner at benchmark scale.

The paper's experiments run single-threaded on 5-27 GB files; these
benchmarks reproduce the same *protocol* (ingest + 200-query workloads,
budgets in µs/record, zero-budget baseline) at tens of MB so the whole
suite finishes in minutes.  All speedups are computed the same way as the paper:
baseline(budget=0) time / CIAO time.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

from repro.core.client import NumpyEngine, encode_chunk
from repro.core.cost_model import CostModel
from repro.core.planner import build_plan
from repro.core.server import CiaoStore, DataSkippingScanner, FullScanBaseline, PushdownPlan
from repro.core.workload import Workload, generate_workload
from repro.data.datasets import generate_records, predicate_pool


@dataclass
class EndToEndResult:
    dataset: str
    workload: str
    budget_us: float
    n_pushed: int
    loading_ratio: float
    prefilter_s: float
    loading_s: float
    query_s: float
    baseline_loading_s: float
    baseline_query_s: float

    @property
    def loading_speedup(self) -> float:
        return self.baseline_loading_s / max(self.loading_s, 1e-9)

    @property
    def query_speedup(self) -> float:
        return self.baseline_query_s / max(self.query_s, 1e-9)

    @property
    def end_to_end_speedup(self) -> float:
        """Conservative: client prefilter serialized with server work."""
        base = self.baseline_loading_s + self.baseline_query_s
        ours = self.prefilter_s + self.loading_s + self.query_s
        return base / max(ours, 1e-9)

    @property
    def end_to_end_overlapped_speedup(self) -> float:
        """Deployment model (paper §IV-B's latency-hiding bet): clients
        evaluate predicates while producing records, so the server-side
        critical path is loading + query; client cost is bounded by the
        budget, not on the path."""
        base = self.baseline_loading_s + self.baseline_query_s
        ours = max(self.loading_s + self.query_s, self.prefilter_s)
        return base / max(ours, 1e-9)


def make_workload(dataset: str, kind: str, n_queries: int = 200,
                  seed: int = 0) -> Workload:
    """Paper Table III: A=Zipf(1.5), B=Zipf(2), C=uniform."""
    pool = predicate_pool(dataset)
    rng = np.random.default_rng(seed)
    if kind == "A":
        return generate_workload(pool, n_queries=n_queries, distribution="zipf",
                                 zipf_a=1.5, rng=rng, name="A")
    if kind == "B":
        return generate_workload(pool, n_queries=n_queries, distribution="zipf",
                                 zipf_a=2.0, rng=rng, name="B")
    return generate_workload(pool, n_queries=n_queries, distribution="uniform",
                             rng=rng, name="C")


def run_end_to_end(dataset: str, workload: Workload, budget_us: float,
                   *, n_records: int = 20000, chunk_size: int = 1000,
                   n_queries_exec: int | None = None, engine=None,
                   cost_model: CostModel | None = None,
                   sample: list | None = None) -> EndToEndResult:
    engine = engine or NumpyEngine()
    records = generate_records(dataset, n_records, seed=17)
    sample = sample if sample is not None else records[:500]

    if budget_us > 0:
        report = build_plan(workload, sample, budget_us=budget_us,
                            cost_model=cost_model)
        plan = report.plan
    else:
        plan = PushdownPlan(clauses=[])

    # client prefiltering (the paper's "prefiltering" bar)
    chunks, bitvecs = [], []
    t0 = time.perf_counter()
    for i in range(0, n_records, chunk_size):
        chunk = encode_chunk(records[i: i + chunk_size])
        bv = engine.eval_packed(chunk, plan.clauses) if plan.n else None
        chunks.append(chunk)
        bitvecs.append(bv)
    prefilter_s = time.perf_counter() - t0

    # server partial loading (the paper's "Data loading" bar)
    store = CiaoStore(plan)
    t0 = time.perf_counter()
    for chunk, bv in zip(chunks, bitvecs):
        store.ingest_chunk(chunk, bv if bv is not None else np.zeros((0, 0), np.uint32))
    loading_s = time.perf_counter() - t0

    # baseline: parse + load everything
    base = FullScanBaseline()
    t0 = time.perf_counter()
    for chunk, _ in zip(chunks, bitvecs):
        base.ingest_chunk(chunk)
    baseline_loading_s = time.perf_counter() - t0

    # query execution (the paper's "Query" bar): the whole workload
    queries = workload.queries[: n_queries_exec or len(workload.queries)]
    scanner = DataSkippingScanner(store)
    t0 = time.perf_counter()
    for q in queries:
        scanner.scan(q)
    query_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    for q in queries:
        base.scan(q)
    baseline_query_s = time.perf_counter() - t0

    return EndToEndResult(
        dataset=dataset,
        workload=workload.name,
        budget_us=budget_us,
        n_pushed=plan.n,
        loading_ratio=store.stats.loading_ratio,
        prefilter_s=prefilter_s if plan.n else 0.0,
        loading_s=loading_s,
        query_s=query_s,
        baseline_loading_s=baseline_loading_s,
        baseline_query_s=baseline_query_s,
    )
