"""Roofline tables from the dry-run artifacts (assignment §Roofline).

Reads artifacts/dryrun/*.json (produced by repro.launch.dryrun) and renders
the per-(arch x shape x mesh) three-term table to stdout + markdown.
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs import SHAPES, list_archs

COLS = ("compute_s", "memory_s", "collective_s")


def load_records(path="artifacts/dryrun2"):
    recs = {}
    for f in glob.glob(os.path.join(path, "*.json")):
        r = json.load(open(f))
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def render(recs, mesh="single") -> str:
    lines = [
        "| arch | shape | compute_s | memory_s | collective_s | dominant | "
        "MODEL/HLO flops | roofline_frac | mem/dev GB |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in list_archs():
        for shape in SHAPES:
            r = recs.get((arch, shape, mesh))
            if r is None:
                continue
            if "skipped" in r:
                lines.append(f"| {arch} | {shape} | — | — | — | skipped | — | — | — |")
                continue
            ro = r["roofline"]
            lines.append(
                f"| {arch} | {shape} | {ro['compute_s']:.3e} | "
                f"{ro['memory_s']:.3e} | {ro['collective_s']:.3e} | "
                f"{ro['dominant']} | {ro['useful_flops_frac']:.2f} | "
                f"{ro['roofline_frac']:.3f} | {ro['memory_per_device_gb']:.1f} |"
            )
    return "\n".join(lines)


def main():
    recs = load_records()
    if not recs:
        print("[roofline] no dry-run artifacts found; run repro.launch.dryrun")
        return {}
    for mesh in ("single", "multi"):
        print(f"\n=== roofline ({mesh}-pod mesh) ===")
        print(render(recs, mesh))
    with open("artifacts/roofline_table.md", "w") as f:
        f.write("# Roofline (single-pod)\n\n" + render(recs, "single"))
        f.write("\n\n# Roofline (multi-pod)\n\n" + render(recs, "multi") + "\n")
    worst = sorted(
        (r for r in recs.values() if "roofline" in r),
        key=lambda r: r["roofline"]["roofline_frac"],
    )[:5]
    print("\nworst roofline fractions:")
    for r in worst:
        print(f"  {r['arch']} x {r['shape']} x {r['mesh']}: "
              f"{r['roofline']['roofline_frac']:.4f} ({r['roofline']['dominant']})")
    return recs


if __name__ == "__main__":
    main()
