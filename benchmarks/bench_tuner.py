"""Online physical-design tuner: drift recovery benchmark (§18).

Measures what :class:`~repro.core.tuner.PhysicalDesignTuner` buys when
the query workload walks away from the physical layout it was built
for.  One 4-shard store is range-routed on ``linear_score`` and filled;
the workload then shifts to point lookups on ``visits`` — a key the
routing and the ingest-ordered segment zone maps know nothing about, so
every query scans every shard.

  * **before** — the fitted workload (panel A on the routing key),
    single-thread panel passes: the healthy baseline.
  * **post_drift** — the shifted workload (panel B) on the UNCHANGED
    layout: the static architecture's steady state forever after the
    drift, and the denominator of the recovery claim.  These scans also
    feed the store's query log — the tuner's only drift signal.
  * **during** — the tuner notices the shift, swaps the router and
    drains an incremental background migration in bounded batches while
    ``query_threads`` reader threads keep answering panel B from
    migration-fenced snapshots.  Every count is checked BIT-IDENTICAL
    to the ``matches_exact`` oracle, and per-query latencies feed the
    reader-stall gate.
  * **after** — panel B re-measured exactly like ``post_drift`` on the
    re-partitioned store: partition pruning works again, and the
    recovery ratio is ``after.qps / post_drift.qps``.

Claim gates (``bench_schema.validate_tuner``):

  * counts bit-identical to the oracle in EVERY phase — before, every
    during-migration check, and after (``counts_match``);
  * the router actually swapped to the drifted key and moved rows in
    >= 2 bounded batches (incremental, not stop-the-world);
  * post-drift recovery >= 1.5x (>= 0.8x quick — tiny quick stores
    leave pruning little to delete, CI gates against collapse only);
  * reader p99 during migration <= 3x the quiesced p99 at the same
    concurrency (<= 8x quick): background moves never stall readers.

    PYTHONPATH=src python -m benchmarks.bench_tuner
"""
from __future__ import annotations

import json
import os
import threading
import time

import numpy as np

from repro.core.client import NumpyEngine, encode_chunk
from repro.core.predicates import Query, clause, key_value
from repro.core.server import PushdownPlan
from repro.core.shard import ShardedCiaoStore, ShardedScanner, ShardRouter
from repro.core.tuner import PhysicalDesignTuner, TunerPolicy
from repro.data.datasets import generate_records, predicate_pool
from repro.serve.store_engine import CiaoServeEngine

PANEL_SIZE = 8
KEY_A = "linear_score"   # routing + plan key the store was built for
KEY_B = "visits"         # the key the workload drifts onto


def _prepare(n_records: int, chunk_records: int):
    recs = generate_records("ycsb", n_records, seed=7)
    objs = [json.loads(r) for r in recs]
    pool = predicate_pool("ycsb")
    plan = PushdownPlan(clauses=pool[:6])
    eng = NumpyEngine()
    chunks = []
    for start in range(0, n_records, chunk_records):
        ch = encode_chunk(recs[start:start + chunk_records])
        chunks.append((ch, eng.eval_fused(ch, plan.clauses)))

    def panel(key: str, lo: int, hi: int) -> list[Query]:
        vals = np.linspace(lo, hi, PANEL_SIZE).astype(int)
        return [Query((clause(key_value(key, int(v))),)) for v in vals]

    panel_a = panel(KEY_A, 2, 97)
    panel_b = panel(KEY_B, 5, 990)
    oracle = {
        id(q): sum(1 for o in objs if q.matches_exact(o))
        for q in panel_a + panel_b
    }
    return plan, objs, chunks, panel_a, panel_b, oracle


def _pcts(lat_s: list[float]) -> tuple[float, float]:
    arr = np.asarray(lat_s, dtype=np.float64) * 1e6
    return float(np.percentile(arr, 50)), float(np.percentile(arr, 99))


def _timed_panel(store, panel, oracle, *, passes: int) -> dict:
    """Single-thread panel passes — the throughput probe used for the
    before / post_drift / after phases (identical methodology, so the
    recovery ratio compares like with like)."""
    scanner = ShardedScanner(store, telemetry=False)  # logs to query_log
    ok = True
    t0 = time.perf_counter()
    for _ in range(passes):
        for q in panel:
            ok &= (scanner.scan(q).count == oracle[id(q)])
    dt = time.perf_counter() - t0
    n = passes * len(panel)
    return {
        "passes": int(passes),
        "queries": int(n),
        "us_per_query": round(dt / n * 1e6, 2),
        "qps": round(n / dt, 2),
        "counts_match": bool(ok),
    }


def run(n_records: int = 49152, chunk_records: int = 512,
        segment_capacity: int = 1024, n_shards: int = 4,
        query_threads: int = 4, passes: int = 6,
        quick: bool | None = None) -> dict:
    quick = (n_records <= 16384) if quick is None else quick
    plan, objs, chunks, panel_a, panel_b, oracle = _prepare(
        n_records, chunk_records)

    store = ShardedCiaoStore(
        plan, router=ShardRouter.from_samples(n_shards, KEY_A, objs[:1024]),
        segment_capacity=segment_capacity)
    t0 = time.perf_counter()
    for ch, bv in chunks:
        store.ingest_chunk(ch, bv)
    ingest_s = time.perf_counter() - t0

    # warm probe outside every timed window: first scans pay one-time
    # column/zone-map materialization, not steady-state panel cost
    warm = ShardedScanner(store, log_queries=False, telemetry=False)
    for q in panel_a + panel_b:
        warm.scan(q)

    # -- before: the fitted workload on the fitted layout -----------------
    before = _timed_panel(store, panel_a, oracle, passes=passes)

    # -- post_drift: the shifted workload on the stale layout -------------
    # (the static baseline AND the tuner's drift evidence: these scans
    # log panel B into the query window the tuner watches)
    post_drift = _timed_panel(store, panel_b, oracle, passes=passes)

    # reader harness over the serve engine: queries answer from the
    # engine's refresh-interval snapshot, so the migration fence is paid
    # by the background refresher, never on the measured read path —
    # exactly the non-blocking claim the p99 gate checks
    serve = CiaoServeEngine(store, queue_depth=4)
    counts_ok = [True]
    errors: list[BaseException] = []

    def read(lat: list, stop: threading.Event) -> None:
        try:
            loops = 0
            while True:
                for q in panel_b:
                    tq = time.perf_counter()
                    r = serve.query(q)
                    lat.append(time.perf_counter() - tq)
                    if r.count != oracle[id(q)]:
                        counts_ok[0] = False
                loops += 1
                if stop.is_set() and loops >= 2:
                    return
        except BaseException as e:      # pragma: no cover - failure path
            errors.append(e)

    def reader_phase():
        """Start the reader pool; returns (per-thread latency lists,
        stop event, threads) — the caller owns the phase's duration."""
        per: list[list[float]] = [[] for _ in range(query_threads)]
        stop = threading.Event()
        threads = [threading.Thread(target=read, args=(per[i], stop))
                   for i in range(query_threads)]
        for t in threads:
            t.start()
        return per, stop, threads

    # -- quiesced reference FIRST: same readers, same stale layout, no
    # migration running — so the p99 ratio isolates exactly the
    # interference the background migration adds, not the layout change
    quiesced_per, stop_q, qthreads = reader_phase()
    time.sleep(0.3 if quick else 1.0)
    stop_q.set()
    for t in qthreads:
        t.join()
    quiesced_lat = [x for per in quiesced_per for x in per]
    q_p50, q_p99 = _pcts(quiesced_lat)

    # -- during: background migration vs live engine readers --------------
    tuner = PhysicalDesignTuner(
        store, policy=TunerPolicy(check_every_scans=1,
                                  batch_rows=max(512, n_records // 48)))
    live_per, stop_l, readers = reader_phase()
    t0 = time.perf_counter()
    serve.start_tuner(tuner, interval_s=0.002)
    deadline = t0 + 600.0
    while not any(e.kind == "migration-finish" for e in tuner.history):
        assert time.perf_counter() < deadline, "migration never finished"
        time.sleep(0.01)
    migrate_s = time.perf_counter() - t0
    stop_l.set()
    for t in readers:
        t.join()
    serve.close()
    if errors:
        raise errors[0]
    assert any(e.kind == "migration-start" for e in tuner.history), \
        "tuner failed to notice the drift"
    mig = tuner.migration
    live_lat = [x for per in live_per for x in per]
    live_p50, live_p99 = _pcts(live_lat)
    p99_ratio = live_p99 / q_p99 if q_p99 > 0 else float("inf")

    # -- after: the shifted workload on the re-partitioned layout ---------
    after = _timed_panel(store, panel_b, oracle, passes=passes)
    probe = ShardedScanner(store, log_queries=False, telemetry=False)
    shards_pruned_after = sum(probe.scan(q).shards_pruned for q in panel_b)

    counts_match = (before["counts_match"] and post_drift["counts_match"]
                    and after["counts_match"] and counts_ok[0])
    recovery = after["qps"] / post_drift["qps"] if post_drift["qps"] else 0.0
    tele = store.telemetry.snapshot()["tuner"]

    out = {
        "quick": bool(quick),
        "n_records": int(n_records),
        "n_chunks": len(chunks),
        "n_shards": int(n_shards),
        "query_threads": int(query_threads),
        "panel_size": PANEL_SIZE,
        "cpu_count": int(os.cpu_count() or 1),
        "key_before": KEY_A,
        "key_after": str(store.router.key),
        "router_swapped": bool(store.router.key == KEY_B),
        "ingest_s": round(ingest_s, 6),
        "before": before,
        "post_drift": post_drift,
        "during": {
            "migrate_s": round(migrate_s, 6),
            "queries": len(live_lat),
            "p50_us": round(live_p50, 1),
            "p99_us": round(live_p99, 1),
        },
        "quiesced": {
            "queries": len(quiesced_lat),
            "p50_us": round(q_p50, 1),
            "p99_us": round(q_p99, 1),
        },
        "after": after,
        "migration": {
            "rows_moved": int(mig.rows_moved),
            "rows_kept": int(mig.rows_kept),
            "segments_moved": int(mig.segments_moved),
            "items_skipped": int(mig.items_skipped),
            "batches": int(mig.batches),
        },
        "telemetry_tuner": {k: int(v) for k, v in tele.items()},
        "tuner_events": [e.describe() for e in tuner.history],
        "recovery_speedup": round(recovery, 2),
        "p99_ratio": round(p99_ratio, 2),
        "shards_pruned_after": int(shards_pruned_after),
        "counts_match": bool(counts_match),
    }
    print(f"[tuner] {n_records} records / {len(chunks)} chunks into "
          f"{n_shards} shards routed on {KEY_A!r}; workload drifts to "
          f"{KEY_B!r} (cpu_count={out['cpu_count']})")
    print(f"[tuner] before (panel A): {before['us_per_query']:9.1f} "
          f"us/q   post_drift (panel B): "
          f"{post_drift['us_per_query']:9.1f} us/q")
    print(f"[tuner] migrated {mig.rows_moved} rows "
          f"({mig.rows_kept} stayed) in {mig.batches} batches over "
          f"{migrate_s:.2f} s; router -> {store.router.key!r}")
    print(f"[tuner] after  (panel B): {after['us_per_query']:9.1f} us/q "
          f"-> recovery x{out['recovery_speedup']} "
          f"(pruned {shards_pruned_after} shard visits)")
    print(f"[tuner] reader p99 during {live_p99:9.1f} us vs quiesced "
          f"{q_p99:9.1f} us = x{out['p99_ratio']}")
    print(f"[tuner] counts_match={out['counts_match']} "
          f"router_swapped={out['router_swapped']}")
    return out


if __name__ == "__main__":
    os.makedirs("artifacts", exist_ok=True)
    out = run()
    with open("artifacts/bench_tuner.json", "w") as f:
        json.dump(out, f, indent=1)
