"""Paper Table IV: cost-model calibration R² across 'platforms'.

We cannot span three physical machines, so the platform axis becomes the
*engine* axis — three genuinely different execution profiles on this host:
the paper-faithful bytes.find engine, the vectorized numpy engine, and the
XLA-jitted oracle.  The paper's claim under test is that the 5-coefficient
linear model fits each platform after per-platform calibration
(paper R²: 0.897 / 0.666 / 0.978).
"""
from __future__ import annotations

import json

from repro.core.client import NumpyEngine, encode_chunk
from repro.core.cost_model import calibrate
from repro.core.predicates import exact, key_value, substring
from repro.data.datasets import generate_records


def _probes():
    probes = []
    probes += [exact("phone_country", c) for c in ("US", "CN", "IN")]
    probes += [substring("url_site", s) for s in
               ("www.alpha.", "www.beta.", "www.gamma.", "q", "zz")]
    probes += [key_value("linear_score", v) for v in (0, 3, 17, 55, 99)]
    probes += [key_value("weighted_score", v) for v in (1, 42)]
    probes += [substring("email", "@"), substring("email", "999@"),
               substring("name", "Warm"), substring("address", "st"),
               exact("age_group", "adult"), exact("age_group", "child")]
    return probes


def main(n_records: int = 3000, repeats: int = 5):
    records = generate_records("ycsb", n_records, seed=41)
    probes = _probes()
    rows = []

    # platform 1: paper-faithful bytes.find
    res = calibrate(records, probes, repeats=repeats)
    rows.append({"platform": "python-bytes-find", "r_squared": round(res.r_squared, 3),
                 "coeffs": [round(float(c), 6) for c in res.model.coefficients()]})

    # platform 2: vectorized numpy engine
    np_eng = NumpyEngine()
    chunk = encode_chunk(records)

    def np_eval(recs, pred):
        from repro.core.predicates import Clause

        return np_eng.eval(chunk, [Clause((pred,))])[0]

    res = calibrate(records, probes, evaluator=np_eval, repeats=repeats)
    rows.append({"platform": "numpy-vectorized", "r_squared": round(res.r_squared, 3),
                 "coeffs": [round(float(c), 6) for c in res.model.coefficients()]})

    # platform 3: XLA-jitted kernel oracle
    from repro.kernels.engine import KernelEngine

    xla_eng = KernelEngine(backend="xla")

    def xla_eval(recs, pred):
        from repro.core.predicates import Clause

        return xla_eng.eval(chunk, [Clause((pred,))])[0]

    # warm the jit caches so we time steady-state
    xla_eval(records, probes[0])
    res = calibrate(records, probes, evaluator=xla_eval, repeats=repeats)
    rows.append({"platform": "xla-jit", "r_squared": round(res.r_squared, 3),
                 "coeffs": [round(float(c), 6) for c in res.model.coefficients()]})

    for r in rows:
        print(f"[tableIV] {r['platform']:20s} R²={r['r_squared']} "
              f"(paper range: 0.666-0.978)")
    with open("artifacts/bench_cost_model.json", "w") as f:
        json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    main()
