"""Skipping-index registry: range/IN/n-gram pruning end-to-end (§19).

Before the registry, a substring- or range-shaped workload had ~nothing
to skip with: RANGE and IN did not exist as predicate kinds, and
SUBSTRING refutation died at the shard level once the value-set
summaries saturated.  This benchmark measures what the registry buys on
exactly that workload: selective BETWEEN / one-sided ranges over
ingest-clustered numeric keys, rare-token substring probes, small IN
lists, and range+substring conjunctions, over a range-partitioned
sharded store.

Two measured paths over the SAME store and queries:

  * ``noskip`` — pruning disabled: every segment of every shard gets the
    full vectorized clause evaluation (the "~0% pruning today" shape,
    with every advantage kept: memoized clause masks, no per-row work);
  * ``skip``   — the full three-level cascade: shard partition pruning
    (range bounds + n-gram blooms in the per-shard summaries), segment
    zone-map pruning (registry probe over exact dictionaries), then the
    identical vectorized evaluation on the survivors.

Counts are asserted bit-identical across both paths and the
``matches_exact`` full-scan oracle, and the checkpoint round trip is
gated: a format-6 save must reload, and the same manifest with the
format-5 fields only (registry slices stripped) must load cleanly and
still produce oracle counts — pruning degrades, correctness does not.

    PYTHONPATH=src python -m benchmarks.bench_skip
"""
from __future__ import annotations

import json
import os
import tempfile
import time

import numpy as np

from repro.core.client import NumpyEngine, encode_chunk
from repro.core.predicates import (
    Query, between, clause, in_list, key_value, rng as rng_pred, substring,
)
from repro.core.server import PlanFamily, PushdownPlan
from repro.core.shard import ShardedCiaoStore, ShardedScanner, ShardRouter

N_TOKENS = 32


def _records(n: int, seed: int) -> list[bytes]:
    """Synthetic log-ish rows with ingest-clustered numeric keys.

    ``seq`` increases with ingest order and ``score`` tracks it with
    noise — the natural time-correlated shape that makes zone maps
    useful.  Each rare token ``tokNN`` appears only inside its own
    1/N_TOKENS window of rows; every 97th ``score`` is written as a JSON
    string (the §IV-B cross-representation case the range bounds must
    keep sound).
    """
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        score = round(i / n * 1000.0 + float(rng.normal(0.0, 5.0)), 3)
        tok = f"tok{i * N_TOKENS // n:02d}"
        obj = {
            "seq": i,
            "score": str(score) if i % 97 == 0 else score,
            "msg": f"session {int(rng.integers(1_000_000))} {tok} event",
            "status": int(rng.integers(0, 6)),
        }
        out.append(json.dumps(obj, separators=(",", ":")).encode())
    return out


def _build_store(recs, objs, n_shards: int, capacity: int):
    fam = PlanFamily(
        plan=PushdownPlan(clauses=[clause(key_value("status", 1)),
                                   clause(key_value("status", 2))]),
        tier_sizes=(1, 2),
    )
    router = ShardRouter.from_samples(n_shards, "seq", objs[:1024])
    store = ShardedCiaoStore(fam, router=router, n_shards=n_shards,
                             segment_capacity=capacity)
    eng = NumpyEngine()
    chunk_records = 512
    for i, start in enumerate(range(0, len(recs), chunk_records)):
        tier = i % fam.n_tiers
        chunk = encode_chunk(recs[start: start + chunk_records])
        bv = eng.eval_fused_prefix(chunk, fam.plan.clauses,
                                   fam.tier_sizes[tier])
        store.ingest_chunk(chunk, bv, epoch=0, tier=tier)
    store.jit_load_raw()
    return store


def _q(*preds) -> Query:
    return Query(tuple(clause(p) for p in preds))


def _workload(n: int) -> list[Query]:
    qs: list[Query] = []
    # narrow BETWEEN windows on the ingest-clustered key (~2% of rows)
    w = max(n // 50, 8)
    for k in range(6):
        lo = (5 + 15 * k) * n // 100
        qs.append(_q(between("seq", lo, lo + w)))
    # score ranges: two-sided narrow + one-sided tails (score ~ U[0,1000])
    qs.append(_q(rng_pred("score", 101.5, 118.25)))
    qs.append(_q(rng_pred("score", 660, 680, lo_incl=False)))
    qs.append(_q(rng_pred("score", hi=4.0)))
    qs.append(_q(rng_pred("score", lo=996.0, lo_incl=False)))
    # rare tokens: each lives in one 1/32 window of the ingest order
    for t in (3, 11, 19, 27, 30, 6):
        qs.append(_q(substring("msg", f"tok{t:02d}")))
    # small IN lists on the clustered key (point-ish, multi-value)
    qs.append(_q(in_list("seq", [n // 10, n // 10 + 1, n // 10 + 2])))
    qs.append(_q(in_list("seq", [n // 3, 2 * n // 3])))
    qs.append(_q(in_list("seq", [n - 1, n + 5])))
    # range AND substring conjunctions: overlapping and disjoint windows
    qs.append(_q(between("seq", 3 * n // 32, 4 * n // 32),
                 substring("msg", "tok03")))
    qs.append(_q(between("seq", 0, n // 32),
                 substring("msg", "tok31")))   # disjoint: 0 rows
    qs.append(_q(rng_pred("score", 300, 340), substring("msg", "tok10")))
    # provable no-matches (the pure-refutation case)
    qs.append(_q(between("seq", 2 * n, 2 * n + 10)))
    qs.append(_q(substring("msg", "zzqxv")))
    return qs


def _shard_segments(store) -> list[list]:
    return [list(sh.blocks) + list(sh.jit_blocks) for sh in store.shards]


def _noskip_count(segs_by_shard, q: Query) -> int:
    """Pruning disabled: full vectorized evaluation of every segment."""
    count = 0
    for segs in segs_by_shard:
        for seg in segs:
            m = None
            for c in q.clauses:
                cm, leftover = seg.clause_mask(c)
                if leftover:
                    cm = cm.copy()
                    for i in range(seg.n_rows):
                        if not cm[i]:
                            obj = json.loads(seg.record(i))
                            if any(t.matches_exact(obj) for t in leftover):
                                cm[i] = True
                m = cm if m is None else (m & cm)
            count += int(m.sum()) if m is not None else seg.n_rows
    return count


def _scan_counts(store, queries):
    """(counts, seg_scanned, seg_pruned_zone, shard_visits_pruned)."""
    counts, scanned, zone_pruned, sh_pruned = [], 0, 0, 0
    with ShardedScanner(store, log_queries=False) as scanner:
        for q in queries:
            r = scanner.scan(q)
            counts.append(r.count)
            scanned += r.segments_scanned
            zone_pruned += r.segments_pruned
            sh_pruned += r.shards_pruned
    return counts, scanned, zone_pruned, sh_pruned


def _migration_ok(store, queries, oracle_counts) -> bool:
    """format-6 save reloads; format-5 (fields stripped) loads + counts."""
    strip = ("rmin", "rmax", "rmin_inf", "rmax_inf", "rnum_prunable",
             "ngram")
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "ckpt")
        store.save(path)
        mpath = os.path.join(path, "manifest.json")
        with open(mpath) as f:
            manifest = json.load(f)
        if manifest.get("format") != 6:
            return False
        s6 = ShardedCiaoStore.load(path)
        c6, *_ = _scan_counts(s6, queries)
        if c6 != oracle_counts:
            return False
        # rewrite the manifest as a format-5 file: registry slices gone
        manifest["format"] = 5
        for summ in manifest["summaries"]:
            for ks in summ["keys"].values():
                for k in strip:
                    ks.pop(k, None)
        with open(mpath, "w") as f:
            json.dump(manifest, f)
        s5 = ShardedCiaoStore.load(path)
        c5, *_ = _scan_counts(s5, queries)
        return c5 == oracle_counts


def _invalidate(store, segs_by_shard) -> None:
    """Simulate segment turnover: drop memoized masks + verdict caches.

    In steady-state serving, segments are continuously sealed and
    retired, so each (segment, clause) mask is evaluated once per
    segment *lifetime* — that first vectorized evaluation is the work
    skipping avoids.  Resetting the memo dicts (fresh dicts, same
    eviction idiom the store itself uses) re-creates that state without
    re-ingesting; the skip path's own probe caches are reset too, so it
    pays its full probe cost every timed pass.
    """
    for segs in segs_by_shard:
        for seg in segs:
            seg._clause_masks = {}
            seg._possible = {}
            seg._and_masks = {}
    for summ in store.summaries:
        summ._possible = {}


def _best_of(fn, repeats: int, setup=None) -> float:
    best = np.inf
    for _ in range(repeats):
        if setup is not None:
            setup()
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(n_records: int = 24576, n_shards: int = 8,
        segment_capacity: int | None = None, repeats: int = 3,
        quick: bool | None = None) -> dict:
    quick = (n_records <= 8192) if quick is None else quick
    if segment_capacity is None:
        segment_capacity = max(256, n_records // 24)
    recs = _records(n_records, seed=17)
    objs = [json.loads(r) for r in recs]
    store = _build_store(recs, objs, n_shards, segment_capacity)
    queries = _workload(n_records)
    segs_by_shard = _shard_segments(store)
    n_segments = sum(len(s) for s in segs_by_shard)

    oracle = [sum(1 for o in objs if q.matches_exact(o)) for q in queries]

    skip_counts, seg_scanned, zone_pruned, sh_pruned = \
        _scan_counts(store, queries)
    noskip_counts = [_noskip_count(segs_by_shard, q) for q in queries]
    counts_match = skip_counts == oracle and noskip_counts == oracle

    # warm steady state (every mask memoized) — informational only: once
    # all masks are cached, both paths reduce to dict hits + tiny ANDs
    with ShardedScanner(store, log_queries=False) as scanner:
        warm_skip_s = _best_of(
            lambda: [scanner.scan(q) for q in queries], repeats)
        warm_noskip_s = _best_of(
            lambda: [_noskip_count(segs_by_shard, q) for q in queries],
            repeats)
        # fresh-evaluation passes (the gated numbers): segment turnover
        # means each mask is computed once per segment lifetime — this is
        # the work pruning actually avoids
        inval = lambda: _invalidate(store, segs_by_shard)
        skip_s = _best_of(
            lambda: [scanner.scan(q) for q in queries], repeats,
            setup=inval)
        noskip_s = _best_of(
            lambda: [_noskip_count(segs_by_shard, q) for q in queries],
            repeats, setup=inval)

    visits = n_segments * len(queries)
    pruned_fraction = 1.0 - seg_scanned / max(visits, 1)
    migration_ok = _migration_ok(store, queries, oracle)

    out = {
        "quick": bool(quick),
        "n_records": int(n_records),
        "n_shards": int(n_shards),
        "n_segments": int(n_segments),
        "n_queries": len(queries),
        "noskip": {
            "scan_s": round(noskip_s, 6),
            "us_per_query": round(noskip_s / len(queries) * 1e6, 1),
            "warm_scan_s": round(warm_noskip_s, 6),
        },
        "skip": {
            "scan_s": round(skip_s, 6),
            "us_per_query": round(skip_s / len(queries) * 1e6, 1),
            "warm_scan_s": round(warm_skip_s, 6),
            "segments_scanned": int(seg_scanned),
            "segments_zone_pruned": int(zone_pruned),
            "shard_visits_pruned": int(sh_pruned),
        },
        "pruned_fraction": round(pruned_fraction, 4),
        "speedup": round(noskip_s / skip_s, 2),
        "warm_speedup": round(warm_noskip_s / warm_skip_s, 2),
        "counts_match": bool(counts_match),
        "migration_ok": bool(migration_ok),
    }
    print(f"[skip] {n_records} records, {n_shards} shards, {n_segments} "
          f"segments, {len(queries)} range/IN/substring queries")
    print(f"[skip] noskip {noskip_s * 1e3:9.2f} ms/batch "
          f"(warm {warm_noskip_s * 1e3:.2f} ms)")
    print(f"[skip] skip   {skip_s * 1e3:9.2f} ms/batch "
          f"(x{out['speedup']}; warm {warm_skip_s * 1e3:.2f} ms, "
          f"x{out['warm_speedup']})")
    print(f"[skip] pruned {pruned_fraction:.1%} of segment visits "
          f"({sh_pruned} shard visits refuted at partition level), "
          f"counts_match={counts_match}, migration_ok={migration_ok}")
    return out


if __name__ == "__main__":
    os.makedirs("artifacts", exist_ok=True)
    out = run()
    with open("artifacts/bench_skip.json", "w") as f:
        json.dump(out, f, indent=1)
