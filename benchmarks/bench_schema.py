"""Shape validation for benchmark JSON artifacts (the CI smoke gate).

``BENCH_kernels.json`` is the tracked perf-trajectory artifact: PR-over-PR
numbers are only comparable if every writer emits the same shape.  This
module is the single source of truth for that shape — ``benchmarks.run``
validates before writing, CI validates the emitted files, and the tier-1
suite validates the tracked copy — so the artifact can never regress to a
malformed form.

    PYTHONPATH=src python -m benchmarks.bench_schema FILE [FILE ...]
"""
from __future__ import annotations

import json
import numbers
import sys


class SchemaError(ValueError):
    """A benchmark artifact does not match its declared shape."""


def _require(cond: bool, where: str, msg: str) -> None:
    if not cond:
        raise SchemaError(f"{where}: {msg}")


def _check_fields(row: dict, spec: dict[str, type | tuple], where: str) -> None:
    _require(isinstance(row, dict), where, f"expected object, got {type(row).__name__}")
    for key, typ in spec.items():
        _require(key in row, where, f"missing key {key!r}")
        _require(isinstance(row[key], typ) and not (
            typ is not bool and isinstance(row[key], bool)),
            where, f"{key!r} expected {typ}, got {row[key]!r}")


_ENGINE_ROW = {
    "engine": str,
    # execution provenance: a pallas number measured under the interpreter
    # must never read as a TPU number in the tracked trajectory
    "backend": str,
    "device": str,
    "interpret": bool,
    "records_per_s": numbers.Integral,
    "us_per_record": numbers.Real,
    "effective_GBps": numbers.Real,
}

_FUSED_ROW = {
    "backend": str,
    "n_records": numbers.Integral,
    "n_clauses": numbers.Integral,
    "n_kv_pairs": numbers.Integral,
    "split_us_per_record": numbers.Real,
    "fused_us_per_record": numbers.Real,
    "speedup": numbers.Real,
    "launches_split": numbers.Integral,
    "launches_fused": numbers.Integral,
}


def validate_kernels(obj: dict) -> None:
    """Raise :class:`SchemaError` unless ``obj`` is a valid kernels artifact."""
    _require(isinstance(obj, dict), "kernels", "top level must be an object")
    for section, spec, min_rows in (
        ("engines", _ENGINE_ROW, 2),
        ("fused_vs_split", _FUSED_ROW, 1),
    ):
        _require(section in obj, "kernels", f"missing section {section!r}")
        rows = obj[section]
        _require(isinstance(rows, list), section, "must be a list")
        _require(len(rows) >= min_rows, section,
                 f"expected >= {min_rows} rows, got {len(rows)}")
        for i, row in enumerate(rows):
            _check_fields(row, spec, f"{section}[{i}]")
    for i, row in enumerate(obj["engines"]):
        _require(row["us_per_record"] > 0, f"engines[{i}]",
                 "us_per_record must be positive")
    for i, row in enumerate(obj["fused_vs_split"]):
        _require(row["launches_fused"] == 1, f"fused_vs_split[{i}]",
                 "the fused path is ONE launch by contract")


def validate_replan(obj: dict) -> None:
    """Raise :class:`SchemaError` unless ``obj`` is a valid replan artifact."""
    _require(isinstance(obj, dict), "replan", "top level must be an object")
    for key in ("budget_us", "static", "adaptive",
                "post_drift_scan_speedup", "eff_loading_ratio_delta"):
        _require(key in obj, "replan", f"missing key {key!r}")
    for side in ("static", "adaptive"):
        _check_fields(obj[side], {
            "epoch": numbers.Integral,
            "eff_loading_ratio": numbers.Real,
            "post_drift_scan_s": numbers.Real,
        }, side)
    _require(obj["adaptive"]["epoch"] >= 1, "replan",
             "adaptive run never advanced the plan epoch")


_TIER_SCENARIO_ROW = {
    "mode": str,
    "tier_assignment": list,
    "budget_spent_us": numbers.Real,
    "budget_ok": bool,
    "n_records": numbers.Integral,
    "eff_loading_ratio": numbers.Real,
    "loading_s": numbers.Real,
    "scan_s": numbers.Real,
    "end_to_end_s": numbers.Real,
    "retier_events": numbers.Integral,
}


def validate_tiers(obj: dict) -> None:
    """Raise :class:`SchemaError` unless ``obj`` is a valid tiers artifact.

    Beyond shape, this gates the benchmark's CLAIM: the tier allocator
    must beat BOTH uniform baselines on effective loading ratio and
    end-to-end time, within the global budget, on a nested family.
    """
    _require(isinstance(obj, dict), "tiers", "top level must be an object")
    for key in ("global_budget_us", "fleet", "tiers", "tiered",
                "uniform_min", "uniform_max", "wins"):
        _require(key in obj, "tiers", f"missing key {key!r}")
    _require(isinstance(obj["tiers"], dict), "tiers",
             "'tiers' must be an object")
    sizes = obj["tiers"].get("sizes")
    _require(isinstance(sizes, list) and len(sizes) >= 2, "tiers.sizes",
             "need >= 2 nested tiers")
    _require(all(a <= b for a, b in zip(sizes, sizes[1:])), "tiers.sizes",
             f"tier sizes must be ascending (nested): {sizes}")
    for side in ("tiered", "uniform_min", "uniform_max"):
        _check_fields(obj[side], _TIER_SCENARIO_ROW, side)
        _require(obj[side]["eff_loading_ratio"] > 0, side,
                 "eff_loading_ratio must be positive")
    tiered, umin, umax = (obj["tiered"], obj["uniform_min"],
                          obj["uniform_max"])
    _require(tiered["budget_ok"], "tiered",
             "the allocator exceeded the global budget")
    _require(tiered["retier_events"] >= 1, "tiered",
             "cost-drift re-tiering never fired (the drift demo must "
             "re-solve the allocation)")
    _require(not umax["budget_ok"], "uniform_max",
             "uniform-max fit the budget: the scenario has no trade-off")
    _require(
        tiered["eff_loading_ratio"]
        < min(umin["eff_loading_ratio"], umax["eff_loading_ratio"]),
        "tiers", "tiered allocation must beat both uniform baselines on "
        "effective loading ratio")
    _require(
        tiered["end_to_end_s"]
        < min(umin["end_to_end_s"], umax["end_to_end_s"]),
        "tiers", "tiered allocation must beat both uniform baselines on "
        "end-to-end time")


_SCAN_SIDE = {
    "scan_s": numbers.Real,
    "us_per_query": numbers.Real,
}


def validate_scan(obj: dict) -> None:
    """Raise :class:`SchemaError` unless ``obj`` is a valid scan artifact.

    Beyond shape, this gates the columnar engine's CLAIM: counts must be
    bit-identical to the exact-match oracle across the mixed-epoch /
    mixed-tier workload, zone maps must demonstrably prune, and the
    vectorized path must beat the row-at-a-time path >= 5x at full size
    (>= 1.5x for reduced-size ``--quick``/CI smoke runs, which trade
    segment sizes for wall-clock).
    """
    _require(isinstance(obj, dict), "scan", "top level must be an object")
    for key in ("quick", "n_records", "n_segments", "n_queries",
                "row_at_a_time", "columnar", "speedup", "cold_speedup",
                "counts_match"):
        _require(key in obj, "scan", f"missing key {key!r}")
    _require(isinstance(obj["quick"], bool), "scan", "'quick' must be bool")
    _check_fields(obj["row_at_a_time"], _SCAN_SIDE, "row_at_a_time")
    _check_fields(obj["columnar"], dict(
        _SCAN_SIDE, cold_scan_s=numbers.Real,
        segments_pruned=numbers.Integral), "columnar")
    _require(obj["counts_match"] is True, "scan",
             "columnar counts diverged from the exact-match oracle")
    _require(obj["n_segments"] >= 2, "scan", "need >= 2 segments")
    _require(obj["n_queries"] >= 10, "scan", "need >= 10 workload queries")
    _require(obj["columnar"]["segments_pruned"] >= 1, "scan",
             "zone maps never pruned a segment (the second skipping "
             "level is not demonstrated)")
    floor = 1.5 if obj["quick"] else 5.0
    _require(obj["speedup"] >= floor, "scan",
             f"columnar speedup {obj['speedup']} < required {floor}x")


_SHARD_RUN_ROW = {
    "n_shards": numbers.Integral,
    "scan_s": numbers.Real,
    "us_per_query": numbers.Real,
    "counts_match": bool,
    "selective_pruned_fraction": numbers.Real,
    "max_shard_rows": numbers.Integral,
    "min_shard_rows": numbers.Integral,
}


def validate_shard(obj: dict) -> None:
    """Raise :class:`SchemaError` unless ``obj`` is a valid shard artifact.

    Beyond shape, this gates the shard plane's CLAIM (DESIGN.md §14):
    counts bit-identical to the 1-shard oracle at every shard count,
    >= 30% of per-query shard visits partition-pruned on the selective
    subset at 8 shards, and >= 2x scan speedup at 8 shards.  Reduced-size
    ``--quick`` runs only gate against collapse (>= 0.8x): their tiny
    per-shard segments leave little vectorized work for pruning to skip,
    so the measured ratio sits in wall-clock noise on loaded 2-core CI
    runners — the 2x claim is full-size-only, like the scan gate's 5x.
    """
    _require(isinstance(obj, dict), "shard", "top level must be an object")
    for key in ("quick", "n_records", "routing_card", "n_queries",
                "n_selective", "routing_key", "mode", "runs",
                "counts_match", "speedup_4", "speedup_8",
                "selective_pruned_fraction"):
        _require(key in obj, "shard", f"missing key {key!r}")
    _require(isinstance(obj["quick"], bool), "shard", "'quick' must be bool")
    _require(isinstance(obj["routing_key"], str) and obj["routing_key"],
             "shard", "routing_key must be a non-empty string")
    runs = obj["runs"]
    _require(isinstance(runs, list) and len(runs) >= 3, "runs",
             "need >= 3 shard-count rows")
    for i, row in enumerate(runs):
        _check_fields(row, _SHARD_RUN_ROW, f"runs[{i}]")
        _require(row["scan_s"] > 0, f"runs[{i}]", "scan_s must be positive")
        _require(row["counts_match"] is True, f"runs[{i}]",
                 "counts diverged from the 1-shard oracle")
        _require(row["min_shard_rows"] >= 0
                 and row["max_shard_rows"] >= row["min_shard_rows"],
                 f"runs[{i}]", "shard row bounds inconsistent")
    shard_counts = [row["n_shards"] for row in runs]
    for need in (1, 4, 8):
        _require(need in shard_counts, "runs",
                 f"missing the {need}-shard row")
    _require(obj["counts_match"] is True, "shard",
             "sharded counts diverged from the unsharded oracle")
    _require(0.0 <= obj["selective_pruned_fraction"] <= 1.0, "shard",
             "selective_pruned_fraction out of [0, 1]")
    _require(obj["selective_pruned_fraction"] >= 0.3, "shard",
             "partition metadata pruned < 30% of shard visits on the "
             "selective workload (the third skipping level is not "
             "demonstrated)")
    floor = 0.8 if obj["quick"] else 2.0
    _require(obj["speedup_8"] >= floor, "shard",
             f"8-shard speedup {obj['speedup_8']} < required {floor}x")


_DEVICE_SIDE = {
    "scan_s": numbers.Real,
    "us_per_query": numbers.Real,
    "records_per_s": numbers.Integral,
}


def validate_device(obj: dict) -> None:
    """Raise :class:`SchemaError` unless ``obj`` is a valid device artifact.

    Beyond shape, this gates the device scan plane's CLAIM (DESIGN.md
    §15): counts bit-identical to the quiesced host oracle, ZERO
    steady-state host->device segment uploads, the fused batched path
    >= 2x the numpy-vectorized reference of the SAME plane scan on the
    selective workload (full-size; reduced-size ``--quick`` runs gate
    against collapse at 0.5x; the host skipping scanner is reported as
    ``host_skipping`` context, not gated), a batch of 8 queries >= 3x
    over 8 sequential device scans (>= 0.8x quick), and a roofline
    fraction computed from the analytic flops model — present,
    positive, and <= 1 (nothing beats the hardware bound).
    """
    _require(isinstance(obj, dict), "device", "top level must be an object")
    for key in ("quick", "backend", "device", "interpret", "n_records",
                "n_segments", "n_queries", "numpy", "host_skipping",
                "device_batched", "device_sequential", "speedup",
                "batch8_speedup", "counts_match", "uploads_steady",
                "roofline", "roofline_frac"):
        _require(key in obj, "device", f"missing key {key!r}")
    _require(isinstance(obj["quick"], bool), "device", "'quick' must be bool")
    _require(isinstance(obj["backend"], str) and obj["backend"],
             "device", "backend must be a non-empty string")
    _require(isinstance(obj["interpret"], bool), "device",
             "'interpret' must be bool")
    for side in ("numpy", "host_skipping", "device_batched",
                 "device_sequential"):
        _check_fields(obj[side], _DEVICE_SIDE, side)
        _require(obj[side]["scan_s"] > 0, side, "scan_s must be positive")
    _require(obj["counts_match"] is True, "device",
             "device counts diverged from the quiesced host oracle")
    _require(obj["uploads_steady"] == 0, "device",
             "steady-state scans re-uploaded segment data "
             f"({obj['uploads_steady']} transfers; the resident plane is "
             "not resident)")
    _require(obj["n_segments"] >= 2, "device", "need >= 2 segments")
    _require(obj["n_queries"] >= 10, "device", "need >= 10 workload queries")
    floor = 0.5 if obj["quick"] else 2.0
    _require(obj["speedup"] >= floor, "device",
             f"device speedup {obj['speedup']} < required {floor}x over "
             "numpy-vectorized")
    b_floor = 0.8 if obj["quick"] else 3.0
    _require(obj["batch8_speedup"] >= b_floor, "device",
             f"batch-of-8 speedup {obj['batch8_speedup']} < required "
             f"{b_floor}x over 8 sequential scans")
    roof = obj["roofline"]
    _require(isinstance(roof, dict), "roofline", "must be an object")
    for key in ("device_flops", "device_bytes", "step_time_s",
                "measured_s", "dominant"):
        _require(key in roof, "roofline", f"missing key {key!r}")
    frac = obj["roofline_frac"]
    _require(isinstance(frac, numbers.Real) and not isinstance(frac, bool),
             "device", "roofline_frac must be a number")
    _require(0.0 < frac <= 1.0, "device",
             f"roofline_frac {frac} outside (0, 1]")


_BATCH_SIDE = {
    "scan_s": numbers.Real,
    "us_per_query": numbers.Real,
}


def validate_batch(obj: dict) -> None:
    """Raise :class:`SchemaError` unless ``obj`` is a valid batch artifact.

    Beyond shape, this gates the multi-query plane's CLAIM (DESIGN.md
    §16): per-query counts AND accounting bit-identical to the
    sequential scanner oracle, batch-of-8 >= 2x over sequential scans at
    full size (>= 0.8x for reduced-size ``--quick`` runs, which gate
    against collapse only — tiny stores leave little parse work for the
    batcher to share), and warm-cache repeats >= 5x over the uncached
    batch (>= 1.5x quick).
    """
    _require(isinstance(obj, dict), "batch", "top level must be an object")
    for key in ("quick", "n_records", "n_segments", "n_queries",
                "n_slices", "audit_key", "sequential", "batched",
                "speedup", "cache", "cache_speedup", "counts_match",
                "accounting_match"):
        _require(key in obj, "batch", f"missing key {key!r}")
    _require(isinstance(obj["quick"], bool), "batch", "'quick' must be bool")
    _require(isinstance(obj["audit_key"], str) and obj["audit_key"],
             "batch", "audit_key must be a non-empty string")
    for side in ("sequential", "batched"):
        _check_fields(obj[side], _BATCH_SIDE, side)
        _require(obj[side]["scan_s"] > 0, side, "scan_s must be positive")
    _check_fields(obj["cache"], {
        "warm_scan_s": numbers.Real,
        "uncached_scan_s": numbers.Real,
        "speedup": numbers.Real,
        "hits": numbers.Integral,
        "misses": numbers.Integral,
        "hit_rate": numbers.Real,
    }, "cache")
    _require(obj["counts_match"] is True, "batch",
             "batched counts diverged from the sequential oracle")
    _require(obj["accounting_match"] is True, "batch",
             "batched accounting diverged from the sequential oracle")
    _require(obj["n_queries"] >= 8, "batch", "need a panel of >= 8 queries")
    _require(obj["n_segments"] >= 2, "batch", "need >= 2 segments")
    _require(obj["cache"]["hits"] >= 1, "batch",
             "the warm pass never hit the result cache")
    floor = 0.8 if obj["quick"] else 2.0
    _require(obj["speedup"] >= floor, "batch",
             f"batch-of-{obj['n_queries']} speedup {obj['speedup']} < "
             f"required {floor}x over sequential scans")
    c_floor = 1.5 if obj["quick"] else 5.0
    _require(obj["cache_speedup"] >= c_floor, "batch",
             f"warm-cache speedup {obj['cache_speedup']} < required "
             f"{c_floor}x over the uncached batch")


def validate_serve(obj: dict) -> None:
    """Raise :class:`SchemaError` unless ``obj`` is a valid serve artifact.

    Beyond shape, this gates the async serving plane's CLAIM (DESIGN.md
    §17): every count answered during live ingest bounded by the
    ``matches_exact`` oracle and the quiesced panel BIT-IDENTICAL to it,
    p99 scan latency under live writes <= 3x the quiesced p99 at the
    same reader concurrency (<= 8x quick — tiny quick stores leave the
    snapshot churn nothing to amortize over), and aggregate scan
    throughput >= 2x the serialized ingest-then-scan loop (>= 0.5x
    quick, a collapse gate only).
    """
    _require(isinstance(obj, dict), "serve", "top level must be an object")
    for key in ("quick", "n_records", "n_chunks", "n_shards",
                "query_threads", "panel_size", "cpu_count", "serialized",
                "live", "quiesced", "throughput_speedup", "p99_ratio",
                "counts_match", "live_counts_bounded"):
        _require(key in obj, "serve", f"missing key {key!r}")
    _require(isinstance(obj["quick"], bool), "serve", "'quick' must be bool")
    _check_fields(obj["serialized"], {
        "ingest_s": numbers.Real,
        "total_s": numbers.Real,
        "queries": numbers.Integral,
        "qps": numbers.Real,
    }, "serialized")
    _check_fields(obj["live"], {
        "total_s": numbers.Real,
        "queries": numbers.Integral,
        "qps": numbers.Real,
        "p50_us": numbers.Real,
        "p99_us": numbers.Real,
        "blocked_s": numbers.Real,
    }, "live")
    _check_fields(obj["quiesced"], {
        "queries": numbers.Integral,
        "p50_us": numbers.Real,
        "p99_us": numbers.Real,
    }, "quiesced")
    for side in ("serialized", "live"):
        _require(obj[side]["total_s"] > 0, side, "total_s must be positive")
        _require(obj[side]["queries"] > 0, side, "queries must be positive")
    _require(obj["query_threads"] >= 8, "serve",
             "the claim is gated at >= 8 query threads")
    _require(obj["counts_match"] is True, "serve",
             "quiesced counts diverged from the matches_exact oracle")
    _require(obj["live_counts_bounded"] is True, "serve",
             "a live count exceeded the final oracle (phantom rows)")
    floor = 0.5 if obj["quick"] else 2.0
    _require(obj["throughput_speedup"] >= floor, "serve",
             f"aggregate scan throughput {obj['throughput_speedup']}x < "
             f"required {floor}x over the serialized ingest-then-scan loop")
    ceil = 8.0 if obj["quick"] else 3.0
    _require(obj["p99_ratio"] <= ceil, "serve",
             f"live p99 is {obj['p99_ratio']}x the quiesced p99 > "
             f"allowed {ceil}x")


def validate_tuner(obj: dict) -> None:
    """Raise :class:`SchemaError` unless ``obj`` is a valid tuner artifact.

    Beyond shape, this gates the online physical-design tuner's CLAIM
    (DESIGN.md §18): counts BIT-IDENTICAL to the ``matches_exact``
    oracle in every phase — before, during the background migration
    (checked continuously by the reader pool), and after; the router
    actually swapped to the drifted key and moved rows in >= 2 bounded
    batches (incremental, not stop-the-world); post-drift scan
    throughput recovered >= 1.5x over the stale layout (>= 0.8x quick —
    tiny quick stores leave pruning little to delete, CI gates against
    collapse only); and reader p99 during the migration <= 3x the
    quiesced p99 at the same concurrency on the same stale layout
    (<= 8x quick), i.e. background moves never stall readers.
    """
    _require(isinstance(obj, dict), "tuner", "top level must be an object")
    for key in ("quick", "n_records", "n_chunks", "n_shards",
                "query_threads", "panel_size", "cpu_count", "key_before",
                "key_after", "router_swapped", "before", "post_drift",
                "during", "quiesced", "after", "migration",
                "telemetry_tuner", "tuner_events", "recovery_speedup",
                "p99_ratio", "shards_pruned_after", "counts_match"):
        _require(key in obj, "tuner", f"missing key {key!r}")
    _require(isinstance(obj["quick"], bool), "tuner", "'quick' must be bool")
    panel = {
        "passes": numbers.Integral,
        "queries": numbers.Integral,
        "us_per_query": numbers.Real,
        "qps": numbers.Real,
        "counts_match": bool,
    }
    for phase in ("before", "post_drift", "after"):
        _check_fields(obj[phase], panel, phase)
        _require(obj[phase]["queries"] > 0, phase, "queries must be positive")
    _check_fields(obj["during"], {
        "migrate_s": numbers.Real,
        "queries": numbers.Integral,
        "p50_us": numbers.Real,
        "p99_us": numbers.Real,
    }, "during")
    _check_fields(obj["quiesced"], {
        "queries": numbers.Integral,
        "p50_us": numbers.Real,
        "p99_us": numbers.Real,
    }, "quiesced")
    _check_fields(obj["migration"], {
        "rows_moved": numbers.Integral,
        "rows_kept": numbers.Integral,
        "segments_moved": numbers.Integral,
        "items_skipped": numbers.Integral,
        "batches": numbers.Integral,
    }, "migration")
    _require(isinstance(obj["tuner_events"], list) and obj["tuner_events"],
             "tuner", "'tuner_events' must be a non-empty list")
    _require(obj["counts_match"] is True, "tuner",
             "a phase's counts diverged from the matches_exact oracle")
    _require(obj["router_swapped"] is True, "tuner",
             f"router never swapped to the drifted key "
             f"(still {obj['key_after']!r})")
    _require(obj["migration"]["rows_moved"] >= 1, "tuner",
             "the migration moved no rows")
    _require(obj["migration"]["batches"] >= 2, "tuner",
             "migration ran in one batch — not incremental")
    _require(obj["shards_pruned_after"] > 0, "tuner",
             "no partition pruning on the new routing key after migration")
    floor = 0.8 if obj["quick"] else 1.5
    _require(obj["recovery_speedup"] >= floor, "tuner",
             f"post-drift recovery {obj['recovery_speedup']}x < required "
             f"{floor}x over the stale layout")
    ceil = 8.0 if obj["quick"] else 3.0
    _require(obj["p99_ratio"] <= ceil, "tuner",
             f"reader p99 during migration is {obj['p99_ratio']}x the "
             f"quiesced p99 > allowed {ceil}x")


_SKIP_SIDE = {
    "scan_s": numbers.Real,
    "us_per_query": numbers.Real,
    "warm_scan_s": numbers.Real,
}


def validate_skip(obj: dict) -> None:
    """Raise :class:`SchemaError` unless ``obj`` is a valid skip artifact.

    Beyond shape, this gates the skipping-index registry's CLAIM
    (DESIGN.md §19): counts BIT-IDENTICAL to the ``matches_exact``
    oracle on the range/IN/substring workload for the skip path, the
    no-skip baseline, AND the reloaded checkpoints (format-6 round trip
    plus a format-5 manifest with the registry fields stripped —
    ``migration_ok``); >= 60% of (query, segment) visits pruned by the
    partition + zone cascade; and >= 5x fresh-evaluation scan speedup
    over the pruning-disabled baseline at full size (>= 1.5x for
    reduced-size ``--quick``/CI smoke runs).
    """
    _require(isinstance(obj, dict), "skip", "top level must be an object")
    for key in ("quick", "n_records", "n_shards", "n_segments",
                "n_queries", "noskip", "skip", "pruned_fraction",
                "speedup", "warm_speedup", "counts_match", "migration_ok"):
        _require(key in obj, "skip", f"missing key {key!r}")
    _require(isinstance(obj["quick"], bool), "skip", "'quick' must be bool")
    _check_fields(obj["noskip"], _SKIP_SIDE, "noskip")
    _check_fields(obj["skip"], dict(
        _SKIP_SIDE, segments_scanned=numbers.Integral,
        segments_zone_pruned=numbers.Integral,
        shard_visits_pruned=numbers.Integral), "skip")
    for side in ("noskip", "skip"):
        _require(obj[side]["scan_s"] > 0, side, "scan_s must be positive")
    _require(obj["counts_match"] is True, "skip",
             "skip-path or no-skip counts diverged from the "
             "matches_exact oracle")
    _require(obj["migration_ok"] is True, "skip",
             "checkpoint round trip failed (format-6 reload or format-5 "
             "migration diverged from the oracle)")
    _require(obj["n_segments"] >= 2, "skip", "need >= 2 segments")
    _require(obj["n_queries"] >= 10, "skip", "need >= 10 workload queries")
    _require(obj["skip"]["segments_zone_pruned"] >= 1, "skip",
             "zone maps never pruned a segment")
    _require(obj["skip"]["shard_visits_pruned"] >= 1, "skip",
             "partition metadata never pruned a shard visit")
    _require(0.0 <= obj["pruned_fraction"] <= 1.0, "skip",
             "pruned_fraction out of [0, 1]")
    _require(obj["pruned_fraction"] >= 0.6, "skip",
             f"pruned_fraction {obj['pruned_fraction']} < required 0.6 "
             "on the selective range/IN/substring workload")
    floor = 1.5 if obj["quick"] else 5.0
    _require(obj["speedup"] >= floor, "skip",
             f"skip speedup {obj['speedup']} < required {floor}x")


_VALIDATORS = {
    "bench_kernels.json": validate_kernels,
    "BENCH_kernels.json": validate_kernels,
    "bench_replan.json": validate_replan,
    "bench_tiers.json": validate_tiers,
    "BENCH_tiers.json": validate_tiers,
    "bench_scan.json": validate_scan,
    "BENCH_scan.json": validate_scan,
    "bench_shard.json": validate_shard,
    "BENCH_shard.json": validate_shard,
    "bench_device.json": validate_device,
    "BENCH_device.json": validate_device,
    "bench_batch.json": validate_batch,
    "BENCH_batch.json": validate_batch,
    "bench_serve.json": validate_serve,
    "BENCH_serve.json": validate_serve,
    "bench_tuner.json": validate_tuner,
    "BENCH_tuner.json": validate_tuner,
    "bench_skip.json": validate_skip,
    "BENCH_skip.json": validate_skip,
}


def validate_file(path: str) -> str:
    """Validate one artifact by filename convention; returns the kind."""
    name = path.rsplit("/", 1)[-1]
    validator = _VALIDATORS.get(name)
    if validator is None:
        raise SchemaError(f"no schema registered for {name!r}")
    with open(path) as f:
        try:
            obj = json.load(f)
        except json.JSONDecodeError as e:
            raise SchemaError(f"{path}: not valid JSON ({e})") from e
    validator(obj)
    return name


def main(argv: list[str]) -> int:
    if not argv:
        print("usage: python -m benchmarks.bench_schema FILE [FILE ...]",
              file=sys.stderr)
        return 2
    for path in argv:
        try:
            validate_file(path)
        except SchemaError as e:
            print(f"SCHEMA FAIL {path}: {e}", file=sys.stderr)
            return 1
        print(f"schema ok: {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
