"""Compatibility shims for optional third-party packages.

The tier-1 environment bakes in the jax toolchain but not every dev
dependency; modules here provide gated stand-ins (see conftest.py) so the
test suite collects and runs without network access.
"""
