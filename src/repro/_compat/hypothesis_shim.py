"""Minimal stand-in for the ``hypothesis`` property-testing API.

Activated by conftest.py ONLY when the real package is not installed.  It
implements the subset our tests use — ``given`` / ``settings`` and the
``integers`` / ``booleans`` / ``sampled_from`` / ``lists`` / ``text`` /
``composite`` strategies — as deterministic random sampling: each test
function gets a fixed per-test seed, so failures reproduce run-to-run.

No shrinking, no database, no health checks.  When real hypothesis is
available it takes priority (conftest tries the real import first), so this
shim never shadows the genuine article.
"""
from __future__ import annotations

import functools
import inspect
import random
import zlib
from typing import Any, Callable, Sequence

DEFAULT_MAX_EXAMPLES = 100


class Strategy:
    """Base strategy: subclasses implement ``do_draw(rng)``."""

    def do_draw(self, rng: random.Random) -> Any:  # pragma: no cover
        raise NotImplementedError

    def example(self, rng: random.Random) -> Any:
        return self.do_draw(rng)


class _Integers(Strategy):
    def __init__(self, min_value: int, max_value: int):
        self.min_value, self.max_value = min_value, max_value

    def do_draw(self, rng):
        # bias a little toward the endpoints (cheap boundary coverage)
        r = rng.random()
        if r < 0.05:
            return self.min_value
        if r < 0.10:
            return self.max_value
        return rng.randint(self.min_value, self.max_value)


class _Booleans(Strategy):
    def do_draw(self, rng):
        return rng.random() < 0.5


class _SampledFrom(Strategy):
    def __init__(self, elements: Sequence[Any]):
        self.elements = list(elements)

    def do_draw(self, rng):
        return rng.choice(self.elements)


class _Lists(Strategy):
    def __init__(self, elements: Strategy, min_size=0, max_size=None, unique=False):
        self.elements = elements
        self.min_size = min_size
        self.max_size = max_size if max_size is not None else min_size + 10
        self.unique = unique

    def do_draw(self, rng):
        if self.unique and isinstance(self.elements, _SampledFrom):
            pool = list(self.elements.elements)
            hi = min(self.max_size, len(pool))
            lo = min(self.min_size, hi)
            n = rng.randint(lo, hi)
            return rng.sample(pool, n)
        n = rng.randint(self.min_size, self.max_size)
        out, seen = [], set()
        attempts = 0
        while len(out) < n and attempts < 100 * (n + 1):
            v = self.elements.do_draw(rng)
            attempts += 1
            if self.unique:
                k = repr(v)
                if k in seen:
                    continue
                seen.add(k)
            out.append(v)
        return out


class _Text(Strategy):
    def __init__(self, alphabet=None, min_size=0, max_size=None):
        self.alphabet = alphabet or "abcdefghijklmnopqrstuvwxyz "
        self.min_size = min_size
        self.max_size = max_size if max_size is not None else min_size + 12

    def do_draw(self, rng):
        n = rng.randint(self.min_size, self.max_size)
        return "".join(rng.choice(self.alphabet) for _ in range(n))


class _Composite(Strategy):
    def __init__(self, fn: Callable, args: tuple, kwargs: dict):
        self.fn, self.args, self.kwargs = fn, args, kwargs

    def do_draw(self, rng):
        draw = lambda strategy: strategy.do_draw(rng)  # noqa: E731
        return self.fn(draw, *self.args, **self.kwargs)


class strategies:  # mirrors `from hypothesis import strategies as st`
    @staticmethod
    def integers(min_value: int = 0, max_value: int = 2**31) -> Strategy:
        return _Integers(min_value, max_value)

    @staticmethod
    def booleans() -> Strategy:
        return _Booleans()

    @staticmethod
    def sampled_from(elements) -> Strategy:
        return _SampledFrom(elements)

    @staticmethod
    def lists(elements, *, min_size=0, max_size=None, unique=False) -> Strategy:
        return _Lists(elements, min_size, max_size, unique)

    @staticmethod
    def text(alphabet=None, *, min_size=0, max_size=None) -> Strategy:
        return _Text(alphabet, min_size, max_size)

    @staticmethod
    def composite(fn: Callable) -> Callable[..., Strategy]:
        @functools.wraps(fn)
        def make(*args, **kwargs):
            return _Composite(fn, args, kwargs)

        return make


def settings(*, max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    """Decorator recording the example budget on the test function."""

    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn

    return deco


class _Unsatisfied(Exception):
    pass


def assume(condition: bool) -> bool:
    """Abort the current example (not the test) when condition is false."""
    if not condition:
        raise _Unsatisfied()
    return True


def given(*arg_strategies: Strategy, **kw_strategies: Strategy):
    """Run the test once per drawn example, deterministically seeded."""

    def deco(fn):
        @functools.wraps(fn)
        def runner(*fixture_args, **fixture_kwargs):
            inner = fn
            n = getattr(inner, "_shim_max_examples", None)
            if n is None:
                n = getattr(runner, "_shim_max_examples", DEFAULT_MAX_EXAMPLES)
            seed = zlib.crc32(f"{fn.__module__}.{fn.__qualname__}".encode())
            rng = random.Random(seed)
            ran = 0
            attempts = 0
            while ran < n and attempts < 5 * n + 50:
                attempts += 1
                args = tuple(s.do_draw(rng) for s in arg_strategies)
                kwargs = {k: s.do_draw(rng) for k, s in kw_strategies.items()}
                try:
                    fn(*fixture_args, *args, **fixture_kwargs, **kwargs)
                except _Unsatisfied:
                    continue
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example (shim seed {seed}, example {ran}): "
                        f"args={args!r} kwargs={kwargs!r}"
                    ) from e
                ran += 1

        # pytest must not mistake the drawn parameters for fixtures: hide
        # them from the reported signature (the wrapper fills them itself).
        if hasattr(runner, "__wrapped__"):
            del runner.__wrapped__
        try:
            sig = inspect.signature(fn)
            params = list(sig.parameters.values())
            n_drawn = len(arg_strategies)
            keep = params[: len(params) - n_drawn] if n_drawn else params
            keep = [p for p in keep if p.name not in kw_strategies]
            runner.__signature__ = sig.replace(parameters=keep)
        except (TypeError, ValueError):  # pragma: no cover
            pass
        return runner

    return deco


class HealthCheck:  # accepted and ignored
    too_slow = "too_slow"
    data_too_large = "data_too_large"
    filter_too_much = "filter_too_much"

    @classmethod
    def all(cls):
        return []
