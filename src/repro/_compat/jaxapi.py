"""Shims over jax APIs whose shapes changed across versions.

The mesh-context helpers (``current_mesh`` / ``use_mesh``) live in
:mod:`repro.dist.sharding` next to their consumers; everything else
version-sensitive goes here.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_mesh(dev_array, axes) -> Mesh:
    """Mesh constructor tolerant of pre-AxisType jax versions."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return Mesh(dev_array, axes, axis_types=(axis_type.Auto,) * len(axes))
    return Mesh(dev_array, axes)


def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` as a dict on every jax version.

    Older jax returns one dict per device (a list); newer jax returns the
    dict directly.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    return cost
