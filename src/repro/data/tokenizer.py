"""Byte-level tokenizer with arch-sized vocab mapping.

The framework trains on CIAO-filtered JSON records.  We tokenize at the byte
level (deterministic, no external vocab files) and fold the 256 byte ids +
specials into whatever vocab size the target architecture declares: byte ids
occupy [0, 256), specials follow, and the remaining id space is reached via a
seeded, fixed *byte-pair folding* (pairs of frequent bytes get dedicated ids)
so embedding tables of the assigned sizes are genuinely exercised.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

PAD_ID = 256
BOS_ID = 257
EOS_ID = 258
N_SPECIALS = 3


@dataclass(frozen=True)
class ByteTokenizer:
    vocab_size: int
    pair_seed: int = 0

    def __post_init__(self) -> None:
        if self.vocab_size < 256 + N_SPECIALS:
            raise ValueError("vocab_size must be >= 259")

    def _pair_table(self) -> np.ndarray:
        """(n_pairs, 2) byte pairs that map to ids >= 259 (seeded, fixed)."""
        n_pairs = min(self.vocab_size - 256 - N_SPECIALS, 65536)
        rng = np.random.default_rng(self.pair_seed)
        pairs = rng.integers(32, 127, size=(n_pairs, 2), dtype=np.int32)
        return pairs

    def encode(self, data: bytes, *, max_len: int | None = None,
               add_bos: bool = True, add_eos: bool = True) -> np.ndarray:
        ids = np.frombuffer(data, dtype=np.uint8).astype(np.int32)
        if self.vocab_size > 256 + N_SPECIALS and len(ids) >= 2:
            pairs = self._pair_table()
            # greedy non-overlapping fold of known pairs (vectorized probe)
            key = ids[:-1].astype(np.int64) * 256 + ids[1:]
            table = {}
            for i, (a, b) in enumerate(pairs):
                table.setdefault(int(a) * 256 + int(b), 256 + N_SPECIALS + i)
            out = []
            i = 0
            while i < len(ids):
                if i + 1 < len(ids) and int(key[i]) in table:
                    out.append(table[int(key[i])])
                    i += 2
                else:
                    out.append(int(ids[i]))
                    i += 1
            ids = np.array(out, dtype=np.int32)
        if add_bos:
            ids = np.concatenate([[BOS_ID], ids])
        if add_eos:
            ids = np.concatenate([ids, [EOS_ID]])
        if max_len is not None:
            ids = ids[:max_len]
        return ids.astype(np.int32)

    def pad_batch(self, seqs: list[np.ndarray], seq_len: int) -> np.ndarray:
        out = np.full((len(seqs), seq_len), PAD_ID, dtype=np.int32)
        for i, s in enumerate(seqs):
            n = min(len(s), seq_len)
            out[i, :n] = s[:n]
        return out
