"""Seeded synthetic JSON datasets, schema-faithful to the paper (§VII-B).

Three generators mirroring the paper's datasets and their predicate templates
(Table II):

  * ``yelp``   — review objects: stars/useful/funny/cool ints, user_id,
    free text, date.
  * ``winlog`` — Windows system log rows: time, level, service, info message.
  * ``ycsb``   — fakeit-style customer objects: isActive, scores,
    phone_country, age_group, url_domain/site, email, and filler attributes.

Records are emitted as JSON bytes (one object per record).  All draws are
seeded; the same (dataset, seed, n) is bit-identical across runs, which the
ingest checkpoint/restart tests rely on.
"""
from __future__ import annotations

import json
from typing import Callable, Iterator

import numpy as np

from repro.core.predicates import (
    Clause,
    clause,
    exact,
    key_value,
    presence,
    substring,
)

_WORDS = (
    "delicious amazing terrible friendly slow fast cozy loud quiet great "
    "awful fresh stale crowded empty cheap pricey clean dirty lovely bland "
    "spicy sweet salty crispy tender juicy dry warm cold attentive rude"
).split()

_SERVICES = (
    "CBS TrustedInstaller WindowsUpdateAgent SessionManager NetworkProfile "
    "Defender Scheduler DHCP DNSCache EventLog"
).split()

_LOG_TEMPLATES = (
    "Loaded Servicing Stack v6.1.7601.{n} with Core",
    "Warning: Unrecognized packageExtended attribute {n}",
    "Failed to connect to endpoint {n} retrying",
    "Read out cached package applicability for package {n}",
    "Session {n} initialized by client WindowsUpdateAgent",
    "Expecting attribute name {n} in manifest",
    "Service {n} entered the running state",
    "Scavenging cache entry {n} complete",
)

_DOMAINS = "com org net io edu gov co uk de jp fr ca".split()
_SITES = (
    "alpha beta gamma delta epsilon zeta eta theta iota kappa lambdaone mutual"
).split()
_COUNTRIES = ["US", "CN", "IN"]
_AGE_GROUPS = ["child", "young", "adult", "senior"]
_LEVELS = ["Info", "Warning", "Error"]


def _text(rng: np.random.Generator, n_words: int) -> str:
    idx = rng.integers(0, len(_WORDS), size=n_words)
    return " ".join(_WORDS[i] for i in idx)


def yelp_record(rng: np.random.Generator) -> dict:
    y, mo, d = int(rng.integers(2005, 2019)), int(rng.integers(1, 13)), int(rng.integers(1, 29))
    return {
        "review_id": f"r{int(rng.integers(0, 10**9)):09d}",
        "user_id": f"u{int(rng.integers(0, 50)):04d}",
        "business_id": f"b{int(rng.integers(0, 10**6)):07d}",
        "stars": int(rng.integers(1, 6)),
        "useful": int(rng.geometric(0.08) - 1) % 100,
        "funny": int(rng.geometric(0.12) - 1) % 100,
        "cool": int(rng.geometric(0.10) - 1) % 100,
        "text": _text(rng, int(rng.integers(8, 40))),
        "date": f"{y:04d}-{mo:02d}-{d:02d}",
    }


def winlog_record(rng: np.random.Generator) -> dict:
    mo, d = int(rng.integers(1, 13)), int(rng.integers(1, 29))
    h, mi, s = int(rng.integers(0, 24)), int(rng.integers(0, 60)), int(rng.integers(0, 60))
    tpl = _LOG_TEMPLATES[int(rng.integers(0, len(_LOG_TEMPLATES)))]
    return {
        "time": f"2016-{mo:02d}-{d:02d} {h:02d}:{mi:02d}:{s:02d},{int(rng.integers(0,1000)):03d}",
        "level": _LEVELS[int(rng.choice(3, p=[0.8, 0.15, 0.05]))],
        "service": _SERVICES[int(rng.integers(0, len(_SERVICES)))],
        "info": tpl.format(n=int(rng.integers(0, 100000))),
    }


def ycsb_record(rng: np.random.Generator) -> dict:
    age_group = _AGE_GROUPS[int(rng.integers(0, 4))]
    dom = _DOMAINS[int(rng.integers(0, len(_DOMAINS)))]
    site = _SITES[int(rng.integers(0, len(_SITES)))]
    first = _text(rng, 1)
    rec = {
        "customer_id": int(rng.integers(0, 10**8)),
        "isActive": bool(rng.random() < 0.5),
        "linear_score": int(rng.integers(0, 100)),
        "weighted_score": int(rng.integers(0, 100)),
        "phone_country": _COUNTRIES[int(rng.choice(3, p=[0.5, 0.3, 0.2]))],
        "age_group": age_group,
        "age_by_group": int(rng.integers(0, 100)),
        "url_domain": dom,
        "url_site": f"www.{site}.{dom}",
        "email": f"{first}{int(rng.integers(0,999))}@{site}.{dom}",
        "name": first.capitalize(),
        "children": int(rng.integers(0, 5)),
        "address": f"{int(rng.integers(1,9999))} {_text(rng,1)} st",
        "phone": f"+{int(rng.integers(1,99))}-{int(rng.integers(10**6,10**7))}",
        "visits": int(rng.integers(0, 1000)),
    }
    return rec


_GENERATORS: dict[str, Callable[[np.random.Generator], dict]] = {
    "yelp": yelp_record,
    "winlog": winlog_record,
    "ycsb": ycsb_record,
}


def generate_records(dataset: str, n: int, seed: int = 0) -> list[bytes]:
    gen = _GENERATORS[dataset]
    rng = np.random.default_rng(seed)
    return [json.dumps(gen(rng), separators=(",", ":")).encode() for _ in range(n)]


def record_stream(dataset: str, seed: int = 0) -> Iterator[bytes]:
    gen = _GENERATORS[dataset]
    rng = np.random.default_rng(seed)
    while True:
        yield json.dumps(gen(rng), separators=(",", ":")).encode()


# ---------------------------------------------------------------------------
# predicate pools per dataset (paper Table II)
# ---------------------------------------------------------------------------

def predicate_pool(dataset: str, rng: np.random.Generator | None = None) -> list[Clause]:
    rng = rng or np.random.default_rng(1)
    pool: list[Clause] = []
    if dataset == "yelp":
        for field_name, n_cand in (("useful", 100), ("cool", 100), ("funny", 100)):
            for v in range(n_cand):
                pool.append(clause(key_value(field_name, v)))
        for v in range(1, 6):
            pool.append(clause(key_value("stars", v)))
        for v in range(5):
            pool.append(clause(exact("user_id", f"u{v:04d}")))
        for w in _WORDS[:5]:
            pool.append(clause(substring("text", w)))
        for y in range(2005, 2019):
            pool.append(clause(substring("date", f"{y:04d}-")))
        for mo in range(1, 13):
            pool.append(clause(substring("date", f"-{mo:02d}-")))
    elif dataset == "winlog":
        # info LIKE <string>: 200 candidates drawn from template fragments
        frags = [
            "Servicing Stack", "Unrecognized", "Failed to connect", "cached package",
            "initialized by client", "attribute name", "running state", "Scavenging",
        ]
        for i in range(200):
            f = frags[i % len(frags)]
            pool.append(clause(substring("info", f if i < len(frags) else f"{f} {i}")))
        for mo in range(1, 13):
            pool.append(clause(substring("time", f"-{mo:02d}-")))
        for d in range(1, 29):
            pool.append(clause(substring("time", f"-{d:02d} ")))
        for h in range(0, 24):
            pool.append(clause(substring("time", f" {h:02d}:")))
        for mi in range(0, 60):
            pool.append(clause(substring("time", f":{mi:02d}:")))
        for s in range(0, 60):
            pool.append(clause(substring("time", f":{s:02d},")))
    elif dataset == "ycsb":
        for b in (True, False):
            pool.append(clause(key_value("isActive", b)))
        for f in ("linear_score", "weighted_score", "age_by_group"):
            for v in range(100):
                pool.append(clause(key_value(f, v)))
        for c in _COUNTRIES:
            pool.append(clause(exact("phone_country", c)))
        for g in _AGE_GROUPS:
            pool.append(clause(exact("age_group", g)))
        for d in _DOMAINS:
            pool.append(clause(substring("url_domain", d)))
        for s in _SITES:
            pool.append(clause(substring("url_site", f"www.{s}.")))
        pool.append(clause(substring("email", "@")))
        pool.append(clause(presence("email")))
    else:
        raise ValueError(f"unknown dataset {dataset!r}")
    return pool


DATASETS = tuple(_GENERATORS)
