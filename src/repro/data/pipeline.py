"""CIAO-integrated training-data pipeline.

Flow (DESIGN.md §2):

  client shards (raw JSON) ──chunks+bitvectors──▶ ingest ──▶ CiaoStore
        ──recipe query (bitvector AND + verify)──▶ token batches ──▶ device

Pieces:
  * :class:`ClientShard` — one data client: seeded record stream, chunk
    encoding, client-side predicate evaluation under its budget class.
  * :class:`IngestCoordinator` — pulls chunks from many clients with a
    work-stealing scheduler (straggler mitigation: idle fast clients claim
    pending chunks of the slowest; virtual-time simulated, deterministic).
  * :class:`RecipeBatcher` — data-skipping selection of recipe-matching rows
    from the store, tokenization, fixed-shape (batch, seq) arrays.
  * :class:`Prefetcher` — background-thread double buffering so host-side
    CIAO work overlaps device compute (the paper's latency-hiding bet).
"""
from __future__ import annotations

import json
import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Iterator, Sequence

import numpy as np

from repro.core import bitvector
from repro.core.client import Chunk, encode_chunk
from repro.core.predicates import Query
from repro.core.server import CiaoStore, PushdownPlan, StaleEpochError
from repro.data.datasets import record_stream
from repro.data.tokenizer import ByteTokenizer


@dataclass
class ClientShard:
    """One data client with its own seed, engine, and speed class.

    Plans are **hot-swappable** between chunks (:meth:`set_plan`): a replan
    broadcast lands as a plain attribute swap, and the kernel engines only
    retrace when the new compiled plan falls in a new ``(P, Mk, Mv)``
    shape bucket (``kernels.plan`` pads pattern widths; ``kernels.ops``
    pads record counts) — same-bucket epochs reuse the jit cache.

    Each shard accumulates measured eval wall-clock
    (:meth:`observed_us_per_record`) — the cost-model recalibration
    feedback the replanner consumes (paper §V-D).
    """

    dataset: str
    shard_id: int
    engine: object                      # core engine protocol
    plan: PushdownPlan
    chunk_records: int = 512
    speed: float = 1.0                  # relative records/sec (straggler sim)

    def __post_init__(self) -> None:
        self._stream = record_stream(self.dataset, seed=1000 + self.shard_id)
        self.eval_time_s = 0.0
        self.eval_records = 0

    def set_plan(self, plan: PushdownPlan) -> None:
        """Epoch bump: evaluate every subsequent chunk under ``plan``."""
        self.plan = plan

    def next_chunk(self) -> tuple[Chunk, bitvector.ChunkBitvectors]:
        recs = [next(self._stream) for _ in range(self.chunk_records)]
        chunk = encode_chunk(recs)
        # fused single-pass evaluation: the ingest load mask ships
        # precomputed alongside the bitvectors (one launch on kernel engines)
        t0 = time.perf_counter()
        bv = self.engine.eval_fused(chunk, self.plan.clauses)
        self.eval_time_s += time.perf_counter() - t0
        self.eval_records += chunk.n_records
        return chunk, bv

    def observed_us_per_record(self) -> float:
        if not self.eval_records:
            return 0.0
        return self.eval_time_s / self.eval_records * 1e6


@dataclass(order=True)
class _Pending:
    ready_at: float
    seq: int
    client_idx: int = field(compare=False)


class IngestCoordinator:
    """Work-stealing chunk scheduler over N clients (virtual time).

    Each client owns a backlog of `chunks_per_client` chunk slots.  A chunk
    produced by client i takes 1/speed_i virtual seconds.  When a fast client
    drains its backlog it steals a slot from the most-backlogged client and
    produces that chunk itself (clients are stateless record producers in
    this simulation, so stealing = re-assigning the production slot).  This
    bounds makespan by the fastest clients instead of the slowest — the
    framework's straggler-mitigation story, testable without wall-clock.
    """

    def __init__(self, clients: Sequence[ClientShard], store: CiaoStore,
                 *, steal: bool = True, replanner=None,
                 on_chunk: Callable[[int], None] | None = None):
        self.clients = list(clients)
        self.store = store
        self.steal = steal
        self.replanner = replanner          # core.replan.Replanner protocol
        self.on_chunk = on_chunk            # called with #chunks ingested
        self.stolen = 0
        self.makespan = 0.0
        self.epoch_bumps = 0

    def _broadcast(self, plan) -> None:
        """Epoch bump: every shard evaluates subsequent chunks under it."""
        for c in self.clients:
            c.set_plan(plan)
        self.epoch_bumps += 1

    def run(self, chunks_per_client: int) -> None:
        backlog = [chunks_per_client for _ in self.clients]
        clock = [0.0 for _ in self.clients]
        total = chunks_per_client * len(self.clients)
        done = 0
        while done < total:
            # next client to finish a chunk = argmin over clock+1/speed
            i = min(
                range(len(self.clients)),
                key=lambda k: clock[k] + 1.0 / self.clients[k].speed
                if backlog[k] > 0 or (self.steal and max(backlog) > 0)
                else float("inf"),
            )
            if backlog[i] == 0:
                if not self.steal:
                    continue
                j = int(np.argmax(backlog))
                if backlog[j] == 0:
                    break
                backlog[j] -= 1
                self.stolen += 1
            else:
                backlog[i] -= 1
            client = self.clients[i]
            eval_before = client.eval_time_s
            chunk, bv = client.next_chunk()
            # plan-eval wall-clock only (the shard times eval_fused
            # itself) — record generation/encoding must not leak into the
            # replanner's cost-model recalibration
            eval_s = client.eval_time_s - eval_before
            # chunks carry their evaluation epoch; the window between a
            # broadcast and a client's next chunk is where staleness lives,
            # so a StaleEpochError re-evaluates under the current plan
            try:
                self.store.ingest_chunk(chunk, bv,
                                        epoch=client.plan.epoch)
            except StaleEpochError:
                client.set_plan(self.store.plan)
                bv = client.engine.eval_fused(chunk, client.plan.clauses)
                self.store.ingest_chunk(chunk, bv,
                                        epoch=client.plan.epoch)
            clock[i] += 1.0 / client.speed
            done += 1
            if self.on_chunk is not None:
                self.on_chunk(done)
            if self.replanner is not None:
                self.replanner.observe_timing(chunk.n_records, eval_s)
                new_plan = self.replanner.step()
                if new_plan is not None:
                    self._broadcast(new_plan)
        self.makespan = max(clock)


class RecipeBatcher:
    """Turns recipe-matching store rows into fixed-shape token batches."""

    def __init__(self, store: CiaoStore, tokenizer: ByteTokenizer,
                 *, seq_len: int, batch_size: int):
        self.store = store
        self.tok = tokenizer
        self.seq_len = seq_len
        self.batch_size = batch_size

    def matching_records(self, recipe: Query) -> Iterator[bytes]:
        # epoch-aware skipping: each block's bitvector rows follow ITS
        # ingest epoch's plan, and raw remainders are JIT-promoted only for
        # epochs that push none of the recipe — the skippability invariant
        # is single-sourced in the store's query-path helpers
        store = self.store
        pushed_by_epoch = store.pushed_by_epoch(recipe)
        for blk in store.blocks:
            pushed = pushed_by_epoch[blk.epoch]
            if pushed:
                words = bitvector.bv_and_many(blk.bitvectors[pushed])
                idx = bitvector.select_indices(words, blk.n_rows)
            else:
                idx = range(blk.n_rows)
            for i in idx:
                row = blk.rows[i]
                if recipe.matches_exact(row):
                    yield json.dumps(row, separators=(",", ":")).encode()
        store.promote_uncovered_raw(pushed_by_epoch)
        for blk in store.jit_blocks:
            if pushed_by_epoch[blk.epoch]:
                continue
            for row in blk.rows:
                if recipe.matches_exact(row):
                    yield json.dumps(row, separators=(",", ":")).encode()

    def batches(self, recipe: Query, *, repeat: bool = True
                ) -> Iterator[tuple[np.ndarray, np.ndarray]]:
        """Yields (tokens, loss_mask) of shape (batch, seq_len): packed docs."""
        buf: list[int] = []
        while True:
            made_any = False
            for rec in self.matching_records(recipe):
                made_any = True
                buf.extend(self.tok.encode(rec).tolist())
                while len(buf) >= self.batch_size * self.seq_len:
                    flat = np.array(
                        buf[: self.batch_size * self.seq_len], dtype=np.int32
                    )
                    del buf[: self.batch_size * self.seq_len]
                    tokens = flat.reshape(self.batch_size, self.seq_len)
                    mask = np.ones_like(tokens, dtype=np.float32)
                    yield tokens, mask
            if not repeat or not made_any:
                return


class Prefetcher:
    """Double-buffered background prefetch (host CIAO work ∥ device step)."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._done = object()
        self._err: BaseException | None = None

        def worker() -> None:
            try:
                for item in it:
                    self._q.put(item)
            except BaseException as e:  # propagate to consumer
                self._err = e
            finally:
                self._q.put(self._done)

        self._t = threading.Thread(target=worker, daemon=True)
        self._t.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item
