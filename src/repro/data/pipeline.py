"""CIAO-integrated training-data pipeline.

Flow (DESIGN.md §2):

  client shards (raw JSON) ──chunks+bitvectors──▶ ingest ──▶ CiaoStore
        ──recipe query (bitvector AND + verify)──▶ token batches ──▶ device

Pieces:
  * :class:`ClientShard` — one data client: seeded record stream, chunk
    encoding, client-side predicate evaluation under its budget class.
  * :class:`IngestCoordinator` — pulls chunks from many clients with a
    work-stealing scheduler (straggler mitigation: idle fast clients claim
    pending chunks of the slowest; virtual-time simulated, deterministic).
  * :class:`RecipeBatcher` — data-skipping selection of recipe-matching rows
    from the store, tokenization, fixed-shape (batch, seq) arrays.
  * :class:`Prefetcher` — background-thread double buffering so host-side
    CIAO work overlaps device compute (the paper's latency-hiding bet).
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Iterator, Sequence

import numpy as np

from repro.core import bitvector
from repro.core.client import Chunk, encode_chunk
from repro.core.columnar import query_mask
from repro.core.predicates import Query
from repro.core.selection import ClientProfile, TierAllocation, allocate_tiers
from repro.core.server import (
    CiaoStore, PlanFamily, PushdownPlan, StaleEpochError,
)
from repro.core.shard import ShardedCiaoStore
from repro.data.datasets import record_stream
from repro.data.tokenizer import ByteTokenizer

# every store front-end the pipeline drives: the coordinator and batcher
# only touch the shared protocol (ingest_chunk / plan / family / blocks /
# pushed_by_epoch), so a sharded store slots in without pipeline changes —
# the ShardRouter inside ShardedCiaoStore.ingest_chunk fans each chunk out
# to its per-shard segment stores.  The async serving plane's
# CiaoServeEngine (repro.serve.store_engine, DESIGN.md §17) duck-types
# the same ingest surface — validation stays synchronous at submit, so
# the coordinator's StaleEpochError retry loop works against it
# unchanged even though the actual ingest happens on a writer pool.
AnyStore = CiaoStore | ShardedCiaoStore


@dataclass
class ClientShard:
    """One data client with its own seed, engine, speed class, and tier.

    Plans are **hot-swappable** between chunks (:meth:`set_plan` /
    :meth:`set_family`): a replan broadcast lands as a plain attribute
    swap, and the kernel engines only retrace when the new compiled plan
    falls in a new ``(P, Mk, Mv)`` shape bucket (``kernels.plan`` pads
    pattern widths; ``kernels.ops`` pads record counts) — same-bucket
    epochs reuse the jit cache, and ALL tiers of one family share one
    trace (``kernels.plan.tier_view``).

    Each shard accumulates measured eval wall-clock
    (:meth:`observed_us_per_record`) — the cost-model recalibration
    feedback the replanner consumes (paper §V-D).  Measured eval time is
    divided by ``speed``, so a simulated slow device reports
    proportionally slower evaluation; :attr:`cost_scale` tracks an EWMA
    of measured-vs-modeled µs (the allocator's per-client speed signal),
    seeded with the ``1/speed`` prior until real timings arrive.
    """

    dataset: str
    shard_id: int
    engine: object                      # core engine protocol
    plan: PushdownPlan
    chunk_records: int = 512
    speed: float = 1.0                  # relative records/sec (straggler sim)
    family: PlanFamily | None = None    # tiered deployments only
    tier: int = 0
    cost_ewma_alpha: float = 0.3
    # optional core.telemetry.TelemetryPlane (typically the store's):
    # every evaluation reports its measured wall-clock there, feeding
    # FleetTierAllocator measured per-client rates (DESIGN.md §16)
    telemetry: object | None = None

    def __post_init__(self) -> None:
        self._stream = record_stream(self.dataset, seed=1000 + self.shard_id)
        self.eval_time_s = 0.0
        self.eval_records = 0
        self.cost_scale = 1.0 / self.speed
        if self.family is not None:
            self.set_family(self.family, self.tier)

    def set_plan(self, plan: PushdownPlan) -> None:
        """Epoch bump: evaluate every subsequent chunk under ``plan``."""
        self.plan = plan
        self.family = None
        self.tier = 0

    def set_family(self, family: PlanFamily, tier: int | None = None) -> None:
        """Tiered epoch bump and/or re-tier: evaluate the tier's prefix."""
        self.family = family
        self.plan = family.plan
        if tier is not None:
            self.set_tier(tier)
        elif self.tier >= family.n_tiers:
            self.tier = family.top_tier

    def set_tier(self, tier: int) -> None:
        if self.family is None:
            raise ValueError("set_tier needs a PlanFamily (set_family first)")
        if not 0 <= tier < self.family.n_tiers:
            raise ValueError(
                f"tier {tier} out of range: family has "
                f"{self.family.n_tiers} tiers")
        self.tier = tier

    @property
    def tier_size(self) -> int:
        if self.family is None:
            return self.plan.n
        return self.family.tier_sizes[self.tier]

    def evaluate(self, chunk: Chunk) -> bitvector.ChunkBitvectors:
        """Tier-aware fused evaluation of one chunk, timed and accounted.

        The single eval dispatch for BOTH the normal produce path and the
        coordinator's stale-epoch retry: every evaluation — retries
        included — lands in ``eval_time_s`` / the cost-scale EWMA, so the
        allocator's per-client speed signal sees all the work done.
        """
        t0 = time.perf_counter()
        if self.family is not None:
            prefix = getattr(self.engine, "eval_fused_prefix", None)
            if prefix is not None:
                bv = prefix(chunk, self.plan.clauses, self.tier_size)
            else:
                bv = self.engine.eval_fused(
                    chunk, self.plan.clauses[: self.tier_size])
        else:
            bv = self.engine.eval_fused(chunk, self.plan.clauses)
        dt = (time.perf_counter() - t0) / self.speed
        self.eval_time_s += dt
        self.eval_records += chunk.n_records
        self._update_cost_scale(dt, chunk.n_records)
        if self.telemetry is not None:
            self.telemetry.record_client_eval(
                self.shard_id, dt, chunk.n_records)
        return bv

    def next_chunk(self) -> tuple[Chunk, bitvector.ChunkBitvectors]:
        recs = [next(self._stream) for _ in range(self.chunk_records)]
        chunk = encode_chunk(recs)
        # fused single-pass evaluation: the ingest load mask ships
        # precomputed alongside the bitvectors (one launch on kernel engines)
        return chunk, self.evaluate(chunk)

    def _update_cost_scale(self, eval_s: float, n_records: int) -> None:
        modeled = 0.0
        if self.family is not None and self.family.tier_costs:
            modeled = self.family.tier_costs[self.tier]
        if modeled <= 0.0 or n_records <= 0:
            return  # empty tier / no cost model: keep the current estimate
        sample = (eval_s / n_records * 1e6) / modeled
        a = self.cost_ewma_alpha
        self.cost_scale = (1.0 - a) * self.cost_scale + a * sample

    def observed_us_per_record(self) -> float:
        if not self.eval_records:
            return 0.0
        return self.eval_time_s / self.eval_records * 1e6


@dataclass(order=True)
class _Pending:
    ready_at: float
    seq: int
    client_idx: int = field(compare=False)


class FleetTierAllocator:
    """Splits a global client-cost budget across a heterogeneous fleet.

    Wraps :func:`repro.core.selection.allocate_tiers` with the live
    signals the pipeline produces: each shard's ``cost_scale`` (measured
    µs per modeled µs, EWMA over its timing reports — the ``1/speed``
    prior until data arrives) and its record rate as the weight.  The
    budget is the fleet-record-weighted average client µs/record: with
    weights normalized to sum 1, ``sum_j w_j * scale_j * tier_cost[t_j]``
    must stay under ``budget_us``.

    Re-tiering: every ``retier_every_records`` ingested records the
    allocation is re-solved from the current cost scales; if any shard's
    tier changes the new assignment is applied in place (a tier change
    within one family needs no epoch bump — the store validates coverage
    per chunk, and kernel engines keep one shared trace across tiers).
    """

    def __init__(self, family: PlanFamily, budget_us: float, *,
                 retier_every_records: int = 4096,
                 telemetry: object | None = None):
        if not family.tier_costs:
            raise ValueError(
                "allocator needs a family with tier_costs "
                "(build it via planner.build_plan_family)")
        self.family = family
        self.budget_us = float(budget_us)
        self.retier_every_records = retier_every_records
        self.allocation: TierAllocation | None = None
        self.retier_events = 0
        self._records_since = 0
        # optional core.telemetry.TelemetryPlane: when attached (and fed
        # by ClientShard.evaluate reports), profiles() weights clients by
        # their MEASURED record rates instead of the speed*chunk prior
        self.telemetry = telemetry

    def profiles(self, clients: Sequence[ClientShard]) -> list[ClientProfile]:
        rates = []
        for c in clients:
            rate = max(c.speed * c.chunk_records, 1e-12)  # modeled prior
            if self.telemetry is not None:
                m = self.telemetry.client_eval(c.shard_id)
                if m is not None and m["records_per_s"] > 0:
                    rate = m["records_per_s"]             # measured
            rates.append(rate)
        rates = np.array(rates)
        weights = rates / rates.sum()
        return [
            ClientProfile(cost_scale=c.cost_scale, weight=float(w))
            for c, w in zip(clients, weights)
        ]

    def assign(self, clients: Sequence[ClientShard]) -> TierAllocation:
        """Solve the allocation and apply it to every shard."""
        alloc = allocate_tiers(
            self.family.tier_costs, self.family.tier_values,
            self.profiles(clients), self.budget_us,
        )
        for c, t in zip(clients, alloc.tiers):
            c.set_family(self.family, t)
        self.allocation = alloc
        return alloc

    def set_family(self, family: PlanFamily,
                   clients: Sequence[ClientShard]) -> TierAllocation:
        """Epoch bump: re-solve tiers for the new family and broadcast."""
        self.family = family
        self._records_since = 0
        return self.assign(clients)

    def on_records(self, n: int, clients: Sequence[ClientShard]) -> bool:
        """Cost-drift re-tiering hook; returns True when tiers changed."""
        self._records_since += n
        if self._records_since < self.retier_every_records:
            return False
        self._records_since = 0
        before = [c.tier for c in clients]
        self.assign(clients)
        if [c.tier for c in clients] != before:
            self.retier_events += 1
            return True
        return False


class IngestCoordinator:
    """Work-stealing chunk scheduler over N clients (virtual time).

    Each client owns a backlog of `chunks_per_client` chunk slots.  A chunk
    produced by client i takes 1/speed_i virtual seconds.  When a fast client
    drains its backlog it steals a slot from the most-backlogged client and
    produces that chunk itself (clients are stateless record producers in
    this simulation, so stealing = re-assigning the production slot).  This
    bounds makespan by the fastest clients instead of the slowest — the
    framework's straggler-mitigation story, testable without wall-clock.

    ``store`` may be a :class:`ShardedCiaoStore` (DESIGN.md §14): ingest
    then routes each chunk's records through the store's ``ShardRouter``
    to N per-shard segment stores, and the replanner keeps consuming the
    same feedback surface (per-shard observed selectivities are
    aggregated into exact fleet totals on read).
    """

    def __init__(self, clients: Sequence[ClientShard], store: AnyStore,
                 *, steal: bool = True, replanner=None,
                 allocator: FleetTierAllocator | None = None,
                 eval_cost_weight: float = 0.0,
                 on_chunk: Callable[[int], None] | None = None):
        self.clients = list(clients)
        self.store = store
        self.steal = steal
        self.replanner = replanner          # core.replan.Replanner protocol
        self.allocator = allocator          # tiered fleets only
        # virtual seconds added per measured eval second: with a non-zero
        # weight, client-side plan evaluation slows chunk delivery in the
        # virtual-time model (the paper's client-cost side of the
        # trade-off); 0 preserves the pure production-rate simulation
        self.eval_cost_weight = eval_cost_weight
        self.on_chunk = on_chunk            # called with #chunks ingested
        self.stolen = 0
        self.makespan = 0.0
        self.epoch_bumps = 0
        if allocator is not None:
            allocator.assign(self.clients)

    def _broadcast(self, plan) -> None:
        """Epoch bump: every shard evaluates subsequent chunks under it.

        A :class:`PlanFamily` bump re-runs the tier allocator (tier
        assignments are family-relative); a bare plan swaps untiered.
        """
        if isinstance(plan, PlanFamily):
            if self.allocator is not None:
                self.allocator.set_family(plan, self.clients)
            else:
                for c in self.clients:
                    c.set_family(plan)
        else:
            for c in self.clients:
                c.set_plan(plan)
        self.epoch_bumps += 1

    def run(self, chunks_per_client: int) -> None:
        backlog = [chunks_per_client for _ in self.clients]
        clock = [0.0 for _ in self.clients]
        total = chunks_per_client * len(self.clients)
        done = 0
        while done < total:
            # next client to finish a chunk = argmin over clock+1/speed
            i = min(
                range(len(self.clients)),
                key=lambda k: clock[k] + 1.0 / self.clients[k].speed
                if backlog[k] > 0 or (self.steal and max(backlog) > 0)
                else float("inf"),
            )
            if backlog[i] == 0:
                if not self.steal:
                    continue
                j = int(np.argmax(backlog))
                if backlog[j] == 0:
                    break
                backlog[j] -= 1
                self.stolen += 1
            else:
                backlog[i] -= 1
            client = self.clients[i]
            eval_before = client.eval_time_s
            # tier coverage of THIS evaluation (the client may be
            # re-tiered later in the loop): the replanner's cost
            # recalibration must predict over the same clause prefix
            n_eval = (client.tier_size if client.family is not None
                      else None)
            chunk, bv = client.next_chunk()
            # plan-eval wall-clock only (the shard times eval_fused
            # itself) — record generation/encoding must not leak into the
            # replanner's cost-model recalibration
            eval_s = client.eval_time_s - eval_before
            # chunks carry their evaluation (epoch, tier); the window
            # between a broadcast and a client's next chunk is where
            # staleness lives, so a StaleEpochError re-evaluates under the
            # current plan/family (tier carries over, clamped)
            tier = client.tier if client.family is not None else None
            try:
                self.store.ingest_chunk(chunk, bv,
                                        epoch=client.plan.epoch, tier=tier)
            except StaleEpochError:
                if client.family is not None:
                    client.set_family(self.store.family)
                    tier = client.tier
                else:
                    client.set_plan(self.store.plan)
                    tier = None
                bv = client.evaluate(chunk)
                self.store.ingest_chunk(chunk, bv,
                                        epoch=client.plan.epoch, tier=tier)
            # eval_s is already speed-scaled by the shard (slow devices
            # evaluate slower), so it adds directly on top of the
            # production slot
            clock[i] += 1.0 / client.speed + self.eval_cost_weight * eval_s
            done += 1
            if self.on_chunk is not None:
                self.on_chunk(done)
            if self.replanner is not None:
                self.replanner.observe_timing(chunk.n_records, eval_s,
                                              n_clauses=n_eval)
                new_plan = self.replanner.step()
                if new_plan is not None:
                    self._broadcast(new_plan)
            if self.allocator is not None:
                self.allocator.on_records(chunk.n_records, self.clients)
        self.makespan = max(clock)


class RecipeBatcher:
    """Turns recipe-matching store rows into fixed-shape token batches."""

    def __init__(self, store: AnyStore, tokenizer: ByteTokenizer,
                 *, seq_len: int, batch_size: int):
        self.store = store
        self.tok = tokenizer
        self.seq_len = seq_len
        self.batch_size = batch_size

    def matching_records(self, recipe: Query) -> Iterator[bytes]:
        # coverage-aware skipping: each segment's bitvector rows follow
        # ITS ingest epoch's plan AND its tier's coverage prefix; raw
        # remainders are JIT-promoted only for (epoch, coverage) groups
        # that push none of the recipe — the skippability invariant is
        # single-sourced in the store's query-path helpers.  Matching is
        # the columnar engine's vectorized exact mask (zone-map prune +
        # bitvector AND + column evaluation), and hits stream the
        # segment's RAW source bytes — no json.dumps round-trip per row.
        store = self.store
        pushed_by_epoch = store.pushed_by_epoch(recipe)
        for seg in store.blocks:
            pushed = pushed_by_epoch[(seg.epoch, seg.n_covered)]
            mask = query_mask(seg, recipe, pushed)
            if mask is None:                  # zone-map pruned whole
                continue
            for i in np.nonzero(mask)[0]:
                yield seg.record(i)
        store.promote_uncovered_raw(pushed_by_epoch)
        for seg in store.jit_blocks:
            if pushed_by_epoch[(seg.epoch, seg.n_covered)]:
                continue
            mask = query_mask(seg, recipe)
            if mask is None:
                continue
            for i in np.nonzero(mask)[0]:
                yield seg.record(i)

    def batches(self, recipe: Query, *, repeat: bool = True
                ) -> Iterator[tuple[np.ndarray, np.ndarray]]:
        """Yields (tokens, loss_mask) of shape (batch, seq_len): packed docs."""
        buf: list[int] = []
        while True:
            made_any = False
            for rec in self.matching_records(recipe):
                made_any = True
                buf.extend(self.tok.encode(rec).tolist())
                while len(buf) >= self.batch_size * self.seq_len:
                    flat = np.array(
                        buf[: self.batch_size * self.seq_len], dtype=np.int32
                    )
                    del buf[: self.batch_size * self.seq_len]
                    tokens = flat.reshape(self.batch_size, self.seq_len)
                    mask = np.ones_like(tokens, dtype=np.float32)
                    yield tokens, mask
            if not repeat or not made_any:
                return


class Prefetcher:
    """Double-buffered background prefetch (host CIAO work ∥ device step).

    Context-manager aware: an abandoned consumer must call :meth:`close`
    (or use ``with``) so the worker thread — possibly blocked on a full
    queue — is released instead of parking forever.  ``close`` also
    re-raises any exception the worker hit, so failures in a pipeline
    whose consumer stopped early still surface instead of being silently
    dropped with the thread.
    """

    _POLL_S = 0.05
    _JOIN_S = 5.0

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._done = object()
        self._err: BaseException | None = None
        self._stop = threading.Event()
        self._err_raised = False

        def _put(item) -> bool:
            """Bounded put that gives up when the consumer closed us."""
            while not self._stop.is_set():
                try:
                    self._q.put(item, timeout=self._POLL_S)
                    return True
                except queue.Full:
                    continue
            return False

        def worker() -> None:
            try:
                for item in it:
                    if not _put(item):
                        return  # closed mid-stream: drop the rest
            except BaseException as e:  # propagate to consumer
                self._err = e
            finally:
                _put(self._done)

        self._t = threading.Thread(target=worker, daemon=True)
        self._t.start()

    def __iter__(self):
        return self

    def __next__(self):
        if self._stop.is_set():
            raise StopIteration  # closed: the buffer was already dropped
        item = self._q.get()
        if item is self._done:
            if self._err is not None and not self._err_raised:
                self._err_raised = True
                raise self._err
            raise StopIteration
        return item

    def close(self) -> None:
        """Release the worker thread; re-raise its pending exception.

        Idempotent.  Safe to call with items still buffered: the worker's
        blocked ``put`` observes the stop flag within one poll interval
        and exits, the buffer is drained and dropped.  A worker that is
        stuck INSIDE the wrapped iterator (not in our queue handoff)
        cannot be released from Python — that raises instead of returning
        as if the thread were gone (its later exception would otherwise
        vanish with the daemon thread).
        """
        self._stop.set()
        while True:  # drain so a worker blocked pre-stop wakes immediately
            try:
                self._q.get_nowait()
            except queue.Empty:
                break
        self._t.join(timeout=self._JOIN_S)
        if self._err is not None and not self._err_raised:
            self._err_raised = True
            raise self._err
        if self._t.is_alive():
            raise RuntimeError(
                f"prefetch worker still running inside the wrapped iterator "
                f"after {self._JOIN_S}s — it cannot be released and any "
                "future failure in it will be lost")

    def __enter__(self) -> "Prefetcher":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is not None:
            # don't mask the consumer's exception with the worker's
            self._stop.set()
            self._t.join(timeout=5.0)
            return
        self.close()
