"""Roofline terms from a compiled dry-run artifact (DESIGN.md §7).

Per (arch × shape × mesh):
    compute_term    = device_FLOPs / peak_FLOPs_per_chip
    memory_term     = device_bytes / HBM_bw_per_chip
    collective_term = device_collective_bytes / ICI_bw_per_chip

``cost_analysis()`` on the SPMD-partitioned module reports *per-device*
FLOPs/bytes, so the terms above are per-chip already (equivalent to the
global-HLO/(chips×peak) formulation).  Collective bytes are parsed from the
optimized per-device HLO: sum of operand bytes of all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute ops (all-reduce counted 2×
for the ring's reduce+broadcast phases).
"""
from __future__ import annotations

import dataclasses
import re
from dataclasses import dataclass, field

# TPU v5e per-chip constants (assignment-specified)
PEAK_FLOPS = 197e12        # bf16 FLOP/s
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s effective per chip

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^\s]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_kind: dict = field(default_factory=dict)
    count_by_kind: dict = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum result-shape bytes of every collective op in the (per-device) HLO.

    ``-done`` ops are skipped (their ``-start`` counterpart is counted).
    all-reduce is weighted 2x (ring reduce-scatter + all-gather phases).
    """
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        if "-done(" in line:
            continue
        type_str, kind = m.group(1), m.group(2)
        b = _shape_bytes(type_str)
        if kind == "all-reduce":
            b *= 2
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) + b
        stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + 1
    return stats


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    device_flops: float
    device_bytes: float
    collective_bytes: float
    model_flops_global: float      # 6·N·D (train) or 2·N_active·tokens (decode)
    n_devices: int
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    dominant: str = ""
    useful_flops_frac: float = 0.0
    step_time_s: float = 0.0
    roofline_frac: float = 0.0
    collectives: dict = field(default_factory=dict)
    memory_per_device_gb: float = 0.0
    notes: str = ""

    def finalize(self) -> "Roofline":
        self.compute_s = self.device_flops / PEAK_FLOPS
        self.memory_s = self.device_bytes / HBM_BW
        self.collective_s = self.collective_bytes / ICI_BW
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        self.dominant = max(terms, key=terms.get)
        total_hlo_flops = self.device_flops * self.n_devices
        self.useful_flops_frac = (
            self.model_flops_global / total_hlo_flops if total_hlo_flops else 0.0
        )
        # bound on step time: max of the three terms (perfect overlap);
        # roofline fraction = useful-compute time / bound.
        self.step_time_s = max(terms.values())
        useful_compute_s = self.model_flops_global / (PEAK_FLOPS * self.n_devices)
        self.roofline_frac = (
            useful_compute_s / self.step_time_s if self.step_time_s else 0.0
        )
        return self

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def model_flops(cfg, shape, n_active_params: int) -> float:
    """MODEL_FLOPS: 6·N·D for train; 2·N·new_tokens for decode; 2·N·D prefill."""
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active_params * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active_params * tokens
    return 2.0 * n_active_params * shape.global_batch  # decode: 1 token/seq
