"""Trip-count-aware HLO collective accounting.

``jax.lax.scan`` lowers to ``while`` ops, and XLA's cost analysis (and a
naive text scan) prices the body ONCE regardless of trip count.  This parser
rebuilds the computation call graph from optimized HLO text, extracts each
while loop's trip count from its condition's comparison constant, and
multiplies collective bytes by the product of enclosing trip counts — giving
exact per-step collective bytes for scan-over-layers programs.

Heuristics (validated in tests against unrolled references):
  * trip count = the max integer constant in the while condition computation
    (scan conditions are ``lt(iter, N)`` with iter starting at 0);
  * ``-start``/``-done`` async pairs are counted once (on start);
  * all-reduce bytes are doubled (ring reduce-scatter + all-gather phases).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVE_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->.*{?\s*$")
_CALLED_RE = re.compile(
    r"(?:to_apply|body|condition|branch_computations|called_computations|"
    r"true_computation|false_computation)=\s*"
    r"(?:{([^}]*)}|%?([\w.\-]+))"
)
_WHILE_RE = re.compile(r"=\s*(?:\([^=]*\)|\S+)\s+while\(")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class _Comp:
    name: str
    lines: list = field(default_factory=list)
    # (kind, bytes) local collectives
    collectives: list = field(default_factory=list)
    # (child_name, multiplier_kind) where multiplier_kind is "call" or ("while", cond)
    calls: list = field(default_factory=list)


def _split_computations(hlo: str) -> dict[str, _Comp]:
    comps: dict[str, _Comp] = {}
    cur: _Comp | None = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if not stripped:
            continue
        # computation headers end with '{' at depth 0 (HLO is flat: one level)
        if (not raw.startswith(" ")) and stripped.endswith("{") and "->" in stripped:
            m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)", stripped)
            if m:
                cur = _Comp(m.group(1))
                comps[cur.name] = cur
            continue
        if stripped == "}" or stripped.startswith("} //"):
            cur = None
            continue
        if cur is not None:
            cur.lines.append(stripped)
    return comps


def _analyze(comps: dict[str, _Comp]) -> None:
    for comp in comps.values():
        for line in comp.lines:
            # collectives
            for kind in _COLLECTIVE_KINDS:
                token = f" {kind}("
                start_token = f" {kind}-start("
                if token in line or start_token in line:
                    # result type: between '=' and opcode
                    m = re.match(r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*?)\s+" +
                                 re.escape(kind), line)
                    if m:
                        b = _shape_bytes(m.group(1))
                        if kind == "all-reduce":
                            b *= 2
                        comp.collectives.append((kind, b))
                    break
                if f" {kind}-done(" in line:
                    break
            # called computations
            is_while = bool(_WHILE_RE.search(line)) or " while(" in line
            body_name = cond_name = None
            _role_re = (r"(body|condition|to_apply|true_computation"
                        r"|false_computation)=%?([\w.\-]+)")
            for m in re.finditer(_role_re, line):
                role, name = m.group(1), m.group(2)
                if role == "body":
                    body_name = name
                elif role == "condition":
                    cond_name = name
                else:
                    comp.calls.append((name, 1))
            m = re.search(r"branch_computations=\{([^}]*)\}", line)
            if m:
                for name in m.group(1).split(","):
                    comp.calls.append((name.strip().lstrip("%"), 1))
            if is_while and body_name:
                # XLA annotates known_trip_count in backend_config — prefer it.
                m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', line)
                trip = int(m.group(1)) if m else _trip_count(comps.get(cond_name))
                comp.calls.append((body_name, trip))
                if cond_name:
                    comp.calls.append((cond_name, trip))


def _trip_count(cond: _Comp | None) -> int:
    if cond is None:
        return 1
    consts = []
    for line in cond.lines:
        if "compare(" in line or "constant(" in line:
            consts += [int(x) for x in _CONST_RE.findall(line)]
    return max(consts) if consts else 1


def collective_bytes(hlo: str) -> dict:
    """Returns {"bytes": {kind: scaled_bytes}, "counts": {kind: scaled_count},
    "total": int} with while-loop trip scaling."""
    comps = _split_computations(hlo)
    _analyze(comps)

    entry = None
    for name, c in comps.items():
        if name.startswith("main") or name == "entry":
            entry = c
            break
    if entry is None and comps:
        # fall back: the computation that nobody calls
        called = {n for c in comps.values() for n, _ in c.calls}
        for name, c in comps.items():
            if name not in called:
                entry = c
                break
    if entry is None:
        return {"bytes": {}, "counts": {}, "total": 0}

    memo: dict[str, tuple[dict, dict]] = {}

    def visit(comp: _Comp, depth=0) -> tuple[dict, dict]:
        if comp.name in memo:
            return memo[comp.name]
        if depth > 64:
            return {}, {}
        bytes_by, counts_by = {}, {}
        for kind, b in comp.collectives:
            bytes_by[kind] = bytes_by.get(kind, 0) + b
            counts_by[kind] = counts_by.get(kind, 0) + 1
        for child_name, mult in comp.calls:
            child = comps.get(child_name)
            if child is None:
                continue
            cb, cc = visit(child, depth + 1)
            for k, v in cb.items():
                bytes_by[k] = bytes_by.get(k, 0) + v * mult
            for k, v in cc.items():
                counts_by[k] = counts_by.get(k, 0) + v * mult
        memo[comp.name] = (bytes_by, counts_by)
        return bytes_by, counts_by

    bytes_by, counts_by = visit(entry)
    return {
        "bytes": bytes_by,
        "counts": counts_by,
        "total": sum(bytes_by.values()),
    }
