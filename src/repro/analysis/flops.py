"""Arch-exact analytic FLOPs / HBM-bytes model for the roofline terms.

XLA's ``cost_analysis`` prices ``while`` bodies once, so scan-over-layers
programs under-report by ~n_layers.  The roofline's compute/memory terms
therefore come from this analytic model, which walks the exact per-layer
einsums of every architecture family (attention incl. the causal 1/2 factor
and flash recompute, MLA ranks, MoE capacity dispatch, RG-LRU gates/scan,
RWKV6 time/channel mix) — and is cross-validated in tests against
``cost_analysis`` of fully-unrolled compiled probes (they must agree within
tolerance on configs small enough to unroll).

Conventions:
  * one MAC = 2 FLOPs; backward = 2x forward matmul FLOPs (dgrad + wgrad);
  * remat="full" recomputes the forward in the backward: fwd factor 2;
  * HBM bytes (train) = param traffic (fwd read + bwd read + grad/opt RW)
    + activation traffic ~ 2 bytes * activations written + read (bf16),
    with remat multiplying activation writes;
  * decode bytes = params read + full cache read + small writes.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ModelConfig, ShapeConfig


@dataclass
class CostEstimate:
    flops_global: float            # one step, all devices, fwd(+bwd)
    hbm_bytes_global: float
    breakdown: dict

    def per_device(self, n: int) -> tuple[float, float]:
        return self.flops_global / n, self.hbm_bytes_global / n


def _attn_layer_flops(cfg: ModelConfig, S: int, kv_len: int | None = None,
                      causal: bool = True) -> tuple[float, float]:
    """(matmul_flops, score_flops) per token-sequence of length S, one layer."""
    d, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd()
    kv_len = kv_len if kv_len is not None else S
    if cfg.attention == "mla":
        m = cfg.mla
        qk = m.qk_nope_head_dim + m.qk_rope_head_dim
        proj = (
            2 * S * d * m.q_lora_rank
            + 2 * S * m.q_lora_rank * H * qk
            + 2 * S * d * (m.kv_lora_rank + m.qk_rope_head_dim)
            + 2 * S * m.kv_lora_rank * H * (m.qk_nope_head_dim + m.v_head_dim)
            + 2 * S * H * m.v_head_dim * d
        )
        eff = 0.5 if causal else 1.0
        score = 2 * H * S * kv_len * (qk + m.v_head_dim) * eff
        return proj, score
    proj = 2 * S * d * H * hd + 2 * 2 * S * d * Hkv * hd + 2 * S * H * hd * d
    window = cfg.window if cfg.attention == "local" and cfg.window else None
    if window:
        eff_len = min(window, kv_len)
        score = 2 * H * S * eff_len * hd * 2
    else:
        eff = 0.5 if causal else 1.0
        score = 2 * H * S * kv_len * hd * 2 * eff
    return proj, score


def _mlp_flops(d: int, ff: int, S: int, gated: bool) -> float:
    n_mats = 3 if gated else 2
    return n_mats * 2 * S * d * ff


def _moe_layer_flops(cfg: ModelConfig, S: int) -> float:
    m = cfg.moe
    # router + dispatched expert FFN at capacity + shared expert
    f = 2 * S * cfg.d_model * m.n_experts
    dispatched = S * m.top_k * m.capacity_factor
    f += 3 * 2 * dispatched * cfg.d_model * m.d_ff_expert
    if m.n_shared_experts:
        sff = m.d_ff_shared or m.d_ff_expert * m.n_shared_experts
        f += 3 * 2 * S * cfg.d_model * sff
    return f


def _rglru_layer_flops(cfg: ModelConfig, S: int) -> float:
    d, D, H = cfg.d_model, cfg.lru_width or cfg.d_model, cfg.n_heads
    f = 2 * S * d * D * 2          # two input projections
    f += 2 * S * cfg.conv_width * D  # depthwise conv
    f += 2 * 2 * S * (D // H) * D    # block-diagonal gates (2x)
    f += 10 * S * D                  # scan combine (elementwise)
    f += 2 * S * D * d               # out projection
    return f


def _rwkv_layer_flops(cfg: ModelConfig, S: int) -> float:
    d, hd = cfg.d_model, cfg.rwkv_head_size
    H = d // hd
    f = 2 * S * d * (5 * 32) + 2 * S * 5 * 32 * d     # ddlerp lora
    f += 2 * S * d * 64 + 2 * S * 64 * d              # decay lora
    f += 5 * 2 * S * d * d                            # r,k,v,g,o projections
    f += S * H * (3 * 2 * hd * hd)                    # state update + readout
    f += 2 * 2 * S * d * cfg.d_ff + 2 * S * d * d     # channel mix
    return f


def _layer_flops(cfg: ModelConfig, block: str, S: int, *, kv_len=None,
                 causal=True) -> float:
    gated = cfg.act in ("silu", "swiglu", "geglu")
    if block in ("dense_attn", "attn"):
        proj, score = _attn_layer_flops(cfg, S, kv_len, causal)
        ff = cfg.d_ff
        if cfg.moe is not None and cfg.moe.first_dense_layers and block == "dense_attn":
            ff = cfg.moe.d_ff_dense or cfg.d_ff
        return proj + score + _mlp_flops(cfg.d_model, ff, S, gated)
    if block == "moe_attn":
        proj, score = _attn_layer_flops(cfg, S, kv_len, causal)
        return proj + score + _moe_layer_flops(cfg, S)
    if block == "rec":
        return _rglru_layer_flops(cfg, S) + _mlp_flops(cfg.d_model, cfg.d_ff, S, gated)
    if block == "rwkv":
        return _rwkv_layer_flops(cfg, S)
    raise ValueError(block)


def _blocks(cfg: ModelConfig) -> list[str]:
    out = []
    for gt, n in cfg.layer_groups():
        if gt.startswith("pattern:"):
            out += gt.split(":", 1)[1].split(",") * n
        else:
            out += [gt] * n
    return out


def forward_flops(cfg: ModelConfig, B: int, S: int, *, kv_len=None,
                  causal=True, with_unembed=True) -> float:
    total = 0.0
    for block in _blocks(cfg):
        total += B * _layer_flops(cfg, block, S, kv_len=kv_len, causal=causal)
    if cfg.family == "encdec":
        # decoder side: self (causal) + cross + mlp; encoder counted above
        pass
    if with_unembed:
        total += 2.0 * B * S * cfg.d_model * cfg.vocab_size
    return total


def _encdec_forward_flops(cfg: ModelConfig, B: int, S_src: int, S_tgt: int) -> float:
    gated = cfg.act in ("silu", "swiglu", "geglu")
    enc = dec = 0.0
    proj_e, score_e = _attn_layer_flops(cfg, S_src, causal=False)
    enc = cfg.enc_layers * (proj_e + score_e + _mlp_flops(cfg.d_model, cfg.d_ff, S_src, gated))
    proj_d, score_d = _attn_layer_flops(cfg, S_tgt, causal=True)
    # cross attention: q from tgt, kv from src
    d, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd()
    cross_proj = 2 * S_tgt * d * H * hd + 2 * 2 * S_src * d * Hkv * hd + 2 * S_tgt * H * hd * d
    cross_score = 2 * H * S_tgt * S_src * hd * 2
    dec = cfg.dec_layers * (
        proj_d + score_d + cross_proj + cross_score
        + _mlp_flops(cfg.d_model, cfg.d_ff, S_tgt, gated)
    )
    unembed = 2.0 * S_tgt * cfg.d_model * cfg.vocab_size
    return B * (enc + dec + unembed)


_REMAT_FWD_FACTOR = {"none": 1.0, "dots": 1.35, "full": 2.0}


def estimate(cfg: ModelConfig, shape: ShapeConfig, n_params: int,
             n_active: int) -> CostEstimate:
    B, S = shape.global_batch, shape.seq_len
    bd: dict = {}
    act_bytes = 2  # bf16 activations

    if shape.kind == "train":
        if cfg.family == "encdec":
            fwd = _encdec_forward_flops(cfg, B, S // 2, S // 2)
            tokens_for_act = B * S
        else:
            S_eff = S  # vlm: frontend_len embeds + text tokens = S total
            fwd = forward_flops(cfg, B, S_eff)
            tokens_for_act = B * S_eff
        remat_f = _REMAT_FWD_FACTOR.get(cfg.remat, 2.0)
        flops = fwd * (remat_f + 2.0)          # fwd(+recompute) + bwd 2x
        bd["fwd_flops"] = fwd
        bd["total_flops"] = flops
        # sanity crosscheck vs 6·N·D
        bd["six_nd"] = 6.0 * n_active * tokens_for_act

        # HBM bytes:
        p_bytes = {"float32": 4, "bfloat16": 2}.get(cfg.param_dtype, 4)
        o_bytes = {"float32": 4, "bfloat16": 2}.get(cfg.opt_dtype, 4)
        n_micro = max(cfg.microbatches, 1)
        param_traffic = n_params * (
            n_micro * 2 * p_bytes      # read per micro: fwd + bwd
            + 4                        # grad write fp32 (accumulated, sharded)
            + 4 * o_bytes + 4          # adam m,v RW + param write
        )
        # activations: per layer ~ 12 * d_model writes+reads per token (attn
        # q/k/v/o + mlp in/gate/out + norms), x2 for bwd reads, x remat
        n_layers = cfg.n_layers if cfg.family != "encdec" else (cfg.enc_layers + cfg.dec_layers)
        act_traffic = (
            tokens_for_act * n_layers * 12 * cfg.d_model * act_bytes
            * (1 + remat_f)
        )
        logits_traffic = 3 * tokens_for_act / n_micro * cfg.vocab_size * 4
        hbm = param_traffic + act_traffic + logits_traffic
        bd.update(param_traffic=param_traffic, act_traffic=act_traffic,
                  logits_traffic=logits_traffic)
        return CostEstimate(flops, hbm, bd)

    if shape.kind == "prefill":
        if cfg.family == "encdec":
            fwd = _encdec_forward_flops(cfg, B, S, max(S // 8, 128))
        else:
            fwd = forward_flops(cfg, B, S, with_unembed=False)
            fwd += 2.0 * B * cfg.d_model * cfg.vocab_size  # last-token logits
        p_bytes = {"float32": 4, "bfloat16": 2}.get(cfg.param_dtype, 4)
        n_layers = cfg.n_layers if cfg.family != "encdec" else (cfg.enc_layers + cfg.dec_layers)
        act_traffic = B * S * n_layers * 12 * cfg.d_model * act_bytes
        cache_write = _cache_bytes(cfg, B, S)
        hbm = n_params * p_bytes + act_traffic + cache_write
        return CostEstimate(fwd, hbm, {"fwd_flops": fwd, "cache_write": cache_write})

    # decode: one token, cache length S
    if cfg.family == "encdec":
        fwd = forward_flops(cfg, B, 1, kv_len=S, causal=False, with_unembed=False)
        fwd += 2.0 * B * cfg.d_model * cfg.vocab_size
    else:
        fwd = forward_flops(cfg, B, 1, kv_len=S, causal=False, with_unembed=False)
        fwd += 2.0 * B * cfg.d_model * cfg.vocab_size
    p_bytes = {"float32": 4, "bfloat16": 2}.get(cfg.param_dtype, 4)
    cache_read = _cache_bytes(cfg, B, S)
    hbm = n_active * p_bytes + cache_read
    return CostEstimate(fwd, hbm, {"cache_read": cache_read, "param_read": n_active * p_bytes})


def scan_estimate(*, n_rows: int, n_terms: int, n_clauses: int,
                  n_queries: int, n_slots: int) -> CostEstimate:
    """Analytic FLOPs / memory-bytes of ONE fused device scan launch.

    Walks the exact stages of ``kernels.scan_fused.scan_core_xla`` over
    N = n_rows plane rows, T terms, C clauses, Q queries and S1 slot
    buckets — every term is derived from the implementation, not a hand
    constant, so the roofline fraction in ``BENCH_device.json`` tracks
    the kernel it measures:

      * term eval — per (T, N) element: 4 mask tests, the EXACT code
        compare, the 3-candidate numeric-repr compare + any-reduce, the
        LUT index arithmetic, null/bool-compat logic and the 4-way kind
        select — 23 integer/predicate ops;
      * clause membership matmul  (C, T) @ (T, N)   -> 2·C·T·N FLOPs;
      * query violation matmul    (Q, C) @ (C, N)   -> 2·Q·C·N FLOPs;
      * pushed AND + zone mask + hit combine        -> 4·Q·N;
      * per-slot popcount scatter (counts + cands)  -> 2·Q·N.

    Memory traffic (read-once streaming, the roofline's HBM term): the
    gathered plane columns (4 uint8 masks + 2 int32 code columns per
    term row), the per-row slot id + clause word, the per-slot parameter
    gathers (code_a, lut_off int32; num_codes int32×3; LUT probe uint8),
    one boolean term/clause/query intermediate each, and the (Q, S1)
    int32 outputs.
    """
    N, T, C, Q = n_rows, n_terms, n_clauses, n_queries
    S1 = n_slots + 1
    flops = {
        "term_eval": 23.0 * T * N,
        "clause_matmul": 2.0 * C * T * N,
        "query_matmul": 2.0 * Q * C * N,
        "pushed_and_hit": 4.0 * Q * N,
        "popcount_scatter": 2.0 * Q * N,
    }
    bytes_ = {
        "plane_gather": (4 * 1 + 2 * 4) * T * N,
        "row_meta": (4 + 4) * N,
        "param_gather": (4 + 4 + 3 * 4 + 1) * T * N,
        "intermediates": (T + C + Q) * N,
        "outputs": 2 * 4 * Q * S1,
    }
    bd = {"flops": flops, "bytes": bytes_,
          "shape": {"n_rows": N, "n_terms": T, "n_clauses": C,
                    "n_queries": Q, "n_slots": n_slots}}
    return CostEstimate(sum(flops.values()), sum(bytes_.values()), bd)


def _cache_bytes(cfg: ModelConfig, B: int, S: int) -> float:
    """Total KV/recurrent cache bytes (bf16) for context length S."""
    if cfg.family == "rwkv":
        hd = cfg.rwkv_head_size
        H = cfg.d_model // hd
        return cfg.n_layers * B * (H * hd * hd * 4 + 2 * cfg.d_model * 4)
    if cfg.family == "hybrid":
        per_attn = 2 * B * min(S, cfg.window + 128) * cfg.n_kv_heads * cfg.hd() * 2
        n_attn = sum(1 for b in _blocks(cfg) if b == "attn")
        n_rec = sum(1 for b in _blocks(cfg) if b == "rec")
        D = cfg.lru_width or cfg.d_model
        return n_attn * per_attn + n_rec * B * D * 4
    if cfg.attention == "mla":
        m = cfg.mla
        return cfg.n_layers * B * S * (m.kv_lora_rank + m.qk_rope_head_dim) * 2
    n_layers = cfg.dec_layers if cfg.family == "encdec" else cfg.n_layers
    cache = n_layers * 2 * B * S * cfg.n_kv_heads * cfg.hd() * 2
    if cfg.family == "encdec":
        cache += cfg.dec_layers * 2 * B * 4096 * cfg.n_kv_heads * cfg.hd() * 2
    return cache
