"""LLM serving engine: jitted prefill / decode steps with sharded caches.

NOT the CIAO store-serving plane: this module serves the *model* (token
generation); the async store engine that serves *queries* under live
ingest lives in :mod:`repro.serve.store_engine` (``CiaoServeEngine``,
DESIGN.md §17).  The two share nothing but the package.

``make_serve_fns(model, mesh, batch, seq)`` builds the two jitted step
functions the dry-run lowers and the serve driver executes:

  * ``prefill_fn(params, batch_inputs) -> (logits, cache)`` — cache comes out
    already in the decode layout (batch over (pod,data), sequence over
    model): the layout transpose is part of the compiled prefill step.
  * ``decode_fn(params, cache, tokens, cur_index) -> (logits, cache)`` —
    cache is donated, so steady-state decode allocates nothing.

The driver (:mod:`repro.launch.serve`) wraps these in a batched greedy
generation loop.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import cache_alloc_len
from repro.dist import sharding as shd
from repro.models.model import Model


def cache_shape(model: Model, batch: int, s_alloc: int, *, s_cross: int = 0,
                cache_dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: model.init_cache(batch, s_alloc, s_cross=s_cross,
                                 cache_dtype=cache_dtype)
    )


def make_serve_fns(model: Model, mesh: Mesh, *, batch: int, seq_len: int,
                   cache_dtype=jnp.bfloat16, param_shardings=None,
                   donate_cache: bool = True):
    cfg = model.cfg
    s_alloc = cache_alloc_len(seq_len)
    s_cross = 4096 if cfg.family == "encdec" else 0

    cache_sds = cache_shape(model, batch, s_alloc, s_cross=s_cross,
                            cache_dtype=cache_dtype)
    cache_sh = shd.cache_shardings(cache_sds, mesh, batch_size=batch)
    tok_sh = NamedSharding(mesh, shd.batch_spec(mesh, 1, batch_size=batch))
    scalar_sh = NamedSharding(mesh, P())

    def prefill(params, inputs):
        return model.prefill(params, inputs, s_alloc=s_alloc,
                             cache_dtype=cache_dtype)

    def decode(params, cache, tokens, cur_index):
        return model.decode(params, cache, tokens, cur_index)

    prefill_jit = None
    if param_shardings is not None:
        logits_sh = NamedSharding(mesh, shd.batch_spec(mesh, 2, batch_size=batch))
        prefill_jit = jax.jit(
            prefill,
            in_shardings=(param_shardings, None),
            out_shardings=(logits_sh, cache_sh),
        )
        decode_jit = jax.jit(
            decode,
            in_shardings=(param_shardings, cache_sh, tok_sh, scalar_sh),
            out_shardings=(logits_sh, cache_sh),
            donate_argnums=(1,) if donate_cache else (),
        )
    else:
        decode_jit = jax.jit(decode, donate_argnums=(1,) if donate_cache else ())
        prefill_jit = jax.jit(prefill)

    return {
        "prefill": prefill_jit,
        "decode": decode_jit,
        "cache_sds": cache_sds,
        "cache_shardings": cache_sh,
        "s_alloc": s_alloc,
        "s_cross": s_cross,
    }


def greedy_generate(model: Model, fns, params, prompt_tokens, *, n_steps: int):
    """Batched greedy decode loop (host-driven; example/serve driver)."""
    B, S = prompt_tokens.shape
    inputs = {"tokens": prompt_tokens}
    logits, cache = fns["prefill"](params, inputs)
    out = []
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    cur = jnp.asarray(S, jnp.int32)
    for _ in range(n_steps):
        out.append(tok)
        logits, cache = fns["decode"](params, cache, tok, cur)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        cur = cur + 1
    return jnp.stack(out, axis=1)
