"""Async store serving plane: CiaoServeEngine (DESIGN.md §17).

NOT the LLM serving engine: :mod:`repro.serve.engine` serves the *model*
(jitted prefill/decode); this module serves *store queries* while ingest
is live.  The two share nothing but the package.

:class:`CiaoServeEngine` wraps a :class:`~repro.core.server.CiaoStore`
or :class:`~repro.core.shard.ShardedCiaoStore` and runs ingest and scans
concurrently with zero reader blocking:

  * **writers** — ``ingest_chunk`` validates the chunk synchronously
    (epoch / tier / bitvector dimensions, so
    :class:`~repro.core.server.StaleEpochError` still surfaces at the
    submit site and the :class:`~repro.data.pipeline.IngestCoordinator`
    retry loop works unchanged), routes it into per-shard slices in the
    submitting thread, and enqueues each slice onto its shard's bounded
    write queue.  A writer pool drains the queues; shard *s* is always
    drained by writer ``s % writers``, so every shard has exactly ONE
    concurrent mutator (the invariant the store's summary versioning and
    ingest locks are designed around) and per-shard ingest order equals
    submit order.  A full queue exerts **backpressure**: policy
    ``"block"`` makes the submitter wait (time accounted), ``"reject"``
    raises :class:`BackpressureError` immediately.
  * **readers** — ``query`` / ``query_batch`` execute against an
    immutable store snapshot (:meth:`CiaoStore.snapshot`), never against
    live shard state, so scans see a consistent ``(epoch, data_version)``
    view while appends continue.  Readers take the current snapshot
    bundle by atomic reference — a background refresher rebuilds it at
    most every ``refresh_interval_s`` when the store version moved, so
    reads are bounded-stale and NEVER wait on writer-held locks
    (``quiesce()`` forces a refresh: read-your-quiesced-writes holds).
    :class:`~repro.core.batch_scan.ResultCache` fencing stays exact
    because snapshot-local JIT promotion forks the version negative
    (see :class:`~repro.core.server.StoreSnapshot`).
  * **admission** — an optional :class:`QueryAdmission` maps tenants to
    tiers with per-tier in-flight quotas; an over-quota query blocks or
    raises :class:`AdmissionError` per the tier's policy, *before* any
    scan work happens.

``quiesce()`` drains every write queue (the post-quiesce store answers
bit-identically to a store that ingested the same chunks serially —
the oracle gate in ``benchmarks/bench_serve.py``); ``close()`` drains,
stops the writer pool and joins it.  Epoch advances must go through
:meth:`CiaoServeEngine.advance_epoch`, which quiesces first — otherwise
queued chunks validated under the old epoch would fail at drain time.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass

from repro.core.batch_scan import ResultCache, ScanBatcher
from repro.core.server import (
    CiaoStore, DataSkippingScanner, LoadStats, ScanResult,
    resolve_ingest_coverage,
)
from repro.core.shard import ShardedCiaoStore, ShardedScanner
from repro.core.predicates import Query


class BackpressureError(RuntimeError):
    """An ingest submit found its shard's write queue full
    (``backpressure="reject"``)."""


class AdmissionError(RuntimeError):
    """A query was denied by tenant-tier admission control
    (``on_full="reject"``)."""


@dataclass(frozen=True)
class TierPolicy:
    """Admission policy for one tenant tier.

    ``max_inflight`` concurrent queries; when the quota is full,
    ``on_full="block"`` queues the caller (FIFO per condition wakeup)
    and ``"reject"`` raises :class:`AdmissionError` immediately.
    """

    max_inflight: int
    on_full: str = "block"

    def __post_init__(self) -> None:
        if self.max_inflight < 0:
            raise ValueError(f"max_inflight must be >= 0, "
                             f"got {self.max_inflight}")
        if self.on_full not in ("block", "reject"):
            raise ValueError(f"unknown on_full policy {self.on_full!r}")


class QueryAdmission:
    """Tenant-tier query admission control (DESIGN.md §17).

    ``tiers`` maps tier name -> :class:`TierPolicy`; ``tenant_tiers``
    maps tenant -> tier name (unmapped tenants use ``default_tier``).
    Thread-safe; counters (admitted / rejected / blocked seconds) are
    kept per tier for :meth:`stats`, and — when ``telemetry`` is set
    (the engine wires its store's plane automatically) — every outcome
    is also recorded per TENANT into the
    :class:`~repro.core.telemetry.TelemetryPlane`, so admission pressure
    shows up in the same per-tenant ``stats_report()`` as scan stats.
    """

    def __init__(self, tiers: dict[str, TierPolicy], *,
                 tenant_tiers: dict[str, str] | None = None,
                 default_tier: str | None = None,
                 telemetry=None):
        if not tiers:
            raise ValueError("need >= 1 tier")
        self.tiers = dict(tiers)
        self.tenant_tiers = dict(tenant_tiers or {})
        self.default_tier = default_tier or next(iter(self.tiers))
        if self.default_tier not in self.tiers:
            raise ValueError(f"default tier {self.default_tier!r} "
                             f"not in tiers {sorted(self.tiers)}")
        for name in self.tenant_tiers.values():
            if name not in self.tiers:
                raise ValueError(f"tenant tier {name!r} not in tiers")
        self.telemetry = telemetry  # optional TelemetryPlane
        self._cond = threading.Condition()
        self._inflight = {name: 0 for name in self.tiers}
        self._admitted = {name: 0 for name in self.tiers}
        self._rejected = {name: 0 for name in self.tiers}
        self._blocked_s = {name: 0.0 for name in self.tiers}

    def tier_of(self, tenant: str) -> str:
        return self.tenant_tiers.get(tenant, self.default_tier)

    def acquire(self, tenant: str) -> str:
        """Admit one query for ``tenant``; returns the tier name to pass
        to :meth:`release`.  Blocks or raises per the tier's policy."""
        tier = self.tier_of(tenant)
        pol = self.tiers[tier]
        blocked = 0.0
        try:
            with self._cond:
                if self._inflight[tier] >= pol.max_inflight:
                    if pol.on_full == "reject":
                        self._rejected[tier] += 1
                        raise AdmissionError(
                            f"tier {tier!r} at max_inflight="
                            f"{pol.max_inflight} (tenant {tenant!r})")
                    t0 = time.perf_counter()
                    while self._inflight[tier] >= pol.max_inflight:
                        self._cond.wait()
                    blocked = time.perf_counter() - t0
                    self._blocked_s[tier] += blocked
                self._inflight[tier] += 1
                self._admitted[tier] += 1
        except AdmissionError:
            # telemetry outside _cond: the plane has its own lock
            if self.telemetry is not None:
                self.telemetry.record_admission(tenant=tenant, rejected=1)
            raise
        if self.telemetry is not None:
            self.telemetry.record_admission(tenant=tenant, admitted=1,
                                            blocked_s=blocked)
        return tier

    def release(self, tier: str) -> None:
        with self._cond:
            self._inflight[tier] -= 1
            self._cond.notify_all()

    def stats(self) -> dict:
        with self._cond:
            return {
                name: {
                    "inflight": self._inflight[name],
                    "admitted": self._admitted[name],
                    "rejected": self._rejected[name],
                    "blocked_s": round(self._blocked_s[name], 6),
                    "max_inflight": self.tiers[name].max_inflight,
                    "on_full": self.tiers[name].on_full,
                }
                for name in self.tiers
            }


class _SnapshotReaders:
    """Scanner bundle over one pinned snapshot, built lazily per mode.

    Scanners are constructed with ``telemetry=False`` — the engine
    records each query ONCE into the store's plane with the caller's
    tenant, so per-tenant attribution survives the shared bundle.
    """

    def __init__(self, engine: "CiaoServeEngine", snap) -> None:
        self._engine = engine
        self.snap = snap
        self._lock = threading.Lock()
        self._host = None
        self._batch = None
        self._device = None

    @property
    def host(self):
        with self._lock:
            if self._host is None:
                e = self._engine
                if e._sharded:
                    self._host = ShardedScanner(
                        self.snap, log_queries=e.log_queries,
                        cache=e.result_cache, telemetry=False)
                else:
                    self._host = DataSkippingScanner(
                        self.snap, log_queries=e.log_queries,
                        telemetry=False)
            return self._host

    @property
    def batch(self) -> ScanBatcher:
        with self._lock:
            if self._batch is None:
                e = self._engine
                self._batch = ScanBatcher(
                    self.snap, cache=e.result_cache,
                    log_queries=e.log_queries, telemetry=False)
            return self._batch

    @property
    def device(self):
        with self._lock:
            if self._device is None:
                # lazy: device_scan pulls jax at import time
                from repro.core.device_scan import (
                    DeviceScanner, ShardedDeviceScanner,
                )
                e = self._engine
                if e._sharded:
                    self._device = ShardedDeviceScanner(
                        self.snap, backend=e.device_backend,
                        log_queries=e.log_queries, telemetry=False)
                else:
                    self._device = DeviceScanner(
                        self.snap, backend=e.device_backend,
                        log_queries=e.log_queries, telemetry=False,
                        result_cache=e.result_cache)
            return self._device


class CiaoServeEngine:
    """Concurrent ingest + scan front-end over one CIAO store.

    See the module docstring for the architecture.  The engine presents
    the coordinator-facing ingest surface (``ingest_chunk`` with
    synchronous :class:`~repro.core.server.StaleEpochError` validation,
    ``plan`` / ``family`` for the stale-chunk retry path), so
    :class:`~repro.data.pipeline.IngestCoordinator` can feed it as its
    ``store`` unchanged.

    Parameters:
      * ``queue_depth`` — per-writer bounded queue capacity (slices).
      * ``writers`` — writer-pool size, default one per shard (capped at
        the shard count: shard -> writer assignment is ``s % writers``).
      * ``backpressure`` — ``"block"`` (default) or ``"reject"``.
      * ``admission`` — optional :class:`QueryAdmission`.
      * ``result_cache`` — optional shared
        :class:`~repro.core.batch_scan.ResultCache` (thread-safe).
      * ``device_backend`` — backend for ``mode="device"`` queries
        (``"xla"``, ``"pallas_interpret"``, or ``"numpy"``).
    """

    def __init__(self, store: "CiaoStore | ShardedCiaoStore", *,
                 queue_depth: int = 64, writers: int | None = None,
                 backpressure: str = "block",
                 admission: QueryAdmission | None = None,
                 result_cache: ResultCache | None = None,
                 device_backend: str = "numpy",
                 eager_promote_uncovered: bool = True,
                 refresh_interval_s: float = 0.02,
                 log_queries: bool = True):
        if backpressure not in ("block", "reject"):
            raise ValueError(f"unknown backpressure policy {backpressure!r}")
        if queue_depth < 1:
            raise ValueError(f"queue_depth must be >= 1, got {queue_depth}")
        self.store = store
        self._sharded = isinstance(store, ShardedCiaoStore)
        self._shards = list(store.shards) if self._sharded else [store]
        self.backpressure = backpressure
        self.admission = admission
        if admission is not None and admission.telemetry is None:
            admission.telemetry = getattr(store, "telemetry", None)
        self.result_cache = result_cache
        self.device_backend = device_backend
        # a raw remainder with EMPTY pushed coverage (n_covered == 0) is
        # unskippable by construction — every query must JIT-promote it.
        # Laziness buys no client-assisted savings there, so the writer
        # promotes those groups eagerly at ingest, keeping the decode
        # cost off the snapshot read path (covered remainders stay lazy:
        # their skipping potential is the paper's whole point).
        self.eager_promote_uncovered = eager_promote_uncovered
        self.log_queries = log_queries
        self.writers = max(1, min(len(self._shards),
                                  writers or len(self._shards)))
        self._queues: list[queue.Queue] = [
            queue.Queue(maxsize=int(queue_depth))
            for _ in range(self.writers)
        ]
        self._stats_lock = threading.Lock()
        self.submitted = 0          # chunks accepted by ingest_chunk
        self.enqueued = 0           # per-shard slices enqueued
        self.drained = 0            # slices applied by the writer pool
        self.rejected = 0           # submits refused by backpressure
        self.blocked_s = 0.0        # submit time spent waiting on queues
        self._errors: list[BaseException] = []
        self._closed = False
        # zero reader blocking: readers take self._readers by atomic
        # reference and NEVER rebuild it.  A background refresher
        # re-snapshots at most every refresh_interval_s when the store
        # version moved — under sustained ingest (a version bump per
        # slice) per-query rebuilds would convoy every reader behind
        # writer-held shard locks.  Reads are bounded-stale by the
        # interval; quiesce() forces a synchronous refresh, so
        # read-your-own-quiesced-writes always holds.
        self.refresh_interval_s = float(refresh_interval_s)
        self._snap_lock = threading.Lock()
        self._readers: _SnapshotReaders | None = None
        self._tuner = None
        self._tuner_thread: threading.Thread | None = None
        self._stop = threading.Event()
        self._threads = [
            threading.Thread(target=self._drain, args=(i,),
                             name=f"ciao-serve-writer-{i}", daemon=True)
            for i in range(self.writers)
        ]
        if self.refresh_interval_s > 0:
            self._threads.append(threading.Thread(
                target=self._refresh_loop, name="ciao-serve-refresher",
                daemon=True))
        for t in self._threads:
            t.start()

    # -- coordinator-facing plan surface --------------------------------------
    @property
    def plan(self):
        return self.store.plan

    @property
    def family(self):
        return self.store.family

    @property
    def epoch(self) -> int:
        return self.store.epoch

    @property
    def stats(self) -> LoadStats:
        return self.store.stats

    # -- ingest (submit side) --------------------------------------------------
    def ingest_chunk(self, chunk, bitvecs, *, epoch: int | None = None,
                     tier: int | None = None,
                     tenant: str = "default") -> LoadStats:
        """Validate, route, and enqueue one chunk; returns live stats.

        Validation is synchronous (stale epochs raise HERE, where the
        coordinator's retry loop can re-evaluate the chunk); the actual
        per-shard ingest happens on the writer pool.  The returned
        :class:`~repro.core.server.LoadStats` is the live aggregate — it
        reflects this chunk only after the writers drain it (callers
        needing post-ingest totals should :meth:`quiesce` first).
        ``tenant`` attributes any backpressure this submit hits to the
        submitting tenant in the store's telemetry plane.
        """
        if self._closed:
            raise RuntimeError("engine is closed")
        store = self.store
        resolve_ingest_coverage(
            store.plan, store.family, n_records=chunk.n_records,
            bitvecs=bitvecs, epoch=epoch, tier=tier)
        if self._sharded and store.n_shards > 1:
            items = [
                (s, sub_chunk, sub_bv, sub_objs, epoch, tier)
                for s, sub_chunk, sub_bv, sub_objs
                in store.route_slices(chunk, bitvecs)
            ]
        else:
            items = [(0, chunk, bitvecs, None, epoch, tier)]
        for item in items:
            self._enqueue(item, tenant)
        with self._stats_lock:
            self.submitted += 1
            self.enqueued += len(items)
        return store.stats

    def _enqueue(self, item, tenant: str = "default") -> None:
        q = self._queues[item[0] % self.writers]
        tele = getattr(self.store, "telemetry", None)
        if self.backpressure == "reject":
            try:
                q.put_nowait(item)
            except queue.Full:
                with self._stats_lock:
                    self.rejected += 1
                if tele is not None:
                    tele.record_backpressure(tenant=tenant, rejected=1)
                raise BackpressureError(
                    f"write queue for shard {item[0]} full "
                    f"(depth {q.maxsize})") from None
        else:
            t0 = time.perf_counter()
            q.put(item)
            dt = time.perf_counter() - t0
            if dt > 0.0:
                with self._stats_lock:
                    self.blocked_s += dt
                if tele is not None:
                    tele.record_backpressure(tenant=tenant, blocked_s=dt)

    # -- ingest (writer pool) --------------------------------------------------
    def _drain(self, wi: int) -> None:
        q = self._queues[wi]
        while True:
            item = q.get()
            try:
                if item is None:
                    return
                self._apply(item)
                with self._stats_lock:
                    self.drained += 1
            except BaseException as e:     # pragma: no cover - defensive
                # post-validation failures are store bugs, not caller
                # errors; record them for quiesce() to surface instead
                # of silently killing the writer
                with self._stats_lock:
                    self._errors.append(e)
            finally:
                q.task_done()

    def _apply(self, item) -> None:
        s, chunk, bv, objs, epoch, tier = item
        if self._sharded and self.store.n_shards > 1:
            self.store.ingest_slice(s, chunk, bv, objs,
                                    epoch=epoch, tier=tier)
            shard = self.store.shards[s]
        else:
            # single store (or 1-shard sharded store): same degenerate
            # path as its own ingest_chunk — no routing parse, no summary
            shard = self._shards[0]
            shard.ingest_chunk(chunk, bv, epoch=epoch, tier=tier)
        if self.eager_promote_uncovered:
            eff = shard.plan.epoch if epoch is None else int(epoch)
            shard.jit_load_raw(only_groups={(eff, 0)})

    def quiesce(self) -> None:
        """Block until every enqueued slice has been applied, then
        refresh the read snapshot; re-raises the first deferred writer
        error, if any.  After quiesce() returns, queries see every
        previously submitted row (read-your-writes)."""
        for q in self._queues:
            q.join()
        self._refresh()
        with self._stats_lock:
            if self._errors:
                raise self._errors[0]

    def advance_epoch(self, new_plan):
        """Quiesce, then install the next plan epoch on the store.

        The quiesce is mandatory: queued slices were validated under the
        old epoch at submit time, and advancing under them would fail
        every one of them at drain time."""
        self.quiesce()
        return self.store.advance_epoch(new_plan)

    # -- snapshot-backed reads ---------------------------------------------
    def _refresh(self) -> None:
        """Swap in a fresh snapshot bundle iff the store version moved.

        Runs on the refresher thread (and synchronously from quiesce /
        the very first read); readers only ever take the resulting
        reference, so a slow rebuild never blocks a query."""
        with self._snap_lock:
            readers = self._readers
            if readers is None or \
                    readers.snap.base_version != self.store.data_version:
                self._readers = _SnapshotReaders(
                    self, self.store.snapshot())

    def _refresh_loop(self) -> None:
        while not self._stop.wait(self.refresh_interval_s):
            try:
                self._refresh()
            except BaseException as e:  # pragma: no cover - defensive
                with self._stats_lock:
                    self._errors.append(e)
                return

    def snapshot(self):
        """The engine's current read snapshot (shared, bounded-stale).

        Between refreshes every reader shares one snapshot and its
        scanner bundle (so segment memos and result-cache entries keep
        paying off); staleness is bounded by ``refresh_interval_s``
        under live ingest and by :meth:`quiesce` on demand.
        """
        return self._reader_bundle().snap

    def _reader_bundle(self) -> _SnapshotReaders:
        readers = self._readers
        if readers is None:             # first read builds synchronously
            self._refresh()
            readers = self._readers
        return readers

    def query(self, q: Query, *, tenant: str = "default",
              mode: str = "host") -> ScanResult:
        """COUNT(*) against the current snapshot.

        ``mode``: ``"host"`` (sequential skipping scan / sharded
        scatter-gather), ``"batch"`` (the multi-query batcher, one-query
        batch), or ``"device"`` (device-resident scan plane).  Admission
        control, when configured, gates BEFORE the snapshot is taken.
        """
        return self._admitted(tenant, lambda r: self._scan(r, q, mode, tenant))

    def query_batch(self, queries, *, tenant: str = "default"
                    ) -> list[ScanResult]:
        """N-query batch against ONE consistent snapshot (admitted as a
        single unit of in-flight work)."""
        def run(readers: _SnapshotReaders) -> list[ScanResult]:
            out = readers.batch.scan_batch(queries)
            tele = getattr(self.store, "telemetry", None)
            if tele is not None:
                for r in out:
                    tele.record_scan(r, tenant=tenant)
            return out
        return self._admitted(tenant, run, record=False)

    def _admitted(self, tenant: str, fn, *, record: bool = True):
        tier = self.admission.acquire(tenant) if self.admission else None
        try:
            readers = self._reader_bundle()
            return fn(readers)
        finally:
            if tier is not None:
                self.admission.release(tier)

    def _scan(self, readers: _SnapshotReaders, q: Query,
              mode: str, tenant: str) -> ScanResult:
        if mode == "host":
            r = readers.host.scan(q)
        elif mode == "batch":
            r = readers.batch.scan(q)
        elif mode == "device":
            r = readers.device.scan(q)
        else:
            raise ValueError(f"unknown query mode {mode!r}")
        tele = getattr(self.store, "telemetry", None)
        if tele is not None:
            tele.record_scan(r, tenant=tenant)
        return r

    # -- background physical-design tuning (DESIGN.md §18) -------------------
    def start_tuner(self, tuner, *, interval_s: float = 0.02) -> None:
        """Drive a :class:`~repro.core.tuner.PhysicalDesignTuner` from a
        background thread: one ``tuner.step()`` per ``interval_s`` tick.

        The tuner's migration writer coexists with the writer pool by
        construction — every per-shard mutation on either side happens
        under that shard's ingest lock, and segment moves are fenced
        against ``snapshot()`` — so readers stay non-blocking and counts
        stay exact while rows migrate.  Stopped by :meth:`close`.
        """
        if self._closed:
            raise RuntimeError("engine is closed")
        if self._tuner_thread is not None:
            raise RuntimeError("a tuner is already running")
        self._tuner = tuner
        t = threading.Thread(target=self._tuner_loop,
                             args=(float(interval_s),),
                             name="ciao-serve-tuner", daemon=True)
        self._tuner_thread = t
        t.start()

    def _tuner_loop(self, interval_s: float) -> None:
        while not self._stop.wait(interval_s):
            try:
                self._tuner.step()
            except BaseException as e:  # pragma: no cover - defensive
                with self._stats_lock:
                    self._errors.append(e)
                return

    # -- lifecycle -----------------------------------------------------------
    def stats_report(self) -> dict:
        """Engine counters + the wrapped store's own report."""
        with self._stats_lock:
            eng = {
                "writers": self.writers,
                "backpressure": self.backpressure,
                "refresh_interval_s": self.refresh_interval_s,
                "submitted": self.submitted,
                "enqueued": self.enqueued,
                "drained": self.drained,
                "rejected": self.rejected,
                "blocked_s": round(self.blocked_s, 6),
                "queue_depths": [q.qsize() for q in self._queues],
                "errors": len(self._errors),
            }
        out = {"engine": eng, "store": self.store.stats_report()}
        if self._tuner is not None:
            out["tuner"] = {
                "migrating": bool(getattr(self._tuner, "migrating", False)),
                "events": len(getattr(self._tuner, "history", ())),
            }
        if self.admission is not None:
            out["admission"] = self.admission.stats()
        if self.result_cache is not None:
            out["result_cache"] = {
                "entries": len(self.result_cache),
                "hits": self.result_cache.hits,
                "misses": self.result_cache.misses,
            }
        return out

    def close(self, *, drain: bool = True) -> None:
        """Stop accepting submits, optionally drain, stop the writers."""
        if self._closed:
            return
        self._closed = True
        if drain:
            for q in self._queues:
                q.join()
        self._stop.set()                  # stops the refresher + tuner
        for q in self._queues:
            q.put(None)                   # one sentinel per writer
        for t in self._threads:
            t.join()
        if self._tuner_thread is not None:
            self._tuner_thread.join()
            self._tuner_thread = None

    def __enter__(self) -> "CiaoServeEngine":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()
