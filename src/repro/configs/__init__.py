"""Config registry: ``get_config(arch_id)`` + shape suite + input specs."""
from __future__ import annotations

import importlib

import jax
import jax.numpy as jnp
import numpy as np

from .base import (  # noqa: F401
    SHAPES, MLAConfig, ModelConfig, MoEConfig, ShapeConfig,
    shape_applicable,
)

ARCHS: dict[str, str] = {
    "seamless-m4t-medium": "seamless_m4t_medium",
    "internvl2-76b": "internvl2_76b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "deepseek-7b": "deepseek_7b",
    "qwen3-1.7b": "qwen3_1_7b",
    "qwen1.5-4b": "qwen1_5_4b",
    "qwen3-8b": "qwen3_8b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "rwkv6-3b": "rwkv6_3b",
}


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; have {sorted(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{ARCHS[arch]}")
    return mod.CONFIG


def list_archs() -> list[str]:
    return list(ARCHS)


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------

def cache_alloc_len(seq_len: int) -> int:
    """Decode cache allocation: context + headroom, 128-aligned."""
    return seq_len + 128


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStructs for every model input of this (arch, shape) cell."""
    B, S = shape.global_batch, shape.seq_len
    f32, i32 = jnp.float32, jnp.int32

    if shape.kind == "train":
        if cfg.family == "encdec":
            return {
                "frames": jax.ShapeDtypeStruct((B, S // 2, cfg.d_model), f32),
                "tokens": jax.ShapeDtypeStruct((B, S // 2), i32),
                "loss_mask": jax.ShapeDtypeStruct((B, S // 2), f32),
            }
        out = {
            "tokens": jax.ShapeDtypeStruct((B, S - cfg.frontend_len), i32),
            "loss_mask": jax.ShapeDtypeStruct((B, S - cfg.frontend_len), f32),
        }
        if cfg.frontend == "vision":
            out["extra_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.frontend_len, cfg.d_model), f32
            )
        return out

    if shape.kind == "prefill":
        if cfg.family == "encdec":
            return {
                "frames": jax.ShapeDtypeStruct((B, S, cfg.d_model), f32),
                "tokens": jax.ShapeDtypeStruct((B, max(S // 8, 128)), i32),
            }
        out = {"tokens": jax.ShapeDtypeStruct((B, S - cfg.frontend_len), i32)}
        if cfg.frontend == "vision":
            out["extra_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.frontend_len, cfg.d_model), f32
            )
        return out

    # decode: one new token against a cache of S
    return {
        "tokens": jax.ShapeDtypeStruct((B,), i32),
        "cur_index": jax.ShapeDtypeStruct((), i32),
    }


def make_batch(cfg: ModelConfig, shape: ShapeConfig, seed: int = 0) -> dict:
    """Concrete random batch matching input_specs (smoke tests)."""
    rng = np.random.default_rng(seed)
    out = {}
    for k, sds in input_specs(cfg, shape).items():
        if sds.dtype == jnp.int32 and k == "tokens":
            out[k] = rng.integers(0, cfg.vocab_size, size=sds.shape).astype(np.int32)
        elif sds.dtype == jnp.int32:
            out[k] = np.zeros(sds.shape, np.int32)
        elif k == "loss_mask":
            out[k] = np.ones(sds.shape, np.float32)
        else:
            out[k] = rng.normal(size=sds.shape).astype(np.float32)
    return out
