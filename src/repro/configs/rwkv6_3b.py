"""rwkv6-3b [ssm]: 32L d=2560 (attn-free) ff=8960 V=65536, head_size 64.

Finch: data-dependent decay + token-shift ddlerp.  [arXiv:2404.05892; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="rwkv",
    n_layers=32,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65536,
    rwkv_head_size=64,
    source="arXiv:2404.05892; hf",
)
