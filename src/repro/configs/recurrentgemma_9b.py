"""recurrentgemma-9b [hybrid]: 38L d=4096 16H(kv=1) ff=12288 V=256000.

[arXiv:2402.19427; unverified].  Griffin pattern: (rec, rec, local-attn)
repeating; 38 = 12x3 + 2 leftover recurrent layers.  RG-LRU width 4096,
local attention window 2048, MQA (kv=1).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    attention="local",
    window=2048,
    block_pattern=("rec", "rec", "attn"),
    lru_width=4096,
    act="gelu",
    microbatches=4,
    source="arXiv:2402.19427; unverified",
)
