"""llama4-scout-17b-a16e [moe]: 48L d=5120 40H(kv=8) ff_expert=8192 V=202048.

MoE 16 experts top-1 + 1 shared expert, every layer routed; early-fusion
multimodal (frontend stubbed).  [hf:meta-llama/Llama-4-Scout-17B-16E;
unverified]
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="decoder",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    rope_theta=500000.0,
    moe=MoEConfig(
        n_experts=16,
        top_k=1,
        d_ff_expert=8192,
        n_shared_experts=1,
        d_ff_shared=8192,
    ),
    param_dtype="bfloat16",
    serve_profile="tp_fsdp",  # params too large for TP-resident serving on one pod
    microbatches=8,
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
)
