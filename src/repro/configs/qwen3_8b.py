"""qwen3-8b [dense]: 36L d=4096 32H(kv=8) ff=12288 V=151936, qk_norm, GQA.

[hf:Qwen/Qwen3-8B; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b",
    family="decoder",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=12288,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1000000.0,
    microbatches=2,
    source="hf:Qwen/Qwen3-8B; hf",
)
