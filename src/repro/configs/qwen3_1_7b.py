"""qwen3-1.7b [dense]: 28L d=2048 16H(kv=8) ff=6144 V=151936, qk_norm, GQA.

[hf:Qwen/Qwen3-8B family; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b",
    family="decoder",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=6144,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1000000.0,
    tied_embeddings=True,
    source="hf:Qwen/Qwen3-8B; hf",
)
