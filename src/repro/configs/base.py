"""Model / run configuration.

One frozen dataclass describes every assigned architecture; per-arch files in
this package instantiate it with the published numbers.  ``reduced()`` shrinks
any config to a CPU-smoke-testable size while preserving its family-defining
structure (GQA ratio, MoE routing, MLA ranks, block pattern, ...).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    d_ff_shared: int = 0
    capacity_factor: float = 1.25
    first_dense_layers: int = 0       # leading dense layers (deepseek-v3: 3)
    d_ff_dense: int = 0               # d_ff of those dense layers
    router_aux_weight: float = 0.001


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_head_dim: int
    qk_rope_head_dim: int
    v_head_dim: int


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                        # decoder | encdec | hybrid | rwkv
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                  # 0 -> d_model // n_heads

    # attention flavor
    attention: str = "full"            # full | local | mla
    window: int = 0                    # local-attention window
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0

    mla: MLAConfig | None = None
    moe: MoEConfig | None = None

    # hybrid (recurrentgemma / griffin)
    block_pattern: tuple[str, ...] = ()  # e.g. ("rec", "rec", "attn")
    lru_width: int = 0
    conv_width: int = 4

    # rwkv
    rwkv_head_size: int = 0

    # enc-dec
    enc_layers: int = 0
    dec_layers: int = 0

    # modality frontend (stub: precomputed embeddings via input_specs)
    frontend: str = "none"             # none | audio | vision
    frontend_len: int = 0              # patches/frames prepended (vision)

    tied_embeddings: bool = False
    act: str = "silu"
    norm_eps: float = 1e-6

    # numerics / runtime
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: str = "full"                # none | dots | full | save_block_io
    seq_parallel: bool = False         # Megatron-SP: shard seq over model between blocks
    sharding_profile: str = "tp_fsdp"  # tp_fsdp | fsdp (pure ZeRO-3, batch over all axes)
    serve_profile: str = "serve_tp"    # prefill/decode param layout (giants: tp_fsdp)
    scan_layers: bool = True
    attn_q_chunk: int = 1024           # flash-jnp chunk sizes
    attn_k_chunk: int = 1024
    rwkv_chunk: int = 128

    # training
    microbatches: int = 1
    opt_dtype: str = "float32"
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    z_loss: float = 0.0

    # paper citation tier
    source: str = ""

    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def is_subquadratic(self) -> bool:
        """Can this arch run long_500k (no full-attention block)?"""
        if self.family == "rwkv":
            return True
        if self.family == "hybrid":
            return all(b != "attn" or self.window > 0 for b in ("attn",)) and self.window > 0
        return False

    def layer_groups(self) -> tuple[tuple[str, int], ...]:
        """Homogeneous layer groups, each lowered as one lax.scan.

        Returns ((block_type, n_repeat), ...).  Block types:
          dense_attn | moe_attn | rec | local_attn | rwkv | pattern:<spec>
        """
        if self.family == "rwkv":
            return (("rwkv", self.n_layers),)
        if self.family == "hybrid":
            pat = self.block_pattern or ("rec", "rec", "attn")
            n_super, rem = divmod(self.n_layers, len(pat))
            groups: list[tuple[str, int]] = []
            if n_super:
                groups.append(("pattern:" + ",".join(pat), n_super))
            if rem:
                groups.append(("pattern:" + ",".join(pat[:rem]), 1))
            return tuple(groups)
        if self.moe is not None:
            groups = []
            if self.moe.first_dense_layers:
                groups.append(("dense_attn", self.moe.first_dense_layers))
            groups.append(("moe_attn", self.n_layers - self.moe.first_dense_layers))
            return tuple(groups)
        return (("dense_attn", self.n_layers),)

    def reduced(self) -> "ModelConfig":
        """Structure-preserving shrink for CPU smoke tests."""
        kw: dict = {}
        kw["n_layers"] = min(
            self.n_layers,
            2 if not self.block_pattern else len(self.block_pattern))
        kw["d_model"] = 64
        ratio = max(self.n_heads // max(self.n_kv_heads, 1), 1)
        kw["n_heads"] = 4
        kw["n_kv_heads"] = max(4 // ratio, 1)
        kw["head_dim"] = 16
        kw["d_ff"] = 128
        kw["vocab_size"] = 512
        kw["lru_width"] = 64 if self.lru_width else 0
        kw["window"] = min(self.window, 32) if self.window else 0
        kw["rwkv_head_size"] = 16 if self.rwkv_head_size else 0
        kw["enc_layers"] = min(self.enc_layers, 2) if self.enc_layers else 0
        kw["dec_layers"] = min(self.dec_layers, 2) if self.dec_layers else 0
        kw["frontend_len"] = min(self.frontend_len, 8) if self.frontend_len else 0
        kw["attn_q_chunk"] = 32
        kw["attn_k_chunk"] = 32
        kw["rwkv_chunk"] = 16
        kw["microbatches"] = 1
        kw["param_dtype"] = "float32"
        if self.mla is not None:
            kw["mla"] = MLAConfig(
                q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                qk_rope_head_dim=8, v_head_dim=16,
            )
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe,
                n_experts=min(self.moe.n_experts, 8),
                top_k=min(self.moe.top_k, 2),
                d_ff_expert=64,
                d_ff_shared=64 if self.moe.n_shared_experts else 0,
                first_dense_layers=min(self.moe.first_dense_layers, 1),
                d_ff_dense=128 if self.moe.first_dense_layers else 0,
            )
            kw["n_layers"] = max(kw["n_layers"], (1 if self.moe.first_dense_layers else 0) + 1)
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned (input-shape) cell."""

    name: str                          # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) — skips documented in DESIGN.md §5."""
    if shape.name == "long_500k" and not cfg.is_subquadratic():
        return False, ("pure full-attention arch: 500k decode context "
                       "is quadratic; skipped per assignment")
    return True, ""
