"""internvl2-76b [vlm]: 80L d=8192 64H(kv=8) ff=28672 V=128256.

[arXiv:2404.16821; unverified].  InternViT frontend is a stub: input_specs
provides 1024 precomputed patch embeddings prepended to the text sequence.
LLM backbone is llama-3-70b-shaped (GQA kv=8, SwiGLU).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="decoder",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    rope_theta=500000.0,
    frontend="vision",
    frontend_len=1024,
    param_dtype="bfloat16",
    microbatches=8,
    source="arXiv:2404.16821; unverified",
)
