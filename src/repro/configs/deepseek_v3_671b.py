"""deepseek-v3-671b [moe]: 61L d=7168 128H MLA, 256 routed top-8 + 1 shared.

MLA: q_lora 1536, kv_lora 512, qk_nope 128, qk_rope 64, v 128.  First 3
layers dense (ff 18432); routed expert ff 2048; shared expert ff 2048.
MTP (multi-token prediction) is provided as an optional extra head (off in
the baseline step; see train.mtp).  [arXiv:2412.19437; hf]
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="decoder",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,
    vocab_size=129280,
    attention="mla",
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        n_experts=256,
        top_k=8,
        d_ff_expert=2048,
        n_shared_experts=1,
        d_ff_shared=2048,
        first_dense_layers=3,
        d_ff_dense=18432,
        capacity_factor=1.25,
    ),
    param_dtype="bfloat16",
    serve_profile="tp_fsdp",  # params too large for TP-resident serving on one pod
    opt_dtype="bfloat16",
    microbatches=8,
    source="arXiv:2412.19437; hf",
)
