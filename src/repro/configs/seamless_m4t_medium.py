"""seamless-m4t-medium [audio enc-dec]: 12L d=1024 16H(kv=16) ff=4096 V=256206.

[arXiv:2308.11596; hf].  Backbone only: the audio frontend is a stub
(precomputed frame embeddings via input_specs).  12 encoder + 12 decoder
layers (the assignment's "12L" is per stack).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=24,
    enc_layers=12,
    dec_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    frontend="audio",
    act="gelu",
    source="arXiv:2308.11596; hf",
)
