"""Pure-jnp oracles for every Pallas kernel (the ``assert_allclose`` refs).

These mirror ``repro.core.client``'s vectorized numpy spec, expressed in
plain jnp so they run under jit on any backend.  The kernel tests sweep
shapes/dtypes and assert exact equality kernel-vs-ref; the core tests assert
ref-vs-PythonEngine (the paper-faithful ``bytes.find`` oracle).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

DELIM_COMMA = 44
DELIM_BRACE = 125


def _shift_left(x: jnp.ndarray, i: int) -> jnp.ndarray:
    if i == 0:
        return x
    pad = jnp.zeros(x.shape[:-1] + (i,), dtype=x.dtype)
    return jnp.concatenate([x[..., i:], pad], axis=-1)


@functools.partial(jax.jit, static_argnames=())
def multi_match_any_ref(data, patterns, plens):
    """uint8[P, R]: pattern p occurs anywhere in record r."""
    P, M = patterns.shape

    def one(pat, m):
        acc = data == pat[0]
        for i in range(1, M):
            acc = jnp.logical_and(
                acc, jnp.logical_or(_shift_left(data, i) == pat[i], i >= m)
            )
        return jnp.any(acc, axis=1)

    hits = jax.vmap(one)(patterns, plens[:, 0])
    return hits.astype(jnp.uint8)


def _window_eq(data, pat, m: int):
    acc = data == pat[0]
    for i in range(1, m):
        acc = jnp.logical_and(acc, _shift_left(data, i) == pat[i])
    return acc


def _segmented_suffix_any(val_hit, delim):
    """cond[p] = exists v >= p, in p's segment, with val_hit[v].

    Suffix scan with resets at delimiters == flip + forward prefix scan with
    the standard reset combine (y resets => drop x's accumulation).
    """
    R, L = val_hit.shape
    pos = lax.broadcasted_iota(jnp.int32, (R, L), 1)
    x = jnp.where(jnp.logical_and(val_hit, jnp.logical_not(delim)), pos, -1)
    xf = jnp.flip(x, axis=1)
    df = jnp.flip(delim, axis=1)

    def combine(a, b):
        am, astop = a
        bm, bstop = b
        return jnp.where(bstop, bm, jnp.maximum(am, bm)), jnp.logical_or(astop, bstop)

    m, _ = lax.associative_scan(combine, (xf, df), axis=1)
    return jnp.flip(m, axis=1) >= 0


@functools.partial(jax.jit, static_argnames=("mk", "mv", "unbounded"))
def key_value_match_ref(data, key_pat, val_pat, *, mk: int, mv: int, unbounded: bool):
    """uint8[1, R]: the paper's key-value predicate semantics."""
    key_hit = _window_eq(data, key_pat[0], mk)
    val_hit = _window_eq(data, val_pat[0], mv)
    if unbounded:
        cond = jnp.flip(
            lax.associative_scan(jnp.logical_or, jnp.flip(val_hit, axis=1), axis=1),
            axis=1,
        )
    else:
        delim = jnp.logical_or(data == DELIM_COMMA, data == DELIM_BRACE)
        cond = _segmented_suffix_any(val_hit, delim)
    hit = jnp.logical_and(key_hit, _shift_left(cond, mk))
    return jnp.any(hit, axis=1).astype(jnp.uint8)[None, :]


@jax.jit
def bitvector_reduce_ref(bitvecs):
    and_w = lax.reduce(
        bitvecs, jnp.uint32(0xFFFFFFFF), lambda a, b: jnp.bitwise_and(a, b), (0,)
    )
    or_w = lax.reduce(
        bitvecs, jnp.uint32(0), lambda a, b: jnp.bitwise_or(a, b), (0,)
    )
    cnt = lax.population_count(and_w).astype(jnp.int32).sum()
    return and_w, or_w, cnt
