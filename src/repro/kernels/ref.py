"""Pure-jnp oracles for every Pallas kernel (the ``assert_allclose`` refs).

These mirror ``repro.core.client``'s vectorized numpy spec, expressed in
plain jnp so they run under jit on any backend.  The kernel tests sweep
shapes/dtypes and assert exact equality kernel-vs-ref; the core tests assert
ref-vs-PythonEngine (the paper-faithful ``bytes.find`` oracle).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

DELIM_COMMA = 44
DELIM_BRACE = 125


def _shift_left(x: jnp.ndarray, i: int) -> jnp.ndarray:
    if i == 0:
        return x
    pad = jnp.zeros(x.shape[:-1] + (i,), dtype=x.dtype)
    return jnp.concatenate([x[..., i:], pad], axis=-1)


@functools.partial(jax.jit, static_argnames=())
def multi_match_any_ref(data, patterns, plens):
    """uint8[P, R]: pattern p occurs anywhere in record r."""
    P, M = patterns.shape

    def one(pat, m):
        acc = data == pat[0]
        for i in range(1, M):
            acc = jnp.logical_and(
                acc, jnp.logical_or(_shift_left(data, i) == pat[i], i >= m)
            )
        return jnp.any(acc, axis=1)

    hits = jax.vmap(one)(patterns, plens[:, 0])
    return hits.astype(jnp.uint8)


def _window_eq(data, pat, m: int):
    acc = data == pat[0]
    for i in range(1, m):
        acc = jnp.logical_and(acc, _shift_left(data, i) == pat[i])
    return acc


def _segmented_suffix_any(val_hit, delim):
    """cond[p] = exists v >= p, in p's segment, with val_hit[v].

    Suffix scan with resets at delimiters == flip + forward prefix scan with
    the standard reset combine (y resets => drop x's accumulation).
    """
    R, L = val_hit.shape
    pos = lax.broadcasted_iota(jnp.int32, (R, L), 1)
    x = jnp.where(jnp.logical_and(val_hit, jnp.logical_not(delim)), pos, -1)
    xf = jnp.flip(x, axis=1)
    df = jnp.flip(delim, axis=1)

    def combine(a, b):
        am, astop = a
        bm, bstop = b
        return jnp.where(bstop, bm, jnp.maximum(am, bm)), jnp.logical_or(astop, bstop)

    m, _ = lax.associative_scan(combine, (xf, df), axis=1)
    return jnp.flip(m, axis=1) >= 0


@functools.partial(jax.jit, static_argnames=("mk", "mv", "unbounded"))
def key_value_match_ref(data, key_pat, val_pat, *, mk: int, mv: int, unbounded: bool):
    """uint8[1, R]: the paper's key-value predicate semantics."""
    key_hit = _window_eq(data, key_pat[0], mk)
    val_hit = _window_eq(data, val_pat[0], mv)
    if unbounded:
        cond = jnp.flip(
            lax.associative_scan(jnp.logical_or, jnp.flip(val_hit, axis=1), axis=1),
            axis=1,
        )
    else:
        delim = jnp.logical_or(data == DELIM_COMMA, data == DELIM_BRACE)
        cond = _segmented_suffix_any(val_hit, delim)
    hit = jnp.logical_and(key_hit, _shift_left(cond, mk))
    return jnp.any(hit, axis=1).astype(jnp.uint8)[None, :]


def _masked_window_eq(data, pat, m, max_len: int):
    """Window-eq with DYNAMIC length m (mask positions where i >= m)."""
    acc = data == pat[0]
    for i in range(1, max_len):
        acc = jnp.logical_and(
            acc, jnp.logical_or(_shift_left(data, i) == pat[i], i >= m)
        )
    return acc


@functools.partial(jax.jit, static_argnames=("n_simple",))
def clause_bitvectors_ref(data, ukeys, uklens, uvals, uvlens, uunb,
                          key_ids, val_ids, membership, n_valid,
                          *, n_simple: int):
    """jnp oracle for the fused pushdown pass (kernels.fused).

    Same contract as :func:`repro.kernels.fused.clause_bitvectors_fused`
    minus the R-blocking: returns packed per-clause words ``uint32[C, W]``,
    the OR'd load-mask words ``uint32[W]`` and per-clause popcounts
    ``int32[C]``, with rows >= ``n_valid`` masked out.

    Exploits the plan structure (``kernels.plan.compile_plan``):
    predicates arrive simple-first with a static ``n_simple`` boundary so
    the simple block skips the key-value machinery; window equality runs
    once per UNIQUE key pattern (shared by simple patterns and key-value
    keys) and the value-confinement scan once per UNIQUE (value,
    unbounded) pair — per-predicate work is just a roll + AND.
    """
    from repro.core import bitvector

    R, L = data.shape
    Uk, Mk = ukeys.shape
    Uv, Mv = uvals.shape
    P = key_ids.shape[0]

    # one window-equality pass per unique key/simple pattern
    ukey_hit = jax.vmap(
        lambda k, m: _masked_window_eq(data, k, m, Mk))(ukeys, uklens)
    any_key = jnp.any(ukey_hit, axis=2)                     # (Uk, R)

    parts = []
    if n_simple:
        ks = key_ids[:n_simple]
        parts.append(jnp.logical_or(any_key[ks], (uklens[ks] == 0)[:, None]))
    if n_simple < P:
        delim_raw = jnp.logical_or(data == DELIM_COMMA, data == DELIM_BRACE)

        # positions/counts are bounded by the (static) stride L: int16
        # halves scan traffic for normal chunks, int32 keeps correctness
        # for strides past the int16 sentinel (no silent wraparound)
        pos_dt = jnp.int16 if L < 0x7FFF else jnp.int32
        big = jnp.array(0x7FFF if L < 0x7FFF else 0x7FFFFFFF, dtype=pos_dt)

        def one_val(val, mv, unb):
            """cond[p] = usable value occurrence at/after p, same segment.

            Reformulated around the NEAREST next value hit: delimiter
            counts are monotone, so if the nearest hit nv[p] crosses a
            delimiter every farther hit does too.  One min-scan + one
            gather — cheaper than the paired int32 max-scan-with-resets
            the stand-alone kernel uses.
            """
            val_hit = _masked_window_eq(data, val, mv, Mv)
            delim = jnp.logical_and(delim_raw, unb == 0)
            pos = lax.broadcasted_iota(pos_dt, val_hit.shape, 1)
            usable = jnp.where(
                jnp.logical_and(val_hit, jnp.logical_not(delim)), pos, big)
            nv = jnp.flip(
                lax.associative_scan(
                    jnp.minimum, jnp.flip(usable, axis=1), axis=1),
                axis=1,
            )
            # E[p] = # delimiters in [0, p): none inside [p, nv[p])
            dinc = jnp.cumsum(delim.astype(pos_dt), axis=1, dtype=pos_dt)
            excl = dinc - delim.astype(pos_dt)
            hit_found = nv < big
            e_at_nv = jnp.take_along_axis(
                excl, jnp.where(hit_found, nv, 0).astype(jnp.int32), axis=1)
            return jnp.logical_and(hit_found, e_at_nv == excl)

        # one confinement scan per unique (value, unbounded) pair
        ucond = jax.vmap(one_val)(uvals, uvlens, uunb)      # (Uv, R, L)
        jpos = lax.broadcasted_iota(jnp.int32, (R, L), 1)

        def one_kv(kid, vid):
            # cond[j + mk] via one dynamic roll (O(L) vs the O(Mk * L)
            # select-over-static-shifts chain the Pallas kernel needs); a
            # key window at j only fits when j + mk <= L, so wrap-around
            # is masked.
            mk = uklens[kid]
            region = jnp.where(
                jpos < L - mk, jnp.roll(ucond[vid], -mk, axis=1), False)
            return jnp.any(jnp.logical_and(ukey_hit[kid], region), axis=1)

        parts.append(jax.vmap(one_kv)(key_ids[n_simple:], val_ids[n_simple:]))
    hits = jnp.concatenate(parts, axis=0)                   # bool[P, R]
    valid = jnp.arange(R, dtype=jnp.int32) < n_valid[0, 0]
    # clause OR over member predicates == membership @ hits > 0
    combined = jnp.einsum(
        "cp,pr->cr", membership.astype(jnp.int32), hits.astype(jnp.int32)
    )
    bits = jnp.logical_and(combined > 0, valid[None, :])
    words = bitvector.jnp_pack(bits)
    or_words = lax.reduce(
        words, jnp.uint32(0), lambda a, b: jnp.bitwise_or(a, b), (0,)
    )
    counts = jnp.sum(bits, axis=1, dtype=jnp.int32)
    return words, or_words, counts


@jax.jit
def bitvector_reduce_ref(bitvecs):
    and_w = lax.reduce(
        bitvecs, jnp.uint32(0xFFFFFFFF), lambda a, b: jnp.bitwise_and(a, b), (0,)
    )
    or_w = lax.reduce(
        bitvecs, jnp.uint32(0), lambda a, b: jnp.bitwise_or(a, b), (0,)
    )
    cnt = lax.population_count(and_w).astype(jnp.int32).sum()
    return and_w, or_w, cnt
