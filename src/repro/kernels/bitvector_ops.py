"""Pallas kernel for bit-vector reduction (data skipping / partial loading).

Given packed bit-vectors ``uint32[P, W]`` it produces, per 128-word tile:
  * ``and_words`` — AND across the P selected clauses (query-side skipping);
  * ``or_words``  — OR across clauses (ingest-side load mask);
  * ``counts``    — surviving-row popcount per tile (selectivity feedback).

One pass, one kernel: on TPU this is a pure VPU streaming op; the popcount
uses ``lax.population_count`` on the reduced words only.

This kernel serves the QUERY side (AND over the pushed clauses of one
query).  The ingest-side OR/load-mask/popcount that used to require a
second launch per chunk is folded into the fused pushdown pass
(:mod:`repro.kernels.fused`), so a chunk is fully evaluated in one launch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _reduce_kernel(bv_ref, and_ref, or_ref, cnt_ref):
    bv = bv_ref[...]                          # (P, W_blk) uint32
    and_words = bv[0]
    or_words = bv[0]
    for p in range(1, bv.shape[0]):           # P is a static block dim
        and_words = jnp.bitwise_and(and_words, bv[p])
        or_words = jnp.bitwise_or(or_words, bv[p])
    and_ref[0, :] = and_words
    or_ref[0, :] = or_words
    cnt_ref[0, 0] = lax.population_count(and_words).astype(jnp.int32).sum()


@functools.partial(jax.jit, static_argnames=("w_blk", "interpret"))
def bitvector_reduce(
    bitvecs: jnp.ndarray,   # uint32[P, W]  (W % w_blk == 0)
    *,
    w_blk: int = 128,
    interpret: bool = True,
):
    P, W = bitvecs.shape
    if W % w_blk:
        raise ValueError(f"W={W} not a multiple of w_blk={w_blk}")
    n_blocks = W // w_blk
    and_w, or_w, cnt = pl.pallas_call(
        _reduce_kernel,
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec((P, w_blk), lambda wb: (0, wb))],
        out_specs=[
            pl.BlockSpec((1, w_blk), lambda wb: (0, wb)),
            pl.BlockSpec((1, w_blk), lambda wb: (0, wb)),
            pl.BlockSpec((1, 1), lambda wb: (0, wb)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, W), jnp.uint32),
            jax.ShapeDtypeStruct((1, W), jnp.uint32),
            jax.ShapeDtypeStruct((1, n_blocks), jnp.int32),
        ],
        interpret=interpret,
    )(bitvecs)
    return and_w[0], or_w[0], cnt[0].sum()
