"""Pallas TPU flash attention (beyond-paper kernel for the compute plane).

Canonical TPU structure: grid (B, H, nq, nk) with the kv dimension
innermost; the output block for (b, h, qi) is revisited across nk steps and
the running softmax stats (m, l) and the f32 accumulator live in VMEM
scratch.  GQA is handled in the BlockSpec index map (kv head = h // G), so
grouped queries share kv blocks without materializing repeats.

VMEM working set per step: q(qb×d) + k/v(kb×d) + acc(qb×d) + stats — with
qb=kb=256, d=128 that is ~0.5 MiB, far under the ~16 MiB/core budget, and
arbitrary sequence lengths stream through the grid.

Validated in interpret mode against ``repro.models.attention.flash_attention``
(the production jnp path) across shape sweeps in tests/test_kernels.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, qb: int, kb: int, nk: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0]                                # (qb, d)
    k = k_ref[0, 0]                                # (kb, d)
    v = v_ref[0, 0]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale                                      # (qb, kb)

    if causal:
        q_pos = qi * qb + lax.broadcasted_iota(jnp.int32, (qb, kb), 0)
        k_pos = ki * kb + lax.broadcasted_iota(jnp.int32, (qb, kb), 1)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    shift = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
    p = jnp.exp(s - shift[:, None])
    if causal:
        p = jnp.where(q_pos >= k_pos, p, 0.0)
    alpha = jnp.exp(jnp.where(m_prev <= NEG_INF / 2, NEG_INF, m_prev - shift))
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v.astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    l_scr[...] = l_prev * alpha + p.sum(axis=-1)
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "q_block", "k_block", "interpret"),
)
def flash_attention_tpu(
    q: jnp.ndarray,   # (B, H, Sq, d)
    k: jnp.ndarray,   # (B, Hkv, Sk, d)
    v: jnp.ndarray,   # (B, Hkv, Sk, d)
    *,
    causal: bool = True,
    q_block: int = 256,
    k_block: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    B, H, Sq, d = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    G = H // Hkv
    qb, kb = min(q_block, Sq), min(k_block, Sk)
    if Sq % qb or Sk % kb:
        raise ValueError(f"S must divide blocks: {Sq}%{qb}, {Sk}%{kb}")
    nq, nk = Sq // qb, Sk // kb
    grid = (B, H, nq, nk)
    scale = d ** -0.5

    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, qb=qb, kb=kb,
                          nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, qb, d), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, kb, d), lambda b, h, qi, ki: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, kb, d), lambda b, h, qi, ki: (b, h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, qb, d), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qb,), jnp.float32),
            pltpu.VMEM((qb,), jnp.float32),
            pltpu.VMEM((qb, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
