"""Device offload hooks for the columnar scanner's residual phase.

The columnar scan (DESIGN.md §13) is host-side numpy by default: packed
bitvector AND, candidate unpack, vectorized column predicates.  The AND
reduction over pushed clause rows is the one piece with a natural device
form — it is exactly the ``reduce_bitvectors`` shape the fused ingest
kernel already exploits — so this module exposes it as an optional
``and_reduce`` for :class:`repro.core.server.DataSkippingScanner`:

    scanner = DataSkippingScanner(store, and_reduce=bv_and_many_xla)

Shapes vary per segment (W = ceil(n_rows/32), P = pushed rows), and a
jitted reduction retraces per exact (P, W).  A store holds one dominant W
after compaction, but open builder tails, tiered coverage groups and the
sharded plane's per-shard row counts each mint fresh shapes — so both
entry points pad to power-of-two (P, W) BUCKETS before dispatch
(``_pow2``), with the reduction identity as fill (0xFFFFFFFF for AND,
0 for popcount).  The jit cache then grows with the log of the largest
shape ever seen, not with the number of distinct segment layouts; pinned
by the trace-count test in ``tests/test_device_scan.py``.  Kept
deliberately tiny: column-predicate evaluation stays on the host, where
the dictionary/zone-map structures live (the full device residual path is
``kernels.scan_fused``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

_AND_IDENTITY = np.uint32(0xFFFFFFFF)


def _pow2(n: int, floor: int = 1) -> int:
    """Smallest power of two >= max(n, floor)."""
    n = max(int(n), floor)
    return 1 << (n - 1).bit_length()


@jax.jit
def _and_reduce(words: jnp.ndarray) -> jnp.ndarray:
    return lax.reduce(
        words.astype(jnp.uint32), jnp.uint32(0xFFFFFFFF),
        lambda a, b: jnp.bitwise_and(a, b), (0,),
    )


def bv_and_many_xla(words: np.ndarray) -> np.ndarray:
    """AND-reduce packed rows (P, W) -> (W,) on the XLA backend.

    Drop-in for :func:`repro.core.bitvector.bv_and_many` (bit-identical;
    the equivalence is pinned by ``tests/test_columnar.py``).  Inputs are
    padded to power-of-two (P, W) buckets with the AND identity
    (all-ones rows; pad columns are sliced back off) so the jit cache
    stays O(log^2) across segment shapes.
    """
    words = np.asarray(words, np.uint32)
    P, W = words.shape
    Pb, Wb = _pow2(P), _pow2(W)
    if (Pb, Wb) != (P, W):
        padded = np.full((Pb, Wb), _AND_IDENTITY, np.uint32)
        padded[:P, :W] = words
        words = padded
    return np.asarray(_and_reduce(jnp.asarray(words)))[:W]


@jax.jit
def _popcount(words: jnp.ndarray) -> jnp.ndarray:
    return lax.population_count(words.astype(jnp.uint32)).sum()


def popcount_xla(words: np.ndarray) -> int:
    """Total set bits of a packed array (device population_count).

    Zero-padded to the same power-of-two buckets as the AND reduction
    (zero words contribute no bits, so the total is unchanged).
    """
    words = np.ascontiguousarray(np.asarray(words, np.uint32))
    flat = words.reshape(-1)
    n = flat.shape[0]
    nb = _pow2(n)
    if nb != n:
        flat = np.concatenate([flat, np.zeros((nb - n,), np.uint32)])
    return int(_popcount(jnp.asarray(flat)))
