"""Device offload hooks for the columnar scanner's residual phase.

The columnar scan (DESIGN.md §13) is host-side numpy by default: packed
bitvector AND, candidate unpack, vectorized column predicates.  The AND
reduction over pushed clause rows is the one piece with a natural device
form — it is exactly the ``reduce_bitvectors`` shape the fused ingest
kernel already exploits — so this module exposes it as an optional
``and_reduce`` for :class:`repro.core.server.DataSkippingScanner`:

    scanner = DataSkippingScanner(store, and_reduce=bv_and_many_xla)

Shapes vary per segment (W = ceil(n_rows/32)); the jitted reduction
retraces per (P, W) bucket, which segment compaction keeps small (one
dominant W per store).  Kept deliberately tiny: column-predicate
evaluation stays on the host, where the dictionary/zone-map structures
live.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax


@jax.jit
def _and_reduce(words: jnp.ndarray) -> jnp.ndarray:
    return lax.reduce(
        words.astype(jnp.uint32), jnp.uint32(0xFFFFFFFF),
        lambda a, b: jnp.bitwise_and(a, b), (0,),
    )


def bv_and_many_xla(words: np.ndarray) -> np.ndarray:
    """AND-reduce packed rows (P, W) -> (W,) on the XLA backend.

    Drop-in for :func:`repro.core.bitvector.bv_and_many` (bit-identical;
    the equivalence is pinned by ``tests/test_columnar.py``).
    """
    return np.asarray(_and_reduce(jnp.asarray(words, jnp.uint32)))


@jax.jit
def _popcount(words: jnp.ndarray) -> jnp.ndarray:
    return lax.population_count(words.astype(jnp.uint32)).sum()


def popcount_xla(words: np.ndarray) -> int:
    """Total set bits of a packed array (device population_count)."""
    return int(_popcount(jnp.asarray(words, jnp.uint32)))
