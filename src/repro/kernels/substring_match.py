"""Pallas TPU kernels for client-side predicate evaluation.

TPU adaptation of the paper's ``string::find`` hot loop (DESIGN.md §3):
records are a dense ``uint8[R, L]`` chunk in VMEM and multi-pattern substring
search becomes *sliding-window equality* across the 8x128 VPU lanes — every
window position of every record is tested in parallel with zero
data-dependent branching.

Two kernels:

  * :func:`multi_match_any` — grid ``(P, R/R_blk)``; block computes
    "pattern p occurs anywhere in record r" for a tile of records.  Pattern
    lengths are dynamic (masked), so one compilation serves any pattern set.
    A block-level first-character prefilter (``pl.when``) skips the O(M)
    inner reduction when no window can match — the TPU analog of the paper's
    found/not-found cost asymmetry (k1,k2 vs k3,k4).
  * :func:`key_value_match` — the paper's two-pattern key-value predicate:
    value must occur between the end of a key occurrence and the next
    delimiter (',' / '}').  Segment confinement is a segmented reverse
    max-scan (log L ``associative_scan`` steps on the VPU).  Pattern lengths
    are static here (few distinct (len_k, len_v) pairs per plan; each gets
    its own specialization).

VMEM budget: a ``(R_blk, L)`` uint8 tile + masks.  Defaults
``R_blk=256, L<=2048`` keep the working set under ~2.5 MiB (v5e VMEM is
128 MiB/core; we stay small so several grid steps pipeline).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

DELIM_COMMA = 44   # ord(',')
DELIM_BRACE = 125  # ord('}')


def _shift_left(x: jnp.ndarray, i: int) -> jnp.ndarray:
    """x[:, j+i] with zero fill on the right (static i)."""
    if i == 0:
        return x
    pad = jnp.zeros((x.shape[0], i), dtype=x.dtype)
    return jnp.concatenate([x[:, i:], pad], axis=1)


def masked_window_eq(data: jnp.ndarray, pat_row: jnp.ndarray, m: jnp.ndarray,
                     max_len: int) -> jnp.ndarray:
    """(R_blk, L) bool: window at j equals pat_row[:m], m DYNAMIC (masked).

    The masking trick from :func:`multi_match_any`: positions where the
    pattern is already exhausted (i >= m) stay valid, so one compilation
    serves every pattern length up to ``max_len``.  Shared with the fused
    pushdown kernel (DESIGN.md §3).
    """
    acc = data == pat_row[0]
    for i in range(1, max_len):
        eq = _shift_left(data, i) == pat_row[i]
        acc = jnp.logical_and(acc, jnp.logical_or(eq, i >= m))
    return acc


def select_shift_left(x: jnp.ndarray, n: jnp.ndarray, max_shift: int) -> jnp.ndarray:
    """x[:, j+n] for DYNAMIC n in [0, max_shift] via select-over-static-shifts.

    TPU lanes cannot gather by a runtime offset cheaply; a chain of
    ``max_shift`` static shifts + selects keeps everything on the VPU.
    """
    out = x
    for i in range(1, max_shift + 1):
        out = jnp.where(n == i, _shift_left(x, i), out)
    return out


# ---------------------------------------------------------------------------
# kernel A: multi-pattern any-position match
# ---------------------------------------------------------------------------

def _multi_match_kernel(pat_ref, plen_ref, data_ref, out_ref, *, max_pat_len: int):
    data = data_ref[...]                      # (R_blk, L) uint8
    pat = pat_ref[...]                        # (1, M) uint8
    m = plen_ref[0, 0]                        # dynamic length

    first = data == pat[0, 0]                 # (R_blk, L) candidate windows

    @pl.when(jnp.any(first))
    def _found_candidates():
        acc = first
        for i in range(1, max_pat_len):
            # masked AND: positions where the pattern is already exhausted
            # (i >= m) stay valid; shifted equality elsewhere.
            eq = _shift_left(data, i) == pat[0, i]
            acc_i = jnp.logical_or(eq, i >= m)
            acc = jnp.logical_and(acc, acc_i)
        out_ref[0, :] = jnp.any(acc, axis=1).astype(jnp.uint8)

    @pl.when(jnp.logical_not(jnp.any(first)))
    def _no_candidates():
        out_ref[0, :] = jnp.zeros((data.shape[0],), dtype=jnp.uint8)


@functools.partial(jax.jit, static_argnames=("r_blk", "interpret"))
def multi_match_any(
    data: jnp.ndarray,      # uint8[R, L]   (R % r_blk == 0)
    patterns: jnp.ndarray,  # uint8[P, M]
    plens: jnp.ndarray,     # int32[P, 1]
    *,
    r_blk: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:           # uint8[P, R]
    R, L = data.shape
    P, M = patterns.shape
    if R % r_blk:
        raise ValueError(f"R={R} not a multiple of r_blk={r_blk}")
    grid = (P, R // r_blk)
    return pl.pallas_call(
        functools.partial(_multi_match_kernel, max_pat_len=M),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, M), lambda p, rb: (p, 0)),
            pl.BlockSpec((1, 1), lambda p, rb: (p, 0)),
            pl.BlockSpec((r_blk, L), lambda p, rb: (rb, 0)),
        ],
        out_specs=pl.BlockSpec((1, r_blk), lambda p, rb: (p, rb)),
        out_shape=jax.ShapeDtypeStruct((P, R), jnp.uint8),
        interpret=interpret,
    )(patterns, plens, data)


# ---------------------------------------------------------------------------
# kernel B: key-value match (static pattern lengths)
# ---------------------------------------------------------------------------

def _window_eq(data: jnp.ndarray, pat_row: jnp.ndarray, m: int) -> jnp.ndarray:
    """(R_blk, L) bool: window starting at j equals pat_row[:m]."""
    acc = data == pat_row[0]
    for i in range(1, m):
        acc = jnp.logical_and(acc, _shift_left(data, i) == pat_row[i])
    return acc


def _segmented_suffix_any(val_hit: jnp.ndarray, delim: jnp.ndarray) -> jnp.ndarray:
    """cond[p] = exists v >= p in p's segment with val_hit[v].

    Segments are delimiter-separated; a delimiter position belongs to no
    segment.  Suffix scan with resets == flip + forward segmented max-scan
    (associative, log L VPU steps).
    """
    R, L = val_hit.shape
    pos = lax.broadcasted_iota(jnp.int32, (R, L), 1)
    x = jnp.where(jnp.logical_and(val_hit, jnp.logical_not(delim)), pos, -1)
    xf = jnp.flip(x, axis=1)
    df = jnp.flip(delim, axis=1)

    def combine(a, b):
        am, astop = a
        bm, bstop = b
        # b is later in scan order; a delimiter in b resets a's accumulation.
        m = jnp.where(bstop, bm, jnp.maximum(am, bm))
        return m, jnp.logical_or(astop, bstop)

    m, _ = lax.associative_scan(combine, (xf, df), axis=1)
    return jnp.flip(m, axis=1) >= 0


def _key_value_kernel(key_ref, val_ref, data_ref, out_ref, *, mk: int, mv: int,
                      unbounded: bool):
    data = data_ref[...]                      # (R_blk, L)
    key_hit = _window_eq(data, key_ref[0], mk)

    @pl.when(jnp.any(key_hit))
    def _have_keys():
        val_hit = _window_eq(data, val_ref[0], mv)
        if unbounded:
            # suffix-any (no segment confinement): flipped or-scan
            cond = jnp.flip(
                lax.associative_scan(
                    jnp.logical_or, jnp.flip(val_hit, axis=1), axis=1
                ),
                axis=1,
            )
        else:
            delim = jnp.logical_or(data == DELIM_COMMA, data == DELIM_BRACE)
            # val pattern contains no delimiter => a window match already
            # implies no delimiter inside [v, v+mv)
            cond = _segmented_suffix_any(val_hit, delim)
        cond_at_value_region = _shift_left(cond, mk)  # cond[j + mk]
        hit = jnp.logical_and(key_hit, cond_at_value_region)
        out_ref[0, :] = jnp.any(hit, axis=1).astype(jnp.uint8)

    @pl.when(jnp.logical_not(jnp.any(key_hit)))
    def _no_keys():
        out_ref[0, :] = jnp.zeros((data.shape[0],), dtype=jnp.uint8)


@functools.partial(
    jax.jit, static_argnames=("mk", "mv", "unbounded", "r_blk", "interpret")
)
def key_value_match(
    data: jnp.ndarray,     # uint8[R, L]
    key_pat: jnp.ndarray,  # uint8[1, mk_padded]
    val_pat: jnp.ndarray,  # uint8[1, mv_padded]
    *,
    mk: int,
    mv: int,
    unbounded: bool,
    r_blk: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:          # uint8[1, R]
    R, L = data.shape
    if R % r_blk:
        raise ValueError(f"R={R} not a multiple of r_blk={r_blk}")
    grid = (R // r_blk,)
    return pl.pallas_call(
        functools.partial(_key_value_kernel, mk=mk, mv=mv, unbounded=unbounded),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, key_pat.shape[1]), lambda rb: (0, 0)),
            pl.BlockSpec((1, val_pat.shape[1]), lambda rb: (0, 0)),
            pl.BlockSpec((r_blk, L), lambda rb: (rb, 0)),
        ],
        out_specs=pl.BlockSpec((1, r_blk), lambda rb: (0, rb)),
        out_shape=jax.ShapeDtypeStruct((1, R), jnp.uint8),
        interpret=interpret,
    )(key_pat, val_pat, data)
