"""Fused multi-query scan kernel over the device-resident segment plane.

This is the device half of DESIGN.md §15.  The host half
(:class:`repro.core.device_cache.DeviceSegmentCache`) keeps every hot
segment's columnar state resident as per-shard ``jnp`` buffers:

  * per-key row masks     — present / notnull / is_bool / num_valid,
    stacked ``uint8[K, N]`` over the concatenated rows of all cached
    segments (``K`` = union of keys, row 0 reserved all-absent);
  * dictionary codes      — ``str_codes`` / ``repr_codes`` ``int32[K, N]``
    (-1 = not-a-string / absent, matching ``core.columnar.KeyColumn``);
  * ``seg_ids int32[N]``  — row -> cache slot (-1 = capacity padding);
  * ``clause_word``       — the segment's packed pushed bitvectors,
    TRANSPOSED to one ``uint32`` per row (bit *p* = clause row *p* of
    that segment's coverage; cache admission requires n_covered <= 32).

A batch of queries compiles once (:func:`compile_scan_batch`) into the
same clause/term-dedup shape the ingest plan compiler uses
(``kernels.plan`` / ``core.client.dedup_terms``) — except keyed on
type-strict predicate identity rather than pattern bytes, because two
predicates with identical raw patterns (e.g. EXACT on different keys)
evaluate differently under ``core.columnar.eval_lowered``.  Everything
else arrives as small per-scan parameter tables resolved on the host
from the segment dictionaries (codes, substring LUTs, pushed-bit masks,
zone-prune verdicts): parameters are O(terms x slots), never O(rows) —
segment columns are uploaded at admission only.

One launch then evaluates the whole batch: zone-prune mask -> pushed
bitvector AND -> lowered residual on dictionary codes -> per-(query,
slot) popcount.  Counts are bit-identical to
``core.columnar.query_mask`` because every ``eval_lowered`` branch has
an exact integer form:

  * KEY_PRESENCE          — ``notnull``;
  * EXACT (str value)     — ``str_codes == str_index.get(v, -2)``;
  * SUBSTRING             — per-slot LUT over the string dictionary,
    probed by ``str_codes`` (offset -1 = provably-empty / missing key);
  * KEY_VALUE             — repr-code equality, plus the null branch,
    plus the numeric branch: ``num_valid & (num == float(v))`` equals
    ``num_valid & repr_codes ∈ codes(_num_reprs(float(v)))`` — the repr
    dictionary encodes ``json_scalar`` of every present value, and
    ``_num_reprs`` enumerates every rendering a float64-equal scalar can
    have — so no float column ever needs to leave the host.

Two backends: ``"xla"`` (jitted jnp, the fast path on CPU hosts) and
``"pallas"``/interpret (one real ``pallas_call``: per-row slot one-hot,
parameter gathers and the final per-slot popcount are all expressed as
f32 matmuls — exact for dictionary codes < 2^24 — so the kernel maps
onto the MXU; the substring LUT probe is the one vector gather,
supported by interpret mode and recent Mosaic toolchains).  Both return
``counts[Q, S]`` / ``cands[Q, S]`` (matches, pushed-candidate rows) per
cache slot, which the device scanner folds into the standard per-(epoch,
tier) :class:`~repro.core.server.ScanResult` accounting.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.predicates import (
    Clause, Kind, Query, SimplePredicate, lowerable,
)

from .plan import compile_query_batch
from .residual import _pow2

KIND_PRESENCE = 0
KIND_EXACT = 1
KIND_SUBSTRING = 2
KIND_KV = 3
_KIND_CODE = {
    Kind.KEY_PRESENCE: KIND_PRESENCE,
    Kind.EXACT: KIND_EXACT,
    Kind.SUBSTRING: KIND_SUBSTRING,
    Kind.KEY_VALUE: KIND_KV,
}

#: cache slots carry pushed coverage as one uint32 word per row
MAX_COVERED = 32


def device_lowerable(t: SimplePredicate) -> bool:
    """True iff ``t`` evaluates on the device dictionary-code plane.

    Stricter than host ``lowerable``: RANGE and IN lower to vectorized
    numpy (repr-LUT / per-element OR) but have no ``_KIND_CODE`` row —
    their repr LUTs would be per-(term, slot) rebuilt parameters of
    unbounded width.  Queries containing them fall back whole to the
    host scanner (the standard non-eligible path), keeping counts
    bit-identical.
    """
    return lowerable(t) and t.kind in _KIND_CODE


# ---------------------------------------------------------------------------
# batch compilation: queries -> deduped clause/term tables
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ScanBatch:
    """Clause/term-deduped encoding of a query batch (host-side)."""

    queries: tuple[Query, ...]
    clauses: tuple[Clause, ...]          # unique clauses across the batch
    terms: tuple[SimplePredicate, ...]   # unique terms across those clauses
    membership: np.ndarray               # uint8[C, T] clause -> term
    query_clause: np.ndarray             # uint8[Q, C] query -> clause
    query_ok: tuple[bool, ...]           # per-query device eligibility

    @property
    def n_queries(self) -> int:
        return len(self.queries)

    @property
    def n_clauses(self) -> int:
        return len(self.clauses)

    @property
    def n_terms(self) -> int:
        return len(self.terms)


def compile_scan_batch(queries: Sequence[Query]) -> ScanBatch:
    """Dedup clauses and terms across a query batch.

    Thin wrapper over :func:`repro.kernels.plan.compile_query_batch` —
    ONE implementation of the query -> clause -> term type-strict dedup
    serves both multi-query planes (the host ``ScanBatcher`` and this
    device compiler); see its docstring for why the dedup keys on
    predicate equality rather than ``dedup_terms``' pattern bytes.
    ``query_ok`` is the per-query device-eligibility flag: every term
    must lower onto the dictionary-code plane (:func:`device_lowerable`
    — host-lowerable RANGE/IN terms still disqualify a query here).
    """
    qb = compile_query_batch(queries)
    ok = tuple(
        all(device_lowerable(t) for c in q.clauses for t in c.terms)
        for q in qb.queries
    )
    return ScanBatch(
        queries=qb.queries, clauses=qb.clauses, terms=qb.terms,
        membership=qb.membership, query_clause=qb.query_clause,
        query_ok=ok,
    )


class ScanParams(NamedTuple):
    """Per-scan parameter tables (host numpy, bucket-padded).

    Shapes: T/C/Q/S1 are power-of-two buckets of (terms, clauses,
    queries, slots + 1); the extra slot S1-1 is the dummy that
    capacity-padding rows (seg_id -1) resolve to, with ``active`` zeroed
    so they can never contribute.
    """

    key_ids: np.ndarray      # int32[T]   term -> plane key row (0 = absent)
    kinds: np.ndarray        # int32[T]   KIND_* (-1 = padding, inert)
    code_a: np.ndarray       # int32[T, S1]  EXACT str code / KV repr code
    num_codes: np.ndarray    # int32[T, 3, S1] KV numeric repr codes
    lut_off: np.ndarray      # int32[T, S1]  substring LUT base (-1 = empty)
    lut_flat: np.ndarray     # uint8[L]      concatenated substring LUTs
    is_null: np.ndarray      # uint8[T]   KV value is None
    is_boolv: np.ndarray     # uint8[T]   KV value is a bool
    membership: np.ndarray   # uint8[C, T]
    query_clause: np.ndarray  # uint8[Q, C]
    pushed_tbl: np.ndarray   # uint32[Q, S1] pushed clause bits (0 = all-pass)
    active: np.ndarray       # uint8[Q, S1]  zone-prune verdict (0 = pruned)


# ---------------------------------------------------------------------------
# XLA backend
# ---------------------------------------------------------------------------

def scan_core_xla(pres, notn, isb, numv, scod, rcod, sid, cw,
                  key_ids, kinds, code_a, num_codes, lut_off, lut_flat,
                  is_null, is_boolv, membership, query_clause,
                  pushed_tbl, active, kind_counts=None):
    """Unjitted fused scan body (also the ``shard_map`` SPMD payload).

    Three CPU-motivated structural choices, all bit-exact:

      * ``optimization_barrier`` after every gather-producing
        intermediate — XLA fusion otherwise inlines the gathers into
        each consumer's scalar loop and recomputes them per use (~2.4x
        on the CPU backend);
      * the clause/query matmuls and the per-slot count reduction run
        as f32 GEMMs (Eigen on CPU, MXU on TPU) instead of int32
        matmuls / ``.at[].add`` scatters.  Exact: every operand is 0/1
        and every sum is bounded by max(T, C, N) < 2^24;
      * when ``kind_counts`` (a static ``(n_presence, n_exact,
        n_substring, n_kv)`` tuple over kind-sorted term rows) is
        given, each term row evaluates ONLY its own kind's branch and
        gathers only the tables that branch reads, instead of
        computing all four branches for every row and selecting.  The
        per-kind expressions are unchanged, so the term matrix is
        identical row-for-row; this is what makes a batched launch
        scale with the batch's real work.  ``None`` keeps the generic
        select body (the ``shard_map`` path, where kinds arrive
        traced).
    """
    S1 = pushed_tbl.shape[1]
    L = lut_flat.shape[0]
    bar = jax.lax.optimization_barrier
    sid = jnp.where(sid < 0, S1 - 1, sid)
    sid = bar(sid)
    if kind_counts is None:
        tp = pres[key_ids] > 0                # (T, N)
        tn = notn[key_ids] > 0
        tb = isb[key_ids] > 0
        tv = numv[key_ids] > 0
        ts = scod[key_ids]
        tr = rcod[key_ids]
        tp, tn, tb, tv, ts, tr = bar((tp, tn, tb, tv, ts, tr))
        ca = code_a[:, sid]                   # (T, N)
        off = lut_off[:, sid]
        ca, off = bar((ca, off))
        m_exact = ts == ca
        idx = jnp.clip(off + 1 + ts, 0, L - 1)
        m_sub = (lut_flat[idx] > 0) & (off >= 0)
        nc = num_codes[:, :, sid]             # (T, 3, N)
        nc = bar(nc)
        m_num = tv & jnp.any(nc == tr[:, None, :], axis=1)
        m_null = (is_null[:, None] > 0) & tp & ~tn
        compat = jnp.where(is_boolv[:, None] > 0, tb, tp & ~tb)
        m_kv = ((tr == ca) | m_num | m_null) & compat
        k = kinds[:, None]
        term = jnp.where(
            k == KIND_PRESENCE, tn,
            jnp.where(k == KIND_EXACT, m_exact,
                      jnp.where(k == KIND_SUBSTRING, m_sub,
                                jnp.where(k == KIND_KV, m_kv, False))))
    else:
        n_pre, n_ex, n_sub, n_kv = kind_counts
        parts = []
        a = 0
        if n_pre:
            parts.append(bar(notn[key_ids[a:a + n_pre]]) > 0)
        a += n_pre
        if n_ex:
            ts = scod[key_ids[a:a + n_ex]]
            ca = code_a[a:a + n_ex][:, sid]
            ts, ca = bar((ts, ca))
            parts.append(ts == ca)
        a += n_ex
        if n_sub:
            ts = scod[key_ids[a:a + n_sub]]
            off = lut_off[a:a + n_sub][:, sid]
            ts, off = bar((ts, off))
            idx = jnp.clip(off + 1 + ts, 0, L - 1)
            hitb = bar(lut_flat[idx])
            parts.append((hitb > 0) & (off >= 0))
        a += n_sub
        if n_kv:
            kk = key_ids[a:a + n_kv]
            tp = pres[kk] > 0
            tn = notn[kk] > 0
            tb = isb[kk] > 0
            tv = numv[kk] > 0
            tr = rcod[kk]
            tp, tn, tb, tv, tr = bar((tp, tn, tb, tv, tr))
            ca = code_a[a:a + n_kv][:, sid]
            nc = num_codes[a:a + n_kv][:, :, sid]
            ca, nc = bar((ca, nc))
            m_num = tv & jnp.any(nc == tr[:, None, :], axis=1)
            m_null = (is_null[a:a + n_kv, None] > 0) & tp & ~tn
            compat = jnp.where(is_boolv[a:a + n_kv, None] > 0,
                               tb, tp & ~tb)
            parts.append(((tr == ca) | m_num | m_null) & compat)
        a += n_kv
        if kinds.shape[0] > a:                # bucket-padding rows: inert
            parts.append(jnp.zeros((kinds.shape[0] - a, sid.shape[0]),
                                   bool))
        term = parts[0] if len(parts) == 1 else jnp.concatenate(parts, 0)
    term = bar(term)
    cm = (membership.astype(jnp.float32) @ term.astype(jnp.float32)) > 0.0
    viol = query_clause.astype(jnp.float32) @ (1.0 - cm.astype(jnp.float32))
    qm = viol == 0.0                          # (Q, N)
    ptab = pushed_tbl[:, sid]                 # (Q, N)
    ptab = jax.lax.optimization_barrier(ptab)
    pm = (cw[None, :] & ptab) == ptab
    act = active[:, sid] > 0
    hit = qm & pm & act
    hit, pa = bar((hit, pm & act))
    # per-slot popcount as ONE (2Q, N) @ (N, S1) f32 GEMM: the one-hot
    # is built directly in (N, S1) layout — Eigen runs the non-transposed
    # product ~3x faster than two (Q, N) @ (S1, N)^T calls
    iota = jax.lax.broadcasted_iota(jnp.int32, (sid.shape[0], S1), 1)
    slot_oh = (sid[:, None] == iota).astype(jnp.float32)
    z = jnp.concatenate([hit, pa], axis=0).astype(jnp.float32)
    seg = (z @ slot_oh).astype(jnp.int32)
    Q = pushed_tbl.shape[0]
    return seg[:Q], seg[Q:]


_scan_core_xla = jax.jit(scan_core_xla, static_argnames=("kind_counts",))


# ---------------------------------------------------------------------------
# pallas backend
# ---------------------------------------------------------------------------

def _scan_kernel(keym_ref, pres_ref, notn_ref, isb_ref, numv_ref,
                 scod_ref, rcod_ref, sid_ref, cw_ref,
                 kinds_ref, code_a_ref, num_codes_ref, lut_off_ref,
                 lut_flat_ref, is_null_ref, is_boolv_ref,
                 mem_ref, qc_ref, plo_ref, phi_ref, act_ref,
                 counts_ref, cands_ref, *, n_slots: int, r_blk: int):
    nb = pl.program_id(0)

    @pl.when(nb == 0)
    def _init():  # first tile zeroes the accumulators
        counts_ref[...] = jnp.zeros_like(counts_ref)
        cands_ref[...] = jnp.zeros_like(cands_ref)

    f32 = jnp.float32
    sid = sid_ref[0, :]
    sid = jnp.where(sid < 0, n_slots - 1, sid)
    # per-row slot one-hot: every parameter gather and the final per-slot
    # reduction become (.., S1) x (S1, blk) matmuls — MXU-friendly, and
    # exact in f32 for dictionary codes / offsets < 2^24
    iota = jax.lax.broadcasted_iota(jnp.int32, (n_slots, r_blk), 0)
    slot_oh = (sid[None, :] == iota).astype(f32)          # (S1, blk)
    keym = keym_ref[...]                                  # (T, K) one-hot
    tp = keym @ pres_ref[...].astype(f32) > 0.0           # (T, blk)
    tn = keym @ notn_ref[...].astype(f32) > 0.0
    tb = keym @ isb_ref[...].astype(f32) > 0.0
    tv = keym @ numv_ref[...].astype(f32) > 0.0
    ts = (keym @ scod_ref[...].astype(f32)).astype(jnp.int32)
    tr = (keym @ rcod_ref[...].astype(f32)).astype(jnp.int32)
    ca = (code_a_ref[...].astype(f32) @ slot_oh).astype(jnp.int32)
    off = (lut_off_ref[...].astype(f32) @ slot_oh).astype(jnp.int32)
    m_exact = ts == ca
    lut = lut_flat_ref[0, :]
    idx = jnp.clip(off + 1 + ts, 0, lut.shape[0] - 1)
    m_sub = (jnp.take(lut, idx) > 0) & (off >= 0)
    ncf = (num_codes_ref[...].astype(f32) @ slot_oh).astype(jnp.int32)
    nc = ncf.reshape(-1, 3, r_blk)                        # (T, 3, blk)
    m_num = tv & jnp.any(nc == tr[:, None, :], axis=1)
    isn = is_null_ref[...] > 0                            # (T, 1)
    isb_v = is_boolv_ref[...] > 0
    m_null = isn & tp & ~tn
    compat = jnp.where(isb_v, tb, tp & ~tb)
    m_kv = ((tr == ca) | m_num | m_null) & compat
    k = kinds_ref[...]                                    # (T, 1)
    term = jnp.where(
        k == KIND_PRESENCE, tn,
        jnp.where(k == KIND_EXACT, m_exact,
                  jnp.where(k == KIND_SUBSTRING, m_sub,
                            jnp.where(k == KIND_KV, m_kv, False))))
    cm = (mem_ref[...].astype(f32) @ term.astype(f32)) > 0.0   # (C, blk)
    viol = qc_ref[...].astype(f32) @ (1.0 - cm.astype(f32))
    qm = viol == 0.0                                      # (Q, blk)
    # pushed words gathered as two exact 16-bit f32 halves
    plo = (plo_ref[...].astype(f32) @ slot_oh).astype(jnp.uint32)
    phi = (phi_ref[...].astype(f32) @ slot_oh).astype(jnp.uint32)
    ptab = (phi << 16) | plo
    cw = cw_ref[0, :]
    pm = (cw[None, :] & ptab) == ptab
    act = (act_ref[...].astype(f32) @ slot_oh) > 0.0
    hit = (qm & pm & act).astype(f32)
    counts_ref[...] += hit @ slot_oh.T                    # (Q, S1)
    cands_ref[...] += (pm & act).astype(f32) @ slot_oh.T


@functools.partial(
    jax.jit, static_argnames=("r_blk", "interpret"))
def _scan_core_pallas(pres, notn, isb, numv, scod, rcod, sid, cw,
                      keym, kinds, code_a, num_codes, lut_off, lut_flat,
                      is_null, is_boolv, membership, query_clause,
                      plo, phi, active, *, r_blk: int, interpret: bool):
    K, N = pres.shape
    T = kinds.shape[0]
    C = membership.shape[0]
    Q, S1 = plo.shape
    L = lut_flat.shape[1]
    grid = (N // r_blk,)

    def col(k):      # (K, N) column tiles
        return pl.BlockSpec((k, r_blk), lambda nb: (0, nb))

    def full(*shape):  # whole-array parameter blocks
        return pl.BlockSpec(shape, lambda nb, _n=len(shape): (0,) * _n)

    counts, cands = pl.pallas_call(
        functools.partial(_scan_kernel, n_slots=S1, r_blk=r_blk),
        grid=grid,
        in_specs=[
            full(T, K),                       # keym
            col(K), col(K), col(K), col(K),   # pres/notn/isb/numv
            col(K), col(K),                   # scod/rcod
            col(1), col(1),                   # sid/cw
            full(T, 1),                       # kinds
            full(T, S1),                      # code_a
            full(3 * T, S1),                  # num_codes
            full(T, S1),                      # lut_off
            full(1, L),                       # lut_flat
            full(T, 1), full(T, 1),           # is_null / is_boolv
            full(C, T), full(Q, C),           # membership / query_clause
            full(Q, S1), full(Q, S1),         # plo / phi
            full(Q, S1),                      # active
        ],
        out_specs=[
            pl.BlockSpec((Q, S1), lambda nb: (0, 0)),
            pl.BlockSpec((Q, S1), lambda nb: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Q, S1), jnp.float32),
            jax.ShapeDtypeStruct((Q, S1), jnp.float32),
        ],
        interpret=interpret,
    )(keym, pres, notn, isb, numv, scod, rcod, sid, cw, kinds,
      code_a, num_codes, lut_off, lut_flat, is_null, is_boolv,
      membership, query_clause, plo, phi, active)
    return counts.astype(jnp.int32), cands.astype(jnp.int32)


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------

class DevicePlaneArrays(NamedTuple):
    """The device-resident plane a launch consumes (all ``jnp``)."""

    pres: jnp.ndarray    # uint8[K, N]
    notn: jnp.ndarray    # uint8[K, N]
    isb: jnp.ndarray     # uint8[K, N]
    numv: jnp.ndarray    # uint8[K, N]
    scod: jnp.ndarray    # int32[K, N]
    rcod: jnp.ndarray    # int32[K, N]
    sid: jnp.ndarray     # int32[N] (-1 = padding)
    cw: jnp.ndarray      # uint32[N]


def scan_core_numpy(pres, notn, isb, numv, scod, rcod, sid, cw,
                    params: ScanParams) -> tuple[np.ndarray, np.ndarray]:
    """Numpy-vectorized reference of the fused scan, bit-identical.

    Plane arrays arrive as HOST numpy (the baseline's "resident"
    mirror).  Serves two roles: the differential oracle the kernel
    backends are tested against, and the ``numpy`` side of
    ``benchmarks.bench_device`` — the same multi-query plane scan,
    vectorized the way a numpy engine would write it (one temporary per
    stage), so the gated speedup isolates what the fused single launch
    buys on identical work.
    """
    S1 = params.pushed_tbl.shape[1]
    L = params.lut_flat.shape[0]
    sid = np.where(sid < 0, S1 - 1, sid)
    key_ids = params.key_ids
    tp = pres[key_ids] > 0                    # (T, N)
    tn = notn[key_ids] > 0
    tb = isb[key_ids] > 0
    tv = numv[key_ids] > 0
    ts = scod[key_ids]
    tr = rcod[key_ids]
    ca = params.code_a[:, sid]
    off = params.lut_off[:, sid]
    m_exact = ts == ca
    idx = np.clip(off + 1 + ts, 0, L - 1)
    m_sub = (params.lut_flat[idx] > 0) & (off >= 0)
    nc = params.num_codes[:, :, sid]
    m_num = tv & (nc == tr[:, None, :]).any(axis=1)
    m_null = (params.is_null[:, None] > 0) & tp & ~tn
    compat = np.where(params.is_boolv[:, None] > 0, tb, tp & ~tb)
    m_kv = ((tr == ca) | m_num | m_null) & compat
    k = params.kinds[:, None]
    term = np.select(
        [k == KIND_PRESENCE, k == KIND_EXACT, k == KIND_SUBSTRING,
         k == KIND_KV],
        [tn, m_exact, m_sub, m_kv], False)
    cm = (params.membership.astype(np.int32) @ term.astype(np.int32)) > 0
    viol = params.query_clause.astype(np.int32) @ (1 - cm.astype(np.int32))
    qm = viol == 0                            # (Q, N)
    ptab = params.pushed_tbl[:, sid]
    pm = (cw[None, :] & ptab) == ptab
    act = params.active[:, sid] > 0
    hit = qm & pm & act
    pa = pm & act
    Q = params.pushed_tbl.shape[0]
    counts = np.zeros((Q, S1), np.int32)
    cands = np.zeros((Q, S1), np.int32)
    for q in range(Q):
        counts[q] = np.bincount(sid, weights=hit[q], minlength=S1)[:S1]
        cands[q] = np.bincount(sid, weights=pa[q], minlength=S1)[:S1]
    return counts, cands


def scan_counts(plane: DevicePlaneArrays, params: ScanParams, *,
                backend: str = "xla", r_blk: int = 512,
                ) -> tuple[np.ndarray, np.ndarray]:
    """One fused launch over the plane; ``(counts, cands)`` as int32[Q, S1].

    ``backend``: ``"xla"`` (jitted jnp), ``"pallas_interpret"`` (the
    pallas kernel under the interpreter — the CPU-verifiable TPU
    artifact), ``"pallas"`` (compiled, real hardware), or ``"numpy"``
    (the host reference — converts the plane per call; perf baselines
    should pre-convert and call :func:`scan_core_numpy` directly).
    """
    if backend == "numpy":
        counts, cands = scan_core_numpy(
            *(np.asarray(a) for a in plane), params)
        return counts, cands
    if backend == "xla":
        # sort term rows by kind (stable; bucket padding, kind -1, goes
        # last) so the launch can evaluate each row's own branch only.
        # Membership columns permute with them — results are identical.
        kinds = params.kinds
        order = np.argsort(
            np.where(kinds < 0, np.int32(KIND_KV + 1), kinds),
            kind="stable")
        kc = tuple(int((kinds == k).sum())
                   for k in (KIND_PRESENCE, KIND_EXACT,
                             KIND_SUBSTRING, KIND_KV))
        counts, cands = _scan_core_xla(
            *plane,
            jnp.asarray(params.key_ids[order]),
            jnp.asarray(params.kinds[order]),
            jnp.asarray(params.code_a[order]),
            jnp.asarray(params.num_codes[order]),
            jnp.asarray(params.lut_off[order]),
            jnp.asarray(params.lut_flat),
            jnp.asarray(params.is_null[order]),
            jnp.asarray(params.is_boolv[order]),
            jnp.asarray(params.membership[:, order]),
            jnp.asarray(params.query_clause),
            jnp.asarray(params.pushed_tbl), jnp.asarray(params.active),
            kind_counts=kc,
        )
    elif backend in ("pallas", "pallas_interpret"):
        K = plane.pres.shape[0]
        T = params.kinds.shape[0]
        keym = np.zeros((T, K), np.float32)
        keym[np.arange(T), params.key_ids] = 1.0
        n = plane.sid.shape[0]
        r_blk = min(r_blk, n)
        counts, cands = _scan_core_pallas(
            plane.pres, plane.notn, plane.isb, plane.numv,
            plane.scod, plane.rcod,
            plane.sid.reshape(1, -1), plane.cw.reshape(1, -1),
            jnp.asarray(keym),
            jnp.asarray(params.kinds.reshape(-1, 1)),
            jnp.asarray(params.code_a),
            jnp.asarray(params.num_codes.reshape(
                params.num_codes.shape[0] * 3, -1)),
            jnp.asarray(params.lut_off),
            jnp.asarray(params.lut_flat.reshape(1, -1)),
            jnp.asarray(params.is_null.reshape(-1, 1)),
            jnp.asarray(params.is_boolv.reshape(-1, 1)),
            jnp.asarray(params.membership), jnp.asarray(params.query_clause),
            jnp.asarray((params.pushed_tbl & np.uint32(0xFFFF))
                        .astype(np.int32)),
            jnp.asarray((params.pushed_tbl >> np.uint32(16))
                        .astype(np.int32)),
            jnp.asarray(params.active),
            r_blk=r_blk, interpret=(backend == "pallas_interpret"),
        )
    else:
        raise ValueError(f"unknown device scan backend {backend!r}")
    return np.asarray(counts), np.asarray(cands)


def bucket_pow2(n: int, floor: int = 1) -> int:
    """Power-of-two shape bucket (shared with ``kernels.residual``)."""
    return _pow2(n, floor)
