"""Plan compilation: clause list -> device-ready predicate tables.

Three levels of dedup (DESIGN.md §3.3), each mirroring how real plans
repeat themselves:

  * term-level   — a disjunct shared by several clauses gets ONE predicate
    slot (``core.client.dedup_terms``);
  * key-level    — key-value predicates over the same field share one
    window-equality pass (``"age" = 7`` and ``"age" = 11`` search the same
    ``'"age"'`` pattern), and simple patterns live in the SAME unique-key
    table, so ``age != NULL`` reuses it too;
  * value-level  — the value-side confinement scan depends only on
    ``(value pattern, unbounded)``, so repeated values across fields share
    one scan.

``CompiledPlan`` carries both representations: the unique tables + index
vectors (consumed by the xla oracle) and the flat per-predicate arrays
(consumed by the Pallas kernel, whose grid is per-predicate).  Predicates
are ordered simple-first so the simple/key-value boundary is a static
split point.  Key and value patterns get SEPARATE padded widths — values
are typically much shorter than quoted keys, so the value window loops
stay tight.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np

from repro.core.client import dedup_terms, encode_patterns
from repro.core.predicates import Clause, Kind

_PAT_ALIGN = 8  # pattern width bucket (stabilizes jit specializations)


def _bucket(n: int) -> int:
    return max(((n + _PAT_ALIGN - 1) // _PAT_ALIGN) * _PAT_ALIGN, _PAT_ALIGN)


@dataclass(frozen=True)
class CompiledPlan:
    """Device-ready encoding of a clause list (see kernels.fused/ref)."""

    # flat per-predicate arrays (Pallas kernel path), simple-first
    keys: np.ndarray        # uint8[P, Mk]
    klens: np.ndarray       # int32[P]
    vals: np.ndarray        # uint8[P, Mv]
    vlens: np.ndarray       # int32[P]
    kinds: np.ndarray       # int32[P]   0 = simple, 1 = key-value
    unbounded: np.ndarray   # int32[P]
    membership: np.ndarray  # uint8[C, P]
    # unique tables + index vectors (xla oracle path)
    ukeys: np.ndarray       # uint8[Uk, Mk]
    uklens: np.ndarray      # int32[Uk]
    uvals: np.ndarray       # uint8[Uv, Mv]
    uvlens: np.ndarray      # int32[Uv]
    uunb: np.ndarray        # int32[Uv]  unbounded flag per unique value
    key_ids: np.ndarray     # int32[P]   predicate -> unique key row
    val_ids: np.ndarray     # int32[P]   predicate -> unique value row (kv)

    @property
    def n_preds(self) -> int:
        return self.keys.shape[0]

    @property
    def n_simple(self) -> int:
        return int(np.sum(self.kinds == 0))

    @property
    def n_clauses(self) -> int:
        return self.membership.shape[0]


def compile_plan(clauses: Sequence[Clause]) -> CompiledPlan:
    terms, membership = dedup_terms(clauses)
    rows = []
    for ti, t in enumerate(terms):
        pats = t.patterns()
        if t.kind is Kind.KEY_VALUE and len(pats[1]) > 0:
            k, v = pats
            rows.append((ti, k, v, 1, int(b"," in v or b"}" in v)))
        else:
            # key-value with an empty value pattern degrades to key presence
            rows.append((ti, pats[0], b"", 0, 0))
    rows.sort(key=lambda r: r[3])  # stable: simple block, then key-value
    P = len(rows)

    uk: dict[bytes, int] = {}
    uv: dict[tuple[bytes, int], int] = {}
    key_ids = np.zeros((P,), np.int32)
    val_ids = np.zeros((P,), np.int32)
    kinds = np.zeros((P,), np.int32)
    unb = np.zeros((P,), np.int32)
    perm = np.zeros((P,), np.int64)
    for i, (ti, k, v, kind, u) in enumerate(rows):
        key_ids[i] = uk.setdefault(k, len(uk))
        if kind:
            val_ids[i] = uv.setdefault((v, u), len(uv))
        kinds[i], unb[i], perm[i] = kind, u, ti

    Mk = _bucket(max((len(k) for k in uk), default=1))
    Mv = _bucket(max((len(v) for v, _ in uv), default=1))
    ukeys, uklens = encode_patterns(list(uk), max_len=Mk)
    uvals, uvlens = encode_patterns([v for v, _ in uv], max_len=Mv)
    uunb = np.array([u for _, u in uv], np.int32).reshape(-1)
    return CompiledPlan(
        keys=ukeys[key_ids], klens=uklens[key_ids],
        vals=uvals[val_ids] if len(uv) else np.zeros((P, Mv), np.uint8),
        vlens=np.where(kinds > 0, uvlens[val_ids] if len(uv) else 0, 0
                       ).astype(np.int32),
        kinds=kinds, unbounded=unb,
        membership=membership[:, perm].astype(np.uint8),
        ukeys=ukeys, uklens=uklens, uvals=uvals, uvlens=uvlens, uunb=uunb,
        key_ids=key_ids, val_ids=val_ids,
    )
