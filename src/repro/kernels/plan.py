"""Plan compilation: clause list -> device-ready predicate tables.

Three levels of dedup (DESIGN.md §3.3), each mirroring how real plans
repeat themselves:

  * term-level   — a disjunct shared by several clauses gets ONE predicate
    slot (``core.client.dedup_terms``);
  * key-level    — key-value predicates over the same field share one
    window-equality pass (``"age" = 7`` and ``"age" = 11`` search the same
    ``'"age"'`` pattern), and simple patterns live in the SAME unique-key
    table, so ``age != NULL`` reuses it too;
  * value-level  — the value-side confinement scan depends only on
    ``(value pattern, unbounded)``, so repeated values across fields share
    one scan.

``CompiledPlan`` carries both representations: the unique tables + index
vectors (consumed by the xla oracle) and the flat per-predicate arrays
(consumed by the Pallas kernel, whose grid is per-predicate).  Predicates
are ordered simple-first so the simple/key-value boundary is a static
split point.  Key and value patterns get SEPARATE padded widths — values
are typically much shorter than quoted keys, so the value window loops
stay tight.

The QUERY-side mirror of the same idea is :func:`compile_query_batch`
(DESIGN.md §16): it dedups a multi-query batch query -> clause -> term,
keyed on the predicates' own type-strict equality (not pattern bytes —
see the function docstring), and both multi-query execution planes
consume it: the host :class:`~repro.core.batch_scan.ScanBatcher` and the
device batch compiler (``kernels.scan_fused.compile_scan_batch``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np

from repro.core.client import dedup_terms, encode_patterns
from repro.core.predicates import (
    Clause, Kind, Query, SimplePredicate, lowerable,
)

_PAT_ALIGN = 8  # pattern width bucket (stabilizes jit specializations)


def _bucket(n: int) -> int:
    return max(((n + _PAT_ALIGN - 1) // _PAT_ALIGN) * _PAT_ALIGN, _PAT_ALIGN)


@dataclass(frozen=True)
class CompiledPlan:
    """Device-ready encoding of a clause list (see kernels.fused/ref)."""

    # flat per-predicate arrays (Pallas kernel path), simple-first
    keys: np.ndarray        # uint8[P, Mk]
    klens: np.ndarray       # int32[P]
    vals: np.ndarray        # uint8[P, Mv]
    vlens: np.ndarray       # int32[P]
    kinds: np.ndarray       # int32[P]   0 = simple, 1 = key-value
    unbounded: np.ndarray   # int32[P]
    membership: np.ndarray  # uint8[C, P]
    # unique tables + index vectors (xla oracle path)
    ukeys: np.ndarray       # uint8[Uk, Mk]
    uklens: np.ndarray      # int32[Uk]
    uvals: np.ndarray       # uint8[Uv, Mv]
    uvlens: np.ndarray      # int32[Uv]
    uunb: np.ndarray        # int32[Uv]  unbounded flag per unique value
    key_ids: np.ndarray     # int32[P]   predicate -> unique key row
    val_ids: np.ndarray     # int32[P]   predicate -> unique value row (kv)

    @property
    def n_preds(self) -> int:
        return self.keys.shape[0]

    @property
    def n_simple(self) -> int:
        return int(np.sum(self.kinds == 0))

    @property
    def n_clauses(self) -> int:
        return self.membership.shape[0]


#: fill byte for neutralized (out-of-tier) predicate patterns.  Records are
#: JSON text and padding is NUL, so 0xFF never occurs in a chunk: the
#: kernel's first-char prefilter retires a neutralized predicate after one
#: vectorized compare over the tile, and the xla oracle's window passes
#: find nothing.  A neutralized pattern keeps FULL width (klen = Mk) so it
#: can never hit the empty-pattern match-all path.
NEUTRAL_BYTE = 0xFF


def tier_view(full: CompiledPlan, n_clauses: int) -> CompiledPlan:
    """Static clause-subset view: the first ``n_clauses`` clauses.

    Tiers of a :class:`~repro.core.server.PlanFamily` are nested prefixes
    of the top tier's clause order, and this view keeps EVERY array shape
    (P, C, Mk, Mv, the unique tables) and the simple/key-value split
    identical to the full compilation — so all tiers of a family share
    ONE jit trace per chunk shape bucket instead of one per tier
    (DESIGN.md §12).  Out-of-tier clauses get zero membership rows (their
    bitvector/count rows emit as zeros and drop out of the load-mask OR);
    predicates and unique key/value table rows no longer referenced by
    any in-tier clause are neutralized to unmatchable ``0xFF`` patterns,
    so the per-predicate grid steps they still occupy exit at the
    first-char prefilter — tier compute scales with the subset while the
    compiled artifact is shared.
    """
    C = full.n_clauses
    if not 0 <= n_clauses <= C:
        raise ValueError(f"tier size {n_clauses} out of range 0..{C}")
    if n_clauses == C:
        return full
    membership = full.membership.copy()
    membership[n_clauses:] = 0
    used = membership.any(axis=0)                      # bool[P]
    keys, klens = full.keys.copy(), full.klens.copy()
    vals, vlens = full.vals.copy(), full.vlens.copy()
    dead = ~used
    keys[dead] = NEUTRAL_BYTE
    klens[dead] = keys.shape[1]
    vals[dead] = NEUTRAL_BYTE
    vlens[dead] = np.where(full.kinds[dead] > 0, vals.shape[1], 0)
    # unique tables (xla-oracle path): neutralize rows unreferenced by any
    # live predicate — a unique key shared with an in-tier predicate stays
    live_k = np.zeros((len(full.ukeys),), bool)
    live_k[full.key_ids[used]] = True
    ukeys, uklens = full.ukeys.copy(), full.uklens.copy()
    ukeys[~live_k] = NEUTRAL_BYTE
    uklens[~live_k] = ukeys.shape[1]
    live_v = np.zeros((len(full.uvals),), bool)
    kv_live = used & (full.kinds > 0)
    live_v[full.val_ids[kv_live]] = True
    uvals, uvlens = full.uvals.copy(), full.uvlens.copy()
    uvals[~live_v] = NEUTRAL_BYTE
    uvlens[~live_v] = uvals.shape[1]
    return CompiledPlan(
        keys=keys, klens=klens, vals=vals, vlens=vlens,
        kinds=full.kinds, unbounded=full.unbounded, membership=membership,
        ukeys=ukeys, uklens=uklens, uvals=uvals, uvlens=uvlens,
        uunb=full.uunb, key_ids=full.key_ids, val_ids=full.val_ids,
    )


def compile_plan(clauses: Sequence[Clause]) -> CompiledPlan:
    terms, membership = dedup_terms(clauses)
    rows = []
    for ti, t in enumerate(terms):
        pats = t.patterns()
        if t.kind is Kind.KEY_VALUE and len(pats[1]) > 0:
            k, v = pats
            rows.append((ti, k, v, 1, int(b"," in v or b"}" in v)))
        else:
            # key-value with an empty value pattern degrades to key presence
            rows.append((ti, pats[0], b"", 0, 0))
    rows.sort(key=lambda r: r[3])  # stable: simple block, then key-value
    P = len(rows)

    uk: dict[bytes, int] = {}
    uv: dict[tuple[bytes, int], int] = {}
    key_ids = np.zeros((P,), np.int32)
    val_ids = np.zeros((P,), np.int32)
    kinds = np.zeros((P,), np.int32)
    unb = np.zeros((P,), np.int32)
    perm = np.zeros((P,), np.int64)
    for i, (ti, k, v, kind, u) in enumerate(rows):
        key_ids[i] = uk.setdefault(k, len(uk))
        if kind:
            val_ids[i] = uv.setdefault((v, u), len(uv))
        kinds[i], unb[i], perm[i] = kind, u, ti

    Mk = _bucket(max((len(k) for k in uk), default=1))
    Mv = _bucket(max((len(v) for v, _ in uv), default=1))
    ukeys, uklens = encode_patterns(list(uk), max_len=Mk)
    uvals, uvlens = encode_patterns([v for v, _ in uv], max_len=Mv)
    uunb = np.array([u for _, u in uv], np.int32).reshape(-1)
    return CompiledPlan(
        keys=ukeys[key_ids], klens=uklens[key_ids],
        vals=uvals[val_ids] if len(uv) else np.zeros((P, Mv), np.uint8),
        vlens=np.where(kinds > 0, uvlens[val_ids] if len(uv) else 0, 0
                       ).astype(np.int32),
        kinds=kinds, unbounded=unb,
        membership=membership[:, perm].astype(np.uint8),
        ukeys=ukeys, uklens=uklens, uvals=uvals, uvlens=uvlens, uunb=uunb,
        key_ids=key_ids, val_ids=val_ids,
    )


# ---------------------------------------------------------------------------
# multi-query batch compilation (DESIGN.md §16)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class QueryBatch:
    """Three-level dedup of a query batch: query -> clause -> term.

    The shared front half of both multi-query planes — the host
    :class:`~repro.core.batch_scan.ScanBatcher` evaluates each unique
    clause once per segment and recombines per query through
    ``query_clause``; the device compiler
    (``kernels.scan_fused.compile_scan_batch``) extends the same tables
    into its per-scan parameter form.  First-occurrence order everywhere:
    ``clauses[j]`` is the j-th distinct clause encountered walking the
    batch in query order, so indexes are deterministic for a given batch.
    """

    queries: tuple[Query, ...]
    clauses: tuple[Clause, ...]          # unique clauses across the batch
    terms: tuple[SimplePredicate, ...]   # unique terms across those clauses
    membership: np.ndarray               # uint8[C, T] clause -> term
    query_clause: np.ndarray             # uint8[Q, C] query -> clause
    clause_ids: tuple[tuple[int, ...], ...]   # per query: its clause rows
    lowerable: tuple[bool, ...]          # per query: every term lowerable

    @property
    def n_queries(self) -> int:
        return len(self.queries)

    @property
    def n_clauses(self) -> int:
        return len(self.clauses)

    @property
    def n_terms(self) -> int:
        return len(self.terms)


def compile_query_batch(queries: Sequence[Query]) -> QueryBatch:
    """Dedup clauses and terms across a query batch.

    Mirrors the ingest path's :func:`compile_plan`/``dedup_terms`` shape —
    one slot per unique disjunct, a clause-membership matrix, and here
    additionally a query->clause matrix — but keys the dedup on the
    predicates' own TYPE-STRICT equality (``SimplePredicate.__eq__``
    includes ``type(value)``).  ``dedup_terms`` keys on pattern BYTES,
    which is sound for the raw-matching client engines (identical
    patterns match identical byte positions) but not for columnar
    evaluation: EXACT compiles a value-only pattern, so ``EXACT(a, "x")``
    and ``EXACT(b, "x")`` alias at the byte level while reading different
    columns.
    """
    queries = tuple(queries)
    cl_index: dict[Clause, int] = {}
    clauses: list[Clause] = []
    clause_ids: list[tuple[int, ...]] = []
    for q in queries:
        rows = []
        for c in q.clauses:
            ci = cl_index.get(c)
            if ci is None:
                ci = cl_index[c] = len(clauses)
                clauses.append(c)
            rows.append(ci)
        clause_ids.append(tuple(rows))
    t_index: dict[SimplePredicate, int] = {}
    terms: list[SimplePredicate] = []
    for c in clauses:
        for t in c.terms:
            if t not in t_index:
                t_index[t] = len(terms)
                terms.append(t)
    membership = np.zeros((len(clauses), len(terms)), np.uint8)
    for ci, c in enumerate(clauses):
        for t in c.terms:
            membership[ci, t_index[t]] = 1
    query_clause = np.zeros((len(queries), len(clauses)), np.uint8)
    for qi, rows in enumerate(clause_ids):
        for ci in rows:
            query_clause[qi, ci] = 1
    low = tuple(
        all(lowerable(t) for c in q.clauses for t in c.terms)
        for q in queries
    )
    return QueryBatch(
        queries=queries, clauses=tuple(clauses), terms=tuple(terms),
        membership=membership, query_clause=query_clause,
        clause_ids=tuple(clause_ids), lowerable=low,
    )
