"""Jit'd public wrappers over the Pallas kernels with backend dispatch.

Backends:
  * ``"pallas"``           — real TPU lowering (``interpret=False``);
  * ``"pallas_interpret"`` — kernel body interpreted on CPU (CI/correctness);
  * ``"xla"``              — the pure-jnp oracle from :mod:`repro.kernels.ref`.

All wrappers pad R to the record-block multiple and slice back, so callers
never see alignment constraints.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from . import ref
from .bitvector_ops import bitvector_reduce
from .substring_match import key_value_match, multi_match_any

_PALLAS_BACKENDS = ("pallas", "pallas_interpret")


def _pad_rows(data: np.ndarray, r_blk: int) -> tuple[jnp.ndarray, int]:
    R = data.shape[0]
    padded = ((R + r_blk - 1) // r_blk) * r_blk
    if padded != R:
        data = np.concatenate(
            [data, np.zeros((padded - R,) + data.shape[1:], data.dtype)], axis=0
        )
    return jnp.asarray(data), R


def match_any(data, patterns, plens, *, backend: str = "pallas_interpret",
              r_blk: int = 256) -> np.ndarray:
    """bool[P, R] any-position multi-pattern match."""
    if backend == "xla":
        out = ref.multi_match_any_ref(
            jnp.asarray(data), jnp.asarray(patterns), jnp.asarray(plens)
        )
        return np.asarray(out, dtype=bool)
    if backend not in _PALLAS_BACKENDS:
        raise ValueError(f"unknown backend {backend!r}")
    dataj, R = _pad_rows(np.asarray(data), r_blk)
    out = multi_match_any(
        dataj,
        jnp.asarray(patterns),
        jnp.asarray(plens, dtype=jnp.int32),
        r_blk=min(r_blk, dataj.shape[0]),
        interpret=(backend == "pallas_interpret"),
    )
    return np.asarray(out, dtype=bool)[:, :R]


def match_key_value(data, key: bytes, val: bytes, *,
                    backend: str = "pallas_interpret", r_blk: int = 256) -> np.ndarray:
    """bool[R] key-value predicate match (paper Table I row 4)."""
    mk, mv = len(key), len(val)
    unbounded = b"," in val or b"}" in val
    key_arr = jnp.asarray(np.frombuffer(key, np.uint8)[None, :])
    val_arr = jnp.asarray(np.frombuffer(val, np.uint8)[None, :])
    if backend == "xla":
        out = ref.key_value_match_ref(
            jnp.asarray(data), key_arr, val_arr, mk=mk, mv=mv, unbounded=unbounded
        )
        return np.asarray(out[0], dtype=bool)
    if backend not in _PALLAS_BACKENDS:
        raise ValueError(f"unknown backend {backend!r}")
    dataj, R = _pad_rows(np.asarray(data), r_blk)
    out = key_value_match(
        dataj, key_arr, val_arr, mk=mk, mv=mv, unbounded=unbounded,
        r_blk=min(r_blk, dataj.shape[0]),
        interpret=(backend == "pallas_interpret"),
    )
    return np.asarray(out[0], dtype=bool)[:R]


def reduce_bitvectors(bitvecs, *, backend: str = "pallas_interpret",
                      w_blk: int = 128):
    """(and_words, or_words, surviving_count) over uint32[P, W]."""
    bv = np.asarray(bitvecs, dtype=np.uint32)
    if backend == "xla":
        a, o, c = ref.bitvector_reduce_ref(jnp.asarray(bv))
        return np.asarray(a), np.asarray(o), int(c)
    W = bv.shape[1]
    w_blk = min(w_blk, W)
    padded = ((W + w_blk - 1) // w_blk) * w_blk
    if padded != W:
        bv = np.concatenate(
            [bv, np.zeros((bv.shape[0], padded - W), np.uint32)], axis=1
        )
    a, o, c = bitvector_reduce(
        jnp.asarray(bv), w_blk=w_blk, interpret=(backend == "pallas_interpret")
    )
    return np.asarray(a)[:W], np.asarray(o)[:W], int(c)
