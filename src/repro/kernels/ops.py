"""Jit'd public wrappers over the Pallas kernels with backend dispatch.

Backends:
  * ``"pallas"``           — real TPU lowering (``interpret=False``);
  * ``"pallas_interpret"`` — kernel body interpreted on CPU (CI/correctness);
  * ``"xla"``              — the pure-jnp oracle from :mod:`repro.kernels.ref`.

All wrappers pad R to the record-block multiple and slice back, so callers
never see alignment constraints.  Block shapes are FIXED (never derived
from the incoming chunk size): a chunk only triggers a fresh jit
specialization when it lands in a new (padded-R, L, P, M) bucket, not per
distinct record count (DESIGN.md §3.5).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from . import ref
from .bitvector_ops import bitvector_reduce
from .fused import clause_bitvectors_fused
from .substring_match import key_value_match, multi_match_any

_PALLAS_BACKENDS = ("pallas", "pallas_interpret")


def _pad_rows(data: np.ndarray, r_blk: int) -> tuple[jnp.ndarray, int]:
    R = data.shape[0]
    padded = max(((R + r_blk - 1) // r_blk) * r_blk, r_blk)
    if padded != R:
        data = np.concatenate(
            [data, np.zeros((padded - R,) + data.shape[1:], data.dtype)], axis=0
        )
    return jnp.asarray(data), R


def match_any(data, patterns, plens, *, backend: str = "pallas_interpret",
              r_blk: int = 256) -> np.ndarray:
    """bool[P, R] any-position multi-pattern match."""
    if backend == "xla":
        out = ref.multi_match_any_ref(
            jnp.asarray(data), jnp.asarray(patterns), jnp.asarray(plens)
        )
        return np.asarray(out, dtype=bool)
    if backend not in _PALLAS_BACKENDS:
        raise ValueError(f"unknown backend {backend!r}")
    dataj, R = _pad_rows(np.asarray(data), r_blk)
    out = multi_match_any(
        dataj,
        jnp.asarray(patterns),
        jnp.asarray(plens, dtype=jnp.int32),
        r_blk=r_blk,
        interpret=(backend == "pallas_interpret"),
    )
    return np.asarray(out, dtype=bool)[:, :R]


def match_key_value(data, key: bytes, val: bytes, *,
                    backend: str = "pallas_interpret", r_blk: int = 256) -> np.ndarray:
    """bool[R] key-value predicate match (paper Table I row 4)."""
    mk, mv = len(key), len(val)
    unbounded = b"," in val or b"}" in val
    key_arr = jnp.asarray(np.frombuffer(key, np.uint8)[None, :])
    val_arr = jnp.asarray(np.frombuffer(val, np.uint8)[None, :])
    if backend == "xla":
        out = ref.key_value_match_ref(
            jnp.asarray(data), key_arr, val_arr, mk=mk, mv=mv, unbounded=unbounded
        )
        return np.asarray(out[0], dtype=bool)
    if backend not in _PALLAS_BACKENDS:
        raise ValueError(f"unknown backend {backend!r}")
    dataj, R = _pad_rows(np.asarray(data), r_blk)
    out = key_value_match(
        dataj, key_arr, val_arr, mk=mk, mv=mv, unbounded=unbounded,
        r_blk=r_blk,
        interpret=(backend == "pallas_interpret"),
    )
    return np.asarray(out[0], dtype=bool)[:R]


def clause_bitvectors(data, plan, *, backend: str = "pallas_interpret",
                      r_blk: int = 256):
    """Fused pushdown pass: dense chunk -> packed per-clause bitvectors.

    ONE device launch regardless of plan composition.  ``plan`` is a
    :class:`repro.kernels.plan.CompiledPlan`.  Returns
    ``(words uint32[C, W], or_words uint32[W], counts int32[C])`` with
    ``W = ceil(R / 32)`` — the clause bitvectors, the ingest load mask
    (OR over clauses) and per-clause popcounts (selectivity feedback).
    """
    data = np.asarray(data, dtype=np.uint8)
    R = data.shape[0]
    C, P = plan.membership.shape
    if C == 0 or P == 0 or R == 0:  # nothing to evaluate: empty outputs
        W = (R + 31) // 32
        return (np.zeros((C, W), np.uint32), np.zeros((W,), np.uint32),
                np.zeros((C,), np.int32))
    if not np.all(np.diff(plan.kinds) >= 0):
        raise ValueError("predicates must be ordered simple-first "
                         "(kernels.plan.compile_plan does this)")
    n_valid = jnp.asarray(np.array([[R]], dtype=np.int32))
    to_col = lambda a: jnp.asarray(  # noqa: E731
        np.asarray(a, dtype=np.int32).reshape(-1, 1))

    if backend == "xla":
        words, or_words, counts = ref.clause_bitvectors_ref(
            jnp.asarray(data),
            jnp.asarray(plan.ukeys), jnp.asarray(plan.uklens),
            jnp.asarray(plan.uvals), jnp.asarray(plan.uvlens),
            jnp.asarray(plan.uunb),
            jnp.asarray(plan.key_ids), jnp.asarray(plan.val_ids),
            jnp.asarray(plan.membership, dtype=jnp.uint8),
            n_valid, n_simple=plan.n_simple,
        )
        return (np.asarray(words), np.asarray(or_words), np.asarray(counts))
    if backend not in _PALLAS_BACKENDS:
        raise ValueError(f"unknown backend {backend!r}")
    dataj, R = _pad_rows(data, r_blk)
    words, or_words, counts = clause_bitvectors_fused(
        dataj, jnp.asarray(plan.keys), to_col(plan.klens),
        jnp.asarray(plan.vals), to_col(plan.vlens), to_col(plan.kinds),
        to_col(plan.unbounded),
        jnp.asarray(plan.membership, dtype=jnp.uint8), n_valid,
        r_blk=r_blk, interpret=(backend == "pallas_interpret"),
    )
    W = (R + 31) // 32
    return (np.asarray(words)[:, :W], np.asarray(or_words)[:W],
            np.asarray(counts))


def reduce_bitvectors(bitvecs, *, backend: str = "pallas_interpret",
                      w_blk: int = 128):
    """(and_words, or_words, surviving_count) over uint32[P, W]."""
    bv = np.asarray(bitvecs, dtype=np.uint32)
    if backend == "xla":
        a, o, c = ref.bitvector_reduce_ref(jnp.asarray(bv))
        return np.asarray(a), np.asarray(o), int(c)
    W = bv.shape[1]
    padded = max(((W + w_blk - 1) // w_blk) * w_blk, w_blk)
    if padded != W:
        bv = np.concatenate(
            [bv, np.zeros((bv.shape[0], padded - W), np.uint32)], axis=1
        )
    a, o, c = bitvector_reduce(
        jnp.asarray(bv), w_blk=w_blk, interpret=(backend == "pallas_interpret")
    )
    return np.asarray(a)[:W], np.asarray(o)[:W], int(c)
