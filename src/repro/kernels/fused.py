"""Fused single-pass pushdown kernel: chunk -> packed clause bitvectors.

ONE ``pallas_call`` evaluates an entire pushdown plan on a dense chunk
(DESIGN.md §3.4).  The seed path needed 1 launch for the simple-pattern set
plus one launch *per key-value pair* (each a fresh jit specialization),
then round-tripped bool hits to the host to OR disjuncts, pack bitvectors
in numpy, and launched ``bitvector_reduce`` again for the load mask.  Here
the whole chunk -> packed-bitvector path stays on device:

  grid = (R/R_blk, P)   (predicate index innermost, so the record tile
                         stays resident in VMEM across all P predicates)

Per grid step (rb, p) the kernel evaluates predicate ``p`` on the record
tile with *masked dynamic lengths* — both the simple any-position match and
the key-value match reuse :func:`masked_window_eq`, so one compilation
serves every pattern in the plan (no per-(mk, mv) specializations) — and
ORs the per-record hits into a (C, R_blk) clause accumulator through the
static clause-membership matrix.  At ``p == P-1`` it bit-packs the
accumulator into uint32 words (little-endian, ``core.bitvector`` layout),
ORs the clause words into the ingest load mask, and accumulates per-clause
popcounts, emitting all three outputs from the same pass.

Predicate encoding (built once per plan by ``kernels.engine``):
  * ``keys  uint8[P, M]`` / ``klens int32[P, 1]`` — the pattern (simple) or
    the key pattern (key-value), zero-padded to the plan-wide max ``M``;
  * ``vals  uint8[P, M]`` / ``vlens int32[P, 1]`` — the value pattern
    (key-value only; zeros otherwise);
  * ``kinds int32[P, 1]`` — 0 = simple any-position, 1 = key-value;
  * ``unbounded int32[P, 1]`` — key-value degraded to unbounded suffix
    search (value pattern contains a delimiter);
  * ``membership uint8[C, P]`` — clause c contains predicate p.

Padding rows (R padded up to R_blk) are masked via the dynamic ``n_valid``
scalar, so jit specializations key on the *bucketed* shape only.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .substring_match import (
    DELIM_BRACE,
    DELIM_COMMA,
    _segmented_suffix_any,
    masked_window_eq,
    select_shift_left,
)

WORD_BITS = 32


def _clause_bitvectors_kernel(
    key_ref, klen_ref, val_ref, vlen_ref, kind_ref, unb_ref, mem_ref, nv_ref,
    data_ref, bv_ref, or_ref, cnt_ref, acc_ref, *, max_key_len: int,
    max_val_len: int, n_clauses: int, r_blk: int,
):
    rb = pl.program_id(0)
    p = pl.program_id(1)
    n_p = pl.num_programs(1)

    @pl.when(p == 0)
    def _fresh_tile():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(jnp.logical_and(p == 0, rb == 0))
    def _fresh_chunk():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    data = data_ref[...]                       # (R_blk, L) uint8
    key = key_ref[...]                         # (1, M)
    mk = klen_ref[0, 0]
    mv = vlen_ref[0, 0]
    is_kv = kind_ref[0, 0] > 0

    # first-character prefilter: the found/not-found cost asymmetry — a tile
    # with zero candidate windows skips the O(M) inner reduction entirely.
    first = data == key[0, 0]

    def _eval_predicate():
        key_hit = masked_window_eq(data, key[0], mk, max_key_len)

        def _simple():
            return jnp.logical_or(jnp.any(key_hit, axis=1), mk == 0)

        def _key_value():
            val_hit = masked_window_eq(data, val_ref[0], mv, max_val_len)

            def _have_values():
                # unbounded search == segmented search with no delimiters
                delim = jnp.logical_and(
                    jnp.logical_or(data == DELIM_COMMA, data == DELIM_BRACE),
                    unb_ref[0, 0] == 0,
                )
                cond = _segmented_suffix_any(val_hit, delim)
                # value region starts mk bytes after the key (dynamic mk)
                region = select_shift_left(cond, mk, max_key_len)
                return jnp.any(jnp.logical_and(key_hit, region), axis=1)

            # second prefilter: no value window in the tile -> no match,
            # skip the scan + shift chain (the expensive stages)
            return lax.cond(
                jnp.any(val_hit), _have_values,
                lambda: jnp.zeros((r_blk,), dtype=jnp.bool_),
            )

        return lax.cond(is_kv, _key_value, _simple)

    hit = lax.cond(
        jnp.logical_or(jnp.any(first), mk == 0),
        _eval_predicate,
        lambda: jnp.zeros((r_blk,), dtype=jnp.bool_),
    )

    mem_col = mem_ref[...]                     # (C, 1) uint8
    acc_ref[...] = acc_ref[...] | (mem_col * hit[None, :].astype(jnp.uint8))

    @pl.when(p == n_p - 1)
    def _emit():
        row = rb * r_blk + lax.broadcasted_iota(jnp.int32, (1, r_blk), 1)
        valid = (row < nv_ref[0, 0]).astype(jnp.uint8)
        bits = acc_ref[...] * valid            # (C, R_blk) in {0, 1}

        shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
        grouped = bits.astype(jnp.uint32).reshape(
            n_clauses, r_blk // WORD_BITS, WORD_BITS
        )
        words = jnp.sum(grouped << shifts, axis=-1, dtype=jnp.uint32)
        bv_ref[...] = words

        or_words = words[0]
        for c in range(1, n_clauses):          # C is a static block dim
            or_words = jnp.bitwise_or(or_words, words[c])
        or_ref[0, :] = or_words

        cnt_ref[...] += jnp.sum(bits, axis=1, dtype=jnp.int32)[:, None]


@functools.partial(
    jax.jit, static_argnames=("r_blk", "interpret")
)
def clause_bitvectors_fused(
    data: jnp.ndarray,        # uint8[R, L]    (R % r_blk == 0)
    keys: jnp.ndarray,        # uint8[P, M]
    klens: jnp.ndarray,       # int32[P, 1]
    vals: jnp.ndarray,        # uint8[P, M]
    vlens: jnp.ndarray,       # int32[P, 1]
    kinds: jnp.ndarray,       # int32[P, 1]
    unbounded: jnp.ndarray,   # int32[P, 1]
    membership: jnp.ndarray,  # uint8[C, P]
    n_valid: jnp.ndarray,     # int32[1, 1]
    *,
    r_blk: int = 256,
    interpret: bool = True,
):
    """(words uint32[C, R/32], or_words uint32[R/32], counts int32[C])."""
    R, L = data.shape
    P, Mk = keys.shape
    Mv = vals.shape[1]
    C = membership.shape[0]
    if R % r_blk or r_blk % WORD_BITS:
        raise ValueError(f"R={R} not a multiple of r_blk={r_blk} (mult of 32)")
    W = R // WORD_BITS
    w_blk = r_blk // WORD_BITS
    grid = (R // r_blk, P)
    kernel = functools.partial(
        _clause_bitvectors_kernel,
        max_key_len=Mk, max_val_len=Mv, n_clauses=C, r_blk=r_blk,
    )
    words, or_words, counts = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Mk), lambda rb, p: (p, 0)),     # keys
            pl.BlockSpec((1, 1), lambda rb, p: (p, 0)),      # klens
            pl.BlockSpec((1, Mv), lambda rb, p: (p, 0)),     # vals
            pl.BlockSpec((1, 1), lambda rb, p: (p, 0)),      # vlens
            pl.BlockSpec((1, 1), lambda rb, p: (p, 0)),      # kinds
            pl.BlockSpec((1, 1), lambda rb, p: (p, 0)),      # unbounded
            pl.BlockSpec((C, 1), lambda rb, p: (0, p)),      # membership col
            pl.BlockSpec((1, 1), lambda rb, p: (0, 0)),      # n_valid
            pl.BlockSpec((r_blk, L), lambda rb, p: (rb, 0)),  # record tile
        ],
        out_specs=[
            pl.BlockSpec((C, w_blk), lambda rb, p: (0, rb)),
            pl.BlockSpec((1, w_blk), lambda rb, p: (0, rb)),
            pl.BlockSpec((C, 1), lambda rb, p: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((C, W), jnp.uint32),
            jax.ShapeDtypeStruct((1, W), jnp.uint32),
            jax.ShapeDtypeStruct((C, 1), jnp.int32),
        ],
        scratch_shapes=[
            # clause accumulator for the current record tile
            pltpu.VMEM((C, r_blk), jnp.uint8),
        ],
        interpret=interpret,
    )(keys, klens, vals, vlens, kinds, unbounded, membership, n_valid, data)
    return words, or_words[0], counts[:, 0]
