"""Pallas TPU kernels (+ pure-jnp oracles in ref.py, jit wrappers in ops.py).

  * fused           — single-pass pushdown: chunk -> packed clause
    bitvectors + load mask + popcounts in ONE launch (DESIGN.md §3.4)
  * substring_match — the paper's hot loop, TPU-adapted (DESIGN.md §3);
    still used stand-alone by ops.match_any / ops.match_key_value
  * bitvector_ops   — AND/OR/popcount streaming reduce for query-time
    data skipping (the ingest-side reduce now lives in the fused pass)
  * flash_attention — canonical grid-accumulated flash attention (GQA via
    BlockSpec index maps), used by the compute plane

All validated in interpret mode; the ops wrappers dispatch between
pallas / pallas_interpret / xla.
"""
from . import ops, ref  # noqa: F401
from .ops import clause_bitvectors  # noqa: F401
