"""Pallas TPU kernels (+ pure-jnp oracles in ref.py, jit wrappers in ops.py).

  * substring_match — the paper's hot loop, TPU-adapted (DESIGN.md §3)
  * bitvector_ops   — AND/OR/popcount streaming reduce for data skipping
  * flash_attention — canonical grid-accumulated flash attention (GQA via
    BlockSpec index maps), used by the compute plane

All validated in interpret mode; ops.match_any / ops.match_key_value /
ops.reduce_bitvectors dispatch between pallas / pallas_interpret / xla.
"""
from . import ops, ref  # noqa: F401
