"""Kernel-backed client engine (implements the core engine protocol).

The whole plan is compiled ONCE into a flat predicate table + clause
membership matrix (:func:`compile_plan`), and a chunk is evaluated with a
single fused device pass (``ops.clause_bitvectors``): simple and key-value
predicates batch into one grid dimension with masked dynamic lengths, the
clause OR-combine, bit-packing, load-mask OR and popcounts all happen on
device.  No per-key-value-pair launches, no host-side OR/pack
(DESIGN.md §3.4).
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.core import bitvector
from repro.core.bitvector import ChunkBitvectors
from repro.core.client import Chunk
from repro.core.predicates import Clause

from . import ops
from .plan import CompiledPlan, compile_plan  # noqa: F401 (re-export)


class KernelEngine:
    def __init__(self, backend: str = "pallas_interpret", r_blk: int = 256):
        if backend == "pallas":
            # explicit opt-in for real hardware; default harness is CPU
            pass
        self.backend = backend
        self.r_blk = r_blk
        self.name = backend
        self._plan_cache: dict[tuple[Clause, ...], CompiledPlan] = {}

    def _compiled(self, clauses: tuple[Clause, ...]) -> CompiledPlan:
        plan = self._plan_cache.get(clauses)
        if plan is None:
            plan = compile_plan(clauses)
            if len(self._plan_cache) > 64:  # plans change rarely; bound it
                self._plan_cache.clear()
            self._plan_cache[clauses] = plan
        return plan

    def eval_fused(self, chunk: Chunk, clauses: Sequence[Clause]) -> ChunkBitvectors:
        """One device launch: packed bitvectors + load mask + popcounts."""
        C, R = len(clauses), chunk.n_records
        W = bitvector.num_words(R)
        if C == 0 or R == 0:
            return ChunkBitvectors(
                words=np.zeros((C, W), np.uint32),
                or_words=np.zeros((W,), np.uint32),
                counts=np.zeros((C,), np.int32),
                n_records=R,
            )
        plan = self._compiled(tuple(clauses))
        words, or_words, counts = ops.clause_bitvectors(
            chunk.data, plan, backend=self.backend, r_blk=self.r_blk,
        )
        return ChunkBitvectors(
            words=words, or_words=or_words, counts=counts, n_records=R
        )

    def eval(self, chunk: Chunk, clauses: Sequence[Clause]) -> np.ndarray:
        fused = self.eval_fused(chunk, clauses)
        return bitvector.unpack(fused.words, chunk.n_records)

    def eval_packed(self, chunk: Chunk, clauses: Sequence[Clause]) -> np.ndarray:
        return self.eval_fused(chunk, clauses).words
