"""Kernel-backed client engine (implements the core engine protocol).

Evaluates a clause list on a dense chunk with the Pallas kernels:
simple predicates (exact / substring / key-presence) batch into one
``match_any`` call over the deduplicated pattern set; key-value predicates
dispatch to ``match_key_value``.  Disjunctions OR at the host level.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.core import bitvector
from repro.core.client import Chunk, encode_patterns
from repro.core.predicates import Clause, Kind

from . import ops


class KernelEngine:
    def __init__(self, backend: str = "pallas_interpret", r_blk: int = 256):
        if backend == "pallas":
            # explicit opt-in for real hardware; default harness is CPU
            pass
        self.backend = backend
        self.r_blk = r_blk
        self.name = backend

    def eval(self, chunk: Chunk, clauses: Sequence[Clause]) -> np.ndarray:
        # 1) collect unique simple patterns across all clauses
        simple_pats: dict[bytes, int] = {}
        kv_pairs: dict[tuple[bytes, bytes], int] = {}
        for cl in clauses:
            for t in cl.terms:
                if t.kind is Kind.KEY_VALUE:
                    k, v = t.patterns()
                    kv_pairs.setdefault((k, v), len(kv_pairs))
                else:
                    simple_pats.setdefault(t.patterns()[0], len(simple_pats))

        R = chunk.n_records
        simple_hits = np.zeros((len(simple_pats), R), dtype=bool)
        if simple_pats:
            pats, plens = encode_patterns(list(simple_pats))
            simple_hits = ops.match_any(
                chunk.data, pats, plens[:, None],
                backend=self.backend, r_blk=self.r_blk,
            )
        kv_hits = np.zeros((len(kv_pairs), R), dtype=bool)
        for (k, v), idx in kv_pairs.items():
            kv_hits[idx] = ops.match_key_value(
                chunk.data, k, v, backend=self.backend, r_blk=self.r_blk
            )

        # 2) combine into per-clause bits (OR over disjuncts)
        out = np.zeros((len(clauses), R), dtype=bool)
        for ci, cl in enumerate(clauses):
            row = out[ci]
            for t in cl.terms:
                if t.kind is Kind.KEY_VALUE:
                    k, v = t.patterns()
                    row |= kv_hits[kv_pairs[(k, v)]]
                else:
                    row |= simple_hits[simple_pats[t.patterns()[0]]]
        return out

    def eval_packed(self, chunk: Chunk, clauses: Sequence[Clause]) -> np.ndarray:
        return bitvector.pack(self.eval(chunk, clauses))
