"""Kernel-backed client engine (implements the core engine protocol).

The whole plan is compiled ONCE into a flat predicate table + clause
membership matrix (:func:`compile_plan`), and a chunk is evaluated with a
single fused device pass (``ops.clause_bitvectors``): simple and key-value
predicates batch into one grid dimension with masked dynamic lengths, the
clause OR-combine, bit-packing, load-mask OR and popcounts all happen on
device.  No per-key-value-pair launches, no host-side OR/pack
(DESIGN.md §3.4).
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.core import bitvector
from repro.core.bitvector import ChunkBitvectors
from repro.core.client import Chunk
from repro.core.predicates import Clause

from . import ops
from .plan import CompiledPlan, compile_plan, tier_view  # noqa: F401 (re-export)


class KernelEngine:
    def __init__(self, backend: str = "pallas_interpret", r_blk: int = 256):
        if backend == "pallas":
            # explicit opt-in for real hardware; default harness is CPU
            pass
        self.backend = backend
        self.r_blk = r_blk
        self.name = backend
        self._plan_cache: dict[tuple[Clause, ...], CompiledPlan] = {}
        # (full clause tuple, tier size) -> neutralized subset view; the
        # views share the full plan's shapes, hence its jit trace
        self._tier_cache: dict[tuple[tuple[Clause, ...], int], CompiledPlan] = {}

    def _compiled(self, clauses: tuple[Clause, ...]) -> CompiledPlan:
        plan = self._plan_cache.get(clauses)
        if plan is None:
            plan = compile_plan(clauses)
            if len(self._plan_cache) > 64:  # plans change rarely; bound it
                self._plan_cache.clear()
                self._tier_cache.clear()
            self._plan_cache[clauses] = plan
        return plan

    def _compiled_tier(self, clauses: tuple[Clause, ...],
                       n_clauses: int) -> CompiledPlan:
        key = (clauses, n_clauses)
        view = self._tier_cache.get(key)
        if view is None:
            view = tier_view(self._compiled(clauses), n_clauses)
            if len(self._tier_cache) > 256:
                self._tier_cache.clear()
            self._tier_cache[key] = view
        return view

    def eval_fused(self, chunk: Chunk, clauses: Sequence[Clause]) -> ChunkBitvectors:
        """One device launch: packed bitvectors + load mask + popcounts."""
        C, R = len(clauses), chunk.n_records
        W = bitvector.num_words(R)
        if C == 0 or R == 0:
            return ChunkBitvectors(
                words=np.zeros((C, W), np.uint32),
                or_words=np.zeros((W,), np.uint32),
                counts=np.zeros((C,), np.int32),
                n_records=R,
            )
        plan = self._compiled(tuple(clauses))
        words, or_words, counts = ops.clause_bitvectors(
            chunk.data, plan, backend=self.backend, r_blk=self.r_blk,
        )
        return ChunkBitvectors(
            words=words, or_words=or_words, counts=counts, n_records=R
        )

    def eval_fused_prefix(self, chunk: Chunk, clauses: Sequence[Clause],
                          n_clauses: int) -> ChunkBitvectors:
        """Tiered evaluation: the first ``n_clauses`` of ``clauses``.

        Unlike ``eval_fused(chunk, clauses[:k])`` — which would compile a
        smaller plan and trigger a fresh jit specialization per tier —
        this evaluates a neutralized subset VIEW of the full compiled
        plan (:func:`repro.kernels.plan.tier_view`), so every tier of a
        family shares one trace per chunk shape bucket; out-of-tier
        predicates retire at the kernel's first-char prefilter.  The
        returned bitvectors carry exactly ``n_clauses`` rows and are
        bit-identical to a direct evaluation of the subset.
        """
        clauses = tuple(clauses)
        C, R = len(clauses), chunk.n_records
        if not 0 <= n_clauses <= C:
            raise ValueError(f"prefix {n_clauses} out of range 0..{C}")
        if n_clauses == C:
            return self.eval_fused(chunk, clauses)
        W = bitvector.num_words(R)
        if n_clauses == 0 or R == 0:
            return ChunkBitvectors(
                words=np.zeros((n_clauses, W), np.uint32),
                or_words=np.zeros((W,), np.uint32),
                counts=np.zeros((n_clauses,), np.int32),
                n_records=R,
            )
        view = self._compiled_tier(clauses, n_clauses)
        words, or_words, counts = ops.clause_bitvectors(
            chunk.data, view, backend=self.backend, r_blk=self.r_blk,
        )
        # out-of-tier clause rows are all-zero by construction: slice them
        # off so the store sees exactly the tier's coverage
        return ChunkBitvectors(
            words=words[:n_clauses], or_words=or_words,
            counts=counts[:n_clauses], n_records=R,
        )

    def eval(self, chunk: Chunk, clauses: Sequence[Clause]) -> np.ndarray:
        fused = self.eval_fused(chunk, clauses)
        return bitvector.unpack(fused.words, chunk.n_records)

    def eval_packed(self, chunk: Chunk, clauses: Sequence[Clause]) -> np.ndarray:
        return self.eval_fused(chunk, clauses).words
