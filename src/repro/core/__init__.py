"""CIAO core: the paper's contribution (predicates, selection, loading)."""
from .predicates import (  # noqa: F401
    Clause,
    Kind,
    Query,
    SimplePredicate,
    all_patterns,
    clause,
    exact,
    key_value,
    presence,
    query,
    substring,
)
from .bitvector import pack, unpack, popcount  # noqa: F401
from .client import Chunk, NumpyEngine, PythonEngine, encode_chunk, get_engine  # noqa: F401
from .cost_model import CostModel, calibrate, fit  # noqa: F401
from .planner import PlanReport, build_plan, plan_for_clients  # noqa: F401
from .selection import (  # noqa: F401
    SelectionProblem,
    SelectionResult,
    brute_force,
    celf_greedy,
    combined_celf,
    combined_greedy,
    greedy,
    objective,
)
from .server import (  # noqa: F401
    CiaoStore,
    DataSkippingScanner,
    FullScanBaseline,
    PushdownPlan,
)
from .workload import Workload, estimate_selectivities, generate_workload  # noqa: F401
