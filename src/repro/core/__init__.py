"""CIAO core: the paper's contribution (predicates, selection, loading)."""
from .bitvector import pack, popcount, unpack  # noqa: F401
from .client import (  # noqa: F401
    Chunk,
    NumpyEngine,
    PythonEngine,
    encode_chunk,
    get_engine,
)
from .cost_model import CostModel, calibrate, fit  # noqa: F401
from .planner import PlanReport, build_plan, plan_for_clients  # noqa: F401
from .predicates import (  # noqa: F401
    Clause,
    Kind,
    Query,
    SimplePredicate,
    all_patterns,
    clause,
    exact,
    key_value,
    presence,
    query,
    substring,
)
from .replan import (  # noqa: F401
    DriftSignal,
    ReplanEvent,
    Replanner,
    ReplanPolicy,
)
from .selection import (  # noqa: F401
    SelectionProblem,
    SelectionResult,
    brute_force,
    celf_greedy,
    combined_celf,
    combined_greedy,
    greedy,
    objective,
)
from .server import (  # noqa: F401
    CiaoStore,
    DataSkippingScanner,
    FullScanBaseline,
    PushdownPlan,
    StaleEpochError,
    evolve_plan,
)
from .shard import (  # noqa: F401
    ShardedCiaoStore,
    ShardedScanner,
    ShardRouter,
    ShardSummary,
    choose_routing_key,
    merge_scan_results,
    reshard,
)
from .workload import (  # noqa: F401
    DriftPhase,
    Workload,
    drifting_query_stream,
    drifting_workloads,
    estimate_selectivities,
    generate_workload,
)
