"""Columnar scan engine: struct-of-arrays segments + zone-map skipping.

The server-side query path used to be row-at-a-time Python: every block
kept ``rows: list[dict]`` and the scanner called ``q.matches_exact(row)``
per surviving row.  This module replaces that layout with *segments*
(DESIGN.md §13):

  * loaded rows are decomposed at ingest into per-key struct-of-arrays
    columns — numeric values as float64 + validity masks, string values
    dictionary-encoded (int32 codes into a per-segment dictionary), and a
    *scalar-repr* dictionary column holding ``json_scalar(v)`` for every
    present value (the paper's §IV-B cross-representation equality,
    e.g. ``age = 10`` matching the string ``"10"``, stays exact);
  * small per-chunk row groups are compacted into large fixed-capacity
    segments (one :class:`SegmentBuilder` per ``(epoch, n_covered, tier)``
    coverage group), amortizing per-block Python overhead;
  * each segment carries *zone maps* — per-key numeric min/max and the
    string/repr dictionary sets — a second level of data skipping for
    residual clauses the client never evaluated (following the
    extensible-data-skipping / raw-data-query-processing line in
    PAPERS.md);
  * predicates are *lowered* to vectorized numpy evaluation over whole
    columns with EXACT ``matches_exact`` semantics (``predicates.
    lowerable`` gates the cases the lowering covers; anything else falls
    back to a per-row oracle check on the raw bytes, so counts are
    bit-identical by construction).

Segments keep the loaded records' raw JSON bytes (one blob + offsets), so
recipe batching streams source bytes without a ``json.dumps`` round-trip
and the per-row fallback parses lazily.  ``matches_exact`` survives only
as the differential oracle (and the fallback for non-lowerable terms).
"""
from __future__ import annotations

import json
import threading
from dataclasses import dataclass
from typing import Callable, Sequence

import numpy as np

from . import bitvector
from .predicates import (
    Clause, Kind, Query, SimplePredicate, json_number, json_scalar,
    lowerable, range_contains,
)
from .skip_index import (
    REGISTRY, KeyStats, NGramBloom, conservative_bounds,
)

def _f64_exact(v) -> bool:
    """True iff ``float(v) == v`` holds exactly (no float64 aliasing)."""
    try:
        return float(v) == v
    except (OverflowError, ValueError):
        return False


# ---------------------------------------------------------------------------
# per-key column bundle
# ---------------------------------------------------------------------------

@dataclass
class KeyColumn:
    """Struct-of-arrays decomposition of one JSON key over a segment.

    Every mask/array is aligned to the segment's row order.  ``repr_*``
    dictionary-encodes ``json_scalar(v)`` for EVERY present value (strings
    included), which is what keeps ``KEY_VALUE`` cross-representation
    equality exact without per-row parsing.  The zone map lives in
    ``num_min``/``num_max`` (numeric values only) plus the dictionary
    index sets themselves.
    """

    present: np.ndarray      # bool[n] — key exists in the row object
    notnull: np.ndarray      # bool[n] — present and value is not None
    is_bool: np.ndarray      # bool[n]
    num_valid: np.ndarray    # bool[n] — int/float (not bool), f64-exact
    num: np.ndarray          # float64[n] — value where num_valid
    str_codes: np.ndarray    # int32[n] — dictionary code, -1 = not a string
    str_dict: list[str]
    str_index: dict[str, int]
    repr_codes: np.ndarray   # int32[n] — json_scalar dictionary, -1 = absent
    repr_dict: list[str]
    repr_index: dict[str, int]
    num_min: float = np.inf   # zone map over num_valid rows
    num_max: float = -np.inf
    any_notnull: bool = False
    # False when a NaN was observed among the key's float values at build
    # time: NaN never enters ``num`` (``_f64_exact`` rejects it, NaN != NaN)
    # so min/max stay finite, but the flag marks the numeric zone map
    # non-prunable — every min/max refutation must gate on it, because a
    # comparison against a poisoned bound would be silently False and skip
    # a segment that still holds matches
    num_prunable: bool = True
    # RANGE-index bounds (DESIGN.md §19): min/max over every value the
    # RANGE semantics can match — numerics (huge ints ulp-widened) AND
    # strings parsing as JSON numbers — so they never hit the §IV-B
    # cross-representation trap the num_* bounds have.  NaN matches no
    # range, so it is simply excluded (no poisoning flag needed: segment
    # bounds are always exact-or-widened, hence always prunable).
    rnum_min: float = np.inf
    rnum_max: float = -np.inf
    # byte-level 3-gram bloom over the string dictionary (None when the
    # segment holds no strings for this key)
    ngram: "NGramBloom | None" = None


class _KeyAcc:
    """Accumulates one key's values; :meth:`finish` emits a KeyColumn."""

    __slots__ = ("present", "notnull", "is_bool", "num_valid", "num",
                 "str_codes", "str_index", "repr_codes", "repr_index",
                 "has_nan")

    def __init__(self, n: int):
        self.present = np.zeros(n, bool)
        self.notnull = np.zeros(n, bool)
        self.is_bool = np.zeros(n, bool)
        self.num_valid = np.zeros(n, bool)
        self.num = np.zeros(n, np.float64)
        self.str_codes = np.full(n, -1, np.int32)
        self.str_index: dict[str, int] = {}
        self.repr_codes = np.full(n, -1, np.int32)
        self.repr_index: dict[str, int] = {}
        self.has_nan = False

    def add(self, i: int, v) -> None:
        self.present[i] = True
        if v is not None:
            self.notnull[i] = True
        if isinstance(v, bool):
            self.is_bool[i] = True
        elif isinstance(v, float) and v != v:
            # NaN: excluded from the numeric column (NaN == NaN is False so
            # _f64_exact rejects it) — detect it EXPLICITLY and poison-mark
            # the zone map instead of relying on that rejection staying true
            self.has_nan = True
        elif isinstance(v, (int, float)) and _f64_exact(v):
            self.num_valid[i] = True
            self.num[i] = float(v)
        elif isinstance(v, str):
            code = self.str_index.setdefault(v, len(self.str_index))
            self.str_codes[i] = code
        r = json_scalar(v)
        self.repr_codes[i] = self.repr_index.setdefault(r, len(self.repr_index))

    def finish(self) -> KeyColumn:
        nums = self.num[self.num_valid]
        # RANGE bounds fold over the DISTINCT reprs, not the rows: every
        # present value's repr round-trips through json_number to exactly
        # the numeric its row contributes (ints arbitrary-precision,
        # floats bit-exact, numeric strings ARE their repr), and the
        # dictionary dedups the parses.  Bool reprs ("true"/"false"),
        # non-numeric strings and "NaN" contribute nothing.
        rmin, rmax = np.inf, -np.inf
        for r in self.repr_index:
            x = json_number(r)
            if x is None or x != x:
                continue
            lo, hi = conservative_bounds(x)
            if lo < rmin:
                rmin = lo
            if hi > rmax:
                rmax = hi
        ngram = None
        if self.str_index:
            ngram = NGramBloom()
            for s in self.str_index:
                ngram.add(s)
        return KeyColumn(
            present=self.present, notnull=self.notnull,
            is_bool=self.is_bool, num_valid=self.num_valid, num=self.num,
            str_codes=self.str_codes,
            str_dict=list(self.str_index), str_index=self.str_index,
            repr_codes=self.repr_codes,
            repr_dict=list(self.repr_index), repr_index=self.repr_index,
            num_min=float(nums.min()) if nums.size else np.inf,
            num_max=float(nums.max()) if nums.size else -np.inf,
            any_notnull=bool(self.notnull.any()),
            num_prunable=not self.has_nan,
            rnum_min=rmin, rnum_max=rmax, ngram=ngram,
        )


def build_key_columns(objs: Sequence[dict],
                      keys: "set[str] | frozenset[str] | None" = None
                      ) -> dict[str, KeyColumn]:
    """Decompose parsed row objects into per-key struct-of-arrays columns.

    ``keys`` restricts the build to a subset (the per-key layout policy's
    eager set, DESIGN.md §18); ``None`` builds every key present.
    """
    accs: dict[str, _KeyAcc] = {}
    n = len(objs)
    for i, obj in enumerate(objs):
        for k, v in obj.items():
            if keys is not None and k not in keys:
                continue
            acc = accs.get(k)
            if acc is None:
                acc = accs[k] = _KeyAcc(n)
            acc.add(i, v)
    return {k: acc.finish() for k, acc in accs.items()}


# ---------------------------------------------------------------------------
# vectorized predicate lowering (exact matches_exact semantics)
# ---------------------------------------------------------------------------

def eval_lowered(col: KeyColumn, pred: SimplePredicate) -> np.ndarray:
    """bool[n]: exact ``pred.matches_exact`` over one column.

    Callers must gate on :func:`repro.core.predicates.lowerable`; the
    per-kind derivations below mirror ``SimplePredicate.matches_exact``
    line by line (bool-vs-non-bool mismatch, cross-representation
    equality via the repr dictionary, float64-exactness guards).
    """
    v = pred.value
    if pred.kind is Kind.KEY_PRESENCE:
        return col.notnull.copy()
    if pred.kind is Kind.EXACT:
        # value is a string (lowerable gate): only string rows can equal it
        code = col.str_index.get(v, -2)
        return col.str_codes == code
    if pred.kind is Kind.SUBSTRING:
        if isinstance(v, bool):
            # matches_exact's bool-mismatch check plus isinstance(v, str)
            # can never both hold: provably empty
            return np.zeros(col.present.shape, bool)
        sub = str(v)
        lut = np.zeros(len(col.str_dict) + 1, bool)
        for s, code in col.str_index.items():
            lut[code + 1] = sub in s
        return lut[col.str_codes + 1]
    if pred.kind is Kind.RANGE:
        # pure repr-LUT: a row's repr round-trips through json_number to
        # exactly the value ``range_contains`` would test (ints
        # arbitrary-precision, floats bit-exact, numeric strings ARE
        # their repr; "true"/"false"/"None"/non-numeric parse to None →
        # False, "NaN" → nan fails every comparison) — bit-identical to
        # matches_exact by case analysis on the row's JSON type
        lut = np.zeros(len(col.repr_dict) + 1, bool)
        for r, code in col.repr_index.items():
            x = json_number(r)
            lut[code + 1] = x is not None and range_contains(v, x)
        return lut[col.repr_codes + 1]
    if pred.kind is Kind.IN:
        # OR of per-element KEY_VALUE lowerings (matches_exact's IN is
        # the same OR of per-element KEY_VALUE semantics)
        m = np.zeros(col.present.shape, bool)
        for e in v:
            m |= eval_lowered(
                col, SimplePredicate(Kind.KEY_VALUE, pred.key, e))
        return m
    # KEY_VALUE: (v == value) OR (json_scalar(value) == json_scalar(v)),
    # masked by the bool-compatibility check
    compat = col.is_bool if isinstance(v, bool) else \
        (col.present & ~col.is_bool)
    rcode = col.repr_index.get(json_scalar(v), -2)
    m = col.repr_codes == rcode
    if v is None:
        m = m | (col.present & ~col.notnull)
    elif not isinstance(v, (bool, str)):
        # numeric direct equality (10 == 10.0 across int/float); skipped
        # when float64 would alias the query value itself
        if _f64_exact(v):
            m = m | (col.num_valid & (col.num == float(v)))
    # strings and bools are fully covered by the repr dictionary: a str
    # row's repr IS the string, a bool's repr is "true"/"false"
    return m & compat


_NUM_REPRS_CACHE: dict[float, frozenset] = {}
_NUM_REPRS_CACHE_CAP = 4096


def _num_reprs(fv: float) -> frozenset[str]:
    """Every ``json_scalar`` a num_valid row numerically equal to ``fv``
    can carry.

    An int row *v* with ``float(v) == fv`` round-trips exactly (that is
    the ``num_valid`` admission rule), so ``v == int(fv)`` and its repr
    is ``str(int(fv))``; a float row equal to ``fv`` is the same float64
    and shares ``json.dumps(fv)`` — except the signed zeros, which are
    float-equal with distinct dumps (0.0 and -0.0 hash alike and share
    one cache slot, whose set contains both dumps).  Memoized: zone-map
    checks call this once per (segment, clause) and the json round-trips
    dominate the probe cost on fresh point lookups.
    """
    global _NUM_REPRS_CACHE
    hit = _NUM_REPRS_CACHE.get(fv)
    if hit is not None:
        return hit
    cands = {json.dumps(fv)}
    if fv == 0.0:
        cands |= {"0", "0.0", "-0.0"}
    elif float(fv).is_integer():
        cands.add(str(int(fv)))
    out = frozenset(cands)
    if len(_NUM_REPRS_CACHE) >= _NUM_REPRS_CACHE_CAP:
        # fresh dict, never .clear(): concurrent readers (serve-plane
        # scan threads) may be probing the old one
        _NUM_REPRS_CACHE = {}
    _NUM_REPRS_CACHE[fv] = out
    return out


def term_possible_over(
    pred: SimplePredicate, *, any_notnull: bool,
    num_min: float, num_max: float, num_prunable: bool,
    strs, reprs,
) -> bool:
    """Compat wrapper: membership-only probe of the skipping registry.

    The single hardcoded refutation rule this function used to BE now
    lives in ``repro.core.skip_index.MembershipIndex``; callers holding
    only the legacy summary fields (no range bounds, no n-gram bloom)
    get exactly the old behavior — the newer indexes see their
    "no data" defaults (``rnum_prunable=False``, ``ngram=None``) and
    never refute.  Must be conservative: False only when provably no
    match.  ``strs``/``reprs`` are value-membership containers (dict or
    set), or ``None`` when the caller's value set SATURATED.  The caller
    handles the missing-key case (which refutes every kind).
    """
    return REGISTRY.term_possible(pred, KeyStats(
        any_notnull=any_notnull, num_min=num_min, num_max=num_max,
        num_prunable=num_prunable, strs=strs, reprs=reprs,
    ))


def column_stats(col: KeyColumn) -> KeyStats:
    """Registry probe view of one segment column (exact dictionaries,
    always-prunable range bounds)."""
    return KeyStats(
        any_notnull=col.any_notnull,
        num_min=col.num_min, num_max=col.num_max,
        num_prunable=col.num_prunable,
        strs=col.str_index, reprs=col.repr_index,
        rnum_min=col.rnum_min, rnum_max=col.rnum_max,
        rnum_prunable=True, ngram=col.ngram,
    )


def _term_possible(col: KeyColumn | None, pred: SimplePredicate) -> bool:
    """Zone-map check: can ``pred`` match ANY row of this segment?

    Every predicate kind requires the key to be present, so a missing
    column refutes every kind — including non-lowerable values.  Segment
    dictionaries are exact (never saturated), so membership refutation is
    always available here, and the segment-level range bounds and n-gram
    bloom are always populated (built at column-finish time).
    """
    if col is None:
        return False
    return REGISTRY.term_possible(pred, column_stats(col))


# ---------------------------------------------------------------------------
# the segment
# ---------------------------------------------------------------------------

_CLAUSE_CACHE_CAP = 128
_AND_CACHE_CAP = 64


class ColumnarSegment:
    """One compacted group of loaded rows in struct-of-arrays layout.

    Carries the same coverage metadata a loaded block used to (``epoch``
    names the plan the bitvector rows index, ``n_covered`` the coverage
    prefix, ``tier`` the producing family tier — DESIGN.md §12), plus:

      * ``bitvectors`` — packed ``uint32[n_covered, W]`` client clause
        bitvectors over the segment's rows (W = ceil(n_rows/32));
      * ``key_cols``   — per-key :class:`KeyColumn` bundles (zone maps
        included);
      * the raw JSON bytes of every row (blob + offsets), for zero-copy
        recipe streaming and the per-row exact fallback.

    Query-path results are memoized per segment: ANDed pushed-bitvector
    masks per pushed-row tuple, lowered clause masks and zone-map verdicts
    per clause (the "(query, epoch, coverage)" cache — a query resolves to
    exactly those keys).
    """

    def __init__(self, *, records: Sequence[bytes],
                 bitvectors: np.ndarray, epoch: int, n_covered: int,
                 tier: int, objs: Sequence[dict] | None = None,
                 eager_keys: "frozenset[str] | None" = None):
        self.n_rows = len(records)
        self.epoch = int(epoch)
        self.n_covered = int(n_covered)
        self.tier = int(tier)
        self.bitvectors = np.asarray(bitvectors, np.uint32)
        lens = np.fromiter((len(r) for r in records), np.int64,
                           count=len(records))
        self.raw_offsets = np.zeros(len(records) + 1, np.int64)
        np.cumsum(lens, out=self.raw_offsets[1:])
        self.raw_blob = np.frombuffer(b"".join(records), np.uint8)
        if objs is None:
            objs = [json.loads(r) for r in records]
        if eager_keys is None:
            self.key_cols = build_key_columns(objs)
            self.lazy_keys: frozenset[str] = frozenset()
        else:
            # Per-key layout policy (DESIGN.md §18): only the eager set is
            # columnarized up front; the rest stay raw until first touched.
            present: set[str] = set()
            for obj in objs:
                present.update(obj)
            self.key_cols = build_key_columns(objs, keys=present & eager_keys)
            self.lazy_keys = frozenset(present - eager_keys)
        self._lazy_lock = threading.Lock()
        self._clause_masks: dict[Clause, tuple] = {}
        self._possible: dict[Clause, bool] = {}
        self._and_masks: dict[tuple[int, ...], np.ndarray] = {}

    def key_col(self, key: str) -> KeyColumn | None:
        """Per-key column, materializing a lazy key on first touch.

        A key absent from ``key_cols`` AND ``lazy_keys`` is genuinely
        absent from every row (sound to refute).  A lazy key decodes the
        raw rows once under ``_lazy_lock`` (a racing reader either wins
        the lock and builds, or blocks and finds the column installed —
        never a lost update), installs into a FRESH dict (peers holding
        the old dict just retry via this method), and shrinks the lazy
        set last so a concurrent ``lazy_keys`` probe stays conservative.
        """
        col = self.key_cols.get(key)
        if col is not None or key not in self.lazy_keys:
            return col
        with self._lazy_lock:
            col = self.key_cols.get(key)
            if col is not None:
                return col
            built = build_key_columns(self.rows, keys={key}).get(key)
            cols = dict(self.key_cols)
            if built is not None:
                cols[key] = built
            self.key_cols = cols
            self.lazy_keys = self.lazy_keys - {key}
            return built

    # -- raw bytes -----------------------------------------------------------
    def record(self, i: int) -> bytes:
        o = self.raw_offsets
        return self.raw_blob[o[i]:o[i + 1]].tobytes()

    def records(self) -> list[bytes]:
        return [self.record(i) for i in range(self.n_rows)]

    @property
    def rows(self) -> list[dict]:
        """Parsed row objects (decoded fresh — differential/test use only)."""
        return [json.loads(self.record(i)) for i in range(self.n_rows)]

    def plane_nbytes(self, k_cap: int) -> int:
        """Device bytes this segment occupies in a resident plane with
        ``k_cap`` key rows (DESIGN.md §15): four uint8 masks + two int32
        code columns per key row, plus the int32 slot id and uint32
        clause word per row."""
        return self.n_rows * (k_cap * (4 * 1 + 2 * 4) + 8)

    # -- pushed-bitvector candidates ----------------------------------------
    def pushed_mask(self, pushed: Sequence[int],
                    and_reduce: Callable | None = None) -> np.ndarray:
        """bool[n]: AND of the pushed clauses' bitvector rows (memoized).

        The memo caches here and in :meth:`clause_possible` /
        :meth:`clause_mask` are safe under concurrent readers (segments
        are shared between the live store and its snapshots, DESIGN.md
        §17): entries are pure functions of immutable segment state, so
        a racing recompute stores an identical value, and eviction swaps
        in a fresh dict rather than clearing the one a peer may hold.
        """
        key = tuple(pushed)
        m = self._and_masks.get(key)
        if m is None:
            reduce = and_reduce or bitvector.bv_and_many
            words = reduce(self.bitvectors[list(key)])
            m = bitvector.unpack(words, self.n_rows)
            if len(self._and_masks) >= _AND_CACHE_CAP:
                self._and_masks = {}
            self._and_masks[key] = m
        return m

    # -- zone maps -----------------------------------------------------------
    def clause_possible(self, c: Clause) -> bool:
        """False iff the zone map proves no row can match clause ``c``."""
        p = self._possible.get(c)
        if p is None:
            p = any(_term_possible(self.key_col(t.key), t)
                    for t in c.terms)
            if len(self._possible) >= _CLAUSE_CACHE_CAP:
                self._possible = {}
            self._possible[c] = p
        return p

    # -- vectorized clause evaluation ---------------------------------------
    def clause_mask(self, c: Clause
                    ) -> tuple[np.ndarray, tuple[SimplePredicate, ...]]:
        """(bool[n] exact OR over lowerable terms, non-lowerable leftovers).

        The mask is memoized and must not be mutated by callers; leftover
        terms need the per-row fallback (``matches_exact`` on the parsed
        raw bytes) for rows the mask leaves False.
        """
        hit = self._clause_masks.get(c)
        if hit is None:
            mask = np.zeros(self.n_rows, bool)
            leftover = []
            for t in c.terms:
                if not lowerable(t):
                    leftover.append(t)
                    continue
                col = self.key_col(t.key)
                if col is not None:
                    mask |= eval_lowered(col, t)
            hit = (mask, tuple(leftover))
            if len(self._clause_masks) >= _CLAUSE_CACHE_CAP:
                self._clause_masks = {}
            self._clause_masks[c] = hit
        return hit


def query_mask(seg: ColumnarSegment, q: Query,
               pushed: Sequence[int] = (),
               and_reduce: Callable | None = None) -> np.ndarray | None:
    """Exact per-row match mask for ``q`` over one segment.

    Returns ``None`` when the zone map prunes the whole segment (some
    query clause provably matches no row), else ``bool[n_rows]`` with
    EXACTLY the rows ``q.matches_exact`` accepts:

      1. zone-map prune on every clause (cheap set/range checks);
      2. AND the pushed clauses' client bitvectors (sound candidate set —
         clients never produce false negatives);
      3. vectorized exact evaluation of every clause over whole columns,
         with a per-row raw-bytes fallback for non-lowerable terms.

    The returned mask may alias a memoized per-clause mask (the common
    single-residual-clause case skips a whole-segment ones-AND round
    trip); callers must treat it as read-only.
    """
    for c in q.clauses:
        if not seg.clause_possible(c):
            return None
    # candidate mask, built lazily: None means "every row" so the common
    # single-clause unpushed probe never allocates or ANDs a ones-mask
    m = seg.pushed_mask(pushed, and_reduce) if pushed else None
    for c in q.clauses:
        cm, leftover = seg.clause_mask(c)
        if leftover:
            need = ~cm if m is None else m & ~cm
            if need.any():
                cm = cm.copy()
                for i in np.nonzero(need)[0]:
                    obj = json.loads(seg.record(i))
                    if any(t.matches_exact(obj) for t in leftover):
                        cm[i] = True
        m = cm if m is None else m & cm
        if not m.any():
            break
    if m is None:  # zero-clause query: every row matches
        m = np.ones(seg.n_rows, bool)
    return m


# ---------------------------------------------------------------------------
# builders: per-coverage-group compaction at ingest
# ---------------------------------------------------------------------------

@dataclass
class SegmentBuilder:
    """Accumulates loaded chunks of ONE ``(epoch, n_covered, tier)`` group.

    Ingest appends parsed chunk rows; when the builder crosses
    ``capacity`` rows it seals into a :class:`ColumnarSegment` (so sealed
    segments hold ``[capacity, capacity + chunk)`` rows — large enough to
    amortize per-segment Python overhead).  ``view()`` materializes the
    open tail as a segment for the query path, cached until the next
    append, so scans between ingests pay the column build once.
    """

    epoch: int
    n_covered: int
    tier: int
    capacity: int = 8192
    touch_seq: int = 0
    eager_keys: "frozenset[str] | None" = None

    def __post_init__(self) -> None:
        self._records: list[bytes] = []
        self._objs: list[dict] = []
        self._bits: list[np.ndarray] = []   # bool[n_covered, k] per chunk
        self._view: ColumnarSegment | None = None

    @property
    def n_rows(self) -> int:
        return len(self._records)

    def add(self, records: Sequence[bytes], objs: Sequence[dict],
            bits: np.ndarray) -> list[ColumnarSegment]:
        """Append one chunk's loaded rows; returns newly sealed segments."""
        if bits.shape != (self.n_covered, len(records)):
            raise ValueError(
                f"bits shape {bits.shape} != ({self.n_covered}, "
                f"{len(records)})")
        self._view = None
        self._records.extend(records)
        self._objs.extend(objs)
        self._bits.append(np.asarray(bits, bool))
        if len(self._records) >= self.capacity:
            return [self.seal()]
        return []

    def _build(self) -> ColumnarSegment:
        n = len(self._records)
        if self._bits:
            bits = np.concatenate(self._bits, axis=1)
        else:
            bits = np.zeros((self.n_covered, n), bool)
        return ColumnarSegment(
            records=self._records, objs=self._objs,
            bitvectors=bitvector.pack(bits) if n else
            np.zeros((self.n_covered, 0), np.uint32),
            epoch=self.epoch, n_covered=self.n_covered, tier=self.tier,
            eager_keys=self.eager_keys,
        )

    def view(self) -> ColumnarSegment:
        """Query-path view of the open tail (cached until the next add)."""
        if self._view is None:
            self._view = self._build()
        return self._view

    def seal(self) -> ColumnarSegment:
        """Finalize and reset the builder."""
        seg = self._build()
        self._records, self._objs, self._bits = [], [], []
        self._view = None
        return seg


def build_segments(records: Sequence[bytes], bits: np.ndarray, *,
                   epoch: int, n_covered: int, tier: int,
                   capacity: int = 8192,
                   objs: Sequence[dict] | None = None
                   ) -> list[ColumnarSegment]:
    """Chop one row batch into capacity-bounded segments (JIT promotion,
    checkpoint restore)."""
    out = []
    n = len(records)
    for lo in range(0, max(n, 1), capacity):
        hi = min(lo + capacity, n)
        if hi <= lo:
            break
        out.append(ColumnarSegment(
            records=records[lo:hi],
            objs=None if objs is None else objs[lo:hi],
            bitvectors=bitvector.pack(bits[:, lo:hi]) if bits.size else
            np.zeros((bits.shape[0], bitvector.num_words(hi - lo)),
                     np.uint32),
            epoch=epoch, n_covered=n_covered, tier=tier,
        ))
    return out


def segment_from_packed(records: Sequence[bytes], words: np.ndarray, *,
                        epoch: int, n_covered: int, tier: int,
                        objs: Sequence[dict] | None = None
                        ) -> ColumnarSegment:
    """Rebuild one segment from checkpointed raw bytes + packed words."""
    return ColumnarSegment(
        records=records, bitvectors=np.asarray(words, np.uint32),
        epoch=epoch, n_covered=n_covered, tier=tier, objs=objs,
    )


def decode_rows(data: np.ndarray, lengths: np.ndarray,
                idx: np.ndarray | None = None,
                objs: Sequence[dict] | None = None
                ) -> tuple[list[bytes], list[dict]]:
    """Batch-decode dense chunk rows: ONE fancy-indexed copy, then slices.

    Replaces the per-row ``chunk.record(i)`` bytes copies on the ingest
    parse path: the selected sub-array is materialized once
    (``tobytes``), record bytes are cheap slices of that buffer, and the
    parsed objects feed the columnar builder directly.  ``objs`` supplies
    already-parsed row objects aligned to the FULL ``data`` (the sharded
    ingest path parses every row once for routing) so the selected rows
    skip the second ``json.loads``.
    """
    if idx is not None:
        data = data[idx]
        lengths = lengths[idx]
        if objs is not None:
            objs = [objs[int(i)] for i in idx]
    n, stride = data.shape
    buf = np.ascontiguousarray(data).tobytes()
    records = [buf[k * stride: k * stride + int(lengths[k])]
               for k in range(n)]
    if objs is None:
        objs = [json.loads(r) for r in records]
    return records, list(objs)
