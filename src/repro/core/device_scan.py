"""Device-resident COUNT(*) scanners (DESIGN.md §15).

:class:`DeviceScanner` is the drop-in device counterpart of
:class:`~repro.core.server.DataSkippingScanner`: same ``scan(q) ->
ScanResult`` contract, bit-identical counts and per-(epoch, tier)
accounting, plus ``scan_batch`` — N queries compiled together
(:func:`~repro.kernels.scan_fused.compile_scan_batch`) and evaluated in
ONE device launch over the resident segment plane.  The division of
labor per scan:

  host   — pushdown resolution (``store.pushed_by_epoch``), raw
           promotion, zone-prune verdicts (memoized
           ``ColumnarSegment.clause_possible``), parameter tables;
  device — pushed-bitvector AND, lowered residual eval, per-(query,
           slot) popcount for every cached segment, all queries fused;
  host   — fold device counts + host-fallback segments (open builder
           tails, evicted/oversized segments, non-lowerable queries —
           scanned by the embedded ``DataSkippingScanner``) into the
           standard accounting.

:class:`ShardedDeviceScanner` mirrors
:class:`~repro.core.shard.ShardedScanner`'s three-level cascade
(partition prune -> per-shard scan -> deterministic
``merge_scan_results`` through ``dist.collectives.tree_reduce``) with a
per-shard :class:`~repro.core.device_cache.DeviceSegmentCache`.  When
every surviving shard can own a jax device
(``dist.sharding.scan_mesh``), the per-shard launches collapse into one
``shard_map`` SPMD program over a ``("shards",)`` mesh — shard planes
are padded to common buckets, stacked, and each device evaluates its
own shard's rows; otherwise shards launch sequentially with identical
results.

Public contract, shared with every other scanner: ``ScanResult.groups``
sorted by (epoch, tier), deterministic merge order, accounting
bit-identical to the host ``DataSkippingScanner``.  Since DESIGN.md §16
a :class:`~repro.core.batch_scan.ResultCache` can be attached (distinct
from the segment cache: it stores finished ``ScanResult`` objects keyed on
type-strict predicates, validated per ``(epoch, data_version)``) and
every scan is folded into the store's
:class:`~repro.core.telemetry.TelemetryPlane`.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.device_cache import (
    CacheSlot, DeviceSegmentCache, _grow1, _grow2,
)
from repro.core.predicates import Query
from repro.core.server import CiaoStore, DataSkippingScanner, ScanResult
from repro.core.shard import ShardedCiaoStore, merge_scan_results
from repro.dist.sharding import scan_mesh
from repro.kernels.scan_fused import (
    DevicePlaneArrays, ScanBatch, ScanParams,
    compile_scan_batch, scan_core_numpy, scan_core_xla, scan_counts,
)


@dataclass
class _Prepared:
    """Host-side launch state for one store's query batch."""

    queries: tuple[Query, ...]
    batch: ScanBatch
    pushed_maps: list
    promoted: list[dict]
    jit_vis: list[int]        # per-query visible jit-segment prefix
    slots: list[CacheSlot]
    pushed_bits: np.ndarray   # uint32[Q, S]
    active: np.ndarray        # uint8[Q, S]
    pruned: np.ndarray        # bool[Q, S] zone map refuted a clause
    params: ScanParams | None  # None when no device launch is needed


class DeviceScanner:
    """Device-plane scanner over a single :class:`CiaoStore`."""

    def __init__(self, store: CiaoStore, *, backend: str = "xla",
                 byte_budget: int = 256 << 20, log_queries: bool = True,
                 r_blk: int = 512, result_cache: "object | None" = None,
                 telemetry: "object | bool | None" = None,
                 tenant: str = "default"):
        self.store = store
        self.backend = backend
        self.log_queries = log_queries
        self.r_blk = r_blk
        self.cache = DeviceSegmentCache(byte_budget=byte_budget)
        # optional core.batch_scan.ResultCache — NOT the segment cache
        # above: entries are whole per-query ScanResults under the same
        # (shard 0, clauses) keys and (epoch, data_version) validity the
        # host batcher and ShardedScanner use, so host and device paths
        # share one cache and one accounting contract (DESIGN.md §16)
        self.result_cache = result_cache
        from repro.core.telemetry import TelemetryPlane
        if telemetry is None:
            telemetry = getattr(store, "telemetry", None)
        self.telemetry = telemetry if isinstance(telemetry, TelemetryPlane) \
            else None
        self.tenant = tenant
        self._synced_version = -1
        # backend="numpy" baseline: host mirror of the plane, converted
        # once per plane generation (not per scan)
        self._np_plane = None
        self._np_plane_src = None
        # host fallback for open tails / evicted segments / non-lowerable
        # queries; shares the store, so memoized segment state is shared
        self._host = DataSkippingScanner(store, log_queries=False,
                                         telemetry=False)

    # -- public API ---------------------------------------------------------

    def scan(self, q: Query) -> ScanResult:
        return self.scan_batch([q])[0]

    def scan_batch(self, queries: Sequence[Query]) -> list[ScanResult]:
        """All queries in one launch; results bit-identical to sequential
        ``DataSkippingScanner.scan`` calls in the same order.

        With a ``result_cache`` attached, each query consults it in batch
        order (a hit skips the query's promotion step — valid entries
        imply a re-scan would promote nothing) and misses are compiled
        into one launch; fresh results are stored at the post-batch
        ``data_version``.
        """
        t0 = time.perf_counter()
        store = self.store
        queries = tuple(queries)
        if self.log_queries:
            for q in queries:
                store.log_query(q)
        hits: dict[int, ScanResult] = {}
        miss: list[int] = []
        pushed_maps: list = []
        promoted: list[dict] = []
        jit_vis: list[int] = []
        for qi, q in enumerate(queries):
            if self.result_cache is not None:
                r = self.result_cache.lookup(
                    0, q, epoch=store.plan.epoch,
                    data_version=store.data_version)
                if r is not None:
                    hits[qi] = r
                    continue
            pm = store.pushed_by_epoch(q)
            pushed_maps.append(pm)
            promoted.append(dict(store.promote_uncovered_raw(pm)))
            jit_vis.append(len(store.jit_blocks))
            miss.append(qi)
        by_pos: dict[int, ScanResult] = dict(hits)
        if miss:
            prep = self._prepare(
                [queries[qi] for qi in miss], pushed_maps=pushed_maps,
                promoted=promoted, jit_vis=jit_vis)
            counts, cands = self._launch(prep)
            for qi, r in zip(miss, self._assemble(prep, counts, cands)):
                by_pos[qi] = r
                if self.result_cache is not None:
                    self.result_cache.store(
                        0, queries[qi], r, epoch=store.plan.epoch,
                        data_version=store.data_version)
        results = [by_pos[qi] for qi in range(len(queries))]
        dt = time.perf_counter() - t0
        for qi, r in enumerate(results):
            r.time_s = dt / max(len(results), 1)
            if self.telemetry is not None:
                self.telemetry.record_scan(
                    r, tenant=self.tenant,
                    cache_hits=int(qi in hits),
                    cache_misses=int(self.result_cache is not None
                                     and qi not in hits))
        return results

    # -- pipeline stages (ShardedDeviceScanner drives these directly) ------

    def _prepare(self, queries: Sequence[Query], *,
                 pushed_maps: list | None = None,
                 promoted: list[dict] | None = None,
                 jit_vis: list[int] | None = None) -> _Prepared:
        store = self.store
        queries = tuple(queries)
        if pushed_maps is None:
            pushed_maps = [store.pushed_by_epoch(q) for q in queries]
        if promoted is None or jit_vis is None:
            # promote raw remainders FIRST (same rows, same order as the
            # sequential host scans), so the promoted segments are
            # admitted by this very sync.  ``jit_vis`` snapshots the
            # jit-segment list length after each query's promotion: query
            # *i* of the batch must account exactly the jit segments a
            # sequential run would have materialized by its turn, not the
            # whole batch's promotions.  (The sharded executor passes
            # these in precomputed — promotions there interleave with
            # pruned-shard snapshots in global query order.)
            promoted, jit_vis = [], []
            for pm in pushed_maps:
                promoted.append(dict(store.promote_uncovered_raw(pm)))
                jit_vis.append(len(store.jit_blocks))
        version = getattr(store, "data_version", None)
        if version is None or version != self._synced_version:
            self.cache.sync(store)
            if version is not None:
                self._synced_version = version
        batch = compile_scan_batch(queries)
        slots = list(self.cache.slots)
        Q, S = len(queries), len(slots)
        pushed_bits = np.zeros((Q, S), np.uint32)
        active = np.zeros((Q, S), np.uint8)
        pruned = np.zeros((Q, S), bool)
        for si, slot in enumerate(slots):
            seg = slot.seg
            for qi, q in enumerate(queries):
                if not batch.query_ok[qi]:
                    continue   # whole query falls back to the host path
                pushed = pushed_maps[qi][(seg.epoch, seg.n_covered)]
                if slot.is_jit:
                    if pushed:
                        continue   # skipped whole by the assembly stage
                elif pushed:
                    bits = np.uint32(0)
                    for p in pushed:
                        bits |= np.uint32(1) << np.uint32(p)
                    pushed_bits[qi, si] = bits
                if any(not seg.clause_possible(c) for c in q.clauses):
                    pruned[qi, si] = True
                    continue
                active[qi, si] = 1
        params = None
        if S and active.any():
            params = self.cache.build_params(
                batch, pushed_bits=pushed_bits, active=active)
            self.cache.touch(
                [si for si in range(S) if active[:, si].any()])
        return _Prepared(
            queries=queries, batch=batch, pushed_maps=pushed_maps,
            promoted=promoted, jit_vis=jit_vis, slots=slots,
            pushed_bits=pushed_bits, active=active, pruned=pruned,
            params=params,
        )

    def _launch(self, prep: _Prepared):
        if prep.params is None:
            return None, None
        plane = self.cache.plane
        assert plane is not None
        if self.backend == "numpy":
            if self._np_plane_src is not plane.pres:
                self._np_plane = tuple(np.asarray(a) for a in plane)
                self._np_plane_src = plane.pres
            return scan_core_numpy(*self._np_plane, prep.params)
        return scan_counts(plane, prep.params, backend=self.backend,
                           r_blk=self.r_blk)

    def _assemble(self, prep: _Prepared, counts, cands) -> list[ScanResult]:
        store = self.store
        slot_of = {id(s.seg): i for i, s in enumerate(prep.slots)}
        results: list[ScanResult] = []
        for qi, q in enumerate(prep.queries):
            pm = prep.pushed_maps[qi]
            use_device = prep.batch.query_ok[qi]
            result = ScanResult(count=0, rows_scanned=0, rows_skipped=0,
                                raw_parsed=0, time_s=0.0,
                                used_skipping=False)

            def eat(seg, g, si):
                if prep.pruned[qi, si]:
                    g.rows_skipped += seg.n_rows
                    g.segments_pruned += 1
                    result.segments_pruned += 1
                    return
                cand = int(cands[qi, si])
                g.rows_scanned += cand
                g.rows_skipped += seg.n_rows - cand
                g.count += int(counts[qi, si])
                result.segments_scanned += 1

            for seg in store.blocks:
                g = result.group(seg.epoch, seg.tier)
                si = slot_of.get(id(seg)) if use_device else None
                if si is None:
                    self._host._scan_segment(
                        seg, q, pm[(seg.epoch, seg.n_covered)], g, result)
                else:
                    eat(seg, g, si)
            for key, n in prep.promoted[qi].items():
                result.group(*key).raw_parsed += n
            for seg in store.jit_blocks[:prep.jit_vis[qi]]:
                g = result.group(seg.epoch, seg.tier)
                if pm[(seg.epoch, seg.n_covered)]:
                    g.rows_skipped += seg.n_rows
                    continue
                si = slot_of.get(id(seg)) if use_device else None
                if si is None:
                    self._host._scan_segment(seg, q, (), g, result)
                else:
                    eat(seg, g, si)
            result.sort_groups()
            for g in result.groups.values():
                result.count += g.count
                result.rows_scanned += g.rows_scanned
                result.rows_skipped += g.rows_skipped
                result.raw_parsed += g.raw_parsed
            result.used_skipping = any(pm.values())
            results.append(result)
        return results


# ---------------------------------------------------------------------------
# sharded scatter-gather
# ---------------------------------------------------------------------------

def _pad_params(p: ScanParams, T: int, C: int, Q: int, S1: int,
                L: int) -> ScanParams:
    """Pad one shard's tables to common SPMD buckets (inert fills)."""

    def pad(a, shape, fill):
        if a.shape == shape:
            return a
        out = np.full(shape, fill, a.dtype)
        out[tuple(slice(0, d) for d in a.shape)] = a
        return out

    return ScanParams(
        key_ids=pad(p.key_ids, (T,), 0),
        kinds=pad(p.kinds, (T,), -1),
        code_a=pad(p.code_a, (T, S1), -2),
        num_codes=pad(p.num_codes, (T, 3, S1), -2),
        lut_off=pad(p.lut_off, (T, S1), -1),
        lut_flat=pad(p.lut_flat, (L,), 0),
        is_null=pad(p.is_null, (T,), 0),
        is_boolv=pad(p.is_boolv, (T,), 0),
        membership=pad(p.membership, (C, T), 0),
        query_clause=pad(p.query_clause, (Q, C), 0),
        pushed_tbl=pad(p.pushed_tbl, (Q, S1), 0),
        active=pad(p.active, (Q, S1), 0),
    )


def _pad_plane(pl: DevicePlaneArrays, K: int, N: int) -> DevicePlaneArrays:
    if pl.pres.shape == (K, N):
        return pl
    return DevicePlaneArrays(
        pres=_grow2(pl.pres, k=K, n=N, fill=0),
        notn=_grow2(pl.notn, k=K, n=N, fill=0),
        isb=_grow2(pl.isb, k=K, n=N, fill=0),
        numv=_grow2(pl.numv, k=K, n=N, fill=0),
        scod=_grow2(pl.scod, k=K, n=N, fill=-1),
        rcod=_grow2(pl.rcod, k=K, n=N, fill=-1),
        sid=_grow1(pl.sid, n=N, fill=-1),
        cw=_grow1(pl.cw, n=N, fill=0),
    )


def _spmd_counts(planes: list[DevicePlaneArrays],
                 params: list[ScanParams], mesh) -> list[tuple]:
    """One ``shard_map`` program: shard i of the stacked inputs lands on
    device i and runs the fused scan over its own plane."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    K = max(pl.pres.shape[0] for pl in planes)
    N = max(pl.pres.shape[1] for pl in planes)
    T = max(p.kinds.shape[0] for p in params)
    C = max(p.membership.shape[0] for p in params)
    Q = max(p.query_clause.shape[0] for p in params)
    S1 = max(p.pushed_tbl.shape[1] for p in params)
    L = max(p.lut_flat.shape[0] for p in params)
    planes = [_pad_plane(pl, K, N) for pl in planes]
    params = [_pad_params(p, T, C, Q, S1, L) for p in params]
    stacked_plane = [jnp.stack(x) for x in zip(*planes)]
    stacked_params = [np.stack(x) for x in zip(*params)]
    spec = P("shards")

    def one(*args):
        c, d = scan_core_xla(*(a[0] for a in args))
        return c[None], d[None]

    run = shard_map(one, mesh=mesh,
                    in_specs=tuple(spec for _ in range(20)),
                    out_specs=(spec, spec))
    counts, cands = jax.jit(run)(*stacked_plane, *stacked_params)
    counts, cands = np.asarray(counts), np.asarray(cands)
    return [(counts[i], cands[i]) for i in range(len(planes))]


class ShardedDeviceScanner:
    """Scatter-gather device scan over a :class:`ShardedCiaoStore`.

    Bit-identical to :class:`~repro.core.shard.ShardedScanner`: empty
    shards contribute nothing, partition-refuted shards contribute their
    resident segment rows as skipped (and never promote), surviving
    shards scan on their device plane, and the per-shard results reduce
    deterministically through ``merge_scan_results``.
    """

    def __init__(self, store: ShardedCiaoStore, *, backend: str = "xla",
                 byte_budget: int = 256 << 20, log_queries: bool = True,
                 r_blk: int = 512, spmd: bool | None = None,
                 telemetry: "object | bool | None" = None,
                 tenant: str = "default"):
        self.store = store
        self.log_queries = log_queries
        from repro.core.telemetry import TelemetryPlane
        if telemetry is None:
            telemetry = getattr(store, "telemetry", None)
        self.telemetry = telemetry if isinstance(telemetry, TelemetryPlane) \
            else None
        self.tenant = tenant
        self._scanners = [
            DeviceScanner(s, backend=backend, byte_budget=byte_budget,
                          log_queries=False, r_blk=r_blk, telemetry=False)
            for s in store.shards
        ]
        # None = auto: engage iff a ("shards",) mesh fits the device count
        self.spmd = spmd

    @property
    def caches(self) -> list[DeviceSegmentCache]:
        return [sc.cache for sc in self._scanners]

    def scan(self, q: Query) -> ScanResult:
        return self.scan_batch([q])[0]

    def scan_batch(self, queries: Sequence[Query]) -> list[ScanResult]:
        t0 = time.perf_counter()
        store = self.store
        queries = tuple(queries)
        if self.log_queries:
            for q in queries:
                store.log_query(q)
        # per-shard surviving query subsets (partition prune, level 1)
        sub: list[list[int]] = []
        pruned_shards: list[list[int]] = [[] for _ in queries]
        for s in range(store.n_shards):
            shard = store.shards[s]
            if not (shard.stats.n_records or shard.blocks
                    or shard.jit_blocks or shard.raw):
                sub.append([])
                continue
            qs: list[int] = []
            for qi, q in enumerate(queries):
                if store.n_shards > 1 and \
                        not store.summaries[s].query_possible(q):
                    pruned_shards[qi].append(s)
                else:
                    qs.append(qi)
            sub.append(qs)
        # promotions and pruned-shard row snapshots in GLOBAL query
        # order: sequential scatter-gather scans run query i across every
        # shard before query i+1, so a shard pruned for query i accounts
        # its resident rows BEFORE later queries' promotions enlarge them
        pushed_maps: list[list] = [[] for _ in range(store.n_shards)]
        promoted: list[list[dict]] = [[] for _ in range(store.n_shards)]
        jit_vis: list[list[int]] = [[] for _ in range(store.n_shards)]
        pruned_rows: dict[tuple[int, int], dict] = {}
        for qi, q in enumerate(queries):
            for s in range(store.n_shards):
                shard = store.shards[s]
                if qi in sub[s]:
                    pm = shard.pushed_by_epoch(q)
                    pushed_maps[s].append(pm)
                    promoted[s].append(dict(shard.promote_uncovered_raw(pm)))
                    jit_vis[s].append(len(shard.jit_blocks))
                elif s in pruned_shards[qi]:
                    pruned_rows[(qi, s)] = shard.resident_group_rows()
        prepared: dict[int, _Prepared] = {}
        for s, qs in enumerate(sub):
            if qs:
                prepared[s] = self._scanners[s]._prepare(
                    [queries[qi] for qi in qs],
                    pushed_maps=pushed_maps[s], promoted=promoted[s],
                    jit_vis=jit_vis[s])
        launch = {s: p for s, p in prepared.items() if p.params is not None}
        outputs: dict[int, tuple] = {}
        mesh = None
        if self.spmd is not False and len(launch) >= 2:
            mesh = scan_mesh(len(launch))
        if mesh is not None and all(
                sc.backend == "xla" for sc in self._scanners):
            order = sorted(launch)
            per = _spmd_counts(
                [self._scanners[s].cache.plane for s in order],
                [launch[s].params for s in order], mesh)
            outputs = dict(zip(order, per))
        else:
            for s, p in launch.items():
                outputs[s] = self._scanners[s]._launch(p)
        shard_results: dict[int, list[ScanResult]] = {}
        for s, p in prepared.items():
            c, d = outputs.get(s, (None, None))
            shard_results[s] = self._scanners[s]._assemble(p, c, d)
        out: list[ScanResult] = []
        dt = time.perf_counter() - t0
        for qi, q in enumerate(queries):
            results: list[ScanResult] = []
            for s in sorted(prepared):
                if qi in sub[s]:
                    r = shard_results[s][sub[s].index(qi)]
                    r.shards_scanned = 1
                    results.append(r)
            if results:
                merged = merge_scan_results(results)
            else:
                merged = ScanResult(count=0, rows_scanned=0,
                                    rows_skipped=0, raw_parsed=0,
                                    time_s=0.0, used_skipping=False)
            for s in pruned_shards[qi]:
                merged.shards_pruned += 1
                for (e, t), n in pruned_rows[(qi, s)].items():
                    merged.group(e, t).rows_skipped += n
                    merged.rows_skipped += n
            if pruned_shards[qi]:
                merged.sort_groups()
            if not results:
                merged.used_skipping = any(
                    store.pushed_by_epoch(q).values())
            merged.time_s = dt / max(len(queries), 1)
            if self.telemetry is not None:
                self.telemetry.record_scan(merged, tenant=self.tenant)
            out.append(merged)
        return out
