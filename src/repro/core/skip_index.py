"""Pluggable skipping-index registry (DESIGN.md §19).

Both pruning levels of the cascade — per-segment zone maps
(``repro.core.columnar``) and per-shard partition summaries
(``repro.core.shard.ShardSummary``) — used to share ONE hardcoded
refutation rule, ``term_possible_over``.  This module generalizes it to a
registry of *skipping indexes*, each declaring:

  * ``handles(pred)``   — which predicate kinds it can refute;
  * ``probe(pred, stats)`` — the conservative refutation itself (``False``
    only when PROVABLY no summarized row matches);
  * ``selectivity(pred)`` — a workload-free prior consumed by the CELF
    selection path (``tiered_celf`` via ``estimate_selectivities``) and
    the Replanner when no sample records are available;
  * ``build_cost_per_row`` — relative maintenance cost, surfaced in docs
    and stats so physical-design tooling can weigh index choices;
  * ``summary_to_obj``/``summary_from_obj`` — its slice of the checkpoint
    summary encoding (format-6 manifests; format-5 files simply lack the
    new fields and deserialize to "cannot refute" defaults).

The composition rule is conjunctive: a predicate is *possible* iff EVERY
index that handles it says possible (each probe is independently sound,
so their intersection is too); a predicate no index handles is always
possible.  Registered indexes:

``membership``
    The original rule — key presence, exact string/repr value-set
    membership (saturating past ``SUMMARY_VALUE_CAP`` at shard level),
    numeric min/max with NaN poisoning, and the PR-5 saturated-repr
    cross-representation guard.  Handles EXACT / SUBSTRING /
    KEY_PRESENCE / KEY_VALUE / IN (an IN list is possible iff ANY element
    is).

``range``
    RANGE predicates against dedicated *range bounds* ``rnum_min`` /
    ``rnum_max`` folded over every value the RANGE semantics can match:
    numeric rows (bool excluded) and strings parsing as JSON numbers via
    ``json_number`` — the exact same value universe ``range_contains``
    accepts, so the cross-representation trap cannot recur.  NaN never
    matches a range, so (unlike the membership zone map) NaN rows do not
    poison these bounds; non-float64-exact values fold with one-ulp
    widening (``conservative_bounds``), keeping refutation sound for
    huge ints.  Inclusivity is ignored (bounds treated closed): at worst
    one fewer refutation, never an unsound one.

``ngram``
    A tiny bloom filter over the byte-level 3-grams of every string
    value.  If ``needle in row_string`` then every 3-gram of the
    needle's UTF-8 encoding appears in the row string's encoding (UTF-8
    substring closure), so a SUBSTRING — or string-valued EXACT — probe
    whose grams are not all present can refute without evaluation.
    Unlike the value sets the bloom never saturates, which is what makes
    shard-level SUBSTRING pruning work past ``SUMMARY_VALUE_CAP``.
    Needles shorter than 3 bytes have no grams and are never refuted.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import numpy as np

from .predicates import (
    Clause, Kind, SimplePredicate, json_number, json_scalar,
)

NGRAM_N = 3
_BLOOM_WORDS = 16          # 16 x uint64 = 1024 bits
_BLOOM_BITS = _BLOOM_WORDS * 64


def _gram_buckets(g: bytes) -> tuple[int, int]:
    """Two deterministic bucket indices for one 3-byte gram."""
    x = int.from_bytes(g, "big")
    h1 = (x * 2654435761) & 0xFFFFFFFF
    h2 = (x * 0x9E3779B1 + 0x7F4A7C15) & 0xFFFFFFFF
    return h1 % _BLOOM_BITS, h2 % _BLOOM_BITS


class NGramBloom:
    """1024-bit bloom filter over byte-level 3-grams of string values.

    Monotone-permissive like every other summary field (bits only get
    set), so the shard-level concurrency argument carries over; reads of
    a torn update can only fail to refute.
    """

    __slots__ = ("bits",)

    def __init__(self, bits: np.ndarray | None = None):
        self.bits = (np.zeros(_BLOOM_WORDS, np.uint64)
                     if bits is None else np.asarray(bits, np.uint64))

    def add(self, s: str) -> None:
        b = s.encode("utf-8")
        bits = self.bits
        for i in range(len(b) - NGRAM_N + 1):
            for idx in _gram_buckets(b[i:i + NGRAM_N]):
                bits[idx >> 6] |= np.uint64(1 << (idx & 63))

    def might_contain(self, needle: str) -> bool:
        """False only when NO summarized string can contain ``needle``."""
        b = needle.encode("utf-8")
        if len(b) < NGRAM_N:
            return True   # no grams to probe: cannot refute
        bits = self.bits
        for i in range(len(b) - NGRAM_N + 1):
            for idx in _gram_buckets(b[i:i + NGRAM_N]):
                if not (bits[idx >> 6] >> np.uint64(idx & 63)) & np.uint64(1):
                    return False
        return True

    def union(self, other: "NGramBloom") -> None:
        self.bits |= other.bits

    def to_hex(self) -> str:
        return self.bits.tobytes().hex()

    @classmethod
    def from_hex(cls, h: str) -> "NGramBloom":
        return cls(np.frombuffer(bytes.fromhex(h), np.uint64).copy())


def conservative_bounds(x) -> tuple[float, float]:
    """Float64 interval guaranteed to contain the exact numeric ``x``.

    Exact-representable values collapse to a point; anything float64
    would round (huge ints, >53-bit ints) widens one ulp each way, and
    values beyond float64 range clamp to the infinity on their side —
    so folding these bounds into a zone map can never exclude ``x``.
    """
    try:
        f = float(x)
    except (OverflowError, ValueError):
        return (np.inf, np.inf) if x > 0 else (-np.inf, -np.inf)
    if f == x:
        return (f, f)
    return (float(np.nextafter(f, -np.inf)), float(np.nextafter(f, np.inf)))


def range_fold_value(v) -> "int | float | None":
    """The numeric a row value contributes to the RANGE bounds, or None.

    Mirrors :func:`repro.core.predicates.range_contains` exactly: bool
    and None never match any range (no contribution), numerics
    contribute themselves (NaN skipped — it matches no range), strings
    contribute their ``json_number`` parse when they have one.
    """
    if isinstance(v, bool) or v is None:
        return None
    if isinstance(v, (int, float)):
        return None if v != v else v
    if isinstance(v, str):
        x = json_number(v)
        return None if x is None or x != x else x
    return None


@dataclass
class KeyStats:
    """Everything the registry may probe about one key's summarized rows.

    Built from either a segment :class:`~repro.core.columnar.KeyColumn`
    (exact dictionaries) or a shard ``_KeySummary`` (saturating sets).
    ``strs``/``reprs`` are membership containers (dict or set) or ``None``
    when saturated; ``rnum_prunable=False`` / ``ngram=None`` mean the
    corresponding index has no data and must answer "possible" — the
    format-5 migration default.
    """

    any_notnull: bool = False
    num_min: float = np.inf
    num_max: float = -np.inf
    num_prunable: bool = True
    strs: Any = None
    reprs: Any = None
    rnum_min: float = np.inf
    rnum_max: float = -np.inf
    rnum_prunable: bool = False
    ngram: NGramBloom | None = None


# ---------------------------------------------------------------------------
# the indexes
# ---------------------------------------------------------------------------

class SkipIndex:
    """One pluggable skipping index: probe + cost/selectivity + codec."""

    name = "index"
    build_cost_per_row = 0.0   # relative per-row maintenance cost units

    def handles(self, pred: SimplePredicate) -> bool:
        raise NotImplementedError

    def probe(self, pred: SimplePredicate, stats: KeyStats) -> bool:
        """False ONLY when provably no summarized row matches ``pred``."""
        raise NotImplementedError

    def selectivity(self, pred: SimplePredicate) -> float:
        """Workload-free prior fraction of rows matching ``pred``."""
        return 1.0

    def summary_to_obj(self, stats: KeyStats) -> dict:
        return {}

    def summary_from_obj(self, obj: dict, stats: KeyStats) -> None:
        pass


class MembershipIndex(SkipIndex):
    """Value-set membership + numeric min/max (the original zone map)."""

    name = "membership"
    build_cost_per_row = 1.0   # dictionary insert + min/max fold

    _KINDS = (Kind.EXACT, Kind.SUBSTRING, Kind.KEY_PRESENCE,
              Kind.KEY_VALUE, Kind.IN)

    def handles(self, pred: SimplePredicate) -> bool:
        return pred.kind in self._KINDS

    def probe(self, pred: SimplePredicate, stats: KeyStats) -> bool:
        if pred.kind is Kind.KEY_PRESENCE:
            return stats.any_notnull
        v = pred.value
        if pred.kind is Kind.EXACT:
            if not isinstance(v, str):
                return True  # non-lowerable value: never prune
            return True if stats.strs is None else v in stats.strs
        if pred.kind is Kind.SUBSTRING:
            if isinstance(v, bool):
                return False
            if stats.strs is None:
                return True
            sub = str(v)
            return any(sub in s for s in stats.strs)
        if pred.kind is Kind.IN:
            # disjunction: possible iff ANY element is
            return any(self._kv_possible(e, stats) for e in v)
        return self._kv_possible(v, stats)

    @staticmethod
    def _kv_possible(v, stats: KeyStats) -> bool:
        from .columnar import _f64_exact, _num_reprs
        if not (v is None or isinstance(v, (str, int, float, bool))):
            return True
        if stats.reprs is not None and json_scalar(v) in stats.reprs:
            return True
        if isinstance(v, (int, float)) and not isinstance(v, bool) \
                and _f64_exact(v):
            fv = float(v)
            # min/max gate first (cheapest), then the exact
            # numeric-equality membership test.  A NaN observed at build
            # time marks the bounds non-prunable: comparisons would be
            # silently False, so skip straight to the membership test
            if stats.num_prunable \
                    and not stats.num_min <= fv <= stats.num_max:
                # out-of-range refutes only the NUMERIC rows: min/max
                # never saw string values, yet a string row can
                # cross-repr match the probe (row {"score": "10"} vs
                # score == 10, §IV-B).  With an exact repr set that
                # string side is already refuted; saturated, fall back
                # to the string value set — and if that saturated too,
                # nothing may refute
                if stats.reprs is not None:
                    return False
                if stats.strs is None:
                    return True
                return json_scalar(v) in stats.strs
            if stats.reprs is None:
                return True
            return any(r in stats.reprs for r in _num_reprs(fv))
        return stats.reprs is None

    def selectivity(self, pred: SimplePredicate) -> float:
        if pred.kind is Kind.KEY_PRESENCE:
            return 0.5
        if pred.kind is Kind.EXACT:
            return 0.01
        if pred.kind is Kind.SUBSTRING:
            return 0.1
        if pred.kind is Kind.IN:
            return min(0.9, 0.02 * len(pred.value))
        return 0.02   # KEY_VALUE point lookup

    def summary_to_obj(self, stats: KeyStats) -> dict:
        # the legacy (format <= 5) summary block, byte-compatible with
        # what pre-registry checkpoints wrote
        empty = stats.num_min > stats.num_max
        return {
            "min": None if empty else stats.num_min,
            "max": None if empty else stats.num_max,
            "num_prunable": stats.num_prunable,
            "any_notnull": stats.any_notnull,
            "reprs": None if stats.reprs is None else sorted(stats.reprs),
            "strs": None if stats.strs is None else sorted(stats.strs),
        }

    def summary_from_obj(self, obj: dict, stats: KeyStats) -> None:
        stats.num_min = np.inf if obj["min"] is None else float(obj["min"])
        stats.num_max = -np.inf if obj["max"] is None else float(obj["max"])
        stats.num_prunable = bool(obj["num_prunable"])
        stats.any_notnull = bool(obj["any_notnull"])
        stats.reprs = None if obj["reprs"] is None else set(obj["reprs"])
        stats.strs = None if obj["strs"] is None else set(obj["strs"])


class RangeIndex(SkipIndex):
    """RANGE refutation via dedicated range bounds (never saturates)."""

    name = "range"
    build_cost_per_row = 0.5   # one json_number parse + min/max fold

    def handles(self, pred: SimplePredicate) -> bool:
        return pred.kind is Kind.RANGE

    def probe(self, pred: SimplePredicate, stats: KeyStats) -> bool:
        if not stats.rnum_prunable:
            return True
        if stats.rnum_min > stats.rnum_max:
            return False   # no range-matchable value anywhere in the key
        lo, hi, _lo_i, _hi_i = pred.value
        # bounds treated closed (inclusivity ignored): conservative
        if lo is not None and stats.rnum_max < lo:
            return False
        if hi is not None and stats.rnum_min > hi:
            return False
        return True

    def selectivity(self, pred: SimplePredicate) -> float:
        lo, hi, _, _ = pred.value
        return 0.1 if (lo is not None and hi is not None) else 0.25

    def summary_to_obj(self, stats: KeyStats) -> dict:
        empty = stats.rnum_min > stats.rnum_max
        return {
            "rmin": None if empty or not np.isfinite(stats.rnum_min)
            else stats.rnum_min,
            "rmax": None if empty or not np.isfinite(stats.rnum_max)
            else stats.rnum_max,
            # infinities can't ride in RFC 8259 JSON, so encode the
            # "bound present but infinite" case (an Infinity-string row)
            # as explicit flags
            "rmin_inf": bool(not empty and stats.rnum_min == -np.inf),
            "rmax_inf": bool(not empty and stats.rnum_max == np.inf),
            "rnum_prunable": bool(stats.rnum_prunable),
        }

    def summary_from_obj(self, obj: dict, stats: KeyStats) -> None:
        if "rnum_prunable" not in obj:
            # format-5 file: no range bounds were recorded — stay
            # non-prunable (conservative) until a reshard rebuilds them
            stats.rnum_prunable = False
            return
        stats.rnum_prunable = bool(obj["rnum_prunable"])
        if obj["rmin"] is not None:
            stats.rnum_min = float(obj["rmin"])
        elif obj.get("rmin_inf"):
            stats.rnum_min = -np.inf
        if obj["rmax"] is not None:
            stats.rnum_max = float(obj["rmax"])
        elif obj.get("rmax_inf"):
            stats.rnum_max = np.inf


class NGramIndex(SkipIndex):
    """Bloom-filter n-gram refutation for substring/exact string probes."""

    name = "ngram"
    build_cost_per_row = 2.0   # per-gram hashing over string values

    def handles(self, pred: SimplePredicate) -> bool:
        return pred.kind in (Kind.SUBSTRING, Kind.EXACT)

    def probe(self, pred: SimplePredicate, stats: KeyStats) -> bool:
        if stats.ngram is None:
            return True
        v = pred.value
        if pred.kind is Kind.EXACT and not isinstance(v, str):
            return True
        if isinstance(v, bool):
            return True   # membership already refutes bool SUBSTRING
        # EXACT: equality implies containment, so the same gram probe is
        # sound; SUBSTRING: directly the containment probe
        return stats.ngram.might_contain(str(v))

    def selectivity(self, pred: SimplePredicate) -> float:
        if pred.kind is Kind.EXACT:
            return 0.01
        # longer needles are rarer: decay with gram count, floored
        n_bytes = len(str(pred.value).encode("utf-8"))
        return max(0.005, 0.3 / max(1, n_bytes - NGRAM_N + 2))

    def summary_to_obj(self, stats: KeyStats) -> dict:
        return {"ngram": None if stats.ngram is None
                else stats.ngram.to_hex()}

    def summary_from_obj(self, obj: dict, stats: KeyStats) -> None:
        h = obj.get("ngram")
        stats.ngram = None if h is None else NGramBloom.from_hex(h)


# ---------------------------------------------------------------------------
# the registry
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SkipIndexRegistry:
    """Conjunctive composition of independently-sound skipping indexes."""

    indexes: tuple[SkipIndex, ...]

    def term_possible(self, pred: SimplePredicate, stats: KeyStats) -> bool:
        """False iff SOME index proves no summarized row matches."""
        for ix in self.indexes:
            if ix.handles(pred) and not ix.probe(pred, stats):
                return False
        return True

    def term_selectivity(self, pred: SimplePredicate) -> float:
        """Most-selective prior among the indexes that handle ``pred``."""
        out = 1.0
        for ix in self.indexes:
            if ix.handles(pred):
                out = min(out, max(0.0, ix.selectivity(pred)))
        return out

    def clause_selectivity_prior(self, clause: Clause) -> float:
        """Disjunction combine: 1 - prod(1 - s_term)."""
        miss = 1.0
        for t in clause.terms:
            miss *= 1.0 - min(1.0, self.term_selectivity(t))
        return 1.0 - miss

    def build_cost_per_row(self) -> float:
        return sum(ix.build_cost_per_row for ix in self.indexes)

    def summary_to_obj(self, stats: KeyStats) -> dict:
        out: dict = {}
        for ix in self.indexes:
            out.update(ix.summary_to_obj(stats))
        return out

    def summary_from_obj(self, obj: dict, stats: KeyStats | None = None
                         ) -> KeyStats:
        stats = stats or KeyStats()
        for ix in self.indexes:
            ix.summary_from_obj(obj, stats)
        return stats


REGISTRY = SkipIndexRegistry((MembershipIndex(), RangeIndex(), NGramIndex()))
