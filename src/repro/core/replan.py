"""Closed-loop adaptive replanning (plan epochs) — the control plane.

CIAO's planner picks a clause set from *estimated* selectivities and costs;
this module closes the paper's feedback loop (§V workload estimation) by
periodically re-solving the budgeted selection from what the system actually
observed:

  * **selectivity feedback** — ``CiaoStore`` accumulates live per-clause
    popcounts from the fused client kernels; observed selectivities replace
    the sample estimates for every currently pushed clause;
  * **workload feedback** — the scanner logs every query; the re-solve runs
    over a sliding window of the live workload, so a Zipf shift in which
    clauses are *queried* moves the pushed set;
  * **cost feedback** — clients report measured whole-plan eval timings;
    the cost model is recalibrated online (``CostModel.scaled``, §V-D)
    before each re-solve so budgets keep meaning wall-clock µs/record.

A replan emits a new **plan epoch** (``server.evolve_plan``): surviving
clauses keep their stable global ids, the store registers the epoch and
keeps per-epoch stats, and the ingest coordinator broadcasts the new plan
to every client shard mid-stream.  Invariants are in DESIGN.md §11.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING, Mapping, Sequence

import numpy as np

from .cost_model import CostModel

if TYPE_CHECKING:  # sharded deployments pass a ShardedCiaoStore
    from .shard import ShardedCiaoStore
from .planner import PlanReport, build_plan, build_plan_family
from .predicates import Clause, Query
from .server import (
    CiaoStore, PlanFamily, PushdownPlan, evolve_family, evolve_plan,
)
from .workload import Workload, estimate_selectivities

SEL_FLOOR = 1e-4


@dataclass(frozen=True)
class ReplanPolicy:
    """When to check for drift, and how much drift triggers a replan."""

    check_every_records: int = 2048   # min records ingested between checks
    min_observe_records: int = 512    # don't trust tiny per-epoch samples
    min_coverage: float = 0.5         # replan if < this fraction of recent
                                      # queries has >= 1 pushed clause
    sel_drift_threshold: float = 0.5  # replan if max relative |obs - plan|
                                      # selectivity error exceeds this
    sel_noise_floor: float = 0.02     # relative-error denominator floor: a
                                      # floored 1e-4 estimate observed at
                                      # 5e-4 is sampling noise, not drift
    workload_window: int = 64         # recent queries used for the re-solve
    min_window_queries: int = 8       # need this many logged queries
    recalibrate_cost: bool = True
    max_cost_scale: float = 100.0     # clamp for the online recalibration


@dataclass(frozen=True)
class DriftSignal:
    """One drift measurement (kept in ``Replanner.history`` for telemetry)."""

    coverage: float        # fraction of window queries with >= 1 pushed clause
    sel_drift: float       # max relative observed-vs-planned selectivity error
    n_observed: int        # records observed under the current epoch
    n_window: int          # queries in the workload window

    def triggers(self, policy: ReplanPolicy) -> str | None:
        if self.n_observed < policy.min_observe_records:
            return None
        if self.n_window >= policy.min_window_queries and \
                self.coverage < policy.min_coverage:
            return "coverage"
        if self.sel_drift > policy.sel_drift_threshold:
            return "selectivity"
        return None


@dataclass(frozen=True)
class LayoutDrift:
    """Physical-design drift: is the PHYSICAL layout still right?

    Orthogonal to :class:`DriftSignal` (which asks whether the pushed
    CLAUSE SET is still right): the store may be pushing exactly the
    right clauses yet routing/partitioning on a key the workload no
    longer filters by, or holding shards whose row counts have skewed
    far apart.  Consumed by ``repro.core.tuner.PhysicalDesignTuner``
    (DESIGN.md §18).
    """

    routing_key: str | None   # the router's current key
    hot_key: str | None       # most-queried key over the window
    hot_share: float          # hot key's share of window key references
    routing_share: float      # routing key's share of same
    n_window: int             # queries in the window
    shard_skew: float = 1.0   # max/mean resident rows across shards

    def triggers(self, *, min_window: int = 8,
                 hot_share_threshold: float = 0.5,
                 margin: float = 1.5,
                 skew_threshold: float = 4.0) -> str | None:
        """``"key-shift"``, ``"skew"`` or ``None``.

        Key-shift needs a real window, a dominant hot key, and the hot
        key beating the current routing key by ``margin``; skew needs
        only the row-count imbalance (it is workload-independent).
        """
        if self.shard_skew > skew_threshold:
            return "skew"
        if (self.n_window >= min_window
                and self.hot_key is not None
                and self.hot_key != self.routing_key
                and self.hot_share >= hot_share_threshold
                and self.hot_share >= margin * self.routing_share):
            return "key-shift"
        return None


def layout_drift_signal(store: "CiaoStore | ShardedCiaoStore", *,
                        window: int = 64) -> LayoutDrift:
    """Measure physical-design drift from the store's own feedback.

    Key frequencies come from the query log's recent window (each query
    contributes each referenced key once, weighted by ``freq``); shard
    skew from the per-shard resident row counts.  Works over a plain
    :class:`CiaoStore` too (no router, skew 1.0) so callers can gate on
    it uniformly.
    """
    router = getattr(store, "router", None)
    routing_key = getattr(router, "key", None)
    recent = store.query_log[-window:]
    weights: dict[str, float] = {}
    for q in recent:
        keys = {t.key for c in q.clauses for t in c.terms}
        for k in keys:
            weights[k] = weights.get(k, 0.0) + float(q.freq)
    total = sum(weights.values())
    hot_key = max(weights, key=weights.get) if weights else None
    hot_share = weights[hot_key] / total if hot_key else 0.0
    routing_share = (weights.get(routing_key, 0.0) / total
                     if total and routing_key else 0.0)
    shards = getattr(store, "shards", None)
    if shards and len(shards) > 1:
        rows = [max(0, sh.stats.n_records) for sh in shards]
        mean = sum(rows) / len(rows)
        skew = (max(rows) / mean) if mean > 0 else 1.0
    else:
        skew = 1.0
    return LayoutDrift(routing_key=routing_key, hot_key=hot_key,
                       hot_share=hot_share, routing_share=routing_share,
                       n_window=len(recent), shard_skew=skew)


@dataclass
class ReplanEvent:
    """One epoch bump: what changed and why."""

    epoch: int
    reason: str
    signal: DriftSignal
    report: PlanReport          # FamilyReport under tiered replanning
    remap: np.ndarray          # new local row -> previous local row, -1 = new
    cost_scale: float

    @property
    def n_survivors(self) -> int:
        return int(np.sum(self.remap >= 0))

    def describe(self) -> str:
        return (
            f"epoch {self.epoch} [{self.reason}] coverage="
            f"{self.signal.coverage:.2f} sel_drift={self.signal.sel_drift:.2f}"
            f" pushed={len(self.remap)} survivors={self.n_survivors}"
            f" cost_scale={self.cost_scale:.3g}"
        )


class Replanner:
    """Closed-loop planner: observe → detect drift → re-solve → bump epoch.

    Wraps one :class:`CiaoStore` (single client class; per-class budgets
    get one replanner per class store, mirroring ``plan_for_clients``) —
    or one :class:`~repro.core.shard.ShardedCiaoStore`, whose feedback
    surface is identical: per-shard observed selectivities, per-clause
    coverage denominators, and record totals are aggregated into exact
    fleet sums BEFORE every drift check and re-solve, and an epoch bump
    fans out to every shard atomically from the replanner's viewpoint.
    Call :meth:`observe_timing` as client timing reports arrive and
    :meth:`step` after every ingest; ``step`` returns the new
    :class:`PushdownPlan` when it advanced the epoch, else ``None``.
    """

    def __init__(
        self,
        store: CiaoStore | ShardedCiaoStore,
        sample_records: Sequence[bytes],
        *,
        budget_us: float | None = None,
        tier_budgets_us: Sequence[float] | None = None,
        base_workload: Workload | None = None,
        cost_model: CostModel | None = None,
        policy: ReplanPolicy | None = None,
        algorithm: str = "celf",
        planned_sel: Mapping[Clause, float] | None = None,
    ):
        if budget_us is None and not tier_budgets_us:
            raise ValueError("need budget_us or tier_budgets_us")
        self.store = store
        self.sample_records = list(sample_records)
        # tiered mode: re-solves emit a whole PlanFamily (nested budget
        # cut-points of one CELF run); the top tier budget IS the budget,
        # so a conflicting explicit budget_us would be silently ignored —
        # reject it instead
        self.tier_budgets_us = (tuple(tier_budgets_us)
                                if tier_budgets_us else None)
        if self.tier_budgets_us is not None and budget_us is not None \
                and float(budget_us) != max(self.tier_budgets_us):
            raise ValueError(
                f"conflicting budgets: budget_us={budget_us} but the top "
                f"tier budget is {max(self.tier_budgets_us)} (tiered "
                "re-solves run under the tier budgets; pass one or the "
                "other)")
        self.budget_us = (float(budget_us) if budget_us is not None
                          else max(self.tier_budgets_us))
        self.base_workload = base_workload
        self.cost_model = cost_model or CostModel()
        self.policy = policy or ReplanPolicy()
        self.algorithm = algorithm
        # selectivity cache: sample-based estimates for pool clauses, plus
        # the values the CURRENT plan was built with (drift reference)
        self._sel_cache: dict[Clause, float] = dict(planned_sel or {})
        self._planned_sel: dict[Clause, float] = {
            c: self._sel_cache.get(c, SEL_FLOOR) for c in store.plan.clauses
        }
        self._records_at_last_check = 0
        # online cost recalibration state (µs totals, predicted vs observed)
        self._pred_us = 0.0
        self._obs_us = 0.0
        self.cost_scale = 1.0
        self.history: list[ReplanEvent] = []

    # -- feedback intake -----------------------------------------------------
    def observe_timing(self, n_records: int, elapsed_s: float,
                       n_clauses: int | None = None) -> None:
        """Client timing report: plan eval of ``n_records`` records.

        ``n_clauses`` names how many leading clauses the client actually
        evaluated (its tier's coverage).  ``None`` means the whole plan —
        a tiered fleet MUST pass its tier size, otherwise a mostly-floor
        fleet's short-prefix timings get compared against whole-plan
        predictions and the recalibration collapses toward the clamp.
        """
        if n_records <= 0 or not self.store.plan.n:
            return
        predicted = self._predicted_plan_us(n_clauses) * n_records
        if predicted <= 0.0:
            return  # empty tier: no cost signal in this report
        self._pred_us += predicted
        self._obs_us += elapsed_s * 1e6
        if self.policy.recalibrate_cost and self._pred_us > 0:
            self.cost_scale = float(np.clip(
                self._obs_us / self._pred_us,
                1.0 / self.policy.max_cost_scale, self.policy.max_cost_scale,
            ))

    def _predicted_plan_us(self, n_clauses: int | None = None) -> float:
        plan = self.store.plan
        clauses = (plan.clauses if n_clauses is None
                   else plan.clauses[:n_clauses])
        sel = self._planned_sel
        return sum(
            self.cost_model.clause_cost(c, sel.get(c, SEL_FLOOR))
            for c in clauses
        )

    # -- drift detection -----------------------------------------------------
    def _window(self) -> list[Query]:
        return self.store.query_log[-self.policy.workload_window:]

    def drift_signal(self) -> DriftSignal:
        store = self.store
        plan = store.plan
        window = self._window()
        if window and plan.n:
            coverage = float(np.mean(
                [1.0 if plan.pushed_in(q) else 0.0 for q in window]))
        else:
            coverage = 1.0 if plan.n else 0.0
        n_obs = store.epoch_records()
        sel_drift = 0.0
        if plan.n and n_obs:
            obs = store.observed_selectivities()
            cov = store.clause_records()
            for c, i in plan.ids.items():
                # a clause no produced tier covered has obs == 0 by
                # construction, not by measurement — drift must only be
                # computed from adequately covered clauses
                if cov[i] < self.policy.min_observe_records:
                    continue
                planned = max(self._planned_sel.get(c, SEL_FLOOR), SEL_FLOOR)
                denom = max(planned, self.policy.sel_noise_floor)
                sel_drift = max(sel_drift,
                                abs(float(obs[i]) - planned) / denom)
        return DriftSignal(coverage=coverage, sel_drift=sel_drift,
                           n_observed=n_obs, n_window=len(window))

    def layout_drift(self) -> LayoutDrift:
        """Physical-design drift over the same workload window the clause
        re-solve uses (see :func:`layout_drift_signal`)."""
        return layout_drift_signal(self.store,
                                   window=self.policy.workload_window)

    # -- the loop ------------------------------------------------------------
    def step(self, force: bool = False) -> "PushdownPlan | PlanFamily | None":
        """Check drift; re-solve and advance the store epoch if triggered.

        Returns the new plan (or, under ``tier_budgets_us``, the new
        :class:`PlanFamily`) when the epoch advanced, else ``None``.
        """
        store = self.store
        if not force:
            since = store.stats.n_records - self._records_at_last_check
            if since < self.policy.check_every_records:
                return None
        self._records_at_last_check = store.stats.n_records
        signal = self.drift_signal()
        reason = "forced" if force else signal.triggers(self.policy)
        if reason is None:
            return None
        return self._replan(reason, signal)

    def _replan(self, reason: str, signal: DriftSignal) -> PushdownPlan | None:
        store = self.store
        window = self._window()
        if len(window) >= self.policy.min_window_queries:
            workload = Workload(name=f"observed@{store.epoch}",
                                queries=list(window))
        elif self.base_workload is not None:
            workload = self.base_workload
        else:
            return None
        # merge selectivities: sample estimates for unseen pool clauses,
        # live observed values for everything the current plan pushes
        pool = workload.clause_pool()
        missing = [c for c in pool if c not in self._sel_cache]
        if missing:
            self._sel_cache.update(
                estimate_selectivities(missing, self.sample_records))
        sel = {c: self._sel_cache[c] for c in pool}
        obs = store.observed_selectivities()
        cov = store.clause_records()
        if signal.n_observed >= self.policy.min_observe_records:
            for c, i in store.plan.ids.items():
                # only clauses with real per-clause coverage update the
                # cache: a tier-uncovered clause's obs of 0 would clobber
                # its sample estimate with a fabricated floor value
                if cov[i] < self.policy.min_observe_records:
                    continue
                self._sel_cache[c] = max(float(obs[i]), SEL_FLOOR)
                if c in sel:
                    sel[c] = self._sel_cache[c]
        cm = (self.cost_model.scaled(self.cost_scale)
              if self.policy.recalibrate_cost else self.cost_model)
        if self.tier_budgets_us is not None:
            return self._replan_tiered(reason, signal, workload, sel, cm)
        report = build_plan(
            workload, self.sample_records, budget_us=self.budget_us,
            cost_model=cm, algorithm=self.algorithm, sel=sel,
        )
        if set(report.plan.clauses) == set(store.plan.clauses):
            # same selection (order is solver-dependent): an epoch bump
            # would only reset the drift-observation sample for nothing.
            # The observed values become the new drift reference — without
            # this the sel-drift trigger never clears and every subsequent
            # check would re-run the whole solve just to land here again.
            self._planned_sel = {
                c: self._sel_cache.get(c, sel.get(c, SEL_FLOOR))
                for c in store.plan.clauses
            }
            return None
        new_plan = evolve_plan(store.plan, report.plan.clauses)
        remap = store.advance_epoch(new_plan)
        self._planned_sel = {c: sel.get(c, SEL_FLOOR)
                             for c in new_plan.clauses}
        self.history.append(ReplanEvent(
            epoch=new_plan.epoch, reason=reason, signal=signal,
            report=report, remap=remap, cost_scale=self.cost_scale,
        ))
        return new_plan

    def _replan_tiered(self, reason: str, signal: DriftSignal,
                       workload: Workload, sel, cm) -> PlanFamily | None:
        """Tiered re-solve: one CELF run, nested cut-points, new family.

        Families are immutable per epoch — a chunk's coverage is validated
        against ITS epoch's tier sizes, so even a pure tier-boundary move
        (same clauses, shifted cut-points from cost recalibration) must
        ride an epoch bump; in-flight chunks then fail with
        StaleEpochError and get re-evaluated, never mis-validated.
        """
        store = self.store
        rep = build_plan_family(
            workload, self.sample_records,
            tier_budgets_us=self.tier_budgets_us, cost_model=cm, sel=sel,
        )
        # no-change guard on per-tier clause SETS, not order: the greedy
        # may swap near-equal-gain clauses within a tier after an obs
        # update, and tiers are prefix cuts — if every cut's set matches
        # (sizes equal), every tier's coverage is semantically identical
        # and an epoch bump would only reset stats / invalidate chunks
        same_tiers = (
            rep.family.tier_sizes == store.family.tier_sizes
            and all(
                set(rep.tiered.order[:s]) == set(store.plan.clauses[:s])
                for s in rep.family.tier_sizes)
        )
        if same_tiers:
            self._planned_sel = {
                c: self._sel_cache.get(c, sel.get(c, SEL_FLOOR))
                for c in store.plan.clauses
            }
            return None
        family = evolve_family(
            store.plan, rep.tiered.order, rep.family.tier_sizes,
            budgets=rep.family.budgets, tier_costs=rep.family.tier_costs,
            tier_values=rep.family.tier_values,
        )
        remap = store.advance_epoch(family)
        self._planned_sel = {c: sel.get(c, SEL_FLOOR)
                             for c in family.plan.clauses}
        self.history.append(ReplanEvent(
            epoch=family.epoch, reason=reason, signal=signal,
            report=rep, remap=remap, cost_scale=self.cost_scale,
        ))
        return family
