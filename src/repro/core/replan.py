"""Closed-loop adaptive replanning (plan epochs) — the control plane.

CIAO's planner picks a clause set from *estimated* selectivities and costs;
this module closes the paper's feedback loop (§V workload estimation) by
periodically re-solving the budgeted selection from what the system actually
observed:

  * **selectivity feedback** — ``CiaoStore`` accumulates live per-clause
    popcounts from the fused client kernels; observed selectivities replace
    the sample estimates for every currently pushed clause;
  * **workload feedback** — the scanner logs every query; the re-solve runs
    over a sliding window of the live workload, so a Zipf shift in which
    clauses are *queried* moves the pushed set;
  * **cost feedback** — clients report measured whole-plan eval timings;
    the cost model is recalibrated online (``CostModel.scaled``, §V-D)
    before each re-solve so budgets keep meaning wall-clock µs/record.

A replan emits a new **plan epoch** (``server.evolve_plan``): surviving
clauses keep their stable global ids, the store registers the epoch and
keeps per-epoch stats, and the ingest coordinator broadcasts the new plan
to every client shard mid-stream.  Invariants are in DESIGN.md §11.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Mapping, Sequence

import numpy as np

from .cost_model import CostModel
from .planner import PlanReport, build_plan
from .predicates import Clause, Query
from .server import CiaoStore, PushdownPlan, evolve_plan
from .workload import Workload, estimate_selectivities

SEL_FLOOR = 1e-4


@dataclass(frozen=True)
class ReplanPolicy:
    """When to check for drift, and how much drift triggers a replan."""

    check_every_records: int = 2048   # min records ingested between checks
    min_observe_records: int = 512    # don't trust tiny per-epoch samples
    min_coverage: float = 0.5         # replan if < this fraction of recent
                                      # queries has >= 1 pushed clause
    sel_drift_threshold: float = 0.5  # replan if max relative |obs - plan|
                                      # selectivity error exceeds this
    sel_noise_floor: float = 0.02     # relative-error denominator floor: a
                                      # floored 1e-4 estimate observed at
                                      # 5e-4 is sampling noise, not drift
    workload_window: int = 64         # recent queries used for the re-solve
    min_window_queries: int = 8       # need this many logged queries
    recalibrate_cost: bool = True
    max_cost_scale: float = 100.0     # clamp for the online recalibration


@dataclass(frozen=True)
class DriftSignal:
    """One drift measurement (kept in ``Replanner.history`` for telemetry)."""

    coverage: float        # fraction of window queries with >= 1 pushed clause
    sel_drift: float       # max relative observed-vs-planned selectivity error
    n_observed: int        # records observed under the current epoch
    n_window: int          # queries in the workload window

    def triggers(self, policy: ReplanPolicy) -> str | None:
        if self.n_observed < policy.min_observe_records:
            return None
        if self.n_window >= policy.min_window_queries and \
                self.coverage < policy.min_coverage:
            return "coverage"
        if self.sel_drift > policy.sel_drift_threshold:
            return "selectivity"
        return None


@dataclass
class ReplanEvent:
    """One epoch bump: what changed and why."""

    epoch: int
    reason: str
    signal: DriftSignal
    report: PlanReport
    remap: np.ndarray          # new local row -> previous local row, -1 = new
    cost_scale: float

    @property
    def n_survivors(self) -> int:
        return int(np.sum(self.remap >= 0))

    def describe(self) -> str:
        return (
            f"epoch {self.epoch} [{self.reason}] coverage="
            f"{self.signal.coverage:.2f} sel_drift={self.signal.sel_drift:.2f}"
            f" pushed={len(self.remap)} survivors={self.n_survivors}"
            f" cost_scale={self.cost_scale:.3g}"
        )


class Replanner:
    """Closed-loop planner: observe → detect drift → re-solve → bump epoch.

    Wraps one :class:`CiaoStore` (single client class; per-class budgets
    get one replanner per class store, mirroring ``plan_for_clients``).
    Call :meth:`observe_timing` as client timing reports arrive and
    :meth:`step` after every ingest; ``step`` returns the new
    :class:`PushdownPlan` when it advanced the epoch, else ``None``.
    """

    def __init__(
        self,
        store: CiaoStore,
        sample_records: Sequence[bytes],
        *,
        budget_us: float,
        base_workload: Workload | None = None,
        cost_model: CostModel | None = None,
        policy: ReplanPolicy | None = None,
        algorithm: str = "celf",
        planned_sel: Mapping[Clause, float] | None = None,
    ):
        self.store = store
        self.sample_records = list(sample_records)
        self.budget_us = budget_us
        self.base_workload = base_workload
        self.cost_model = cost_model or CostModel()
        self.policy = policy or ReplanPolicy()
        self.algorithm = algorithm
        # selectivity cache: sample-based estimates for pool clauses, plus
        # the values the CURRENT plan was built with (drift reference)
        self._sel_cache: dict[Clause, float] = dict(planned_sel or {})
        self._planned_sel: dict[Clause, float] = {
            c: self._sel_cache.get(c, SEL_FLOOR) for c in store.plan.clauses
        }
        self._records_at_last_check = 0
        # online cost recalibration state (µs totals, predicted vs observed)
        self._pred_us = 0.0
        self._obs_us = 0.0
        self.cost_scale = 1.0
        self.history: list[ReplanEvent] = []

    # -- feedback intake -----------------------------------------------------
    def observe_timing(self, n_records: int, elapsed_s: float) -> None:
        """Client timing report: whole-plan eval of ``n_records`` records."""
        if n_records <= 0 or not self.store.plan.n:
            return
        predicted = self._predicted_plan_us() * n_records
        self._pred_us += predicted
        self._obs_us += elapsed_s * 1e6
        if self.policy.recalibrate_cost and self._pred_us > 0:
            self.cost_scale = float(np.clip(
                self._obs_us / self._pred_us,
                1.0 / self.policy.max_cost_scale, self.policy.max_cost_scale,
            ))

    def _predicted_plan_us(self) -> float:
        plan = self.store.plan
        sel = self._planned_sel
        return sum(
            self.cost_model.clause_cost(c, sel.get(c, SEL_FLOOR))
            for c in plan.clauses
        )

    # -- drift detection -----------------------------------------------------
    def _window(self) -> list[Query]:
        return self.store.query_log[-self.policy.workload_window:]

    def drift_signal(self) -> DriftSignal:
        store = self.store
        plan = store.plan
        window = self._window()
        if window and plan.n:
            coverage = float(np.mean(
                [1.0 if plan.pushed_in(q) else 0.0 for q in window]))
        else:
            coverage = 1.0 if plan.n else 0.0
        n_obs = store.epoch_records()
        sel_drift = 0.0
        if plan.n and n_obs:
            obs = store.observed_selectivities()
            for c, i in plan.ids.items():
                planned = max(self._planned_sel.get(c, SEL_FLOOR), SEL_FLOOR)
                denom = max(planned, self.policy.sel_noise_floor)
                sel_drift = max(sel_drift,
                                abs(float(obs[i]) - planned) / denom)
        return DriftSignal(coverage=coverage, sel_drift=sel_drift,
                           n_observed=n_obs, n_window=len(window))

    # -- the loop ------------------------------------------------------------
    def step(self, force: bool = False) -> PushdownPlan | None:
        """Check drift; re-solve and advance the store epoch if triggered."""
        store = self.store
        if not force:
            since = store.stats.n_records - self._records_at_last_check
            if since < self.policy.check_every_records:
                return None
        self._records_at_last_check = store.stats.n_records
        signal = self.drift_signal()
        reason = "forced" if force else signal.triggers(self.policy)
        if reason is None:
            return None
        return self._replan(reason, signal)

    def _replan(self, reason: str, signal: DriftSignal) -> PushdownPlan | None:
        store = self.store
        window = self._window()
        if len(window) >= self.policy.min_window_queries:
            workload = Workload(name=f"observed@{store.epoch}",
                                queries=list(window))
        elif self.base_workload is not None:
            workload = self.base_workload
        else:
            return None
        # merge selectivities: sample estimates for unseen pool clauses,
        # live observed values for everything the current plan pushes
        pool = workload.clause_pool()
        missing = [c for c in pool if c not in self._sel_cache]
        if missing:
            self._sel_cache.update(
                estimate_selectivities(missing, self.sample_records))
        sel = {c: self._sel_cache[c] for c in pool}
        obs = store.observed_selectivities()
        if signal.n_observed >= self.policy.min_observe_records:
            for c, i in store.plan.ids.items():
                self._sel_cache[c] = max(float(obs[i]), SEL_FLOOR)
                if c in sel:
                    sel[c] = self._sel_cache[c]
        cm = (self.cost_model.scaled(self.cost_scale)
              if self.policy.recalibrate_cost else self.cost_model)
        report = build_plan(
            workload, self.sample_records, budget_us=self.budget_us,
            cost_model=cm, algorithm=self.algorithm, sel=sel,
        )
        if set(report.plan.clauses) == set(store.plan.clauses):
            # same selection (order is solver-dependent): an epoch bump
            # would only reset the drift-observation sample for nothing.
            # The observed values become the new drift reference — without
            # this the sel-drift trigger never clears and every subsequent
            # check would re-run the whole solve just to land here again.
            self._planned_sel = {
                c: self._sel_cache.get(c, sel.get(c, SEL_FLOOR))
                for c in store.plan.clauses
            }
            return None
        new_plan = evolve_plan(store.plan, report.plan.clauses)
        remap = store.advance_epoch(new_plan)
        self._planned_sel = {c: sel.get(c, SEL_FLOOR)
                             for c in new_plan.clauses}
        self.history.append(ReplanEvent(
            epoch=new_plan.epoch, reason=reason, signal=signal,
            report=report, remap=remap, cost_scale=self.cost_scale,
        ))
        return new_plan
