"""Cost model for client-side predicate evaluation (paper §V-D).

Expected cost (microseconds) of evaluating one pattern on one JSON object:

    T = sel(p) * (k1*len(p) + k2*len(t))
      + (1 - sel(p)) * (k3*len(p) + k4*len(t)) + c

where ``len(p)`` is pattern length, ``len(t)`` the average record length and
``sel(p)`` the match selectivity.  k1..k4, c are hardware-dependent and fitted
by multivariate linear regression from timed probes (paper §VII-F reports
R^2 = 0.897 / 0.666 / 0.978 across three platforms).

A :class:`CostModel` prices a *clause* as the sum of its disjuncts' pattern
costs (paper: "For a disjunction of predicates ... its cost is the summation
of the cost of evaluating each simple predicate").
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Sequence

import numpy as np

from .predicates import Clause, SimplePredicate


@dataclass
class CostModel:
    """5-coefficient linear substring-search cost model (µs / record)."""

    k1: float = 0.004   # found: per pattern byte
    k2: float = 0.0015  # found: per record byte
    k3: float = 0.002   # not found: per pattern byte
    k4: float = 0.001   # not found: per record byte
    c: float = 0.05     # per-search startup
    avg_record_len: float = 256.0

    def pattern_cost(self, pattern_len: int, sel: float) -> float:
        return self.sel_len_cost(sel, pattern_len, self.avg_record_len)

    def sel_len_cost(self, sel: float, pattern_len: int, record_len: float) -> float:
        lp = float(pattern_len)
        return (
            sel * (self.k1 * lp + self.k2 * record_len)
            + (1.0 - sel) * (self.k3 * lp + self.k4 * record_len)
            + self.c
        )

    def simple_cost(self, pred: SimplePredicate, sel: float) -> float:
        return sum(self.pattern_cost(len(p), sel) for p in pred.patterns())

    def clause_cost(self, cl: Clause, sel: float) -> float:
        # Disjunction cost = sum of disjunct costs (worst case: all evaluated).
        return sum(self.simple_cost(t, sel) for t in cl.terms)

    def coefficients(self) -> np.ndarray:
        return np.array([self.k1, self.k2, self.k3, self.k4, self.c])

    def scaled(self, factor: float) -> "CostModel":
        """Multiplicatively recalibrated copy (online feedback, §V-D).

        Clients report measured whole-plan eval time per record; the ratio
        observed/predicted recalibrates every coefficient at once.  This is
        the cheap online complement to the full regression refit
        (:func:`fit`): it corrects hardware-speed drift without needing
        per-pattern probe timings on the client.
        """
        if factor <= 0:
            raise ValueError(f"scale factor must be positive, got {factor}")
        return CostModel(
            k1=self.k1 * factor, k2=self.k2 * factor,
            k3=self.k3 * factor, k4=self.k4 * factor,
            c=self.c * factor, avg_record_len=self.avg_record_len,
        )


@dataclass
class CalibrationResult:
    model: CostModel
    r_squared: float
    n_probes: int
    residual_us: float


def _design_row(sel: float, len_p: float, len_t: float) -> list[float]:
    return [
        sel * len_p,
        sel * len_t,
        (1.0 - sel) * len_p,
        (1.0 - sel) * len_t,
        1.0,
    ]


def fit(
    sels: Sequence[float],
    pattern_lens: Sequence[int],
    record_lens: Sequence[float],
    times_us: Sequence[float],
    avg_record_len: float | None = None,
) -> CalibrationResult:
    """Least-squares fit of (k1..k4, c) from timed probes."""
    X = np.array(
        [_design_row(s, float(lp), float(lt)) for s, lp, lt in zip(sels, pattern_lens, record_lens)]
    )
    y = np.asarray(times_us, dtype=np.float64)
    coef, *_ = np.linalg.lstsq(X, y, rcond=None)
    pred = X @ coef
    ss_res = float(((pred - y) ** 2).sum())
    ss_tot = float(((y - y.mean()) ** 2).sum())
    r2 = 1.0 - ss_res / ss_tot if ss_tot > 0 else 1.0
    model = CostModel(
        k1=float(coef[0]),
        k2=float(coef[1]),
        k3=float(coef[2]),
        k4=float(coef[3]),
        c=float(coef[4]),
        avg_record_len=float(
            avg_record_len if avg_record_len is not None
            else np.mean(record_lens)),
    )
    return CalibrationResult(
        model=model,
        r_squared=r2,
        n_probes=len(y),
        residual_us=float(np.sqrt(ss_res / max(len(y), 1))),
    )


def calibrate_scaled(
    records: Sequence[bytes],
    probe_clauses: Sequence[Clause],
    engine,
    *,
    base: CostModel | None = None,
    sel: dict[Clause, float] | None = None,
    repeats: int = 3,
) -> CostModel:
    """Whole-plan timed-probe recalibration on a production engine (§V-D).

    Times ``engine.eval_fused`` over the probe clause set on the encoded
    record sample and scales ``base`` by observed/predicted — the same
    multiplicative recalibration the replanner applies online, so every
    clause cost stays positive (an unconstrained :func:`fit` does not
    guarantee that).  Size the probe like the plans the budget will buy:
    vectorized engines amortize shared chunk scans, so probing with a much
    larger plan understates live per-clause cost.
    """
    from .client import encode_chunk
    from .workload import estimate_selectivities

    base = base or CostModel()
    if sel is None:
        sel = estimate_selectivities(probe_clauses, records)
    predicted_us = sum(base.clause_cost(c, sel[c]) for c in probe_clauses)
    chunk = encode_chunk(records)
    engine.eval_fused(chunk, probe_clauses)  # warm caches / jit
    best = np.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        engine.eval_fused(chunk, probe_clauses)
        best = min(best, time.perf_counter() - t0)
    observed_us = best / max(chunk.n_records, 1) * 1e6
    return base.scaled(max(observed_us / max(predicted_us, 1e-9), 1e-3))


def calibrate(
    records: Sequence[bytes],
    probe_preds: Sequence[SimplePredicate],
    evaluator: Callable[[Sequence[bytes], SimplePredicate], np.ndarray] | None = None,
    repeats: int = 3,
) -> CalibrationResult:
    """Time real probes on this hardware and fit the model (paper §VII-F).

    ``evaluator(records, pred) -> bool[n]`` defaults to the paper-faithful
    ``bytes.find`` engine.  Returns the fitted model plus R^2.
    """
    if evaluator is None:
        def evaluator(recs, pred):  # noqa: ANN001
            return np.array([pred.matches_raw(r) for r in recs])

    lens = np.array([len(r) for r in records], dtype=np.float64)
    avg_len = float(lens.mean())
    sels, plens, rlens, times = [], [], [], []
    for pred in probe_preds:
        best = np.inf
        hits = None
        for _ in range(repeats):
            t0 = time.perf_counter()
            hits = evaluator(records, pred)
            dt = time.perf_counter() - t0
            best = min(best, dt)
        sel = float(np.mean(hits))
        per_record_us = best / len(records) * 1e6
        sels.append(sel)
        plens.append(pred.pattern_length())
        rlens.append(avg_len)
        times.append(per_record_us)
    return fit(sels, plens, rlens, times, avg_record_len=avg_len)
