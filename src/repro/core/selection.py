"""Budgeted predicate selection (paper §V).

Maximize the expected filter benefit

    f(S) = sum_q freq(q) * (1 - prod_{c in S ∩ P_q} sel(c))

subject to  sum_{c in S} cost(c) <= B.   f is submodular (paper §V-B), and
the knapsack-constrained greedy pair (Khuller/Moss/Naor) gives a
(1/2)(1 - 1/e) ≈ 0.316 approximation:

  * Algorithm 1 — naive greedy: argmax_{p} f(S ∪ {p})           (max gain)
  * Algorithm 2 — ratio greedy: argmax_{p} Δf / cost(p)          (max gain/cost)
  * combined    — run both, keep the better f(S).

Beyond-paper: :func:`celf_greedy` implements CELF lazy evaluation (valid by
submodularity: stale marginal gains are upper bounds), which returns the
*identical* set to the eager greedy while evaluating far fewer marginals —
our selection-scaling benchmark quantifies the speedup.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass
from typing import Iterable, Mapping

import numpy as np

from .predicates import Clause, Query


@dataclass(frozen=True)
class SelectionProblem:
    """Immutable problem instance: queries + per-clause selectivity & cost."""

    queries: tuple[Query, ...]
    sel: Mapping[Clause, float]
    cost: Mapping[Clause, float]
    budget: float

    def candidates(self) -> list[Clause]:
        seen: dict[Clause, None] = {}
        for q in self.queries:
            for c in q.clauses:
                if c in self.sel and c in self.cost:
                    seen.setdefault(c, None)
        return list(seen)


@dataclass
class SelectionResult:
    selected: list[Clause]
    objective: float
    total_cost: float
    algorithm: str
    evaluations: int = 0  # marginal-gain evaluations (CELF metric)

    def describe(self) -> str:
        return (
            f"{self.algorithm}: |S|={len(self.selected)} f(S)={self.objective:.4f} "
            f"cost={self.total_cost:.4f} evals={self.evaluations}"
        )


# ---------------------------------------------------------------------------
# objective
# ---------------------------------------------------------------------------

def objective(problem: SelectionProblem, S: Iterable[Clause]) -> float:
    Sset = set(S)
    total = 0.0
    for q in problem.queries:
        prod = 1.0
        for c in q.clauses:
            if c in Sset:
                prod *= problem.sel[c]
        total += q.freq * (1.0 - prod)
    return total


class _Marginals:
    """Incremental marginal-gain evaluation.

    Keeps per-query running product of selected clauses' selectivities so a
    marginal gain is O(#queries containing the clause).
    """

    def __init__(self, problem: SelectionProblem):
        self.problem = problem
        self.query_prod = [1.0] * len(problem.queries)
        self.by_clause: dict[Clause, list[int]] = {}
        for qi, q in enumerate(problem.queries):
            for c in q.clauses:
                self.by_clause.setdefault(c, []).append(qi)
        self.evaluations = 0

    def gain(self, c: Clause) -> float:
        self.evaluations += 1
        s = self.problem.sel[c]
        g = 0.0
        for qi in self.by_clause.get(c, ()):  # queries containing c
            g += self.problem.queries[qi].freq * self.query_prod[qi] * (1.0 - s)
        return g

    def add(self, c: Clause) -> None:
        s = self.problem.sel[c]
        for qi in self.by_clause.get(c, ()):
            self.query_prod[qi] *= s

    def objective_value(self) -> float:
        return sum(
            q.freq * (1.0 - p) for q, p in zip(self.problem.queries, self.query_prod)
        )


# ---------------------------------------------------------------------------
# Algorithms 1 & 2 (paper) — eager greedy
# ---------------------------------------------------------------------------

def greedy(problem: SelectionProblem, *, ratio: bool) -> SelectionResult:
    """Eager greedy.  ``ratio=False`` -> Alg.1 (max gain); True -> Alg.2."""
    marg = _Marginals(problem)
    remaining = set(problem.candidates())
    S: list[Clause] = []
    spent = 0.0
    while True:
        best_c, best_key = None, -np.inf
        for c in remaining:
            cost_c = problem.cost[c]
            if spent + cost_c > problem.budget + 1e-12:
                continue
            g = marg.gain(c)
            key = g / cost_c if ratio else g
            if key > best_key:
                best_key, best_c = key, c
        if best_c is None:
            break
        S.append(best_c)
        spent += problem.cost[best_c]
        marg.add(best_c)
        remaining.discard(best_c)
    return SelectionResult(
        selected=S,
        objective=marg.objective_value(),
        total_cost=spent,
        algorithm="ratio-greedy" if ratio else "naive-greedy",
        evaluations=marg.evaluations,
    )


def combined_greedy(problem: SelectionProblem) -> SelectionResult:
    """Paper §V-C: better of Alg.1 / Alg.2 — >= 0.316 * OPT."""
    a = greedy(problem, ratio=False)
    b = greedy(problem, ratio=True)
    best = a if a.objective >= b.objective else b
    return SelectionResult(
        selected=best.selected,
        objective=best.objective,
        total_cost=best.total_cost,
        algorithm=f"combined({best.algorithm})",
        evaluations=a.evaluations + b.evaluations,
    )


# ---------------------------------------------------------------------------
# CELF lazy greedy (beyond-paper optimization, identical output)
# ---------------------------------------------------------------------------

def celf_greedy(problem: SelectionProblem, *, ratio: bool) -> SelectionResult:
    """Lazy greedy with a max-heap of stale gains (upper bounds).

    Submodularity guarantees a clause's marginal gain only decreases as S
    grows, so a heap entry whose gain was computed at the current round size
    is exact and safe to pop.  Ties are broken identically to the eager
    greedy (by heap order on (-key, seq)).
    """
    marg = _Marginals(problem)
    heap: list[tuple[float, int, Clause]] = []
    seq = itertools.count()
    for c in problem.candidates():
        g = marg.gain(c)
        key = g / problem.cost[c] if ratio else g
        heapq.heappush(heap, (-key, next(seq), c))
    S: list[Clause] = []
    spent = 0.0
    stale: list[tuple[float, int, Clause]] = []
    round_id = 0
    fresh: dict[Clause, int] = {c: 0 for c in problem.candidates()}
    while heap:
        negkey, sq, c = heapq.heappop(heap)
        if spent + problem.cost[c] > problem.budget + 1e-12:
            continue  # cannot afford; drop (cost is static, gain only shrinks)
        if fresh[c] == round_id:
            S.append(c)
            spent += problem.cost[c]
            marg.add(c)
            round_id += 1
        else:
            g = marg.gain(c)
            key = g / problem.cost[c] if ratio else g
            fresh[c] = round_id
            heapq.heappush(heap, (-key, sq, c))
    return SelectionResult(
        selected=S,
        objective=marg.objective_value(),
        total_cost=spent,
        algorithm="celf-ratio" if ratio else "celf-naive",
        evaluations=marg.evaluations,
    )


def combined_celf(problem: SelectionProblem) -> SelectionResult:
    a = celf_greedy(problem, ratio=False)
    b = celf_greedy(problem, ratio=True)
    best = a if a.objective >= b.objective else b
    return SelectionResult(
        selected=best.selected,
        objective=best.objective,
        total_cost=best.total_cost,
        algorithm=f"combined({best.algorithm})",
        evaluations=a.evaluations + b.evaluations,
    )


# ---------------------------------------------------------------------------
# exact OPT (tests only — exponential)
# ---------------------------------------------------------------------------

def brute_force(problem: SelectionProblem, max_candidates: int = 18) -> SelectionResult:
    cands = problem.candidates()
    if len(cands) > max_candidates:
        raise ValueError(f"brute force capped at {max_candidates} candidates")
    best_S: tuple[Clause, ...] = ()
    best_f = 0.0
    for r in range(len(cands) + 1):
        for S in itertools.combinations(cands, r):
            if sum(problem.cost[c] for c in S) > problem.budget + 1e-12:
                continue
            fS = objective(problem, S)
            if fS > best_f:
                best_f, best_S = fS, S
    return SelectionResult(
        selected=list(best_S),
        objective=best_f,
        total_cost=sum(problem.cost[c] for c in best_S),
        algorithm="brute-force",
    )
