"""Budgeted predicate selection (paper §V).

Maximize the expected filter benefit

    f(S) = sum_q freq(q) * (1 - prod_{c in S ∩ P_q} sel(c))

subject to  sum_{c in S} cost(c) <= B.   f is submodular (paper §V-B), and
the knapsack-constrained greedy pair (Khuller/Moss/Naor) gives a
(1/2)(1 - 1/e) ≈ 0.316 approximation:

  * Algorithm 1 — naive greedy: argmax_{p} f(S ∪ {p})           (max gain)
  * Algorithm 2 — ratio greedy: argmax_{p} Δf / cost(p)          (max gain/cost)
  * combined    — run both, keep the better f(S).

Beyond-paper: :func:`celf_greedy` implements CELF lazy evaluation (valid by
submodularity: stale marginal gains are upper bounds), which returns the
*identical* set to the eager greedy while evaluating far fewer marginals —
our selection-scaling benchmark quantifies the speedup.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass
from typing import Iterable, Mapping, Sequence

import numpy as np

from .predicates import Clause, Query


@dataclass(frozen=True)
class SelectionProblem:
    """Immutable problem instance: queries + per-clause selectivity & cost."""

    queries: tuple[Query, ...]
    sel: Mapping[Clause, float]
    cost: Mapping[Clause, float]
    budget: float

    def candidates(self) -> list[Clause]:
        seen: dict[Clause, None] = {}
        for q in self.queries:
            for c in q.clauses:
                if c in self.sel and c in self.cost:
                    seen.setdefault(c, None)
        return list(seen)


@dataclass
class SelectionResult:
    selected: list[Clause]
    objective: float
    total_cost: float
    algorithm: str
    evaluations: int = 0  # marginal-gain evaluations (CELF metric)

    def describe(self) -> str:
        return (
            f"{self.algorithm}: |S|={len(self.selected)} f(S)={self.objective:.4f} "
            f"cost={self.total_cost:.4f} evals={self.evaluations}"
        )


# ---------------------------------------------------------------------------
# objective
# ---------------------------------------------------------------------------

def objective(problem: SelectionProblem, S: Iterable[Clause]) -> float:
    Sset = set(S)
    total = 0.0
    for q in problem.queries:
        prod = 1.0
        for c in q.clauses:
            if c in Sset:
                prod *= problem.sel[c]
        total += q.freq * (1.0 - prod)
    return total


class _Marginals:
    """Incremental marginal-gain evaluation.

    Keeps per-query running product of selected clauses' selectivities so a
    marginal gain is O(#queries containing the clause).
    """

    def __init__(self, problem: SelectionProblem):
        self.problem = problem
        self.query_prod = [1.0] * len(problem.queries)
        self.by_clause: dict[Clause, list[int]] = {}
        for qi, q in enumerate(problem.queries):
            for c in q.clauses:
                self.by_clause.setdefault(c, []).append(qi)
        self.evaluations = 0

    def gain(self, c: Clause) -> float:
        self.evaluations += 1
        s = self.problem.sel[c]
        g = 0.0
        for qi in self.by_clause.get(c, ()):  # queries containing c
            g += self.problem.queries[qi].freq * self.query_prod[qi] * (1.0 - s)
        return g

    def add(self, c: Clause) -> None:
        s = self.problem.sel[c]
        for qi in self.by_clause.get(c, ()):
            self.query_prod[qi] *= s

    def objective_value(self) -> float:
        return sum(
            q.freq * (1.0 - p) for q, p in zip(self.problem.queries, self.query_prod)
        )


# ---------------------------------------------------------------------------
# Algorithms 1 & 2 (paper) — eager greedy
# ---------------------------------------------------------------------------

def greedy(problem: SelectionProblem, *, ratio: bool) -> SelectionResult:
    """Eager greedy.  ``ratio=False`` -> Alg.1 (max gain); True -> Alg.2."""
    marg = _Marginals(problem)
    remaining = set(problem.candidates())
    S: list[Clause] = []
    spent = 0.0
    while True:
        best_c, best_key = None, -np.inf
        for c in remaining:
            cost_c = problem.cost[c]
            if spent + cost_c > problem.budget + 1e-12:
                continue
            g = marg.gain(c)
            key = g / cost_c if ratio else g
            if key > best_key:
                best_key, best_c = key, c
        if best_c is None:
            break
        S.append(best_c)
        spent += problem.cost[best_c]
        marg.add(best_c)
        remaining.discard(best_c)
    return SelectionResult(
        selected=S,
        objective=marg.objective_value(),
        total_cost=spent,
        algorithm="ratio-greedy" if ratio else "naive-greedy",
        evaluations=marg.evaluations,
    )


def combined_greedy(problem: SelectionProblem) -> SelectionResult:
    """Paper §V-C: better of Alg.1 / Alg.2 — >= 0.316 * OPT."""
    a = greedy(problem, ratio=False)
    b = greedy(problem, ratio=True)
    best = a if a.objective >= b.objective else b
    return SelectionResult(
        selected=best.selected,
        objective=best.objective,
        total_cost=best.total_cost,
        algorithm=f"combined({best.algorithm})",
        evaluations=a.evaluations + b.evaluations,
    )


# ---------------------------------------------------------------------------
# CELF lazy greedy (beyond-paper optimization, identical output)
# ---------------------------------------------------------------------------

def _celf_run(problem: SelectionProblem, *, ratio: bool
              ) -> tuple[list[Clause], list[float], _Marginals]:
    """The CELF loop itself: selection order + cumulative costs + marginals.

    Shared by :func:`celf_greedy` (single budget) and :func:`tiered_celf`
    (nested budget cut-points over ONE run).
    """
    marg = _Marginals(problem)
    heap: list[tuple[float, int, Clause]] = []
    seq = itertools.count()
    for c in problem.candidates():
        g = marg.gain(c)
        key = g / problem.cost[c] if ratio else g
        heapq.heappush(heap, (-key, next(seq), c))
    S: list[Clause] = []
    cum_cost: list[float] = []
    spent = 0.0
    round_id = 0
    fresh: dict[Clause, int] = {c: 0 for c in problem.candidates()}
    while heap:
        negkey, sq, c = heapq.heappop(heap)
        if spent + problem.cost[c] > problem.budget + 1e-12:
            continue  # cannot afford; drop (cost is static, gain only shrinks)
        if fresh[c] == round_id:
            S.append(c)
            spent += problem.cost[c]
            cum_cost.append(spent)
            marg.add(c)
            round_id += 1
        else:
            g = marg.gain(c)
            key = g / problem.cost[c] if ratio else g
            fresh[c] = round_id
            heapq.heappush(heap, (-key, sq, c))
    return S, cum_cost, marg


def celf_greedy(problem: SelectionProblem, *, ratio: bool) -> SelectionResult:
    """Lazy greedy with a max-heap of stale gains (upper bounds).

    Submodularity guarantees a clause's marginal gain only decreases as S
    grows, so a heap entry whose gain was computed at the current round size
    is exact and safe to pop.  Ties are broken identically to the eager
    greedy (by heap order on (-key, seq)).
    """
    S, cum_cost, marg = _celf_run(problem, ratio=ratio)
    return SelectionResult(
        selected=S,
        objective=marg.objective_value(),
        total_cost=cum_cost[-1] if cum_cost else 0.0,
        algorithm="celf-ratio" if ratio else "celf-naive",
        evaluations=marg.evaluations,
    )


def combined_celf(problem: SelectionProblem) -> SelectionResult:
    a = celf_greedy(problem, ratio=False)
    b = celf_greedy(problem, ratio=True)
    best = a if a.objective >= b.objective else b
    return SelectionResult(
        selected=best.selected,
        objective=best.objective,
        total_cost=best.total_cost,
        algorithm=f"combined({best.algorithm})",
        evaluations=a.evaluations + b.evaluations,
    )


# ---------------------------------------------------------------------------
# multi-budget (tiered) selection — one CELF run, nested budget cut-points
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TieredSelection:
    """Nested budget tiers T0 ⊆ T1 ⊆ … ⊆ Tk from ONE CELF run.

    ``order`` is the greedy selection order under the TOP budget; tier *t*
    is the longest prefix whose cumulative cost fits ``budgets[t]``.  The
    greedy prefix property makes every tier the prefix-greedy solution for
    its own budget, and the nesting invariant Ti ⊆ Ti+1 holds by
    construction — which is what lets a fleet run unequal tiers against
    ONE clause universe (clause local ids are prefix-stable across tiers).
    """

    budgets: tuple[float, ...]      # ascending
    order: tuple[Clause, ...]       # greedy order under the top budget
    cum_costs: tuple[float, ...]    # cumulative cost after each selection
    tier_sizes: tuple[int, ...]     # |Tt|, non-decreasing, last == len(order)
    objectives: tuple[float, ...]   # f(Tt) per tier
    evaluations: int = 0

    @property
    def n_tiers(self) -> int:
        return len(self.budgets)

    def tier(self, t: int) -> tuple[Clause, ...]:
        return self.order[: self.tier_sizes[t]]

    def tier_cost(self, t: int) -> float:
        k = self.tier_sizes[t]
        return self.cum_costs[k - 1] if k else 0.0

    def describe(self) -> str:
        parts = [
            f"T{t}: |S|={self.tier_sizes[t]} f={self.objectives[t]:.4f} "
            f"cost={self.tier_cost(t):.3f}/{self.budgets[t]:.3f}"
            for t in range(self.n_tiers)
        ]
        return "tiered-celf  " + "  ".join(parts)


def tiered_celf(problem: SelectionProblem,
                budgets: Sequence[float], *, ratio: bool = True
                ) -> TieredSelection:
    """Solve every budget tier with ONE CELF run (paper §VI trade-off).

    ``problem.budget`` is ignored; the run uses ``max(budgets)``.  Budgets
    must be ascending.  Because CELF emits clauses in greedy order with
    monotone cumulative cost, cutting that order at each budget yields
    nested tiers — no per-tier re-solve, so a k-tier family costs the same
    marginal evaluations as the single top-budget solve.
    """
    if not budgets:
        raise ValueError("need at least one tier budget")
    bs = tuple(float(b) for b in budgets)
    if any(b < 0 for b in bs):
        raise ValueError(f"tier budgets must be non-negative: {bs}")
    if any(b2 < b1 for b1, b2 in zip(bs, bs[1:])):
        raise ValueError(f"tier budgets must be ascending: {bs}")
    top = SelectionProblem(queries=problem.queries, sel=problem.sel,
                           cost=problem.cost, budget=bs[-1])
    order, cum, marg = _celf_run(top, ratio=ratio)
    sizes = []
    for b in bs:
        k = 0
        while k < len(order) and cum[k] <= b + 1e-12:
            k += 1
        sizes.append(k)
    objectives = tuple(objective(problem, order[:k]) for k in sizes)
    return TieredSelection(
        budgets=bs, order=tuple(order), cum_costs=tuple(cum),
        tier_sizes=tuple(sizes), objectives=objectives,
        evaluations=marg.evaluations,
    )


# ---------------------------------------------------------------------------
# fleet tier allocation — split a GLOBAL client-cost budget across clients
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ClientProfile:
    """What the allocator knows about one client.

    ``cost_scale`` — measured µs spent per *modeled* µs of plan cost (a
    slow phone has scale ≫ 1; recalibrated online from per-shard timing
    reports).  ``weight`` — the client's share of ingested records per
    unit time (its data volume: savings from pushing a clause set to this
    client scale with how many records it contributes).
    """

    cost_scale: float = 1.0
    weight: float = 1.0


@dataclass
class TierAllocation:
    """Per-client tier assignment under a global cost budget."""

    tiers: list[int]            # tier index per client
    spent: float                # sum_j weight_j * scale_j * tier_cost[t_j]
    budget: float
    expected_savings: float     # sum_j weight_j * tier_value[t_j]
    upgrades: int = 0           # greedy upgrade steps taken

    @property
    def feasible(self) -> bool:
        return self.spent <= self.budget + 1e-9

    def describe(self) -> str:
        return (f"tiers={self.tiers} spent={self.spent:.3f}/"
                f"{self.budget:.3f} savings={self.expected_savings:.4f}")


def allocate_tiers(
    tier_costs: Sequence[float],
    tier_values: Sequence[float],
    clients: Sequence[ClientProfile],
    budget: float,
) -> TierAllocation:
    """Maximize expected server savings under a global client-cost budget.

    Multiple-choice knapsack over the nested tiers: every client starts at
    tier 0 and greedy upgrades are applied in order of marginal savings per
    marginal cost, ``weight_j * Δvalue / (weight_j * scale_j * Δcost)``.
    Along a CELF prefix the per-tier value increments are diminishing
    (submodularity), so each client's upgrade ratios are non-increasing
    and the greedy matches the LP-relaxation optimum up to one fractional
    upgrade — the classical MCKP argument.

    A client whose next upgrade does not fit is frozen (its later upgrades
    are nested behind the unaffordable one).  Tier 0 is never refused: if
    even the floor exceeds the budget the allocation is returned as-is
    with ``feasible == False`` (the caller should widen the family or the
    budget rather than silently dropping clients).
    """
    k = len(tier_costs)
    if k != len(tier_values):
        raise ValueError("tier_costs and tier_values must have equal length")
    if any(c2 < c1 for c1, c2 in zip(tier_costs, tier_costs[1:])):
        raise ValueError("tier costs must be non-decreasing (nested tiers)")
    tiers = [0] * len(clients)
    spent = sum(cl.weight * cl.cost_scale * tier_costs[0] for cl in clients)
    savings = sum(cl.weight * tier_values[0] for cl in clients)
    heap: list[tuple[float, int]] = []

    def push_upgrade(j: int) -> None:
        t = tiers[j]
        if t + 1 >= k:
            return
        cl = clients[j]
        dv = cl.weight * (tier_values[t + 1] - tier_values[t])
        dc = cl.weight * cl.cost_scale * (tier_costs[t + 1] - tier_costs[t])
        if dc <= 0.0:  # free upgrade (identical tier cut): take it outright
            ratio = np.inf
        else:
            ratio = dv / dc
        heapq.heappush(heap, (-ratio, j))

    for j in range(len(clients)):
        push_upgrade(j)
    upgrades = 0
    while heap:
        _, j = heapq.heappop(heap)
        t = tiers[j]
        if t + 1 >= k:
            continue
        cl = clients[j]
        dc = cl.weight * cl.cost_scale * (tier_costs[t + 1] - tier_costs[t])
        if spent + dc > budget + 1e-9:
            continue  # frozen: nested upgrades behind this one cost >= dc
        tiers[j] = t + 1
        spent += dc
        savings += cl.weight * (tier_values[t + 1] - tier_values[t])
        upgrades += 1
        push_upgrade(j)
    return TierAllocation(tiers=tiers, spent=spent, budget=float(budget),
                          expected_savings=savings, upgrades=upgrades)


# ---------------------------------------------------------------------------
# exact OPT (tests only — exponential)
# ---------------------------------------------------------------------------

def brute_force(problem: SelectionProblem, max_candidates: int = 18) -> SelectionResult:
    cands = problem.candidates()
    if len(cands) > max_candidates:
        raise ValueError(f"brute force capped at {max_candidates} candidates")
    best_S: tuple[Clause, ...] = ()
    best_f = 0.0
    for r in range(len(cands) + 1):
        for S in itertools.combinations(cands, r):
            if sum(problem.cost[c] for c in S) > problem.budget + 1e-12:
                continue
            fS = objective(problem, S)
            if fS > best_f:
                best_f, best_S = fS, S
    return SelectionResult(
        selected=list(best_S),
        objective=best_f,
        total_cost=sum(problem.cost[c] for c in best_S),
        algorithm="brute-force",
    )
