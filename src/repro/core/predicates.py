"""Predicate AST and pattern-string compilation (paper §IV).

CIAO supports four predicate kinds, each compiled to one or two *pattern
strings* that a client can evaluate by raw substring search over JSON bytes
(no parsing).  Client evaluation may produce false positives (a query
re-verifies on parsed values at scan time) but NEVER false negatives — this
is the invariant the whole system rests on, and the one our property tests
enforce.

Terminology follows the paper:
  * ``SimplePredicate`` — one string-matchable SQL predicate (Table I).
  * ``Clause`` — a disjunction of simple predicates; the *atomic unit* of
    pushdown (paper §V-A: each conjunctive clause is pushed whole or not at
    all, because pushing one disjunct of an IN-list cannot filter tuples).
  * ``Query`` — a conjunction of clauses.
"""
from __future__ import annotations

import enum
import json
from dataclasses import dataclass
from typing import Any, Iterable, Sequence


class Kind(enum.Enum):
    EXACT = "exact"             # name = "Bob"          -> pattern '"Bob"'
    SUBSTRING = "substring"     # text LIKE "%x%"       -> pattern 'x'
    KEY_PRESENCE = "presence"   # email != NULL         -> pattern '"email"'
    KEY_VALUE = "key_value"     # age = 10              -> patterns '"age"', '10'


def _enc(s: str) -> bytes:
    return s.encode("utf-8")


@dataclass(frozen=True, eq=False)
class SimplePredicate:
    """One string-matchable predicate over a JSON record."""

    kind: Kind
    key: str
    value: Any = None  # str | int | float | bool | None

    # Equality is TYPE-STRICT on the value: Python's cross-type numeric
    # equality (10 == 10.0 == True) would alias predicates whose exact
    # semantics differ — ``json_scalar(10)`` is "10" but
    # ``json_scalar(10.0)`` is "10.0", so ``score = 10`` matches a string
    # row "10" while ``score = 10.0`` does not.  Clause caches and the
    # pushed-clause lookup (``PushdownPlan.pushed_in``) key on predicate
    # equality, so aliasing would let an earlier query's cached mask or
    # bitvector answer a later, semantically different one.
    def __eq__(self, other: object):
        if not isinstance(other, SimplePredicate):
            return NotImplemented
        return (self.kind is other.kind and self.key == other.key
                and type(self.value) is type(other.value)
                and self.value == other.value)

    def __hash__(self) -> int:
        return hash((self.kind, self.key, type(self.value), self.value))

    # ---- pattern compilation (paper Table I) -------------------------------
    def patterns(self) -> tuple[bytes, ...]:
        if self.kind is Kind.EXACT:
            # Exact string match: operand string including JSON quotes.
            return (_enc(f'"{self.value}"'),)
        if self.kind is Kind.SUBSTRING:
            return (_enc(str(self.value)),)
        if self.kind is Kind.KEY_PRESENCE:
            return (_enc(f'"{self.key}"'),)
        if self.kind is Kind.KEY_VALUE:
            return (_enc(f'"{self.key}"'), _enc(_json_scalar(self.value)))
        raise AssertionError(self.kind)

    # ---- client-side semantics (string search, false-positive tolerant) ----
    def matches_raw(self, record: bytes) -> bool:
        """Paper-faithful ``string::find`` evaluation on raw JSON bytes."""
        pats = self.patterns()
        if self.kind is Kind.KEY_VALUE:
            key_pat, val_pat = pats
            # Search every occurrence of the key; for each, look for the
            # value between the end of the key and the next delimiter
            # (',' or '}').  Checking every occurrence (not just the first)
            # is required to keep the no-false-negative invariant when the
            # key string also appears inside a text field.
            # Values that themselves contain a delimiter could be cut short
            # by the segment search and yield a false negative; for those we
            # degrade to "value appears anywhere after the key" (more false
            # positives, never a false negative).
            unbounded = b"," in val_pat or b"}" in val_pat
            start = record.find(key_pat)
            while start != -1:
                seg_start = start + len(key_pat)
                if unbounded:
                    seg_end = len(record)
                else:
                    c = record.find(b",", seg_start)
                    b = record.find(b"}", seg_start)
                    cands = [x for x in (c, b) if x != -1]
                    seg_end = min(cands) if cands else len(record)
                if record.find(val_pat, seg_start, seg_end) != -1:
                    return True
                start = record.find(key_pat, start + 1)
            return False
        return pats[0] in record

    # ---- exact semantics on a parsed record (server-side verification) -----
    def matches_exact(self, obj: dict) -> bool:
        if self.kind is Kind.KEY_PRESENCE:
            return self.key in obj and obj[self.key] is not None
        if self.key not in obj:
            return False
        v = obj[self.key]
        # bool/number equality across representations is unsupported (paper
        # §IV-B excludes e.g. 2.4 vs 24e-1 for the same reason: the raw
        # pattern cannot match, so allowing it would be a false negative).
        if isinstance(v, bool) != isinstance(self.value, bool):
            return False
        if self.kind is Kind.EXACT:
            return v == self.value
        if self.kind is Kind.SUBSTRING:
            return isinstance(v, str) and str(self.value) in v
        if self.kind is Kind.KEY_VALUE:
            return v == self.value or _json_scalar(self.value) == _json_scalar(v)
        raise AssertionError(self.kind)

    def pattern_length(self) -> int:
        return sum(len(p) for p in self.patterns())

    def describe(self) -> str:
        if self.kind is Kind.EXACT:
            return f'{self.key} = "{self.value}"'
        if self.kind is Kind.SUBSTRING:
            return f'{self.key} LIKE "%{self.value}%"'
        if self.kind is Kind.KEY_PRESENCE:
            return f"{self.key} != NULL"
        return f"{self.key} = {_json_scalar(self.value)}"


def _json_scalar(v: Any) -> str:
    """Render a scalar the way our JSON writer renders it (for pattern gen)."""
    if isinstance(v, bool):
        return "true" if v else "false"
    if isinstance(v, (int, float)):
        return json.dumps(v)
    return str(v)


# public alias: the columnar scan engine dictionary-encodes json_scalar(v)
# per key (the "repr column"), which is what keeps KEY_VALUE's
# cross-representation equality exact without per-row parsing
json_scalar = _json_scalar


def lowerable(p: SimplePredicate) -> bool:
    """True iff ``p`` can be lowered to vectorized columnar evaluation.

    The lowering (``repro.core.columnar.eval_lowered``) reproduces
    ``matches_exact`` bit for bit over struct-of-arrays columns, but only
    for the value shapes it models: scalar JSON values.  Anything else
    (non-string EXACT operands, exotic KEY_VALUE value objects) falls
    back to the per-row exact oracle — never evaluated wrong, just not
    vectorized.
    """
    if p.kind in (Kind.KEY_PRESENCE, Kind.SUBSTRING):
        return True
    if p.kind is Kind.EXACT:
        return isinstance(p.value, str)
    if p.kind is Kind.KEY_VALUE:
        return p.value is None or isinstance(p.value, (str, int, float, bool))
    return False


def clause_lowerable(c: Clause) -> bool:
    """True iff every disjunct of ``c`` lowers to columnar evaluation."""
    return all(lowerable(t) for t in c.terms)


@dataclass(frozen=True)
class Clause:
    """A disjunction of simple predicates — the atomic pushdown unit."""

    terms: tuple[SimplePredicate, ...]

    def __post_init__(self) -> None:
        if not self.terms:
            raise ValueError("empty clause")

    # Client semantics: valid iff ANY disjunct pattern-matches.
    def matches_raw(self, record: bytes) -> bool:
        return any(t.matches_raw(record) for t in self.terms)

    def matches_exact(self, obj: dict) -> bool:
        return any(t.matches_exact(obj) for t in self.terms)

    def patterns(self) -> tuple[tuple[bytes, ...], ...]:
        return tuple(t.patterns() for t in self.terms)

    def pattern_length(self) -> int:
        return sum(t.pattern_length() for t in self.terms)

    def describe(self) -> str:
        if len(self.terms) == 1:
            return self.terms[0].describe()
        return "(" + " OR ".join(t.describe() for t in self.terms) + ")"

    # Clauses are dict keys throughout the optimizer.
    def __hash__(self) -> int:  # pragma: no cover - trivial
        return hash(self.terms)


@dataclass(frozen=True)
class Query:
    """A conjunction of clauses with a workload frequency weight."""

    clauses: tuple[Clause, ...]
    freq: float = 1.0

    def matches_exact(self, obj: dict) -> bool:
        return all(c.matches_exact(obj) for c in self.clauses)

    def describe(self) -> str:
        return " AND ".join(c.describe() for c in self.clauses)


# ---------------------------------------------------------------------------
# convenience constructors
# ---------------------------------------------------------------------------

def exact(key: str, value: str) -> SimplePredicate:
    return SimplePredicate(Kind.EXACT, key, value)


def substring(key: str, value: str) -> SimplePredicate:
    return SimplePredicate(Kind.SUBSTRING, key, value)


def presence(key: str) -> SimplePredicate:
    return SimplePredicate(Kind.KEY_PRESENCE, key)


def key_value(key: str, value: Any) -> SimplePredicate:
    return SimplePredicate(Kind.KEY_VALUE, key, value)


def clause(*terms: SimplePredicate) -> Clause:
    return Clause(tuple(terms))


def query(*clauses_: Clause | SimplePredicate, freq: float = 1.0) -> Query:
    cs = tuple(c if isinstance(c, Clause) else Clause((c,)) for c in clauses_)
    return Query(cs, freq=freq)


# ---------------------------------------------------------------------------
# JSON-safe (de)serialization — plan persistence (server checkpoints)
# ---------------------------------------------------------------------------

def predicate_to_obj(p: SimplePredicate) -> dict:
    return {"kind": p.kind.value, "key": p.key, "value": p.value}


def predicate_from_obj(d: dict) -> SimplePredicate:
    return SimplePredicate(Kind(d["kind"]), d["key"], d.get("value"))


def clause_to_obj(c: Clause) -> list[dict]:
    return [predicate_to_obj(t) for t in c.terms]


def clause_from_obj(terms: Sequence[dict]) -> Clause:
    return Clause(tuple(predicate_from_obj(t) for t in terms))


def all_patterns(clauses_: Iterable[Clause]) -> list[bytes]:
    """Flat, deduplicated pattern list for a set of clauses (kernel input)."""
    seen: dict[bytes, None] = {}
    for c in clauses_:
        for term_pats in c.patterns():
            for p in term_pats:
                seen.setdefault(p, None)
    return list(seen)
