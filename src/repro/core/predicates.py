"""Predicate AST and pattern-string compilation (paper §IV).

CIAO supports four predicate kinds, each compiled to one or two *pattern
strings* that a client can evaluate by raw substring search over JSON bytes
(no parsing).  Client evaluation may produce false positives (a query
re-verifies on parsed values at scan time) but NEVER false negatives — this
is the invariant the whole system rests on, and the one our property tests
enforce.

Terminology follows the paper:
  * ``SimplePredicate`` — one string-matchable SQL predicate (Table I).
  * ``Clause`` — a disjunction of simple predicates; the *atomic unit* of
    pushdown (paper §V-A: each conjunctive clause is pushed whole or not at
    all, because pushing one disjunct of an IN-list cannot filter tuples).
  * ``Query`` — a conjunction of clauses.
"""
from __future__ import annotations

import enum
import json
from dataclasses import dataclass
from typing import Any, Iterable, Sequence


class Kind(enum.Enum):
    EXACT = "exact"             # name = "Bob"          -> pattern '"Bob"'
    SUBSTRING = "substring"     # text LIKE "%x%"       -> pattern 'x'
    KEY_PRESENCE = "presence"   # email != NULL         -> pattern '"email"'
    KEY_VALUE = "key_value"     # age = 10              -> patterns '"age"', '10'
    RANGE = "range"             # 10 <= age < 20        -> pattern '"age"'
    IN = "in"                   # age IN (1, 2, 3)      -> pattern '"age"'


def _enc(s: str) -> bytes:
    return s.encode("utf-8")


def _strict_key(v: Any):
    """Hashable key carrying the value AND its type, recursively.

    ``10 == 10.0 == True`` under Python equality, and for composite
    values ``(10,) == (10.0,)`` — so RANGE bound tuples and IN element
    tuples must be keyed per-element as ``(type, value)`` pairs or two
    semantically different predicates would share cache slots
    (``ResultCache``, ``PushdownPlan.pushed_in``, clause-mask memos).
    """
    if isinstance(v, tuple):
        return ("t",) + tuple(_strict_key(e) for e in v)
    return (type(v), v)


@dataclass(frozen=True, eq=False)
class SimplePredicate:
    """One string-matchable predicate over a JSON record."""

    kind: Kind
    key: str
    value: Any = None  # str | int | float | bool | None

    # Equality is TYPE-STRICT on the value: Python's cross-type numeric
    # equality (10 == 10.0 == True) would alias predicates whose exact
    # semantics differ — ``json_scalar(10)`` is "10" but
    # ``json_scalar(10.0)`` is "10.0", so ``score = 10`` matches a string
    # row "10" while ``score = 10.0`` does not.  Clause caches and the
    # pushed-clause lookup (``PushdownPlan.pushed_in``) key on predicate
    # equality, so aliasing would let an earlier query's cached mask or
    # bitvector answer a later, semantically different one.  Strictness
    # recurses into tuple values (RANGE bounds, IN elements) via
    # ``_strict_key``: ``IN (10,)`` and ``IN (10.0,)`` differ the same
    # way the scalars do.
    def __eq__(self, other: object):
        if not isinstance(other, SimplePredicate):
            return NotImplemented
        return (self.kind is other.kind and self.key == other.key
                and _strict_key(self.value) == _strict_key(other.value))

    def __hash__(self) -> int:
        h = self.__dict__.get("_hash")
        if h is None:
            h = hash((self.kind, self.key, _strict_key(self.value)))
            object.__setattr__(self, "_hash", h)
        return h

    # ---- pattern compilation (paper Table I) -------------------------------
    def patterns(self) -> tuple[bytes, ...]:
        # Memoized per instance (predicates are frozen): the client hot
        # path calls this per (record, term) and byte-encoding the same
        # strings every call dominated `matches_raw` on short records.
        pats = self.__dict__.get("_patterns")
        if pats is None:
            pats = self._compile_patterns()
            object.__setattr__(self, "_patterns", pats)
        return pats

    def _compile_patterns(self) -> tuple[bytes, ...]:
        if self.kind is Kind.EXACT:
            # Exact string match: operand string including JSON quotes.
            return (_enc(f'"{self.value}"'),)
        if self.kind is Kind.SUBSTRING:
            return (_enc(str(self.value)),)
        if self.kind is Kind.KEY_PRESENCE:
            return (_enc(f'"{self.key}"'),)
        if self.kind is Kind.KEY_VALUE:
            return (_enc(f'"{self.key}"'), _enc(_json_scalar(self.value)))
        if self.kind in (Kind.RANGE, Kind.IN):
            # A value pattern cannot express a range or a disjunction of
            # encodings, so the client degrades to key presence — more
            # false positives, never a false negative (the invariant all
            # four engines share); the server's exact residual catches
            # the rest.
            return (_enc(f'"{self.key}"'),)
        raise AssertionError(self.kind)

    # ---- client-side semantics (string search, false-positive tolerant) ----
    def matches_raw(self, record: bytes) -> bool:
        """Paper-faithful ``string::find`` evaluation on raw JSON bytes."""
        pats = self.patterns()
        if self.kind is Kind.KEY_VALUE:
            key_pat, val_pat = pats
            # Search every occurrence of the key; for each, look for the
            # value between the end of the key and the next delimiter
            # (',' or '}').  Checking every occurrence (not just the first)
            # is required to keep the no-false-negative invariant when the
            # key string also appears inside a text field.
            # Values that themselves contain a delimiter could be cut short
            # by the segment search and yield a false negative; for those we
            # degrade to "value appears anywhere after the key" (more false
            # positives, never a false negative).
            unbounded = b"," in val_pat or b"}" in val_pat
            start = record.find(key_pat)
            while start != -1:
                seg_start = start + len(key_pat)
                if unbounded:
                    seg_end = len(record)
                else:
                    c = record.find(b",", seg_start)
                    b = record.find(b"}", seg_start)
                    cands = [x for x in (c, b) if x != -1]
                    seg_end = min(cands) if cands else len(record)
                if record.find(val_pat, seg_start, seg_end) != -1:
                    return True
                start = record.find(key_pat, start + 1)
            return False
        return pats[0] in record

    # ---- exact semantics on a parsed record (server-side verification) -----
    def matches_exact(self, obj: dict) -> bool:
        if self.kind is Kind.KEY_PRESENCE:
            return self.key in obj and obj[self.key] is not None
        if self.key not in obj:
            return False
        v = obj[self.key]
        if self.kind is Kind.RANGE:
            return range_contains(self.value, v)
        if self.kind is Kind.IN:
            # OR of per-element KEY_VALUE semantics (type-strict, §IV-B
            # cross-representation equality per element).
            return any(_kv_matches(v, e) for e in self.value)
        # bool/number equality across representations is unsupported (paper
        # §IV-B excludes e.g. 2.4 vs 24e-1 for the same reason: the raw
        # pattern cannot match, so allowing it would be a false negative).
        if isinstance(v, bool) != isinstance(self.value, bool):
            return False
        if self.kind is Kind.EXACT:
            return v == self.value
        if self.kind is Kind.SUBSTRING:
            return isinstance(v, str) and str(self.value) in v
        if self.kind is Kind.KEY_VALUE:
            return v == self.value or _json_scalar(self.value) == _json_scalar(v)
        raise AssertionError(self.kind)

    def pattern_length(self) -> int:
        return sum(len(p) for p in self.patterns())

    def describe(self) -> str:
        if self.kind is Kind.EXACT:
            return f'{self.key} = "{self.value}"'
        if self.kind is Kind.SUBSTRING:
            return f'{self.key} LIKE "%{self.value}%"'
        if self.kind is Kind.KEY_PRESENCE:
            return f"{self.key} != NULL"
        if self.kind is Kind.RANGE:
            lo, hi, lo_i, hi_i = self.value
            parts = []
            if lo is not None:
                parts.append(f"{self.key} >{'=' if lo_i else ''} "
                             f"{_json_scalar(lo)}")
            if hi is not None:
                parts.append(f"{self.key} <{'=' if hi_i else ''} "
                             f"{_json_scalar(hi)}")
            return " AND ".join(parts)
        if self.kind is Kind.IN:
            vals = ", ".join(_json_scalar(e) for e in self.value)
            return f"{self.key} IN ({vals})"
        return f"{self.key} = {_json_scalar(self.value)}"


def _kv_matches(v: Any, pv: Any) -> bool:
    """One KEY_VALUE disjunct of an IN list: exact §IV-B equality of a
    row value ``v`` against a probe element ``pv``."""
    if isinstance(v, bool) != isinstance(pv, bool):
        return False
    return v == pv or _json_scalar(pv) == _json_scalar(v)


def range_contains(bounds: tuple, v: Any) -> bool:
    """Exact RANGE semantics: does row value ``v`` fall in ``bounds``?

    ``bounds`` is ``(lo, hi, lo_incl, hi_incl)`` with ``None`` for an
    open side.  Numeric rows (bool excluded) compare directly — Python
    comparisons between huge ints and float bounds are exact, and NaN
    fails every comparison so it never matches.  String rows match iff
    they parse as a JSON number in range (the cross-representation rule:
    ``"10"`` satisfies ``score BETWEEN 5 AND 15`` just as KEY_VALUE's
    ``score = 10`` matches the string row ``"10"``).  Everything else
    (bool, None, objects) never matches.
    """
    lo, hi, lo_i, hi_i = bounds
    if isinstance(v, bool) or v is None:
        return False
    if isinstance(v, (int, float)):
        x = v
    elif isinstance(v, str):
        x = json_number(v)
        if x is None:
            return False
    else:
        return False
    if lo is not None and not (x > lo or (lo_i and x == lo)):
        return False
    if hi is not None and not (x < hi or (hi_i and x == hi)):
        return False
    return True


_JSON_NUMBER_CACHE: dict[str, Any] = {}
_JSON_NUMBER_CACHE_CAP = 4096


def json_number(s: str) -> "int | float | None":
    """Parse ``s`` as a JSON number; ``None`` if it is not one.

    This is THE rule deciding which strings participate in numeric RANGE
    semantics — shared by ``matches_exact``, the vectorized lowering, and
    both summary levels so they can never disagree.  ``json.loads`` keeps
    int parses arbitrary-precision (huge ints stay exact) and rejects
    non-JSON spellings like ``"007"`` or ``"1_0"``; Python's reader also
    accepts the ``NaN``/``Infinity`` extended tokens, which is fine — NaN
    fails every range and infinities compare correctly.  Memoized with a
    fresh-dict eviction (concurrent scan threads may hold the old dict).
    """
    global _JSON_NUMBER_CACHE
    if s in _JSON_NUMBER_CACHE:
        return _JSON_NUMBER_CACHE[s]
    try:
        v = json.loads(s)
    except Exception:
        v = None
    if isinstance(v, bool) or not isinstance(v, (int, float)):
        v = None
    if len(_JSON_NUMBER_CACHE) >= _JSON_NUMBER_CACHE_CAP:
        _JSON_NUMBER_CACHE = {}
    _JSON_NUMBER_CACHE[s] = v
    return v


def _json_scalar(v: Any) -> str:
    """Render a scalar the way our JSON writer renders it (for pattern gen)."""
    if isinstance(v, bool):
        return "true" if v else "false"
    if isinstance(v, (int, float)):
        return json.dumps(v)
    return str(v)


# public alias: the columnar scan engine dictionary-encodes json_scalar(v)
# per key (the "repr column"), which is what keeps KEY_VALUE's
# cross-representation equality exact without per-row parsing
json_scalar = _json_scalar


def lowerable(p: SimplePredicate) -> bool:
    """True iff ``p`` can be lowered to vectorized columnar evaluation.

    The lowering (``repro.core.columnar.eval_lowered``) reproduces
    ``matches_exact`` bit for bit over struct-of-arrays columns, but only
    for the value shapes it models: scalar JSON values.  Anything else
    (non-string EXACT operands, exotic KEY_VALUE value objects) falls
    back to the per-row exact oracle — never evaluated wrong, just not
    vectorized.
    """
    if p.kind in (Kind.KEY_PRESENCE, Kind.SUBSTRING):
        return True
    if p.kind is Kind.EXACT:
        return isinstance(p.value, str)
    if p.kind is Kind.KEY_VALUE:
        return p.value is None or isinstance(p.value, (str, int, float, bool))
    if p.kind is Kind.RANGE:
        return True
    if p.kind is Kind.IN:
        return all(e is None or isinstance(e, (str, int, float, bool))
                   for e in p.value)
    return False


def clause_lowerable(c: Clause) -> bool:
    """True iff every disjunct of ``c`` lowers to columnar evaluation."""
    return all(lowerable(t) for t in c.terms)


@dataclass(frozen=True)
class Clause:
    """A disjunction of simple predicates — the atomic pushdown unit."""

    terms: tuple[SimplePredicate, ...]

    def __post_init__(self) -> None:
        if not self.terms:
            raise ValueError("empty clause")

    # Client semantics: valid iff ANY disjunct pattern-matches.
    def matches_raw(self, record: bytes) -> bool:
        return any(t.matches_raw(record) for t in self.terms)

    def matches_exact(self, obj: dict) -> bool:
        return any(t.matches_exact(obj) for t in self.terms)

    def patterns(self) -> tuple[tuple[bytes, ...], ...]:
        return tuple(t.patterns() for t in self.terms)

    def pattern_length(self) -> int:
        return sum(t.pattern_length() for t in self.terms)

    def describe(self) -> str:
        if len(self.terms) == 1:
            return self.terms[0].describe()
        return "(" + " OR ".join(t.describe() for t in self.terms) + ")"

    # Clauses are dict keys throughout the optimizer.
    def __hash__(self) -> int:  # pragma: no cover - trivial
        return hash(self.terms)


@dataclass(frozen=True)
class Query:
    """A conjunction of clauses with a workload frequency weight."""

    clauses: tuple[Clause, ...]
    freq: float = 1.0

    def matches_exact(self, obj: dict) -> bool:
        return all(c.matches_exact(obj) for c in self.clauses)

    def describe(self) -> str:
        return " AND ".join(c.describe() for c in self.clauses)


# ---------------------------------------------------------------------------
# convenience constructors
# ---------------------------------------------------------------------------

def exact(key: str, value: str) -> SimplePredicate:
    return SimplePredicate(Kind.EXACT, key, value)


def substring(key: str, value: str) -> SimplePredicate:
    return SimplePredicate(Kind.SUBSTRING, key, value)


def presence(key: str) -> SimplePredicate:
    return SimplePredicate(Kind.KEY_PRESENCE, key)


def key_value(key: str, value: Any) -> SimplePredicate:
    return SimplePredicate(Kind.KEY_VALUE, key, value)


def rng(key: str, lo: "int | float | None" = None,
        hi: "int | float | None" = None, *,
        lo_incl: bool = True, hi_incl: bool = True) -> SimplePredicate:
    """RANGE predicate: ``lo <(=) key <(=) hi`` (``None`` = open side)."""
    for b in (lo, hi):
        if b is None:
            continue
        if isinstance(b, bool) or not isinstance(b, (int, float)):
            raise TypeError(f"range bound must be numeric or None: {b!r}")
        if b != b:
            raise ValueError("NaN range bound")
    if lo is None and hi is None:
        raise ValueError("range needs at least one bound")
    return SimplePredicate(Kind.RANGE, key,
                           (lo, hi, bool(lo_incl), bool(hi_incl)))


def between(key: str, lo: "int | float", hi: "int | float"
            ) -> SimplePredicate:
    """SQL BETWEEN: both bounds inclusive."""
    return rng(key, lo, hi)


def in_list(key: str, values: Iterable[Any]) -> SimplePredicate:
    """IN-list predicate: OR of per-element KEY_VALUE equality."""
    vals = tuple(values)
    if not vals:
        raise ValueError("empty IN list")
    return SimplePredicate(Kind.IN, key, vals)


def clause(*terms: SimplePredicate) -> Clause:
    return Clause(tuple(terms))


def query(*clauses_: Clause | SimplePredicate, freq: float = 1.0) -> Query:
    cs = tuple(c if isinstance(c, Clause) else Clause((c,)) for c in clauses_)
    return Query(cs, freq=freq)


# ---------------------------------------------------------------------------
# JSON-safe (de)serialization — plan persistence (server checkpoints)
# ---------------------------------------------------------------------------

def predicate_to_obj(p: SimplePredicate) -> dict:
    v = p.value
    if isinstance(v, tuple):
        v = list(v)   # RANGE bounds / IN elements: JSON arrays
    return {"kind": p.kind.value, "key": p.key, "value": v}


def predicate_from_obj(d: dict) -> SimplePredicate:
    k = Kind(d["kind"])
    v = d.get("value")
    if k in (Kind.RANGE, Kind.IN) and isinstance(v, list):
        v = tuple(v)
    return SimplePredicate(k, d["key"], v)


def clause_to_obj(c: Clause) -> list[dict]:
    return [predicate_to_obj(t) for t in c.terms]


def clause_from_obj(terms: Sequence[dict]) -> Clause:
    return Clause(tuple(predicate_from_obj(t) for t in terms))


def all_patterns(clauses_: Iterable[Clause]) -> list[bytes]:
    """Flat, deduplicated pattern list for a set of clauses (kernel input)."""
    seen: dict[bytes, None] = {}
    for c in clauses_:
        for term_pats in c.patterns():
            for p in term_pats:
                seen.setdefault(p, None)
    return list(seen)
