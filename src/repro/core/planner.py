"""End-to-end CIAO planning (paper §III Step 1).

Inputs: a query workload, a record sample, a client computation budget
(µs/record), and a calibrated cost model.  Output: a :class:`PushdownPlan`
with per-clause ids and pattern strings, ready to ship to clients.

Per-client budgets: the paper (§I, abstract) notes CIAO "will address the
trade-off between client cost and server savings by setting different budgets
for different clients".  :func:`plan_for_clients` supports a budget per client
class — each class gets its own knapsack solve over the same workload stats,
so under-powered clients push fewer predicates (possibly none).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Mapping, Sequence

from .cost_model import CostModel
from .predicates import Clause
from .selection import (
    SelectionProblem,
    SelectionResult,
    TieredSelection,
    combined_celf,
    combined_greedy,
    tiered_celf,
)
from .server import PlanFamily, PushdownPlan
from .workload import Workload, estimate_selectivities


@dataclass
class PlanReport:
    plan: PushdownPlan
    selection: SelectionResult
    sel: dict[Clause, float]
    cost: dict[Clause, float]
    budget_us: float

    def describe(self) -> str:
        lines = [
            f"budget={self.budget_us:.3f}us  {self.selection.describe()}",
        ]
        for c in self.plan.clauses:
            lines.append(
                f"  id={self.plan.ids[c]} sel={self.sel[c]:.4f} "
                f"cost={self.cost[c]:.4f}us  {c.describe()}"
            )
        return "\n".join(lines)


def build_plan(
    workload: Workload,
    sample_records: Sequence[bytes],
    *,
    budget_us: float,
    cost_model: CostModel | None = None,
    algorithm: str = "celf",
    sel: Mapping[Clause, float] | None = None,
) -> PlanReport:
    """Estimate stats, solve the budgeted selection, emit the plan."""
    cost_model = cost_model or CostModel()
    pool = workload.clause_pool()
    sel_map = dict(sel) if sel is not None else estimate_selectivities(pool, sample_records)
    cost_map = {c: cost_model.clause_cost(c, sel_map[c]) for c in pool}
    problem = SelectionProblem(
        queries=tuple(workload.queries),
        sel=sel_map,
        cost=cost_map,
        budget=budget_us,
    )
    solver = combined_celf if algorithm == "celf" else combined_greedy
    result = solver(problem)
    plan = PushdownPlan(clauses=list(result.selected))
    return PlanReport(
        plan=plan, selection=result, sel=sel_map, cost=cost_map, budget_us=budget_us
    )


@dataclass
class FamilyReport:
    """A :class:`PlanFamily` plus the stats it was solved from."""

    family: PlanFamily
    tiered: TieredSelection
    sel: dict[Clause, float]
    cost: dict[Clause, float]

    @property
    def plan(self) -> PushdownPlan:
        return self.family.plan

    def describe(self) -> str:
        lines = [self.tiered.describe()]
        sizes = self.family.tier_sizes
        for i, c in enumerate(self.family.plan.clauses):
            tier = next(t for t, s in enumerate(sizes) if i < s)
            lines.append(
                f"  id={i} tier>={tier} sel={self.sel[c]:.4f} "
                f"cost={self.cost[c]:.4f}us  {c.describe()}"
            )
        return "\n".join(lines)


def build_plan_family(
    workload: Workload,
    sample_records: Sequence[bytes],
    *,
    tier_budgets_us: Sequence[float],
    cost_model: CostModel | None = None,
    sel: Mapping[Clause, float] | None = None,
) -> FamilyReport:
    """Solve every budget tier with ONE CELF run -> nested plan family.

    The paper's per-client-budget trade-off (§VI) without per-class
    re-solves: ``tiered_celf`` cuts the top-budget greedy order at each
    budget, so tier *t* is the prefix-greedy solution for
    ``tier_budgets_us[t]`` and T0 ⊆ T1 ⊆ … ⊆ Tk by construction.  The
    returned family's ``tier_costs``/``tier_values`` feed the fleet
    allocator (``selection.allocate_tiers``).
    """
    cost_model = cost_model or CostModel()
    pool = workload.clause_pool()
    sel_map = (dict(sel) if sel is not None
               else estimate_selectivities(pool, sample_records))
    cost_map = {c: cost_model.clause_cost(c, sel_map[c]) for c in pool}
    problem = SelectionProblem(
        queries=tuple(workload.queries),
        sel=sel_map,
        cost=cost_map,
        budget=max(tier_budgets_us),
    )
    tiered = tiered_celf(problem, tier_budgets_us)
    plan = PushdownPlan(clauses=list(tiered.order))
    family = PlanFamily(
        plan=plan,
        tier_sizes=tiered.tier_sizes,
        budgets=tiered.budgets,
        tier_costs=tuple(tiered.tier_cost(t) for t in range(tiered.n_tiers)),
        tier_values=tiered.objectives,
    )
    return FamilyReport(family=family, tiered=tiered, sel=sel_map,
                        cost=cost_map)


def plan_for_clients(
    workload: Workload,
    sample_records: Sequence[bytes],
    *,
    client_budgets_us: Mapping[str, float],
    cost_model: CostModel | None = None,
    algorithm: str = "celf",
) -> dict[str, PlanReport]:
    """One plan per client class (heterogeneous-budget deployment)."""
    cost_model = cost_model or CostModel()
    pool = workload.clause_pool()
    sel_map = estimate_selectivities(pool, sample_records)
    return {
        cls: build_plan(
            workload,
            sample_records,
            budget_us=b,
            cost_model=cost_model,
            algorithm=algorithm,
            sel=sel_map,
        )
        for cls, b in client_budgets_us.items()
    }
