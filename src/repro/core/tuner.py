"""Online physical-design tuner (DESIGN.md §18).

PR 2 closed the LOGICAL adaptive loop: the :class:`~repro.core.replan.
Replanner` re-solves WHICH clauses the clients evaluate when the
workload drifts.  This module closes the PHYSICAL one — in the spirit of
*Workload-Driven Vertical Partitioning over Raw Data* (Zhao et al.) and
the *Cost-based Storage Format Selector* (Munir et al.) from PAPERS.md —
by re-deciding, online, WHERE rows live and WHAT gets columnarized:

  * **incremental background re-partition** — when the observed query
    keys drift off the routing key (``LayoutDrift`` "key-shift") or the
    per-shard row counts skew ("skew"), the tuner builds a fresh
    :class:`~repro.core.shard.ShardRouter` (sample-quantile range
    boundaries on the new hot key, hash fallback when the key has no
    numeric values) and drives a
    :class:`~repro.core.shard.SegmentMigration` in bounded batches —
    scans, snapshots and ingest stay online and bit-identical to the
    unsharded oracle throughout (the migration fence in ``shard.py``
    carries the correctness argument);
  * **workload-driven column layout** — which JSON keys each shard
    eagerly columnarizes at ingest is co-selected from the same
    telemetry.  The cost model is the Zhao/Munir trade reduced to its
    sign: eagerly building key *k*'s column costs decode + column-build
    time and resident memory on EVERY ingested row, and pays off only
    when scans actually evaluate *k* (frequency × per-scan vectorized
    speedup).  Keys whose observed reference share clears
    ``TunerPolicy.layout_min_freq`` — plus the plan's clause keys and
    the routing key, which the scan path touches on every query — go
    eager; everything else stays raw per segment until a scan first
    touches it (``ColumnarSegment.key_col`` materializes lazily, so
    counts never change, only where the decode cost lands).

The tuner is a polling loop: call :meth:`PhysicalDesignTuner.step` after
scans/ingest (or let ``CiaoServeEngine.start_tuner`` drive it from a
background thread).  Each step either advances an in-flight migration by
one bounded batch or runs a drift check; every action is recorded in
``history`` and the store's telemetry plane.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING

from .replan import LayoutDrift, layout_drift_signal
from .shard import SegmentMigration, ShardedCiaoStore, ShardRouter

if TYPE_CHECKING:  # pragma: no cover - typing only
    from .replan import Replanner


@dataclass(frozen=True)
class TunerPolicy:
    """When the tuner acts, and how aggressively."""

    check_every_scans: int = 32    # min logged queries between drift checks
    window: int = 64               # workload window for key frequencies
    min_window: int = 8            # need this many queries to trust a shift
    hot_share_threshold: float = 0.5   # hot key must dominate the window
    margin: float = 1.5            # ...and beat the routing key by this
    skew_threshold: float = 4.0    # max/mean resident rows triggering "skew"
    batch_rows: int = 4096         # rows examined per migration step
    sample_rows: int = 1024        # resident rows sampled for new boundaries
    layout_min_freq: float = 0.02  # eager-columnarize keys above this share
    retune_layout: bool = True     # co-select the per-shard eager key set


@dataclass
class TunerEvent:
    """One tuner action (kept in ``PhysicalDesignTuner.history``)."""

    kind: str                  # "migration-start" | "migration-finish" |
                               # "layout"
    reason: str                # triggering signal ("key-shift", "skew", ...)
    routing_key: str | None    # router key after the action
    detail: dict = field(default_factory=dict)

    def describe(self) -> str:
        extra = " ".join(f"{k}={v}" for k, v in sorted(self.detail.items()))
        return f"{self.kind} [{self.reason}] key={self.routing_key} {extra}"


class PhysicalDesignTuner:
    """Watch drift signals; re-partition and re-layout the store online.

    Wraps one :class:`~repro.core.shard.ShardedCiaoStore` (the N=1 case
    degenerates to layout-only tuning — there is nothing to re-route).
    An optional :class:`~repro.core.replan.Replanner` aligns the
    workload window with the clause re-solve's; otherwise the tuner
    reads the store's query log directly.

    Exactly one migration runs at a time; :meth:`step` drives it one
    bounded batch per call, so the caller controls how much ingest/scan
    bandwidth the background writer may steal.
    """

    def __init__(self, store: ShardedCiaoStore, *,
                 replanner: "Replanner | None" = None,
                 policy: TunerPolicy | None = None):
        self.store = store
        self.replanner = replanner
        self.policy = policy or TunerPolicy()
        self.migration: SegmentMigration | None = None
        self.history: list[TunerEvent] = []
        self._checked_at = 0

    # -- signals -------------------------------------------------------------
    def layout_drift(self) -> LayoutDrift:
        if self.replanner is not None:
            return self.replanner.layout_drift()
        return layout_drift_signal(self.store, window=self.policy.window)

    def key_weights(self) -> dict[str, float]:
        """Observed key -> reference weight over the workload window
        (each query counts each referenced key once, times its freq)."""
        recent = self.store.query_log[-self.policy.window:]
        weights: dict[str, float] = {}
        for q in recent:
            for k in {t.key for c in q.clauses for t in c.terms}:
                weights[k] = weights.get(k, 0.0) + float(q.freq)
        return weights

    @property
    def migrating(self) -> bool:
        return self.migration is not None and not self.migration.done

    # -- planning ------------------------------------------------------------
    def _sample_objs(self) -> list[dict]:
        """Up to ``sample_rows`` resident row objects, spread across
        shards (quantile boundaries must see the whole key range, not
        one shard's slice of it)."""
        store = self.store
        quota = max(1, self.policy.sample_rows // max(1, store.n_shards))
        out: list[dict] = []
        for sh in store.shards:
            taken = 0
            for seg in (*sh.blocks, *sh.jit_blocks):
                rows = seg.rows[:quota - taken]
                out.extend(rows)
                taken += len(rows)
                if taken >= quota:
                    break
        return out

    def decide(self) -> tuple[str, ShardRouter] | None:
        """Drift check: returns ``(reason, new_router)`` when the layout
        should change, else ``None``.  Pure planning — no mutation."""
        store = self.store
        if store.n_shards < 2:
            return None
        sig = self.layout_drift()
        p = self.policy
        reason = sig.triggers(
            min_window=p.min_window,
            hot_share_threshold=p.hot_share_threshold,
            margin=p.margin, skew_threshold=p.skew_threshold)
        if reason is None:
            return None
        key = sig.hot_key if reason == "key-shift" else \
            (store.router.key or sig.hot_key)
        if key is None:
            return None
        try:
            router = ShardRouter.from_samples(
                store.n_shards, key, self._sample_objs())
        except ValueError:
            # no numeric sample values: hash-partition the new key
            router = ShardRouter(n_shards=store.n_shards, key=key,
                                 mode="hash")
        if router == store.router:
            return None  # re-quantile landed on the same cut points
        return reason, router

    # -- acting --------------------------------------------------------------
    def step(self) -> TunerEvent | None:
        """One tuner tick: advance the in-flight migration by one batch,
        or run a (throttled) drift check and maybe start one.  Returns
        the event when an action started/finished, else ``None``."""
        mig = self.migration
        if mig is not None and not mig.done:
            mig.step()
            if not mig.done:
                return None
            ev = TunerEvent(
                kind="migration-finish", reason="drain",
                routing_key=self.store.router.key,
                detail={"rows_moved": mig.rows_moved,
                        "rows_kept": mig.rows_kept,
                        "segments_moved": mig.segments_moved,
                        "items_skipped": mig.items_skipped})
            self.history.append(ev)
            return ev
        n_q = len(self.store.query_log)
        if self._checked_at > n_q:       # the log was trimmed
            self._checked_at = n_q
        if n_q - self._checked_at < self.policy.check_every_scans:
            return None
        self._checked_at = n_q
        decision = self.decide()
        if decision is None:
            return None
        reason, router = decision
        self.migration = self.store.begin_migration(
            router, batch_rows=self.policy.batch_rows)
        telemetry = getattr(self.store, "telemetry", None)
        if telemetry is not None:
            telemetry.record_tuner(router_swaps=1)
        if self.policy.retune_layout:
            self.retune_layout(reason=reason)
        ev = TunerEvent(
            kind="migration-start", reason=reason, routing_key=router.key,
            detail={"mode": router.mode,
                    "items": self.migration.items_left})
        self.history.append(ev)
        return ev

    def run_migration(self) -> None:
        """Drain the in-flight migration to completion (tests/benches —
        the serve plane drives :meth:`step` incrementally instead)."""
        while self.migrating:
            self.step()

    def retune_layout(self, *, reason: str = "workload") -> frozenset[str]:
        """Re-select the eager columnarization key set from telemetry.

        The eager set is the cost model's positive side: the plan's
        clause keys and the routing key (touched by every scan's pruning
        cascade) plus every key whose observed reference share clears
        ``layout_min_freq`` — for those, frequency × vectorized-scan
        benefit exceeds the per-row decode + memory cost of building the
        column; everything else stays raw per segment until first touch.
        Applies to NEW segments only (existing columns are never torn
        down — their build cost is sunk and their memory is reclaimed by
        normal segment lifecycle, not by the tuner).
        """
        store = self.store
        weights = self.key_weights()
        total = sum(weights.values())
        eager = {k for k, w in weights.items()
                 if total and w / total >= self.policy.layout_min_freq}
        for c in store.plan.clauses:
            eager.update(t.key for t in c.terms)
        if store.router.key is not None:
            eager.add(store.router.key)
        eager_fs = frozenset(eager)
        for sh in store.shards:
            sh.layout_eager_keys = eager_fs
        telemetry = getattr(store, "telemetry", None)
        if telemetry is not None:
            telemetry.record_tuner(layout_retunes=1)
        self.history.append(TunerEvent(
            kind="layout", reason=reason, routing_key=store.router.key,
            detail={"eager_keys": sorted(eager_fs)}))
        return eager_fs
