"""Per-tenant scan/ingest telemetry plane (DESIGN.md §16).

Every store front-end owns one :class:`TelemetryPlane`; scanners record
one event per finished query (:meth:`TelemetryPlane.record_scan`) and
client shards report their measured eval wall-clock
(:meth:`TelemetryPlane.record_client_eval`).  The plane is pure
bookkeeping — it never influences scan results — and is snapshot as a
JSON-able dict via ``store.stats_report()``.

What it aggregates, per tenant and per (epoch, tier):

  * result-cache hit rates (the :class:`~repro.core.batch_scan.ResultCache`
    consultations a scanner made on the tenant's behalf);
  * skip fractions at all three levels of the cascade, each in its
    natural unit — shards partition-pruned (level 1), segments
    zone-pruned out of segments visited (level 2), rows bitvector-skipped
    out of rows resident in scanned segments (level 3);
  * scan latency histograms (log-spaced buckets, p50/p90/p99).

The per-client eval measurements feed
:class:`repro.data.pipeline.FleetTierAllocator`: with a plane attached,
re-tiering uses measured µs/record and measured record rates instead of
the modeled ``1/speed`` priors.

All counters are derived from the :class:`~repro.core.server.ScanResult`
accounting contract, so telemetry is exactly as trustworthy as the scan
counts themselves (pinned by ``tests/test_batch_scan.py``).
"""
from __future__ import annotations

import threading
from bisect import bisect_left
from typing import TYPE_CHECKING

if TYPE_CHECKING:  # pragma: no cover - typing only
    from .server import ScanResult

# log-spaced latency buckets: 1µs .. ~67s, doubling (27 upper edges)
_EDGES_S = tuple(1e-6 * (1 << i) for i in range(27))


class LatencyHistogram:
    """Fixed log-bucket latency histogram (seconds in, µs out)."""

    __slots__ = ("counts", "total_s", "n")

    def __init__(self) -> None:
        self.counts = [0] * (len(_EDGES_S) + 1)
        self.total_s = 0.0
        self.n = 0

    def record(self, seconds: float) -> None:
        self.counts[bisect_left(_EDGES_S, seconds)] += 1
        self.total_s += seconds
        self.n += 1

    def quantile_us(self, q: float) -> float:
        """Upper bucket edge at quantile ``q`` (0 when empty)."""
        if not self.n:
            return 0.0
        need = q * self.n
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= need:
                edge = _EDGES_S[min(i, len(_EDGES_S) - 1)]
                return edge * 1e6
        return _EDGES_S[-1] * 1e6

    def to_obj(self) -> dict:
        return {
            "n": self.n,
            "mean_us": round(self.total_s / self.n * 1e6, 3) if self.n else 0.0,
            "p50_us": round(self.quantile_us(0.50), 3),
            "p90_us": round(self.quantile_us(0.90), 3),
            "p99_us": round(self.quantile_us(0.99), 3),
        }


class _TenantStats:
    """One tenant's scan counters (summed :class:`ScanResult` fields),
    plus the serve-plane pressure counters (DESIGN.md §17/§18): ingest
    backpressure blocks/rejections and query-admission outcomes."""

    __slots__ = ("scans", "cache_hits", "cache_misses", "count",
                 "rows_scanned", "rows_skipped", "raw_parsed",
                 "segments_scanned", "segments_pruned",
                 "shards_scanned", "shards_pruned", "latency",
                 "ingest_blocked_s", "ingest_rejected",
                 "admitted", "admission_blocked_s", "admission_rejected")

    def __init__(self) -> None:
        self.scans = 0
        self.cache_hits = 0
        self.cache_misses = 0
        self.count = 0
        self.rows_scanned = 0
        self.rows_skipped = 0
        self.raw_parsed = 0
        self.segments_scanned = 0
        self.segments_pruned = 0
        self.shards_scanned = 0
        self.shards_pruned = 0
        self.latency = LatencyHistogram()
        self.ingest_blocked_s = 0.0
        self.ingest_rejected = 0
        self.admitted = 0
        self.admission_blocked_s = 0.0
        self.admission_rejected = 0

    def fold(self, r: "ScanResult", *, cache_hits: int, cache_misses: int,
             wall_s: float) -> None:
        self.scans += 1
        self.cache_hits += int(cache_hits)
        self.cache_misses += int(cache_misses)
        self.count += r.count
        self.rows_scanned += r.rows_scanned
        self.rows_skipped += r.rows_skipped
        self.raw_parsed += r.raw_parsed
        self.segments_scanned += r.segments_scanned
        self.segments_pruned += r.segments_pruned
        self.shards_scanned += r.shards_scanned
        self.shards_pruned += r.shards_pruned
        self.latency.record(wall_s)

    def to_obj(self) -> dict:
        lookups = self.cache_hits + self.cache_misses
        shard_visits = self.shards_scanned + self.shards_pruned
        seg_visits = self.segments_scanned + self.segments_pruned
        rows = self.rows_scanned + self.rows_skipped
        return {
            "scans": self.scans,
            "count": self.count,
            "cache_hits": self.cache_hits,
            "cache_misses": self.cache_misses,
            "cache_hit_rate":
                round(self.cache_hits / lookups, 4) if lookups else 0.0,
            "rows_scanned": self.rows_scanned,
            "rows_skipped": self.rows_skipped,
            "raw_parsed": self.raw_parsed,
            "segments_scanned": self.segments_scanned,
            "segments_pruned": self.segments_pruned,
            "shards_scanned": self.shards_scanned,
            "shards_pruned": self.shards_pruned,
            # the three-level cascade, each level in its natural unit
            "partition_skip_fraction":
                round(self.shards_pruned / shard_visits, 4)
                if shard_visits else 0.0,
            "zone_skip_fraction":
                round(self.segments_pruned / seg_visits, 4)
                if seg_visits else 0.0,
            "row_skip_fraction":
                round(self.rows_skipped / rows, 4) if rows else 0.0,
            "latency": self.latency.to_obj(),
            "backpressure": {
                "ingest_blocked_s": round(self.ingest_blocked_s, 6),
                "ingest_rejected": self.ingest_rejected,
                "admitted": self.admitted,
                "admission_blocked_s": round(self.admission_blocked_s, 6),
                "admission_rejected": self.admission_rejected,
            },
        }


class _ClientEval:
    """Measured eval wall-clock for one ingest client."""

    __slots__ = ("n_records", "eval_s", "reports")

    def __init__(self) -> None:
        self.n_records = 0
        self.eval_s = 0.0
        self.reports = 0

    def to_obj(self) -> dict:
        return {
            "reports": self.reports,
            "n_records": self.n_records,
            "eval_s": round(self.eval_s, 6),
            "us_per_record":
                round(self.eval_s / self.n_records * 1e6, 4)
                if self.n_records else 0.0,
            "records_per_s":
                round(self.n_records / self.eval_s, 1)
                if self.eval_s > 0 else 0.0,
        }


class TelemetryPlane:
    """Store-resident per-tenant / per-tier scan + ingest statistics.

    Thread-safe for concurrent ``record_*`` calls (scanners may share a
    plane across a thread pool).  Recording never raises into the scan
    path and never changes scan results.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._tenants: dict[str, _TenantStats] = {}
        # (epoch, tier) -> summed group accounting over every recorded scan
        self._tiers: dict[tuple[int, int], dict[str, int]] = {}
        self._clients: dict[object, _ClientEval] = {}
        # physical-design tuner counters (DESIGN.md §18)
        self._tuner: dict[str, float] = {
            "migrations": 0, "rows_moved": 0, "rows_kept": 0,
            "segments_moved": 0, "layout_retunes": 0, "router_swaps": 0,
        }

    # -- recording -----------------------------------------------------------
    def record_scan(self, result: "ScanResult", *, tenant: str = "default",
                    cache_hits: int = 0, cache_misses: int = 0,
                    wall_s: float | None = None) -> None:
        """Fold one finished query's :class:`ScanResult` into the plane."""
        wall = result.time_s if wall_s is None else wall_s
        with self._lock:
            ts = self._tenants.get(tenant)
            if ts is None:
                ts = self._tenants[tenant] = _TenantStats()
            ts.fold(result, cache_hits=cache_hits,
                    cache_misses=cache_misses, wall_s=wall)
            for key, g in result.groups.items():
                tg = self._tiers.get(key)
                if tg is None:
                    tg = self._tiers[key] = {
                        "count": 0, "rows_scanned": 0, "rows_skipped": 0,
                        "raw_parsed": 0, "segments_pruned": 0,
                    }
                tg["count"] += g.count
                tg["rows_scanned"] += g.rows_scanned
                tg["rows_skipped"] += g.rows_skipped
                tg["raw_parsed"] += g.raw_parsed
                tg["segments_pruned"] += g.segments_pruned

    def record_backpressure(self, *, tenant: str = "default",
                            blocked_s: float = 0.0,
                            rejected: int = 0) -> None:
        """One ingest submission's backpressure outcome (serve plane)."""
        with self._lock:
            ts = self._tenants.get(tenant)
            if ts is None:
                ts = self._tenants[tenant] = _TenantStats()
            ts.ingest_blocked_s += float(blocked_s)
            ts.ingest_rejected += int(rejected)

    def record_admission(self, *, tenant: str = "default",
                         admitted: int = 0, blocked_s: float = 0.0,
                         rejected: int = 0) -> None:
        """One :class:`~repro.serve.store_engine.QueryAdmission` outcome."""
        with self._lock:
            ts = self._tenants.get(tenant)
            if ts is None:
                ts = self._tenants[tenant] = _TenantStats()
            ts.admitted += int(admitted)
            ts.admission_blocked_s += float(blocked_s)
            ts.admission_rejected += int(rejected)

    def record_tuner(self, *, migrations: int = 0, rows_moved: int = 0,
                     rows_kept: int = 0, segments_moved: int = 0,
                     layout_retunes: int = 0,
                     router_swaps: int = 0) -> None:
        """Fold one physical-design tuner action into the plane."""
        with self._lock:
            t = self._tuner
            t["migrations"] += migrations
            t["rows_moved"] += rows_moved
            t["rows_kept"] += rows_kept
            t["segments_moved"] += segments_moved
            t["layout_retunes"] += layout_retunes
            t["router_swaps"] += router_swaps

    def record_client_eval(self, client_id, seconds: float,
                           n_records: int) -> None:
        """One client-side chunk evaluation's measured wall-clock."""
        with self._lock:
            ce = self._clients.get(client_id)
            if ce is None:
                ce = self._clients[client_id] = _ClientEval()
            ce.reports += 1
            ce.eval_s += float(seconds)
            ce.n_records += int(n_records)

    # -- reads ---------------------------------------------------------------
    def client_eval(self, client_id) -> dict | None:
        """Measured eval stats for one client, or None before any report."""
        with self._lock:
            ce = self._clients.get(client_id)
            return None if ce is None else ce.to_obj()

    def tenant(self, tenant: str = "default") -> dict | None:
        with self._lock:
            ts = self._tenants.get(tenant)
            return None if ts is None else ts.to_obj()

    def snapshot(self) -> dict:
        """JSON-able snapshot of every tenant / tier / client series."""
        with self._lock:
            return {
                "tenants": {
                    name: ts.to_obj()
                    for name, ts in sorted(self._tenants.items())
                },
                "tiers": {
                    f"{e},{t}": dict(v)
                    for (e, t), v in sorted(self._tiers.items())
                },
                "clients": {
                    str(cid): ce.to_obj()
                    for cid, ce in sorted(self._clients.items(),
                                          key=lambda kv: str(kv[0]))
                },
                "tuner": dict(self._tuner),
            }
