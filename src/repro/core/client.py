"""Client-side predicate evaluation engines (paper §IV).

Clients ship records in fixed-size *chunks*.  We encode a chunk as a dense
``uint8[R, L]`` matrix (records zero-padded to a common stride) — this is the
TPU-native representation every engine shares:

  * :class:`PythonEngine` — the paper-faithful ``bytes.find`` oracle
    (string::find semantics, record at a time).  Slow; ground truth.
  * :class:`NumpyEngine` — vectorized sliding-window matching on the dense
    chunk; the production host-side (ingest server / CPU client) path.
  * :class:`PallasEngine` / :class:`XLAEngine` — live in ``repro.kernels``
    (TPU kernel and its jnp oracle); constructed via :func:`get_engine`.

All engines MUST agree exactly: same bits, same false positives.  The
property tests sweep random records × clauses across engines.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np

from . import bitvector
from .predicates import Clause, Kind, SimplePredicate


# ---------------------------------------------------------------------------
# chunk encoding
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Chunk:
    """A dense batch of raw JSON records plus true lengths."""

    data: np.ndarray      # uint8[R, L]
    lengths: np.ndarray   # int32[R]

    @property
    def n_records(self) -> int:
        return int(self.data.shape[0])

    @property
    def stride(self) -> int:
        return int(self.data.shape[1])

    def record(self, i: int) -> bytes:
        return self.data[i, : self.lengths[i]].tobytes()

    def records(self) -> list[bytes]:
        return [self.record(i) for i in range(self.n_records)]

    def nbytes(self) -> int:
        return int(self.lengths.sum())


def encode_chunk(records: Sequence[bytes], *, stride: int | None = None,
                 align: int = 128) -> Chunk:
    """Pad records into a dense uint8 matrix.

    ``stride`` defaults to max record length rounded up to ``align`` (lane
    width) — records are never truncated (truncation could cause false
    negatives, which are forbidden).
    """
    if not records:
        return Chunk(np.zeros((0, align), np.uint8), np.zeros((0,), np.int32))
    max_len = max(len(r) for r in records)
    if stride is None:
        stride = ((max_len + align - 1) // align) * align
    if stride < max_len:
        raise ValueError(f"stride {stride} < max record length {max_len}")
    data = np.zeros((len(records), stride), dtype=np.uint8)
    lengths = np.zeros((len(records),), dtype=np.int32)
    for i, r in enumerate(records):
        arr = np.frombuffer(r, dtype=np.uint8)
        data[i, : len(arr)] = arr
        lengths[i] = len(arr)
    return Chunk(data=data, lengths=lengths)


def encode_patterns(patterns: Sequence[bytes], *, max_len: int = 64
                    ) -> tuple[np.ndarray, np.ndarray]:
    """Pad patterns to ``uint8[P, max_len]`` + lengths (kernel input)."""
    m = max((len(p) for p in patterns), default=1)
    if m > max_len:
        max_len = m
    out = np.zeros((len(patterns), max_len), dtype=np.uint8)
    lens = np.zeros((len(patterns),), dtype=np.int32)
    for i, p in enumerate(patterns):
        out[i, : len(p)] = np.frombuffer(p, dtype=np.uint8)
        lens[i] = len(p)
    return out, lens


# ---------------------------------------------------------------------------
# vectorized matching primitives (numpy; ref.py mirrors these in jnp)
# ---------------------------------------------------------------------------

def window_hits(data: np.ndarray, pattern: bytes, *,
                counts: np.ndarray | None = None) -> np.ndarray:
    """bool[R, L-m+1]: window j matches pattern exactly.

    An empty pattern matches at every position (``b"" in x`` semantics) —
    the engine-equivalence contract: PythonEngine and the kernels treat a
    zero-length pattern as match-all.

    Candidate-filtered: instead of ``m`` full (R, L) comparison passes,
    ONE pass on the chunk's rarest pattern byte (``counts``: the chunk's
    byte histogram, computed here when not supplied) yields a sparse
    candidate set, and the remaining pattern bytes verify by gathers over
    the shrinking survivors — ordered rarest-first so dead candidates die
    early.  JSON chunks made the old dense path memory-bound: every
    pattern starts with ``"`` (~10% of chunk bytes), but almost every
    pattern also contains a byte with frequency well under 1%.
    """
    m = len(pattern)
    R, L = data.shape
    if m == 0:
        return np.ones((R, L + 1), dtype=bool)
    W = L - m + 1
    if m > L:
        return np.zeros((R, max(W, 0)), dtype=bool)
    pat = np.frombuffer(pattern, dtype=np.uint8)
    out = np.zeros((R, W), dtype=bool)
    if R == 0:
        return out
    if counts is None:
        counts = np.bincount(data.ravel(), minlength=256)
    order = np.argsort(counts[pat], kind="stable")
    a = int(order[0])
    rs, ps = np.nonzero(data[:, a: a + W] == pat[a])
    for i in order[1:]:
        if not rs.size:
            return out
        keep = data[rs, ps + int(i)] == pat[int(i)]
        rs, ps = rs[keep], ps[keep]
    out[rs, ps] = True
    return out


def any_match(data: np.ndarray, pattern: bytes, *,
              counts: np.ndarray | None = None) -> np.ndarray:
    """bool[R]: pattern occurs anywhere in the record."""
    hits = window_hits(data, pattern, counts=counts)
    return hits.any(axis=1) if hits.size else np.zeros(data.shape[0], bool)


def key_value_match(data: np.ndarray, key_pat: bytes, val_pat: bytes, *,
                    counts: np.ndarray | None = None) -> np.ndarray:
    """bool[R]: paper's key-value semantics on the dense chunk.

    Valid iff there is an occurrence of ``key_pat`` ending at position p such
    that ``val_pat`` occurs entirely within [p, next_delimiter(p)), where the
    delimiters are ',' and '}'.  If the value pattern itself contains a
    delimiter we degrade to an unbounded search after the key (false-positive
    safe; see predicates.SimplePredicate.matches_raw).

    The delimiter-confinement machinery (cumsum + segmented max) is the
    expensive part; it runs only over *active* rows — rows with at least
    one key hit AND one value hit — which selective predicates make a
    small minority of the chunk.
    """
    R, L = data.shape
    mk, mv = len(key_pat), len(val_pat)
    key_hit = window_hits(data, key_pat, counts=counts)   # (R, L-mk+1)
    if not key_hit.any():
        return np.zeros(R, dtype=bool)
    val_hit = window_hits(data, val_pat, counts=counts)   # (R, L-mv+1)
    if not val_hit.any():
        return np.zeros(R, dtype=bool)

    out = np.zeros(R, dtype=bool)
    active = key_hit.any(axis=1) & val_hit.any(axis=1)
    if not active.any():
        return out
    act = np.nonzero(active)[0]
    data = data[act]
    key_hit = key_hit[act]
    val_hit = val_hit[act]
    Ra = len(act)

    unbounded = (b"," in val_pat) or (b"}" in val_pat)
    # any_val_from[r, p] = exists v >= p with (clean) val hit at v, p in [0, L]
    if unbounded:
        ok = val_hit
    else:
        delim = (data == ord(",")) | (data == ord("}"))    # (Ra, L)
        # exclusive prefix count of delimiters: C[r, p] = # delims in [0, p)
        C = np.zeros((Ra, L + 1), dtype=np.int32)
        np.cumsum(delim, axis=1, out=C[:, 1:])
        # clean val hit: no delimiter inside [v, v+mv)
        ok = val_hit & ((C[:, mv : mv + val_hit.shape[1]] - C[:, : val_hit.shape[1]]) == 0)
        if not ok.any():
            return out

    # suffix "exists a usable value at v >= p (same segment unless unbounded)"
    pos = np.where(ok, np.arange(ok.shape[1])[None, :], -1)
    if unbounded:
        # reverse running max of hit positions
        last_from = np.flip(np.maximum.accumulate(np.flip(pos, axis=1), axis=1), axis=1)
        any_from = np.full((Ra, L + 1), False)
        any_from[:, : pos.shape[1]] = last_from >= np.arange(pos.shape[1])[None, :]
        # positions beyond the last window start cannot begin a match
    else:
        # segmented: max usable-value position per (record, segment)
        seg_of_pos = C[:, :L]                                  # segment id of p
        nseg = L + 1
        flat = seg_of_pos[:, : pos.shape[1]] + nseg * np.arange(Ra)[:, None]
        seg_max = np.full(Ra * nseg, -1, dtype=np.int64)
        np.maximum.at(seg_max, flat.ravel(), pos.ravel())
        seg_max = seg_max.reshape(Ra, nseg)
        any_from = np.full((Ra, L + 1), False)
        p_idx = np.arange(L)
        any_from[:, :L] = np.take_along_axis(seg_max, seg_of_pos, axis=1) >= p_idx[None, :]

    # key hit at window j -> value region starts at p = j + mk
    jmax = key_hit.shape[1]
    region = any_from[:, mk : mk + jmax]
    out[act] = (key_hit & region).any(axis=1)
    return out


def eval_simple(data: np.ndarray, pred: SimplePredicate, *,
                counts: np.ndarray | None = None) -> np.ndarray:
    pats = pred.patterns()
    if pred.kind is Kind.KEY_VALUE:
        if len(pats[1]) == 0:
            # empty value pattern degrades to key presence — mirrors
            # kernels.plan.compile_plan and matches_raw (find(b"") != -1)
            return any_match(data, pats[0], counts=counts)
        return key_value_match(data, pats[0], pats[1], counts=counts)
    return any_match(data, pats[0], counts=counts)


def eval_clause(data: np.ndarray, cl: Clause) -> np.ndarray:
    out = np.zeros(data.shape[0], dtype=bool)
    for t in cl.terms:
        out |= eval_simple(data, t)
    return out


def dedup_terms(clauses: Sequence[Clause]
                ) -> tuple[list[SimplePredicate], np.ndarray]:
    """Unique predicates across a clause list + clause-membership matrix.

    Two terms that compile to the same pattern strings (and kind) evaluate
    identically, so they share one slot.  Returns ``(terms, membership)``
    with ``membership bool[C, P]``: clause c contains predicate p.  Every
    engine combines per-clause hits as ``membership @ hits > 0`` — the OR
    over disjuncts — so a disjunct shared by several clauses is evaluated
    once per chunk, not once per clause.
    """
    uniq: dict[tuple, int] = {}
    terms: list[SimplePredicate] = []
    for cl in clauses:
        for t in cl.terms:
            key = (t.kind is Kind.KEY_VALUE, t.patterns())
            if key not in uniq:
                uniq[key] = len(terms)
                terms.append(t)
    membership = np.zeros((len(clauses), len(terms)), dtype=bool)
    for ci, cl in enumerate(clauses):
        for t in cl.terms:
            membership[ci, uniq[(t.kind is Kind.KEY_VALUE, t.patterns())]] = True
    return terms, membership


# ---------------------------------------------------------------------------
# engines
# ---------------------------------------------------------------------------

class _HostEngine:
    """Shared packed/fused derivations for the host-side engines."""

    def eval(self, chunk: Chunk, clauses: Sequence[Clause]) -> np.ndarray:
        raise NotImplementedError

    def eval_packed(self, chunk: Chunk, clauses: Sequence[Clause]) -> np.ndarray:
        return bitvector.pack(self.eval(chunk, clauses))

    def eval_fused(self, chunk: Chunk,
                   clauses: Sequence[Clause]) -> bitvector.ChunkBitvectors:
        """Same contract as the fused kernel pass (bitvectors+mask+counts)."""
        return bitvector.ChunkBitvectors.from_bits(self.eval(chunk, clauses))

    def eval_fused_prefix(self, chunk: Chunk, clauses: Sequence[Clause],
                          n_clauses: int) -> bitvector.ChunkBitvectors:
        """Tiered evaluation: the first ``n_clauses`` of ``clauses``.

        Host engines have no jit traces to share, so the view is a plain
        slice — work genuinely scales with the tier.  The kernel engines
        override this with a shape-preserving subset view
        (``KernelEngine.eval_fused_prefix``); both produce bit-identical
        results to ``eval_fused(chunk, clauses[:n_clauses])`` and reject
        the same out-of-range prefixes.
        """
        clauses = list(clauses)
        if not 0 <= n_clauses <= len(clauses):
            raise ValueError(
                f"prefix {n_clauses} out of range 0..{len(clauses)}")
        return self.eval_fused(chunk, clauses[:n_clauses])


class PythonEngine(_HostEngine):
    """Paper-faithful string::find oracle (slow; ground truth)."""

    name = "python"

    def eval(self, chunk: Chunk, clauses: Sequence[Clause]) -> np.ndarray:
        recs = chunk.records()
        out = np.zeros((len(clauses), chunk.n_records), dtype=bool)
        for pi, cl in enumerate(clauses):
            for ri, rec in enumerate(recs):
                out[pi, ri] = cl.matches_raw(rec)
        return out


class NumpyEngine(_HostEngine):
    """Vectorized sliding-window engine on the dense chunk.

    Mirrors the fused kernel's dedup: a disjunct shared by several clauses
    is evaluated once per chunk, then clauses OR their members' hit rows.
    """

    name = "numpy"

    def eval(self, chunk: Chunk, clauses: Sequence[Clause]) -> np.ndarray:
        terms, membership = dedup_terms(clauses)
        R = chunk.n_records
        if not terms or R == 0:
            return np.zeros((len(clauses), R), dtype=bool)
        # one byte histogram per chunk: window_hits anchors every pattern
        # on its rarest byte, amortized across all the plan's terms
        counts = np.bincount(chunk.data.ravel(), minlength=256)
        hits = np.zeros((len(terms), R), dtype=bool)
        for ti, t in enumerate(terms):
            hits[ti] = eval_simple(chunk.data, t, counts=counts)
        return membership @ hits  # bool matmul == OR over member predicates


def get_engine(name: str):
    """Engine factory; kernel-backed engines are imported lazily."""
    if name == "python":
        return PythonEngine()
    if name == "numpy":
        return NumpyEngine()
    if name in ("xla", "pallas", "pallas_interpret"):
        from repro.kernels.engine import KernelEngine

        return KernelEngine(backend=name)
    raise ValueError(f"unknown engine {name!r}")
