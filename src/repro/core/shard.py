"""Sharded store plane: partition-aware CiaoStore (DESIGN.md §14).

The monolithic :class:`~repro.core.server.CiaoStore` stays the per-shard
segment store (and the N=1 degenerate case / differential oracle); this
module scales it out into N shards:

  * :class:`ShardRouter` — deterministic record -> shard assignment: hash
    or workload-derived range partitioning on a *routing key*, by default
    the plan's hottest clause key (:func:`choose_routing_key`).  Routing
    never affects correctness, only locality — partition metadata keeps
    skipping sound whatever the placement.
  * :class:`ShardedCiaoStore` — routes ingest to N per-shard stores and
    maintains per-shard *partition metadata* (:class:`ShardSummary`:
    per-key numeric min/max + bounded value-set summaries over ALL rows
    resident in the shard, raw remainders included).  That metadata is a
    third skipping level above zone maps; the full cascade is
    partition-prune -> zone-prune -> pushed-bitvector AND -> vectorized
    residual.
  * :class:`ShardedScanner` — scatter-gather scan executor: partition
    pruning first, then per-shard :class:`DataSkippingScanner` scans on a
    thread pool (shard-level work queue), merged deterministically —
    stable shard order, binary tree via
    :func:`repro.dist.collectives.tree_reduce`, sorted per-(epoch, tier)
    groups (:func:`merge_scan_results`).
  * format-6 checkpoints — one manifest + per-shard files
    (:meth:`ShardedCiaoStore.save`), per-key summaries serialized by the
    skipping-index registry.  Format-5 manifests (no range bounds /
    n-gram blooms) and formats 2-4 still load
    (:meth:`ShardedCiaoStore.load`) and :func:`reshard` re-partitions a
    store offline onto a new router.

Public contract: every query over a sharded store returns counts AND
accounting bit-identical to the unsharded oracle across engines,
epochs, and tiers — ``ScanResult.groups`` sorted by (epoch, tier),
merge order deterministic regardless of thread scheduling — pinned by
the differential sweep in ``tests/test_shard.py`` and the
``bench_shard`` schema gate.  Since DESIGN.md §16 the scanner optionally
consults a per-shard :class:`~repro.core.batch_scan.ResultCache` before
dispatch (validated against each shard's ``(epoch, data_version)``,
cached shards skipped and merged in the same stable order) and folds
every merged result into the store's
:class:`~repro.core.telemetry.TelemetryPlane`.
"""
from __future__ import annotations

import bisect
import json
import os
import threading
import time
import zlib
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from typing import Callable, Sequence

import numpy as np

from repro.dist import collectives

from . import bitvector
from .client import Chunk
from .columnar import (
    ColumnarSegment, _f64_exact, build_segments, decode_rows,
)
from .predicates import (
    Clause, Query, SimplePredicate, clause_from_obj, clause_to_obj,
    json_scalar,
)
from .skip_index import (
    REGISTRY, KeyStats, NGramBloom, conservative_bounds, range_fold_value,
)
from .server import (
    CiaoStore, DataSkippingScanner, LoadStats, PlanFamily, PushdownPlan,
    RawRemainder, ScanResult, TierScan, _EpochPushdown,
    resolve_ingest_coverage,
)
from .telemetry import TelemetryPlane

# distinct values tracked per key per shard before the value-set summary
# saturates (min/max survives saturation; set-based refutation does not)
SUMMARY_VALUE_CAP = 4096
_CLAUSE_CACHE_CAP = 256


def _crc(token: bytes) -> int:
    return zlib.crc32(token) & 0xFFFFFFFF


def choose_routing_key(plan: "PushdownPlan | PlanFamily",
                       workload=None) -> str | None:
    """The plan's hottest clause key — the default routing key.

    Tallies the JSON keys referenced by the plan's clause terms, weighted
    by workload query frequency when a workload is given (a clause's
    weight is the summed ``freq`` of the queries containing it), else one
    per clause.  Ties break toward the earliest (highest-ranked) clause.
    Returns ``None`` for an empty plan (the router falls back to
    raw-bytes hashing).
    """
    if isinstance(plan, PlanFamily):
        plan = plan.plan
    weight: dict[Clause, float] = {c: 1.0 for c in plan.clauses}
    if workload is not None:
        for q in workload.queries:
            for c in q.clauses:
                if c in weight:
                    weight[c] += float(q.freq)
    score: dict[str, float] = {}
    first_rank: dict[str, int] = {}
    for rank, c in enumerate(plan.clauses):
        for t in c.terms:
            score[t.key] = score.get(t.key, 0.0) + weight[c]
            first_rank.setdefault(t.key, rank)
    if not score:
        return None
    return min(score, key=lambda k: (-score[k], first_rank[k]))


@dataclass(frozen=True)
class ShardRouter:
    """Deterministic record -> shard assignment.

    ``mode="hash"``: crc32 of ``json_scalar(value-at-key)`` (or of the
    raw record bytes when ``key`` is None / absent) modulo ``n_shards``.
    ``mode="range"``: workload-derived range partitioning — ``boundaries``
    are ascending numeric cut points (``n_shards - 1`` of them, typically
    sample quantiles via :meth:`from_samples`); a numeric value lands in
    ``searchsorted(boundaries, v, side="right")``, everything non-numeric
    falls back to the hash rule.  Range mode is what clusters routing-key
    values so partition min/max metadata can refute queries a monolithic
    store's ingest-ordered segments never could.
    """

    n_shards: int
    key: str | None = None
    mode: str = "hash"
    boundaries: tuple[float, ...] = ()

    def __post_init__(self) -> None:
        if self.n_shards < 1:
            raise ValueError(f"need >= 1 shard, got {self.n_shards}")
        if self.mode not in ("hash", "range"):
            raise ValueError(f"unknown routing mode {self.mode!r}")
        if self.mode == "range":
            if self.key is None:
                raise ValueError("range routing needs a routing key")
            b = tuple(float(x) for x in self.boundaries)
            if len(b) != self.n_shards - 1 or list(b) != sorted(b):
                raise ValueError(
                    f"range routing over {self.n_shards} shards needs "
                    f"{self.n_shards - 1} ascending boundaries, got {b}")
            object.__setattr__(self, "boundaries", b)

    @classmethod
    def from_samples(cls, n_shards: int, key: str,
                     sample_objs: Sequence[dict], *,
                     mode: str = "range") -> "ShardRouter":
        """Router with boundaries at sample quantiles of ``key``.

        Quantile cut points balance ROW counts per shard even when the
        key's value distribution is skewed — the workload-derived flavor
        of range partitioning.
        """
        if mode == "hash":
            return cls(n_shards=n_shards, key=key, mode="hash")
        vals = sorted(
            float(v) for o in sample_objs
            for v in [o.get(key)]
            if isinstance(v, (int, float)) and not isinstance(v, bool)
            and v == v and _f64_exact(v))
        if not vals:
            raise ValueError(
                f"no numeric sample values for routing key {key!r}")
        bnd = tuple(
            vals[min(len(vals) - 1, (i * len(vals)) // n_shards)]
            for i in range(1, n_shards))
        return cls(n_shards=n_shards, key=key, mode="range", boundaries=bnd)

    def shard_of(self, obj: dict | None, rec: bytes) -> int:
        if self.key is None or obj is None or self.key not in obj:
            return _crc(rec) % self.n_shards
        v = obj[self.key]
        # range-routes exactly the values the partition summaries admit
        # to their numeric bounds (_f64_exact also rejects NaN and ints
        # beyond float64, which would overflow float(v)); bisect, not
        # np.searchsorted — per-record numpy dispatch dominates routing
        # cost on large chunks
        if self.mode == "range" and isinstance(v, (int, float)) \
                and not isinstance(v, bool) and _f64_exact(v):
            return bisect.bisect_right(self.boundaries, float(v))
        return _crc(json_scalar(v).encode()) % self.n_shards

    def route(self, objs: Sequence[dict], recs: Sequence[bytes]
              ) -> np.ndarray:
        """int32[n]: shard id per record."""
        return np.fromiter(
            (self.shard_of(o, r) for o, r in zip(objs, recs)),
            np.int32, count=len(recs))

    def to_obj(self) -> dict:
        return {"n_shards": self.n_shards, "key": self.key,
                "mode": self.mode, "boundaries": list(self.boundaries)}

    @classmethod
    def from_obj(cls, d: dict) -> "ShardRouter":
        return cls(n_shards=int(d["n_shards"]), key=d.get("key"),
                   mode=d.get("mode", "hash"),
                   boundaries=tuple(d.get("boundaries", ())))


class _KeySummary:
    """One routing partition's metadata for one JSON key.

    The shard-level analogue of a zone map: numeric min/max over the
    f64-exact values, plus bounded ``json_scalar`` / string value sets
    (``None`` = saturated past :data:`SUMMARY_VALUE_CAP` — membership
    refutation unavailable, min/max still live).  ``num_prunable`` goes
    False when a NaN is observed (same poisoning rule as the segment zone
    maps: a min/max comparison against NaN-tainted data is silently
    False, so it must never refute).
    """

    __slots__ = ("num_min", "num_max", "num_prunable", "any_notnull",
                 "reprs", "strs", "rnum_min", "rnum_max", "rnum_prunable",
                 "ngram")

    def __init__(self) -> None:
        self.num_min = np.inf
        self.num_max = -np.inf
        self.num_prunable = True
        self.any_notnull = False
        self.reprs: set[str] | None = set()
        self.strs: set[str] | None = set()
        # RANGE-index bounds over every range-matchable value (numerics
        # + numeric strings; see skip_index.range_fold_value) — folded
        # incrementally with ulp-widening, so unlike the value sets they
        # never saturate.  rnum_prunable goes False only on format-5
        # restore (bounds unknown).
        self.rnum_min = np.inf
        self.rnum_max = -np.inf
        self.rnum_prunable = True
        # 3-gram bloom over string values; created lazily on the first
        # string (None + empty strs still refutes via membership)
        self.ngram: NGramBloom | None = None

    def add(self, v, cap: int) -> None:
        if v is not None:
            self.any_notnull = True
        if isinstance(v, bool):
            pass
        elif isinstance(v, float) and v != v:
            self.num_prunable = False
        elif isinstance(v, (int, float)) and _f64_exact(v):
            fv = float(v)
            if fv < self.num_min:
                self.num_min = fv
            if fv > self.num_max:
                self.num_max = fv
        elif isinstance(v, str) and self.strs is not None:
            self.strs.add(v)
            if len(self.strs) > cap:
                self.strs = None
        if isinstance(v, str):
            if self.ngram is None:
                self.ngram = NGramBloom()
            self.ngram.add(v)
        x = range_fold_value(v)
        if x is not None:
            lo, hi = conservative_bounds(x)
            if lo < self.rnum_min:
                self.rnum_min = lo
            if hi > self.rnum_max:
                self.rnum_max = hi
        if self.reprs is not None:
            self.reprs.add(json_scalar(v))
            if len(self.reprs) > cap:
                self.reprs = None

    def stats(self) -> KeyStats:
        """Registry probe view (shared with the segment zone maps)."""
        return KeyStats(
            any_notnull=self.any_notnull,
            num_min=self.num_min, num_max=self.num_max,
            num_prunable=self.num_prunable,
            strs=self.strs, reprs=self.reprs,
            rnum_min=self.rnum_min, rnum_max=self.rnum_max,
            rnum_prunable=self.rnum_prunable, ngram=self.ngram,
        )

    def to_obj(self) -> dict:
        # each registered index serializes its own summary slice
        # (format 6); the membership index's block is byte-compatible
        # with the pre-registry format-5 encoding, +/-inf bounds
        # serialize as null/flags (RFC 8259 has no Infinity tokens)
        return REGISTRY.summary_to_obj(self.stats())

    @classmethod
    def from_obj(cls, d: dict) -> "_KeySummary":
        ks = cls()
        st = REGISTRY.summary_from_obj(d)
        ks.num_min = st.num_min
        ks.num_max = st.num_max
        ks.num_prunable = st.num_prunable
        ks.any_notnull = st.any_notnull
        ks.reprs = st.reprs
        ks.strs = st.strs
        ks.rnum_min = st.rnum_min
        ks.rnum_max = st.rnum_max
        ks.rnum_prunable = st.rnum_prunable
        ks.ngram = st.ngram
        return ks


class ShardSummary:
    """Partition-level skipping metadata for ONE shard.

    Covers EVERY row routed to the shard — loaded segments, JIT-promoted
    segments AND raw remainders (the router parses each record once, so
    the summary sees rows the zone maps never will until promotion).
    That total coverage is what makes partition pruning sound for raw
    rows: a refuted shard cannot hold a match anywhere, so the scan skips
    it without JIT-promoting.

    ``exhaustive=False`` (a store migrated from a pre-shard checkpoint,
    or the N=1 degenerate case where routing is skipped) disables pruning
    entirely — the summary answers "possible" for every clause until
    :func:`reshard` rebuilds it from the full row population.

    Concurrent-read soundness (async serve plane, DESIGN.md §17): every
    field is *monotone-permissive* — mins only fall, maxes only rise,
    value sets only grow (or saturate to ``None``), ``any_notnull`` only
    flips True, ``num_prunable`` only flips False — so a reader racing
    ONE writer (the serve plane guarantees a single writer per shard)
    observes a state at least as permissive as some fully-applied prefix
    of the updates.  Since the summary is updated BEFORE its shard's
    ingest, that prefix covers every row any store snapshot can contain,
    and a torn read can only *fail* to prune, never prune unsoundly.
    Cached clause verdicts are version-tagged: :meth:`update` bumps
    ``_version`` after its mutations, retiring any verdict whose compute
    overlapped them.
    """

    def __init__(self, *, exhaustive: bool = True,
                 value_cap: int = SUMMARY_VALUE_CAP):
        self.exhaustive = exhaustive
        self.value_cap = int(value_cap)
        self.n_rows = 0
        self._keys: dict[str, _KeySummary] = {}
        # clause -> (version-at-compute-start, verdict); valid only while
        # the tag equals the current _version (see class docstring)
        self._possible: dict[Clause, tuple[int, bool]] = {}
        self._version = 0

    def update(self, objs: Sequence[dict]) -> None:
        if not self.exhaustive or not objs:
            return
        cap = self.value_cap
        keys = self._keys
        for obj in objs:
            for k, v in obj.items():
                ks = keys.get(k)
                if ks is None:
                    ks = keys[k] = _KeySummary()
                ks.add(v, cap)
        self.n_rows += len(objs)
        # invalidate cached verdicts LAST: a verdict computed concurrently
        # with the mutations above carries the pre-bump version tag, so
        # this bump retires it even if it lands in the cache afterwards.
        # Fresh dict, never .clear() — readers may hold the old one.
        self._version += 1
        self._possible = {}

    # -- pruning -------------------------------------------------------------
    def term_possible(self, t: SimplePredicate) -> bool:
        """Conservative: False only when provably no shard row matches.

        THE refutation rules are shared with the segment zone maps (the
        ``repro.core.skip_index`` registry) — every kind needs the key
        present, set membership refutes exactly, a saturated value set
        degrades to min/max-only refutation, range bounds refute RANGE,
        and the n-gram bloom refutes substring probes past saturation.
        """
        ks = self._keys.get(t.key)
        if ks is None:
            return False
        try:
            return REGISTRY.term_possible(t, ks.stats())
        except RuntimeError:
            # a concurrent writer grew a value set mid-membership-scan
            # ("set changed size during iteration"): answer conservatively
            return True

    def clause_possible(self, c: Clause) -> bool:
        if not self.exhaustive:
            return True
        ver = self._version          # read BEFORE computing the verdict
        cache = self._possible
        hit = cache.get(c)
        if hit is not None and hit[0] == ver:
            return hit[1]
        p = any(self.term_possible(t) for t in c.terms)
        if len(cache) >= _CLAUSE_CACHE_CAP:
            self._possible = cache = {}
        cache[c] = (ver, p)
        return p

    def query_possible(self, q: Query) -> bool:
        """False iff some query clause provably matches no shard row."""
        return all(self.clause_possible(c) for c in q.clauses)

    # -- persistence ---------------------------------------------------------
    def to_obj(self) -> dict:
        return {
            "exhaustive": self.exhaustive,
            "value_cap": self.value_cap,
            "n_rows": self.n_rows,
            "keys": {k: ks.to_obj() for k, ks in sorted(self._keys.items())},
        }

    @classmethod
    def from_obj(cls, d: dict) -> "ShardSummary":
        s = cls(exhaustive=bool(d["exhaustive"]),
                value_cap=int(d.get("value_cap", SUMMARY_VALUE_CAP)))
        s.n_rows = int(d.get("n_rows", 0))
        s._keys = {k: _KeySummary.from_obj(v) for k, v in d["keys"].items()}
        return s


class ShardedCiaoStore:
    """N per-shard :class:`CiaoStore`\\ s behind one store surface.

    Presents the same protocol the scanner, recipe batcher, replanner and
    ingest coordinator already consume — ``ingest_chunk`` /
    ``advance_epoch`` / ``blocks`` / ``jit_blocks`` / ``pushed_by_epoch``
    / ``observed_selectivities`` / ``stats`` — so every control-plane
    component runs unmodified over a sharded substrate.  Plan state is
    shared: all shards hold the same plan/family objects and advance
    epochs together; statistics are kept per shard and aggregated on read
    (the replanner re-solves from per-shard observed selectivities summed
    into exact fleet totals).

    ``n_shards == 1`` is the degenerate case: ingest delegates straight
    to the single inner store (no routing parse, no partition metadata),
    making it bit-identical — in counts AND in cost shape — to a plain
    :class:`CiaoStore`.
    """

    def __init__(self, plan: "PushdownPlan | PlanFamily", *,
                 router: ShardRouter | None = None,
                 n_shards: int | None = None,
                 segment_capacity: int = 8192,
                 summary_value_cap: int = SUMMARY_VALUE_CAP):
        if router is None:
            router = ShardRouter(n_shards=n_shards or 1)
        elif n_shards is not None and n_shards != router.n_shards:
            raise ValueError(
                f"n_shards {n_shards} contradicts router over "
                f"{router.n_shards} shards")
        self.router = router
        self.segment_capacity = int(segment_capacity)
        self.shards = [
            CiaoStore(plan, segment_capacity=segment_capacity)
            for _ in range(router.n_shards)
        ]
        # a 1-shard store skips routing, so its summary never becomes
        # exhaustive — pruning the only shard is pointless anyway
        self.summaries = [
            ShardSummary(exhaustive=router.n_shards > 1,
                         value_cap=summary_value_cap)
            for _ in range(router.n_shards)
        ]
        self.route_time_s = 0.0
        self.query_log: list[Query] = []
        self.query_log_cap = 4096
        # front-end telemetry plane (DESIGN.md §16): scanners over the
        # sharded store record ONCE here (per merged query), never into
        # the per-shard stores' planes
        self.telemetry = TelemetryPlane()
        # fences snapshot() against in-flight migration moves (DESIGN.md
        # §18): a segment move is remove-from-src + add-to-dst; holding
        # this across both (and across snapshot capture) means no
        # snapshot ever observes a row absent from every shard or
        # present in two.  Lock order: _migration_lock BEFORE any
        # shard's _ingest_lock, never the reverse.
        self._migration_lock = threading.RLock()

    # -- shared plan state ---------------------------------------------------
    @property
    def n_shards(self) -> int:
        return len(self.shards)

    @property
    def data_version(self) -> int:
        """Sum of the shards' segment-surface counters (device cache sync
        fast-path, DESIGN.md §15): monotonic, changes iff a shard's did."""
        return sum(s.data_version for s in self.shards)

    @property
    def plan(self) -> PushdownPlan:
        return self.shards[0].plan

    @property
    def family(self) -> PlanFamily:
        return self.shards[0].family

    @property
    def plans(self) -> dict[int, PushdownPlan]:
        return self.shards[0].plans

    @property
    def families(self) -> dict[int, PlanFamily]:
        return self.shards[0].families

    @property
    def epoch(self) -> int:
        return self.plan.epoch

    def advance_epoch(self, new_plan: "PushdownPlan | PlanFamily"
                      ) -> np.ndarray:
        """Install the next plan epoch on every shard; returns the remap."""
        remaps = [s.advance_epoch(new_plan) for s in self.shards]
        return remaps[0]

    # -- aggregated statistics ----------------------------------------------
    @property
    def stats(self) -> LoadStats:
        """Fleet :class:`LoadStats`: exact sums over the shards, plus the
        router's parse/route wall-clock folded into load/parse time."""
        agg = LoadStats()
        for s in self.shards:
            agg.add(s.stats)
        agg.load_time_s += self.route_time_s
        agg.parse_time_s += self.route_time_s
        return agg

    def stats_report(self) -> dict:
        """JSON-able operational snapshot: the front-end telemetry plane
        (where sharded scanners record their merged per-query results)
        plus one nested :meth:`CiaoStore.stats_report` per shard."""
        s = self.stats
        return {
            "epoch": self.epoch,
            "data_version": self.data_version,
            "n_shards": self.n_shards,
            "load": {
                "n_records": s.n_records,
                "n_loaded": s.n_loaded,
                "n_jit_loaded": s.n_jit_loaded,
                "loading_ratio": round(s.loading_ratio, 4),
                "load_time_s": round(s.load_time_s, 6),
                "parse_time_s": round(s.parse_time_s, 6),
                "jit_time_s": round(s.jit_time_s, 6),
            },
            "telemetry": self.telemetry.snapshot(),
            "shards": [sh.stats_report() for sh in self.shards],
        }

    def _sum_epoch(self, attr: str, epoch: int) -> np.ndarray:
        out = None
        for s in self.shards:
            v = getattr(s, attr).get(epoch)
            if v is None:
                continue
            out = np.asarray(v, np.int64) if out is None else out + v
        if out is None:
            out = np.zeros((self.plans[epoch].n,), np.int64)
        return out

    @property
    def clause_counts(self) -> np.ndarray:
        """int64[P]: current epoch's per-clause totals over all shards."""
        return self._sum_epoch("_epoch_counts", self.epoch)

    def epoch_records(self, epoch: int | None = None) -> int:
        e = self.epoch if epoch is None else epoch
        return sum(s._epoch_records.get(e, 0) for s in self.shards)

    def clause_records(self, epoch: int | None = None) -> np.ndarray:
        e = self.epoch if epoch is None else epoch
        return self._sum_epoch("_epoch_clause_records", e)

    def observed_selectivities(self, epoch: int | None = None) -> np.ndarray:
        """float64[P]: per-shard observed selectivities aggregated into
        fleet totals (summed counts over summed per-clause denominators)
        — what the replanner re-solves from."""
        e = self.epoch if epoch is None else epoch
        counts = self._sum_epoch("_epoch_counts", e)
        denom = np.maximum(self._sum_epoch("_epoch_clause_records", e), 1)
        return counts / denom

    @property
    def group_records(self) -> dict[tuple[int, int], int]:
        out: dict[tuple[int, int], int] = {}
        for s in self.shards:
            for k, n in s.group_records.items():
                out[k] = out.get(k, 0) + n
        return out

    @property
    def group_loaded(self) -> dict[tuple[int, int], int]:
        out: dict[tuple[int, int], int] = {}
        for s in self.shards:
            for k, n in s.group_loaded.items():
                out[k] = out.get(k, 0) + n
        return out

    # -- query-path surface (same contract as CiaoStore) ---------------------
    @property
    def blocks(self) -> list[ColumnarSegment]:
        """All shards' loaded segments, stable shard order."""
        return [seg for s in self.shards for seg in s.blocks]

    @property
    def jit_blocks(self) -> list[ColumnarSegment]:
        return [seg for s in self.shards for seg in s.jit_blocks]

    @property
    def raw(self) -> list[RawRemainder]:
        return [rr for s in self.shards for rr in s.raw]

    def log_query(self, q: Query) -> None:
        self.query_log.append(q)
        if len(self.query_log) > 2 * self.query_log_cap:
            del self.query_log[:-self.query_log_cap]

    def pushed_by_epoch(self, q: Query) -> _EpochPushdown:
        m = _EpochPushdown(self, q)
        m[self.plan.epoch]
        return m

    def promote_uncovered_raw(
        self, pushed: _EpochPushdown,
    ) -> dict[tuple[int, int], int]:
        out: dict[tuple[int, int], int] = {}
        for s in self.shards:
            for k, n in s.promote_uncovered_raw(pushed).items():
                out[k] = out.get(k, 0) + n
        return out

    def jit_load_raw(
        self, only_epochs: set[int] | None = None,
        *, only_groups: set[tuple[int, int]] | None = None,
    ) -> dict[tuple[int, int], int]:
        out: dict[tuple[int, int], int] = {}
        for s in self.shards:
            for k, n in s.jit_load_raw(only_epochs,
                                       only_groups=only_groups).items():
                out[k] = out.get(k, 0) + n
        return out

    # -- ingest --------------------------------------------------------------
    def ingest_chunk(
        self, chunk: Chunk,
        bitvecs: "np.ndarray | bitvector.ChunkBitvectors",
        *, epoch: int | None = None, tier: int | None = None,
    ) -> LoadStats:
        """Route one chunk's records to their shards and ingest each slice.

        Validation (epoch, tier, bitvector dimensions) runs ONCE up front
        via :func:`repro.core.server.resolve_ingest_coverage` — a rejected
        chunk touches no shard.  Rows are parsed once for routing; the
        parsed objects feed both the partition summaries and the per-shard
        ingest (loaded rows are not re-parsed).  Per-shard bitvector
        slices are repacked from the chunk's bit matrix, so per-clause
        popcounts land on the owning shard and the aggregated observed
        selectivities stay exact.

        Routing uses ``self.router`` at call time: a migration swaps the
        router FIRST, so every slice routed after the swap already lands
        in its final home and never needs moving.
        """
        resolve_ingest_coverage(
            self.plan, self.family, n_records=chunk.n_records,
            bitvecs=bitvecs, epoch=epoch, tier=tier)
        if self.n_shards == 1:  # degenerate case: no routing parse
            self.shards[0].ingest_chunk(chunk, bitvecs,
                                        epoch=epoch, tier=tier)
            return self.stats
        for s, sub_chunk, sub_bv, sub_objs in \
                self.route_slices(chunk, bitvecs):
            self.ingest_slice(s, sub_chunk, sub_bv, sub_objs,
                              epoch=epoch, tier=tier)
        return self.stats

    def route_slices(
        self, chunk: Chunk,
        bitvecs: "np.ndarray | bitvector.ChunkBitvectors",
    ) -> list[tuple[int, Chunk, "bitvector.ChunkBitvectors", list[dict]]]:
        """Parse + route one validated chunk into per-shard slices.

        Returns ``(shard, sub_chunk, sub_bitvectors, sub_objs)`` per
        non-empty target shard.  Split out of :meth:`ingest_chunk` so the
        serve plane (``repro.serve.store_engine``) can route in the
        submitting thread and enqueue each slice onto its shard's writer
        queue.  Callers must have validated the chunk with
        :func:`~repro.core.server.resolve_ingest_coverage` first.
        ``route_time_s`` accumulation is unsynchronized — approximate
        when several submitters race (it is a timing stat, never a gate).
        """
        n = chunk.n_records
        t0 = time.perf_counter()
        recs, objs = decode_rows(chunk.data, chunk.lengths)
        sid = self.router.route(objs, recs)
        words = (bitvecs.words
                 if isinstance(bitvecs, bitvector.ChunkBitvectors)
                 else np.asarray(bitvecs, np.uint32))
        bits = bitvector.unpack(words, n)
        out: list[tuple[int, Chunk, bitvector.ChunkBitvectors, list[dict]]] \
            = []
        for s in range(self.n_shards):
            idx = np.nonzero(sid == s)[0]
            if not idx.size:
                continue
            out.append((
                s,
                Chunk(data=chunk.data[idx], lengths=chunk.lengths[idx]),
                bitvector.ChunkBitvectors.from_bits(bits[:, idx]),
                [objs[i] for i in idx],
            ))
        self.route_time_s += time.perf_counter() - t0
        return out

    def ingest_slice(
        self, s: int, chunk: Chunk,
        bitvecs: "bitvector.ChunkBitvectors", objs: list[dict],
        *, epoch: int | None = None, tier: int | None = None,
    ) -> None:
        """Apply one routed slice to shard ``s``: summary update FIRST,
        then the per-shard ingest — the ordering that keeps partition
        pruning sound for concurrent snapshot readers (every row a
        snapshot can see was already summarized; see
        :class:`ShardSummary`).

        The whole slice is applied under the shard's ingest lock: the
        serve plane's writer queues already assign each shard to exactly
        one writer, but a background migration writer (DESIGN.md §18)
        may place rows into the same shard concurrently — the lock makes
        the two mutators mutually exclusive per shard."""
        sh = self.shards[s]
        with sh._ingest_lock:
            self.summaries[s].update(objs)
            sh.ingest_chunk(chunk, bitvecs,
                            epoch=epoch, tier=tier, objs=objs)

    # -- consistent reads (async serve plane, DESIGN.md §17) -----------------
    def snapshot(self) -> "ShardedStoreSnapshot":
        """Pin an immutable view of every shard.

        Per-shard snapshots are taken sequentially, each under its own
        shard's ingest lock, so the view is *per-shard prefix-consistent*:
        each shard's slice is a prefix of that shard's ingest history.
        Under the serve plane's single-writer-per-shard queues that is
        snapshot isolation per shard; cross-shard atomicity of one
        multi-shard chunk is NOT guaranteed (a snapshot may contain shard
        A's slice of a chunk but not yet shard B's).  Counts still
        quiesce to the oracle because every slice lands exactly once.

        Taken under the migration fence: an in-flight background segment
        move (remove-from-src + add-to-dst, DESIGN.md §18) is atomic
        w.r.t. this capture, so snapshot counts stay bit-identical to
        the oracle THROUGHOUT a migration.
        """
        with self._migration_lock:
            return ShardedStoreSnapshot(self)

    # -- online physical-design migration (DESIGN.md §18) --------------------
    def begin_migration(self, router: ShardRouter, *,
                        batch_rows: int = 4096) -> "SegmentMigration":
        """Swap the routing function and start moving resident rows.

        The new ``router`` (same shard count — changing N is offline
        :func:`reshard`'s job) takes effect for NEW ingest immediately,
        so post-swap rows never need moving; the returned
        :class:`SegmentMigration` then drains the PRE-swap resident
        surface in bounded batches (:meth:`SegmentMigration.step`) while
        scans and ingest stay online.  Open builder tails are sealed at
        the swap point so every pre-swap row lives in an immutable
        segment the migration can move by identity.
        """
        if router.n_shards != self.n_shards:
            raise ValueError(
                f"online migration keeps the shard count: store has "
                f"{self.n_shards}, router wants {router.n_shards}")
        with self._migration_lock:
            self.router = router
            work: list[tuple[str, int, object]] = []
            for s, sh in enumerate(self.shards):
                with sh._ingest_lock:
                    for b in sh._builders.values():
                        if b.n_rows:
                            sh.segments.append(b.seal())
                            sh.data_version += 1
                    work.extend(("loaded", s, seg) for seg in sh.segments)
                    work.extend(("jit", s, seg) for seg in sh.jit_segments)
                    work.extend(("raw", s, rr) for rr in sh.raw)
            return SegmentMigration(self, router, work,
                                    batch_rows=batch_rows)

    # -- persistence (format 6: manifest + per-shard files) ------------------
    def save(self, path: str) -> None:
        """Checkpoint as a DIRECTORY: ``manifest.json`` + one format-4
        ``shard_<i>.npz`` per shard.

        The manifest carries the shard plane's own state — router config,
        partition summaries (which cover raw remainder rows no segment
        restore could rebuild), and the top-level query log; each shard
        file is a complete, independently loadable per-shard store.
        Format 6 extends the format-5 per-key summaries with the
        registry indexes' slices (range bounds, n-gram blooms); format-5
        files still load (missing fields deserialize to "cannot refute").
        """
        os.makedirs(path, exist_ok=True)
        shard_files = []
        for i, s in enumerate(self.shards):
            name = f"shard_{i:05d}.npz"
            s.save(os.path.join(path, name))
            shard_files.append(name)
        manifest = {
            "format": 6,
            "segment_capacity": self.segment_capacity,
            "router": self.router.to_obj(),
            "shard_files": shard_files,
            "summaries": [s.to_obj() for s in self.summaries],
            "route_time_s": self.route_time_s,
            "query_log": [
                {"freq": q.freq,
                 "clauses": [clause_to_obj(c) for c in q.clauses]}
                for q in self.query_log[-self.query_log_cap:]
            ],
        }
        with open(os.path.join(path, "manifest.json"), "w") as f:
            json.dump(manifest, f)

    @classmethod
    def load(cls, path: str,
             plan: PushdownPlan | None = None) -> "ShardedCiaoStore":
        """Restore a checkpoint — format 5/6 (directory) or formats 2-4.

        A pre-shard ``.npz`` checkpoint (format 2/3/4) loads into a
        1-shard store whose summary is non-exhaustive (pruning disabled
        until :func:`reshard` re-partitions it offline); counts and
        coverage claims survive unchanged because the inner store IS the
        migrated :class:`CiaoStore`.
        """
        manifest_path = os.path.join(path, "manifest.json")
        if not os.path.isdir(path):
            inner = CiaoStore.load(path, plan)
            store = cls.__new__(cls)
            store.router = ShardRouter(n_shards=1)
            store.segment_capacity = inner.segment_capacity
            store.shards = [inner]
            store.summaries = [ShardSummary(exhaustive=False)]
            store.route_time_s = 0.0
            store.query_log = list(inner.query_log)
            store.query_log_cap = inner.query_log_cap
            store.telemetry = TelemetryPlane()
            store._migration_lock = threading.RLock()
            return store
        with open(manifest_path) as f:
            manifest = json.load(f)
        if manifest.get("format") not in (5, 6):
            raise ValueError(
                f"{path}: unsupported sharded checkpoint format "
                f"{manifest.get('format')!r}")
        store = cls.__new__(cls)
        store.router = ShardRouter.from_obj(manifest["router"])
        store.segment_capacity = int(manifest["segment_capacity"])
        store.shards = [
            CiaoStore.load(os.path.join(path, name), plan)
            for name in manifest["shard_files"]
        ]
        store.summaries = [
            ShardSummary.from_obj(d) for d in manifest["summaries"]
        ]
        store.route_time_s = float(manifest.get("route_time_s", 0.0))
        store.query_log = [
            Query(tuple(clause_from_obj(c) for c in q["clauses"]),
                  freq=float(q["freq"]))
            for q in manifest.get("query_log", [])
        ]
        store.query_log_cap = 4096
        store.telemetry = TelemetryPlane()
        store._migration_lock = threading.RLock()
        return store


class ShardedStoreSnapshot:
    """Immutable view of a :class:`ShardedCiaoStore` (DESIGN.md §17).

    ``shards`` holds one :class:`~repro.core.server.StoreSnapshot` per
    shard, so :class:`ShardedScanner`,
    :class:`~repro.core.batch_scan.ScanBatcher` and the device scanners
    run over it unchanged.  ``summaries`` is a shallow COPY of the
    store's summary list: each :class:`ShardSummary` object is still
    shared live (monotone-permissive and updated before its shard's
    ingest, so a concurrent update only makes verdicts more permissive),
    but a migration ``finish()`` installing fresh exhaustive summaries
    into the live list does not retroactively tighten this snapshot —
    the old, over-permissive objects keep covering every row the
    snapshot pinned.

    ``data_version`` is the sum of the per-shard snapshot versions (the
    same composition rule as the live store); snapshot-local JIT
    promotion in any shard forks it negative, keeping cache fencing
    exact (see :class:`~repro.core.server.StoreSnapshot`).
    """

    def __init__(self, store: ShardedCiaoStore):
        self._store = store               # query-log feedback only
        self.router = store.router
        self.segment_capacity = store.segment_capacity
        self.shards = [s.snapshot() for s in store.shards]
        self.summaries = list(store.summaries)
        self.telemetry = store.telemetry
        self.route_time_s = store.route_time_s
        self.base_version = sum(s.base_version for s in self.shards)

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    @property
    def data_version(self) -> int:
        return sum(s.data_version for s in self.shards)

    @property
    def plan(self) -> PushdownPlan:
        return self.shards[0].plan

    @property
    def family(self) -> PlanFamily:
        return self.shards[0].family

    @property
    def plans(self) -> dict[int, PushdownPlan]:
        return self.shards[0].plans

    @property
    def families(self) -> dict[int, PlanFamily]:
        return self.shards[0].families

    @property
    def epoch(self) -> int:
        return self.plan.epoch

    @property
    def stats(self) -> LoadStats:
        agg = LoadStats()
        for s in self.shards:
            agg.add(s.stats)
        agg.load_time_s += self.route_time_s
        agg.parse_time_s += self.route_time_s
        return agg

    @property
    def blocks(self) -> list[ColumnarSegment]:
        return [seg for s in self.shards for seg in s.blocks]

    @property
    def jit_blocks(self) -> list[ColumnarSegment]:
        return [seg for s in self.shards for seg in s.jit_blocks]

    @property
    def raw(self) -> list[RawRemainder]:
        return [rr for s in self.shards for rr in s.raw]

    def log_query(self, q: Query) -> None:
        self._store.log_query(q)

    def pushed_by_epoch(self, q: Query) -> _EpochPushdown:
        m = _EpochPushdown(self, q)
        m[self.plan.epoch]
        return m

    def resident_group_rows(self) -> dict[tuple[int, int], int]:
        out: dict[tuple[int, int], int] = {}
        for s in self.shards:
            for k, n in s.resident_group_rows().items():
                out[k] = out.get(k, 0) + n
        return out

    def promote_uncovered_raw(
        self, pushed: _EpochPushdown,
    ) -> dict[tuple[int, int], int]:
        out: dict[tuple[int, int], int] = {}
        for s in self.shards:
            for k, n in s.promote_uncovered_raw(pushed).items():
                out[k] = out.get(k, 0) + n
        return out

    def close(self) -> None:
        """Retire every per-shard snapshot (see
        :meth:`repro.core.server.StoreSnapshot.close`).  Idempotent."""
        for s in self.shards:
            s.close()


# ---------------------------------------------------------------------------
# row placement primitives (shared by offline reshard + online migration)
# ---------------------------------------------------------------------------


def _account_rows(sh: CiaoStore, epoch: int, tier: int, k: int, *,
                  loaded: int = 0, jit: int = 0) -> None:
    """Adjust one shard's placement-derived counters by ``k`` rows.

    These are exactly the per-shard counters the scan executor consults
    (``group_records``/``group_loaded`` for pruned-shard attribution,
    ``_epoch_records`` + the ``LoadStats`` row counts for the empty-shard
    check and the parallel-dispatch heuristic).  ``k`` (and the
    ``loaded``/``jit`` row deltas) may be negative — a migration removes
    a segment from its source shard before re-placing its rows.
    """
    sh._epoch_records[epoch] = sh._epoch_records.get(epoch, 0) + k
    gkey = (epoch, tier)
    sh.group_records[gkey] = sh.group_records.get(gkey, 0) + k
    sh.stats.n_records += k
    if loaded:
        sh.group_loaded[gkey] = sh.group_loaded.get(gkey, 0) + loaded
        sh.stats.n_loaded += loaded
    if jit:
        sh.stats.n_jit_loaded += jit


def _split_by_shard(sid: np.ndarray, n_shards: int) -> dict[int, np.ndarray]:
    """shard -> row indices, omitting empty targets."""
    out: dict[int, np.ndarray] = {}
    for s in range(n_shards):
        idx = np.nonzero(sid == s)[0]
        if idx.size:
            out[s] = idx
    return out


def _place_loaded(tgt: CiaoStore, seg: ColumnarSegment, idx: np.ndarray,
                  sub_recs: list[bytes], sub_objs: list[dict],
                  bits: np.ndarray) -> None:
    """Append ``seg``'s rows at ``idx`` (with their bitvector slices) to
    ``tgt``'s open builder for the segment's coverage group."""
    tgt.segments.extend(
        tgt._builder(seg.epoch, seg.n_covered, seg.tier)
        .add(sub_recs, sub_objs, bits[:, idx]))


def _place_jit(tgt: CiaoStore, seg: ColumnarSegment,
               sub_recs: list[bytes], sub_objs: list[dict],
               cap: int) -> None:
    """Append JIT-promoted rows (no bitvectors) as fresh segments."""
    tgt.jit_segments.extend(build_segments(
        sub_recs, np.zeros((0, len(sub_recs)), bool), objs=sub_objs,
        epoch=seg.epoch, n_covered=seg.n_covered, tier=seg.tier,
        capacity=cap))


def _place_raw(tgt: CiaoStore, rr: RawRemainder, idx: np.ndarray) -> None:
    """Append the ``idx`` slice of one raw remainder."""
    tgt.raw.append(RawRemainder(
        data=rr.data[idx], lengths=rr.lengths[idx],
        epoch=rr.epoch, n_covered=rr.n_covered, tier=rr.tier))


class SegmentMigration:
    """Incremental background re-partition of one :class:`ShardedCiaoStore`.

    Created by :meth:`ShardedCiaoStore.begin_migration` (which swaps the
    router first, so new ingest needs no moving).  Each :meth:`step`
    drains up to ``batch_rows`` rows of the pre-swap work list; a single
    item (segment or raw remainder) moves atomically w.r.t. snapshots:

      1. route the item's rows with the NEW router OUTSIDE every lock
         (decode + crc are the expensive part);
      2. all-stay fast path: if every row already lives on its target
         shard, the item is untouched (the common case — only segments
         straddling a boundary change pay anything);
      3. else, under the store's migration fence: remove the item from
         its source shard (identity filter, negative accounting, version
         bump) and re-place each row slice on its target shard (summary
         update BEFORE placement — same ordering as live ingest — then
         builder/segment append, positive accounting, version bump).

    Source summaries are never rebuilt mid-migration: they stay
    monotone-over-permissive for departed rows (pruning remains sound,
    merely less sharp) until :meth:`finish` installs fresh exhaustive
    summaries per shard.  A raw remainder that a concurrent scan
    JIT-promoted away is simply skipped (``items_skipped``): its rows
    became resident jit segments of the SOURCE shard — stragglers the
    next migration can move; routing never affects correctness.

    At most one ``SegmentMigration`` should run at a time (the tuner is
    the single driver); ``step`` itself is safe against concurrent
    ingest, scans and snapshots by construction.
    """

    def __init__(self, store: ShardedCiaoStore, router: ShardRouter,
                 work: list[tuple[str, int, object]], *,
                 batch_rows: int = 4096):
        self.store = store
        self.router = router
        self._work = work
        self.batch_rows = int(batch_rows)
        self.rows_moved = 0
        self.rows_kept = 0
        self.segments_moved = 0
        self.items_skipped = 0
        self.batches = 0
        self.finished = False

    @property
    def done(self) -> bool:
        return self.finished

    @property
    def items_left(self) -> int:
        return len(self._work)

    def step(self, max_rows: int | None = None) -> int:
        """Process work items until ``max_rows`` rows were examined (or
        the work list drains, which auto-:meth:`finish`\\ es).  Returns
        the number of rows examined this call."""
        if self.finished:
            return 0
        budget = self.batch_rows if max_rows is None else int(max_rows)
        processed = 0
        while self._work and processed < budget:
            kind, src, item = self._work.pop()
            processed += self._move_item(kind, src, item)
        self.batches += 1
        if not self._work:
            self.finish()
        return processed

    def run(self) -> None:
        """Drain the whole work list (bounded batches, then finish)."""
        while not self.finished:
            self.step()

    def _move_item(self, kind: str, src: int, item) -> int:
        store = self.store
        sh = store.shards[src]
        if kind == "raw":
            rr: RawRemainder = item  # type: ignore[assignment]
            recs, objs = decode_rows(rr.data, rr.lengths)
            n = len(recs)
        else:
            seg: ColumnarSegment = item  # type: ignore[assignment]
            recs, objs = seg.records(), seg.rows
            n = seg.n_rows
        if n == 0:
            return 0
        sid = self.router.route(objs, recs)
        if int(np.count_nonzero(sid != src)) == 0:
            self.rows_kept += n
            return n
        split = _split_by_shard(sid, store.n_shards)
        with store._migration_lock:
            # remove from the source shard first: a fenced snapshot sees
            # the item either fully present or fully re-placed, and an
            # unfenced live reader can only transiently UNDERcount (the
            # same window a racing ingest always had)
            with sh._ingest_lock:
                if kind == "loaded":
                    if not any(g is item for g in sh.segments):
                        self.items_skipped += 1
                        return n
                    sh.segments = [g for g in sh.segments if g is not item]
                    _account_rows(sh, seg.epoch, seg.tier, -n, loaded=-n)
                elif kind == "jit":
                    if not any(g is item for g in sh.jit_segments):
                        self.items_skipped += 1
                        return n
                    sh.jit_segments = [
                        g for g in sh.jit_segments if g is not item]
                    _account_rows(sh, seg.epoch, seg.tier, -n, jit=-n)
                else:
                    # a concurrent scan may have JIT-promoted this
                    # remainder away; its rows are now source-resident
                    # jit segments outside this work list — skip
                    if not any(x is item for x in sh.raw):
                        self.items_skipped += 1
                        return n
                    sh.raw = [x for x in sh.raw if x is not item]
                    _account_rows(sh, rr.epoch, rr.tier, -n)
                sh.data_version += 1
            if kind == "loaded":
                bits = bitvector.unpack(seg.bitvectors, n)
            for dst, idx in split.items():
                tgt = store.shards[dst]
                sub_recs = [recs[i] for i in idx]
                sub_objs = [objs[i] for i in idx]
                with tgt._ingest_lock:
                    if dst != src:
                        # source rows are already covered by the source
                        # summary (over-permissive until finish())
                        store.summaries[dst].update(sub_objs)
                    if kind == "loaded":
                        _place_loaded(tgt, seg, idx, sub_recs, sub_objs,
                                      bits)
                        _account_rows(tgt, seg.epoch, seg.tier, len(idx),
                                      loaded=len(idx))
                    elif kind == "jit":
                        _place_jit(tgt, seg, sub_recs, sub_objs,
                                   store.segment_capacity)
                        _account_rows(tgt, seg.epoch, seg.tier, len(idx),
                                      jit=len(idx))
                    else:
                        _place_raw(tgt, rr, idx)
                        _account_rows(tgt, rr.epoch, rr.tier, len(idx))
                    tgt.data_version += 1
                    if dst != src:
                        self.rows_moved += len(idx)
                    else:
                        self.rows_kept += len(idx)
        self.segments_moved += 1
        return n

    def finish(self) -> None:
        """Install fresh exhaustive per-shard summaries and record the
        migration into the store's telemetry plane.  Idempotent; called
        automatically when :meth:`step` drains the work list.

        Each shard's summary is rebuilt from its ACTUAL resident rows
        (segments, jit segments, decoded raw) under that shard's ingest
        lock — a racing ingest either lands before the rebuild (its rows
        are counted) or blocks until the fresh summary is installed and
        then updates it.  Old snapshots keep the old summary objects
        (their ``summaries`` list was copied), so their pruning stays
        over-permissive, never unsound.
        """
        if self.finished:
            return
        self.finished = True
        store = self.store
        with store._migration_lock:
            for s, sh in enumerate(store.shards):
                old = store.summaries[s]
                with sh._ingest_lock:
                    fresh = ShardSummary(
                        exhaustive=store.n_shards > 1,
                        value_cap=old.value_cap)
                    for seg in (*sh.blocks, *sh.jit_blocks):
                        fresh.update(seg.rows)
                    for rr in sh.raw:
                        _, objs = decode_rows(rr.data, rr.lengths)
                        fresh.update(objs)
                    store.summaries[s] = fresh
        telemetry = getattr(store, "telemetry", None)
        if telemetry is not None:
            telemetry.record_tuner(
                migrations=1, rows_moved=self.rows_moved,
                rows_kept=self.rows_kept,
                segments_moved=self.segments_moved)


def reshard(store: "ShardedCiaoStore | CiaoStore",
            router: ShardRouter, *,
            segment_capacity: int | None = None) -> ShardedCiaoStore:
    """Offline re-partition of a store onto ``router`` (DESIGN.md §14).

    Every resident row — loaded segments, JIT-promoted segments, raw
    remainders — is routed to its new shard with its coverage metadata
    ``(epoch, n_covered, tier)`` and bitvector rows intact, so scan
    counts and coverage claims are preserved bit for bit (pinned by the
    migration tests).  Partition summaries are rebuilt exhaustively from
    the full row population, re-enabling pruning for stores migrated from
    pre-shard checkpoints.

    Statistics split by who reads them: the PER-SHARD counters the scan
    executor consults (``group_records``/``group_loaded`` for
    pruned-shard attribution, ``_epoch_records`` and the ``LoadStats``
    row counts for the empty-shard check and the parallel-dispatch
    heuristic) are re-derived from actual row placement, so they are
    exact for every target shard; the client-feedback arrays
    (``_epoch_counts``/``_epoch_clause_records`` — per-clause popcounts
    that cannot be attributed to rows after the fact) and the load-path
    timings are carried onto shard 0, where only their fleet SUM is ever
    read.

    Placement and accounting go through the same primitives the online
    :class:`SegmentMigration` uses (:func:`_place_loaded` /
    :func:`_place_jit` / :func:`_place_raw` / :func:`_account_rows`) —
    offline reshard is the degenerate migration where every item moves
    into a freshly built store with no concurrent readers.
    """
    src_shards = (store.shards if isinstance(store, ShardedCiaoStore)
                  else [store])
    src0 = src_shards[0]
    cap = segment_capacity or src0.segment_capacity
    current_family = src0.families[src0.plan.epoch]
    out = ShardedCiaoStore(current_family, router=router,
                           segment_capacity=cap)
    # graft the full epoch registry (shared plan objects) onto every shard
    epochs = sorted(src0.plans)
    for sh in out.shards:
        sh.plans = dict(src0.plans)
        sh.families = dict(src0.families)
        sh.plan = src0.plan
        sh.family = current_family
        sh._epoch_records = {e: 0 for e in epochs}
        sh._epoch_counts = {
            e: np.zeros((src0.plans[e].n,), np.int64) for e in epochs}
        sh._epoch_clause_records = {
            e: np.zeros((src0.plans[e].n,), np.int64) for e in epochs}
    # shard 0 carries the fleet-sum-only feedback state
    agg0 = out.shards[0]
    for src in src_shards:
        for e in epochs:
            for attr in ("_epoch_counts", "_epoch_clause_records"):
                v = getattr(src, attr).get(e)
                if v is not None:
                    getattr(agg0, attr)[e] += np.asarray(v, np.int64)
        st = src.stats
        agg0.stats.load_time_s += st.load_time_s
        agg0.stats.parse_time_s += st.parse_time_s
        agg0.stats.jit_time_s += st.jit_time_s
    out.query_log = list(
        store.query_log if isinstance(store, ShardedCiaoStore)
        else src0.query_log)

    def _scatter(recs: list[bytes], objs: list[dict],
                 place: Callable[[int, np.ndarray, list, list], None]
                 ) -> None:
        sid = router.route(objs, recs)
        for s, idx in _split_by_shard(sid, router.n_shards).items():
            sub_recs = [recs[i] for i in idx]
            sub_objs = [objs[i] for i in idx]
            out.summaries[s].update(sub_objs)
            place(s, idx, sub_recs, sub_objs)

    for src in src_shards:
        for seg in src.blocks:
            bits = bitvector.unpack(seg.bitvectors, seg.n_rows)

            def _loaded(s, idx, sub_recs, sub_objs, seg=seg, bits=bits):
                _place_loaded(out.shards[s], seg, idx, sub_recs, sub_objs,
                              bits)
                _account_rows(out.shards[s], seg.epoch, seg.tier, len(idx),
                              loaded=len(idx))

            _scatter(seg.records(), seg.rows, _loaded)
        for seg in src.jit_blocks:
            def _jit(s, idx, sub_recs, sub_objs, seg=seg):
                _place_jit(out.shards[s], seg, sub_recs, sub_objs, cap)
                _account_rows(out.shards[s], seg.epoch, seg.tier, len(idx),
                              jit=len(idx))

            _scatter(seg.records(), seg.rows, _jit)
        for rr in src.raw:
            recs, objs = decode_rows(rr.data, rr.lengths)

            def _raw(s, idx, sub_recs, sub_objs, rr=rr):
                _place_raw(out.shards[s], rr, idx)
                _account_rows(out.shards[s], rr.epoch, rr.tier, len(idx))

            _scatter(recs, objs, _raw)
    return out


def merge_scan_results(results: Sequence[ScanResult]) -> ScanResult:
    """Deterministic scatter-gather merge of per-shard scan results.

    Routed through :func:`repro.dist.collectives.tree_reduce` — the
    association order is fixed by shard position, never by completion
    order — and normalized to the :class:`ScanResult` groups ordering
    contract (ascending (epoch, tier) keys).  Counters sum; per-group
    :class:`TierScan` breakdowns sum field-wise; ``used_skipping`` ORs.
    ``time_s`` is the summed per-shard scan time (the executor overwrites
    it with scatter-gather wall clock).
    """

    def _merge2(a: ScanResult, b: ScanResult) -> ScanResult:
        groups: dict[tuple[int, int], TierScan] = {}
        for src in (a.groups, b.groups):
            for k, g in src.items():
                t = groups.setdefault(k, TierScan())
                t.rows_scanned += g.rows_scanned
                t.rows_skipped += g.rows_skipped
                t.raw_parsed += g.raw_parsed
                t.count += g.count
                t.segments_pruned += g.segments_pruned
        return ScanResult(
            count=a.count + b.count,
            rows_scanned=a.rows_scanned + b.rows_scanned,
            rows_skipped=a.rows_skipped + b.rows_skipped,
            raw_parsed=a.raw_parsed + b.raw_parsed,
            time_s=a.time_s + b.time_s,
            used_skipping=a.used_skipping or b.used_skipping,
            groups=groups,
            segments_pruned=a.segments_pruned + b.segments_pruned,
            segments_scanned=a.segments_scanned + b.segments_scanned,
            shards_scanned=a.shards_scanned + b.shards_scanned,
            shards_pruned=a.shards_pruned + b.shards_pruned,
        )

    # seed with a neutral element: the reduction then always allocates a
    # fresh result, so callers may mutate the merge output even when a
    # single (possibly cached/shared) input was passed
    zero = ScanResult(count=0, rows_scanned=0, rows_skipped=0, raw_parsed=0,
                      time_s=0.0, used_skipping=False)
    merged = collectives.tree_reduce([zero, *results], _merge2)
    merged.sort_groups()
    return merged


class ShardedScanner:
    """Scatter-gather COUNT(*) over a :class:`ShardedCiaoStore`.

    The three-level skipping cascade in execution order:

      1. **partition prune** — shards whose :class:`ShardSummary` refutes
         any query clause are skipped whole (their loaded + JIT segment
         rows land in the merged result as ``rows_skipped``, attributed
         per (epoch, tier) group — the same population a scanned shard
         reports; no JIT promotion happens in a refuted shard, so its
         raw-remainder rows stay out of the accounting on both paths);
      2. **per-shard scan** — surviving shards run the monolithic
         :class:`DataSkippingScanner` (zone-prune -> pushed-bitvector AND
         -> vectorized residual) concurrently on a thread pool;
      3. **deterministic merge** — results gather in stable shard order
         and reduce through :func:`merge_scan_results`.

    Counts are bit-identical to the unsharded oracle by construction
    (rows partition the shards; every level of skipping is sound).
    """

    def __init__(self, store: ShardedCiaoStore, *, log_queries: bool = True,
                 and_reduce: Callable | None = None,
                 max_workers: int | None = None,
                 parallel_threshold_rows: int = 1 << 20,
                 cache: "object | None" = None,
                 telemetry: "TelemetryPlane | bool | None" = None,
                 tenant: str = "default"):
        self.store = store
        self.log_queries = log_queries
        # optional core.batch_scan.ResultCache (duck-typed to avoid the
        # import cycle): per-shard entries under the shared (shard,
        # clauses) keys, validated per shard (epoch, data_version)
        self.cache = cache
        if telemetry is None:
            telemetry = getattr(store, "telemetry", None)
        self.telemetry = telemetry if isinstance(telemetry, TelemetryPlane) \
            else None
        self.tenant = tenant
        self._scanners = [
            DataSkippingScanner(s, log_queries=False, and_reduce=and_reduce,
                                telemetry=False)
            for s in store.shards
        ]
        self._max_workers = max_workers or min(
            store.n_shards, os.cpu_count() or 1)
        # thread dispatch + future gather costs O(100µs)+ per query while
        # the workers contend for the GIL on small per-shard scans: fan
        # out only when the surviving shards hold enough rows (>= 1M by
        # default) for the numpy-released sections to amortize it, else
        # run the shard loop inline (same results, no pool round-trip)
        self.parallel_threshold_rows = parallel_threshold_rows
        self._pool: ThreadPoolExecutor | None = None
        # scan() may run from many serve-plane reader threads at once;
        # without the lock two of them could race _ensure_pool and leak
        # an executor
        self._pool_lock = threading.Lock()

    def _ensure_pool(self) -> ThreadPoolExecutor:
        with self._pool_lock:
            if self._pool is None:
                self._pool = ThreadPoolExecutor(
                    max_workers=self._max_workers,
                    thread_name_prefix="ciao-shard-scan")
            return self._pool

    def close(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None

    def __enter__(self) -> "ShardedScanner":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    def scan(self, q: Query) -> ScanResult:
        t0 = time.perf_counter()
        store = self.store
        if self.log_queries:
            store.log_query(q)
        run: list[int] = []
        pruned: list[int] = []
        hits: dict[int, ScanResult] = {}
        run_rows = 0
        for s in range(store.n_shards):
            shard = store.shards[s]
            if not (shard.stats.n_records or shard.blocks
                    or shard.jit_blocks or shard.raw):
                continue  # empty shard: contributes nothing
            if store.n_shards > 1 and not store.summaries[s].query_possible(q):
                pruned.append(s)
                continue
            if self.cache is not None:
                r = self.cache.lookup(s, q, epoch=shard.plan.epoch,
                                      data_version=shard.data_version)
                if r is not None:
                    hits[s] = r   # already a private copy
                    continue
            run.append(s)
            run_rows += shard.stats.n_records
        use_pool = (len(run) > 1 and self._max_workers > 1
                    and run_rows >= self.parallel_threshold_rows)
        if use_pool:
            pool = self._ensure_pool()
            futures = [pool.submit(self._scanners[s].scan, q) for s in run]
            scanned = [f.result() for f in futures]  # stable shard order
        else:
            scanned = [self._scanners[s].scan(q) for s in run]
        if self.cache is not None:
            for s, r in zip(run, scanned):
                # post-scan version: the scan's own JIT promotions are
                # folded in, so a valid future hit implies a re-scan
                # would promote nothing and counts stay bit-identical
                self.cache.store(s, q, r, epoch=store.shards[s].plan.epoch,
                                 data_version=store.shards[s].data_version)
        by_shard = dict(zip(run, scanned)) | hits
        results = [by_shard[s] for s in sorted(by_shard)]
        for r in results:
            r.shards_scanned = 1
        if results:
            merged = merge_scan_results(results)
        else:
            merged = ScanResult(count=0, rows_scanned=0, rows_skipped=0,
                                raw_parsed=0, time_s=0.0,
                                used_skipping=False)
        # refuted shards contribute their resident SEGMENT rows (loaded +
        # JIT-promoted) as skipped — the same population a scanned shard
        # reports, so skip rates stay comparable between the pruned and
        # scanned paths (and with the unsharded scanner).  Raw-remainder
        # rows appear on neither path: a scanned shard only surfaces them
        # once promotion parses them (raw_parsed), and a refuted shard
        # never promotes
        for s in pruned:
            merged.shards_pruned += 1
            for (e, t), n in store.shards[s].resident_group_rows().items():
                merged.group(e, t).rows_skipped += n
                merged.rows_skipped += n
        if pruned:
            merged.sort_groups()
        if not results:
            # nothing scanned (all shards pruned or empty): resolve the
            # current epoch's pushdown the way an empty monolithic scan
            # would.  When shards DID run, their merged used_skipping is
            # already correct — the per-shard scanner resolved pushdown
            # per SEGMENT epoch, which a current-epoch-only recomputation
            # here would clobber (e.g. a clause pushed under epoch 0 but
            # dropped by the epoch-1 replan must still report True)
            merged.used_skipping = any(store.pushed_by_epoch(q).values())
        merged.time_s = time.perf_counter() - t0
        if self.telemetry is not None:
            self.telemetry.record_scan(merged, tenant=self.tenant,
                                       cache_hits=len(hits),
                                       cache_misses=len(run))
        return merged
